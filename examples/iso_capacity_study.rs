//! Full iso-capacity study (paper §IV-A): regenerates Figs 3, 4 and 5
//! across the five-DNN zoo and writes the CSVs to `results/`.
//!
//! Run: `cargo run --release --example iso_capacity_study`

use deepnvm::coordinator::reports;
use deepnvm::coordinator::store::Store;

fn main() -> anyhow::Result<()> {
    let mut store = Store::new("results");

    let (f3, f4) = reports::fig3_fig4();
    println!("{}", f3.text);
    println!("{}", f4.text);
    store.save(&f3)?;
    store.save(&f4)?;

    let f5 = reports::fig5(&[1, 4, 16, 64, 128, 256]);
    println!("{}", f5.text);
    store.save(&f5)?;

    store.finish(&[("study", "iso_capacity")])?;
    println!("CSVs written to results/ (f3.csv, f4.csv, f5.csv)");
    Ok(())
}
