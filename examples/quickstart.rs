//! Quickstart: the DeepNVM++ flow in one page.
//!
//! 1. Characterize STT/SOT bitcells from device physics (Table I flow).
//! 2. EDAP-tune SRAM/STT/SOT caches at the 1080 Ti's 3 MB (Table II).
//! 3. Evaluate one DL workload on each cache and print the headline
//!    energy/EDP reductions (Fig 4's money numbers).
//!
//! Run: `cargo run --release --example quickstart`

use deepnvm::analysis::{evaluate, DramCost};
use deepnvm::device::{characterize, MemTech};
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::TrafficModel;

const MB: u64 = 1024 * 1024;

fn main() {
    // -- 1. device layer ---------------------------------------------
    println!("== 1. circuit-level bitcell characterization (LLGS + RC) ==");
    let cells = characterize::characterize();
    println!(
        "  STT: {} write fins, set {:.1} ns / {:.2} pJ, sense {:.0} ps, area {:.2}x SRAM",
        cells.stt.fins_write,
        cells.stt.write_latency_set * 1e9,
        cells.stt.write_energy_set * 1e12,
        cells.stt.sense_latency * 1e12,
        cells.stt.area_rel
    );
    println!(
        "  SOT: {}+{} fins, set {:.0} ps / {:.3} pJ, sense {:.0} ps, area {:.2}x SRAM",
        cells.sot.fins_write,
        cells.sot.fins_read,
        cells.sot.write_latency_set * 1e12,
        cells.sot.write_energy_set * 1e12,
        cells.sot.sense_latency * 1e12,
        cells.sot.area_rel
    );

    // -- 2. cache layer ----------------------------------------------
    println!("\n== 2. EDAP-optimal 3 MB last-level caches (NVSim-class model) ==");
    let designs: Vec<_> = MemTech::ALL
        .iter()
        .map(|&t| (t, tuned_cache(t, 3 * MB)))
        .collect();
    for (t, d) in &designs {
        println!(
            "  {:<9} read {:.2} ns, write {:.2} ns, leak {:>5.0} mW, area {:.2} mm2  [{}]",
            t.name(),
            d.ppa.read_latency * 1e9,
            d.ppa.write_latency * 1e9,
            d.ppa.leakage_power * 1e3,
            d.ppa.area * 1e6,
            d.opt.name()
        );
    }

    // -- 3. workload analysis ----------------------------------------
    println!("\n== 3. ResNet-18 inference (batch 4) on each cache ==");
    let dnn = Dnn::by_name("ResNet-18").unwrap();
    let stats =
        TrafficModel::default().run_paper(&dnn, Phase::Inference);
    println!(
        "  L2 traffic: {:.1} M reads, {:.1} M writes (R/W {:.1}), {:.1} M DRAM tx",
        stats.l2_reads as f64 / 1e6,
        stats.l2_writes as f64 / 1e6,
        stats.rw_ratio(),
        stats.dram_total() as f64 / 1e6
    );
    let sram = evaluate(&stats, &designs[0].1.ppa, Some(DramCost::default()));
    for (t, d) in &designs[1..] {
        let e = evaluate(&stats, &d.ppa, Some(DramCost::default()));
        println!(
            "  {:<9} energy {:.1}x lower, EDP {:.1}x lower than SRAM",
            t.name(),
            sram.energy() / e.energy(),
            sram.edp() / e.edp()
        );
    }
    println!("\npaper headline (iso-capacity): EDP up to 3.8x (STT) / 4.7x (SOT) lower");
}
