//! Scalability study (paper §IV-C): EDAP-tunes every memory at every
//! capacity 1-32 MB (Fig 9) and projects normalized workload
//! energy/latency/EDP with cross-workload error bars (Fig 10).
//!
//! Run: `cargo run --release --example scalability_study`

use deepnvm::coordinator::reports;
use deepnvm::coordinator::store::Store;

fn main() -> anyhow::Result<()> {
    let caps: Vec<u64> = vec![1, 2, 4, 8, 16, 32];
    let mut store = Store::new("results");

    let f9 = reports::fig9(&caps);
    println!("{}", f9.text);
    store.save(&f9)?;

    let f10 = reports::fig10(&caps);
    println!("{}", f10.text);
    store.save(&f10)?;

    store.finish(&[("study", "scalability")])?;
    println!("CSVs written to results/ (f9.csv, f10.csv)");
    Ok(())
}
