//! Full iso-area study (paper §IV-B): runs the GPU hierarchy simulator
//! to regenerate Fig 6 (DRAM-access reduction vs L2 capacity), then the
//! iso-area energy/EDP analyses of Figs 7-8 using the measured
//! reductions. Writes CSVs to `results/`.
//!
//! Run: `cargo run --release --example iso_area_study [--quick]`

use deepnvm::analysis::iso_area;
use deepnvm::coordinator::reports;
use deepnvm::coordinator::store::Store;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let batch = if quick { 1 } else { 4 };
    let mut store = Store::new("results");

    println!("simulating AlexNet through the memory hierarchy (batch {batch})...");
    let f6 = reports::fig6(batch);
    println!("{}", f6.text);
    store.save(&f6)?;

    // feed the measured reductions into the energy/EDP analysis
    let red_stt = iso_area::dram_reduction_at(iso_area::STT_MB, batch);
    let red_sot = iso_area::dram_reduction_at(iso_area::SOT_MB, batch);
    println!(
        "measured DRAM reductions: STT@7MB {:.1}%, SOT@10MB {:.1}%\n",
        red_stt * 100.0,
        red_sot * 100.0
    );
    let (f7, f8) = reports::fig7_fig8(Some((red_stt, red_sot)));
    println!("{}", f7.text);
    println!("{}", f8.text);
    store.save(&f7)?;
    store.save(&f8)?;

    store.finish(&[("study", "iso_area")])?;
    println!("CSVs written to results/ (f6.csv, f7.csv, f8.csv)");
    Ok(())
}
