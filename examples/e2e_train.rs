//! End-to-end driver: proves all three layers compose on a real
//! workload.
//!
//! 1. **Execute**: load the AOT-compiled `tinycnn_train_step` artifact
//!    (L1 Pallas GEMM/conv kernels inside an L2 JAX fwd+bwd+SGD graph)
//!    on CPU PJRT and train it for a few hundred steps on synthetic
//!    labeled data, logging the loss curve (results/e2e_loss.csv).
//! 2. **Analyze**: feed the *same network* (as a layer table) through
//!    the DeepNVM++ pipeline — traffic model -> EDAP-tuned caches ->
//!    energy/EDP — and report the paper's headline metric for the
//!    workload we just executed.
//!
//! Run: `make artifacts && cargo run --release --example e2e_train [steps]`

use deepnvm::analysis::{evaluate, DramCost};
use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::runtime::{trainer, Engine};
use deepnvm::util::csv::Csv;
use deepnvm::workload::models::{Dnn, Layer, LayerKind, Phase};
use deepnvm::workload::traffic::TrafficModel;

const MB: u64 = 1024 * 1024;

/// TinyCNN as a workload-zoo entry (must mirror python/compile/model.py).
fn tinycnn_dnn() -> Dnn {
    let conv = |name: &str, cin, cout, in_hw| Layer {
        name: name.to_string(),
        kind: LayerKind::Conv { k: 3, stride: 1, pad: 1, cin, cout, groups: 1 },
        in_hw,
        out_hw: in_hw,
    };
    let fc = |name: &str, din, dout| Layer {
        name: name.to_string(),
        kind: LayerKind::Fc { din, dout },
        in_hw: 1,
        out_hw: 1,
    };
    Dnn {
        name: "TinyCNN",
        top5_error: f64::NAN,
        layers: vec![
            conv("conv1", 3, 16, 16),
            Layer { name: "pool1".into(), kind: LayerKind::Pool { k: 2, stride: 2 }, in_hw: 16, out_hw: 8 },
            conv("conv2", 16, 32, 8),
            Layer { name: "pool2".into(), kind: LayerKind::Pool { k: 2, stride: 2 }, in_hw: 8, out_hw: 4 },
            fc("fc1", 512, 64),
            fc("fc2", 64, 10),
        ],
    }
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // ---- 1. execute ----------------------------------------------------
    println!("== phase 1: train TinyCNN via PJRT (AOT Pallas/JAX artifact) ==");
    let engine = Engine::default()?;
    println!("platform: {}", engine.platform());
    let (report, params) = trainer::train(&engine, steps, 0.05, 7, |s, l| {
        if s % 25 == 0 {
            println!("  step {s:>4}  loss {l:.4}");
        }
    })?;
    let acc = trainer::eval_accuracy(&engine, &params, 999)?;
    println!(
        "  {} steps x batch {} in {:.1}s ({:.1} steps/s)",
        report.steps, report.batch, report.seconds, report.steps_per_sec()
    );
    println!(
        "  loss {:.3} -> {:.3}; held-out accuracy {:.0}% (chance 10%)",
        report.first_loss(),
        report.last_loss(),
        acc * 100.0
    );
    assert!(
        report.last_loss() < report.first_loss(),
        "training must reduce the loss"
    );

    let mut csv = Csv::new(&["step", "loss"]);
    for (i, l) in report.losses.iter().enumerate() {
        csv.row(&[i.to_string(), format!("{l:.6}")]);
    }
    csv.write("results/e2e_loss.csv")?;
    println!("  loss curve -> results/e2e_loss.csv");

    // ---- 2. analyze -----------------------------------------------------
    println!("\n== phase 2: DeepNVM++ analysis of the same workload ==");
    let dnn = tinycnn_dnn();
    let stats = TrafficModel::default().run(&dnn, Phase::Training, report.batch);
    println!(
        "  per-step L2 traffic: {:.2} M reads / {:.2} M writes (R/W {:.1}), {:.2} M DRAM tx",
        stats.l2_reads as f64 / 1e6,
        stats.l2_writes as f64 / 1e6,
        stats.rw_ratio(),
        stats.dram_total() as f64 / 1e6
    );

    let dram = DramCost::default();
    let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
    let base = evaluate(&stats, &sram, Some(dram));
    println!(
        "  SRAM 3MB baseline: {:.2} uJ, {:.1} us per training step (cache+DRAM model)",
        base.energy() * 1e6,
        base.time_total * 1e6
    );
    for tech in [MemTech::SttMram, MemTech::SotMram] {
        let ppa = tuned_cache(tech, 3 * MB).ppa;
        let e = evaluate(&stats, &ppa, Some(dram));
        println!(
            "  {:<9} energy {:.1}x lower, EDP {:.1}x lower (headline metric)",
            tech.name(),
            base.energy() / e.energy(),
            base.edp() / e.edp()
        );
    }
    println!("\nall three layers composed: Pallas kernel -> JAX graph -> HLO -> PJRT -> analysis");
    Ok(())
}
