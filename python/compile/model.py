"""L2: JAX compute graphs for the DL workloads DeepNVM++ analyzes.

Two roles:

1. **Executable workloads** for the Rust runtime (AOT-lowered to HLO text
   by ``aot.py``): a parameterized CNN family scaled so that
   interpret-mode Pallas kernels run in reasonable time on CPU-PJRT.
   ``tinycnn`` is trainable end-to-end (fwd + bwd + SGD step fused into a
   single donated-buffer HLO module) and drives ``examples/e2e_train.rs``.

2. **Ground truth** for the analytic per-layer memory model: every conv /
   dense here routes through the L1 Pallas kernels, whose BlockSpec
   schedule is what ``rust/src/workload/traffic.rs`` models analytically
   for the full-size networks (AlexNet..SqueezeNet, Table III).

All parameters travel as flat tuples (stable ordering) so the Rust side
can allocate/feed buffers without pytree machinery.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import conv2d, matmul


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------

def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


# TinyCNN: 16x16x3 -> conv3x3(16) -> relu -> pool2 -> conv3x3(32) -> relu
#          -> pool2 -> flatten(512) -> dense(64) -> relu -> dense(10)
TINYCNN_IMG = 16
TINYCNN_CLASSES = 10
TINYCNN_PARAM_SHAPES = [
    ("conv1_w", (3, 3, 3, 16)),
    ("conv1_b", (16,)),
    ("conv2_w", (3, 3, 16, 32)),
    ("conv2_b", (32,)),
    ("fc1_w", (512, 64)),
    ("fc1_b", (64,)),
    ("fc2_w", (64, 10)),
    ("fc2_b", (10,)),
]


def tinycnn_init(seed: int = 0):
    """He-initialized TinyCNN parameters as a flat tuple."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(TINYCNN_PARAM_SHAPES))
    params = []
    for key, (name, shape) in zip(keys, TINYCNN_PARAM_SHAPES):
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(jnp.prod(jnp.array(shape[:-1])))
            params.append(_he(key, shape, fan_in))
    return tuple(params)


def _maxpool2(x):
    """2x2/2 max pool, NHWC."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def tinycnn_logits(params, x):
    """TinyCNN forward. x: (N, 16, 16, 3) -> (N, 10). All convs and
    denses route through the L1 Pallas kernels."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    h = conv2d(x, c1w, stride=1, padding=1) + c1b
    h = _maxpool2(jax.nn.relu(h))
    h = conv2d(h, c2w, stride=1, padding=1) + c2b
    h = _maxpool2(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(matmul(h, f1w, None) + f1b)
    return matmul(h, f2w, None) + f2b


def tinycnn_loss(params, x, y):
    """Mean softmax cross-entropy; y: (N,) int32 labels."""
    logits = tinycnn_logits(params, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def tinycnn_train_step(params, x, y, lr):
    """One fused SGD step: returns (loss, *new_params).

    Lowered as a single HLO module; the Rust e2e driver threads the
    returned params back in each step (buffer donation happens at the
    PJRT level via aot.py's donate_argnums).
    """
    loss, grads = jax.value_and_grad(tinycnn_loss)(params, x, y)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (loss,) + new_params


def tinycnn_accuracy(params, x, y):
    """Top-1 accuracy over a batch."""
    pred = jnp.argmax(tinycnn_logits(params, x), axis=1)
    return jnp.mean((pred == y).astype(jnp.float32))


# --------------------------------------------------------------------------
# MicroAlexNet: a faithfully-shaped (conv-stack) AlexNet scaled to 32x32
# inputs so interpret-mode Pallas runs on CPU. Used by the runtime to
# validate that the workload zoo's layer walk matches an executable graph.
# --------------------------------------------------------------------------

MICROALEX_IMG = 32
MICROALEX_LAYERS = [
    # (name, kind, params) mirroring AlexNet's 5-conv/3-fc topology.
    ("conv1", "conv", dict(k=3, cin=3, cout=16, stride=1, pad=1)),
    ("pool1", "pool", {}),
    ("conv2", "conv", dict(k=3, cin=16, cout=32, stride=1, pad=1)),
    ("pool2", "pool", {}),
    ("conv3", "conv", dict(k=3, cin=32, cout=48, stride=1, pad=1)),
    ("conv4", "conv", dict(k=3, cin=48, cout=48, stride=1, pad=1)),
    ("conv5", "conv", dict(k=3, cin=48, cout=32, stride=1, pad=1)),
    ("pool5", "pool", {}),
    ("fc6", "fc", dict(din=32 * 4 * 4, dout=256)),
    ("fc7", "fc", dict(din=256, dout=128)),
    ("fc8", "fc", dict(din=128, dout=10)),
]


def microalex_init(seed: int = 1):
    key = jax.random.PRNGKey(seed)
    params = []
    for name, kind, p in MICROALEX_LAYERS:
        if kind == "conv":
            key, k1 = jax.random.split(key)
            fan_in = p["k"] * p["k"] * p["cin"]
            params.append(_he(k1, (p["k"], p["k"], p["cin"], p["cout"]), fan_in))
            params.append(jnp.zeros((p["cout"],), jnp.float32))
        elif kind == "fc":
            key, k1 = jax.random.split(key)
            params.append(_he(k1, (p["din"], p["dout"]), p["din"]))
            params.append(jnp.zeros((p["dout"],), jnp.float32))
    return tuple(params)


def microalex_logits(params, x):
    """MicroAlexNet forward, x: (N, 32, 32, 3) -> (N, 10)."""
    it = iter(params)
    h = x
    for name, kind, p in MICROALEX_LAYERS:
        if kind == "conv":
            w, b = next(it), next(it)
            h = jax.nn.relu(conv2d(h, w, stride=p["stride"], padding=p["pad"]) + b)
        elif kind == "pool":
            h = _maxpool2(h)
        elif kind == "fc":
            w, b = next(it), next(it)
            if h.ndim > 2:
                h = h.reshape(h.shape[0], -1)
            h = matmul(h, w, None) + b
            if name != "fc8":
                h = jax.nn.relu(h)
    return h


# --------------------------------------------------------------------------
# Standalone GEMM workload (microbenchmark artifact for the runtime).
# --------------------------------------------------------------------------

def gemm(a, b):
    """Single Pallas GEMM as its own artifact (runtime smoke/bench)."""
    return matmul(a, b, None)
