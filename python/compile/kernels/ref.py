"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package must match its oracle to float32 tolerance;
pytest (python/tests/test_kernels.py) enforces it with hypothesis sweeps
over shapes and dtypes. These functions are intentionally the most naive
correct implementations available.
"""

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference GEMM."""
    return jnp.matmul(a, b, preferred_element_type=jnp.result_type(a, b))


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 0):
    """Reference NHWC conv2d via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
