"""L1 Pallas kernels: the compute hot-spot of the DL workloads that
DeepNVM++ analyzes.

Everything here is build-time only: kernels are lowered (interpret=True,
CPU-PJRT compatible) into the L2 model HLO by ``compile/aot.py`` and then
executed from the Rust coordinator. The BlockSpec tiling schedule in
``matmul.py`` is mirrored by ``rust/src/workload/trace.rs`` to generate
the L2-cache transaction traces for the architecture-level analysis.
"""

from .matmul import matmul, matmul_pallas, MatmulConfig, default_config
from .conv import conv2d, conv2d_im2col
from . import ref

__all__ = [
    "matmul",
    "matmul_pallas",
    "MatmulConfig",
    "default_config",
    "conv2d",
    "conv2d_im2col",
    "ref",
]
