"""Conv2D expressed as im2col + the Pallas tiled matmul (L1).

This mirrors how the paper's GPU workloads (Caffe on a 1080 Ti) actually
execute convolutions: im2col lowering followed by a blocked SGEMM. The
per-layer L2-transaction model in rust/src/workload/traffic.rs is derived
from exactly this schedule (ifmap patch reads, filter block reads, ofmap
writes), so the kernel is the single source of truth for the memory
behaviour DeepNVM++ analyzes.
"""

import jax
import jax.numpy as jnp

from .matmul import matmul, MatmulConfig, default_config


def conv2d_im2col(
    x: jax.Array,
    w: jax.Array,
    stride: int = 1,
    padding: int = 0,
    cfg: MatmulConfig | None = None,
) -> jax.Array:
    """NHWC conv via im2col + Pallas GEMM.

    x: (N, H, W, Cin); w: (KH, KW, Cin, Cout) -> (N, HO, WO, Cout).
    ``conv_general_dilated_patches`` is differentiable, and the GEMM has a
    custom VJP, so the whole op trains.
    """
    n, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, f"channel mismatch {cin} vs {cin2}"

    ho = (h + 2 * padding - kh) // stride + 1
    wo = (wdt + 2 * padding - kw) // stride + 1

    # (N, HO, WO, Cin*KH*KW) patches; feature dim ordered (cin, kh, kw).
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    m = n * ho * wo
    kdim = cin * kh * kw
    a = patches.reshape(m, kdim)
    # Match the patch feature order (cin, kh, kw).
    b = jnp.transpose(w, (2, 0, 1, 3)).reshape(kdim, cout)

    if cfg is None:
        cfg = default_config(m, kdim, cout)
    y = matmul(a, b, cfg)
    return y.reshape(n, ho, wo, cout)


def conv2d(x, w, stride=1, padding=0, cfg=None):
    """Public conv entry point (alias for the im2col/GEMM path)."""
    return conv2d_im2col(x, w, stride=stride, padding=padding, cfg=cfg)
