"""Tiled Pallas matmul kernel (L1).

The kernel expresses the HBM->VMEM block schedule with BlockSpecs: grid
(M/bm, N/bn, K/bk), blocks of A (bm x bk), B (bk x bn), accumulating into
an output block (bm x bn) kept resident in VMEM across the K axis.

TPU adaptation notes (DESIGN.md SS2): the paper's workloads ran on a GPU
where the analogous schedule is threadblock tiling through the L2/shared
memory. On TPU the block shapes are chosen so that
  bm*bk + bk*bn + bm*bn  floats fit comfortably in VMEM (~16 MB/core)
and bm/bn/bk are multiples of the MXU systolic tile (128) when the
problem is large enough. interpret=True is mandatory here: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

A custom VJP is provided so the L2 training graph can differentiate
through the kernel (dA = dY @ B^T, dB = A^T @ dY, both computed with the
same Pallas kernel).
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class MatmulConfig:
    """Block schedule for the tiled matmul.

    The same numbers drive rust/src/workload/trace.rs when generating
    cache-line traces for gpusim.
    """

    bm: int = 128
    bn: int = 128
    bk: int = 128

    def vmem_bytes(self, dtype_bytes: int = 4) -> int:
        """Resident VMEM footprint of one grid step (A, B and O blocks)."""
        return dtype_bytes * (
            self.bm * self.bk + self.bk * self.bn + self.bm * self.bn
        )

    def mxu_utilization(self) -> float:
        """Fraction of MXU 128x128 systolic tiles that carry real work."""
        def frac(b):
            return min(b, 128) / 128.0

        return frac(self.bm) * frac(self.bn)


def default_config(m: int, k: int, n: int) -> MatmulConfig:
    """Pick a block schedule for the given problem.

    Shrinks blocks for small problems so padding waste stays bounded,
    keeps MXU-aligned 128 tiles for large ones.
    """

    def pick(dim, pref):
        b = pref
        while b > 8 and b > dim:
            b //= 2
        return max(b, 8)

    return MatmulConfig(bm=pick(m, 128), bn=pick(n, 128), bk=pick(k, 128))


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One grid step: o[bm,bn] (+)= a[bm,bk] @ b[bk,bn].

    Grid is (M/bm, N/bn, K/bk) with K innermost; the output block stays
    resident while K streams through VMEM (the "accumulate in scratch"
    pattern - on real TPU this keeps partial sums out of HBM entirely).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def matmul_pallas(a: jax.Array, b: jax.Array, cfg: MatmulConfig) -> jax.Array:
    """Raw pallas_call wrapper: pads to block multiples, runs the grid,
    slices the result back. No autodiff rule - see ``matmul``."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dims mismatch: {k} vs {k2}"
    out_dtype = jnp.result_type(a.dtype, b.dtype)

    ap = _pad_to(a, cfg.bm, cfg.bk)
    bp = _pad_to(b, cfg.bk, cfg.bn)
    mp, kp = ap.shape
    _, np_ = bp.shape

    grid = (mp // cfg.bm, np_ // cfg.bn, kp // cfg.bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.bm, cfg.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cfg.bk, cfg.bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((cfg.bm, cfg.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(ap, bp)
    return out[:m, :n]


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul(a: jax.Array, b: jax.Array, cfg: MatmulConfig | None = None):
    """Differentiable tiled matmul; the public kernel entry point."""
    if cfg is None:
        cfg = default_config(a.shape[0], a.shape[1], b.shape[1])
    return matmul_pallas(a, b, cfg)


def _matmul_fwd(a, b, cfg):
    return matmul(a, b, cfg), (a, b)


def _matmul_bwd(cfg, res, dy):
    a, b = res
    # Both grads reuse the same Pallas kernel (transposed operands), so
    # the backward pass exercises the identical HBM<->VMEM schedule.
    da = matmul(dy, b.T, None)
    db = matmul(a.T, dy, None)
    return da.astype(a.dtype), db.astype(b.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
