"""AOT compile path: lower the L2 JAX workloads to HLO **text** artifacts.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
``HloModuleProto`` — is the interchange format. jax >= 0.5 emits protos
with 64-bit instruction ids which the runtime's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the HLO *text* parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out ../artifacts`` from ``python/``
(the Makefile `artifacts` target). Emits one ``<name>.hlo.txt`` per
workload plus ``manifest.json`` describing argument/result shapes so the
Rust runtime (rust/src/runtime/artifact.rs) can allocate buffers without
re-parsing HLO.

Python runs ONLY here: never on the analysis/request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _meta(specs):
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in specs
    ]


def build_artifacts():
    """Return {name: (lowered, in_specs, out_specs, extra_meta)}."""
    arts = {}

    # --- Standalone Pallas GEMM microbenchmark -------------------------
    m = k = n = 128
    gemm_in = [_spec((m, k)), _spec((k, n))]
    arts["gemm_128"] = (
        jax.jit(model.gemm).lower(*gemm_in),
        gemm_in,
        [_spec((m, n))],
        {"kind": "gemm", "m": m, "k": k, "n": n},
    )

    # --- TinyCNN inference ---------------------------------------------
    batch_i = 32
    p0 = model.tinycnn_init(0)
    pspecs = [_spec(p.shape) for p in p0]
    x_i = _spec((batch_i, model.TINYCNN_IMG, model.TINYCNN_IMG, 3))
    arts["tinycnn_infer"] = (
        jax.jit(model.tinycnn_logits).lower(tuple(pspecs), x_i),
        pspecs + [x_i],
        [_spec((batch_i, model.TINYCNN_CLASSES))],
        {"kind": "infer", "batch": batch_i, "n_params": len(pspecs)},
    )

    # --- TinyCNN fused SGD train step ----------------------------------
    batch_t = 32
    x_t = _spec((batch_t, model.TINYCNN_IMG, model.TINYCNN_IMG, 3))
    y_t = _spec((batch_t,), jnp.int32)
    lr = _spec((), jnp.float32)
    # donate params: the runtime threads new params back each step.
    arts["tinycnn_train_step"] = (
        jax.jit(model.tinycnn_train_step, donate_argnums=(0,)).lower(
            tuple(pspecs), x_t, y_t, lr
        ),
        pspecs + [x_t, y_t, lr],
        [_spec(())] + pspecs,
        {"kind": "train_step", "batch": batch_t, "n_params": len(pspecs)},
    )

    # --- MicroAlexNet inference (workload-zoo validation graph) --------
    batch_a = 4
    ap0 = model.microalex_init(1)
    aspecs = [_spec(p.shape) for p in ap0]
    x_a = _spec((batch_a, model.MICROALEX_IMG, model.MICROALEX_IMG, 3))
    arts["microalex_infer"] = (
        jax.jit(model.microalex_logits).lower(tuple(aspecs), x_a),
        aspecs + [x_a],
        [_spec((batch_a, 10))],
        {"kind": "infer", "batch": batch_a, "n_params": len(aspecs)},
    )

    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output dir")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact names"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {}
    for name, (lowered, ins, outs, extra) in build_artifacts().items():
        if only and name not in only:
            continue
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _meta(ins),
            "outputs": _meta(outs),
            **extra,
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
