"""AOT path tests: HLO-text emission must be parseable interchange."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_emits_entry():
    lowered = jax.jit(model.gemm).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    # return_tuple=True: root must be a tuple
    assert "tuple(" in text or "(f32[16,16]" in text


def test_build_artifacts_complete():
    arts = aot.build_artifacts()
    assert set(arts) == {
        "gemm_128",
        "tinycnn_infer",
        "tinycnn_train_step",
        "microalex_infer",
    }
    for name, (lowered, ins, outs, extra) in arts.items():
        assert ins and outs, name
        assert "kind" in extra, name


def test_train_step_artifact_arity():
    """train step: n_params + x + y + lr inputs; 1 + n_params outputs."""
    arts = aot.build_artifacts()
    _, ins, outs, extra = arts["tinycnn_train_step"]
    n = extra["n_params"]
    assert len(ins) == n + 3
    assert len(outs) == n + 1


def test_aot_writes_manifest(tmp_path):
    """End-to-end emission of the smallest artifact + manifest."""
    import sys
    from unittest import mock

    argv = ["aot", "--out", str(tmp_path), "--only", "gemm_128"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert "gemm_128" in man
    hlo = (tmp_path / "gemm_128.hlo.txt").read_text()
    assert "ENTRY" in hlo
    assert man["gemm_128"]["inputs"][0]["shape"] == [128, 128]
