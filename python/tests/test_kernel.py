"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes and dtypes of the Pallas kernels and asserts
allclose against the pure-jnp oracles in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    MatmulConfig,
    conv2d,
    default_config,
    matmul,
    matmul_pallas,
    ref,
)

jax.config.update("jax_enable_x64", False)

DIM = st.integers(min_value=1, max_value=96)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------------------
# GEMM
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    """Pallas GEMM == jnp GEMM for arbitrary (incl. non-multiple) shapes."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (m, k), jnp.float32)
    b = _rand(k2, (k, n), jnp.float32)
    got = matmul(a, b, None)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
)
def test_matmul_block_config_invariance(bm, bn, bk):
    """Result must not depend on the block schedule (pure perf knob)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = _rand(k1, (48, 40), jnp.float32)
    b = _rand(k2, (40, 56), jnp.float32)
    got = matmul_pallas(a, b, MatmulConfig(bm=bm, bn=bn, bk=bk))
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    a = _rand(k1, (32, 24), dtype)
    b = _rand(k2, (24, 16), dtype)
    got = matmul(a, b, None)
    want = ref.matmul(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_matmul_gradients_match_ref():
    """Custom VJP must equal autodiff through the reference GEMM."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a = _rand(k1, (24, 40), jnp.float32)
    b = _rand(k2, (40, 18), jnp.float32)

    def f_pallas(a, b):
        return jnp.sum(jnp.sin(matmul(a, b, None)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(ref.matmul(a, b)))

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb_p, gb_r, rtol=1e-4, atol=1e-4)


def test_default_config_small_dims_shrink():
    cfg = default_config(4, 4, 4)
    assert cfg.bm == 8 and cfg.bn == 8 and cfg.bk == 8
    cfg = default_config(512, 512, 512)
    assert cfg.bm == 128 and cfg.bn == 128 and cfg.bk == 128


def test_vmem_footprint_and_mxu_estimates():
    cfg = MatmulConfig(bm=128, bn=128, bk=128)
    assert cfg.vmem_bytes() == 4 * 3 * 128 * 128
    assert cfg.mxu_utilization() == 1.0
    assert MatmulConfig(bm=64, bn=128, bk=128).mxu_utilization() == 0.5


# --------------------------------------------------------------------------
# Conv2D
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.integers(4, 14),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    pad=st.sampled_from([0, 1]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, hw, cin, cout, k, stride, pad, seed):
    if hw + 2 * pad < k:
        return
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (n, hw, hw, cin), jnp.float32)
    w = _rand(k2, (k, k, cin, cout), jnp.float32)
    got = conv2d(x, w, stride=stride, padding=pad)
    want = ref.conv2d(x, w, stride=stride, padding=pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_gradient_matches_ref():
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    x = _rand(k1, (2, 8, 8, 4), jnp.float32)
    w = _rand(k2, (3, 3, 4, 8), jnp.float32)

    g_p = jax.grad(lambda w: jnp.sum(conv2d(x, w, padding=1) ** 2))(w)
    g_r = jax.grad(lambda w: jnp.sum(ref.conv2d(x, w, padding=1) ** 2))(w)
    np.testing.assert_allclose(g_p, g_r, rtol=1e-4, atol=1e-4)
