"""L2 model tests: shapes, loss behaviour, and trainability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _synth_batch(key, n):
    """Synthetic classification data with learnable structure: class k
    images have a bright kxk corner patch."""
    kx, ky = jax.random.split(key)
    y = jax.random.randint(ky, (n,), 0, model.TINYCNN_CLASSES)
    x = jax.random.normal(kx, (n, model.TINYCNN_IMG, model.TINYCNN_IMG, 3)) * 0.1
    # stamp a class-dependent mean into a corner region
    stamp = (y[:, None, None, None].astype(jnp.float32) + 1.0) / 10.0
    x = x.at[:, :4, :4, :].add(stamp)
    return x, y


def test_tinycnn_shapes():
    params = model.tinycnn_init(0)
    x = jnp.zeros((5, model.TINYCNN_IMG, model.TINYCNN_IMG, 3))
    logits = model.tinycnn_logits(params, x)
    assert logits.shape == (5, model.TINYCNN_CLASSES)


def test_tinycnn_loss_at_init_near_uniform():
    """At init the loss should be ~ln(10)."""
    params = model.tinycnn_init(0)
    x, y = _synth_batch(jax.random.PRNGKey(0), 32)
    loss = model.tinycnn_loss(params, x, y)
    assert abs(float(loss) - np.log(10.0)) < 0.5


def test_tinycnn_train_step_reduces_loss():
    """A few fused SGD steps on one batch must reduce the loss."""
    params = model.tinycnn_init(0)
    x, y = _synth_batch(jax.random.PRNGKey(1), 32)
    step = jax.jit(model.tinycnn_train_step)
    lr = jnp.float32(0.05)
    out = step(params, x, y, lr)
    first = float(out[0])
    params = out[1:]
    for _ in range(5):
        out = step(params, x, y, lr)
        params = out[1:]
    last = float(out[0])
    assert last < first, f"loss did not fall: {first} -> {last}"


def test_tinycnn_param_shapes_match_spec():
    params = model.tinycnn_init(0)
    assert len(params) == len(model.TINYCNN_PARAM_SHAPES)
    for p, (_, shape) in zip(params, model.TINYCNN_PARAM_SHAPES):
        assert p.shape == shape


def test_microalex_shapes():
    params = model.microalex_init(1)
    x = jnp.zeros((2, model.MICROALEX_IMG, model.MICROALEX_IMG, 3))
    logits = model.microalex_logits(params, x)
    assert logits.shape == (2, 10)


def test_microalex_layer_walk_covers_5conv_3fc():
    """Topology mirrors AlexNet: 5 conv + 3 fc (Table III row 1)."""
    convs = [l for l in model.MICROALEX_LAYERS if l[1] == "conv"]
    fcs = [l for l in model.MICROALEX_LAYERS if l[1] == "fc"]
    assert len(convs) == 5 and len(fcs) == 3
