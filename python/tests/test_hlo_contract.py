"""Contract tests on the emitted HLO text: the properties the Rust
runtime depends on (interchange format stability)."""

import jax
import jax.numpy as jnp

from compile import aot, model


def _hlo(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_pallas_interpret_lowers_to_plain_hlo():
    """interpret=True must leave no Mosaic/TPU custom-calls behind —
    otherwise the CPU PJRT client cannot execute the artifact."""
    text = _hlo(model.gemm, _spec((32, 32)), _spec((32, 32)))
    assert "custom-call" not in text.lower().replace("custom_call", "custom-call") or \
        "mosaic" not in text.lower()
    assert "mosaic" not in text.lower()


def test_root_is_tuple_for_single_output():
    """return_tuple=True: even single-output graphs return a 1-tuple,
    which the Rust side unwraps with to_tuple()."""
    text = _hlo(model.gemm, _spec((16, 16)), _spec((16, 16)))
    entry = text[text.index("ENTRY"):]
    root_line = next(l for l in entry.splitlines() if "ROOT" in l)
    assert "tuple" in root_line, root_line


def test_train_step_has_param_count_outputs():
    arts = aot.build_artifacts()
    lowered, ins, outs, extra = arts["tinycnn_train_step"]
    text = aot.to_hlo_text(lowered)
    # all params + loss come back: count the leaf types in the ROOT tuple
    entry = text[text.index("ENTRY"):]
    root_line = next(l for l in entry.splitlines() if "ROOT" in l)
    assert root_line.count("f32[") >= len(outs), root_line


def test_hlo_is_reparseable_text():
    """No binary sections, stable header."""
    text = _hlo(model.gemm, _spec((8, 8)), _spec((8, 8)))
    assert text.startswith("HloModule")
    assert text.isprintable() or "\n" in text
    assert "\x00" not in text
