//! End-to-end guarantees of the `/optimize` search engine:
//!
//! (a) on seeded random sub-grids, branch-and-bound returns the same
//!     winner as an exhaustive first-wins argmin over `sweep::run` —
//!     for every objective, bit-for-bit on the winning evaluation and
//!     the objective score, with the reference computed on an
//!     independent memo (so the identity is a property of the model,
//!     not of cache sharing);
//! (b) a pinned golden query — min EDP, area <= 25 mm², node in
//!     {7, 5} — is answered by the exhaustive argmin, satisfies its
//!     own constraints, reproduces bit-for-bit on a warm rerun, and
//!     does not materialize the full grid;
//! (c) an unsatisfiable budget surfaces as the typed
//!     [`optimize::Infeasible`] error, never a free-text string.

use deepnvm::device::MemTech;
use deepnvm::nvsim::TechSel;
use deepnvm::sweep::spec::parse_tech_sel;
use deepnvm::sweep::{self, optimize, Memo, OptObjective, OptimizeRequest, SweepSpec};
use deepnvm::util::rng::Rng;
use deepnvm::workload::models::Phase;

/// Exhaustive reference: evaluate the whole grid, filter by the
/// request's budgets, and take the first-wins argmin of the objective
/// in spec order — the semantics the search must reproduce exactly.
fn exhaustive_winner(
    req: &OptimizeRequest,
    memo: &Memo,
) -> Option<(sweep::PointResult, f64)> {
    let res = sweep::run(&req.spec, 2, memo).expect("reference sweep");
    let mut best: Option<(sweep::PointResult, f64)> = None;
    for p in &res.points {
        if !req.feasible(&p.tuned.ppa) {
            continue;
        }
        let v = optimize::objective_value(req.objective, p);
        let better = match &best {
            None => true,
            Some((_, bv)) => v < *bv,
        };
        if better {
            best = Some((p.clone(), v));
        }
    }
    best
}

/// Random nonempty subset of `pool`, preserving pool order (the order
/// axes carry in a spec), with at most `max` members.
fn pick<T: Copy>(rng: &mut Rng, pool: &[T], max: usize) -> Vec<T> {
    let k = rng.range_usize(1, max.min(pool.len()));
    let mut idx: Vec<usize> = (0..pool.len()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(k);
    idx.sort_unstable();
    idx.into_iter().map(|i| pool[i]).collect()
}

fn check_against_exhaustive(
    trial: usize,
    req: &OptimizeRequest,
    search_memo: &Memo,
    ref_memo: &Memo,
) {
    let got = optimize::run(req, 2, search_memo);
    let want = exhaustive_winner(req, ref_memo);
    match (got, want) {
        (Ok(resp), Some((p, v))) => {
            let w = resp.winner.unwrap_or_else(|| {
                panic!("trial {trial} {:?}: no winner, expected {:?}", req.objective, p.point)
            });
            assert_eq!(w.point, p.point, "trial {trial} {:?}", req.objective);
            assert_eq!(
                resp.best_value.unwrap().to_bits(),
                v.to_bits(),
                "trial {trial} {:?}: objective score must be bit-identical",
                req.objective
            );
            match (w.eval, p.eval) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.edp.to_bits(), b.edp.to_bits());
                    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
                    assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
                }
                (None, None) => {}
                other => panic!("trial {trial}: eval presence mismatch {other:?}"),
            }
            assert_eq!(
                resp.points_evaluated + resp.points_pruned,
                resp.points_total,
                "trial {trial}: search accounting must cover the grid"
            );
        }
        (Err(e), None) => {
            assert!(
                e.chain().any(|c| c.downcast_ref::<optimize::Infeasible>().is_some()),
                "trial {trial}: infeasible grids must fail typed, got: {e:#}"
            );
        }
        (Ok(resp), None) => panic!(
            "trial {trial} {:?}: search returned {:?} on an infeasible grid",
            req.objective, resp.winner
        ),
        (Err(e), Some((p, _))) => panic!(
            "trial {trial} {:?}: search errored ({e:#}) but {:?} is feasible",
            req.objective, p.point
        ),
    }
}

// ---------------------------------------------------------------- (a)

#[test]
fn search_matches_exhaustive_argmin_on_seeded_random_grids() {
    let mut rng = Rng::new(0x5EED_0071);
    let search_memo = Memo::new();
    let ref_memo = Memo::new();
    let cap_pool = [1u64, 2, 3, 4, 8];
    let node_pool = [16u32, 7, 5];
    let dnn_pool = ["AlexNet", "ResNet-18", "SqueezeNet"];
    let batch_pool = [1usize, 2, 4, 8, 16, 32];
    // The tech axis draws from pures AND way-partitioned hybrids, so
    // the argmin identity is checked across hybrid composition too.
    let tech_pool: Vec<TechSel> = {
        let mut v = TechSel::pure_all();
        v.push(parse_tech_sel("hybrid-stt:4@0.85").unwrap());
        v.push(parse_tech_sel("hybrid-sot:8@0.9").unwrap());
        v
    };

    for trial in 0..8 {
        let with_workload = rng.chance(0.75);
        let spec = SweepSpec {
            techs: pick(&mut rng, &tech_pool, 3),
            capacities_mb: pick(&mut rng, &cap_pool, 3),
            dnns: if with_workload {
                pick(&mut rng, &dnn_pool, 2).into_iter().map(String::from).collect()
            } else {
                vec![]
            },
            phases: pick(&mut rng, &Phase::ALL, 2),
            batches: if with_workload { pick(&mut rng, &batch_pool, 3) } else { vec![] },
            nodes_nm: pick(&mut rng, &node_pool, 2),
            filters: vec![],
        };
        let area_max_mm2 = rng.chance(0.4).then(|| rng.range_f64(0.5, 40.0));
        let leakage_max_w = rng.chance(0.3).then(|| rng.range_f64(0.05, 4.0));

        let objectives: &[OptObjective] = if with_workload {
            &OptObjective::ALL
        } else {
            &[OptObjective::Edap, OptObjective::Capacity]
        };
        for &objective in objectives {
            let req = OptimizeRequest {
                spec: spec.clone(),
                objective,
                area_max_mm2,
                leakage_max_w,
                frontier: false,
            };
            check_against_exhaustive(trial, &req, &search_memo, &ref_memo);
        }
        if !with_workload {
            // a circuit-only grid cannot answer workload objectives
            let req = OptimizeRequest {
                spec: spec.clone(),
                objective: OptObjective::Edp,
                area_max_mm2: None,
                leakage_max_w: None,
                frontier: false,
            };
            assert!(
                optimize::run(&req, 2, &search_memo).is_err(),
                "trial {trial}: EDP over a circuit-only grid must be rejected"
            );
        }
    }
}

// ---------------------------------------------------------------- (b)

#[test]
fn golden_min_edp_area_25mm2_nodes_7_and_5() {
    let req = OptimizeRequest {
        spec: SweepSpec {
            techs: TechSel::pure_all(),
            capacities_mb: vec![1, 2, 4, 8, 16, 32],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![1, 4, 16, 64],
            nodes_nm: vec![7, 5],
            filters: vec![],
        },
        objective: OptObjective::Edp,
        area_max_mm2: Some(25.0),
        leakage_max_w: None,
        frontier: false,
    };
    let memo = Memo::new();
    let resp = optimize::run(&req, 2, &memo).unwrap();
    let w = resp.winner.expect("small caps fit 25 mm² at 7/5 nm");

    // the winner satisfies its own constraints...
    assert!(req.spec.nodes_nm.contains(&w.point.node_nm), "{:?}", w.point);
    assert!(w.tuned.ppa.area * 1e6 <= 25.0, "area {} m²", w.tuned.ppa.area);
    // ...and IS the exhaustive argmin, on an independent memo
    let (best, bv) = exhaustive_winner(&req, &Memo::new()).expect("feasible");
    assert_eq!(w.point, best.point);
    assert_eq!(
        w.eval.unwrap().edp.to_bits(),
        best.eval.unwrap().edp.to_bits(),
        "winner evaluation must be bit-identical to the exhaustive one"
    );
    assert_eq!(resp.best_value.unwrap().to_bits(), bv.to_bits());

    // pinned determinism: a warm rerun reproduces the result exactly
    let again = optimize::run(&req, 2, &memo).unwrap();
    assert_eq!(again.winner.unwrap().point, w.point);
    assert_eq!(
        again.best_value.unwrap().to_bits(),
        resp.best_value.unwrap().to_bits()
    );

    // the search earns its keep: strictly fewer evaluations than grid
    assert!(
        resp.points_evaluated < resp.points_total,
        "search must not materialize the whole grid: {resp:?}"
    );
}

// ---------------------------------------------------------------- (c)

#[test]
fn unsatisfiable_budget_is_the_typed_infeasible_error() {
    let req = OptimizeRequest {
        spec: SweepSpec::circuit_only(vec![MemTech::SttMram], vec![1]),
        objective: OptObjective::Edap,
        area_max_mm2: Some(1e-9),
        leakage_max_w: None,
        frontier: false,
    };
    let err = optimize::run(&req, 2, &Memo::new()).unwrap_err();
    let inf = err
        .chain()
        .find_map(|c| c.downcast_ref::<optimize::Infeasible>())
        .unwrap_or_else(|| panic!("expected Infeasible in the chain, got: {err:#}"));
    assert_eq!(inf.area_max_mm2, Some(1e-9));
    assert!(inf.leakage_max_w.is_none());
}
