//! End-to-end runtime integration: load real AOT artifacts, execute on
//! CPU PJRT, verify numerics and training behaviour.
//!
//! Requires `make artifacts` (skipped with a notice otherwise, so bare
//! `cargo test` still passes on a fresh checkout).

use deepnvm::runtime::engine::HostTensor;
use deepnvm::runtime::{trainer, Engine, Manifest};

fn engine_or_skip() -> Option<Engine> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping runtime e2e: run `make artifacts` first");
        return None;
    }
    Some(Engine::default().expect("engine"))
}

#[test]
fn gemm_artifact_matches_cpu_reference() {
    let Some(engine) = engine_or_skip() else { return };
    let wl = engine.load("gemm_128").expect("load gemm");
    let n = 128usize;
    // deterministic inputs
    let a: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) * 0.25).collect();
    let b: Vec<f32> = (0..n * n).map(|i| ((i % 5) as f32 - 2.0) * 0.5).collect();
    let out = wl
        .run(&[HostTensor::F32(a.clone()), HostTensor::F32(b.clone())])
        .expect("run");
    let got = out[0].as_f32().unwrap();

    // naive reference
    let mut want = vec![0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                want[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "max err {max_err}");
}

#[test]
fn tinycnn_infer_shapes_and_determinism() {
    let Some(engine) = engine_or_skip() else { return };
    let wl = engine.load("tinycnn_infer").expect("load");
    let n_params = wl.spec.n_params;
    let params =
        trainer::init_params(&wl.spec.inputs[..n_params], 42).unwrap();
    let batch = wl.spec.batch;
    let img = wl.spec.inputs[n_params].shape[1];
    let xs = vec![0.5f32; batch * img * img * 3];
    let mut inputs = params.clone();
    inputs.push(HostTensor::F32(xs));
    let o1 = wl.run(&inputs).expect("run1");
    let o2 = wl.run(&inputs).expect("run2");
    assert_eq!(o1[0].as_f32().unwrap().len(), batch * 10);
    assert_eq!(o1, o2, "inference must be deterministic");
}

#[test]
fn training_reduces_loss_and_learns() {
    let Some(engine) = engine_or_skip() else { return };
    let (report, params) =
        trainer::train(&engine, 40, 0.05, 7, |_, _| {}).expect("train");
    assert_eq!(report.losses.len(), 40);
    // initial loss near ln(10)
    assert!(
        (report.first_loss() - 2.303).abs() < 0.6,
        "first loss {}",
        report.first_loss()
    );
    assert!(
        report.last_loss() < report.first_loss() * 0.8,
        "loss did not fall: {} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    // the learned net must beat the 10% chance rate on fresh data
    let acc = trainer::eval_accuracy(&engine, &params, 999).expect("eval");
    assert!(acc > 0.2, "accuracy {acc}");
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(engine) = engine_or_skip() else { return };
    let wl = engine.load("gemm_128").expect("load");
    assert!(wl.run(&[HostTensor::F32(vec![0.0; 128 * 128])]).is_err());
}
