//! Cross-validation: the analytic traffic model (nvprof substitute) vs
//! the trace-driven hierarchy simulation (GPGPU-Sim substitute). The
//! two substrates were built independently on top of the same GEMM
//! schedule; this test keeps them honest against each other.

use deepnvm::gpusim::{gpu::simulate_dnn, GpuConfig};
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::{TrafficModel, WorkloadStats};

const MB: u64 = 1024 * 1024;

fn gemm_only_stats(dnn: &Dnn, phase: Phase, b: usize, l2: u64) -> WorkloadStats {
    // pool/eltwise layers are modeled only analytically; compare the
    // GEMM-backed portion, which is what the trace contains.
    let m = TrafficModel { l2_bytes: l2, ..Default::default() };
    let mut s = WorkloadStats::default();
    for l in &dnn.layers {
        if l.gemm_dims(b).is_some() {
            s.add(&m.layer_stats(l, phase, b));
        }
    }
    s
}

#[test]
fn l2_transaction_counts_agree() {
    let d = Dnn::by_name("SqueezeNet").unwrap();
    let analytic = gemm_only_stats(&d, Phase::Inference, 1, 3 * MB);
    let sim = simulate_dnn(GpuConfig::gtx1080ti(3 * MB), &d, Phase::Inference, 1);

    // The simulator's L2 sees what misses the L1s; writes are
    // write-through so they match exactly, reads are a subset.
    assert_eq!(
        sim.l2_writes,
        analytic.l2_writes,
        "write-through writes must match the schedule exactly"
    );
    assert!(
        sim.l2_reads <= analytic.l2_reads,
        "L1 can only filter reads: sim {} vs analytic {}",
        sim.l2_reads,
        analytic.l2_reads
    );
    // ... and since one 128 B L1 line covers four consecutive 32 B
    // sectors of a streaming block, the L1 coalesces reads by ~4x.
    // The analytic model counts sector-granular requests (nvprof's
    // convention); the simulated post-L1 read count must sit right at
    // that coalescing factor.
    let ratio = sim.l2_reads as f64 / analytic.l2_reads as f64;
    assert!(
        (0.15..0.6).contains(&ratio),
        "L1 read coalescing off: {ratio} (expect ~0.25)"
    );
}

#[test]
fn dram_traffic_same_ballpark() {
    // The analytic spill model and the real cache simulation must agree
    // on total DRAM traffic within ~2.5x for a batch-1 inference pass
    // (the analytic model is deliberately simple).
    let d = Dnn::by_name("SqueezeNet").unwrap();
    let analytic = gemm_only_stats(&d, Phase::Inference, 1, 3 * MB);
    let sim = simulate_dnn(GpuConfig::gtx1080ti(3 * MB), &d, Phase::Inference, 1);
    let ratio = sim.dram_total() as f64 / analytic.dram_total() as f64;
    assert!(
        (0.4..2.5).contains(&ratio),
        "sim {} vs analytic {} (ratio {ratio:.2})",
        sim.dram_total(),
        analytic.dram_total()
    );
}

#[test]
fn capacity_sensitivity_directionally_consistent() {
    // Growing the L2 must reduce DRAM traffic in both models.
    let d = Dnn::by_name("AlexNet").unwrap();
    let a_small = gemm_only_stats(&d, Phase::Inference, 1, 2 * MB).dram_total();
    let a_large = gemm_only_stats(&d, Phase::Inference, 1, 16 * MB).dram_total();
    assert!(a_large <= a_small);

    let s_small =
        simulate_dnn(GpuConfig::gtx1080ti(2 * MB), &d, Phase::Inference, 1).dram_total();
    let s_large =
        simulate_dnn(GpuConfig::gtx1080ti(16 * MB), &d, Phase::Inference, 1).dram_total();
    assert!(s_large < s_small);
}
