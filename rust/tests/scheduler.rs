//! End-to-end guarantees of `serve::scheduler` over real sockets:
//!
//! (a) a coordinator driving two live workers completes the full grid
//!     — after the merges, a local `sweep::run` performs **zero**
//!     circuit solves and **zero** traffic evals, and the coordinator
//!     itself never solved anything;
//! (b) a worker killed mid-shard (connection severed, then refusing
//!     even `/healthz`) has its shard reassigned to the surviving
//!     worker and the run still converges;
//! (c) a fleet with nobody listening fails cleanly, as does a run
//!     whose every worker dies;
//! (d) `GET /scheduler/status` reports per-shard scheduler state;
//! (e) trace propagation survives a reassignment — a shard that fails
//!     on one worker and lands on another keeps one trace id across
//!     both dispatch attempts, and the stitched fleet trace carries
//!     both plus the worker-side span flow-linked to its dispatch;
//! (f) `GET /scheduler/metrics` federates worker expositions exactly
//!     (fleet value = sum of per-worker scrapes).

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use deepnvm::device::MemTech;
use deepnvm::serve::http::{self, Server};
use deepnvm::serve::routes::{self, ServerCtx};
use deepnvm::serve::scheduler::{coordinate, Coordinator, ScheduleConfig, ShardState};
use deepnvm::sweep::{self, Memo, SweepSpec};
use deepnvm::util::json;
use deepnvm::workload::models::Phase;

/// A real worker: the full serve stack over a private leaked memo.
fn worker() -> Server {
    let memo: &'static Memo = Box::leak(Box::new(Memo::new()));
    let ctx = Arc::new(ServerCtx::new(memo, 2));
    Server::bind("127.0.0.1:0", 2, move |req| routes::handle(&ctx, req)).unwrap()
}

/// A real worker whose `/shard/run` handling blocks until `gate` opens
/// — lets a test force another worker to receive a shard first.
fn gated_worker(gate: Arc<AtomicBool>) -> Server {
    let memo: &'static Memo = Box::leak(Box::new(Memo::new()));
    let ctx = Arc::new(ServerCtx::new(memo, 2));
    Server::bind("127.0.0.1:0", 2, move |req| {
        if req.path == "/shard/run" {
            while !gate.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        routes::handle(&ctx, req)
    })
    .unwrap()
}

/// A worker that answers the liveness probe, then drops dead the
/// moment it is handed a shard: the connection is severed without a
/// response, `gate` opens, and the listener stops accepting — exactly
/// what a killed `deepnvm serve` process looks like to a coordinator.
fn dying_worker(gate: Arc<AtomicBool>) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { return };
            let mut buf = [0u8; 1024];
            let n = s.read(&mut buf).unwrap_or(0);
            let head = String::from_utf8_lossy(&buf[..n]);
            if head.starts_with("GET /healthz") {
                let body = r#"{"status": "ok"}"#;
                let _ = s.write_all(
                    format!(
                        "HTTP/1.1 200 OK\r\nContent-Length: {}\r\n\
                         Connection: close\r\n\r\n{body}",
                        body.len()
                    )
                    .as_bytes(),
                );
            } else {
                drop(s);
                gate.store(true, Ordering::SeqCst);
                return;
            }
        }
    });
    (addr, handle)
}

fn grid() -> SweepSpec {
    SweepSpec {
        techs: MemTech::ALL.to_vec(),
        capacities_mb: vec![1, 2],
        dnns: vec!["AlexNet".into()],
        phases: Phase::ALL.to_vec(),
        batches: vec![],
        nodes_nm: vec![16],
        filters: vec![],
    }
}

// ---------------------------------------------------------------- (a)

#[test]
fn two_worker_fleet_completes_the_grid_with_zero_solve_replay() {
    let (w1, w2) = (worker(), worker());
    let cfg = ScheduleConfig {
        workers: vec![w1.local_addr().to_string(), w2.local_addr().to_string()],
        ..ScheduleConfig::default()
    };
    let spec = grid();
    let memo = Memo::new();
    let report = coordinate(&spec, &cfg, &memo).unwrap();

    assert_eq!(report.grid_points, spec.expand().unwrap().len());
    assert_eq!(report.replay_solves, 0, "merged union must answer the grid");
    assert_eq!(report.replay_evals, 0);
    assert!(report.accepted > 0);
    assert!(report
        .shards
        .iter()
        .all(|s| matches!(s.state, ShardState::Merged { .. })));
    assert_eq!(memo.solve_count(), 0, "the coordinator itself never solves");
    assert_eq!(memo.eval_count(), 0, "the coordinator itself never evaluates");

    // the merged memo is the full-grid cache: a fresh sweep over it is
    // pure replay, point for point
    let again = sweep::run(&spec, 2, &memo).unwrap();
    assert_eq!(again.points.len(), report.grid_points);
    assert_eq!(memo.solve_count(), 0);
}

#[test]
fn multi_node_grid_distributes_and_replays_solve_free() {
    // the 7/5 nm calibration rides the existing shard protocol: a
    // cross-node spec fans out over a real two-worker fleet and the
    // merged union replays every node from cache alone
    let (w1, w2) = (worker(), worker());
    let cfg = ScheduleConfig {
        workers: vec![w1.local_addr().to_string(), w2.local_addr().to_string()],
        ..ScheduleConfig::default()
    };
    let spec = SweepSpec {
        techs: vec![MemTech::SttMram, MemTech::SotMram],
        capacities_mb: vec![1, 2],
        dnns: vec![],
        phases: Phase::ALL.to_vec(),
        batches: vec![],
        nodes_nm: vec![16, 7],
        filters: vec![],
    };
    let memo = Memo::new();
    let report = coordinate(&spec, &cfg, &memo).unwrap();
    assert_eq!(report.grid_points, 2 * 2 * 2, "techs x caps x nodes");
    assert_eq!(report.replay_solves, 0);
    assert_eq!(report.replay_evals, 0);
    assert_eq!(memo.solve_count(), 0, "the coordinator never solves");

    // the merged cache answers each node with a distinct design
    let n16 = memo.tuned_at(MemTech::SttMram, 2 * 1024 * 1024, 16).unwrap();
    let n7 = memo.tuned_at(MemTech::SttMram, 2 * 1024 * 1024, 7).unwrap();
    assert!(n7.ppa.area < n16.ppa.area, "no 16 nm aliasing after the merge");
    assert_eq!(memo.solve_count(), 0);
}

// ---------------------------------------------------------------- (b)

#[test]
fn killed_workers_shard_is_reassigned_and_completed() {
    let gate = Arc::new(AtomicBool::new(false));
    let (dead_addr, dying) = dying_worker(Arc::clone(&gate));
    // the live worker cannot take its first shard until the dying
    // worker has been handed (and dropped) one, so the reassignment
    // path is exercised deterministically
    let live = gated_worker(Arc::clone(&gate));
    let live_addr = live.local_addr().to_string();

    let cfg = ScheduleConfig {
        workers: vec![dead_addr.clone(), live_addr.clone()],
        retries: 3,
        deadline: Duration::from_secs(60),
        ..ScheduleConfig::default()
    };
    let spec = grid();
    let memo = Memo::new();
    let report = coordinate(&spec, &cfg, &memo).unwrap();
    dying.join().unwrap();

    assert_eq!(report.replay_solves, 0);
    assert_eq!(report.replay_evals, 0);
    assert!(
        report.reassigned >= 1,
        "the killed worker's shard must be retried: {:?}",
        report.shards
    );
    for s in &report.shards {
        match &s.state {
            ShardState::Merged { worker, .. } => {
                assert_eq!(worker, &live_addr, "only the survivor can merge");
            }
            other => panic!("shard not merged: {other}"),
        }
    }
}

// ---------------------------------------------------------------- (c)

#[test]
fn unreachable_fleet_fails_cleanly() {
    // an address with (almost certainly) nothing listening
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    let cfg = ScheduleConfig { workers: vec![addr], ..ScheduleConfig::default() };
    let err = coordinate(&grid(), &cfg, &Memo::new()).unwrap_err();
    assert!(err.to_string().contains("/healthz"), "{err}");
}

#[test]
fn fleet_that_dies_entirely_fails_with_retry_accounting() {
    let gate = Arc::new(AtomicBool::new(true)); // nobody to wait for
    let (dead_addr, dying) = dying_worker(gate);
    let cfg = ScheduleConfig {
        workers: vec![dead_addr],
        retries: 3,
        deadline: Duration::from_secs(10),
        ..ScheduleConfig::default()
    };
    let err = coordinate(&grid(), &cfg, &Memo::new()).unwrap_err();
    dying.join().unwrap();
    assert!(err.to_string().contains("died"), "{err}");
}

/// A worker that is alive and chatty but corrupt: `/healthz` is fine,
/// yet every `/shard/run` answers 200 with an export whose first
/// payload hash does not verify.
fn corrupt_worker() -> String {
    let m = Memo::new();
    let spec = SweepSpec::circuit_only(vec![MemTech::SttMram], vec![1]);
    sweep::run(&spec, 1, &m).unwrap();
    let doc = m.to_json().to_pretty();
    let needle = "\"payload_hash\": \"";
    let at = doc.find(needle).unwrap() + needle.len();
    let mut corrupt = doc;
    corrupt.replace_range(at..at + 16, "0123456789abcdef");
    let body = format!("{{\"points\": 1, \"solves\": 1, \"evals\": 0, \"export\": {corrupt}}}");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { return };
            let mut buf = [0u8; 4096];
            let n = s.read(&mut buf).unwrap_or(0);
            let head = String::from_utf8_lossy(&buf[..n]);
            let payload = if head.starts_with("GET /healthz") {
                r#"{"status": "ok"}"#.to_string()
            } else {
                body.clone()
            };
            let _ = s.write_all(
                format!(
                    "HTTP/1.1 200 OK\r\nContent-Length: {}\r\n\
                     Connection: close\r\n\r\n{payload}",
                    payload.len()
                )
                .as_bytes(),
            );
        }
    });
    addr
}

#[test]
fn corrupt_exports_are_redispatched_until_the_retry_budget_fails_the_run() {
    let addr = corrupt_worker();
    let cfg = ScheduleConfig {
        workers: vec![addr],
        retries: 1,
        deadline: Duration::from_secs(10),
        ..ScheduleConfig::default()
    };
    // a single-cap grid -> one shard, so the failure is attributable
    let spec = SweepSpec { capacities_mb: vec![1], ..grid() };
    let err = coordinate(&spec, &cfg, &Memo::new()).unwrap_err();
    assert!(
        err.to_string().contains("hash-rejected"),
        "a corrupt export must fail the dispatch, not count as merged: {err}"
    );
}

// ---------------------------------------------------------------- (d)

#[test]
fn status_route_reports_scheduler_state() {
    let w = worker();
    let cfg = ScheduleConfig {
        workers: vec![w.local_addr().to_string()],
        status_addr: Some("127.0.0.1:0".into()),
        ..ScheduleConfig::default()
    };
    let c = Coordinator::new(&grid(), &cfg).unwrap();
    let addr = c.status_addr().expect("status server bound").to_string();

    // before the run: every shard pending, the fleet unprobed
    let (status, body) =
        http::call(&addr, "GET", "/scheduler/status", "", Duration::from_secs(5)).unwrap();
    assert_eq!(status, 200, "{body}");
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("pending").unwrap().as_u64(), Some(c.shard_count() as u64));
    assert_eq!(j.get("merged").unwrap().as_u64(), Some(0));

    let memo = Memo::new();
    let report = c.run(&memo).unwrap();
    assert_eq!(report.replay_solves, 0);

    // after the run: everything merged, the worker alive and credited
    let (status, body) =
        http::call(&addr, "GET", "/scheduler/status", "", Duration::from_secs(5)).unwrap();
    assert_eq!(status, 200);
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("merged").unwrap().as_u64(), Some(c.shard_count() as u64));
    assert_eq!(j.get("pending").unwrap().as_u64(), Some(0));
    assert_eq!(j.get("failed").unwrap().as_u64(), Some(0));
    let workers = j.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(workers[0].get("alive").unwrap().as_bool(), Some(true));
    assert_eq!(
        workers[0].get("shards_merged").unwrap().as_u64(),
        Some(c.shard_count() as u64)
    );

    // the coordinator's own health endpoint names its role
    let (status, body) =
        http::call(&addr, "GET", "/healthz", "", Duration::from_secs(5)).unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("coordinator"), "{body}");
}

// ---------------------------------------------------------------- (e)

#[test]
fn reassigned_shard_keeps_one_trace_id_across_both_attempts() {
    use deepnvm::obs::trace;
    use deepnvm::util::json::Json;

    let gate = Arc::new(AtomicBool::new(false));
    let (dead_addr, dying) = dying_worker(Arc::clone(&gate));
    let live = gated_worker(Arc::clone(&gate));

    let cfg = ScheduleConfig {
        workers: vec![dead_addr, live.local_addr().to_string()],
        retries: 3,
        deadline: Duration::from_secs(60),
        ..ScheduleConfig::default()
    };
    let c = Coordinator::new(&grid(), &cfg).unwrap();
    let run = c.run_seq();
    let memo = Memo::new();
    let report = c.run(&memo).unwrap();
    dying.join().unwrap();
    let reassigned = report
        .shards
        .iter()
        .position(|s| s.attempts > 1)
        .expect("the killed worker's shard must have been retried");

    // Both dispatch attempts of the reassigned shard are in the span
    // ring, tagged with this run, on the one process-wide trace id.
    let has = |r: &trace::SpanRecord, k: &str, v: u64| {
        r.args.iter().flatten().any(|&(n, x)| n == k && x == v)
    };
    let dispatches: Vec<trace::SpanRecord> = trace::records()
        .into_iter()
        .filter(|r| {
            r.name == "shard.dispatch"
                && has(r, "run", run)
                && has(r, "shard", reassigned as u64)
        })
        .collect();
    assert!(
        dispatches.len() >= 2,
        "both attempts must be spans: {dispatches:?}"
    );
    for d in &dispatches {
        assert_eq!(d.trace, trace::trace_id(), "one trace id end-to-end");
    }

    // The worker that completed the shard adopted the header: its
    // request span's remote parent is one of this run's dispatches.
    let run_dispatch_ids: Vec<u64> = trace::records()
        .iter()
        .filter(|r| r.name == "shard.dispatch" && has(r, "run", run))
        .map(|r| r.id)
        .collect();
    let adopted = trace::records().into_iter().any(|r| {
        r.name == "http./shard/run"
            && r.trace == trace::trace_id()
            && run_dispatch_ids.contains(&r.remote_parent)
    });
    assert!(adopted, "a worker span must join the coordinator's trace");

    // The stitched fleet trace carries the same story: one traceId,
    // both dispatch attempts, the surviving worker's process, and flow
    // links from dispatch spans to worker spans.
    let doc = c.fleet_trace();
    let trace_hex = format!("{:016x}", trace::trace_id());
    assert_eq!(doc.get("traceId").and_then(Json::as_str), Some(trace_hex.as_str()));
    assert!(
        doc.get("workersStitched").and_then(Json::as_u64) >= Some(1),
        "the survivor must be scraped"
    );
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let stitched_dispatches = events
        .iter()
        .filter(|e| {
            e.get("name").and_then(Json::as_str) == Some("shard.dispatch")
                && e.get("pid").and_then(Json::as_u64) == Some(1)
                && e.get("args").and_then(|a| a.get("run")).and_then(Json::as_u64)
                    == Some(run)
                && e.get("args").and_then(|a| a.get("shard")).and_then(Json::as_u64)
                    == Some(reassigned as u64)
        })
        .count();
    assert!(stitched_dispatches >= 2, "both attempts in the stitched trace");
    assert!(
        events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("shard.dispatch.flow")
        }),
        "dispatch -> worker flow links must be present"
    );
    assert!(
        events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("http./shard/run")
                && e.get("pid").and_then(Json::as_u64) > Some(1)
                && e.get("args").and_then(|a| a.get("trace")).and_then(Json::as_str)
                    == Some(trace_hex.as_str())
        }),
        "a worker-pid span must share the coordinator's trace id"
    );
}

// ---------------------------------------------------------------- (f)

#[test]
fn scheduler_metrics_federate_worker_scrapes_exactly() {
    // Series owned by this test alone: in-process workers share one
    // global registry, so a uniquely named counter/histogram gives a
    // deterministic expectation — each worker scrape reports the same
    // value v, and the fleet view must show exactly workers x v.
    let reg = deepnvm::obs::global();
    reg.counter("test_federation_counter_total").add(7);
    let h = reg.histogram("test_federation_hist");
    h.record(1);
    h.record(100);

    let (w1, w2) = (worker(), worker());
    let cfg = ScheduleConfig {
        workers: vec![w1.local_addr().to_string(), w2.local_addr().to_string()],
        status_addr: Some("127.0.0.1:0".into()),
        ..ScheduleConfig::default()
    };
    let c = Coordinator::new(&grid(), &cfg).unwrap();
    let addr = c.status_addr().unwrap().to_string();
    let memo = Memo::new();
    c.run(&memo).unwrap();

    let (status, body) =
        http::call(&addr, "GET", "/scheduler/metrics", "", Duration::from_secs(5))
            .unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("merged /metrics from 2/2 workers"), "{body}");
    // counter: 7 per worker scrape -> 14 fleet-wide, 7 coordinator-local
    assert!(body.contains("test_federation_counter_total 14"), "{body}");
    assert!(
        body.contains("test_federation_counter_total{role=\"coordinator\"} 7"),
        "{body}"
    );
    // histogram: bucket-wise addition of the two worker scrapes
    // (1 -> le="1", 100 -> le="128"; cumulative doubles per worker)
    assert!(body.contains("test_federation_hist_bucket{le=\"1\"} 2"), "{body}");
    assert!(body.contains("test_federation_hist_count 4"), "{body}");
    assert!(
        body.contains("test_federation_hist_count{role=\"coordinator\"} 2"),
        "{body}"
    );

    // the probes also estimated clock offsets for the status view
    let (status, body) =
        http::call(&addr, "GET", "/scheduler/status", "", Duration::from_secs(5))
            .unwrap();
    assert_eq!(status, 200);
    let j = json::parse(&body).unwrap();
    for w in j.get("workers").unwrap().as_arr().unwrap() {
        assert!(
            w.get("clock_offset_ns").unwrap().as_f64().is_some(),
            "in-process workers report clock_ns, so offsets must be estimated: {body}"
        );
    }
}
