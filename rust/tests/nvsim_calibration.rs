//! Table II calibration: the EDAP-tuned cache designs must reproduce
//! the paper's published latency/energy/leakage/area at the 3 MB
//! iso-capacity points and the 7/10 MB iso-area points.
//!
//! Tolerances are per-metric; deviations and their causes are recorded
//! in EXPERIMENTS.md §T2 (the known outlier is STT write energy, where
//! the paper's value is *below* 256 x its own Table I cell write
//! energy, so an exact match is not reachable from its own bitcell
//! numbers).

use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::nvsim::CachePpa;

const MB: u64 = 1024 * 1024;

struct Target {
    tech: MemTech,
    mb: u64,
    read_lat_ns: f64,
    write_lat_ns: f64,
    read_nj: f64,
    write_nj: f64,
    leak_mw: f64,
    area_mm2: f64,
}

/// Paper Table II.
const TABLE2: [Target; 5] = [
    Target { tech: MemTech::Sram, mb: 3, read_lat_ns: 2.91, write_lat_ns: 1.53, read_nj: 0.35, write_nj: 0.32, leak_mw: 6442.0, area_mm2: 5.53 },
    Target { tech: MemTech::SttMram, mb: 3, read_lat_ns: 2.98, write_lat_ns: 9.31, read_nj: 0.81, write_nj: 0.31, leak_mw: 748.0, area_mm2: 2.34 },
    Target { tech: MemTech::SttMram, mb: 7, read_lat_ns: 4.58, write_lat_ns: 10.06, read_nj: 0.93, write_nj: 0.43, leak_mw: 1706.0, area_mm2: 5.12 },
    Target { tech: MemTech::SotMram, mb: 3, read_lat_ns: 3.71, write_lat_ns: 1.38, read_nj: 0.49, write_nj: 0.22, leak_mw: 527.0, area_mm2: 1.95 },
    Target { tech: MemTech::SotMram, mb: 10, read_lat_ns: 6.69, write_lat_ns: 2.47, read_nj: 0.51, write_nj: 0.40, leak_mw: 1434.0, area_mm2: 5.64 },
];

fn within(got: f64, want: f64, tol: f64, what: &str) {
    let err = (got - want).abs() / want;
    assert!(
        err <= tol,
        "{what}: got {got:.3}, paper {want:.3} (err {:.0}% > {:.0}%)",
        err * 100.0,
        tol * 100.0
    );
}

fn check(t: &Target, p: &CachePpa) {
    let name = format!("{} {}MB", t.tech, t.mb);
    within(p.read_latency * 1e9, t.read_lat_ns, 0.40, &format!("{name} read latency"));
    within(p.write_latency * 1e9, t.write_lat_ns, 0.35, &format!("{name} write latency"));
    within(p.read_energy * 1e9, t.read_nj, 0.35, &format!("{name} read energy"));
    // STT write energy: known outlier (see header comment) — 80% band.
    let we_tol = if t.tech == MemTech::SttMram { 0.80 } else { 0.35 };
    within(p.write_energy * 1e9, t.write_nj, we_tol, &format!("{name} write energy"));
    within(p.leakage_power * 1e3, t.leak_mw, 0.30, &format!("{name} leakage"));
    within(p.area * 1e6, t.area_mm2, 0.25, &format!("{name} area"));
}

#[test]
fn table2_calibration() {
    for t in &TABLE2 {
        let tc = tuned_cache(t.tech, t.mb * MB);
        check(t, &tc.ppa);
    }
}

#[test]
fn iso_capacity_relative_shape() {
    // The *relative* Table II relations the downstream studies rely on.
    let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
    let stt = tuned_cache(MemTech::SttMram, 3 * MB).ppa;
    let sot = tuned_cache(MemTech::SotMram, 3 * MB).ppa;

    // Area reduction: paper 2.4x (STT), 2.8x (SOT).
    within(sram.area / stt.area, 2.4, 0.25, "STT area reduction");
    within(sram.area / sot.area, 2.8, 0.25, "SOT area reduction");
    // Leakage reduction: paper 8.6x / 12.2x.
    within(sram.leakage_power / stt.leakage_power, 8.6, 0.30, "STT leak red.");
    within(sram.leakage_power / sot.leakage_power, 12.2, 0.35, "SOT leak red.");
    // Write latency: STT ~6x SRAM; SOT comparable to SRAM.
    assert!(stt.write_latency > 4.0 * sram.write_latency);
    assert!(sot.write_latency < 1.5 * sram.write_latency);
    // Read energy: MRAMs cost more per read than SRAM (iso-capacity).
    assert!(stt.read_energy > sram.read_energy);
    assert!(sot.read_energy > sram.read_energy);
}

#[test]
fn iso_area_capacity_gains() {
    // Paper: within SRAM's 3MB footprint, STT fits 7MB (2.3x) and SOT
    // fits 10MB (3.3x).
    let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
    let stt7 = tuned_cache(MemTech::SttMram, 7 * MB).ppa;
    let sot10 = tuned_cache(MemTech::SotMram, 10 * MB).ppa;
    within(stt7.area * 1e6, sram.area * 1e6, 0.25, "STT 7MB fits SRAM 3MB area");
    within(sot10.area * 1e6, sram.area * 1e6, 0.30, "SOT 10MB fits SRAM 3MB area");
}
