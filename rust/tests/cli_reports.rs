//! CLI + report + store integration: every fast command produces a
//! printable table and a persistable CSV, and the store index is
//! readable back.

use deepnvm::coordinator::cli::{generate, parse_args, CliOptions};
use deepnvm::coordinator::store::Store;
use deepnvm::util::json;

fn opts(cmd: &str) -> CliOptions {
    parse_args(&[cmd.to_string(), "--quick".to_string()]).unwrap()
}

#[test]
fn every_table_command_generates() {
    for cmd in ["table1", "table2", "table3", "fig1"] {
        let rs = generate(&opts(cmd)).unwrap();
        assert!(!rs.is_empty(), "{cmd}");
        for r in rs {
            assert!(r.text.lines().count() > 3, "{cmd}: thin report");
            assert!(r.csv.n_rows() > 0, "{cmd}: empty csv");
        }
    }
}

#[test]
fn analysis_figures_generate_quick() {
    for cmd in ["fig3", "fig5", "fig7", "fig9", "fig10"] {
        let rs = generate(&opts(cmd)).unwrap();
        assert!(!rs.is_empty(), "{cmd}");
    }
}

#[test]
fn store_roundtrip_via_cli_pipeline() {
    let dir = std::env::temp_dir().join("deepnvm_cli_store");
    let _ = std::fs::remove_dir_all(&dir);
    let rs = generate(&opts("table3")).unwrap();
    let mut store = Store::new(&dir);
    for r in &rs {
        store.save(r).unwrap();
    }
    let idx = store.finish(&[("command", "table3")]).unwrap();
    let parsed = json::parse(&std::fs::read_to_string(&idx).unwrap()).unwrap();
    assert!(parsed.get("experiments").unwrap().get("T3").is_some());
    assert!(dir.join("t3.csv").exists());
    // CSV has 5 networks + header
    let csv = std::fs::read_to_string(dir.join("t3.csv")).unwrap();
    assert_eq!(csv.lines().count(), 6);
}

#[test]
fn fig5_custom_batches_respected() {
    let o = parse_args(&[
        "fig5".to_string(),
        "--batches".to_string(),
        "2,32".to_string(),
    ])
    .unwrap();
    let rs = generate(&o).unwrap();
    assert_eq!(rs[0].csv.n_rows(), 2 * 2 * 2);
}
