//! CLI + report + store integration: every fast command produces a
//! printable table and a persistable CSV, and the store index is
//! readable back. Also the golden paper-batch pins: fig5 and table2
//! rows must be byte-identical to the pre-BatchLine per-batch
//! recompute path.

use deepnvm::analysis::{evaluate, iso_capacity, DramCost};
use deepnvm::coordinator::cli::{generate, parse_args, CliOptions};
use deepnvm::coordinator::reports;
use deepnvm::coordinator::store::Store;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::util::json;
use deepnvm::util::table::f;
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::TrafficModel;

fn opts(cmd: &str) -> CliOptions {
    parse_args(&[cmd.to_string(), "--quick".to_string()]).unwrap()
}

#[test]
fn every_table_command_generates() {
    for cmd in ["table1", "table2", "table3", "fig1"] {
        let rs = generate(&opts(cmd)).unwrap();
        assert!(!rs.is_empty(), "{cmd}");
        for r in rs {
            assert!(r.text.lines().count() > 3, "{cmd}: thin report");
            assert!(r.csv.n_rows() > 0, "{cmd}: empty csv");
        }
    }
}

#[test]
fn analysis_figures_generate_quick() {
    for cmd in ["fig3", "fig5", "fig7", "fig9", "fig10"] {
        let rs = generate(&opts(cmd)).unwrap();
        assert!(!rs.is_empty(), "{cmd}");
    }
}

#[test]
fn store_roundtrip_via_cli_pipeline() {
    let dir = std::env::temp_dir().join("deepnvm_cli_store");
    let _ = std::fs::remove_dir_all(&dir);
    let rs = generate(&opts("table3")).unwrap();
    let mut store = Store::new(&dir);
    for r in &rs {
        store.save(r).unwrap();
    }
    let idx = store.finish(&[("command", "table3")]).unwrap();
    let parsed = json::parse(&std::fs::read_to_string(&idx).unwrap()).unwrap();
    assert!(parsed.get("experiments").unwrap().get("T3").is_some());
    assert!(dir.join("t3.csv").exists());
    // CSV has 5 networks + header
    let csv = std::fs::read_to_string(dir.join("t3.csv")).unwrap();
    assert_eq!(csv.lines().count(), 6);
}

#[test]
fn fig5_custom_batches_respected() {
    let o = parse_args(&[
        "fig5".to_string(),
        "--batches".to_string(),
        "2,32".to_string(),
    ])
    .unwrap();
    let rs = generate(&o).unwrap();
    assert_eq!(rs[0].csv.n_rows(), 2 * 2 * 2);
}

#[test]
fn fig5_csv_byte_identical_to_per_batch_recompute_at_paper_batches() {
    // Golden pin for the BatchLine rewire: the fig5 rows at the paper
    // batches — 4 (inference) and 64 (training) — must be
    // byte-identical to the pre-BatchLine implementation, which re-ran
    // the full GEMM lowering at every batch. That legacy path is
    // inlined here verbatim (same loop order, same float ops, same
    // formatting).
    let o = parse_args(&[
        "fig5".to_string(),
        "--batches".to_string(),
        "4,64".to_string(),
    ])
    .unwrap();
    let csv = generate(&o).unwrap()[0].csv.to_string();

    let caches = iso_capacity::iso_caches();
    let traffic = TrafficModel {
        l2_bytes: iso_capacity::ISO_CAPACITY,
        ..Default::default()
    };
    let dram = DramCost::default();
    let dnn = Dnn::by_name("AlexNet").unwrap();
    let mut want = vec!["batch,phase,tech,edp_reduction".to_string()];
    for &b in &[4usize, 64] {
        for phase in Phase::ALL {
            let stats = traffic.run(&dnn, phase, b);
            let sram = evaluate(&stats, &caches[0].1, Some(dram));
            for &(tech, ppa) in &caches[1..] {
                let e = evaluate(&stats, &ppa, Some(dram));
                let norm = e.edp() / sram.edp();
                want.push(format!(
                    "{b},{},{},{}",
                    phase.name(),
                    tech.name(),
                    f(1.0 / norm, 2)
                ));
            }
        }
    }
    assert_eq!(csv.lines().collect::<Vec<_>>(), want, "fig5 rows drifted");
}

#[test]
fn table2_csv_byte_identical_to_direct_solver_rows() {
    // table2 carries no traffic terms, so the batch-axis rewire must
    // leave it untouched: rows pinned against direct Algorithm-1
    // solves, and stable across repeated generation.
    let report = reports::table2();
    let csv = report.csv.to_string();
    assert_eq!(csv, reports::table2().csv.to_string(), "non-deterministic");

    const MB: u64 = 1024 * 1024;
    let points: [(&str, deepnvm::device::MemTech, u64); 5] = [
        ("SRAM 3MB", deepnvm::device::MemTech::Sram, 3),
        ("STT 3MB (iso-cap)", deepnvm::device::MemTech::SttMram, 3),
        ("STT 7MB (iso-area)", deepnvm::device::MemTech::SttMram, 7),
        ("SOT 3MB (iso-cap)", deepnvm::device::MemTech::SotMram, 3),
        ("SOT 10MB (iso-area)", deepnvm::device::MemTech::SotMram, 10),
    ];
    let mut want = vec![
        "design,read_lat_ns,write_lat_ns,read_nj,write_nj,leak_mw,area_mm2,org"
            .to_string(),
    ];
    for (name, tech, mb) in points {
        let c = tuned_cache(tech, mb * MB);
        let p = c.ppa;
        want.push(format!(
            "{name},{},{},{},{},{},{},{}",
            f(p.read_latency * 1e9, 2),
            f(p.write_latency * 1e9, 2),
            f(p.read_energy * 1e9, 2),
            f(p.write_energy * 1e9, 2),
            f(p.leakage_power * 1e3, 0),
            f(p.area * 1e6, 2),
            c.org.describe(),
        ));
    }
    assert_eq!(csv.lines().collect::<Vec<_>>(), want, "table2 rows drifted");
}
