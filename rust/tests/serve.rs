//! End-to-end guarantees of the serve subsystem, over real sockets:
//!
//! (a) a `/sweep` for the fig9 slice returns rows value-identical to
//!     `analysis::scalability` (and therefore to the `fig9` CLI CSV,
//!     whose formatting is pinned in `tests/sweep.rs`), and a warm
//!     repeat performs zero circuit solves and zero evaluations;
//! (b) `/memo/merge` of two disjoint shard exports reproduces the
//!     full-grid memo — the merged server replays the whole grid
//!     without solving — while tampered entries are rejected with
//!     their payload-hash checks failing;
//! (c) `/solve` answers from cache on repeat, and protocol errors map
//!     to 4xx, never a hang or a worker death;
//! (d) `/metrics` and `/trace` expose live telemetry — the series and
//!     spans this file's own traffic creates, not a static page;
//! (f) every response carries the `Deepnvm-Api-Version` header, every
//!     4xx/5xx body carries the typed `{"error": {code, kind,
//!     message}}` envelope with a stable kind, and `/optimize` answers
//!     a live search (and a typed 422 on an infeasible budget);
//! (g) with an auth key set, unsigned/tampered/wrong-key mutating
//!     requests are typed 401s that leave the memo bit-identical, a
//!     fully signed fleet exchange converges exactly like an open one,
//!     and flooding past the accept-queue cap sheds with 503 +
//!     `Retry-After` while the server stays live.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use deepnvm::analysis::scalability;
use deepnvm::serve::auth;
use deepnvm::serve::http::Server;
use deepnvm::serve::routes::{self, ServerCtx};
use deepnvm::serve::shard;
use deepnvm::sweep::{Memo, SweepSpec};
use deepnvm::util::json::{self, Json};
use deepnvm::util::table::f;
use deepnvm::workload::models::Phase;
use deepnvm::device::MemTech;

const MB: u64 = 1024 * 1024;

fn leaked_memo() -> &'static Memo {
    Box::leak(Box::new(Memo::new()))
}

fn boot(memo: &'static Memo) -> Server {
    let ctx = Arc::new(ServerCtx::new(memo, 2));
    Server::bind("127.0.0.1:0", 2, move |req| routes::handle(&ctx, req)).unwrap()
}

/// Raw one-shot HTTP client: returns (status, body). With `tag`, the
/// request carries an `X-Deepnvm-Auth` header.
fn request_tagged(
    server: &Server,
    method: &str,
    path: &str,
    body: &str,
    tag: Option<&str>,
) -> (u16, String) {
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    let auth_line = match tag {
        Some(t) => format!("{}: {t}\r\n", auth::AUTH_HEADER),
        None => String::new(),
    };
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n{auth_line}\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {buf:?}"))
        .parse()
        .unwrap();
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn request(server: &Server, method: &str, path: &str, body: &str) -> (u16, String) {
    request_tagged(server, method, path, body, None)
}

fn get(server: &Server, path: &str) -> (u16, String) {
    request(server, "GET", path, "")
}

fn post(server: &Server, path: &str, body: &str) -> (u16, String) {
    request(server, "POST", path, body)
}

// ---------------------------------------------------------------- (a)

#[test]
fn sweep_fig9_rows_match_scalability_and_warm_repeat_is_free() {
    let memo = leaked_memo();
    let server = boot(memo);
    let body = r#"{"report": "fig9", "caps_mb": [1, 2]}"#;

    let (status, text) = post(&server, "/sweep", body);
    assert_eq!(status, 200, "{text}");
    let j = json::parse(&text).unwrap();

    // expected rows from the analysis layer, formatted exactly as the
    // fig9 CLI CSV formats them
    let expect: Vec<Vec<String>> = scalability::ppa_sweep(&[1, 2])
        .iter()
        .map(|c| {
            let p = c.ppa;
            vec![
                c.tech.name().to_string(),
                (c.capacity_bytes / MB).to_string(),
                f(p.read_latency * 1e9, 2),
                f(p.write_latency * 1e9, 2),
                f(p.read_energy * 1e9, 3),
                f(p.write_energy * 1e9, 3),
                f(p.leakage_power * 1e3, 0),
                f(p.area * 1e6, 2),
            ]
        })
        .collect();
    let rows = j.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), expect.len());
    for (row, want) in rows.iter().zip(&expect) {
        let got: Vec<String> = row
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        assert_eq!(&got, want, "HTTP rows must match the fig9 CSV cells");
    }

    // warm repeat: pure memo hits
    let solves = memo.solve_count();
    let evals = memo.eval_count();
    let (status, text) = post(&server, "/sweep", body);
    assert_eq!(status, 200);
    let j = json::parse(&text).unwrap();
    assert_eq!(j.get("solves").unwrap().as_u64(), Some(0));
    assert_eq!(j.get("evals").unwrap().as_u64(), Some(0));
    assert_eq!(memo.solve_count(), solves, "warm /sweep must not solve");
    assert_eq!(memo.eval_count(), evals, "warm /sweep must not re-evaluate");
}

#[test]
fn sweep_default_report_round_trips_spec_options() {
    let server = boot(leaked_memo());
    let body = r#"{"techs": ["sot"], "caps_mb": [1], "dnns": ["SqueezeNet"],
                   "phases": ["training"], "pareto": true, "render": true}"#;
    let (status, text) = post(&server, "/sweep", body);
    assert_eq!(status, 200, "{text}");
    let j = json::parse(&text).unwrap();
    assert_eq!(j.get("id").unwrap().as_str(), Some("SW"));
    let rows = j.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    let header = j.get("header").unwrap().as_arr().unwrap();
    assert_eq!(header[0].as_str(), Some("tech"));
    assert!(j.get("text").unwrap().as_str().unwrap().contains("Pareto frontier"));
}

// ---------------------------------------------------------------- (b)

#[test]
fn disjoint_shard_merges_reproduce_the_full_grid_memo() {
    let full = SweepSpec {
        techs: deepnvm::nvsim::TechSel::pures(&MemTech::ALL),
        capacities_mb: vec![1, 2],
        dnns: vec!["AlexNet".into()],
        phases: Phase::ALL.to_vec(),
        batches: vec![],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let shards = shard::split_caps(&full, 2);
    assert_eq!(shards.len(), 2);

    let memo = leaked_memo();
    let server = boot(memo);
    let mut exports = Vec::new();
    for s in &shards {
        let worker = Memo::new();
        let doc = shard::run_shard(s, 2, &worker).unwrap();
        exports.push(doc.to_pretty());
    }
    for e in &exports {
        let (status, text) = post(&server, "/memo/merge", e);
        assert_eq!(status, 200, "{text}");
        let j = json::parse(&text).unwrap();
        assert_eq!(j.get("version_ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("rejected").unwrap().as_u64(), Some(0));
        assert!(j.get("accepted").unwrap().as_u64().unwrap() > 0);
    }

    // the merged cache answers the FULL grid over HTTP with zero work
    let spec_body = r#"{"techs": ["sram", "stt", "sot"], "caps_mb": [1, 2],
                        "dnns": ["AlexNet"]}"#;
    let (status, text) = post(&server, "/sweep", spec_body);
    assert_eq!(status, 200, "{text}");
    let j = json::parse(&text).unwrap();
    assert_eq!(j.get("solves").unwrap().as_u64(), Some(0), "zero solves on replay");
    assert_eq!(j.get("evals").unwrap().as_u64(), Some(0), "zero evals on replay");
    assert_eq!(memo.solve_count(), 0);
    assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 12);

    // export from the coordinator equals a re-mergeable document
    let (status, text) = get(&server, "/memo/export");
    assert_eq!(status, 200);
    let reimport = Memo::new();
    let st = reimport.merge_json(&json::parse(&text).unwrap());
    assert!(st.version_ok);
    assert_eq!(st.rejected, 0);
    assert_eq!(reimport.point_len(), memo.point_len());
}

#[test]
fn partial_merge_accounts_exactly_and_leaves_the_memo_consistent() {
    // Worker A's shard: caps [1]; the full export additionally carries
    // caps [2]. Per capacity the export holds 2 circuit entries
    // (stt + the sram baseline) and 2 point entries (two phases), plus
    // — shared across the capacities — 2 traffic lines (AlexNet x two
    // phases): 6 entries in shard A's document, 10 in the full one.
    let spec = SweepSpec {
        techs: vec![MemTech::SttMram.into()],
        capacities_mb: vec![1, 2],
        dnns: vec!["AlexNet".into()],
        phases: Phase::ALL.to_vec(),
        batches: vec![],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let shard_a = SweepSpec { capacities_mb: vec![1], ..spec.clone() };

    let worker = Memo::new();
    let export_a = shard::run_shard(&shard_a, 1, &worker).unwrap();
    let export_full = shard::run_shard(&spec, 1, &worker).unwrap();
    assert_eq!(export_a.get("traffic").unwrap().as_arr().unwrap().len(), 2);

    // tamper with exactly one cap-2 point entry in the full document
    let victim = export_full
        .get("points")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|p| p.get("capacity_mb").unwrap().as_u64() == Some(2))
        .expect("a cap-2 point entry");
    let victim_hash = victim.get("payload_hash").unwrap().as_str().unwrap();
    let text = export_full.to_pretty();
    let tampered = text.replace(victim_hash, "00000000deadbeef");
    assert_ne!(tampered, text);

    // resident memo already holds shard A
    let memo = leaked_memo();
    let server = boot(memo);
    let (status, body) = post(&server, "/memo/merge", &export_a.to_pretty());
    assert_eq!(status, 200, "{body}");
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("accepted").unwrap().as_u64(), Some(6), "{body}");
    assert_eq!(j.get("skipped").unwrap().as_u64(), Some(0));
    assert_eq!(j.get("rejected").unwrap().as_u64(), Some(0));

    // the mixed document: 3 fresh valid entries, 6 duplicates of shard
    // A (circuit + point + traffic), 1 tampered — every entry lands in
    // exactly one bucket
    let (status, body) = post(&server, "/memo/merge", &tampered);
    assert_eq!(status, 200, "{body}");
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("accepted").unwrap().as_u64(), Some(3), "{body}");
    assert_eq!(j.get("skipped").unwrap().as_u64(), Some(6), "{body}");
    assert_eq!(j.get("rejected").unwrap().as_u64(), Some(1), "{body}");

    // the rejected entry was NOT merged: the memo still answers the
    // untampered slice without it...
    assert_eq!(memo.circuit_len(), 4);
    assert_eq!(memo.traffic_len(), 2);
    assert_eq!(memo.point_len(), 3);
    // ...and re-merging the clean document back-fills exactly that one
    // entry, after which the full grid replays with zero work
    let st = memo.merge_json(&export_full);
    assert_eq!((st.accepted, st.skipped, st.rejected), (1, 9, 0));
    assert_eq!(st.total(), 10);
    let res = deepnvm::sweep::run(&spec, 1, memo).unwrap();
    assert_eq!(res.points.len(), 4);
    assert_eq!(memo.solve_count(), 0, "consistent memo: replay solves nothing");
    assert_eq!(memo.eval_count(), 0);
    assert_eq!(memo.traffic_build_count(), 0, "replay folds merged coefficients");
}

#[test]
fn forged_traffic_coefficients_never_poison_the_batch_axis() {
    // A worker ships a batch-axis shard; an attacker rewrites one
    // traffic line's coefficients in flight. The merge must reject the
    // entry on its payload-hash check, and the server must keep
    // serving CORRECT batch rows afterwards (re-deriving the line
    // locally instead of trusting the forged one).
    let spec = SweepSpec {
        techs: vec![MemTech::SttMram.into()],
        capacities_mb: vec![1],
        dnns: vec!["AlexNet".into()],
        phases: vec![Phase::Training],
        batches: vec![8, 16],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let worker = Memo::new();
    let export = shard::run_shard(&spec, 1, &worker).unwrap();
    let text = export.to_pretty();
    // rewrite the MAC slope inside the (only) traffic entry
    let slope = worker.traffic_line("AlexNet", Phase::Training).macs_slope;
    let needle = format!("\"macs_slope\": {slope}");
    let forged = text.replace(&needle, "\"macs_slope\": 1");
    assert_ne!(forged, text);

    let memo = leaked_memo();
    let server = boot(memo);
    let (status, body) = post(&server, "/memo/merge", &forged);
    assert_eq!(status, 200, "{body}");
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("rejected").unwrap().as_u64(), Some(1), "{body}");
    assert_eq!(memo.traffic_len(), 0, "forged line must not become resident");

    // Query a batch the merged export did NOT carry (32): its point is
    // uncached, so the server must evaluate through a traffic line —
    // forcing it to re-derive the genuine coefficients locally instead
    // of trusting anything forged. Rows must equal a clean local
    // computation, batch for batch.
    let query_spec = SweepSpec { batches: vec![8, 32], ..spec.clone() };
    let body_sweep = r#"{"techs": ["stt"], "caps_mb": [1], "dnns": ["AlexNet"],
                         "phases": ["training"], "batches": [8, 32]}"#;
    let (status, body) = post(&server, "/sweep", body_sweep);
    assert_eq!(status, 200, "{body}");
    let j = json::parse(&body).unwrap();
    assert_eq!(
        memo.traffic_build_count(),
        1,
        "the uncached batch must have forced a local line derivation"
    );
    let clean = deepnvm::coordinator::reports::sweep_report_with(
        &query_spec,
        1,
        false,
        &Memo::new(),
    )
    .unwrap();
    let rows = j.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), clean.csv.rows().len());
    for (row, want) in rows.iter().zip(clean.csv.rows()) {
        let got: Vec<&str> =
            row.as_arr().unwrap().iter().map(|c| c.as_str().unwrap()).collect();
        let want: Vec<&str> = want.iter().map(|s| s.as_str()).collect();
        assert_eq!(got, want);
    }
}

#[test]
fn model_version_2_shard_documents_are_rejected_on_merge() {
    // Pre-BatchLine (v2) exports carried strictly per-batch results;
    // mixing them into a v3 memo would resurrect entries whose hashes
    // know nothing of the traffic section. The merge route must 409
    // with zero entries accounted.
    let worker = Memo::new();
    let mut doc = shard::run_shard(
        &SweepSpec {
            techs: vec![MemTech::SttMram.into()],
            capacities_mb: vec![1],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![4],
            nodes_nm: vec![16],
            filters: vec![],
        },
        1,
        &worker,
    )
    .unwrap();
    doc.set("version", Json::Num(2.0));

    let memo = leaked_memo();
    let server = boot(memo);
    let (status, body) = post(&server, "/memo/merge", &doc.to_pretty());
    assert_eq!(status, 409, "{body}");
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("version_ok").unwrap().as_bool(), Some(false));
    assert_eq!(j.get("accepted").unwrap().as_u64(), Some(0));
    assert_eq!(j.get("skipped").unwrap().as_u64(), Some(0));
    assert_eq!(j.get("rejected").unwrap().as_u64(), Some(0));
    assert_eq!(memo.circuit_len() + memo.traffic_len() + memo.point_len(), 0);
}

#[test]
fn tampered_shard_entries_are_rejected() {
    let worker = Memo::new();
    let doc = shard::run_shard(
        &SweepSpec::circuit_only(vec![MemTech::SttMram], vec![1]),
        1,
        &worker,
    )
    .unwrap();
    let text = doc.to_pretty();
    // corrupt the first payload hash in the document
    let needle = "\"payload_hash\": \"";
    let at = text.find(needle).unwrap() + needle.len();
    let mut tampered = text.clone();
    tampered.replace_range(at..at + 16, "0123456789abcdef");
    assert_ne!(tampered, text);

    let server = boot(leaked_memo());
    let (status, body) = post(&server, "/memo/merge", &tampered);
    assert_eq!(status, 200);
    let j = json::parse(&body).unwrap();
    assert!(j.get("rejected").unwrap().as_u64().unwrap() >= 1, "{body}");

    // stale model version: 409, nothing merged
    let mut stale = doc;
    stale.set("version", Json::Num(0.0));
    let (status, body) = post(&server, "/memo/merge", &stale.to_pretty());
    assert_eq!(status, 409);
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("version_ok").unwrap().as_bool(), Some(false));
    assert_eq!(j.get("accepted").unwrap().as_u64(), Some(0));
}

// ---------------------------------------------------------------- (c)

#[test]
fn solve_healthz_and_protocol_errors() {
    let memo = leaked_memo();
    let server = boot(memo);

    let (status, text) = get(&server, "/healthz");
    assert_eq!(status, 200);
    assert!(text.contains("\"status\": \"ok\""), "{text}");

    let body = r#"{"tech": "sot", "capacity_mb": 1, "dnn": "AlexNet", "phase": "training"}"#;
    let (status, text) = post(&server, "/solve", body);
    assert_eq!(status, 200, "{text}");
    let j = json::parse(&text).unwrap();
    assert_eq!(j.get("cached").unwrap().as_bool(), Some(false));
    let eval = j.get("result").unwrap().get("eval").unwrap();
    assert!(eval.get("edp_norm").unwrap().as_f64().unwrap() > 0.0);

    let (_, text) = post(&server, "/solve", body);
    let j = json::parse(&text).unwrap();
    assert_eq!(j.get("cached").unwrap().as_bool(), Some(true));

    let (status, text) = get(&server, "/memo/stats");
    assert_eq!(status, 200);
    let j = json::parse(&text).unwrap();
    assert!(j.get("point_entries").unwrap().as_u64().unwrap() >= 1);
    assert_eq!(j.get("point_capacity").unwrap(), &Json::Null);

    // error mapping
    assert_eq!(post(&server, "/solve", "{oops").0, 400);
    assert_eq!(post(&server, "/solve", r#"{"tech": "stt"}"#).0, 422);
    assert_eq!(get(&server, "/bogus").0, 404);
    assert_eq!(get(&server, "/sweep").0, 405);
}

// ---------------------------------------------------------------- (d)

#[test]
fn metrics_and_trace_expose_live_telemetry_over_http() {
    let memo = leaked_memo();
    let server = boot(memo);

    assert_eq!(get(&server, "/healthz").0, 200);
    let solve = r#"{"tech": "stt", "capacity_mb": 1, "dnn": "AlexNet", "phase": "inference"}"#;
    assert_eq!(post(&server, "/solve", solve).0, 200);

    // raw scrape, so the exposition content type is visible
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\nContent-Length: 0\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let (head, text) = raw.split_once("\r\n\r\n").unwrap();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");

    // The registry is process-global and shared by every test in this
    // binary, so only floors are exact here — but this test's own two
    // requests guarantee each of these series exists.
    let series = text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')).count();
    assert!(series >= 10, "only {series} series in:\n{text}");
    for needle in [
        "# TYPE deepnvm_http_requests_total counter",
        "# TYPE deepnvm_circuit_solve_duration_ns histogram",
        "deepnvm_circuit_solve_duration_ns_bucket{",
        "deepnvm_circuit_solves_total",
        "deepnvm_memo_circuit_misses_total",
        "deepnvm_uptime_seconds",
        "deepnvm_http_request_duration_ns_count{route=\"/solve\"}",
        "deepnvm_http_responses_total{route=\"/healthz\",status=\"200\"}",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }

    // the span timeline exports as Chrome trace events and holds the
    // spans this test's own requests opened
    let (status, text) = get(&server, "/trace");
    assert_eq!(status, 200);
    let j = json::parse(&text).unwrap();
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    assert!(
        events.iter().any(|e| e.get("name").unwrap().as_str() == Some("http./solve")),
        "no http./solve span recorded"
    );
}

// ---------------------------------------------------------------- (e)

#[test]
fn loadgen_soaks_a_live_server_and_reports_quantiles() {
    use deepnvm::serve::loadgen::{self, LoadgenConfig};
    use std::time::Duration;

    let memo = leaked_memo();
    let server = boot(memo);
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        duration: Duration::from_millis(800),
        concurrency: 2,
        solve_weight: 3,
        sweep_weight: 1,
        optimize_weight: 1,
        hot_frac: Some(0.5),
        p99_ms: None,
        auth_key: None,
    };
    let report = loadgen::run(&cfg).unwrap();
    assert!(report.requests > 0, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");
    assert!(report.qps > 0.0, "{report:?}");
    assert!(
        report.solve.requests > 0
            && report.sweep.requests > 0
            && report.optimize.requests > 0,
        "the 3:1:1 mix must exercise all three kinds: {report:?}"
    );
    // --hot-frac 0.5 classifies every /solve: both classes must show
    // up, they must sum to the solve kind, and the cold tail (hybrid
    // cache-miss bodies) must have been served without errors.
    let hot = report.hot.as_ref().expect("hot stats with hot_frac set");
    let cold = report.cold.as_ref().expect("cold stats with hot_frac set");
    assert!(hot.requests > 0 && cold.requests > 0, "{report:?}");
    assert_eq!(hot.requests + cold.requests, report.solve.requests, "{report:?}");
    assert!(report.p50_ms <= report.p99_ms, "{report:?}");
    assert!(report.meets_p99(f64::INFINITY));
    assert!(!report.meets_p99(0.0), "bucketed quantiles are never zero");
    assert!(report.render().contains("req/s"));
    assert!(report.render().contains("hot"), "{}", report.render());

    // the soak's latency series is scrape-visible on the same registry
    let (status, text) = get(&server, "/metrics");
    assert_eq!(status, 200);
    assert!(
        text.contains("deepnvm_loadgen_request_duration_ns_count{kind=\"solve\"}"),
        "{text}"
    );
}

// ---------------------------------------------------------------- (f)

/// Parse the error envelope `{"error": {code, kind, message}}` out of
/// a response body and return (code, kind).
fn envelope(text: &str) -> (u64, String) {
    let j = json::parse(text).unwrap_or_else(|e| panic!("unparseable body {text:?}: {e}"));
    let e = j.get("error").unwrap_or_else(|| panic!("no envelope in {text}"));
    (
        e.get("code").unwrap().as_u64().unwrap(),
        e.get("kind").unwrap().as_str().unwrap().to_string(),
    )
}

#[test]
fn typed_errors_and_api_version_over_live_http() {
    let memo = leaked_memo();
    let server = boot(memo);

    // the version header rides EVERY response — success and error alike
    for reqline in [
        "GET /healthz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n".to_string(),
        "GET /bogus HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n".to_string(),
    ] {
        let mut s = TcpStream::connect(server.local_addr()).unwrap();
        s.write_all(reqline.as_bytes()).unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let (head, _) = raw.split_once("\r\n\r\n").unwrap();
        assert!(
            head.contains(&format!(
                "Deepnvm-Api-Version: {}",
                deepnvm::sweep::memo::MODEL_VERSION
            )),
            "{head}"
        );
    }

    // /healthz advertises the same version in-band
    let (_, text) = get(&server, "/healthz");
    let j = json::parse(&text).unwrap();
    assert_eq!(
        j.get("api_version").unwrap().as_u64(),
        Some(deepnvm::sweep::memo::MODEL_VERSION as u64)
    );

    // GET / is the generated route table, and it lists /optimize
    let (status, text) = get(&server, "/");
    assert_eq!(status, 200);
    let j = json::parse(&text).unwrap();
    let routes: Vec<&str> = j
        .get("routes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|r| r.get("path").and_then(Json::as_str))
        .collect();
    assert!(routes.contains(&"/optimize"), "{routes:?}");

    // one stable kind per error class, asserted over the wire
    let (status, text) = post(&server, "/solve", "{oops");
    assert_eq!((status, envelope(&text)), (400, (400, "bad_json".into())));

    let (status, text) = post(&server, "/solve", r#"{"tech": "stt"}"#);
    assert_eq!((status, envelope(&text)), (422, (422, "invalid_spec".into())));

    let (status, text) =
        post(&server, "/solve", r#"{"tech": "stt", "capacity_mb": 1, "node_nm": 9}"#);
    assert_eq!((status, envelope(&text)), (422, (422, "uncalibrated_node".into())));

    let (status, text) = post(&server, "/sweep", r#"{"report": "fig99"}"#);
    assert_eq!((status, envelope(&text)), (422, (422, "unknown_report".into())));

    let (status, text) = get(&server, "/bogus");
    assert_eq!((status, envelope(&text)), (404, (404, "not_found".into())));

    let (status, text) = get(&server, "/sweep");
    assert_eq!((status, envelope(&text)), (405, (405, "method_not_allowed".into())));

    // /optimize: a live search answers, and an impossible area budget
    // is a typed 422, not a free-text string
    let (status, text) = post(
        &server,
        "/optimize",
        r#"{"techs": ["stt", "sot"], "caps_mb": [1, 2], "dnns": ["AlexNet"],
            "phases": ["inference"], "batches": [1, 4], "objective": "edp",
            "jobs": 2}"#,
    );
    assert_eq!(status, 200, "{text}");
    let j = json::parse(&text).unwrap();
    let winner = j.get("winner").unwrap();
    assert_ne!(winner, &Json::Null, "{text}");
    assert!(winner.get("eval").unwrap().get("edp").unwrap().as_f64().unwrap() > 0.0);
    let total = j.get("points_total").unwrap().as_u64().unwrap();
    let evaluated = j.get("points_evaluated").unwrap().as_u64().unwrap();
    let pruned = j.get("points_pruned").unwrap().as_u64().unwrap();
    assert_eq!((total, evaluated + pruned), (8, 8), "{text}");

    let (status, text) = post(
        &server,
        "/optimize",
        r#"{"techs": ["stt"], "caps_mb": [1], "dnns": [], "objective": "edap",
            "area_max_mm2": 1e-9}"#,
    );
    assert_eq!((status, envelope(&text)), (422, (422, "infeasible".into())));
}

// ---------------------------------------------------------------- (g)
// Hardening: authenticated exchange and bounded admission, end to end.

fn boot_with_auth(memo: &'static Memo, key: &str) -> Server {
    let ctx = Arc::new(ServerCtx::new(memo, 2).with_auth_key(Some(key.to_string())));
    Server::bind("127.0.0.1:0", 2, move |req| routes::handle(&ctx, req)).unwrap()
}

#[test]
fn unsigned_and_tampered_merges_are_401_and_leave_the_memo_bit_identical() {
    let key = "fleet-secret";
    let memo = leaked_memo();
    let server = boot_with_auth(memo, key);

    // A signed merge of a clean shard export is accepted...
    let worker = Memo::new();
    let export = shard::run_shard(
        &SweepSpec::circuit_only(vec![MemTech::SttMram], vec![1]),
        1,
        &worker,
    )
    .unwrap()
    .to_pretty();
    let tag = auth::sign(key, "POST", "/memo/merge", export.as_bytes());
    let (status, text) =
        request_tagged(&server, "POST", "/memo/merge", &export, Some(&tag));
    assert_eq!(status, 200, "{text}");
    let j = json::parse(&text).unwrap();
    assert!(j.get("accepted").unwrap().as_u64().unwrap() > 0, "{text}");

    // ...and becomes the baseline every rejected merge is compared to.
    // GET routes stay open: export needs no signature.
    let (status, baseline) = get(&server, "/memo/export");
    assert_eq!(status, 200);
    let resident =
        (memo.circuit_len(), memo.traffic_len(), memo.point_len());

    // A second, disjoint export: valid content, three invalid ways in.
    let export2 = shard::run_shard(
        &SweepSpec::circuit_only(vec![MemTech::SotMram], vec![2]),
        1,
        &Memo::new(),
    )
    .unwrap()
    .to_pretty();
    let tag2 = auth::sign(key, "POST", "/memo/merge", export2.as_bytes());

    // (1) unsigned
    let (status, text) = post(&server, "/memo/merge", &export2);
    assert_eq!((status, envelope(&text)), (401, (401, "unauthorized".into())), "{text}");
    // (2) valid tag over a body that was then tampered with
    let tampered = format!("{export2} ");
    let (status, text) =
        request_tagged(&server, "POST", "/memo/merge", &tampered, Some(&tag2));
    assert_eq!((status, envelope(&text)), (401, (401, "unauthorized".into())), "{text}");
    // (3) tag minted under the wrong key
    let forged = auth::sign("not-the-key", "POST", "/memo/merge", export2.as_bytes());
    let (status, text) =
        request_tagged(&server, "POST", "/memo/merge", &export2, Some(&forged));
    assert_eq!((status, envelope(&text)), (401, (401, "unauthorized".into())), "{text}");

    // Zero entries merged by any of the three: the memo is bit-identical.
    assert_eq!(
        (memo.circuit_len(), memo.traffic_len(), memo.point_len()),
        resident,
        "a rejected merge must not change residency"
    );
    let (_, after) = get(&server, "/memo/export");
    assert_eq!(after, baseline, "a rejected merge must leave the export bit-identical");

    // The same document with its honest tag proves the rejections were
    // about the signature, not the payload.
    let (status, text) =
        request_tagged(&server, "POST", "/memo/merge", &export2, Some(&tag2));
    assert_eq!(status, 200, "{text}");

    // /solve is gated the same way: unsigned 401, signed 200.
    let solve = r#"{"tech": "stt", "capacity_mb": 1}"#;
    let (status, text) = post(&server, "/solve", solve);
    assert_eq!((status, envelope(&text)), (401, (401, "unauthorized".into())), "{text}");
    let tag = auth::sign(key, "POST", "/solve", solve.as_bytes());
    let (status, text) = request_tagged(&server, "POST", "/solve", solve, Some(&tag));
    assert_eq!(status, 200, "{text}");
}

#[test]
fn a_signed_fleet_exchange_converges_identically_to_an_open_one() {
    // The same two-shard exchange, once over an open server and once
    // over an authenticated one with every merge signed: the resident
    // memos must export byte-for-byte the same entry counts and answer
    // the full grid with zero solves either way.
    let key = "fleet-secret";
    let spec = SweepSpec::circuit_only(vec![MemTech::SttMram, MemTech::SotMram], vec![1, 2]);
    let shards = shard::split_caps(&spec, 2);
    assert_eq!(shards.len(), 2);
    let exports: Vec<String> = shards
        .iter()
        .map(|s| shard::run_shard(s, 1, &Memo::new()).unwrap().to_pretty())
        .collect();

    let open_memo = leaked_memo();
    let open = boot(open_memo);
    for e in &exports {
        assert_eq!(post(&open, "/memo/merge", e).0, 200);
    }

    let auth_memo = leaked_memo();
    let authed = boot_with_auth(auth_memo, key);
    for e in &exports {
        let tag = auth::sign(key, "POST", "/memo/merge", e.as_bytes());
        let (status, text) =
            request_tagged(&authed, "POST", "/memo/merge", e, Some(&tag));
        assert_eq!(status, 200, "{text}");
    }

    assert_eq!(open_memo.circuit_len(), auth_memo.circuit_len());
    assert_eq!(open_memo.point_len(), auth_memo.point_len());
    let body = r#"{"techs": ["stt", "sot"], "caps_mb": [1, 2], "dnns": []}"#;
    let tag = auth::sign(key, "POST", "/sweep", body.as_bytes());
    let (_, open_rows) = post(&open, "/sweep", body);
    let (_, auth_rows) = request_tagged(&authed, "POST", "/sweep", body, Some(&tag));
    let or = json::parse(&open_rows).unwrap();
    let ar = json::parse(&auth_rows).unwrap();
    assert_eq!(or.get("rows"), ar.get("rows"), "identical grids either way");
    assert_eq!(ar.get("solves").unwrap().as_u64(), Some(0), "zero solves on replay");
}

#[test]
fn floods_past_the_queue_cap_are_shed_and_the_routes_stack_stays_live() {
    use std::time::Duration;

    let memo = leaked_memo();
    let ctx = Arc::new(ServerCtx::new(memo, 1));
    // One worker, accept queue capped at 1: capacity for at most two
    // admitted connections (one being served + one queued).
    let server =
        Server::bind_with("127.0.0.1:0", 1, Some(1), move |req| routes::handle(&ctx, req))
            .unwrap();

    // Flood with silent connections. An admitted one pins a worker (or
    // a queue slot) inside the 30 s read timeout and stays mute within
    // the probe window; a shed one answers 503 immediately.
    let mut held: Vec<TcpStream> = Vec::new();
    let mut shed_raw = None;
    for _ in 0..20 {
        let mut s = TcpStream::connect(server.local_addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        let mut buf = String::new();
        match s.read_to_string(&mut buf) {
            Ok(_) if buf.starts_with("HTTP/1.1 503") => {
                shed_raw = Some(buf);
                break;
            }
            _ => held.push(s),
        }
    }
    let raw = shed_raw.expect("flooding past the cap must shed a connection");

    // The shed response is the full typed contract: 503, Retry-After,
    // and the stable `overloaded` envelope kind.
    let (head, body) = raw.split_once("\r\n\r\n").unwrap();
    assert!(head.contains("Retry-After: 1"), "{head}");
    assert_eq!(envelope(body), (503, "overloaded".into()), "{body}");
    // Queue stayed bounded: nothing past one in-flight plus one queued
    // was ever admitted.
    assert!(held.len() <= 2, "{} connections admitted past the cap", held.len());

    // Freeing the flood frees the server: health and solve answer again.
    drop(held);
    assert_eq!(get(&server, "/healthz").0, 200);
    let (status, text) =
        post(&server, "/solve", r#"{"tech": "stt", "capacity_mb": 1}"#);
    assert_eq!(status, 200, "{text}");

    // The shed is scrape-visible on the shared registry.
    let (status, text) = get(&server, "/metrics");
    assert_eq!(status, 200);
    let shed_line = text
        .lines()
        .find(|l| l.starts_with("deepnvm_http_shed_total"))
        .unwrap_or_else(|| panic!("no shed counter in:\n{text}"));
    let count: u64 = shed_line.split_whitespace().last().unwrap().parse().unwrap();
    assert!(count >= 1, "{shed_line}");
}
