//! Sweep-engine guarantees:
//!
//! (a) parallel execution is byte-identical to the serial schedule and
//!     returns results in spec order for any `--jobs`;
//! (b) a warm memo (in-process or reloaded from disk) serves reruns
//!     with zero circuit-model solves and zero traffic evaluations;
//! (c) Pareto-frontier extraction is correct on a hand-built grid;
//! (d) the rewired `fig9`/`fig10` reports are numerically identical to
//!     the original serial, unmemoized computation path.

use deepnvm::analysis::{evaluate, DramCost};
use deepnvm::coordinator::reports;
use deepnvm::coordinator::store::Store;
use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::nvsim::TechSel;
use deepnvm::sweep::spec::parse_tech_sel;
use deepnvm::sweep::{self, Memo, SweepSpec};
use deepnvm::util::stats::{mean, std_dev};
use deepnvm::util::table::f;
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::TrafficModel;

const MB: u64 = 1024 * 1024;

fn small_spec() -> SweepSpec {
    // The three pure techs plus a way-partitioned hybrid, so every
    // guarantee below (byte-stable parallel rows, zero-solve warm and
    // disk-restored reruns) covers the hybrid axis too.
    let mut techs = TechSel::pure_all();
    techs.push(parse_tech_sel("hybrid-stt:4@0.85").unwrap());
    SweepSpec {
        techs,
        capacities_mb: vec![1, 2],
        dnns: vec!["AlexNet".into(), "SqueezeNet".into()],
        phases: Phase::ALL.to_vec(),
        batches: vec![],
        nodes_nm: vec![16],
        filters: vec![],
    }
}

// ---------------------------------------------------------------- (a)

#[test]
fn parallel_results_identical_to_serial_and_spec_ordered() {
    let spec = small_spec();
    let serial = sweep::run(&spec, 1, &Memo::new()).unwrap();

    // spec-ordered: result i belongs to expansion point i
    let expanded = spec.expand().unwrap();
    assert_eq!(serial.points.len(), expanded.len());
    for (r, p) in serial.points.iter().zip(&expanded) {
        assert_eq!(r.point, *p);
    }

    // byte-identical across worker counts (Debug shows every f64 bit)
    let reference = format!("{:?}", serial.points);
    for jobs in [2, 3, 4, 8] {
        let par = sweep::run(&spec, jobs, &Memo::new()).unwrap();
        assert_eq!(format!("{:?}", par.points), reference, "jobs={jobs}");
    }
}

// ---------------------------------------------------------------- (b)

#[test]
fn warm_memo_rerun_solves_and_evaluates_nothing() {
    let spec = small_spec();
    let memo = Memo::new();
    let first = sweep::run(&spec, 4, &memo).unwrap();
    let solves = memo.solve_count();
    let evals = memo.eval_count();
    assert!(solves > 0, "cold run must solve circuits");
    assert!(evals > 0, "cold run must evaluate points");

    let second = sweep::run(&spec, 4, &memo).unwrap();
    assert_eq!(memo.solve_count(), solves, "warm rerun performed circuit solves");
    assert_eq!(memo.eval_count(), evals, "warm rerun re-evaluated points");
    assert_eq!(
        format!("{:?}", first.points),
        format!("{:?}", second.points),
        "memoized results must be identical"
    );
}

#[test]
fn on_disk_memo_restores_across_processes() {
    let dir = std::env::temp_dir().join("deepnvm_sweep_disk_memo_test");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Store::new(&dir);
    let spec = small_spec();

    let hot = Memo::new();
    let first = sweep::run(&spec, 2, &hot).unwrap();
    hot.save_to(&store).unwrap();

    // a fresh Memo stands in for a fresh process
    let cold = Memo::new();
    assert!(cold.load_from(&store).unwrap() > 0);
    let second = sweep::run(&spec, 2, &cold).unwrap();
    assert_eq!(cold.solve_count(), 0, "disk-warmed run must not solve");
    assert_eq!(cold.eval_count(), 0, "disk-warmed run must not evaluate");
    assert_eq!(format!("{:?}", first.points), format!("{:?}", second.points));
}

#[test]
fn batch_axis_sweep_identical_to_per_batch_recompute() {
    // The closed-form batch engine: coefficients lowered once per
    // (dnn, phase), every batch a fold — and every eval field exactly
    // equal to the legacy path that re-ran TrafficModel::run at each
    // (batch, capacity), inlined here verbatim.
    let spec = SweepSpec {
        techs: TechSel::pures(&[MemTech::SttMram, MemTech::SotMram]),
        capacities_mb: vec![2],
        dnns: vec!["AlexNet".into(), "SqueezeNet".into()],
        phases: Phase::ALL.to_vec(),
        batches: vec![1, 4, 64, 65],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let memo = Memo::new();
    let res = sweep::run(&spec, 2, &memo).unwrap();
    assert_eq!(res.points.len(), 2 * 2 * 2 * 4);
    assert_eq!(
        memo.traffic_build_count(),
        4,
        "2 dnns x 2 phases — NOT x 4 batches"
    );

    let dram = DramCost::default();
    for p in &res.points {
        let w = p.point.workload.unwrap();
        let bytes = p.point.capacity_mb * MB;
        let dnn = Dnn::by_name(w.dnn).unwrap();
        let traffic = TrafficModel { l2_bytes: bytes, ..Default::default() };
        let stats = traffic.run(&dnn, w.phase, w.batch);
        let tech = p.point.tech.pure().expect("this spec is all-pure");
        let e = evaluate(&stats, &tuned_cache(tech, bytes).ppa, Some(dram));
        let base = evaluate(&stats, &tuned_cache(MemTech::Sram, bytes).ppa, Some(dram));
        let ev = p.eval.unwrap();
        assert_eq!(ev.energy_j, e.energy(), "{w:?}");
        assert_eq!(ev.time_s, e.time_total, "{w:?}");
        assert_eq!(ev.edp, e.edp(), "{w:?}");
        assert_eq!(ev.energy_norm, e.energy() / base.energy(), "{w:?}");
        assert_eq!(ev.latency_norm, e.time_total / base.time_total, "{w:?}");
        assert_eq!(ev.edp_norm, e.edp() / base.edp(), "{w:?}");
    }
}

// ---------------------------------------------------------------- (c)

#[test]
fn pareto_frontier_correct_on_hand_built_grid() {
    use deepnvm::sweep::pareto::{dominates, frontier_indices, Objective};

    struct P {
        edp: f64,
        area: f64,
        capacity: f64,
    }
    let objectives = [
        Objective::<P> { name: "edp", maximize: false, get: |p| p.edp },
        Objective::<P> { name: "area", maximize: false, get: |p| p.area },
        Objective::<P> { name: "capacity", maximize: true, get: |p| p.capacity },
    ];
    let grid = [
        P { edp: 1.0, area: 1.0, capacity: 4.0 }, // optimal all-round
        P { edp: 2.0, area: 2.0, capacity: 2.0 }, // dominated by [0]
        P { edp: 0.5, area: 3.0, capacity: 4.0 }, // wins on EDP alone
    ];
    assert!(dominates(&grid[0], &grid[1], &objectives));
    assert!(!dominates(&grid[0], &grid[2], &objectives));
    assert!(!dominates(&grid[2], &grid[0], &objectives));
    assert_eq!(frontier_indices(&grid, &objectives), vec![0, 2]);
}

#[test]
fn pareto_on_real_grid_prefers_nvm_at_scale() {
    // On a {STT, SOT} x {2, 32} MB AlexNet grid, the frontier must not
    // be empty and every frontier member must be undominated.
    let spec = SweepSpec {
        techs: TechSel::pures(&[MemTech::SttMram, MemTech::SotMram]),
        capacities_mb: vec![2, 32],
        dnns: vec!["AlexNet".into()],
        phases: vec![Phase::Training],
        batches: vec![],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let res = sweep::run(&spec, 2, &Memo::new()).unwrap();
    let objectives = sweep::pareto::edp_area_capacity();
    let front = sweep::pareto::frontier_indices(&res.points, &objectives);
    assert!(!front.is_empty());
    for &i in &front {
        for (j, other) in res.points.iter().enumerate() {
            assert!(
                j == i || !sweep::pareto::dominates(other, &res.points[i], &objectives),
                "frontier point {i} is dominated by {j}"
            );
        }
    }
}

// ---------------------------------------------------------------- (d)

#[test]
fn fig9_csv_identical_to_unmemoized_serial_path() {
    let caps = [1u64, 2];
    let report = reports::fig9(&caps);

    // The pre-sweep implementation: direct Algorithm-1 solves, tech
    // outer / capacity inner.
    let mut legacy = Vec::new();
    for &tech in &MemTech::ALL {
        for &mb in &caps {
            legacy.push(tuned_cache(tech, mb * MB));
        }
    }

    let csv = report.csv.to_string();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 1 + legacy.len());
    for (line, c) in lines[1..].iter().zip(&legacy) {
        let p = c.ppa;
        let want = format!(
            "{},{},{},{},{},{},{},{}",
            c.tech.name(),
            c.capacity_bytes / MB,
            f(p.read_latency * 1e9, 2),
            f(p.write_latency * 1e9, 2),
            f(p.read_energy * 1e9, 3),
            f(p.write_energy * 1e9, 3),
            f(p.leakage_power * 1e3, 0),
            f(p.area * 1e6, 2),
        );
        assert_eq!(*line, want);
    }
}

#[test]
fn fig10_csv_identical_to_legacy_serial_loop() {
    let caps = [2u64];
    let report = reports::fig10(&caps);

    // The pre-sweep serial loop, inlined verbatim: mb -> tech -> phase
    // -> dnn, with the same normalization and accumulation order.
    let dram = DramCost::default();
    let mut legacy: Vec<(MemTech, u64, Phase, [f64; 6])> = Vec::new();
    for &mb in &caps {
        let sram = tuned_cache(MemTech::Sram, mb * MB).ppa;
        let traffic = TrafficModel { l2_bytes: mb * MB, ..Default::default() };
        for &tech in &[MemTech::SttMram, MemTech::SotMram] {
            let ppa = tuned_cache(tech, mb * MB).ppa;
            for phase in Phase::ALL {
                let mut e_norms = vec![];
                let mut t_norms = vec![];
                let mut edp_norms = vec![];
                for dnn in Dnn::zoo() {
                    let stats = traffic.run_paper(&dnn, phase);
                    let base = evaluate(&stats, &sram, Some(dram));
                    let e = evaluate(&stats, &ppa, Some(dram));
                    e_norms.push(e.energy() / base.energy());
                    t_norms.push(e.time_total / base.time_total);
                    edp_norms.push(e.edp() / base.edp());
                }
                legacy.push((
                    tech,
                    mb,
                    phase,
                    [
                        mean(&e_norms),
                        std_dev(&e_norms),
                        mean(&t_norms),
                        std_dev(&t_norms),
                        mean(&edp_norms),
                        std_dev(&edp_norms),
                    ],
                ));
            }
        }
    }

    let csv = report.csv.to_string();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 1 + legacy.len());
    for (line, (tech, mb, phase, m)) in lines[1..].iter().zip(&legacy) {
        let want = format!(
            "{},{},{},{},{},{},{},{},{}",
            tech.name(),
            mb,
            phase.name(),
            f(m[0], 3),
            f(m[1], 3),
            f(m[2], 3),
            f(m[3], 3),
            f(m[4], 3),
            f(m[5], 3),
        );
        assert_eq!(*line, want);
    }
}
