//! Cross-module property tests and failure injection: system-level
//! invariants that no unit suite owns.

use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::util::proptest::{check, Gen};
use deepnvm::util::{json, rng::Rng};
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::TrafficModel;

const MB: u64 = 1024 * 1024;

#[test]
fn json_parser_never_panics_on_garbage() {
    // failure injection: arbitrary byte soup must error, not panic
    check(300, |g: &mut Gen| {
        let len = g.usize_in(0, 200);
        let mut rng = Rng::new(g.u64_in(0, u64::MAX - 1));
        let bytes: Vec<u8> = (0..len)
            .map(|_| (rng.below(96) as u8 + 32).min(126))
            .collect();
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = json::parse(&s); // Result either way; must not panic
    });
}

#[test]
fn json_roundtrip_on_random_documents() {
    fn random_json(g: &mut Gen, depth: usize) -> json::Json {
        use json::Json;
        if depth == 0 || g.bool() {
            match g.usize_in(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                _ => Json::Str(format!("s{}", g.u64_in(0, 999))),
            }
        } else if g.bool() {
            let n = g.usize_in(0, 4);
            Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
        } else {
            let mut o = Json::obj();
            for i in 0..g.usize_in(0, 4) {
                o.set(&format!("k{i}"), random_json(g, depth - 1));
            }
            o
        }
    }
    check(150, |g| {
        let doc = random_json(g, 3);
        assert_eq!(json::parse(&doc.to_string()).unwrap(), doc);
        assert_eq!(json::parse(&doc.to_pretty()).unwrap(), doc);
    });
}

#[test]
fn cache_ppa_monotone_in_capacity() {
    // Area and leakage of EDAP-tuned designs must grow with capacity
    // for every technology (the structural backbone of Figs 9-10).
    for tech in MemTech::ALL {
        let mut prev_area = 0.0;
        let mut prev_leak = 0.0;
        for mb in [1u64, 2, 4, 8, 16, 32] {
            let p = tuned_cache(tech, mb * MB).ppa;
            assert!(
                p.area > prev_area,
                "{tech} {mb}MB area non-monotone"
            );
            assert!(
                p.leakage_power > prev_leak,
                "{tech} {mb}MB leakage non-monotone"
            );
            prev_area = p.area;
            prev_leak = p.leakage_power;
        }
    }
}

#[test]
fn traffic_monotone_in_batch() {
    check(30, |g| {
        let zoo = Dnn::zoo();
        let d = g.choose(&zoo);
        let ph = *g.choose(&Phase::ALL);
        let b1 = g.usize_in(1, 32);
        let b2 = b1 + g.usize_in(1, 32);
        let m = TrafficModel::default();
        let s1 = m.run(d, ph, b1);
        let s2 = m.run(d, ph, b2);
        assert!(s2.l2_reads > s1.l2_reads, "{} reads", d.name);
        assert!(s2.l2_writes > s1.l2_writes, "{} writes", d.name);
        assert!(s2.macs > s1.macs, "{} macs", d.name);
    });
}

#[test]
fn training_always_heavier_than_inference_at_equal_batch() {
    check(20, |g| {
        let zoo = Dnn::zoo();
        let d = g.choose(&zoo);
        let b = g.usize_in(1, 64);
        let m = TrafficModel::default();
        let i = m.run(d, Phase::Inference, b);
        let t = m.run(d, Phase::Training, b);
        assert!(t.l2_reads > i.l2_reads);
        assert!(t.l2_writes > i.l2_writes);
        assert!(t.macs >= 3 * i.macs);
    });
}

#[test]
fn mram_leakage_advantage_holds_at_every_capacity() {
    // The core paper claim must hold across the whole explored space.
    for mb in [1u64, 3, 7, 10, 16, 32] {
        let sram = tuned_cache(MemTech::Sram, mb * MB).ppa;
        for tech in [MemTech::SttMram, MemTech::SotMram] {
            let m = tuned_cache(tech, mb * MB).ppa;
            assert!(
                m.leakage_power < 0.5 * sram.leakage_power,
                "{tech} at {mb}MB: {} vs SRAM {}",
                m.leakage_power,
                sram.leakage_power
            );
        }
    }
}

#[test]
fn edap_tuner_is_deterministic() {
    let a = tuned_cache(MemTech::SotMram, 3 * MB);
    let b = tuned_cache(MemTech::SotMram, 3 * MB);
    assert_eq!(a.org, b.org);
    assert_eq!(a.opt.name(), b.opt.name());
    assert!((a.ppa.edap() - b.ppa.edap()).abs() < 1e-30);
}
