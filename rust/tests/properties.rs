//! Cross-module property tests and failure injection: system-level
//! invariants that no unit suite owns.

use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::util::proptest::{check, Gen};
use deepnvm::util::{json, rng::Rng};
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::TrafficModel;

const MB: u64 = 1024 * 1024;

#[test]
fn json_parser_never_panics_on_garbage() {
    // failure injection: arbitrary byte soup must error, not panic
    check(300, |g: &mut Gen| {
        let len = g.usize_in(0, 200);
        let mut rng = Rng::new(g.u64_in(0, u64::MAX - 1));
        let bytes: Vec<u8> = (0..len)
            .map(|_| (rng.below(96) as u8 + 32).min(126))
            .collect();
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = json::parse(&s); // Result either way; must not panic
    });
}

#[test]
fn json_roundtrip_on_random_documents() {
    fn random_json(g: &mut Gen, depth: usize) -> json::Json {
        use json::Json;
        if depth == 0 || g.bool() {
            match g.usize_in(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                _ => Json::Str(format!("s{}", g.u64_in(0, 999))),
            }
        } else if g.bool() {
            let n = g.usize_in(0, 4);
            Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
        } else {
            let mut o = Json::obj();
            for i in 0..g.usize_in(0, 4) {
                o.set(&format!("k{i}"), random_json(g, depth - 1));
            }
            o
        }
    }
    check(150, |g| {
        let doc = random_json(g, 3);
        assert_eq!(json::parse(&doc.to_string()).unwrap(), doc);
        assert_eq!(json::parse(&doc.to_pretty()).unwrap(), doc);
    });
}

#[test]
fn cache_ppa_monotone_in_capacity() {
    // Area and leakage of EDAP-tuned designs must grow with capacity
    // for every technology (the structural backbone of Figs 9-10).
    for tech in MemTech::ALL {
        let mut prev_area = 0.0;
        let mut prev_leak = 0.0;
        for mb in [1u64, 2, 4, 8, 16, 32] {
            let p = tuned_cache(tech, mb * MB).ppa;
            assert!(
                p.area > prev_area,
                "{tech} {mb}MB area non-monotone"
            );
            assert!(
                p.leakage_power > prev_leak,
                "{tech} {mb}MB leakage non-monotone"
            );
            prev_area = p.area;
            prev_leak = p.leakage_power;
        }
    }
}

// ------------------------------------------------------------------
// Closed-form batch-axis equivalence: BatchLine::at(b) must be
// bit-identical to the direct GEMM re-lowering at every batch, for
// every workload, phase, capacity and im2col setting — including the
// ceil(M/T) re-streaming breakpoints.
// ------------------------------------------------------------------

/// The dense batch set of the equivalence contract: small batches, the
/// paper batches (4 / 64) and their neighbours, and a deep batch that
/// crosses the fc-layer supertile boundary (m1 = 1 breaks first at
/// b = 129).
const EQUIV_BATCHES: [usize; 10] = [1, 2, 3, 4, 7, 8, 63, 64, 65, 512];

#[test]
fn batch_line_bit_identical_to_direct_traffic_across_zoo() {
    let m = TrafficModel::default();
    for d in Dnn::zoo() {
        for ph in Phase::ALL {
            let line = m.line(&d, ph);
            for &b in &EQUIV_BATCHES {
                assert_eq!(
                    line.at(b),
                    m.run(&d, ph, b),
                    "{} {} b{b}",
                    d.name,
                    ph.name()
                );
            }
        }
    }
}

#[test]
fn batch_line_exact_across_restreaming_breakpoints() {
    // ceil(m1*b / T) (T = 128) increments for layer rows-per-batch m1
    // exactly at b = floor(T*j / m1) + 1. For every layer of every
    // network, straddle the first few breakpoints explicitly
    // (b-1, b, b+1): these are the seams where an affine-only
    // approximation would go wrong.
    const T: u64 = 128;
    let m = TrafficModel::default();
    for d in Dnn::zoo() {
        let mut breakpoints = std::collections::BTreeSet::new();
        for l in &d.layers {
            let Some((m1, _, _)) = l.gemm_dims(1) else { continue };
            for j in 1..=3u64 {
                breakpoints.insert((T * j / m1 + 1) as usize);
            }
        }
        assert!(!breakpoints.is_empty(), "{}", d.name);
        for ph in Phase::ALL {
            let line = m.line(&d, ph);
            for &bp in &breakpoints {
                for b in [bp.saturating_sub(1).max(1), bp, bp + 1] {
                    assert_eq!(
                        line.at(b),
                        m.run(&d, ph, b),
                        "{} {} breakpoint {bp} at b{b}",
                        d.name,
                        ph.name()
                    );
                }
            }
        }
    }
}

#[test]
fn batch_line_matches_direct_at_any_capacity_and_im2col() {
    // The coefficients must be capacity-independent (the L2 size only
    // enters DRAM spill EVALUATION) and respect the builder's im2col
    // setting — the two invariants behind the sweep memo's
    // (dnn, phase) traffic key.
    check(60, |g| {
        let zoo = Dnn::zoo();
        let d = g.choose(&zoo);
        let ph = *g.choose(&Phase::ALL);
        let b = g.usize_in(1, 600);
        let l2 = g.u64_in(1 << 18, 64 << 20);
        let im2col = g.bool();
        let direct = TrafficModel { l2_bytes: l2, materialize_im2col: im2col };
        // line built at a DIFFERENT capacity, evaluated at l2
        let builder = TrafficModel { l2_bytes: 3 << 20, materialize_im2col: im2col };
        let line = builder.line(d, ph);
        assert_eq!(
            line.at_capacity(b, l2),
            direct.run(d, ph, b),
            "{} {} b{b} l2={l2} im2col={im2col}",
            d.name,
            ph.name()
        );
    });
}

#[test]
fn traffic_monotone_in_batch() {
    check(30, |g| {
        let zoo = Dnn::zoo();
        let d = g.choose(&zoo);
        let ph = *g.choose(&Phase::ALL);
        let b1 = g.usize_in(1, 32);
        let b2 = b1 + g.usize_in(1, 32);
        let m = TrafficModel::default();
        let s1 = m.run(d, ph, b1);
        let s2 = m.run(d, ph, b2);
        assert!(s2.l2_reads > s1.l2_reads, "{} reads", d.name);
        assert!(s2.l2_writes > s1.l2_writes, "{} writes", d.name);
        assert!(s2.macs > s1.macs, "{} macs", d.name);
    });
}

#[test]
fn training_always_heavier_than_inference_at_equal_batch() {
    check(20, |g| {
        let zoo = Dnn::zoo();
        let d = g.choose(&zoo);
        let b = g.usize_in(1, 64);
        let m = TrafficModel::default();
        let i = m.run(d, Phase::Inference, b);
        let t = m.run(d, Phase::Training, b);
        assert!(t.l2_reads > i.l2_reads);
        assert!(t.l2_writes > i.l2_writes);
        assert!(t.macs >= 3 * i.macs);
    });
}

#[test]
fn mram_leakage_advantage_holds_at_every_capacity() {
    // The core paper claim must hold across the whole explored space.
    for mb in [1u64, 3, 7, 10, 16, 32] {
        let sram = tuned_cache(MemTech::Sram, mb * MB).ppa;
        for tech in [MemTech::SttMram, MemTech::SotMram] {
            let m = tuned_cache(tech, mb * MB).ppa;
            assert!(
                m.leakage_power < 0.5 * sram.leakage_power,
                "{tech} at {mb}MB: {} vs SRAM {}",
                m.leakage_power,
                sram.leakage_power
            );
        }
    }
}

#[test]
fn edap_tuner_is_deterministic() {
    let a = tuned_cache(MemTech::SotMram, 3 * MB);
    let b = tuned_cache(MemTech::SotMram, 3 * MB);
    assert_eq!(a.org, b.org);
    assert_eq!(a.opt.name(), b.opt.name());
    assert!((a.ppa.edap() - b.ppa.edap()).abs() < 1e-30);
}
