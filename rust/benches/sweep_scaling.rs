//! Bench SW: the sweep engine on a fig9/fig10-sized grid — serial vs
//! parallel vs memoized — and the `BENCH_sweep.json` baseline emitter
//! future PRs use to track the perf trajectory.
//!
//! Run: `cargo bench --bench sweep_scaling [-- --quick]`

mod bench_common;

use std::time::Instant;

use deepnvm::device::MemTech;
use deepnvm::nvsim::TechSel;
use deepnvm::sweep::spec::parse_tech_sel;
use deepnvm::sweep::{self, exec, Memo, SweepSpec};
use deepnvm::util::bench::{self, Bench};
use deepnvm::util::json::Json;
use deepnvm::workload::models::{Dnn, Phase};

fn grid(quick: bool) -> SweepSpec {
    let capacities_mb = if quick {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    };
    SweepSpec { capacities_mb, ..SweepSpec::default() }
}

/// Wall-clock of one full sweep under the given schedule and cache.
/// Each run also lands in the global `name` histogram, so the BENCH
/// JSON timing fields below are read back out of the same registry
/// `GET /metrics` serves.
fn timed(name: &str, spec: &SweepSpec, jobs: usize, memo: &Memo) -> f64 {
    let t0 = Instant::now();
    let res =
        bench::time_into(name, || sweep::run(spec, jobs, memo).expect("bench spec expands"));
    assert!(!res.points.is_empty());
    t0.elapsed().as_secs_f64()
}

/// Write `key` from the mean of the global histogram `hist`, or null
/// when it has no samples — an absent measurement must never read as
/// 0 ms.
fn set_hist_ms(j: &mut Json, key: &str, hist: &str) {
    let v = match bench::hist_ms(hist) {
        Some(h) => Json::Num(h.mean_ms),
        None => Json::Null,
    };
    j.set(key, v);
}

fn main() {
    let quick = bench_common::quick();
    let spec = grid(quick);
    let n_points = spec.expand().expect("bench spec").len();
    let jobs = exec::default_jobs().clamp(1, 4);

    let serial_memo = Memo::new();
    let t_serial = timed("bench_sweep_serial", &spec, 1, &serial_memo);

    let par_memo = Memo::new();
    let t_parallel = timed("bench_sweep_parallel", &spec, jobs, &par_memo);
    let cold_solves = par_memo.solve_count();

    let t_memoized = timed("bench_sweep_memoized", &spec, jobs, &par_memo);
    let warm_solves = par_memo.solve_count() - cold_solves;

    println!(
        "sweep_scaling: {n_points} grid points, {} circuit solves",
        cold_solves
    );
    println!("  serial   (jobs=1)   {:>10.2} ms", t_serial * 1e3);
    println!(
        "  parallel (jobs={jobs})   {:>10.2} ms  ({:.2}x vs serial)",
        t_parallel * 1e3,
        t_serial / t_parallel
    );
    println!(
        "  memoized rerun      {:>10.2} ms  ({:.2}x vs serial, {warm_solves} new solves)",
        t_memoized * 1e3,
        t_serial / t_memoized
    );
    assert_eq!(warm_solves, 0, "warm rerun must not re-solve circuits");

    // Cross-node sweep: the same engine over the full calibrated node
    // axis (circuit-only — the node axis multiplies circuit solves,
    // the expensive layer). The warm rerun must answer every node from
    // cache: per-node CircuitKeys, no 16 nm aliasing.
    let node_spec = SweepSpec {
        capacities_mb: if quick { vec![1, 4] } else { vec![1, 2, 4, 8] },
        dnns: vec![],
        nodes_nm: deepnvm::device::CALIBRATED_NODES_NM.to_vec(),
        ..SweepSpec::default()
    };
    let node_points = node_spec.expand().expect("node bench spec").len();
    let node_memo = Memo::new();
    let t_node_cold = timed("bench_node_sweep_cold", &node_spec, jobs, &node_memo);
    let node_solves = node_memo.solve_count();
    let t_node_warm = timed("bench_node_sweep_warm", &node_spec, jobs, &node_memo);
    let node_warm_solves = node_memo.solve_count() - node_solves;
    println!(
        "  node sweep ({} nodes) {:>8.2} ms cold ({node_solves} solves), \
         {:.2} ms warm ({node_warm_solves} new solves)",
        node_spec.nodes_nm.len(),
        t_node_cold * 1e3,
        t_node_warm * 1e3,
    );
    assert_eq!(
        node_warm_solves, 0,
        "warm rerun must re-solve nothing across all nodes"
    );

    // Batch-axis sweep: 16 batch sizes across the workload zoo at one
    // capacity. The closed-form BatchLine engine must lower each
    // workload's GEMMs exactly once per (dnn, phase) — traffic work
    // must NOT scale with the batch count — and the warm rerun must
    // fold everything from cache.
    let batch_dnns: Vec<String> = if quick {
        vec!["AlexNet".into(), "VGG-16".into()]
    } else {
        Dnn::zoo().iter().map(|d| d.name.to_string()).collect()
    };
    let batch_spec = SweepSpec {
        techs: vec![MemTech::SttMram.into()],
        capacities_mb: vec![3],
        dnns: batch_dnns,
        phases: Phase::ALL.to_vec(),
        batches: vec![1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let batch_points = batch_spec.expand().expect("batch bench spec").len();
    let workload_pairs = (batch_spec.dnns.len() * batch_spec.phases.len()) as u64;
    let batch_memo = Memo::new();
    let t_batch_cold = timed("bench_batch_sweep_cold", &batch_spec, jobs, &batch_memo);
    let batch_traffic_evals = batch_memo.traffic_build_count();
    let t_batch_warm = timed("bench_batch_sweep_warm", &batch_spec, jobs, &batch_memo);
    let batch_warm_traffic = batch_memo.traffic_build_count() - batch_traffic_evals;
    println!(
        "  batch sweep ({} batches, {batch_points} points) {:>6.2} ms cold \
         ({batch_traffic_evals} traffic builds for {workload_pairs} workload pairs), \
         {:.2} ms warm ({batch_warm_traffic} new builds)",
        batch_spec.batches.len(),
        t_batch_cold * 1e3,
        t_batch_warm * 1e3,
    );
    assert!(
        batch_traffic_evals <= workload_pairs,
        "traffic lowering must run at most once per (dnn, phase), \
         not per batch: {batch_traffic_evals} > {workload_pairs}"
    );
    assert_eq!(batch_warm_traffic, 0, "warm batch sweep must not re-lower");

    // Hybrid tech axis: way-partitioned SRAM/MRAM selections compose
    // their PPA from the two cached pure partner solves. A steer/way
    // sweep over many hybrid selections must therefore cost exactly
    // the pure partner solves (2 per capacity here) — zero extra
    // circuit work per hybrid — and the warm rerun must solve nothing.
    let mut hybrid_techs = TechSel::pures(&[MemTech::Sram, MemTech::SttMram]);
    for ways in [2u32, 4, 8, 12] {
        for steer in ["0.25", "0.5", "0.85"] {
            hybrid_techs
                .push(parse_tech_sel(&format!("hybrid-stt:{ways}@{steer}")).unwrap());
        }
    }
    let hybrid_spec = SweepSpec {
        techs: hybrid_techs,
        capacities_mb: if quick { vec![2] } else { vec![2, 8] },
        dnns: vec![],
        phases: Phase::ALL.to_vec(),
        batches: vec![],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let hybrid_points = hybrid_spec.expand().expect("hybrid bench spec").len();
    let hybrid_memo = Memo::new();
    let t_hybrid_cold = timed("bench_hybrid_sweep_cold", &hybrid_spec, jobs, &hybrid_memo);
    let hybrid_solves = hybrid_memo.solve_count();
    let t_hybrid_warm = timed("bench_hybrid_sweep_warm", &hybrid_spec, jobs, &hybrid_memo);
    let hybrid_warm_solves = hybrid_memo.solve_count() - hybrid_solves;
    let pure_partner_solves = 2 * hybrid_spec.capacities_mb.len() as u64;
    println!(
        "  hybrid sweep ({} selections, {hybrid_points} points) {:>5.2} ms cold \
         ({hybrid_solves} solves for {pure_partner_solves} pure partners), \
         {:.2} ms warm ({hybrid_warm_solves} new solves)",
        hybrid_spec.techs.len(),
        t_hybrid_cold * 1e3,
        t_hybrid_warm * 1e3,
    );
    assert_eq!(
        hybrid_solves, pure_partner_solves,
        "hybrid selections must compose from cached pure solves, \
         never solve circuits of their own"
    );
    assert_eq!(hybrid_warm_solves, 0, "warm hybrid sweep must not re-solve");

    // Optimize: branch-and-bound argmin over a wide implicit grid. The
    // search returns the exhaustive argmin bit-for-bit (tests prove
    // that) while materializing a fraction of the grid — the pruning
    // ratio recorded here is CI-gated at >= 10x.
    let opt_spec = SweepSpec {
        techs: TechSel::pure_all(),
        capacities_mb: if quick { vec![1, 2, 4] } else { vec![1, 2, 4, 8] },
        dnns: batch_spec.dnns.clone(),
        phases: Phase::ALL.to_vec(),
        batches: batch_spec.batches.clone(),
        nodes_nm: vec![16],
        filters: vec![],
    };
    let opt_req = deepnvm::sweep::OptimizeRequest {
        spec: opt_spec,
        objective: deepnvm::sweep::OptObjective::Edp,
        area_max_mm2: None,
        leakage_max_w: None,
        frontier: false,
    };
    let opt_memo = Memo::new();
    let t_opt_start = Instant::now();
    let opt = bench::time_into("bench_optimize_search", || {
        deepnvm::sweep::optimize::run(&opt_req, jobs, &opt_memo).expect("optimize bench")
    });
    let t_optimize = t_opt_start.elapsed().as_secs_f64();
    assert!(opt.winner.is_some(), "the optimize bench grid must yield a winner");
    println!(
        "  optimize ({} points) {:>8.2} ms: {} evaluated, {} pruned ({:.0}x)",
        opt.points_total,
        t_optimize * 1e3,
        opt.points_evaluated,
        opt.points_pruned,
        opt.points_pruned as f64 / opt.points_evaluated.max(1) as f64
    );

    // Steady-state warm-grid query rate (the serving path the ROADMAP
    // cares about: many scenarios against one resident grid).
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    b.run_items("sweep/warm_grid_query", n_points as f64, &mut || {
        sweep::run(&spec, jobs, &par_memo).expect("warm query").points.len()
    });

    let mut j = Json::obj();
    j.set("bench", Json::Str("sweep_scaling".into()));
    j.set(
        "note",
        Json::Str(
            "Baseline for the sweep-engine perf trajectory; regenerate with \
             `cargo bench --bench sweep_scaling`."
                .into(),
        ),
    );
    let mut acc = Json::obj();
    acc.set("parallel_speedup_min", Json::Num(1.5));
    acc.set("warm_rerun_circuit_solves_max", Json::Num(0.0));
    acc.set("node_sweep_warm_rerun_circuit_solves_max", Json::Num(0.0));
    // one traffic-coefficient build per (dnn, phase), however many
    // batches the axis carries
    acc.set("batch_sweep_traffic_evals_max", Json::Num(workload_pairs as f64));
    acc.set("batch_sweep_warm_rerun_traffic_evals_max", Json::Num(0.0));
    // every hybrid selection rides its two pure partner solves:
    // extra = total - partners must be zero, cold and warm alike
    acc.set("hybrid_sweep_extra_circuit_solves_max", Json::Num(0.0));
    acc.set("hybrid_sweep_warm_rerun_circuit_solves_max", Json::Num(0.0));
    // branch-and-bound must prune at least 10 grid points for every
    // one it evaluates on the wide search grid
    acc.set("optimize_prune_ratio_min", Json::Num(10.0));
    j.set("acceptance", acc);
    j.set("quick", Json::Bool(quick));
    j.set("grid_points", Json::Num(n_points as f64));
    j.set("circuit_solves", Json::Num(cold_solves as f64));
    j.set("jobs", Json::Num(jobs as f64));
    // Timing fields come from the obs histograms the runs above fed —
    // the same source `GET /metrics` scrapes on a server.
    set_hist_ms(&mut j, "serial_ms", "bench_sweep_serial");
    set_hist_ms(&mut j, "parallel_ms", "bench_sweep_parallel");
    set_hist_ms(&mut j, "memoized_rerun_ms", "bench_sweep_memoized");
    set_hist_ms(&mut j, "warm_ms", "bench_sweep_memoized");
    j.set("parallel_speedup", Json::Num(t_serial / t_parallel));
    j.set("memoized_speedup", Json::Num(t_serial / t_memoized));
    j.set("warm_rerun_circuit_solves", Json::Num(warm_solves as f64));
    j.set("node_sweep_nodes", Json::Num(node_spec.nodes_nm.len() as f64));
    j.set("node_sweep_grid_points", Json::Num(node_points as f64));
    j.set("node_sweep_circuit_solves", Json::Num(node_solves as f64));
    set_hist_ms(&mut j, "node_sweep_cold_ms", "bench_node_sweep_cold");
    set_hist_ms(&mut j, "node_sweep_warm_ms", "bench_node_sweep_warm");
    j.set(
        "node_sweep_warm_rerun_circuit_solves",
        Json::Num(node_warm_solves as f64),
    );
    j.set("batch_sweep_batches", Json::Num(batch_spec.batches.len() as f64));
    j.set("batch_sweep_grid_points", Json::Num(batch_points as f64));
    j.set("batch_sweep_workload_pairs", Json::Num(workload_pairs as f64));
    j.set("batch_sweep_traffic_evals", Json::Num(batch_traffic_evals as f64));
    j.set(
        "batch_sweep_warm_rerun_traffic_evals",
        Json::Num(batch_warm_traffic as f64),
    );
    set_hist_ms(&mut j, "batch_sweep_cold_ms", "bench_batch_sweep_cold");
    set_hist_ms(&mut j, "batch_sweep_warm_ms", "bench_batch_sweep_warm");
    j.set("hybrid_sweep_tech_selections", Json::Num(hybrid_spec.techs.len() as f64));
    j.set("hybrid_sweep_grid_points", Json::Num(hybrid_points as f64));
    j.set("hybrid_sweep_circuit_solves", Json::Num(hybrid_solves as f64));
    j.set(
        "hybrid_sweep_pure_partner_solves",
        Json::Num(pure_partner_solves as f64),
    );
    j.set(
        "hybrid_sweep_extra_circuit_solves",
        Json::Num((hybrid_solves - pure_partner_solves) as f64),
    );
    j.set(
        "hybrid_sweep_warm_rerun_circuit_solves",
        Json::Num(hybrid_warm_solves as f64),
    );
    set_hist_ms(&mut j, "hybrid_sweep_cold_ms", "bench_hybrid_sweep_cold");
    set_hist_ms(&mut j, "hybrid_sweep_warm_ms", "bench_hybrid_sweep_warm");
    set_hist_ms(&mut j, "optimize_ms", "bench_optimize_search");
    j.set("optimize_grid_points", Json::Num(opt.points_total as f64));
    j.set("optimize_points_evaluated", Json::Num(opt.points_evaluated as f64));
    j.set("optimize_points_pruned", Json::Num(opt.points_pruned as f64));

    // Algorithm-1 solve latency across every cold sweep above, from
    // the instrumentation inside sweep::memo itself.
    match bench::hist_ms("deepnvm_circuit_solve_duration_ns") {
        Some(h) => {
            j.set("circuit_solve_samples", Json::Num(h.count as f64));
            j.set("circuit_solve_p50_ms", Json::Num(h.p50_ms));
            j.set("circuit_solve_p99_ms", Json::Num(h.p99_ms));
        }
        None => {
            j.set("circuit_solve_samples", Json::Null);
            j.set("circuit_solve_p50_ms", Json::Null);
            j.set("circuit_solve_p99_ms", Json::Null);
        }
    }

    // Land next to CHANGES.md when run from rust/ or the repo root.
    let path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_sweep.json"
    } else {
        "BENCH_sweep.json"
    };
    match std::fs::write(path, j.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    // The span timeline of the whole bench run (CI uploads this next
    // to the BENCH JSONs; open in chrome://tracing).
    let trace_path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_trace.json"
    } else {
        "BENCH_trace.json"
    };
    match std::fs::write(trace_path, deepnvm::obs::trace::chrome_trace_json().to_pretty()) {
        Ok(()) => println!("wrote {trace_path} ({} spans)", deepnvm::obs::trace::span_count()),
        Err(e) => eprintln!("warning: could not write {trace_path}: {e}"),
    }
}
