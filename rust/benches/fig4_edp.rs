//! Bench F4: regenerate Fig 4 (iso-capacity energy + EDP) and time the
//! evaluation kernel.

mod bench_common;

use deepnvm::analysis::{evaluate, DramCost};
use deepnvm::coordinator::reports;
use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::util::bench::Bench;
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::TrafficModel;

fn main() {
    let (_, f4) = reports::fig3_fig4();
    bench_common::emit(&f4);

    let mut b = Bench::new();
    let stats = TrafficModel::default()
        .run_paper(&Dnn::by_name("AlexNet").unwrap(), Phase::Training);
    let ppa = tuned_cache(MemTech::SttMram, 3 * 1024 * 1024).ppa;
    b.run("analysis/evaluate_one_workload", || {
        evaluate(&stats, &ppa, Some(DramCost::default()))
    });
}
