//! Bench F5: regenerate Fig 5 (batch-size impact on AlexNet EDP) and
//! time the batch sweep.

mod bench_common;

use deepnvm::analysis::iso_capacity;
use deepnvm::coordinator::reports;
use deepnvm::util::bench::Bench;

fn main() {
    let batches = [1usize, 4, 16, 64, 128, 256];
    bench_common::emit(&reports::fig5(&batches));

    let mut b = Bench::new();
    b.run("analysis/batch_study_6_points", || {
        iso_capacity::batch_study(&batches)
    });
}
