//! Bench SL: warm vs cold `/solve` latency through the resident
//! server — the serving-path numbers behind the `serve` subsystem
//! (cold = first-ever circuit solve for a capacity; warm = pure memo
//! hit, the steady state after `--prewarm`). Emits `BENCH_serve.json`.
//!
//! Run: `cargo bench --bench serve_latency [-- --quick]`

mod bench_common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use deepnvm::serve::http::{Client, Server};
use deepnvm::serve::routes::{self, ServerCtx};
use deepnvm::sweep::Memo;
use deepnvm::util::bench::{self, Bench};
use deepnvm::util::json::Json;

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, usize) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("recv");
    let status: u16 = buf.split_whitespace().nth(1).expect("status").parse().expect("code");
    (status, buf.len())
}

fn main() {
    let quick = bench_common::quick();
    let memo: &'static Memo = Box::leak(Box::new(Memo::new()));
    let ctx = Arc::new(ServerCtx::new(memo, 2));
    let server =
        Server::bind("127.0.0.1:0", 2, move |req| routes::handle(&ctx, req)).expect("bind");
    let addr = server.local_addr();

    // Cold: the very first solve for this capacity walks the full
    // Algorithm-1 enumeration behind the HTTP hop.
    let cap_mb = if quick { 2 } else { 8 };
    let solve_body = format!("{{\"tech\": \"stt\", \"capacity_mb\": {cap_mb}}}");
    let (status, _) =
        bench::time_into("bench_serve_cold_solve", || post(addr, "/solve", &solve_body));
    assert_eq!(status, 200);
    let cold_ms = bench::hist_ms("bench_serve_cold_solve").expect("recorded").mean_ms;

    // Warm: identical query, answered from the resident cache.
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let warm = b
        .run("serve/solve_warm", || {
            let (status, n) = post(addr, "/solve", &solve_body);
            assert_eq!(status, 200);
            n
        })
        .clone();

    // Warm fig9 slice: the full paper query at cache-hit latency.
    let sweep_body = "{\"report\": \"fig9\", \"caps_mb\": [1, 2]}";
    let (status, _) = post(addr, "/sweep", sweep_body); // warm the slice
    assert_eq!(status, 200);
    let sweep_warm = b
        .run("serve/sweep_fig9_warm", || {
            let (status, n) = post(addr, "/sweep", sweep_body);
            assert_eq!(status, 200);
            n
        })
        .clone();

    // Keep-alive: the same warm query over one pooled connection — no
    // TCP handshake per request (the `http::Client` path the
    // coordinator's dispatch loop uses).
    let mut client = Client::new(&addr.to_string(), Duration::from_secs(10));
    let ka = b
        .run("serve/solve_warm_keepalive", || {
            let (status, body) = client.call("POST", "/solve", &solve_body).expect("keepalive");
            assert_eq!(status, 200);
            body.len()
        })
        .clone();

    let warm_ms = warm.mean_ns / 1e6;
    let speedup = cold_ms / warm_ms.max(1e-9);
    let ka_ms = ka.mean_ns / 1e6;
    let ka_speedup = warm_ms / ka_ms.max(1e-9);
    println!("serve_latency: cold /solve ({cap_mb}MB STT) {cold_ms:>10.2} ms");
    println!("               warm /solve              {warm_ms:>10.3} ms  ({speedup:.0}x)");
    println!("               warm keep-alive /solve   {ka_ms:>10.3} ms  ({ka_speedup:.2}x)");
    println!(
        "               warm /sweep fig9         {:>10.3} ms",
        sweep_warm.mean_ns / 1e6
    );
    assert!(
        warm_ms < cold_ms,
        "warm memo hits must beat the cold solve ({warm_ms:.3} ms vs {cold_ms:.3} ms)"
    );

    let mut j = Json::obj();
    j.set("bench", Json::Str("serve_latency".into()));
    j.set(
        "note",
        Json::Str(
            "Warm vs cold /solve through the resident server; regenerate with \
             `cargo bench --bench serve_latency`."
                .into(),
        ),
    );
    let mut acc = Json::obj();
    acc.set("warm_faster_than_cold", Json::Bool(true));
    j.set("acceptance", acc);
    j.set("quick", Json::Bool(quick));
    j.set("cold_cap_mb", Json::Num(cap_mb as f64));
    j.set("cold_solve_ms", Json::Num(cold_ms));
    j.set("warm_solve_ms", Json::Num(warm_ms));
    j.set("warm_solve_speedup", Json::Num(speedup));
    j.set("warm_solve_keepalive_ms", Json::Num(ka_ms));
    j.set("keepalive_speedup", Json::Num(ka_speedup));
    j.set("warm_sweep_fig9_ms", Json::Num(sweep_warm.mean_ns / 1e6));

    // The per-route latency histogram the server recorded for /solve —
    // the identical series a `GET /metrics` scrape would export.
    match bench::hist_ms("deepnvm_http_request_duration_ns{route=\"/solve\"}") {
        Some(h) => {
            j.set("solve_route_requests", Json::Num(h.count as f64));
            j.set("solve_route_p50_ms", Json::Num(h.p50_ms));
            j.set("solve_route_p99_ms", Json::Num(h.p99_ms));
        }
        None => {
            j.set("solve_route_requests", Json::Null);
            j.set("solve_route_p50_ms", Json::Null);
            j.set("solve_route_p99_ms", Json::Null);
        }
    }

    let path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_serve.json"
    } else {
        "BENCH_serve.json"
    };
    match std::fs::write(path, j.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
