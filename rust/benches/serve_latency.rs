//! Bench SL: warm vs cold `/solve` latency through the resident
//! server — the serving-path numbers behind the `serve` subsystem
//! (cold = first-ever circuit solve for a capacity; warm = pure memo
//! hit, the steady state after `--prewarm`). Emits `BENCH_serve.json`.
//!
//! Run: `cargo bench --bench serve_latency [-- --quick]`

mod bench_common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use deepnvm::serve::http::Server;
use deepnvm::serve::routes::{self, ServerCtx};
use deepnvm::sweep::Memo;
use deepnvm::util::bench::Bench;
use deepnvm::util::json::Json;

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, usize) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("recv");
    let status: u16 = buf.split_whitespace().nth(1).expect("status").parse().expect("code");
    (status, buf.len())
}

fn main() {
    let quick = bench_common::quick();
    let memo: &'static Memo = Box::leak(Box::new(Memo::new()));
    let ctx = Arc::new(ServerCtx::new(memo, 2));
    let server =
        Server::bind("127.0.0.1:0", 2, move |req| routes::handle(&ctx, req)).expect("bind");
    let addr = server.local_addr();

    // Cold: the very first solve for this capacity walks the full
    // Algorithm-1 enumeration behind the HTTP hop.
    let cap_mb = if quick { 2 } else { 8 };
    let solve_body = format!("{{\"tech\": \"stt\", \"capacity_mb\": {cap_mb}}}");
    let t0 = Instant::now();
    let (status, _) = post(addr, "/solve", &solve_body);
    assert_eq!(status, 200);
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Warm: identical query, answered from the resident cache.
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let warm = b
        .run("serve/solve_warm", || {
            let (status, n) = post(addr, "/solve", &solve_body);
            assert_eq!(status, 200);
            n
        })
        .clone();

    // Warm fig9 slice: the full paper query at cache-hit latency.
    let sweep_body = "{\"report\": \"fig9\", \"caps_mb\": [1, 2]}";
    let (status, _) = post(addr, "/sweep", sweep_body); // warm the slice
    assert_eq!(status, 200);
    let sweep_warm = b
        .run("serve/sweep_fig9_warm", || {
            let (status, n) = post(addr, "/sweep", sweep_body);
            assert_eq!(status, 200);
            n
        })
        .clone();

    let warm_ms = warm.mean_ns / 1e6;
    let speedup = cold_ms / warm_ms.max(1e-9);
    println!("serve_latency: cold /solve ({cap_mb}MB STT) {cold_ms:>10.2} ms");
    println!("               warm /solve              {warm_ms:>10.3} ms  ({speedup:.0}x)");
    println!(
        "               warm /sweep fig9         {:>10.3} ms",
        sweep_warm.mean_ns / 1e6
    );
    assert!(
        warm_ms < cold_ms,
        "warm memo hits must beat the cold solve ({warm_ms:.3} ms vs {cold_ms:.3} ms)"
    );

    let mut j = Json::obj();
    j.set("bench", Json::Str("serve_latency".into()));
    j.set(
        "note",
        Json::Str(
            "Warm vs cold /solve through the resident server; regenerate with \
             `cargo bench --bench serve_latency`."
                .into(),
        ),
    );
    let mut acc = Json::obj();
    acc.set("warm_faster_than_cold", Json::Bool(true));
    j.set("acceptance", acc);
    j.set("quick", Json::Bool(quick));
    j.set("cold_cap_mb", Json::Num(cap_mb as f64));
    j.set("cold_solve_ms", Json::Num(cold_ms));
    j.set("warm_solve_ms", Json::Num(warm_ms));
    j.set("warm_solve_speedup", Json::Num(speedup));
    j.set("warm_sweep_fig9_ms", Json::Num(sweep_warm.mean_ns / 1e6));

    let path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_serve.json"
    } else {
        "BENCH_serve.json"
    };
    match std::fs::write(path, j.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
