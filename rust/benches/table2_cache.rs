//! Bench T2: regenerate Table II (EDAP-tuned caches) and time the
//! design-space exploration (Algorithm 1 inner loop).

mod bench_common;

use deepnvm::coordinator::reports;
use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::tuned_cache;
use deepnvm::nvsim::{model, org::AccessMode, CacheOrg, TechParams};
use deepnvm::util::bench::Bench;

const MB: u64 = 1024 * 1024;

fn main() {
    bench_common::emit(&reports::table2());

    let mut b = Bench::new();
    b.run("nvsim/tuned_cache_sram_3mb", || {
        tuned_cache(MemTech::Sram, 3 * MB)
    });
    b.run("nvsim/tuned_cache_stt_32mb", || {
        tuned_cache(MemTech::SttMram, 32 * MB)
    });
    // single-config evaluation (the innermost kernel of Algorithm 1)
    let tech = TechParams::n16();
    let cell = deepnvm::nvsim::tech::Bitcell::paper(MemTech::SttMram);
    let orgs = CacheOrg::enumerate(3 * MB, AccessMode::Normal);
    let n = orgs.len() as f64;
    let mut f = || {
        orgs.iter()
            .map(|o| model::evaluate(&tech, &cell, o).edap())
            .sum::<f64>()
    };
    b.run_items("nvsim/evaluate_all_3mb_orgs", n, &mut f);
}
