//! Bench F8: regenerate Fig 8 (iso-area EDP without/with DRAM).

mod bench_common;

use deepnvm::analysis::iso_area;
use deepnvm::coordinator::reports;
use deepnvm::device::MemTech;
use deepnvm::util::bench::Bench;

fn main() {
    let (_, f8) = reports::fig7_fig8(Some((0.146, 0.198)));
    bench_common::emit(&f8);

    let mut b = Bench::new();
    b.run("analysis/iso_area_summaries", || {
        let rows = iso_area::study(Some((0.146, 0.198)));
        (
            iso_area::mean_of(&rows, MemTech::SttMram, |r| r.edp_norm_with_dram),
            iso_area::mean_of(&rows, MemTech::SotMram, |r| r.edp_norm_with_dram),
        )
    });
}
