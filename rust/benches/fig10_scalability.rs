//! Bench F10: regenerate Fig 10 (workload-normalized scalability) and
//! time the cross-product sweep (capacities x techs x workloads x
//! phases).

mod bench_common;

use deepnvm::analysis::scalability;
use deepnvm::coordinator::reports;
use deepnvm::util::bench::Bench;

fn main() {
    let caps: Vec<u64> = if bench_common::quick() {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    };
    bench_common::emit(&reports::fig10(&caps));

    let mut b = Bench::new();
    b.run("analysis/workload_sweep_2caps", || {
        scalability::workload_sweep(&[2, 16])
    });
}
