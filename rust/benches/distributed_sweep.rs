//! Bench DC: the multi-host scheduler end to end — one coordinator
//! driving two in-process `serve` workers over loopback, versus the
//! same grid swept in a single process — and the
//! `BENCH_distributed.json` baseline emitter. The acceptance contract
//! is correctness-shaped: after the merges, the coordinator's replay
//! of the full grid must perform zero circuit solves and zero traffic
//! evals (the distributed path may of course be slower than in-process
//! on one machine: it pays HTTP, JSON and merge overhead to buy
//! multi-host scale-out).
//!
//! Run: `cargo bench --bench distributed_sweep [-- --quick]`

mod bench_common;

use std::sync::Arc;

use deepnvm::serve::http::Server;
use deepnvm::serve::routes::{self, ServerCtx};
use deepnvm::serve::scheduler::{Coordinator, ScheduleConfig};
use deepnvm::sweep::{self, Memo, SweepSpec};
use deepnvm::util::bench;
use deepnvm::util::json::Json;

fn worker() -> Server {
    let memo: &'static Memo = Box::leak(Box::new(Memo::new()));
    let ctx = Arc::new(ServerCtx::new(memo, 2));
    Server::bind("127.0.0.1:0", 2, move |req| routes::handle(&ctx, req)).expect("bind")
}

fn main() {
    let quick = bench_common::quick();
    let spec = SweepSpec {
        capacities_mb: if quick { vec![1, 2] } else { vec![1, 2, 4, 8] },
        dnns: vec!["AlexNet".into()],
        ..SweepSpec::default()
    };
    let n_points = spec.expand().expect("bench spec").len();

    // reference: the same grid in-process, cold (timed into the global
    // obs registry, which the JSON fields below read back)
    let single = bench::time_into("bench_dist_single", || {
        sweep::run(&spec, 2, &Memo::new()).expect("single-process sweep")
    });
    let single_s = bench::hist_ms("bench_dist_single").expect("recorded").mean_ms / 1e3;
    assert_eq!(single.points.len(), n_points);

    // fleet: two workers, one coordinator, everything over loopback
    let (w1, w2) = (worker(), worker());
    let cfg = ScheduleConfig {
        workers: vec![w1.local_addr().to_string(), w2.local_addr().to_string()],
        jobs: 2,
        ..ScheduleConfig::default()
    };
    let memo = Memo::new();
    let coordinator = Coordinator::new(&spec, &cfg).expect("coordinator");
    let report = bench::time_into("bench_dist_coordinated", || {
        coordinator.run(&memo).expect("coordinate")
    });
    let dist_s = bench::hist_ms("bench_dist_coordinated").expect("recorded").mean_ms / 1e3;

    assert_eq!(report.grid_points, n_points);
    assert_eq!(report.replay_solves, 0, "merged union must replay without solving");
    assert_eq!(report.replay_evals, 0, "merged union must replay without evaluating");

    println!(
        "distributed_sweep: {n_points} grid points, {} shards over 2 workers",
        report.shards.len()
    );
    println!("  single process      {:>10.2} ms", single_s * 1e3);
    println!(
        "  coordinated fleet   {:>10.2} ms  ({:.2}x the single-process time)",
        dist_s * 1e3,
        dist_s / single_s
    );
    println!(
        "  merged {} entries, replay: {} solves / {} evals",
        report.accepted, report.replay_solves, report.replay_evals
    );

    let mut j = Json::obj();
    j.set("bench", Json::Str("distributed_sweep".into()));
    j.set(
        "note",
        Json::Str(
            "Coordinator + two loopback workers vs one process; regenerate with \
             `cargo bench --bench distributed_sweep`."
                .into(),
        ),
    );
    let mut acc = Json::obj();
    acc.set("replay_solves_max", Json::Num(0.0));
    acc.set("replay_evals_max", Json::Num(0.0));
    j.set("acceptance", acc);
    j.set("quick", Json::Bool(quick));
    j.set("grid_points", Json::Num(n_points as f64));
    j.set("shards", Json::Num(report.shards.len() as f64));
    j.set("workers", Json::Num(2.0));
    j.set("single_ms", Json::Num(single_s * 1e3));
    j.set("distributed_ms", Json::Num(dist_s * 1e3));
    j.set("distributed_overhead", Json::Num(dist_s / single_s));
    j.set("merge_accepted", Json::Num(report.accepted as f64));
    j.set("replay_solves", Json::Num(report.replay_solves as f64));
    j.set("replay_evals", Json::Num(report.replay_evals as f64));

    // Scheduler-side obs counters for this process: dispatch volume,
    // retry count, and the dispatch latency histogram.
    let dispatches = deepnvm::obs::global().counter("deepnvm_shard_dispatches_total").get();
    let retries = deepnvm::obs::global().counter("deepnvm_shard_retries_total").get();
    j.set("dispatches", Json::Num(dispatches as f64));
    j.set("dispatch_retries", Json::Num(retries as f64));
    match bench::hist_ms("deepnvm_shard_dispatch_duration_ns") {
        Some(h) => {
            j.set("dispatch_p50_ms", Json::Num(h.p50_ms));
            j.set("dispatch_p99_ms", Json::Num(h.p99_ms));
        }
        None => {
            j.set("dispatch_p50_ms", Json::Null);
            j.set("dispatch_p99_ms", Json::Null);
        }
    }

    // Fleet stitching cost and volume: scrape both workers' /trace,
    // rebase, and flow-link — the observability path `coordinate
    // --trace-out` pays after a run.
    let fleet = bench::time_into("bench_dist_fleet_trace", || coordinator.fleet_trace());
    let fleet_events =
        fleet.get("traceEvents").and_then(Json::as_arr).map_or(0, |a| a.len());
    assert!(fleet_events > 0, "the stitched trace must carry events");
    println!("  stitched fleet trace: {fleet_events} events");
    j.set("fleet_trace_events", Json::Num(fleet_events as f64));
    j.set(
        "fleet_trace_workers",
        fleet.get("workersStitched").cloned().unwrap_or(Json::Null),
    );
    match bench::hist_ms("bench_dist_fleet_trace") {
        Some(h) => {
            j.set("fleet_trace_ms", Json::Num(h.mean_ms));
        }
        None => {
            j.set("fleet_trace_ms", Json::Null);
        }
    }

    // Land next to CHANGES.md when run from rust/ or the repo root.
    let path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_distributed.json"
    } else {
        "BENCH_distributed.json"
    };
    match std::fs::write(path, j.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
