//! Bench F6: regenerate Fig 6 (DRAM reduction vs L2 capacity) via the
//! hierarchy simulator, and measure simulator throughput — the hot
//! path of the whole framework (EXPERIMENTS.md §Perf target:
//! >= 10 M trace-events/s).

mod bench_common;

use deepnvm::coordinator::reports;
use deepnvm::gpusim::{GpuSim, GpuConfig};
use deepnvm::util::bench::Bench;
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::trace::DnnTrace;

const MB: u64 = 1024 * 1024;

fn main() {
    let batch = if bench_common::quick() { 1 } else { 4 };
    bench_common::emit(&reports::fig6(batch));

    // simulator throughput on a SqueezeNet trace (~5M events)
    let d = Dnn::by_name("SqueezeNet").unwrap();
    let n = DnnTrace::new(&d, Phase::Inference, 1).len_estimate() as f64;
    let mut b = Bench::new();
    let mut f = || {
        let mut sim = GpuSim::new(GpuConfig::gtx1080ti(3 * MB));
        sim.run(DnnTrace::new(&d, Phase::Inference, 1)).dram_total()
    };
    b.run_items("gpusim/squeezenet_b1_events", n, &mut f);
}
