//! Bench F7: regenerate Fig 7 (iso-area dynamic/leakage energy).

mod bench_common;

use deepnvm::analysis::iso_area;
use deepnvm::coordinator::reports;
use deepnvm::util::bench::Bench;

fn main() {
    // paper-measured reductions for the report (the bench times the
    // analytic study, fig6_dram times the simulation itself)
    let (f7, _) = reports::fig7_fig8(Some((0.146, 0.198)));
    bench_common::emit(&f7);

    let mut b = Bench::new();
    b.run("analysis/iso_area_study_cached_reductions", || {
        iso_area::study(Some((0.146, 0.198)))
    });
}
