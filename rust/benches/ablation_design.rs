//! Ablation bench: how much each dimension of Algorithm 1's design
//! space matters. Regenerates the EDAP-optimal 3 MB designs with parts
//! of the search space disabled and reports the EDAP penalty — the
//! design-choice justification DESIGN.md calls out.

mod bench_common;

use deepnvm::device::MemTech;
use deepnvm::nvsim::explorer::OptTarget;
use deepnvm::nvsim::model::evaluate;
use deepnvm::nvsim::org::{AccessMode, CacheOrg};
use deepnvm::nvsim::tech::{Bitcell, TechParams};
use deepnvm::util::bench::Bench;
use deepnvm::util::table::{f, Table};

const MB: u64 = 1024 * 1024;

/// Best EDAP for one memory with a restricted search space.
fn best_edap(
    mem: MemTech,
    modes: &[AccessMode],
    opts: &[OptTarget],
) -> f64 {
    let tech = TechParams::n16();
    let cell = Bitcell::paper(mem);
    let mut best = f64::INFINITY;
    for &mode in modes {
        for org in CacheOrg::enumerate(3 * MB, mode) {
            let base = evaluate(&tech, &cell, &org);
            for opt in opts {
                best = best.min(opt.apply(&base).edap());
            }
        }
    }
    best
}

fn main() {
    let all_modes = AccessMode::ALL;
    let all_opts = OptTarget::ALL;

    let mut t = Table::new(&["tech", "search space", "EDAP penalty"])
        .title("Ablation: restricting Algorithm 1's search space (3 MB)");
    for mem in MemTech::ALL {
        let full = best_edap(mem, &all_modes, &all_opts);
        let cases: [(&str, f64); 4] = [
            ("full (baseline)", full),
            (
                "Normal mode only",
                best_edap(mem, &[AccessMode::Normal], &all_opts),
            ),
            (
                "no opt targets (ReadEDP only)",
                best_edap(mem, &all_modes, &[OptTarget::ReadEdp]),
            ),
            (
                "Normal + ReadEDP only",
                best_edap(mem, &[AccessMode::Normal], &[OptTarget::ReadEdp]),
            ),
        ];
        for (name, edap) in cases {
            t.row(&[
                mem.name().to_string(),
                name.to_string(),
                format!("{}x", f(edap / full, 3)),
            ]);
        }
        t.sep();
    }
    println!("{}", t.to_string());

    let mut b = Bench::new();
    b.run("ablation/full_space_sram_3mb", || {
        best_edap(MemTech::Sram, &all_modes, &all_opts)
    });
    b.run("ablation/restricted_space_sram_3mb", || {
        best_edap(MemTech::Sram, &[AccessMode::Normal], &[OptTarget::ReadEdp])
    });
}
