//! Bench F3: regenerate Fig 3 (iso-capacity dynamic/leakage energy) and
//! time the workload traffic model.

mod bench_common;

use deepnvm::analysis::iso_capacity;
use deepnvm::coordinator::reports;
use deepnvm::util::bench::Bench;
use deepnvm::workload::models::{Dnn, Phase};
use deepnvm::workload::traffic::TrafficModel;

fn main() {
    let (f3, _) = reports::fig3_fig4();
    bench_common::emit(&f3);

    let mut b = Bench::new();
    b.run("analysis/iso_capacity_full_study", iso_capacity::study);
    let vgg = Dnn::by_name("VGG-16").unwrap();
    let m = TrafficModel::default();
    b.run("workload/traffic_vgg16_training_b64", || {
        m.run(&vgg, Phase::Training, 64)
    });
    b.run("workload/zoo_construction", Dnn::zoo);
}
