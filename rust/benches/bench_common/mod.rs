#![allow(dead_code)] // each bench uses the subset it needs
//! Shared scaffolding for the per-table/figure bench harnesses.
//!
//! Each bench target (a) regenerates its paper artifact and prints it
//! (paper-vs-measured), and (b) times the underlying computation with
//! the `util::bench` harness so `cargo bench` doubles as the perf
//! regression suite.

use deepnvm::coordinator::reports::Report;
use deepnvm::coordinator::store::Store;

/// Print a report and persist its CSV under results/.
pub fn emit(report: &Report) {
    println!("{}", report.text);
    let mut store = Store::new("results");
    if let Err(e) = store.save(report) {
        eprintln!("warning: could not persist {}: {e}", report.id);
    }
    let _ = store.finish(&[("source", "bench")]);
}

/// `--quick` flag (used by CI / `make bench` smoke runs).
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}
