//! Bench F9: regenerate Fig 9 (capacity scaling of PPA) and time the
//! full Algorithm 1 sweep.

mod bench_common;

use deepnvm::analysis::scalability;
use deepnvm::coordinator::reports;
use deepnvm::nvsim::explorer;
use deepnvm::util::bench::Bench;

fn main() {
    let caps: Vec<u64> = if bench_common::quick() {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    };
    bench_common::emit(&reports::fig9(&caps));

    let mut b = Bench::new();
    // Raw Algorithm-1 solver (unmemoized), so this number keeps
    // tracking circuit-solve cost; the memoized production path is
    // covered by `cargo bench --bench sweep_scaling`.
    b.run("nvsim/explore_3techs_x_6caps", || {
        explorer::explore(&scalability::CAPACITIES_MB)
    });
}
