//! Bench T1: regenerate Table I (device characterization) and time the
//! LLGS/RC characterization flow.

mod bench_common;

use deepnvm::coordinator::reports;
use deepnvm::device::characterize;
use deepnvm::util::bench::Bench;

fn main() {
    bench_common::emit(&reports::table1());

    let mut b = Bench::new();
    b.run("device/characterize_full_sweep", characterize::characterize);
    b.run("device/stt_point_4fins", || characterize::stt_point(4));
    b.run("device/sot_point_3fins", || characterize::sot_point(3));
}
