//! Lightweight span tracer: RAII guards recording (name, start,
//! duration, thread, parent) into a bounded process-global ring buffer,
//! exportable as Chrome trace-event JSON (`chrome://tracing`,
//! <https://ui.perfetto.dev>).
//!
//! `Span::enter("circuit.solve")` pushes onto a thread-local stack so
//! nested spans record their parent id; the record lands in the ring on
//! drop. The ring keeps the newest [`RING_CAPACITY`] spans (tunable via
//! [`set_ring_capacity`] or `DEEPNVM_TRACE_RING`) and counts what it
//! evicts, so a long-lived server never grows without bound and a trace
//! dump is honest about truncation.
//!
//! Spans also carry a **trace id** for cross-process correlation: every
//! process owns one [`trace_id`], a root span started under an
//! `X-Deepnvm-Trace: <trace>:<parent>` header adopts the remote trace
//! via [`Span::remote`], and children inherit the adopted trace through
//! the thread-local stack. The coordinator uses this to stitch worker
//! span rings into one fleet-wide Chrome trace.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Default newest-spans-kept bound; ~100 bytes each, so the ring tops
/// out near 6 MB. Override with [`set_ring_capacity`] (`--trace-ring`)
/// or the `DEEPNVM_TRACE_RING` environment variable.
pub const RING_CAPACITY: usize = 65_536;

/// HTTP header carrying `trace_id:parent_span_id` (both zero-padded
/// lowercase hex), stamped by the scheduler on every dispatch and probe.
pub const TRACE_HEADER: &str = "X-Deepnvm-Trace";

/// Maximum per-span numeric arguments (shard index, run sequence, ...).
pub const MAX_ARGS: usize = 2;

/// One completed span. Times are nanoseconds since [`super::epoch`].
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    pub name: &'static str,
    pub id: u64,
    /// Id of the enclosing span on the same thread; 0 for roots.
    pub parent: u64,
    /// Small dense thread number (assigned on first span per thread).
    pub tid: u64,
    /// Trace id this span belongs to: the process-wide [`trace_id`],
    /// or a remote coordinator's id adopted via [`Span::remote`].
    pub trace: u64,
    /// Span id of the remote parent that dispatched the request this
    /// span handles (from the `X-Deepnvm-Trace` header); 0 when local.
    pub remote_parent: u64,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Optional numeric arguments, e.g. `("shard", 3)`, filled front
    /// to back.
    pub args: [Option<(&'static str, u64)>; MAX_ARGS],
}

/// Drop-oldest bounded buffer; factored out of the global so the
/// eviction policy is testable at tiny capacities.
struct Ring {
    cap: usize,
    buf: VecDeque<SpanRecord>,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { cap: cap.max(1), buf: VecDeque::new(), dropped: 0 }
    }

    fn push(&mut self, rec: SpanRecord) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(rec);
    }
}

static RING: OnceLock<Mutex<Ring>> = OnceLock::new();

/// Capacity requested before first use; 0 means "not configured".
static CONFIGURED_CAP: AtomicUsize = AtomicUsize::new(0);

fn configured_capacity() -> usize {
    let cap = CONFIGURED_CAP.load(Ordering::Relaxed);
    if cap > 0 {
        return cap;
    }
    if let Ok(v) = std::env::var("DEEPNVM_TRACE_RING") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    RING_CAPACITY
}

fn ring() -> &'static Mutex<Ring> {
    RING.get_or_init(|| Mutex::new(Ring::new(configured_capacity())))
}

/// Configure the ring capacity (spans kept). Takes effect only if
/// called before the first span commits (the ring is created lazily);
/// returns whether the request landed in time. The `--trace-ring` flag
/// and `DEEPNVM_TRACE_RING` both route through here.
pub fn set_ring_capacity(cap: usize) -> bool {
    CONFIGURED_CAP.store(cap.max(1), Ordering::Relaxed);
    RING.get().is_none()
}

/// The capacity the ring is (or will be) using.
pub fn ring_capacity() -> usize {
    match RING.get() {
        Some(r) => r.lock().unwrap().cap,
        None => configured_capacity(),
    }
}

static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
    /// In-flight spans on this thread: (span id, trace id). Children
    /// read both so an adopted remote trace propagates to everything
    /// nested under the adopting root.
    static STACK: RefCell<Vec<(u64, u64)>> = const { RefCell::new(Vec::new()) };
}

/// The process-wide trace id: one nonzero 64-bit id minted per process,
/// stamped on every local root span and propagated to workers in the
/// `X-Deepnvm-Trace` header.
pub fn trace_id() -> u64 {
    static TRACE_ID: OnceLock<u64> = OnceLock::new();
    *TRACE_ID.get_or_init(|| {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        // splitmix64 over (clock ^ pid) spreads ids minted in the same
        // tick across the 64-bit space.
        let mut x = nanos ^ (std::process::id() as u64).rotate_left(32);
        x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        x ^= x >> 31;
        x.max(1) // 0 means "no trace" on the wire
    })
}

/// Render an `X-Deepnvm-Trace` header value: `trace:parent`, both as
/// fixed-width lowercase hex (u64 trace ids exceed 2^53, so they must
/// never pass through a float-backed JSON number — hex strings only).
pub fn trace_header_value(trace: u64, parent: u64) -> String {
    format!("{trace:016x}:{parent:016x}")
}

/// Parse an `X-Deepnvm-Trace` header value. Returns `None` for
/// malformed values or a zero trace id (zero means "no trace").
pub fn parse_trace_header(value: &str) -> Option<(u64, u64)> {
    let (trace, parent) = value.trim().split_once(':')?;
    let trace = u64::from_str_radix(trace.trim(), 16).ok()?;
    let parent = u64::from_str_radix(parent.trim(), 16).ok()?;
    if trace == 0 {
        return None;
    }
    Some((trace, parent))
}

/// An in-flight span. Create with [`Span::enter`]; the record is
/// committed to the ring when the guard drops.
pub struct Span {
    name: &'static str,
    id: u64,
    parent: u64,
    trace: u64,
    remote_parent: u64,
    start: Instant,
    start_ns: u64,
    args: [Option<(&'static str, u64)>; MAX_ARGS],
}

impl Span {
    pub fn enter(name: &'static str) -> Span {
        let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        let (parent, trace) = STACK.with(|s| {
            let mut s = s.borrow_mut();
            let (parent, trace) = match s.last() {
                Some(&(pid, ptrace)) => (pid, ptrace),
                None => (0, trace_id()),
            };
            s.push((id, trace));
            (parent, trace)
        });
        let start_ns = super::epoch().elapsed().as_nanos() as u64;
        Span {
            name,
            id,
            parent,
            trace,
            remote_parent: 0,
            start: Instant::now(),
            start_ns,
            args: [None; MAX_ARGS],
        }
    }

    /// Adopt a remote trace context (from an `X-Deepnvm-Trace` header):
    /// this span and everything nested under it record the remote
    /// trace id, and this span records which remote span dispatched
    /// it. A zero trace id is ignored.
    pub fn remote(mut self, trace: u64, remote_parent: u64) -> Span {
        if trace == 0 {
            return self;
        }
        self.trace = trace;
        self.remote_parent = remote_parent;
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            if let Some(top) = s.iter_mut().rev().find(|(id, _)| *id == self.id) {
                top.1 = trace;
            }
        });
        self
    }

    /// Attach a numeric argument (shard index, batch, ...); the first
    /// [`MAX_ARGS`] stick, later ones are dropped.
    pub fn arg(mut self, key: &'static str, value: u64) -> Span {
        if let Some(slot) = self.args.iter_mut().find(|a| a.is_none()) {
            *slot = Some((key, value));
        }
        self
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// The trace id this span currently records under.
    pub fn trace(&self) -> u64 {
        self.trace
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            // Spans normally drop LIFO; a guard held across scopes can
            // drop out of order, so remove by id rather than popping.
            if s.last().map(|&(id, _)| id) == Some(self.id) {
                s.pop();
            } else {
                s.retain(|&(id, _)| id != self.id);
            }
        });
        let tid = TID.with(|t| {
            if t.get() == 0 {
                t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
            }
            t.get()
        });
        let rec = SpanRecord {
            name: self.name,
            id: self.id,
            parent: self.parent,
            tid,
            trace: self.trace,
            remote_parent: self.remote_parent,
            start_ns: self.start_ns,
            dur_ns: self.start.elapsed().as_nanos() as u64,
            args: self.args,
        };
        ring().lock().unwrap().push(rec);
    }
}

/// Snapshot of the ring, oldest first.
pub fn records() -> Vec<SpanRecord> {
    ring().lock().unwrap().buf.iter().copied().collect()
}

/// Completed spans currently held in the ring.
pub fn span_count() -> usize {
    ring().lock().unwrap().buf.len()
}

/// Spans evicted from the ring since process start. `/metrics` mirrors
/// this as `deepnvm_trace_spans_dropped_total` at scrape time.
pub fn dropped() -> u64 {
    ring().lock().unwrap().dropped
}

/// The ring as a Chrome trace-event JSON document: complete (`ph: "X"`)
/// events with microsecond timestamps, one Chrome "thread" per traced
/// OS thread, span/parent/trace ids under `args`. Trace ids are
/// rendered as hex *strings* (they exceed f64's 2^53 integer range);
/// span ids are small and stay numeric.
pub fn chrome_trace_json() -> Json {
    let (recs, dropped) = {
        let r = ring().lock().unwrap();
        (r.buf.iter().copied().collect::<Vec<_>>(), r.dropped)
    };
    let mut events = Vec::with_capacity(recs.len());
    for r in recs {
        let mut args = Json::obj();
        args.set("id", Json::Num(r.id as f64));
        args.set("parent", Json::Num(r.parent as f64));
        args.set("trace", Json::Str(format!("{:016x}", r.trace)));
        if r.remote_parent != 0 {
            args.set("remoteParent", Json::Num(r.remote_parent as f64));
        }
        for (k, v) in r.args.iter().flatten() {
            args.set(k, Json::Num(*v as f64));
        }
        let mut e = Json::obj();
        e.set("name", Json::Str(r.name.to_string()));
        e.set("cat", Json::Str("deepnvm".to_string()));
        e.set("ph", Json::Str("X".to_string()));
        e.set("ts", Json::Num(r.start_ns as f64 / 1e3));
        e.set("dur", Json::Num(r.dur_ns as f64 / 1e3));
        e.set("pid", Json::Num(1.0));
        e.set("tid", Json::Num(r.tid as f64));
        e.set("args", args);
        events.push(e);
    }
    let mut doc = Json::obj();
    doc.set("displayTimeUnit", Json::Str("ms".to_string()));
    doc.set("traceId", Json::Str(format!("{:016x}", trace_id())));
    doc.set("droppedSpans", Json::Num(dropped as f64));
    doc.set("traceEvents", Json::Arr(events));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64) -> SpanRecord {
        SpanRecord {
            name: "t",
            id,
            parent: 0,
            tid: 1,
            trace: 1,
            remote_parent: 0,
            start_ns: id,
            dur_ns: 1,
            args: [None; MAX_ARGS],
        }
    }

    #[test]
    fn ring_is_bounded_and_keeps_the_newest() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(rec(i));
        }
        assert_eq!(r.buf.len(), 3);
        assert_eq!(r.dropped, 2);
        assert_eq!(r.buf.front().unwrap().id, 2);
        assert_eq!(r.buf.back().unwrap().id, 4);
    }

    #[test]
    fn spans_record_parent_child_nesting() {
        let parent = Span::enter("obs_test_parent");
        let parent_id = parent.id();
        {
            let child = Span::enter("obs_test_child").arg("k", 7);
            assert!(child.id() > parent_id, "span ids are monotone");
        }
        drop(parent);
        let recs = records();
        let p = recs.iter().rev().find(|r| r.name == "obs_test_parent").unwrap();
        let c = recs.iter().rev().find(|r| r.name == "obs_test_child").unwrap();
        assert_eq!(p.id, parent_id);
        assert_eq!(c.parent, p.id, "child records the enclosing span");
        assert_eq!(p.parent, 0, "top-level span is a root");
        assert_eq!(c.tid, p.tid, "same thread, same lane");
        assert_eq!(c.args[0], Some(("k", 7)));
        assert_eq!(p.trace, trace_id(), "local roots carry the process trace id");
        assert_eq!(c.trace, trace_id());
        assert!(p.start_ns <= c.start_ns);
        assert!(p.dur_ns >= c.dur_ns, "parent encloses the child");
    }

    #[test]
    fn sibling_after_child_drop_sees_the_same_parent() {
        let parent = Span::enter("obs_test_outer");
        let first = Span::enter("obs_test_first");
        drop(first);
        let second = Span::enter("obs_test_second");
        drop(second);
        drop(parent);
        let recs = records();
        let outer = recs.iter().rev().find(|r| r.name == "obs_test_outer").unwrap();
        let second = recs.iter().rev().find(|r| r.name == "obs_test_second").unwrap();
        assert_eq!(second.parent, outer.id);
    }

    #[test]
    fn spans_fit_two_args_and_drop_the_rest() {
        {
            let _s = Span::enter("obs_test_args").arg("a", 1).arg("b", 2).arg("c", 3);
        }
        let recs = records();
        let r = recs.iter().rev().find(|r| r.name == "obs_test_args").unwrap();
        assert_eq!(r.args, [Some(("a", 1)), Some(("b", 2))]);
    }

    #[test]
    fn remote_context_is_adopted_and_inherited() {
        let remote_trace = 0xdead_beef_cafe_f00d_u64;
        let (root_rec, child_rec, after_rec) = {
            let root = Span::enter("obs_test_remote_root").remote(remote_trace, 42);
            let root_id = root.id();
            let child_id = {
                let child = Span::enter("obs_test_remote_child");
                assert_eq!(child.trace(), remote_trace, "children inherit the adopted trace");
                child.id()
            };
            drop(root);
            // a sibling AFTER the adopting root dropped is back on the
            // process trace
            let after = Span::enter("obs_test_remote_after");
            let after_id = after.id();
            drop(after);
            (root_id, child_id, after_id)
        };
        let recs = records();
        let root = recs.iter().rev().find(|r| r.id == root_rec).unwrap();
        let child = recs.iter().rev().find(|r| r.id == child_rec).unwrap();
        let after = recs.iter().rev().find(|r| r.id == after_rec).unwrap();
        assert_eq!(root.trace, remote_trace);
        assert_eq!(root.remote_parent, 42);
        assert_eq!(child.trace, remote_trace);
        assert_eq!(child.remote_parent, 0, "only the adopting root records the remote parent");
        assert_eq!(after.trace, trace_id());
    }

    #[test]
    fn trace_header_roundtrips() {
        let v = trace_header_value(trace_id(), 7);
        assert_eq!(parse_trace_header(&v), Some((trace_id(), 7)));
        assert_eq!(parse_trace_header("nonsense"), None);
        assert_eq!(parse_trace_header(""), None);
        assert_eq!(
            parse_trace_header("0000000000000000:0000000000000001"),
            None,
            "zero trace means no trace"
        );
        assert_eq!(parse_trace_header("00ff:0001"), Some((0xff, 1)));
    }

    #[test]
    fn chrome_trace_has_complete_events() {
        {
            let _s = Span::enter("obs_test_chrome");
        }
        let doc = chrome_trace_json();
        assert_eq!(
            doc.get("traceId").and_then(|t| t.as_str()),
            Some(format!("{:016x}", trace_id()).as_str())
        );
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let e = events
            .iter()
            .rev()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("obs_test_chrome"))
            .expect("span reaches the trace export");
        assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(e.get("pid").and_then(|p| p.as_f64()), Some(1.0));
        assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("dur").and_then(|d| d.as_f64()).is_some());
        let args = e.get("args").unwrap();
        assert!(args.get("id").is_some());
        assert_eq!(
            args.get("trace").and_then(|t| t.as_str()),
            Some(format!("{:016x}", trace_id()).as_str()),
            "every exported span names its trace"
        );
    }

    #[test]
    fn ring_capacity_is_configurable_before_first_use() {
        // The global ring may already exist (other tests create spans),
        // so only the "too late" contract is assertable here; the
        // capacity plumbing itself is covered via configured_capacity.
        // Only capacities >= the default are used here so a parallel
        // test initializing the global ring mid-test never shrinks it.
        CONFIGURED_CAP.store(0, Ordering::Relaxed);
        assert_eq!(configured_capacity(), RING_CAPACITY);
        let landed = set_ring_capacity(RING_CAPACITY * 2);
        assert_eq!(configured_capacity(), RING_CAPACITY * 2);
        assert_eq!(landed, RING.get().is_none());
        assert!(ring_capacity() >= RING_CAPACITY);
        CONFIGURED_CAP.store(0, Ordering::Relaxed);
    }
}
