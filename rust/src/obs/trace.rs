//! Lightweight span tracer: RAII guards recording (name, start,
//! duration, thread, parent) into a bounded process-global ring buffer,
//! exportable as Chrome trace-event JSON (`chrome://tracing`,
//! <https://ui.perfetto.dev>).
//!
//! `Span::enter("circuit.solve")` pushes onto a thread-local stack so
//! nested spans record their parent id; the record lands in the ring on
//! drop. The ring keeps the newest [`RING_CAPACITY`] spans and counts
//! what it evicts, so a long-lived server never grows without bound and
//! a trace dump is honest about truncation.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Newest spans kept; ~100 bytes each, so the ring tops out near 6 MB.
pub const RING_CAPACITY: usize = 65_536;

/// One completed span. Times are nanoseconds since [`super::epoch`].
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    pub name: &'static str,
    pub id: u64,
    /// Id of the enclosing span on the same thread; 0 for roots.
    pub parent: u64,
    /// Small dense thread number (assigned on first span per thread).
    pub tid: u64,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Optional single numeric argument, e.g. `("shard", 3)`.
    pub arg: Option<(&'static str, u64)>,
}

/// Drop-oldest bounded buffer; factored out of the global so the
/// eviction policy is testable at tiny capacities.
struct Ring {
    cap: usize,
    buf: VecDeque<SpanRecord>,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { cap, buf: VecDeque::new(), dropped: 0 }
    }

    fn push(&mut self, rec: SpanRecord) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(rec);
    }
}

fn ring() -> &'static Mutex<Ring> {
    static RING: OnceLock<Mutex<Ring>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(Ring::new(RING_CAPACITY)))
}

static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
    static STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// An in-flight span. Create with [`Span::enter`]; the record is
/// committed to the ring when the guard drops.
pub struct Span {
    name: &'static str,
    id: u64,
    parent: u64,
    start: Instant,
    start_ns: u64,
    arg: Option<(&'static str, u64)>,
}

impl Span {
    pub fn enter(name: &'static str) -> Span {
        let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        let parent = STACK.with(|s| {
            let mut s = s.borrow_mut();
            let parent = s.last().copied().unwrap_or(0);
            s.push(id);
            parent
        });
        let start_ns = super::epoch().elapsed().as_nanos() as u64;
        Span { name, id, parent, start: Instant::now(), start_ns, arg: None }
    }

    /// Attach one numeric argument (shard index, batch, ...).
    pub fn arg(mut self, key: &'static str, value: u64) -> Span {
        self.arg = Some((key, value));
        self
    }

    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            // Spans normally drop LIFO; a guard held across scopes can
            // drop out of order, so remove by id rather than popping.
            if s.last() == Some(&self.id) {
                s.pop();
            } else {
                s.retain(|&x| x != self.id);
            }
        });
        let tid = TID.with(|t| {
            if t.get() == 0 {
                t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
            }
            t.get()
        });
        let rec = SpanRecord {
            name: self.name,
            id: self.id,
            parent: self.parent,
            tid,
            start_ns: self.start_ns,
            dur_ns: self.start.elapsed().as_nanos() as u64,
            arg: self.arg,
        };
        ring().lock().unwrap().push(rec);
    }
}

/// Snapshot of the ring, oldest first.
pub fn records() -> Vec<SpanRecord> {
    ring().lock().unwrap().buf.iter().copied().collect()
}

/// Completed spans currently held in the ring.
pub fn span_count() -> usize {
    ring().lock().unwrap().buf.len()
}

/// Spans evicted from the ring since process start.
pub fn dropped() -> u64 {
    ring().lock().unwrap().dropped
}

/// The ring as a Chrome trace-event JSON document: complete (`ph: "X"`)
/// events with microsecond timestamps, one Chrome "thread" per traced
/// OS thread, span/parent ids under `args`.
pub fn chrome_trace_json() -> Json {
    let (recs, dropped) = {
        let r = ring().lock().unwrap();
        (r.buf.iter().copied().collect::<Vec<_>>(), r.dropped)
    };
    let mut events = Vec::with_capacity(recs.len());
    for r in recs {
        let mut args = Json::obj();
        args.set("id", Json::Num(r.id as f64));
        args.set("parent", Json::Num(r.parent as f64));
        if let Some((k, v)) = r.arg {
            args.set(k, Json::Num(v as f64));
        }
        let mut e = Json::obj();
        e.set("name", Json::Str(r.name.to_string()));
        e.set("cat", Json::Str("deepnvm".to_string()));
        e.set("ph", Json::Str("X".to_string()));
        e.set("ts", Json::Num(r.start_ns as f64 / 1e3));
        e.set("dur", Json::Num(r.dur_ns as f64 / 1e3));
        e.set("pid", Json::Num(1.0));
        e.set("tid", Json::Num(r.tid as f64));
        e.set("args", args);
        events.push(e);
    }
    let mut doc = Json::obj();
    doc.set("displayTimeUnit", Json::Str("ms".to_string()));
    doc.set("droppedSpans", Json::Num(dropped as f64));
    doc.set("traceEvents", Json::Arr(events));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64) -> SpanRecord {
        SpanRecord { name: "t", id, parent: 0, tid: 1, start_ns: id, dur_ns: 1, arg: None }
    }

    #[test]
    fn ring_is_bounded_and_keeps_the_newest() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(rec(i));
        }
        assert_eq!(r.buf.len(), 3);
        assert_eq!(r.dropped, 2);
        assert_eq!(r.buf.front().unwrap().id, 2);
        assert_eq!(r.buf.back().unwrap().id, 4);
    }

    #[test]
    fn spans_record_parent_child_nesting() {
        let parent = Span::enter("obs_test_parent");
        let parent_id = parent.id();
        {
            let child = Span::enter("obs_test_child").arg("k", 7);
            assert!(child.id() > parent_id, "span ids are monotone");
        }
        drop(parent);
        let recs = records();
        let p = recs.iter().rev().find(|r| r.name == "obs_test_parent").unwrap();
        let c = recs.iter().rev().find(|r| r.name == "obs_test_child").unwrap();
        assert_eq!(p.id, parent_id);
        assert_eq!(c.parent, p.id, "child records the enclosing span");
        assert_eq!(p.parent, 0, "top-level span is a root");
        assert_eq!(c.tid, p.tid, "same thread, same lane");
        assert_eq!(c.arg, Some(("k", 7)));
        assert!(p.start_ns <= c.start_ns);
        assert!(p.dur_ns >= c.dur_ns, "parent encloses the child");
    }

    #[test]
    fn sibling_after_child_drop_sees_the_same_parent() {
        let parent = Span::enter("obs_test_outer");
        let first = Span::enter("obs_test_first");
        drop(first);
        let second = Span::enter("obs_test_second");
        drop(second);
        drop(parent);
        let recs = records();
        let outer = recs.iter().rev().find(|r| r.name == "obs_test_outer").unwrap();
        let second = recs.iter().rev().find(|r| r.name == "obs_test_second").unwrap();
        assert_eq!(second.parent, outer.id);
    }

    #[test]
    fn chrome_trace_has_complete_events() {
        {
            let _s = Span::enter("obs_test_chrome");
        }
        let doc = chrome_trace_json();
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let e = events
            .iter()
            .rev()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("obs_test_chrome"))
            .expect("span reaches the trace export");
        assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(e.get("pid").and_then(|p| p.as_f64()), Some(1.0));
        assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("dur").and_then(|d| d.as_f64()).is_some());
        assert!(e.get("args").and_then(|a| a.get("id")).is_some());
    }
}
