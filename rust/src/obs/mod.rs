//! `obs` — zero-dependency observability: metrics and span tracing for
//! the sweep engine, the serve stack, and the coordinator fleet.
//!
//! Everything here is std-only (matching the house style of
//! [`crate::serve::http`]): no tracing/prometheus/opentelemetry crates,
//! just atomics, a `Mutex<BTreeMap>` registry, and a bounded ring.
//!
//! Two primitives:
//!
//! * **Metrics** ([`metrics`]) — a process-global [`Registry`] of
//!   [`Counter`]s, [`Gauge`]s, and fixed log₂-bucket [`Histogram`]s
//!   (65 `AtomicU64` buckets, `le = 2^0 .. 2^63` plus `+Inf`; p50/p90/
//!   p99 derivable to within 2x). Hot paths hold handles in `static`
//!   [`LazyCounter`]/[`LazyGauge`]/[`LazyHistogram`] cells, so steady-
//!   state cost is one relaxed atomic op per event. Rendered as
//!   Prometheus text by `GET /metrics`.
//! * **Spans** ([`trace`]) — `let _span = Span::enter("circuit.solve")`
//!   RAII guards recording (name, start, duration, thread, parent)
//!   into a bounded ring, exported as Chrome trace-event JSON by
//!   `GET /trace` and `deepnvm <cmd> --trace-out FILE`.
//!
//! Instrumented layers: `sweep::memo` (circuit-solve durations, memo
//! hit/miss and traffic-build counters, lock-wait time), `serve::http`
//! + `routes` (per-route latency histograms, status counters, worker
//! queue depth), `serve::scheduler` (shard dispatch/merge timelines,
//! retry and probe counts), and `util::bench`, which fills the BENCH
//! JSON timing fields from these same histograms — one clock for
//! scrapes, traces, and committed baselines.
//!
//! Tests needing exact counts construct a private [`Registry`] (see
//! `ServerCtx::with_registry`) instead of asserting on [`global`],
//! which is shared by every test in the process.

pub mod metrics;
pub mod trace;

pub use metrics::{
    global, Counter, Gauge, HistSnapshot, Histogram, LazyCounter, LazyGauge, LazyHistogram,
    Registry,
};
pub use trace::Span;

use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// The process observability epoch: all span timestamps and uptime
/// reports are measured from here. Anchored on first call — the CLI
/// entry point calls this immediately, so route uptimes and the span
/// clock agree.
pub fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic time since [`epoch`].
pub fn uptime() -> Duration {
    epoch().elapsed()
}
