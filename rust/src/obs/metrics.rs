//! Process-global metric registry: counters, gauges, and log₂-bucket
//! latency histograms, all std-only and lock-free on the hot path.
//!
//! A [`Registry`] maps series keys (`name` or `name{k="v",...}`) to
//! atomic cells; handles are `Arc`s, so a call site pays the registry
//! mutex once at registration and a relaxed atomic op per event after
//! that. The [`LazyCounter`]/[`LazyGauge`]/[`LazyHistogram`] wrappers
//! make that pattern a one-liner for `static` call sites.
//!
//! Histograms use 65 fixed buckets with upper bounds `2^0 .. 2^63` plus
//! `+Inf`, which covers 1 ns to ~292 years at a guaranteed 2x quantile
//! resolution without any configuration. [`Registry::prometheus_text`]
//! renders the whole registry in Prometheus text exposition format for
//! `GET /metrics`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// A monotonically increasing event count.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Raise the counter to `total` if it is below it (monotone — a
    /// lower `total` is a no-op). For mirroring an externally-owned
    /// monotone count (e.g. trace-ring evictions) into the registry at
    /// scrape time.
    pub fn set_max(&self, total: u64) {
        self.0.fetch_max(total, Ordering::Relaxed);
    }
}

/// A signed instantaneous level (queue depth, uptime seconds).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn sub(&self, d: i64) {
        self.0.fetch_sub(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket count: upper bounds `2^0 .. 2^63` plus an overflow bucket.
pub const BUCKETS: usize = 65;

/// Values above this saturate: they land in the overflow bucket and
/// contribute exactly `2^63` to the sum, so one absurd sample cannot
/// wrap the running total.
const SATURATION: u64 = 1 << 63;

/// A fixed log₂-bucket histogram (nanoseconds by convention). Recording
/// is three relaxed `fetch_add`s — no locks, no allocation — and the
/// bucket layout needs no configuration: bucket `i` holds values in
/// `(2^(i-1), 2^i]`, bucket 0 holds `0..=1`, bucket 64 is `+Inf`.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    fn bucket_index(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            // Smallest i with v <= 2^i; v > 2^63 lands in the overflow
            // bucket because (v - 1).leading_zeros() is then 0.
            64 - (v - 1).leading_zeros() as usize
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v.min(SATURATION), Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Time one call of `f` into this histogram.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_duration(t0.elapsed());
        out
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        self.snapshot().mean()
    }

    /// See [`HistSnapshot::quantile`].
    pub fn quantile(&self, q: f64) -> u64 {
        self.snapshot().quantile(q)
    }

    /// A point-in-time copy. Buckets, count, and sum are read with
    /// independent relaxed loads, so a snapshot taken under concurrent
    /// recording can be off by in-flight events — fine for reporting.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count(),
            sum: self.sum(),
        }
    }

    /// Fold another histogram into this one: bucket-wise and count
    /// adds, saturating sum. Exact for federation because every
    /// histogram shares the same fixed log₂ bucket bounds — merging
    /// buckets is indistinguishable from having recorded the
    /// concatenated samples into one histogram.
    pub fn merge_from(&self, other: &Histogram) {
        self.merge_snapshot(&other.snapshot());
    }

    /// [`Histogram::merge_from`], from an owned snapshot.
    pub fn merge_snapshot(&self, snap: &HistSnapshot) {
        for (i, &c) in snap.buckets.iter().enumerate() {
            if c > 0 {
                self.buckets[i].fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(snap.count, Ordering::Relaxed);
        // fetch_update with a total function always returns Ok
        let _ = self
            .sum
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
                Some(s.saturating_add(snap.sum))
            });
    }
}

/// An owned copy of a [`Histogram`]'s state.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket holding the q-quantile (cumulative
    /// walk), i.e. the true quantile rounded up to the next power of
    /// two — within 2x by construction. Returns 0 on an empty
    /// histogram and `u64::MAX` when the quantile falls in `+Inf`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return if i >= BUCKETS - 1 { u64::MAX } else { 1u64 << i };
            }
        }
        u64::MAX
    }

    /// The delta since an earlier snapshot of the same histogram
    /// (saturating, so a racing in-flight record never underflows).
    /// This is how `loadgen` isolates one run's latencies from a
    /// process-lived histogram.
    pub fn minus(&self, earlier: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_sub(earlier.buckets[i])
            }),
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
        }
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A named collection of metrics. Production code uses the process
/// [`global`] registry; tests construct private registries so exact
/// counter assertions never race with unrelated instrumentation.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

/// Build the canonical series key: sanitized metric name plus a fixed
/// `{k="v",...}` label rendering (values escaped Prometheus-style).
/// `name` may itself carry a literal label block (a `Lazy*` static
/// naming one series) — it is kept verbatim past the first `{`.
fn series_key(name: &str, labels: &[(&str, &str)]) -> String {
    let (base, suffix) = match name.split_once('{') {
        Some((b, rest)) => (b, Some(rest)),
        None => (name, None),
    };
    let mut key: String = base
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if let Some(rest) = suffix {
        key.push('{');
        key.push_str(rest);
        return key;
    }
    if !labels.is_empty() {
        key.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                key.push(',');
            }
            key.push_str(k);
            key.push_str("=\"");
            for ch in v.chars() {
                match ch {
                    '\\' => key.push_str("\\\\"),
                    '"' => key.push_str("\\\""),
                    '\n' => key.push_str("\\n"),
                    c => key.push(c),
                }
            }
            key.push('"');
        }
        key.push('}');
    }
    key
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counter_key(&series_key(name, &[]))
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        self.counter_key(&series_key(name, labels))
    }

    fn counter_key(&self, key: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(key.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())));
        match entry {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{key}' is already registered with another type"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let key = series_key(name, &[]);
        let mut m = self.metrics.lock().unwrap();
        let entry = m.entry(key).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())));
        match entry {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' is already registered with another type"),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_key(&series_key(name, &[]))
    }

    pub fn histogram_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        self.histogram_key(&series_key(name, labels))
    }

    fn histogram_key(&self, key: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(key.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())));
        match entry {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric '{key}' is already registered with another type"),
        }
    }

    /// Number of registered series (histograms count as one here; the
    /// text exposition expands them into `_bucket`/`_sum`/`_count`).
    pub fn series_count(&self) -> usize {
        self.metrics.lock().unwrap().len()
    }

    /// Render every series in Prometheus text exposition format
    /// (version 0.0.4): `# TYPE` once per metric family, labeled
    /// series grouped under it, histograms expanded into cumulative
    /// `_bucket{le=...}` lines plus `_sum` and `_count`.
    pub fn prometheus_text(&self) -> String {
        let metrics = self.metrics.lock().unwrap();
        let mut families: BTreeMap<&str, Vec<(&str, &Metric)>> = BTreeMap::new();
        for (key, m) in metrics.iter() {
            let fam = key.split('{').next().unwrap_or(key);
            families.entry(fam).or_default().push((key.as_str(), m));
        }
        let mut out = String::new();
        for (fam, series) in &families {
            let kind = match series[0].1 {
                Metric::Counter(_) => "counter",
                Metric::Gauge(_) => "gauge",
                Metric::Histogram(_) => "histogram",
            };
            out.push_str("# TYPE ");
            out.push_str(fam);
            out.push(' ');
            out.push_str(kind);
            out.push('\n');
            for (key, m) in series {
                match m {
                    Metric::Counter(c) => {
                        out.push_str(&format!("{key} {}\n", c.get()));
                    }
                    Metric::Gauge(g) => {
                        out.push_str(&format!("{key} {}\n", g.get()));
                    }
                    Metric::Histogram(h) => write_histogram(&mut out, fam, key, h),
                }
            }
        }
        out
    }
}

fn write_histogram(out: &mut String, fam: &str, key: &str, h: &Histogram) {
    let snap = h.snapshot();
    // "" for a bare family, or the literal `{...}` label block.
    let labels = key.strip_prefix(fam).unwrap_or("");
    let inner = labels.trim_start_matches('{').trim_end_matches('}');
    let with_le = |le: &str| {
        if inner.is_empty() {
            format!("{fam}_bucket{{le=\"{le}\"}}")
        } else {
            format!("{fam}_bucket{{{inner},le=\"{le}\"}}")
        }
    };
    let last = snap.buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
    let mut cum = 0u64;
    for (i, &c) in snap.buckets.iter().enumerate().take(last.min(BUCKETS - 2) + 1) {
        cum += c;
        out.push_str(&format!("{} {cum}\n", with_le(&(1u64 << i).to_string())));
    }
    out.push_str(&format!("{} {}\n", with_le("+Inf"), snap.count));
    out.push_str(&format!("{fam}_sum{labels} {}\n", snap.sum));
    out.push_str(&format!("{fam}_count{labels} {}\n", snap.count));
}

/// Split a `series value` exposition line at its last whitespace run.
fn split_series_line(line: &str) -> Option<(&str, &str)> {
    let (series, value) = line.rsplit_once(|c: char| c.is_whitespace())?;
    Some((series.trim_end(), value))
}

/// Bucket index named by a `le="..."` label inside a label block, for
/// the fixed log₂ layout: `le="2^i"` is bucket `i`, `+Inf` the
/// overflow bucket. `None` for foreign bucket bounds.
fn le_bucket_index(labels: &str) -> Option<usize> {
    let le = labels.split(',').find_map(|kv| kv.strip_prefix("le=\""))?.trim_end_matches('"');
    if le == "+Inf" {
        return Some(BUCKETS - 1);
    }
    let v: u64 = le.parse().ok()?;
    if !v.is_power_of_two() {
        return None;
    }
    Some(v.trailing_zeros() as usize)
}

/// A histogram bucket line's label block with the `le` label removed —
/// the key that groups one histogram's lines back together.
fn labels_without_le(labels: &str) -> String {
    labels.split(',').filter(|kv| !kv.starts_with("le=\"")).collect::<Vec<_>>().join(",")
}

fn fmt_metric_value(v: f64) -> String {
    // 2^53: above this an f64 no longer holds every integer, so stop
    // pretending the value is one
    if v.fract() == 0.0 && v.abs() < 9_007_199_254_740_992.0 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Merge several Prometheus text expositions into one fleet-level
/// exposition: scalar series with identical keys are summed, and
/// histogram families are merged bucket-wise — each source's
/// cumulative `_bucket{le=...}` lines are differenced back into
/// per-bucket counts, summed, and re-rendered cumulatively. Because
/// every [`Histogram`] shares the same fixed log₂ bounds, the merge is
/// exact: the result is indistinguishable from one process having
/// recorded all samples. Families and series keep first-appearance
/// order; `# TYPE` lines are deduplicated. Histogram component lines
/// are only recognized under a `# TYPE <fam> histogram` header (our
/// own expositions always carry one).
pub fn merge_expositions<T: AsRef<str>>(texts: &[T]) -> String {
    use std::collections::HashMap;

    struct Fam {
        kind: String,
        scalar_order: Vec<String>,
        scalars: HashMap<String, f64>,
        /// histogram groups keyed by label block minus `le`:
        /// (per-bucket counts, sum, count)
        group_order: Vec<String>,
        groups: HashMap<String, ([u64; BUCKETS], u64, u64)>,
    }
    impl Fam {
        fn new(kind: &str) -> Fam {
            Fam {
                kind: kind.to_string(),
                scalar_order: Vec::new(),
                scalars: HashMap::new(),
                group_order: Vec::new(),
                groups: HashMap::new(),
            }
        }
        fn group(&mut self, labels: &str) -> &mut ([u64; BUCKETS], u64, u64) {
            if !self.groups.contains_key(labels) {
                self.group_order.push(labels.to_string());
                self.groups.insert(labels.to_string(), ([0u64; BUCKETS], 0, 0));
            }
            self.groups.get_mut(labels).unwrap()
        }
    }

    let mut order: Vec<String> = Vec::new();
    let mut fams: HashMap<String, Fam> = HashMap::new();

    for text in texts {
        // this source's cumulative bucket lines, differenced into
        // per-bucket counts once the source is fully read
        let mut cums: HashMap<(String, String), Vec<(usize, u64)>> = HashMap::new();
        let mut cum_order: Vec<(String, String)> = Vec::new();
        for line in text.as_ref().lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                if let (Some(name), Some(kind)) = (it.next(), it.next()) {
                    if !fams.contains_key(name) {
                        order.push(name.to_string());
                        fams.insert(name.to_string(), Fam::new(kind));
                    }
                }
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let Some((series, value)) = split_series_line(line) else { continue };
            let base = series.split('{').next().unwrap_or(series);
            let labels = series
                .split_once('{')
                .map(|(_, l)| l.trim_end_matches('}'))
                .unwrap_or("");
            let hist_part = ["_bucket", "_sum", "_count"].iter().find_map(|suf| {
                let fam = base.strip_suffix(suf)?;
                let is_hist = matches!(fams.get(fam), Some(f) if f.kind == "histogram");
                is_hist.then(|| (fam.to_string(), *suf))
            });
            match hist_part {
                Some((fam_name, "_bucket")) => {
                    let Some(idx) = le_bucket_index(labels) else { continue };
                    let Ok(cum) = value.parse::<u64>() else { continue };
                    let gkey = (fam_name, labels_without_le(labels));
                    let entry = cums.entry(gkey.clone()).or_default();
                    if entry.is_empty() {
                        cum_order.push(gkey);
                    }
                    entry.push((idx, cum));
                }
                Some((fam_name, suf)) => {
                    let Ok(v) = value.parse::<u64>() else { continue };
                    let g = fams.get_mut(&fam_name).unwrap().group(labels);
                    if suf == "_sum" {
                        g.1 = g.1.saturating_add(v);
                    } else {
                        g.2 = g.2.saturating_add(v);
                    }
                }
                None => {
                    let Ok(v) = value.parse::<f64>() else { continue };
                    if !fams.contains_key(base) {
                        order.push(base.to_string());
                        fams.insert(base.to_string(), Fam::new("untyped"));
                    }
                    let fam = fams.get_mut(base).unwrap();
                    if !fam.scalars.contains_key(series) {
                        fam.scalar_order.push(series.to_string());
                    }
                    *fam.scalars.entry(series.to_string()).or_insert(0.0) += v;
                }
            }
        }
        for gkey in cum_order {
            let mut lines = cums.remove(&gkey).unwrap();
            lines.sort_by_key(|&(i, _)| i);
            let (fam_name, labels) = gkey;
            let g = fams.get_mut(&fam_name).unwrap().group(&labels);
            let mut prev = 0u64;
            for (idx, cum) in lines {
                g.0[idx] = g.0[idx].saturating_add(cum.saturating_sub(prev));
                prev = cum;
            }
        }
    }

    let mut out = String::new();
    for fam_name in &order {
        let fam = &fams[fam_name];
        if fam.kind != "untyped" {
            out.push_str(&format!("# TYPE {fam_name} {}\n", fam.kind));
        }
        for key in &fam.scalar_order {
            out.push_str(&format!("{key} {}\n", fmt_metric_value(fam.scalars[key])));
        }
        for labels in &fam.group_order {
            let (buckets, sum, count) = &fam.groups[labels];
            let count = (*count).max(buckets.iter().sum());
            let with_le = |le: &str| {
                if labels.is_empty() {
                    format!("{fam_name}_bucket{{le=\"{le}\"}}")
                } else {
                    format!("{fam_name}_bucket{{{labels},le=\"{le}\"}}")
                }
            };
            let last = buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
            let mut cum = 0u64;
            for (i, &c) in buckets.iter().enumerate().take(last.min(BUCKETS - 2) + 1) {
                cum += c;
                out.push_str(&format!("{} {cum}\n", with_le(&(1u64 << i).to_string())));
            }
            out.push_str(&format!("{} {count}\n", with_le("+Inf")));
            let block =
                if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
            out.push_str(&format!("{fam_name}_sum{block} {sum}\n"));
            out.push_str(&format!("{fam_name}_count{block} {count}\n"));
        }
    }
    out
}

/// Inject `key="value"` as the first label of every series line in a
/// Prometheus text exposition (comment lines pass through). The
/// coordinator uses this to expose its own series next to the
/// fleet-summed ones without key collisions.
pub fn relabel_exposition(text: &str, key: &str, value: &str) -> String {
    let mut out = String::with_capacity(text.len() + 64);
    for line in text.lines() {
        let trimmed = line.trim_end();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            out.push_str(trimmed);
            out.push('\n');
            continue;
        }
        let Some((series, rest)) = split_series_line(trimmed) else {
            out.push_str(trimmed);
            out.push('\n');
            continue;
        };
        match series.split_once('{') {
            Some((name, labels)) => {
                out.push_str(&format!("{name}{{{key}=\"{value}\",{labels} {rest}\n"));
            }
            None => {
                out.push_str(&format!("{series}{{{key}=\"{value}\"}} {rest}\n"));
            }
        }
    }
    out
}

/// The process-global registry behind `GET /metrics` and the `Lazy*`
/// statics.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// A `static`-friendly handle to one global-registry counter:
/// registration happens on first use, after which every increment is
/// one relaxed atomic op with no registry lock. The name may carry a
/// literal label block (`probes_total{result="ok"}`) to pin a series.
pub struct LazyCounter {
    name: &'static str,
    cell: OnceLock<Arc<Counter>>,
}

impl LazyCounter {
    pub const fn new(name: &'static str) -> LazyCounter {
        LazyCounter { name, cell: OnceLock::new() }
    }

    pub fn handle(&self) -> &Counter {
        self.cell.get_or_init(|| global().counter(self.name))
    }

    pub fn inc(&self) {
        self.handle().inc();
    }

    pub fn add(&self, n: u64) {
        self.handle().add(n);
    }

    pub fn value(&self) -> u64 {
        self.handle().get()
    }
}

/// [`LazyCounter`], for gauges.
pub struct LazyGauge {
    name: &'static str,
    cell: OnceLock<Arc<Gauge>>,
}

impl LazyGauge {
    pub const fn new(name: &'static str) -> LazyGauge {
        LazyGauge { name, cell: OnceLock::new() }
    }

    pub fn handle(&self) -> &Gauge {
        self.cell.get_or_init(|| global().gauge(self.name))
    }

    pub fn set(&self, v: i64) {
        self.handle().set(v);
    }

    pub fn add(&self, d: i64) {
        self.handle().add(d);
    }

    pub fn sub(&self, d: i64) {
        self.handle().sub(d);
    }

    pub fn value(&self) -> i64 {
        self.handle().get()
    }
}

/// [`LazyCounter`], for histograms.
pub struct LazyHistogram {
    name: &'static str,
    cell: OnceLock<Arc<Histogram>>,
}

impl LazyHistogram {
    pub const fn new(name: &'static str) -> LazyHistogram {
        LazyHistogram { name, cell: OnceLock::new() }
    }

    pub fn handle(&self) -> &Histogram {
        self.cell.get_or_init(|| global().histogram(self.name))
    }

    pub fn record(&self, v: u64) {
        self.handle().record(v);
    }

    pub fn record_duration(&self, d: Duration) {
        self.handle().record_duration(d);
    }

    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        self.handle().time(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_exact_under_racing_workers() {
        let r = Registry::new();
        let total = r.counter("race_total");
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = r.counter("race_total");
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(total.get(), 80_000);
    }

    #[test]
    fn histogram_is_exact_under_racing_workers() {
        let r = Registry::new();
        let h = r.histogram("race_ns");
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = r.histogram("race_ns");
                s.spawn(move || {
                    for _ in 0..10_000 {
                        h.record(5);
                    }
                });
            }
        });
        let snap = h.snapshot();
        assert_eq!(snap.count, 80_000);
        assert_eq!(snap.sum, 400_000);
        // 5 lands in (4, 8] — bucket index 3 — and nowhere else
        assert_eq!(snap.buckets[3], 80_000);
        assert_eq!(snap.buckets.iter().sum::<u64>(), 80_000);
    }

    #[test]
    fn histogram_bucket_edges() {
        let h = Histogram::new();
        h.record(0); // bucket 0 (le = 1)
        h.record(1); // bucket 0: the 1 ns floor
        h.record(2); // bucket 1 (le = 2)
        h.record((1 << 20) - 1); // bucket 20 (le = 2^20)
        h.record(1 << 20); // exactly on the 2^20 boundary: still bucket 20
        h.record((1 << 20) + 1); // first value of bucket 21
        h.record((1 << 62) + 1); // bucket 63 (le = 2^63)
        h.record(u64::MAX); // overflow bucket; sum saturates at 2^63
        let s = h.snapshot();
        assert_eq!(s.buckets[0], 2);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[20], 2);
        assert_eq!(s.buckets[21], 1);
        assert_eq!(s.buckets[63], 1);
        assert_eq!(s.buckets[BUCKETS - 1], 1);
        assert_eq!(s.count, 8);
        let exact: u64 = 3 + ((1 << 20) - 1) + (1 << 20) + ((1 << 20) + 1) + ((1 << 62) + 1);
        assert_eq!(s.sum, exact + (1 << 63), "u64::MAX contributes a saturated 2^63");
    }

    #[test]
    fn quantiles_walk_cumulative_buckets() {
        let h = Histogram::new();
        for _ in 0..8 {
            h.record(100); // bucket with upper bound 128
        }
        for _ in 0..2 {
            h.record(10_000); // bucket with upper bound 16384
        }
        assert_eq!(h.quantile(0.5), 128);
        assert_eq!(h.quantile(0.9), 16_384);
        assert_eq!(h.quantile(0.99), 16_384);
        assert!((h.mean() - 2_080.0).abs() < 1e-9);
        let empty = Histogram::new();
        assert_eq!(empty.quantile(0.5), 0);
    }

    #[test]
    fn prometheus_text_golden() {
        let r = Registry::new();
        r.counter("golden_total").add(3);
        r.gauge("golden_gauge").set(-2);
        let h = r.histogram("golden_ns");
        h.record(1);
        h.record(3);
        let want = "# TYPE golden_gauge gauge\n\
                    golden_gauge -2\n\
                    # TYPE golden_ns histogram\n\
                    golden_ns_bucket{le=\"1\"} 1\n\
                    golden_ns_bucket{le=\"2\"} 1\n\
                    golden_ns_bucket{le=\"4\"} 2\n\
                    golden_ns_bucket{le=\"+Inf\"} 2\n\
                    golden_ns_sum 4\n\
                    golden_ns_count 2\n\
                    # TYPE golden_total counter\n\
                    golden_total 3\n";
        assert_eq!(r.prometheus_text(), want);
    }

    #[test]
    fn labeled_series_share_one_type_line() {
        let r = Registry::new();
        r.counter_with("lbl_total", &[("route", "/solve"), ("status", "200")]).inc();
        r.counter_with("lbl_total", &[("route", "/sweep"), ("status", "200")]).add(2);
        let text = r.prometheus_text();
        assert_eq!(text.matches("# TYPE lbl_total counter").count(), 1);
        assert!(text.contains("lbl_total{route=\"/solve\",status=\"200\"} 1\n"));
        assert!(text.contains("lbl_total{route=\"/sweep\",status=\"200\"} 2\n"));
    }

    #[test]
    fn labeled_histogram_renders_le_after_labels() {
        let r = Registry::new();
        r.histogram_with("lat_ns", &[("route", "/x")]).record(2);
        let text = r.prometheus_text();
        assert!(text.contains("lat_ns_bucket{route=\"/x\",le=\"2\"} 1\n"));
        assert!(text.contains("lat_ns_bucket{route=\"/x\",le=\"+Inf\"} 1\n"));
        assert!(text.contains("lat_ns_sum{route=\"/x\"} 2\n"));
        assert!(text.contains("lat_ns_count{route=\"/x\"} 1\n"));
    }

    #[test]
    fn names_are_sanitized_and_handles_alias() {
        let r = Registry::new();
        r.counter("bench_sweep/serial").inc();
        assert_eq!(r.counter("bench_sweep_serial").get(), 1, "same cell after sanitizing");
        assert!(r.prometheus_text().contains("bench_sweep_serial 1\n"));
    }

    #[test]
    #[should_panic(expected = "another type")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("dual");
        r.gauge("dual");
    }

    #[test]
    fn counter_set_max_is_monotone() {
        let c = Counter::default();
        c.set_max(5);
        assert_eq!(c.get(), 5);
        c.set_max(3);
        assert_eq!(c.get(), 5, "set_max never lowers the count");
        c.set_max(9);
        assert_eq!(c.get(), 9);
    }

    #[test]
    fn merged_histograms_equal_concatenated_samples() {
        let a = Histogram::new();
        let b = Histogram::new();
        let both = Histogram::new();
        let a_samples = [1u64, 3, 100, 1 << 20, 1 << 45];
        let b_samples = [2u64, 5, 5, 900, 1 << 30];
        for &v in &a_samples {
            a.record(v);
            both.record(v);
        }
        for &v in &b_samples {
            b.record(v);
            both.record(v);
        }
        let fed = Histogram::new();
        fed.merge_from(&a);
        fed.merge_from(&b);
        let f = fed.snapshot();
        let c = both.snapshot();
        assert_eq!(f.buckets, c.buckets, "bucket-wise add == concatenated recording");
        assert_eq!(f.count, c.count);
        assert_eq!(f.sum, c.sum);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(f.quantile(q), c.quantile(q), "federated quantile at q={q}");
        }
    }

    #[test]
    fn merge_saturates_the_sum() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(u64::MAX); // contributes a saturated 2^63 to the sum
        b.record(u64::MAX);
        let fed = Histogram::new();
        fed.merge_from(&a);
        fed.merge_from(&b);
        assert_eq!(fed.sum(), u64::MAX, "2^63 + 2^63 saturates instead of wrapping");
        assert_eq!(fed.count(), 2);
        assert_eq!(fed.snapshot().buckets[BUCKETS - 1], 2);
    }

    #[test]
    fn snapshot_minus_isolates_a_window() {
        let h = Histogram::new();
        h.record(10);
        h.record(1000);
        let before = h.snapshot();
        h.record(10);
        h.record(20);
        let delta = h.snapshot().minus(&before);
        assert_eq!(delta.count, 2);
        assert_eq!(delta.sum, 30);
        assert_eq!(delta.quantile(1.0), 32);
    }

    #[test]
    fn merged_expositions_equal_one_registry_with_all_samples() {
        let w1 = Registry::new();
        let w2 = Registry::new();
        let all = Registry::new();
        for (r, n) in [(&w1, 3u64), (&w2, 7u64)] {
            r.counter("fed_total").add(n);
            all.counter("fed_total").add(n);
            r.counter_with("fed_routes_total", &[("route", "/solve")]).add(n + 1);
            all.counter_with("fed_routes_total", &[("route", "/solve")]).add(n + 1);
            r.gauge("fed_gauge").add(n as i64);
            all.gauge("fed_gauge").add(n as i64);
        }
        // deliberately different bucket spans so the merge has to
        // reconcile cumulative lines of different lengths
        let h1 = w1.histogram_with("fed_ns", &[("route", "/x")]);
        let h2 = w2.histogram_with("fed_ns", &[("route", "/x")]);
        let ha = all.histogram_with("fed_ns", &[("route", "/x")]);
        for v in [1u64, 7, 30] {
            h1.record(v);
            ha.record(v);
        }
        for v in [2u64, 5_000_000] {
            h2.record(v);
            ha.record(v);
        }
        let merged = merge_expositions(&[w1.prometheus_text(), w2.prometheus_text()]);
        assert_eq!(
            merged,
            all.prometheus_text(),
            "federation by text merge is exact and order-stable"
        );
    }

    #[test]
    fn relabel_injects_a_first_label() {
        let r = Registry::new();
        r.counter("rl_total").add(2);
        r.counter_with("rl_routes_total", &[("route", "/solve")]).inc();
        r.histogram("rl_ns").record(3);
        let text = relabel_exposition(&r.prometheus_text(), "role", "coordinator");
        assert!(text.contains("# TYPE rl_total counter\n"), "comments pass through");
        assert!(text.contains("rl_total{role=\"coordinator\"} 2\n"));
        assert!(text.contains("rl_routes_total{role=\"coordinator\",route=\"/solve\"} 1\n"));
        assert!(text.contains("rl_ns_bucket{role=\"coordinator\",le=\"4\"} 1\n"));
        assert!(text.contains("rl_ns_sum{role=\"coordinator\"} 3\n"));
        assert!(text.contains("rl_ns_count{role=\"coordinator\"} 1\n"));
    }

    #[test]
    fn lazy_handles_register_globally_once() {
        static LAZY: LazyCounter = LazyCounter::new("obs_lazy_test_total");
        LAZY.inc();
        LAZY.add(2);
        assert_eq!(LAZY.value(), 3);
        assert_eq!(global().counter("obs_lazy_test_total").get(), 3);
    }
}
