//! Content-addressed memoization for the sweep engine.
//!
//! Three cache layers, all safe to share across worker threads:
//!
//! * **circuit** — `(tech, capacity, node) -> TunedConfig`, so each
//!   NVSim-style Algorithm-1 solve (the expensive enumeration of
//!   organizations x targets x modes) runs at most once per process,
//!   no matter how many figures, workloads or batches query it.
//! * **traffic** — `(dnn, phase) -> BatchLine`: the closed-form batch
//!   coefficients of the workload layer. Keyed WITHOUT the batch (and
//!   without the capacity — spill terms take it at evaluation time),
//!   so a `--batches` sweep over any number of batch sizes lowers each
//!   workload's GEMMs exactly once.
//! * **points** — `GridPoint -> PointResult`, so repeated sweeps skip
//!   even the per-batch coefficient folds.
//!
//! All layers serialize to one JSON document keyed by hashed spec
//! points and persist through [`crate::coordinator::store::Store`]
//! (`sweep_memo.json` in the results directory), so a *second process*
//! re-running the same grid performs zero circuit solves. Entries carry
//! [`MODEL_VERSION`]; bumping it invalidates every cached result when
//! the underlying models change.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::store::Store;
use crate::device::{node_calibrated, MemTech, UncalibratedNode};
use crate::nvsim::explorer::{tuned_cache_at, OptTarget, TunedConfig};
use crate::nvsim::org::{AccessMode, CacheOrg};
use crate::nvsim::{compose_ppa, CachePpa, TechSel};
use crate::obs::{LazyCounter, LazyHistogram, Span};
use crate::util::json::{self, Json};
use crate::workload::models::{Dnn, Phase};
use crate::workload::traffic::{BatchLine, DramTerm, TrafficModel, TxTerm, SUPERTILE};

use super::spec::{parse_phase, parse_tech, parse_tech_sel, resolve_dnn, GridPoint, WorkloadPoint};
use super::{PointResult, WorkloadEval};

/// Bump when any model feeding the sweep changes numerically; stale
/// on-disk caches are then ignored wholesale. v2: the process-node
/// axis went live (7/5 nm calibration) and circuit payload hashes now
/// bind the node id, so v1 caches — hashed without it — are retired.
/// v3: the batch-axis traffic engine — the wire format gained the
/// `traffic` section of `(dnn, phase)`-keyed [`BatchLine`]
/// coefficients, whose payload hashes bind the coefficient payload to
/// its workload key; v2 documents, whose entries were derived strictly
/// per batch, are rejected wholesale so shard merges can never mix the
/// two generations. v4: the hybrid tech axis — point keys now carry a
/// [`TechSel`] spelling (`hybrid-stt:4@0.85`) whose way-partition
/// parameters are part of the content address, so v3 documents (whose
/// keys only ever named pure technologies) are retired rather than
/// merged into a grid they cannot describe.
pub const MODEL_VERSION: u32 = 4;

/// File name of the persisted cache inside a results directory.
pub const MEMO_FILE: &str = "sweep_memo.json";

const MB: u64 = 1024 * 1024;

// Global-registry mirrors of the memoization activity, feeding
// `GET /metrics` and the span traces. The per-instance atomics on
// [`Memo`] stay authoritative for `/memo/stats` and the exact-count
// tests (which use private memos); these accumulate across every memo
// in the process.
static OBS_CIRCUIT_HITS: LazyCounter = LazyCounter::new("deepnvm_memo_circuit_hits_total");
static OBS_CIRCUIT_MISSES: LazyCounter = LazyCounter::new("deepnvm_memo_circuit_misses_total");
static OBS_SOLVES: LazyCounter = LazyCounter::new("deepnvm_circuit_solves_total");
static OBS_TRAFFIC_HITS: LazyCounter = LazyCounter::new("deepnvm_memo_traffic_hits_total");
static OBS_TRAFFIC_BUILDS: LazyCounter = LazyCounter::new("deepnvm_memo_traffic_builds_total");
static OBS_POINT_HITS: LazyCounter = LazyCounter::new("deepnvm_memo_point_hits_total");
static OBS_POINT_MISSES: LazyCounter = LazyCounter::new("deepnvm_memo_point_misses_total");
static OBS_EVALS: LazyCounter = LazyCounter::new("deepnvm_point_evals_total");
static OBS_SOLVE_NS: LazyHistogram = LazyHistogram::new("deepnvm_circuit_solve_duration_ns");
static OBS_LOCK_WAIT_NS: LazyHistogram = LazyHistogram::new("deepnvm_memo_lock_wait_ns");

/// 64-bit FNV-1a — the content-address hash for spec-point keys
/// (dependency-free and stable across platforms/processes).
pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct CircuitKey {
    tech: MemTech,
    capacity_bytes: u64,
    node_nm: u32,
}

/// The point-layer cache with an optional LRU capacity bound. The
/// circuit layer stays unbounded: it holds one entry per
/// (tech, capacity, node) — a few dozen at most — while the point
/// layer grows with the full workload cross-product and is what makes
/// `sweep_memo.json` balloon on very large grids.
///
/// Recency is a monotonic clock: every hit or insert stamps the entry,
/// and `order` (stamp -> point) yields the least-recently-used victim
/// in O(log n) when over capacity.
#[derive(Default)]
struct PointCache {
    map: HashMap<GridPoint, (PointResult, u64)>,
    order: BTreeMap<u64, GridPoint>,
    clock: u64,
    cap: Option<usize>,
}

impl PointCache {
    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Cached result, bumping the entry's recency. While unbounded
    /// (the common configuration) recency is not tracked at all, so
    /// the hot memoization path pays no BTreeMap churn; [`set_cap`]
    /// rebuilds the bookkeeping if a bound arrives later.
    ///
    /// [`set_cap`]: PointCache::set_cap
    fn get_touch(&mut self, p: &GridPoint) -> Option<PointResult> {
        if self.cap.is_none() {
            return self.map.get(p).map(|(r, _)| r.clone());
        }
        let stamp = self.tick();
        let (r, s) = self.map.get_mut(p)?;
        let old = std::mem::replace(s, stamp);
        let out = r.clone();
        self.order.remove(&old);
        self.order.insert(stamp, *p);
        Some(out)
    }

    /// Presence check without touching recency (cheap: no clone, no
    /// reordering — the executor probes every grid point up front).
    fn peek(&self, p: &GridPoint) -> bool {
        self.map.contains_key(p)
    }

    fn insert(&mut self, r: PointResult) {
        if self.cap.is_none() {
            self.map.insert(r.point, (r, 0));
            return;
        }
        let stamp = self.tick();
        let point = r.point;
        if let Some((_, old)) = self.map.insert(point, (r, stamp)) {
            self.order.remove(&old);
        }
        self.order.insert(stamp, point);
        self.trim();
    }

    /// Insert only when absent (merge semantics: in-memory entries
    /// win). Returns whether the entry was inserted.
    fn insert_if_absent(&mut self, r: PointResult) -> bool {
        if self.map.contains_key(&r.point) {
            return false;
        }
        self.insert(r);
        true
    }

    fn trim(&mut self) {
        if let Some(cap) = self.cap {
            while self.map.len() > cap {
                let (&oldest, &victim) =
                    self.order.iter().next().expect("order tracks map");
                self.order.remove(&oldest);
                self.map.remove(&victim);
            }
        }
    }

    fn set_cap(&mut self, cap: Option<usize>) {
        let rebuild = cap.is_some() && self.cap.is_none();
        self.cap = cap;
        if rebuild {
            // Recency was not tracked while unbounded; seed every
            // resident entry with a fresh (arbitrary-order) stamp.
            self.order.clear();
            let points: Vec<GridPoint> = self.map.keys().copied().collect();
            for p in points {
                self.clock += 1;
                let stamp = self.clock;
                if let Some((_, s)) = self.map.get_mut(&p) {
                    *s = stamp;
                }
                self.order.insert(stamp, p);
            }
        } else if cap.is_none() {
            self.order.clear();
        }
        self.trim();
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn clear(&mut self) {
        self.map.clear();
        self.order.clear();
        self.clock = 0;
    }

    fn snapshot(&self) -> Vec<PointResult> {
        self.map.values().map(|(r, _)| r.clone()).collect()
    }

    /// Clone only the results for `wanted` points (cheaper than
    /// [`snapshot`] + filter: nothing outside the set is cloned).
    ///
    /// [`snapshot`]: PointCache::snapshot
    fn snapshot_for(&self, wanted: &HashSet<GridPoint>) -> Vec<PointResult> {
        self.map
            .iter()
            .filter(|(p, _)| wanted.contains(p))
            .map(|(_, (r, _))| r.clone())
            .collect()
    }
}

/// Outcome of merging a serialized cache document into a [`Memo`] —
/// the shard-exchange accounting the serve subsystem reports back to
/// workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MergeStats {
    /// Entries newly inserted.
    pub accepted: usize,
    /// Entries skipped because the key is already resident (in-memory
    /// results are never clobbered).
    pub skipped: usize,
    /// Entries rejected by payload-hash / sanity checks.
    pub rejected: usize,
    /// False when the document's model version mismatches
    /// [`MODEL_VERSION`]; nothing is merged in that case.
    pub version_ok: bool,
}

impl MergeStats {
    /// Entries the document carried (every one is accounted for in
    /// exactly one bucket — the invariant the scheduler and the
    /// partial-merge tests lean on).
    pub fn total(&self) -> usize {
        self.accepted + self.skipped + self.rejected
    }
}

/// The memoization cache. One [`global`] instance backs the analysis
/// and report paths; tests and benches create private instances to get
/// isolated solve/eval counters.
#[derive(Default)]
pub struct Memo {
    circuit: Mutex<HashMap<CircuitKey, TunedConfig>>,
    traffic: Mutex<HashMap<TrafficKey, Arc<BatchLine>>>,
    points: Mutex<PointCache>,
    solves: AtomicU64,
    traffic_builds: AtomicU64,
    evals: AtomicU64,
}

/// Key of the traffic sub-memo: resolved zoo name + phase — no batch,
/// no capacity (see [`BatchLine::at_capacity`]).
type TrafficKey = (&'static str, Phase);

impl Memo {
    pub fn new() -> Self {
        Memo::default()
    }

    /// A memo whose point layer is LRU-bounded to `cap` entries (the
    /// `--memo-cap` bound; the small circuit layer is never evicted).
    pub fn with_capacity(cap: usize) -> Self {
        let m = Memo::default();
        m.points.lock().unwrap().cap = Some(cap);
        m
    }

    /// (Re)bound the point layer; `None` removes the bound. Shrinking
    /// below the current population evicts least-recently-used entries
    /// immediately.
    pub fn set_point_capacity(&self, cap: Option<usize>) {
        self.points.lock().unwrap().set_cap(cap);
    }

    /// The point layer's LRU bound, if any.
    pub fn point_capacity(&self) -> Option<usize> {
        self.points.lock().unwrap().cap
    }

    /// EDAP-optimal cache at (tech, capacity) on the default 16 nm
    /// node, solving on a cache miss.
    pub fn tuned(&self, tech: MemTech, capacity_bytes: u64) -> TunedConfig {
        self.tuned_at(tech, capacity_bytes, 16).expect("16 nm is calibrated")
    }

    /// As [`Memo::tuned`] with an explicit process node. Returns a
    /// typed error for uncalibrated nodes — spec expansion and the
    /// serve routes validate earlier, but a corrupt or hostile body
    /// that slips through must degrade to an error response, never
    /// kill a worker thread.
    pub fn tuned_at(
        &self,
        tech: MemTech,
        capacity_bytes: u64,
        node_nm: u32,
    ) -> Result<TunedConfig, UncalibratedNode> {
        let key = CircuitKey { tech, capacity_bytes, node_nm };
        let cached = {
            let wait = Instant::now();
            let map = self.circuit.lock().unwrap();
            OBS_LOCK_WAIT_NS.record_duration(wait.elapsed());
            map.get(&key).copied()
        };
        if let Some(c) = cached {
            OBS_CIRCUIT_HITS.inc();
            return Ok(c);
        }
        OBS_CIRCUIT_MISSES.inc();
        // Solve outside the lock so distinct keys solve concurrently.
        // A racing duplicate solve is possible but harmless: the solver
        // is deterministic and the first insert wins.
        let solved = {
            let _span = Span::enter("circuit.solve");
            OBS_SOLVE_NS.time(|| tuned_cache_at(tech, capacity_bytes, node_nm))?
        };
        self.solves.fetch_add(1, Ordering::Relaxed);
        OBS_SOLVES.inc();
        Ok(*self.circuit.lock().unwrap().entry(key).or_insert(solved))
    }

    /// EDAP-optimal design for a tech-axis *selection*. Pure
    /// selections are plain [`Memo::tuned_at`] queries. Hybrid
    /// selections compose from the two cached pure partner solves via
    /// [`compose_ppa`] — the circuit layer stays pure-tech only, so a
    /// hybrid point never costs a solve of its own and never parks an
    /// entry the merge path could not re-derive. The returned config
    /// carries the NVM partner's organization (the array geometry is
    /// shared) with the composed PPA.
    pub fn tuned_sel_at(
        &self,
        sel: TechSel,
        capacity_bytes: u64,
        node_nm: u32,
    ) -> Result<TunedConfig, UncalibratedNode> {
        match sel {
            TechSel::Pure(t) => self.tuned_at(t, capacity_bytes, node_nm),
            TechSel::Hybrid(h) => {
                let s = self.tuned_at(MemTech::Sram, capacity_bytes, node_nm)?;
                let n = self.tuned_at(h.nvm, capacity_bytes, node_nm)?;
                let ppa = compose_ppa(&s.ppa, &n.ppa, h.sram_ways as u32, h.steer());
                Ok(TunedConfig { ppa, ..n })
            }
        }
    }

    /// Whether a circuit solve is already cached for this key.
    pub fn has_circuit(&self, tech: MemTech, capacity_bytes: u64, node_nm: u32) -> bool {
        let key = CircuitKey { tech, capacity_bytes, node_nm };
        self.circuit.lock().unwrap().contains_key(&key)
    }

    /// The closed-form batch-traffic line of `(dnn, phase)`, lowering
    /// the workload's GEMMs on the first query only. `dnn` must be a
    /// resolved zoo name (spec expansion, the serve routes and
    /// [`point_from_json`] all resolve before reaching here). Every
    /// batch and every cache capacity evaluates against the same line,
    /// so a wide `--batches` sweep performs exactly one traffic build
    /// per workload x phase.
    pub fn traffic_line(&self, dnn: &'static str, phase: Phase) -> Arc<BatchLine> {
        let key: TrafficKey = (dnn, phase);
        {
            let wait = Instant::now();
            let map = self.traffic.lock().unwrap();
            OBS_LOCK_WAIT_NS.record_duration(wait.elapsed());
            if let Some(line) = map.get(&key) {
                OBS_TRAFFIC_HITS.inc();
                return line.clone();
            }
        }
        // Resolve OUTSIDE the lock: an unresolved name panics this
        // call only, instead of poisoning the shared Mutex for every
        // other worker thread.
        let net = Dnn::by_name(dnn).expect("traffic lines are keyed by resolved zoo names");
        // The build itself happens under a re-checked lock: lowering
        // is O(layers) and re-entrancy-free, and the re-check keeps
        // `traffic_build_count` exact — at most one build per key even
        // with racing workers, which is what the batch-sweep bench
        // gate measures.
        let mut map = self.traffic.lock().unwrap();
        if let Some(line) = map.get(&key) {
            OBS_TRAFFIC_HITS.inc();
            return line.clone();
        }
        let line = {
            let _span = Span::enter("traffic.lower");
            Arc::new(TrafficModel::default().line(&net, phase))
        };
        self.traffic_builds.fetch_add(1, Ordering::Relaxed);
        OBS_TRAFFIC_BUILDS.inc();
        map.insert(key, line.clone());
        line
    }

    /// Whether a traffic line is already cached for `(dnn, phase)`.
    pub fn has_traffic_line(&self, dnn: &str, phase: Phase) -> bool {
        self.traffic
            .lock()
            .unwrap()
            .keys()
            .any(|(d, p)| *d == dnn && *p == phase)
    }

    /// Cached full grid-point result, if any (bumps LRU recency).
    pub fn cached_point(&self, p: &GridPoint) -> Option<PointResult> {
        let hit = self.points.lock().unwrap().get_touch(p);
        if hit.is_some() {
            OBS_POINT_HITS.inc();
        } else {
            OBS_POINT_MISSES.inc();
        }
        hit
    }

    /// Whether a grid-point result is already cached (cheaper than
    /// [`Memo::cached_point`]: no clone, recency untouched).
    pub fn has_point(&self, p: &GridPoint) -> bool {
        self.points.lock().unwrap().peek(p)
    }

    /// Record a freshly evaluated grid point (counts as one traffic-
    /// model evaluation).
    pub fn record_point(&self, r: PointResult) {
        self.evals.fetch_add(1, Ordering::Relaxed);
        OBS_EVALS.inc();
        self.points.lock().unwrap().insert(r);
    }

    /// Circuit-model solves performed (not served from cache).
    pub fn solve_count(&self) -> u64 {
        self.solves.load(Ordering::Relaxed)
    }

    /// Traffic-coefficient builds performed (not served from cache) —
    /// the number the batch-sweep bench gates at one per
    /// `(dnn, phase)`.
    pub fn traffic_build_count(&self) -> u64 {
        self.traffic_builds.load(Ordering::Relaxed)
    }

    /// Grid-point evaluations performed (not served from cache).
    pub fn eval_count(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    pub fn circuit_len(&self) -> usize {
        self.circuit.lock().unwrap().len()
    }

    pub fn traffic_len(&self) -> usize {
        self.traffic.lock().unwrap().len()
    }

    pub fn point_len(&self) -> usize {
        self.points.lock().unwrap().len()
    }

    /// Drop all cached entries and zero the counters (the LRU bound is
    /// kept).
    pub fn clear(&self) {
        self.circuit.lock().unwrap().clear();
        self.traffic.lock().unwrap().clear();
        self.points.lock().unwrap().clear();
        self.solves.store(0, Ordering::Relaxed);
        self.traffic_builds.store(0, Ordering::Relaxed);
        self.evals.store(0, Ordering::Relaxed);
    }

    /// Serialize all three layers (entries sorted for diffable
    /// output).
    pub fn to_json(&self) -> Json {
        let circuit: Vec<(CircuitKey, TunedConfig)> = self
            .circuit
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (*k, *v))
            .collect();
        let traffic: Vec<(TrafficKey, Arc<BatchLine>)> = self
            .traffic
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (*k, v.clone()))
            .collect();
        let points = self.points.lock().unwrap().snapshot();
        assemble_doc(circuit, traffic, points)
    }

    /// Serialize only the entries answering `wanted` grid points: each
    /// point's own result plus the circuit solves it depends on (its
    /// (tech, capacity, node), and the SRAM baseline for workload
    /// points). This is the shard-sized export `POST /shard/run` ships
    /// back — O(shard), even when the resident memo holds the whole
    /// paper grid from `--prewarm` or earlier shards.
    pub fn to_json_for(&self, wanted: &[GridPoint]) -> Json {
        let mut pset: HashSet<GridPoint> = HashSet::new();
        let mut cset: HashSet<CircuitKey> = HashSet::new();
        let mut tset: HashSet<TrafficKey> = HashSet::new();
        for p in wanted {
            pset.insert(*p);
            let bytes = p.capacity_mb * MB;
            for tech in p.tech.circuit_deps() {
                cset.insert(CircuitKey {
                    tech,
                    capacity_bytes: bytes,
                    node_nm: p.node_nm,
                });
            }
            if let Some(w) = p.workload {
                cset.insert(CircuitKey {
                    tech: MemTech::Sram,
                    capacity_bytes: bytes,
                    node_nm: p.node_nm,
                });
                tset.insert((w.dnn, w.phase));
            }
        }
        let circuit: Vec<(CircuitKey, TunedConfig)> = self
            .circuit
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| cset.contains(k))
            .map(|(k, v)| (*k, *v))
            .collect();
        let traffic: Vec<(TrafficKey, Arc<BatchLine>)> = self
            .traffic
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| tset.contains(k))
            .map(|(k, v)| (*k, v.clone()))
            .collect();
        let points = self.points.lock().unwrap().snapshot_for(&pset);
        assemble_doc(circuit, traffic, points)
    }

    /// Merge entries from a serialized cache. Returns how many entries
    /// were accepted; a version mismatch ignores the whole document.
    /// Shorthand for [`Memo::merge_json`]`.accepted`.
    pub fn load_json(&self, doc: &Json) -> usize {
        self.merge_json(doc).accepted
    }

    /// Merge entries from a serialized cache document (the on-disk
    /// `sweep_memo.json` format, which is also the shard-exchange wire
    /// format of `GET /memo/export` / `POST /memo/merge`), with full
    /// per-entry accounting.
    ///
    /// In-memory entries take precedence: freshly computed results are
    /// never clobbered by what arrives (this is what makes
    /// `--cold`-then-persist extend the cache rather than let stale
    /// disk entries overwrite the recomputation, and what lets a
    /// coordinator union shard caches in any order). Entries whose
    /// stored payload hash does not match their re-serialized content
    /// — or whose values fail basic sanity (non-finite/non-positive
    /// PPA, inconsistent organization, non-integer traffic
    /// coefficients) — are rejected.
    pub fn merge_json(&self, doc: &Json) -> MergeStats {
        let mut st = MergeStats { version_ok: true, ..MergeStats::default() };
        let version = doc.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        if version as u32 != MODEL_VERSION {
            st.version_ok = false;
            return st;
        }
        if let Some(entries) = doc.get("circuit").and_then(Json::as_arr) {
            for e in entries {
                let parsed = e
                    .get("node_nm")
                    .and_then(Json::as_f64)
                    .zip(e.get("tuned").and_then(tuned_from_json));
                let Some((node, t)) = parsed else {
                    st.rejected += 1;
                    continue;
                };
                // A node outside the calibrated set could never be
                // re-derived locally; reject it instead of caching an
                // unverifiable entry. (The f64 -> u32 cast saturates,
                // so 2^32 + 16 cannot alias to 16 nm either.)
                if node < 0.0 || node > u32::MAX as f64 || !node_calibrated(node as u32) {
                    st.rejected += 1;
                    continue;
                }
                // Integrity: the stored hash must match the payload as
                // the reconstructed config re-serializes it, node id
                // included (a relabeled node must not verify).
                let expect = circuit_payload_hash(node as u32, &tuned_to_json(&t));
                if e.get("payload_hash").and_then(Json::as_str) != Some(expect.as_str()) {
                    st.rejected += 1;
                    continue;
                }
                let key = CircuitKey {
                    tech: t.tech,
                    capacity_bytes: t.capacity_bytes,
                    node_nm: node as u32,
                };
                let mut map = self.circuit.lock().unwrap();
                if map.contains_key(&key) {
                    st.skipped += 1;
                } else {
                    map.insert(key, t);
                    st.accepted += 1;
                }
            }
        }
        if let Some(entries) = doc.get("traffic").and_then(Json::as_arr) {
            for e in entries {
                // Key: a resolvable zoo workload + phase. An unknown
                // workload could never be re-derived locally.
                let parsed = e
                    .get("dnn")
                    .and_then(Json::as_str)
                    .and_then(|d| resolve_dnn(d).ok())
                    .zip(
                        e.get("phase")
                            .and_then(Json::as_str)
                            .and_then(|p| parse_phase(p).ok()),
                    )
                    .zip(e.get("line").and_then(line_from_json));
                let Some(((dnn, phase), line)) = parsed else {
                    st.rejected += 1;
                    continue;
                };
                // Value sanity: the payload hash is a content address,
                // not a MAC, so bound the coefficients to the
                // derivable range (see [`line_sane`]) before trusting
                // them — a pa = 0 term would underflow spill math.
                if !line_sane(&line) {
                    st.rejected += 1;
                    continue;
                }
                // Integrity: the stored hash must match the coefficient
                // payload as the reconstructed line re-serializes it,
                // workload key included (a relabeled dnn or phase must
                // not verify — it would poison that workload's whole
                // batch axis).
                let expect = traffic_payload_hash(dnn, phase, &line_to_json(&line));
                if e.get("payload_hash").and_then(Json::as_str) != Some(expect.as_str()) {
                    st.rejected += 1;
                    continue;
                }
                let mut map = self.traffic.lock().unwrap();
                if map.contains_key(&(dnn, phase)) {
                    st.skipped += 1;
                } else {
                    map.insert((dnn, phase), Arc::new(line));
                    st.accepted += 1;
                }
            }
        }
        if let Some(entries) = doc.get("points").and_then(Json::as_arr) {
            for e in entries {
                let Some(r) = point_from_json(e) else {
                    st.rejected += 1;
                    continue;
                };
                if !node_calibrated(r.point.node_nm) {
                    st.rejected += 1;
                    continue;
                }
                // Content checks: identity key + hash, and the payload
                // hash over the re-serialized result values.
                let expect_key = r.point.key();
                let expect_hash = format!("{:016x}", r.point.key_hash());
                let expect_payload = point_payload_hash(&r);
                if e.get("key").and_then(Json::as_str) != Some(expect_key.as_str())
                    || e.get("hash").and_then(Json::as_str) != Some(expect_hash.as_str())
                    || e.get("payload_hash").and_then(Json::as_str)
                        != Some(expect_payload.as_str())
                {
                    st.rejected += 1;
                    continue;
                }
                if self.points.lock().unwrap().insert_if_absent(r) {
                    st.accepted += 1;
                } else {
                    st.skipped += 1;
                }
            }
        }
        st
    }

    /// Persist to `sweep_memo.json` in the store's directory.
    pub fn save_to(&self, store: &Store) -> Result<PathBuf> {
        store.save_blob(MEMO_FILE, &self.to_json().to_pretty())
    }

    /// Warm from a previously persisted cache, if present. Returns the
    /// number of entries loaded (0 when absent or version-stale).
    pub fn load_from(&self, store: &Store) -> Result<usize> {
        match store.read_blob(MEMO_FILE)? {
            Some(text) => Ok(self.load_json(&json::parse(&text)?)),
            None => Ok(0),
        }
    }
}

/// The process-wide cache behind the analysis and report paths, so
/// `deepnvm all` solves each (tech, capacity) exactly once across every
/// figure it generates.
pub fn global() -> &'static Memo {
    static GLOBAL: OnceLock<Memo> = OnceLock::new();
    GLOBAL.get_or_init(Memo::new)
}

/// Shorthand for `global().tuned(...)` — the drop-in replacement for
/// `nvsim::explorer::tuned_cache` on analysis paths.
pub fn tuned(tech: MemTech, capacity_bytes: u64) -> TunedConfig {
    global().tuned(tech, capacity_bytes)
}

/// Assemble the cache document from entry snapshots (shared by the
/// full [`Memo::to_json`] and the filtered [`Memo::to_json_for`];
/// entries are sorted so output is diffable).
fn assemble_doc(
    mut circuit: Vec<(CircuitKey, TunedConfig)>,
    mut traffic: Vec<(TrafficKey, Arc<BatchLine>)>,
    mut points: Vec<PointResult>,
) -> Json {
    let mut root = Json::obj();
    root.set("version", Json::Num(MODEL_VERSION as f64));
    circuit.sort_by_key(|(k, _)| (k.tech.name(), k.capacity_bytes, k.node_nm));
    let centries: Vec<Json> = circuit
        .iter()
        .map(|(k, t)| {
            let tuned = tuned_to_json(t);
            let mut e = Json::obj();
            e.set("node_nm", Json::Num(k.node_nm as f64));
            e.set(
                "payload_hash",
                Json::Str(circuit_payload_hash(k.node_nm, &tuned)),
            );
            e.set("tuned", tuned);
            e
        })
        .collect();
    root.set("circuit", Json::Arr(centries));
    traffic.sort_by_key(|(k, _)| (k.0, k.1.name()));
    let tentries: Vec<Json> = traffic
        .iter()
        .map(|(k, line)| traffic_to_json(k.0, k.1, line))
        .collect();
    root.set("traffic", Json::Arr(tentries));
    points.sort_by_key(|r| r.point.key());
    let pentries: Vec<Json> = points.iter().map(point_to_json).collect();
    root.set("points", Json::Arr(pentries));
    root
}

/// Content hash of a serialized payload (the tamper check for on-disk
/// entries; stable because `Json` serialization is deterministic).
fn payload_hash(j: &Json) -> String {
    format!("{:016x}", fnv1a64(&j.to_string()))
}

/// Payload hash of a circuit entry: the tuned config *bound to its
/// process node*. `TunedConfig` itself carries no node, so hashing the
/// config alone would let a relabeled entry (7 nm rewritten to 5 nm)
/// pass the integrity check and poison the other node's cache.
fn circuit_payload_hash(node_nm: u32, tuned: &Json) -> String {
    let mut payload = Json::obj();
    payload.set("node_nm", Json::Num(node_nm as f64));
    payload.set("tuned", tuned.clone());
    payload_hash(&payload)
}

/// Payload hash of a traffic entry: the coefficient payload *bound to
/// its workload key*. A [`BatchLine`] carries no workload identity of
/// its own, so hashing the coefficients alone would let an AlexNet
/// line relabeled as VGG-16 (or inference relabeled as training) pass
/// the integrity check and poison the whole batch axis of that
/// workload.
fn traffic_payload_hash(dnn: &str, phase: Phase, line: &Json) -> String {
    let mut payload = Json::obj();
    payload.set("dnn", Json::Str(dnn.to_string()));
    payload.set("phase", Json::Str(phase.name().to_string()));
    payload.set("line", line.clone());
    payload_hash(&payload)
}

/// Serialize one closed-form transaction term as a fixed-shape array
/// `[base, slope, ceil_mult, ceil_unit]`.
fn tx_term_to_json(t: &TxTerm) -> Json {
    Json::Arr(
        [t.base, t.slope, t.ceil_mult, t.ceil_unit]
            .iter()
            .map(|&v| Json::Num(v as f64))
            .collect(),
    )
}

/// Parse `[base, slope, ceil_mult, ceil_unit]` back, rejecting
/// anything that is not an exact non-negative integer (coefficients
/// are element counts; a fractional or negative value can only be
/// corruption).
fn tx_term_from_json(j: &Json) -> Option<TxTerm> {
    let a = j.as_arr()?;
    if a.len() != 4 {
        return None;
    }
    Some(TxTerm {
        base: a[0].as_u64()?,
        slope: a[1].as_u64()?,
        ceil_mult: a[2].as_u64()?,
        ceil_unit: a[3].as_u64()?,
    })
}

/// Serialize one DRAM spill term as
/// `[a_base, a_slope, b_base, b_slope, c_base, c_slope, pa, pb_const, pb_unit]`.
fn dram_term_to_json(t: &DramTerm) -> Json {
    Json::Arr(
        [
            t.a_base, t.a_slope, t.b_base, t.b_slope, t.c_base, t.c_slope,
            t.pa, t.pb_const, t.pb_unit,
        ]
        .iter()
        .map(|&v| Json::Num(v as f64))
        .collect(),
    )
}

fn dram_term_from_json(j: &Json) -> Option<DramTerm> {
    let a = j.as_arr()?;
    if a.len() != 9 {
        return None;
    }
    Some(DramTerm {
        a_base: a[0].as_u64()?,
        a_slope: a[1].as_u64()?,
        b_base: a[2].as_u64()?,
        b_slope: a[3].as_u64()?,
        c_base: a[4].as_u64()?,
        c_slope: a[5].as_u64()?,
        pa: a[6].as_u64()?,
        pb_const: a[7].as_u64()?,
        pb_unit: a[8].as_u64()?,
    })
}

/// Per-field magnitude bound for merged traffic coefficients: ~23x
/// the largest coefficient the zoo can derive (~5e10), and small
/// enough that every *intermediate* u64 product in term evaluation
/// (`slope * b`, `ceil_unit * b`, `macs_slope * b`) stays in range for
/// batches up to [`MAX_SANE_BATCH`].
const MAX_TRAFFIC_COEFF: u64 = 1 << 40;

/// The batch ceiling merged lines are sanity-evaluated at — the same
/// [`super::spec::MAX_BATCH`] every untrusted entry point (spec
/// expansion, the serve `/solve` body) enforces, so no accepted batch
/// can exceed what the gate proved. Real zoo lines peak around 2^46
/// elems here, a thousandfold under [`MAX_SANE_ELEMS`].
const MAX_SANE_BATCH: u128 = super::spec::MAX_BATCH as u128;

/// Element/byte ceiling for one term evaluated at [`MAX_SANE_BATCH`]:
/// totals below this keep the u64 arithmetic of [`TxTerm::at`] /
/// [`DramTerm::at`] (including the `* ELEM` and 3x spill factors)
/// overflow-free for every batch up to the ceiling, since all terms
/// are monotone in `b`.
const MAX_SANE_ELEMS: u128 = 1 << 56;

fn tx_term_sane(t: &TxTerm) -> bool {
    let fields_ok = [t.base, t.slope, t.ceil_mult, t.ceil_unit]
        .iter()
        .all(|&v| v < MAX_TRAFFIC_COEFF);
    // Per-field bounds alone do not bound the ceil_mult * ceil(...)
    // PRODUCT; evaluate the whole term in u128 at the batch ceiling.
    let elems = t.base as u128
        + t.slope as u128 * MAX_SANE_BATCH
        + t.ceil_mult as u128
            * (t.ceil_unit as u128 * MAX_SANE_BATCH).div_ceil(SUPERTILE as u128);
    fields_ok && elems < MAX_SANE_ELEMS
}

/// A derivable DRAM term always has `pa = ceil(N/T) >= 1` and a B
/// stream that is either constant (`pb_const >= 1`) or symbolic
/// (`pb_unit >= 1`) — `pa = 0` would underflow the `(pa - 1)` spill
/// pass count in [`DramTerm::at`]. Operand footprints are checked in
/// u128 at the batch ceiling (the spill path multiplies them by at
/// most 4 overall).
fn dram_term_sane(d: &DramTerm) -> bool {
    let fields_ok = [
        d.a_base, d.a_slope, d.b_base, d.b_slope, d.c_base, d.c_slope,
        d.pa, d.pb_const, d.pb_unit,
    ]
    .iter()
    .all(|&v| v < MAX_TRAFFIC_COEFF);
    let footprint_ok = [
        (d.a_base, d.a_slope),
        (d.b_base, d.b_slope),
        (d.c_base, d.c_slope),
    ]
    .iter()
    .all(|&(base, slope)| {
        base as u128 + slope as u128 * MAX_SANE_BATCH < MAX_SANE_ELEMS
    });
    fields_ok && footprint_ok && d.pa >= 1 && (d.pb_const >= 1 || d.pb_unit >= 1)
}

/// Term-count ceiling per coefficient vector. The deepest zoo network
/// lowers to under 200 terms; the cap also bounds the evaluation SUM —
/// 2^9 terms x at most 2^53 transactions each stays under 2^62, so the
/// u64 accumulators in [`BatchLine::at_capacity`] cannot wrap — and
/// caps per-entry memory in the merge path.
const MAX_TRAFFIC_TERMS: usize = 512;

/// Value-level sanity of a merged batch line (the traffic counterpart
/// of [`ppa_sane`]): the payload hash is a content address, not a MAC,
/// so this gate is what keeps a hash-consistent hostile document from
/// parking terms whose arithmetic underflows or overflows.
fn line_sane(l: &BatchLine) -> bool {
    l.l2_reads.len() <= MAX_TRAFFIC_TERMS
        && l.l2_writes.len() <= MAX_TRAFFIC_TERMS
        && l.streams.len() <= MAX_TRAFFIC_TERMS
        && l.dram.len() <= MAX_TRAFFIC_TERMS
        && l.l2_reads
            .iter()
            .chain(&l.l2_writes)
            .chain(&l.streams)
            .all(tx_term_sane)
        && l.dram.iter().all(dram_term_sane)
        && l.const_reads < MAX_TRAFFIC_COEFF
        && l.const_writes < MAX_TRAFFIC_COEFF
        && l.macs_slope < MAX_TRAFFIC_COEFF
        && l.macs_slope >= 1
}

/// Serialize a [`BatchLine`]'s coefficient payload.
fn line_to_json(line: &BatchLine) -> Json {
    let terms = |ts: &[TxTerm]| Json::Arr(ts.iter().map(tx_term_to_json).collect());
    let mut o = Json::obj();
    o.set("l2_bytes", Json::Num(line.l2_bytes as f64));
    o.set("l2_reads", terms(&line.l2_reads));
    o.set("l2_writes", terms(&line.l2_writes));
    o.set("streams", terms(&line.streams));
    o.set(
        "dram",
        Json::Arr(line.dram.iter().map(dram_term_to_json).collect()),
    );
    o.set("const_reads", Json::Num(line.const_reads as f64));
    o.set("const_writes", Json::Num(line.const_writes as f64));
    o.set("macs_slope", Json::Num(line.macs_slope as f64));
    o
}

fn line_from_json(j: &Json) -> Option<BatchLine> {
    let terms = |key: &str| -> Option<Vec<TxTerm>> {
        j.get(key)?.as_arr()?.iter().map(tx_term_from_json).collect()
    };
    Some(BatchLine {
        l2_bytes: j.get("l2_bytes")?.as_u64()?,
        l2_reads: terms("l2_reads")?,
        l2_writes: terms("l2_writes")?,
        streams: terms("streams")?,
        dram: j
            .get("dram")?
            .as_arr()?
            .iter()
            .map(dram_term_from_json)
            .collect::<Option<Vec<_>>>()?,
        const_reads: j.get("const_reads")?.as_u64()?,
        const_writes: j.get("const_writes")?.as_u64()?,
        macs_slope: j.get("macs_slope")?.as_u64()?,
    })
}

/// Serialize one traffic entry — workload key, payload hash bound to
/// that key, and the coefficient payload.
pub fn traffic_to_json(dnn: &str, phase: Phase, line: &BatchLine) -> Json {
    let payload = line_to_json(line);
    let mut e = Json::obj();
    e.set("dnn", Json::Str(dnn.to_string()));
    e.set("phase", Json::Str(phase.name().to_string()));
    e.set(
        "payload_hash",
        Json::Str(traffic_payload_hash(dnn, phase, &payload)),
    );
    e.set("line", payload);
    e
}

/// All PPA terms must be finite and positive for a cached design to be
/// credible.
fn ppa_sane(p: &CachePpa) -> bool {
    [
        p.read_latency,
        p.write_latency,
        p.read_energy,
        p.write_energy,
        p.leakage_power,
        p.area,
    ]
    .into_iter()
    .all(|v| v.is_finite() && v > 0.0)
}

fn ppa_to_json(p: &CachePpa) -> Json {
    let mut o = Json::obj();
    o.set("read_latency", Json::Num(p.read_latency));
    o.set("write_latency", Json::Num(p.write_latency));
    o.set("read_energy", Json::Num(p.read_energy));
    o.set("write_energy", Json::Num(p.write_energy));
    o.set("leakage_power", Json::Num(p.leakage_power));
    o.set("area", Json::Num(p.area));
    o
}

fn ppa_from_json(j: &Json) -> Option<CachePpa> {
    Some(CachePpa {
        read_latency: j.get("read_latency")?.as_f64()?,
        write_latency: j.get("write_latency")?.as_f64()?,
        read_energy: j.get("read_energy")?.as_f64()?,
        write_energy: j.get("write_energy")?.as_f64()?,
        leakage_power: j.get("leakage_power")?.as_f64()?,
        area: j.get("area")?.as_f64()?,
    })
}

/// Serialize a tuned cache configuration (also the `tuned` payload of
/// serve's `/solve` responses).
pub fn tuned_to_json(t: &TunedConfig) -> Json {
    let mut o = Json::obj();
    o.set("tech", Json::Str(t.tech.name().to_string()));
    o.set("capacity_bytes", Json::Num(t.capacity_bytes as f64));
    o.set("opt", Json::Str(t.opt.name().to_string()));
    let mut org = Json::obj();
    org.set("banks", Json::Num(t.org.banks as f64));
    org.set("mats_per_bank", Json::Num(t.org.mats_per_bank as f64));
    org.set("rows", Json::Num(t.org.rows as f64));
    org.set("cols", Json::Num(t.org.cols as f64));
    org.set("mux", Json::Num(t.org.mux as f64));
    org.set("mode", Json::Str(t.org.mode.name().to_string()));
    o.set("org", org);
    o.set("ppa", ppa_to_json(&t.ppa));
    o
}

/// Parse a tuned cache configuration back from its JSON form,
/// rejecting insane values.
pub fn tuned_from_json(j: &Json) -> Option<TunedConfig> {
    let tech = parse_tech(j.get("tech")?.as_str()?).ok()?;
    let capacity_bytes = j.get("capacity_bytes")?.as_f64()? as u64;
    let opt = OptTarget::from_name(j.get("opt")?.as_str()?)?;
    let jorg = j.get("org")?;
    let org = CacheOrg {
        capacity_bytes,
        banks: jorg.get("banks")?.as_f64()? as u32,
        mats_per_bank: jorg.get("mats_per_bank")?.as_f64()? as u32,
        rows: jorg.get("rows")?.as_f64()? as u32,
        cols: jorg.get("cols")?.as_f64()? as u32,
        mux: jorg.get("mux")?.as_f64()? as u32,
        mode: AccessMode::from_name(jorg.get("mode")?.as_str()?)?,
    };
    let ppa = ppa_from_json(j.get("ppa")?)?;
    let t = TunedConfig { tech, capacity_bytes, org, opt, ppa };
    if !ppa_sane(&t.ppa) || !t.org.is_consistent() {
        return None;
    }
    Some(t)
}

fn eval_to_json(e: &WorkloadEval) -> Json {
    let mut ev = Json::obj();
    ev.set("energy_j", Json::Num(e.energy_j));
    ev.set("time_s", Json::Num(e.time_s));
    ev.set("edp", Json::Num(e.edp));
    ev.set("energy_norm", Json::Num(e.energy_norm));
    ev.set("latency_norm", Json::Num(e.latency_norm));
    ev.set("edp_norm", Json::Num(e.edp_norm));
    ev
}

/// Payload hash of a point result: tuned config + eval values.
fn point_payload_hash(r: &PointResult) -> String {
    let mut payload = Json::obj();
    payload.set("tuned", tuned_to_json(&r.tuned));
    payload.set(
        "eval",
        match &r.eval {
            Some(e) => eval_to_json(e),
            None => Json::Null,
        },
    );
    payload_hash(&payload)
}

/// Serialize one evaluated grid point — key, content hashes, tuned
/// config and (for workload points) the projected metrics. The memo
/// file format and serve's `/solve` result body.
pub fn point_to_json(r: &PointResult) -> Json {
    let p = &r.point;
    let mut o = Json::obj();
    o.set("key", Json::Str(p.key()));
    o.set("hash", Json::Str(format!("{:016x}", p.key_hash())));
    o.set("payload_hash", Json::Str(point_payload_hash(r)));
    o.set("tech", Json::Str(p.tech.name()));
    o.set("capacity_mb", Json::Num(p.capacity_mb as f64));
    o.set("node_nm", Json::Num(p.node_nm as f64));
    match p.workload {
        Some(w) => {
            o.set("dnn", Json::Str(w.dnn.to_string()));
            o.set("phase", Json::Str(w.phase.name().to_string()));
            o.set("batch", Json::Num(w.batch as f64));
        }
        None => {
            o.set("dnn", Json::Null);
            o.set("phase", Json::Null);
            o.set("batch", Json::Null);
        }
    }
    o.set("tuned", tuned_to_json(&r.tuned));
    o.set(
        "eval",
        match &r.eval {
            Some(e) => eval_to_json(e),
            None => Json::Null,
        },
    );
    o
}

/// Parse one evaluated grid point back from its JSON form (identity
/// and payload hashes are NOT verified here — [`Memo::merge_json`]
/// does that).
pub fn point_from_json(j: &Json) -> Option<PointResult> {
    let tech = parse_tech_sel(j.get("tech")?.as_str()?).ok()?;
    let capacity_mb = j.get("capacity_mb")?.as_f64()? as u64;
    let node_nm = j.get("node_nm")?.as_f64()? as u32;
    let workload = match j.get("dnn") {
        Some(Json::Str(name)) => Some(WorkloadPoint {
            dnn: resolve_dnn(name).ok()?,
            phase: parse_phase(j.get("phase")?.as_str()?).ok()?,
            batch: j.get("batch")?.as_f64()? as usize,
        }),
        _ => None,
    };
    let point = GridPoint { tech, capacity_mb, node_nm, workload };
    let tuned = tuned_from_json(j.get("tuned")?)?;
    let eval = match j.get("eval") {
        Some(ev @ Json::Obj(_)) => Some(WorkloadEval {
            energy_j: ev.get("energy_j")?.as_f64()?,
            time_s: ev.get("time_s")?.as_f64()?,
            edp: ev.get("edp")?.as_f64()?,
            energy_norm: ev.get("energy_norm")?.as_f64()?,
            latency_norm: ev.get("latency_norm")?.as_f64()?,
            edp_norm: ev.get("edp_norm")?.as_f64()?,
        }),
        _ => None,
    };
    Some(PointResult { point, tuned, eval })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nvsim::explorer::tuned_cache;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a64(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn circuit_layer_memoizes() {
        let m = Memo::new();
        let a = m.tuned(MemTech::SttMram, 2 * MB);
        assert_eq!(m.solve_count(), 1);
        let b = m.tuned(MemTech::SttMram, 2 * MB);
        assert_eq!(m.solve_count(), 1, "second query must hit the cache");
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        m.tuned(MemTech::Sram, 2 * MB);
        assert_eq!(m.solve_count(), 2);
        assert_eq!(m.circuit_len(), 2);
        m.clear();
        assert_eq!(m.circuit_len(), 0);
        assert_eq!(m.solve_count(), 0);
    }

    #[test]
    fn memoized_result_matches_direct_solver() {
        let m = Memo::new();
        let memoized = m.tuned(MemTech::SotMram, MB);
        let direct = tuned_cache(MemTech::SotMram, MB);
        assert_eq!(format!("{memoized:?}"), format!("{direct:?}"));
    }

    #[test]
    fn tuned_config_json_roundtrip() {
        let t = tuned_cache(MemTech::SttMram, 3 * MB);
        let j = tuned_to_json(&t);
        let back = tuned_from_json(&j).expect("roundtrip");
        assert_eq!(format!("{t:?}"), format!("{back:?}"));
    }

    #[test]
    fn version_mismatch_ignored() {
        let m = Memo::new();
        m.tuned(MemTech::Sram, MB);
        let mut doc = m.to_json();
        doc.set("version", Json::Num(9999.0));
        let fresh = Memo::new();
        assert_eq!(fresh.load_json(&doc), 0);
        assert_eq!(fresh.circuit_len(), 0);
    }

    #[test]
    fn json_roundtrip_through_parser() {
        let m = Memo::new();
        m.tuned(MemTech::SttMram, MB);
        m.tuned(MemTech::Sram, 2 * MB);
        let text = m.to_json().to_pretty();
        let fresh = Memo::new();
        assert_eq!(fresh.load_json(&json::parse(&text).unwrap()), 2);
        assert_eq!(fresh.circuit_len(), 2);
        // warmed cache serves without solving
        fresh.tuned(MemTech::SttMram, MB);
        assert_eq!(fresh.solve_count(), 0);
    }

    #[test]
    fn tampered_circuit_entry_rejected() {
        let m = Memo::new();
        let t = m.tuned(MemTech::Sram, MB);
        let text = m.to_json().to_pretty();
        let hash = circuit_payload_hash(16, &tuned_to_json(&t));
        assert!(text.contains(&hash), "serialized doc must carry the payload hash");
        let tampered = text.replace(&hash, "0000000000000000");
        let fresh = Memo::new();
        assert_eq!(fresh.load_json(&json::parse(&tampered).unwrap()), 0);
        assert_eq!(fresh.circuit_len(), 0);
    }

    #[test]
    fn lru_capacity_evicts_oldest_point() {
        use crate::sweep::evaluate_point;
        use crate::sweep::spec::GridPoint;

        let m = Memo::with_capacity(2);
        assert_eq!(m.point_capacity(), Some(2));
        let pt = |mb| GridPoint {
            tech: MemTech::Sram.into(),
            capacity_mb: mb,
            node_nm: 16,
            workload: None,
        };
        let (a, b, c) = (pt(1), pt(2), pt(3));
        evaluate_point(&a, &m).unwrap();
        evaluate_point(&b, &m).unwrap();
        // touch `a` so `b` becomes least recently used
        assert!(m.cached_point(&a).is_some());
        evaluate_point(&c, &m).unwrap();
        assert_eq!(m.point_len(), 2, "cap must hold");
        assert!(m.has_point(&a), "recently touched entry must survive");
        assert!(!m.has_point(&b), "LRU entry must be evicted");
        assert!(m.has_point(&c));
        // the circuit layer is never evicted
        assert_eq!(m.circuit_len(), 3);

        // shrinking the bound trims immediately
        m.set_point_capacity(Some(1));
        assert_eq!(m.point_len(), 1);
        // lifting it allows regrowth
        m.set_point_capacity(None);
        evaluate_point(&b, &m).unwrap();
        evaluate_point(&a, &m).unwrap();
        assert_eq!(m.point_len(), 3);
        // bounding a previously unbounded cache (where recency was not
        // tracked) still trims to the cap
        m.set_point_capacity(Some(2));
        assert_eq!(m.point_len(), 2);
        m.cached_point(&m_resident(&m, &[a, b, c])).unwrap();
    }

    /// First of `candidates` still resident in `m`.
    fn m_resident(
        m: &Memo,
        candidates: &[crate::sweep::spec::GridPoint],
    ) -> crate::sweep::spec::GridPoint {
        *candidates.iter().find(|p| m.has_point(p)).expect("one resident")
    }

    #[test]
    fn capped_serialization_stays_bounded() {
        use crate::sweep::evaluate_point;
        use crate::sweep::spec::GridPoint;

        let m = Memo::with_capacity(1);
        for mb in 1..=3u64 {
            evaluate_point(
                &GridPoint {
                    tech: MemTech::SttMram.into(),
                    capacity_mb: mb,
                    node_nm: 16,
                    workload: None,
                },
                &m,
            )
            .unwrap();
        }
        let doc = m.to_json();
        assert_eq!(doc.get("points").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn filtered_export_is_shard_scoped_and_self_sufficient() {
        use crate::sweep::spec::{GridPoint, WorkloadPoint};
        use crate::workload::models::Phase;

        // resident memo: a workload point at 1 MB plus unrelated
        // circuit-only points at 2 and 3 MB
        let m = Memo::new();
        let wl = GridPoint {
            tech: MemTech::SttMram.into(),
            capacity_mb: 1,
            node_nm: 16,
            workload: Some(WorkloadPoint {
                dnn: "AlexNet",
                phase: Phase::Inference,
                batch: 4,
            }),
        };
        crate::sweep::evaluate_point(&wl, &m).unwrap();
        for mb in [2u64, 3] {
            crate::sweep::evaluate_point(
                &GridPoint {
                    tech: MemTech::SotMram.into(),
                    capacity_mb: mb,
                    node_nm: 16,
                    workload: None,
                },
                &m,
            )
            .unwrap();
        }
        assert_eq!(m.point_len(), 3);
        assert_eq!(m.circuit_len(), 4, "stt@1 + sram@1 baseline + sot@2 + sot@3");
        assert_eq!(m.traffic_len(), 1, "one batch line for AlexNet inference");

        // the filtered export carries only the wanted point and its
        // dependencies — the circuit solves (including the SRAM
        // baseline) and the workload's traffic line
        let doc = m.to_json_for(&[wl]);
        assert_eq!(doc.get("points").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(doc.get("circuit").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(doc.get("traffic").unwrap().as_arr().unwrap().len(), 1);

        // and it is self-sufficient: a fresh memo merged from it
        // replays the point with zero solves, zero evals and zero
        // traffic lowerings
        let fresh = Memo::new();
        let st = fresh.merge_json(&doc);
        assert!(st.version_ok);
        assert_eq!((st.accepted, st.rejected), (4, 0));
        crate::sweep::evaluate_point(&wl, &fresh).unwrap();
        assert_eq!(fresh.solve_count(), 0);
        assert_eq!(fresh.eval_count(), 0);
        assert_eq!(fresh.traffic_build_count(), 0);
    }

    #[test]
    fn merge_json_accounts_for_every_entry() {
        let a = Memo::new();
        a.tuned(MemTech::Sram, MB);
        a.tuned(MemTech::SttMram, MB);
        let doc = a.to_json();

        // fresh memo: everything accepted
        let fresh = Memo::new();
        let st = fresh.merge_json(&doc);
        assert!(st.version_ok);
        assert_eq!(st.accepted, 2);
        assert_eq!(st.skipped, 0);
        assert_eq!(st.rejected, 0);

        // idempotent re-merge: everything skipped
        let st = fresh.merge_json(&doc);
        assert_eq!((st.accepted, st.skipped, st.rejected), (0, 2, 0));
        assert_eq!(st.total(), 2, "every entry lands in exactly one bucket");

        // tampered hash: rejected, not silently dropped
        let t = a.tuned(MemTech::Sram, MB);
        let text = doc.to_pretty();
        let hash = circuit_payload_hash(16, &tuned_to_json(&t));
        let tampered = text.replace(&hash, "ffffffffffffffff");
        let st = Memo::new().merge_json(&json::parse(&tampered).unwrap());
        assert_eq!(st.accepted, 1);
        assert_eq!(st.rejected, 1);

        // stale version: nothing merged, flagged
        let mut stale = a.to_json();
        stale.set("version", Json::Num(0.0));
        let st = Memo::new().merge_json(&stale);
        assert!(!st.version_ok);
        assert_eq!(st.accepted + st.skipped + st.rejected, 0);
    }

    #[test]
    fn cross_node_round_trip_and_per_node_isolation() {
        let m = Memo::new();
        // the same (tech, capacity) across every calibrated node
        let mut cfgs = Vec::new();
        for node in crate::device::CALIBRATED_NODES_NM {
            cfgs.push(m.tuned_at(MemTech::SttMram, 2 * MB, node).unwrap());
        }
        assert_eq!(m.solve_count(), 3, "per-node CircuitKeys must not alias");
        assert_eq!(m.circuit_len(), 3);
        // each node tunes to a distinct design (no 16 nm aliasing)
        assert!(cfgs[0].ppa.area > cfgs[1].ppa.area, "7nm denser than 16nm");
        assert!(cfgs[1].ppa.area > cfgs[2].ppa.area, "5nm denser than 7nm");
        // re-queries on every node are pure cache hits
        for node in crate::device::CALIBRATED_NODES_NM {
            m.tuned_at(MemTech::SttMram, 2 * MB, node).unwrap();
        }
        assert_eq!(m.solve_count(), 3);
        // an uncalibrated node is a typed error, not a panic, and
        // leaves the cache untouched
        let err = m.tuned_at(MemTech::SttMram, 2 * MB, 9).unwrap_err();
        assert_eq!(err, UncalibratedNode(9));
        assert_eq!(m.circuit_len(), 3);
        assert_eq!(m.solve_count(), 3);

        // export -> merge: a fresh memo answers all three nodes with
        // zero solves, through the JSON text round trip
        let text = m.to_json().to_pretty();
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&text).unwrap());
        assert!(st.version_ok);
        assert_eq!((st.accepted, st.rejected), (3, 0));
        for (node, want) in crate::device::CALIBRATED_NODES_NM.iter().zip(&cfgs) {
            let got = fresh.tuned_at(MemTech::SttMram, 2 * MB, *node).unwrap();
            assert_eq!(format!("{got:?}"), format!("{want:?}"), "{node}nm");
        }
        assert_eq!(fresh.solve_count(), 0, "multi-node replay must be solve-free");
    }

    #[test]
    fn merge_rejects_forged_node_entries() {
        let m = Memo::new();
        m.tuned_at(MemTech::Sram, MB, 7).unwrap();
        let text = m.to_json().to_pretty();
        assert!(text.contains("\"node_nm\": 7"), "{text}");
        // an uncalibrated node id could never be re-derived locally:
        // rejected outright
        let forged = text.replace("\"node_nm\": 7", "\"node_nm\": 9");
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&forged).unwrap());
        assert_eq!((st.accepted, st.rejected), (0, 1));
        assert_eq!(fresh.circuit_len(), 0);

        // relabeling to a *calibrated* node must fail the payload hash
        // (the node id is bound into it) — otherwise a 7 nm design
        // could masquerade as 5 nm and poison that node's cache
        let relabeled = text.replace("\"node_nm\": 7", "\"node_nm\": 5");
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&relabeled).unwrap());
        assert_eq!((st.accepted, st.rejected), (0, 1));
        assert_eq!(fresh.circuit_len(), 0);

        // the untampered document still merges cleanly
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&text).unwrap());
        assert_eq!((st.accepted, st.rejected), (1, 0));
        assert!(fresh.has_circuit(MemTech::Sram, MB, 7));
    }

    #[test]
    fn merge_rejects_tampered_hybrid_parameters() {
        use crate::sweep::evaluate_point;
        use crate::sweep::spec::{parse_tech_sel, GridPoint};

        // a hybrid circuit-only point: two pure partner solves plus
        // one composed point entry
        let m = Memo::new();
        let pt = GridPoint {
            tech: parse_tech_sel("hybrid-stt:4@0.85").unwrap(),
            capacity_mb: 2,
            node_nm: 16,
            workload: None,
        };
        evaluate_point(&pt, &m).unwrap();
        assert_eq!(m.solve_count(), 2, "hybrid composes from SRAM + STT solves");
        assert_eq!(m.circuit_len(), 2, "no hybrid entry parks in the circuit cache");
        let text = m.to_json().to_pretty();
        assert!(text.contains("hybrid-stt:4@0.85"), "{text}");

        // a forged way split rewrites the tech spelling consistently
        // across the point's tech and key fields, but the stored
        // identity hash is bound to the original key string
        let forged = text.replace("hybrid-stt:4@0.85", "hybrid-stt:8@0.85");
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&forged).unwrap());
        assert!(st.version_ok);
        assert_eq!(st.rejected, 1, "relabeled way split must not merge");
        assert_eq!(fresh.point_len(), 0);

        // a forged steer fraction takes the same rejection path
        let forged = text.replace("hybrid-stt:4@0.85", "hybrid-stt:4@0.6");
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&forged).unwrap());
        assert_eq!(st.rejected, 1, "relabeled steer must not merge");
        assert_eq!(fresh.point_len(), 0);

        // an out-of-range way count never parses, so the entry cannot
        // even reach the hash checks
        let forged = text.replace("hybrid-stt:4@0.85", "hybrid-stt:99@0.85");
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&forged).unwrap());
        assert_eq!(st.rejected, 1, "unparseable hybrid must not merge");
        assert_eq!(fresh.point_len(), 0);

        // the untampered document merges with exact accounting (two
        // circuit deps + the point) and replays without solving
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&text).unwrap());
        assert_eq!((st.accepted, st.skipped, st.rejected), (3, 0, 0));
        evaluate_point(&pt, &fresh).unwrap();
        assert_eq!(fresh.solve_count(), 0, "hybrid replay must be solve-free");
        assert_eq!(fresh.eval_count(), 0);
    }

    #[test]
    fn traffic_lines_round_trip_and_replay_without_rebuilds() {
        let m = Memo::new();
        let a = m.traffic_line("AlexNet", Phase::Training);
        assert_eq!(m.traffic_build_count(), 1);
        // second query is a cache hit on the same Arc'd coefficients
        let b = m.traffic_line("AlexNet", Phase::Training);
        assert_eq!(m.traffic_build_count(), 1);
        assert_eq!(*a, *b);
        // phases never alias
        m.traffic_line("AlexNet", Phase::Inference);
        assert_eq!(m.traffic_build_count(), 2);
        assert_eq!(m.traffic_len(), 2);

        // export -> text -> merge: a fresh memo answers both phases
        // with zero lowerings, and the merged line is bit-identical
        let text = m.to_json().to_pretty();
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&text).unwrap());
        assert!(st.version_ok);
        assert_eq!((st.accepted, st.skipped, st.rejected), (2, 0, 0));
        let back = fresh.traffic_line("AlexNet", Phase::Training);
        assert_eq!(fresh.traffic_build_count(), 0, "merged lines serve directly");
        assert_eq!(*a, *back);
        // the coefficients drive identical stats on both sides
        for batch in [1usize, 4, 64, 129] {
            assert_eq!(a.at(batch), back.at(batch));
        }

        m.clear();
        assert_eq!(m.traffic_len(), 0);
        assert_eq!(m.traffic_build_count(), 0);
    }

    #[test]
    fn merge_rejects_forged_traffic_coefficients() {
        let m = Memo::new();
        let line = m.traffic_line("AlexNet", Phase::Training);
        let text = m.to_json().to_pretty();

        // a tampered coefficient no longer matches the payload hash —
        // it must be rejected, never poison the batch axis
        let needle = format!("\"macs_slope\": {}", line.macs_slope);
        assert!(text.contains(&needle), "{text}");
        let forged = text.replace(&needle, "\"macs_slope\": 1");
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&forged).unwrap());
        assert_eq!((st.accepted, st.rejected), (0, 1));
        assert_eq!(fresh.traffic_len(), 0);
        // ...and the poisoned-axis check end to end: the fresh memo
        // re-derives the true line locally
        let rebuilt = fresh.traffic_line("AlexNet", Phase::Training);
        assert_eq!(*rebuilt, *line);

        // relabeling the workload key must fail the hash (the payload
        // hash binds dnn + phase, not just the coefficients)
        for (from, to) in [
            ("\"phase\": \"training\"", "\"phase\": \"inference\""),
            ("\"dnn\": \"AlexNet\"", "\"dnn\": \"VGG-16\""),
        ] {
            let relabeled = text.replace(from, to);
            assert_ne!(relabeled, text, "{from}");
            let fresh = Memo::new();
            let st = fresh.merge_json(&json::parse(&relabeled).unwrap());
            assert_eq!((st.accepted, st.rejected), (0, 1), "{from}");
            assert_eq!(fresh.traffic_len(), 0);
        }

        // fractional coefficients cannot be element counts: rejected
        // in parsing, before any hash check
        let frac = text.replace(&needle, "\"macs_slope\": 1.5");
        let st = Memo::new().merge_json(&json::parse(&frac).unwrap());
        assert_eq!((st.accepted, st.rejected), (0, 1));

        // an unknown workload could never be re-derived locally
        let unknown = text.replace("\"dnn\": \"AlexNet\"", "\"dnn\": \"LeNet\"");
        let st = Memo::new().merge_json(&json::parse(&unknown).unwrap());
        assert_eq!((st.accepted, st.rejected), (0, 1));

        // the untampered document still merges cleanly
        let fresh = Memo::new();
        let st = fresh.merge_json(&json::parse(&text).unwrap());
        assert_eq!((st.accepted, st.rejected), (1, 0));
        assert!(fresh.has_traffic_line("AlexNet", Phase::Training));
    }

    #[test]
    fn merge_rejects_hash_consistent_but_insane_traffic_lines() {
        // The payload hash is a content address anyone can recompute,
        // so a crafted document can be hash-consistent and still carry
        // underivable values: those must fail the value-sanity gate,
        // the same way insane PPA fails circuit entries.
        let m = Memo::new();
        let line = m.traffic_line("AlexNet", Phase::Inference);

        let doc_for = |evil: &BatchLine| {
            let mut doc = Json::obj();
            doc.set("version", Json::Num(MODEL_VERSION as f64));
            doc.set(
                "traffic",
                Json::Arr(vec![traffic_to_json("AlexNet", Phase::Inference, evil)]),
            );
            doc
        };

        // pa = 0 would underflow the (pa - 1) spill pass count
        let mut evil = (*line).clone();
        evil.dram[0].pa = 0;
        let st = Memo::new().merge_json(&doc_for(&evil));
        assert_eq!((st.accepted, st.rejected), (0, 1));

        // a B stream that is neither constant nor symbolic
        let mut evil = (*line).clone();
        evil.dram[0].pb_const = 0;
        evil.dram[0].pb_unit = 0;
        let st = Memo::new().merge_json(&doc_for(&evil));
        assert_eq!((st.accepted, st.rejected), (0, 1));

        // coefficients beyond any derivable magnitude (1 << 50 round-
        // trips f64 exactly, so the hash stays consistent)
        let mut evil = (*line).clone();
        evil.macs_slope = 1 << 50;
        let st = Memo::new().merge_json(&doc_for(&evil));
        assert_eq!((st.accepted, st.rejected), (0, 1));

        // per-field bounds alone would pass this, but the symbolic
        // ceil product would overflow u64 evaluation: the u128 total
        // check must reject it
        let mut evil = (*line).clone();
        evil.l2_reads[0].ceil_mult = (1 << 40) - 1;
        evil.l2_reads[0].ceil_unit = (1 << 40) - 1;
        let st = Memo::new().merge_json(&doc_for(&evil));
        assert_eq!((st.accepted, st.rejected), (0, 1));

        // a term-count bomb: every term individually sane, but the
        // accumulated SUM would wrap — the length cap must reject it
        let mut evil = (*line).clone();
        let t = evil.l2_reads[0];
        evil.l2_reads = vec![t; MAX_TRAFFIC_TERMS + 1];
        let st = Memo::new().merge_json(&doc_for(&evil));
        assert_eq!((st.accepted, st.rejected), (0, 1));

        // the genuine line still passes the same gate
        let fresh = Memo::new();
        let st = fresh.merge_json(&doc_for(&line));
        assert_eq!((st.accepted, st.rejected), (1, 0));
        assert_eq!(fresh.traffic_len(), 1);
    }

    #[test]
    fn model_version_2_documents_rejected_wholesale() {
        // v2 documents predate the batch-axis engine: their point
        // entries were derived strictly per batch and their hashes
        // know nothing of traffic lines. A merge must reject the whole
        // generation — version_ok false, zero entries in any bucket —
        // so shard exchanges can never mix the two.
        let m = Memo::new();
        m.tuned(MemTech::SttMram, MB);
        crate::sweep::evaluate_point(
            &GridPoint {
                tech: MemTech::SttMram.into(),
                capacity_mb: 1,
                node_nm: 16,
                workload: Some(WorkloadPoint {
                    dnn: "AlexNet",
                    phase: Phase::Inference,
                    batch: 4,
                }),
            },
            &m,
        )
        .unwrap();
        let mut doc = m.to_json();
        doc.set("version", Json::Num(2.0));
        let fresh = Memo::new();
        let st = fresh.merge_json(&doc);
        assert!(!st.version_ok);
        assert_eq!(st.total(), 0, "exact accounting: nothing in any bucket");
        assert_eq!(fresh.circuit_len(), 0);
        assert_eq!(fresh.traffic_len(), 0);
        assert_eq!(fresh.point_len(), 0);
    }

    #[test]
    fn load_never_clobbers_fresh_in_memory_entries() {
        // Serialize one solved config, then load it into a memo that
        // already holds a fresh result for the same key: the fresh
        // entry must win and the loaded count must be zero.
        let m = Memo::new();
        m.tuned(MemTech::Sram, MB);
        let doc = m.to_json();

        let fresh = Memo::new();
        let own = fresh.tuned(MemTech::Sram, MB);
        assert_eq!(fresh.load_json(&doc), 0, "already-present key must be skipped");
        let after = fresh.tuned(MemTech::Sram, MB);
        assert_eq!(format!("{own:?}"), format!("{after:?}"));
    }
}
