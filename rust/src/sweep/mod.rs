//! The design-space sweep engine — DeepNVM++'s cross-layer model as one
//! queryable grid.
//!
//! Every headline artifact of the paper (Figs 3-10, Tables I-II) is a
//! slice of the same grid: {SRAM, STT-MRAM, SOT-MRAM} x cache capacity
//! x workload x phase x batch. This subsystem makes that grid a
//! first-class object instead of something each CLI command re-derives
//! serially from scratch:
//!
//! * [`spec`] — [`SweepSpec`]: axis lists, cartesian expansion into
//!   deterministically ordered [`GridPoint`]s, declarative filters.
//! * [`exec`] — a hand-rolled `std::thread` + `mpsc` self-stealing pool
//!   that evaluates points in parallel yet returns results in spec
//!   order, so output is byte-identical for any `--jobs`.
//! * [`memo`] — content-addressed memoization (in-memory + on-disk via
//!   the results store): each Algorithm-1 circuit solve, each
//!   closed-form traffic lowering (once per `(dnn, phase)` — the batch
//!   axis folds coefficients instead of re-lowering GEMMs) and each
//!   grid-point evaluation runs at most once per content key.
//! * [`pareto`] — Pareto-frontier extraction over EDP / area / capacity
//!   for co-optimization queries.
//!
//! `analysis::{scalability, iso_capacity, iso_area}` and the
//! `fig9`/`fig10`/`all`/`sweep` CLI commands are thin queries over this
//! engine; see `rust/tests/sweep.rs` for the equivalence guarantees.

pub mod exec;
pub mod memo;
pub mod optimize;
pub mod pareto;
pub mod spec;

pub use memo::Memo;
pub use spec::{
    Filter, GridPoint, OptimizeRequest, OptimizeResponse, OptObjective, SweepSpec, WorkloadPoint,
};
pub use crate::nvsim::{HybridSel, TechSel};

use anyhow::Result;
use std::collections::HashSet;

use crate::analysis::energy::{evaluate, DramCost};
use crate::device::MemTech;
use crate::nvsim::explorer::TunedConfig;

const MB: u64 = 1024 * 1024;

/// Workload-dependent metrics of one grid point. Absolute values plus
/// normalizations against the SRAM baseline at the same capacity,
/// workload, phase and batch (DRAM terms included, as in Fig 10).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkloadEval {
    pub energy_j: f64,
    pub time_s: f64,
    pub edp: f64,
    pub energy_norm: f64,
    pub latency_norm: f64,
    pub edp_norm: f64,
}

/// One evaluated grid point: the EDAP-tuned cache at (tech, capacity)
/// and, for workload-bearing points, the projected workload metrics.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: GridPoint,
    pub tuned: TunedConfig,
    pub eval: Option<WorkloadEval>,
}

/// Evaluate one grid point against the memo cache. Self-contained: a
/// workload point pulls its own SRAM baseline through the same cache,
/// so points can be scheduled in any order on any worker. Fallible: a
/// point naming an uncalibrated process node surfaces the typed
/// device-layer error (spec expansion validates earlier, but points
/// can also arrive from untrusted HTTP bodies).
pub fn evaluate_point(point: &GridPoint, memo: &Memo) -> Result<PointResult> {
    if let Some(hit) = memo.cached_point(point) {
        return Ok(hit);
    }
    // Span only the miss path: warm grids are pure map lookups and
    // would otherwise flood the trace ring with microsecond noise.
    // Any circuit.solve / traffic.lower spans nest under this one.
    let _span = crate::obs::Span::enter("point.evaluate");
    let bytes = point.capacity_mb * MB;
    let tuned = memo.tuned_sel_at(point.tech, bytes, point.node_nm)?;
    let eval = match point.workload {
        None => None,
        Some(w) => {
            // The closed-form batch line is built once per
            // (dnn, phase) across the WHOLE sweep — every batch on the
            // axis and every cache capacity folds the same
            // coefficients (bit-identical to re-running the GEMM
            // lowering; see rust/tests/properties.rs).
            let line = memo.traffic_line(w.dnn, w.phase);
            let stats = line.at_capacity(w.batch, bytes);
            let dram = DramCost::default();
            let e = evaluate(&stats, &tuned.ppa, Some(dram));
            let sram = memo.tuned_at(MemTech::Sram, bytes, point.node_nm)?;
            let base = evaluate(&stats, &sram.ppa, Some(dram));
            Some(WorkloadEval {
                energy_j: e.energy(),
                time_s: e.time_total,
                edp: e.edp(),
                energy_norm: e.energy() / base.energy(),
                latency_norm: e.time_total / base.time_total,
                edp_norm: e.edp() / base.edp(),
            })
        }
    };
    let result = PointResult { point: *point, tuned, eval };
    memo.record_point(result.clone());
    Ok(result)
}

/// A completed sweep: the spec and one result per surviving grid
/// point, in spec order.
#[derive(Clone, Debug)]
pub struct SweepResults {
    pub spec: SweepSpec,
    pub points: Vec<PointResult>,
}

impl SweepResults {
    /// The distinct tuned cache configurations touched by this sweep,
    /// in first-appearance order (the Fig 9 view of the grid).
    pub fn tuned_configs(&self) -> Vec<TunedConfig> {
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        for p in &self.points {
            if seen.insert((p.point.tech, p.point.capacity_mb, p.point.node_nm)) {
                out.push(p.tuned);
            }
        }
        out
    }
}

/// Run a sweep: expand the spec, solve each distinct circuit point once
/// across `jobs` workers, then evaluate every grid point in parallel.
/// `jobs = 0` means one worker per core. Results are in spec order and
/// bit-identical to the serial (`jobs = 1`) schedule.
pub fn run(spec: &SweepSpec, jobs: usize, memo: &Memo) -> Result<SweepResults> {
    let points = spec.expand()?;
    let jobs = if jobs == 0 { exec::default_jobs() } else { jobs };

    // Phase 1: distinct *uncached* circuit solves (the expensive
    // NVSim-style enumerations), deduplicated up front so parallel
    // workers never duplicate a solve. A hybrid selection depends on
    // its two pure partner solves (never a solve of its own); workload
    // points also need the SRAM baseline.
    let mut seen = HashSet::new();
    let mut circuits: Vec<(MemTech, u64, u32)> = Vec::new();
    for p in &points {
        let mut deps = p.tech.circuit_deps();
        if p.workload.is_some() {
            deps.push(MemTech::Sram);
        }
        for tech in deps {
            if seen.insert((tech, p.capacity_mb, p.node_nm))
                && !memo.has_circuit(tech, p.capacity_mb * MB, p.node_nm)
            {
                circuits.push((tech, p.capacity_mb, p.node_nm));
            }
        }
    }
    if !circuits.is_empty() {
        for solved in exec::run_ordered(&circuits, jobs, |&(tech, mb, node)| {
            memo.tuned_at(tech, mb * MB, node)
        }) {
            // Expansion already validated the node axis, so this only
            // fires if the calibrated set and the validator drift.
            solved?;
        }
    }

    // Phase 2: the full grid (cheap traffic evaluations against the
    // now-warm circuit cache; point-memoized reruns skip even these).
    // A fully-warm grid is served inline — map lookups do not merit
    // thread spawns, which keeps warm-query latency at cache speed.
    let all_cached = points.iter().all(|p| memo.has_point(p));
    let jobs = if all_cached { 1 } else { jobs };
    let results: std::result::Result<Vec<PointResult>, _> =
        exec::run_ordered(&points, jobs, |p| evaluate_point(p, memo))
            .into_iter()
            .collect();
    Ok(SweepResults { spec: spec.clone(), points: results? })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::Phase;

    #[test]
    fn run_covers_spec_in_order() {
        let spec = SweepSpec {
            techs: TechSel::pures(&[MemTech::Sram, MemTech::SotMram]),
            capacities_mb: vec![1, 2],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let memo = Memo::new();
        let res = run(&spec, 2, &memo).unwrap();
        let expanded = spec.expand().unwrap();
        assert_eq!(res.points.len(), expanded.len());
        for (r, p) in res.points.iter().zip(&expanded) {
            assert_eq!(r.point, *p);
            assert!(r.eval.is_some());
        }
        // 2 techs x 2 caps, SRAM baseline already among the techs
        assert_eq!(memo.solve_count(), 4);
    }

    #[test]
    fn sram_points_normalize_to_exactly_one() {
        let spec = SweepSpec {
            techs: vec![MemTech::Sram.into()],
            capacities_mb: vec![2],
            dnns: vec!["SqueezeNet".into()],
            phases: vec![Phase::Training],
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let res = run(&spec, 1, &Memo::new()).unwrap();
        let e = res.points[0].eval.unwrap();
        assert_eq!(e.energy_norm, 1.0);
        assert_eq!(e.latency_norm, 1.0);
        assert_eq!(e.edp_norm, 1.0);
    }

    #[test]
    fn multi_node_run_solves_per_node_and_keeps_nodes_distinct() {
        let spec = SweepSpec {
            techs: vec![MemTech::SttMram.into()],
            capacities_mb: vec![1],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![],
            nodes_nm: vec![16, 7, 5],
            filters: vec![],
        };
        let memo = Memo::new();
        let res = run(&spec, 2, &memo).unwrap();
        assert_eq!(res.points.len(), 3, "one workload point per node");
        // STT + the SRAM baseline solve once per node — never aliased
        assert_eq!(memo.solve_count(), 6);
        assert_eq!(res.tuned_configs().len(), 3, "one tuned design per node");
        for p in &res.points {
            assert!(p.eval.is_some(), "each node normalizes against its own SRAM");
        }
        let areas: Vec<f64> = res.points.iter().map(|p| p.tuned.ppa.area).collect();
        assert!(
            areas[0] > areas[1] && areas[1] > areas[2],
            "deeper nodes must tune denser: {areas:?}"
        );
        // a warm rerun of the multi-node grid is pure cache hits
        run(&spec, 2, &memo).unwrap();
        assert_eq!(memo.solve_count(), 6);
        assert_eq!(memo.eval_count(), 3);
    }

    #[test]
    fn batch_axis_lowers_traffic_once_per_workload_phase() {
        // A wide --batches grid must not scale traffic-coefficient
        // work with the batch count: one lowering per (dnn, phase),
        // shared by every batch AND every capacity.
        let spec = SweepSpec {
            techs: vec![MemTech::SttMram.into()],
            capacities_mb: vec![1, 2],
            dnns: vec!["AlexNet".into()],
            phases: Phase::ALL.to_vec(),
            batches: vec![1, 2, 4, 8, 16, 32],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let memo = Memo::new();
        let res = run(&spec, 3, &memo).unwrap();
        assert_eq!(res.points.len(), 2 * 2 * 6);
        assert_eq!(memo.eval_count(), 24);
        assert_eq!(memo.traffic_build_count(), 2, "one lowering per (dnn, phase)");
        assert_eq!(memo.traffic_len(), 2);
        // a warm rerun folds coefficients from cache: no new builds
        run(&spec, 3, &memo).unwrap();
        assert_eq!(memo.traffic_build_count(), 2);
        assert_eq!(memo.eval_count(), 24);
    }

    #[test]
    fn tuned_configs_deduplicate_across_workloads() {
        let spec = SweepSpec {
            techs: vec![MemTech::SttMram.into()],
            capacities_mb: vec![1],
            dnns: vec!["AlexNet".into(), "VGG-16".into()],
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let res = run(&spec, 1, &Memo::new()).unwrap();
        assert_eq!(res.points.len(), 4);
        assert_eq!(res.tuned_configs().len(), 1);
    }

    #[test]
    fn hybrid_points_compose_from_pure_solves() {
        use crate::sweep::spec::parse_tech_sel;
        let hybrid = parse_tech_sel("hybrid-stt:4@0.85").unwrap();
        let spec = SweepSpec {
            techs: vec![hybrid, MemTech::SttMram.into()],
            capacities_mb: vec![2],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let memo = Memo::new();
        let res = run(&spec, 2, &memo).unwrap();
        assert_eq!(res.points.len(), 2);
        // the hybrid composes from the SRAM + STT solves the grid
        // already needs: exactly 2 circuit solves total, not 3
        assert_eq!(memo.solve_count(), 2);
        let h = &res.points[0];
        let pure = &res.points[1];
        assert_eq!(h.point.tech, hybrid);
        // composed PPA sits strictly between its endpoints
        assert!(h.tuned.ppa.write_latency < pure.tuned.ppa.write_latency);
        assert!(h.tuned.ppa.leakage_power > pure.tuned.ppa.leakage_power);
        // and matches the standalone node-aware hybrid model bit-exactly
        let direct = crate::nvsim::hybrid_at(MemTech::SttMram, 2 * MB, 4, 0.85, 16)
            .unwrap();
        assert_eq!(h.tuned.ppa.write_latency.to_bits(), direct.ppa.write_latency.to_bits());
        assert_eq!(h.tuned.ppa.area.to_bits(), direct.ppa.area.to_bits());
        // workload eval exists and normalizes against SRAM
        assert!(h.eval.is_some());
        // a warm rerun is pure cache hits
        run(&spec, 2, &memo).unwrap();
        assert_eq!(memo.solve_count(), 2);
        assert_eq!(memo.eval_count(), 2);
    }
}
