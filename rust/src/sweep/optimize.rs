//! Branch-and-bound search over the implicit sweep grid — "search, not
//! sweep".
//!
//! The closed-form batch axis (PR 5) made single-point evaluation
//! nearly free, which moves the cost of a design query from *model
//! evaluation* to *grid enumeration*. This module answers `POST
//! /optimize` / `deepnvm optimize` queries ("best config under
//! area ≤ A, node ∈ {7, 5}") without materializing the grid:
//!
//! 1. **Admissible lower bounds.** Dropping the `ceil` terms of
//!    [`TxTerm`](crate::workload::traffic::TxTerm) /
//!    [`DramTerm`](crate::workload::traffic::DramTerm) yields an affine
//!    traffic bound ([`BatchLine::lower_bound_at`]) that is monotone in
//!    batch and independent of capacity, so one evaluation bounds a
//!    whole (capacity, batch) rectangle. A second, tighter bound keeps
//!    the exact ceil arithmetic and exploits monotonicity directly:
//!    DRAM traffic never increases with capacity at a fixed batch, so
//!    the rectangle's largest capacity plus a field-wise floor of the
//!    tuned PPAs bounds every point in a capacity range. Both bounds
//!    flow through the *same* [`evaluate`] expression tree as the exact
//!    path — f64 rounding is monotone, so admissibility survives
//!    floating point.
//! 2. **Best-first search.** A min-heap over (bound, spec-order) pops
//!    the most promising rectangle first: slices (one per node × tech ×
//!    dnn × phase) triaged by the affine bound, capacity ranges split
//!    binary with the tight bound, singleton points carrying their
//!    exact value. The incumbent comes from a coarse corner seed, and
//!    because the heap is ordered lexicographically the first prunable
//!    pop proves everything still enqueued is prunable too.
//! 3. **Bit-identical winners.** Every candidate the search actually
//!    accepts is folded through [`super::evaluate_point`] — the same
//!    memoized path the exhaustive sweep uses — and ties are broken by
//!    spec-expansion order, so the winner (value *and* bytes) is
//!    exactly what `argmin` over [`super::run`] would have returned.
//!
//! Constraint budgets (`area_max_mm2`, `leakage_max_w`) are properties
//! of the tuned circuit alone, so an infeasible (tech, capacity, node)
//! column disappears before the workload axes are even considered; the
//! `techs` / `nodes_nm` spec axes double as membership constraints.
//! Multi-objective `frontier` mode reuses [`super::pareto`] over the
//! feasible grid (exhaustive by construction — a frontier needs every
//! non-dominated point).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::fmt;

use anyhow::{bail, Result};

use crate::analysis::energy::{evaluate, DramCost, Evaluation};
use crate::device::MemTech;
use crate::nvsim::explorer::TunedConfig;
use crate::nvsim::model::CachePpa;
use crate::nvsim::TechSel;
use crate::obs::{LazyCounter, Span};
use crate::workload::models::Phase;
use crate::workload::traffic::BatchLine;

use super::spec::{OptimizeRequest, OptimizeResponse, OptObjective};
use super::{exec, pareto, GridPoint, Memo, MB, PointResult};

static OPT_REQUESTS: LazyCounter = LazyCounter::new("deepnvm_optimize_requests_total");
static OPT_EVALUATED: LazyCounter = LazyCounter::new("deepnvm_optimize_points_evaluated_total");
static OPT_PRUNED: LazyCounter = LazyCounter::new("deepnvm_optimize_points_pruned_total");

/// No grid point survived the design budgets. Typed so the serve layer
/// can map it onto the `infeasible` error kind instead of a generic
/// 4xx string.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Infeasible {
    pub area_max_mm2: Option<f64>,
    pub leakage_max_w: Option<f64>,
}

impl fmt::Display for Infeasible {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "no grid point satisfies the design budgets")?;
        if let Some(a) = self.area_max_mm2 {
            write!(f, " area <= {a} mm2")?;
        }
        if let Some(l) = self.leakage_max_w {
            write!(f, " leakage <= {l} W")?;
        }
        Ok(())
    }
}

impl std::error::Error for Infeasible {}

/// The scalar the search minimizes for one evaluated point — shared
/// with the exhaustive-argmin property tests so both sides can never
/// disagree about what "best" means. `Capacity` is maximized, scored
/// as its negation; workload objectives are infinite on circuit-only
/// points (they never reach here through [`run`], which rejects that
/// combination up front).
pub fn objective_value(objective: OptObjective, r: &PointResult) -> f64 {
    match objective {
        OptObjective::Edp => r.eval.map(|e| e.edp).unwrap_or(f64::INFINITY),
        OptObjective::Edap => match r.eval {
            Some(e) => e.edp * r.tuned.ppa.area,
            None => r.tuned.ppa.edap(),
        },
        OptObjective::Energy => {
            r.eval.map(|e| e.energy_j).unwrap_or(f64::INFINITY)
        }
        OptObjective::Latency => r.eval.map(|e| e.time_s).unwrap_or(f64::INFINITY),
        OptObjective::Capacity => -(r.point.capacity_mb as f64),
    }
}

/// The same scalar, read off a (possibly lower-bounding) [`Evaluation`]
/// plus the range's smallest area / largest capacity. At a singleton
/// point fed the exact stats and PPA this reproduces
/// [`objective_value`] bit for bit — the identity that lets heap order
/// stand in for exhaustive comparison.
fn objective_bound(
    objective: OptObjective,
    e: &Evaluation,
    area_min: f64,
    cap_max_mb: u64,
) -> f64 {
    match objective {
        OptObjective::Edp => e.edp(),
        OptObjective::Edap => e.edp() * area_min,
        OptObjective::Energy => e.energy(),
        OptObjective::Latency => e.time_total,
        OptObjective::Capacity => -(cap_max_mb as f64),
    }
}

/// Field-wise floor of a set of tuned PPAs: a synthetic cache at least
/// as good as every real design in the range on every axis, hence an
/// admissible stand-in inside [`evaluate`] (which is monotone
/// nondecreasing in every PPA field). Hybrid selections need no
/// special casing: [`crate::nvsim::compose_ppa`] is affine in the SRAM
/// way fraction (writes constant on the steered plateau), so a
/// column's composed PPA is itself a convex mix of its pure partners
/// and the elementwise floor over *actual* column PPAs — which is what
/// flows in here — still under-approximates every point in the range.
fn ppa_floor(ppas: &[CachePpa]) -> CachePpa {
    let mut m = ppas[0];
    for p in &ppas[1..] {
        m.read_latency = m.read_latency.min(p.read_latency);
        m.write_latency = m.write_latency.min(p.write_latency);
        m.read_energy = m.read_energy.min(p.read_energy);
        m.write_energy = m.write_energy.min(p.write_energy);
        m.leakage_power = m.leakage_power.min(p.leakage_power);
        m.area = m.area.min(p.area);
    }
    m
}

/// One (node, tech, dnn, phase) slab of the grid: its feasible
/// capacity column (spec order) and batch row (spec order) span a
/// rectangle of grid points the search bounds as a unit.
struct Slice {
    tech: TechSel,
    node_nm: u32,
    dnn: &'static str,
    phase: Phase,
    caps_mb: Vec<u64>,
    batches: Vec<usize>,
    /// Tuned designs aligned with `caps_mb`.
    ppas: Vec<CachePpa>,
    line: std::sync::Arc<BatchLine>,
}

impl Slice {
    fn point(&self, cap_i: usize, batch_i: usize) -> GridPoint {
        GridPoint {
            tech: self.tech,
            capacity_mb: self.caps_mb[cap_i],
            node_nm: self.node_nm,
            workload: Some(super::WorkloadPoint {
                dnn: self.dnn,
                phase: self.phase,
                batch: self.batches[batch_i],
            }),
        }
    }

    /// Tight bound over caps `lo..=hi` (spec-order indices) and the
    /// full batch row: exact ceil traffic at the range's numerically
    /// largest capacity (DRAM spill is nonincreasing in capacity at a
    /// fixed batch) against the field-wise PPA floor. The batch axis
    /// is scanned explicitly — the spill branch can flip with batch,
    /// so batch monotonicity is not assumed.
    fn range_bound(&self, objective: OptObjective, lo: usize, hi: usize) -> f64 {
        let ppa = ppa_floor(&self.ppas[lo..=hi]);
        let cap_max = *self.caps_mb[lo..=hi].iter().max().unwrap();
        let l2_max = cap_max * MB;
        let dram = DramCost::default();
        let mut best = f64::INFINITY;
        for &b in &self.batches {
            let e = evaluate(&self.line.at_capacity(b, l2_max), &ppa, Some(dram));
            best = best.min(objective_bound(objective, &e, ppa.area, cap_max));
        }
        best
    }

    /// Cheap triage bound for the whole slice: the ceil-dropped affine
    /// traffic line at the smallest batch (capacity-independent by
    /// construction) against the slice-wide PPA floor.
    fn affine_bound(&self, objective: OptObjective) -> f64 {
        let b_min = *self.batches.iter().min().unwrap();
        let ppa = ppa_floor(&self.ppas);
        let cap_max = *self.caps_mb.iter().max().unwrap();
        let stats = self.line.lower_bound_at(b_min);
        let e = evaluate(&stats, &ppa, Some(DramCost::default()));
        objective_bound(objective, &e, ppa.area, cap_max)
    }
}

/// What a heap node still owes the search.
enum Task {
    /// A whole slice, triaged by its affine bound.
    Slice(usize),
    /// Caps `lo..=hi` of a slice, bounded by [`Slice::range_bound`].
    CapRange { slice: usize, lo: usize, hi: usize },
    /// A single grid point; its bound *is* its exact objective value.
    Point { slice: usize, cap_i: usize, batch_i: usize },
}

/// Min-heap entry: `(bound, spec-order of the rectangle's first
/// point)`. Lexicographic order makes the heap's pop order a proof —
/// once the best remaining bound cannot beat the incumbent (ties
/// resolved by spec order, matching exhaustive first-wins argmin),
/// nothing behind it can either.
struct Node {
    bound: f64,
    order: usize,
    task: Task,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Node {}

impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the smallest
        // (bound, order) on top.
        other
            .bound
            .total_cmp(&self.bound)
            .then_with(|| other.order.cmp(&self.order))
    }
}

/// `(value, order) >= (inc_value, inc_order)` lexicographically — the
/// prune test (a tie on value loses to an earlier spec position).
fn lex_ge(value: f64, order: usize, inc_value: f64, inc_order: usize) -> bool {
    match value.total_cmp(&inc_value) {
        Ordering::Greater => true,
        Ordering::Less => false,
        Ordering::Equal => order >= inc_order,
    }
}

/// Run one optimize query. `jobs` parallelizes the up-front circuit
/// solves (and the exhaustive sweep in frontier mode); the
/// branch-and-bound itself is sequential — it evaluates so few points
/// that thread handoff would dominate.
pub fn run(req: &OptimizeRequest, jobs: usize, memo: &Memo) -> Result<OptimizeResponse> {
    OPT_REQUESTS.inc();
    let _span = Span::enter("optimize.search");

    let points = req.spec.expand()?;
    let points_total = points.len() as u64;
    if points.is_empty() {
        bail!("the grid is empty after filters; nothing to optimize");
    }

    // Solve every distinct *pure* circuit column once, in parallel —
    // cheap relative to the workload grid (caps × techs × nodes vs the
    // full product) and exactly what feasibility and the PPA floors
    // need. Hybrid selections contribute their SRAM + NVM partner
    // columns here and then compose from the warm cache below.
    let mut seen = HashSet::new();
    let mut columns: Vec<(MemTech, u64, u32)> = Vec::new();
    for p in &points {
        for tech in p.tech.circuit_deps() {
            if seen.insert((tech, p.capacity_mb, p.node_nm)) {
                columns.push((tech, p.capacity_mb, p.node_nm));
            }
        }
    }
    let jobs = if jobs == 0 { exec::default_jobs() } else { jobs };
    for solved in exec::run_ordered(&columns, jobs, |&(tech, mb, node)| {
        memo.tuned_at(tech, mb * MB, node)
    }) {
        solved?;
    }
    let mut tuned: HashMap<(TechSel, u64, u32), TunedConfig> = HashMap::new();
    for p in &points {
        let col = (p.tech, p.capacity_mb, p.node_nm);
        if !tuned.contains_key(&col) {
            // pure cache hits — every partner column was solved above
            let cfg = memo.tuned_sel_at(p.tech, p.capacity_mb * MB, p.node_nm)?;
            tuned.insert(col, cfg);
        }
    }
    let feasible: Vec<GridPoint> = points
        .iter()
        .filter(|p| req.feasible(&tuned[&(p.tech, p.capacity_mb, p.node_nm)].ppa))
        .copied()
        .collect();
    if feasible.is_empty() {
        return Err(Infeasible {
            area_max_mm2: req.area_max_mm2,
            leakage_max_w: req.leakage_max_w,
        }
        .into());
    }

    if req.frontier {
        return frontier_mode(req, jobs, memo, points_total);
    }

    let workload_grid = points[0].workload.is_some();
    if !workload_grid {
        if req.objective.needs_workload() {
            bail!(
                "objective '{}' needs a workload axis; this grid is circuit-only \
                 (add 'dnns' or pick edap|capacity)",
                req.objective.name()
            );
        }
        return circuit_only(req, memo, &feasible, &tuned, points_total);
    }

    // Spec-expansion position of every surviving point: the global
    // tie-break order, shared bit for bit with exhaustive argmin.
    let order_of: HashMap<GridPoint, usize> =
        points.iter().enumerate().map(|(i, p)| (*p, i)).collect();

    // Feasible points arrive grouped (node, tech) outer, capacity next,
    // (dnn, phase) inner, batch innermost — so each slice's capacity
    // column and batch row fill in spec order.
    let mut slice_of: HashMap<(u32, TechSel, &'static str, Phase), usize> =
        HashMap::new();
    let mut slices: Vec<Slice> = Vec::new();
    for p in &feasible {
        let w = p.workload.expect("workload grid");
        let key = (p.node_nm, p.tech, w.dnn, w.phase);
        let si = *slice_of.entry(key).or_insert_with(|| {
            slices.push(Slice {
                tech: p.tech,
                node_nm: p.node_nm,
                dnn: w.dnn,
                phase: w.phase,
                caps_mb: Vec::new(),
                batches: Vec::new(),
                ppas: Vec::new(),
                line: memo.traffic_line(w.dnn, w.phase),
            });
            slices.len() - 1
        });
        let s = &mut slices[si];
        if s.caps_mb.last() != Some(&p.capacity_mb) {
            s.caps_mb.push(p.capacity_mb);
            s.ppas.push(tuned[&(p.tech, p.capacity_mb, p.node_nm)].ppa);
        }
        if s.caps_mb.len() == 1 {
            s.batches.push(w.batch);
        }
    }

    let mut evaluated: HashSet<GridPoint> = HashSet::new();
    let mut incumbent: Option<(f64, usize, PointResult)> = None;
    let mut offer = |gp: GridPoint,
                     evaluated: &mut HashSet<GridPoint>,
                     incumbent: &mut Option<(f64, usize, PointResult)>|
     -> Result<()> {
        if !evaluated.insert(gp) {
            return Ok(());
        }
        let r = super::evaluate_point(&gp, memo)?;
        let value = objective_value(req.objective, &r);
        let order = order_of[&gp];
        let beats = match incumbent {
            None => true,
            Some((iv, io, _)) => !lex_ge(value, order, *iv, *io),
        };
        if beats {
            *incumbent = Some((value, order, r));
        }
        Ok(())
    };

    // Heap of every slice under its affine triage bound.
    let mut heap: BinaryHeap<Node> = BinaryHeap::new();
    let mut triage: Vec<(f64, usize)> = Vec::with_capacity(slices.len());
    for (si, s) in slices.iter().enumerate() {
        let bound = s.affine_bound(req.objective);
        let order = order_of[&s.point(0, 0)];
        triage.push((bound, order));
        heap.push(Node { bound, order, task: Task::Slice(si) });
    }

    // Seed the incumbent from the first corner of the three most
    // promising slices so pruning has something to cut against before
    // the first rectangle is split.
    let mut seed: Vec<usize> = (0..slices.len()).collect();
    seed.sort_by(|&a, &b| {
        triage[a].0.total_cmp(&triage[b].0).then_with(|| triage[a].1.cmp(&triage[b].1))
    });
    for &si in seed.iter().take(3) {
        offer(slices[si].point(0, 0), &mut evaluated, &mut incumbent)?;
    }

    while let Some(node) = heap.pop() {
        if let Some((iv, io, _)) = &incumbent {
            // The heap pops in (bound, order) order: the first
            // prunable node proves every remaining node prunable.
            if lex_ge(node.bound, node.order, *iv, *io) {
                break;
            }
        }
        match node.task {
            Task::Slice(si) => {
                let s = &slices[si];
                let hi = s.caps_mb.len() - 1;
                let bound = s.range_bound(req.objective, 0, hi);
                heap.push(Node {
                    bound,
                    order: node.order,
                    task: Task::CapRange { slice: si, lo: 0, hi },
                });
            }
            Task::CapRange { slice: si, lo, hi } if lo < hi => {
                let s = &slices[si];
                let mid = lo + (hi - lo) / 2;
                for (a, b) in [(lo, mid), (mid + 1, hi)] {
                    heap.push(Node {
                        bound: s.range_bound(req.objective, a, b),
                        order: order_of[&s.point(a, 0)],
                        task: Task::CapRange { slice: si, lo: a, hi: b },
                    });
                }
            }
            Task::CapRange { slice: si, lo, hi: _ } => {
                let s = &slices[si];
                let ppa = s.ppas[lo];
                let l2 = s.caps_mb[lo] * MB;
                let dram = DramCost::default();
                for (bi, &b) in s.batches.iter().enumerate() {
                    // Exact stats, exact PPA: this bound IS the
                    // point's objective value, so the heap pops the
                    // true minimum first.
                    let e = evaluate(&s.line.at_capacity(b, l2), &ppa, Some(dram));
                    let bound =
                        objective_bound(req.objective, &e, ppa.area, s.caps_mb[lo]);
                    heap.push(Node {
                        bound,
                        order: order_of[&s.point(lo, bi)],
                        task: Task::Point { slice: si, cap_i: lo, batch_i: bi },
                    });
                }
            }
            Task::Point { slice: si, cap_i, batch_i } => {
                offer(slices[si].point(cap_i, batch_i), &mut evaluated, &mut incumbent)?;
            }
        }
    }

    let (best_value, _, winner) = incumbent.expect("seeded incumbent");
    let points_evaluated = evaluated.len() as u64;
    OPT_EVALUATED.add(points_evaluated);
    OPT_PRUNED.add(points_total - points_evaluated);
    Ok(OptimizeResponse {
        objective: req.objective,
        winner: Some(winner),
        best_value: Some(best_value),
        frontier: Vec::new(),
        points_total,
        points_evaluated,
        points_pruned: points_total - points_evaluated,
    })
}

/// Circuit-only scalar objectives (`edap`, `capacity`): the objective
/// is a pure function of the already-solved tuned designs, so argmin
/// runs over the columns directly and only the winner is folded into
/// a memoized [`PointResult`].
fn circuit_only(
    req: &OptimizeRequest,
    memo: &Memo,
    feasible: &[GridPoint],
    tuned: &HashMap<(TechSel, u64, u32), TunedConfig>,
    points_total: u64,
) -> Result<OptimizeResponse> {
    let mut best: Option<(f64, usize)> = None;
    for (i, p) in feasible.iter().enumerate() {
        let ppa = tuned[&(p.tech, p.capacity_mb, p.node_nm)].ppa;
        let value = match req.objective {
            OptObjective::Edap => ppa.edap(),
            OptObjective::Capacity => -(p.capacity_mb as f64),
            _ => unreachable!("workload objectives rejected earlier"),
        };
        let beats = match best {
            None => true,
            Some((bv, _)) => value.total_cmp(&bv) == Ordering::Less,
        };
        if beats {
            best = Some((value, i));
        }
    }
    let (best_value, wi) = best.expect("feasible set is non-empty");
    let winner = super::evaluate_point(&feasible[wi], memo)?;
    OPT_EVALUATED.inc();
    OPT_PRUNED.add(points_total - 1);
    Ok(OptimizeResponse {
        objective: req.objective,
        winner: Some(winner),
        best_value: Some(best_value),
        frontier: Vec::new(),
        points_total,
        points_evaluated: 1,
        points_pruned: points_total - 1,
    })
}

/// Frontier mode: exhaustive by necessity (every non-dominated point
/// must be proven non-dominated), grouped the way absolute EDP is
/// comparable — within one (dnn, phase, batch) workload cell — and
/// unioned back into spec order.
fn frontier_mode(
    req: &OptimizeRequest,
    jobs: usize,
    memo: &Memo,
    points_total: u64,
) -> Result<OptimizeResponse> {
    let results = super::run(&req.spec, jobs, memo)?;
    let feas: Vec<PointResult> = results
        .points
        .into_iter()
        .filter(|p| req.feasible(&p.tuned.ppa))
        .collect();
    if feas.is_empty() {
        return Err(Infeasible {
            area_max_mm2: req.area_max_mm2,
            leakage_max_w: req.leakage_max_w,
        }
        .into());
    }
    let mut groups: Vec<(Option<(&str, Phase, usize)>, Vec<usize>)> = Vec::new();
    for (i, p) in feas.iter().enumerate() {
        let key = p.point.workload.map(|w| (w.dnn, w.phase, w.batch));
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((key, vec![i])),
        }
    }
    let objectives = pareto::edp_area_capacity();
    let mut keep: Vec<usize> = Vec::new();
    for (_, idxs) in &groups {
        let items: Vec<PointResult> = idxs.iter().map(|&i| feas[i].clone()).collect();
        for fi in pareto::frontier_indices(&items, &objectives) {
            keep.push(idxs[fi]);
        }
    }
    keep.sort_unstable();
    OPT_EVALUATED.add(points_total);
    Ok(OptimizeResponse {
        objective: req.objective,
        winner: None,
        best_value: None,
        frontier: keep.into_iter().map(|i| feas[i].clone()).collect(),
        points_total,
        points_evaluated: points_total,
        points_pruned: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::{optimize_request_from_json, parse_tech_sel};
    use crate::sweep::{Filter, SweepSpec};
    use crate::util::json;

    fn req(spec: SweepSpec, objective: OptObjective) -> OptimizeRequest {
        OptimizeRequest {
            spec,
            objective,
            area_max_mm2: None,
            leakage_max_w: None,
            frontier: false,
        }
    }

    /// Exhaustive reference: sweep the whole grid, filter feasibility,
    /// first-wins argmin on the objective.
    fn exhaustive_argmin(
        r: &OptimizeRequest,
        memo: &Memo,
    ) -> Option<(f64, PointResult)> {
        let all = super::super::run(&r.spec, 2, memo).unwrap();
        let mut best: Option<(f64, PointResult)> = None;
        for p in all.points {
            if !r.feasible(&p.tuned.ppa) {
                continue;
            }
            let v = objective_value(r.objective, &p);
            if best.as_ref().is_none_or(|(bv, _)| v.total_cmp(bv) == Ordering::Less)
            {
                best = Some((v, p));
            }
        }
        best
    }

    #[test]
    fn search_matches_exhaustive_argmin_bit_for_bit() {
        // the tech axis mixes pure and hybrid selections so the
        // equivalence proof covers composed PPAs too
        let spec = SweepSpec {
            techs: vec![
                MemTech::SttMram.into(),
                parse_tech_sel("hybrid-stt:4@0.85").unwrap(),
                parse_tech_sel("hybrid-sot:8@0.9").unwrap(),
                MemTech::SotMram.into(),
            ],
            capacities_mb: vec![1, 2, 4],
            dnns: vec!["AlexNet".into()],
            phases: Phase::ALL.to_vec(),
            batches: vec![1, 4, 16, 64],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let memo = Memo::new();
        for objective in OptObjective::ALL {
            let r = req(spec.clone(), objective);
            let got = run(&r, 2, &memo).unwrap();
            let (want_v, want_p) = exhaustive_argmin(&r, &memo).unwrap();
            let w = got.winner.expect("scalar mode returns a winner");
            assert_eq!(w.point, want_p.point, "{}", objective.name());
            assert_eq!(got.best_value, Some(want_v), "{}", objective.name());
            // bit-identity of the full result document, not just the key
            assert_eq!(w.tuned.ppa.area, want_p.tuned.ppa.area);
            assert_eq!(
                w.eval.map(|e| e.edp),
                want_p.eval.map(|e| e.edp),
                "{}",
                objective.name()
            );
            assert_eq!(
                got.points_evaluated + got.points_pruned,
                got.points_total
            );
        }
    }

    #[test]
    fn search_prunes_most_of_a_wide_grid() {
        let spec = SweepSpec {
            techs: TechSel::pures(&[MemTech::Sram, MemTech::SttMram, MemTech::SotMram]),
            capacities_mb: vec![1, 2, 4, 8, 16, 32],
            dnns: vec!["AlexNet".into(), "ResNet-18".into()],
            phases: Phase::ALL.to_vec(),
            batches: vec![1, 2, 4, 8, 16, 32, 64, 128],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let memo = Memo::new();
        let r = req(spec, OptObjective::Edp);
        let got = run(&r, 2, &memo).unwrap();
        assert_eq!(got.points_total, 3 * 6 * 2 * 2 * 8);
        assert!(
            got.points_evaluated * 10 <= got.points_total,
            "evaluated {} of {}",
            got.points_evaluated,
            got.points_total
        );
        // and the winner still matches the exhaustive reference
        let (_, want) = exhaustive_argmin(&r, &memo).unwrap();
        assert_eq!(got.winner.unwrap().point, want.point);
    }

    #[test]
    fn budgets_prune_and_infeasible_is_typed() {
        let spec = SweepSpec {
            techs: vec![MemTech::SttMram.into()],
            capacities_mb: vec![1, 2],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let memo = Memo::new();
        let mut r = req(spec, OptObjective::Edp);
        r.area_max_mm2 = Some(1e-6);
        let err = run(&r, 1, &memo).unwrap_err();
        let inf = err
            .chain()
            .find_map(|c| c.downcast_ref::<Infeasible>())
            .expect("typed Infeasible in the chain");
        assert_eq!(inf.area_max_mm2, Some(1e-6));
        assert!(format!("{inf}").contains("design budgets"));
    }

    #[test]
    fn circuit_only_grids_answer_edap_and_reject_workload_objectives() {
        let spec = SweepSpec::circuit_only(
            vec![MemTech::SttMram, MemTech::SotMram],
            vec![1, 2, 4],
        );
        let memo = Memo::new();
        let got = run(&req(spec.clone(), OptObjective::Edap), 2, &memo).unwrap();
        let w = got.winner.unwrap();
        assert_eq!(got.points_evaluated, 1);
        assert_eq!(got.points_pruned, got.points_total - 1);
        assert_eq!(got.best_value, Some(w.tuned.ppa.edap()));
        // exhaustive check over the tuned columns
        let all = super::super::run(&spec, 2, &memo).unwrap();
        let min = all
            .points
            .iter()
            .map(|p| p.tuned.ppa.edap())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(got.best_value, Some(min));

        let err = run(&req(spec, OptObjective::Edp), 1, &memo).unwrap_err();
        assert!(format!("{err:#}").contains("needs a workload axis"), "{err:#}");
    }

    #[test]
    fn capacity_objective_maximizes_under_filters() {
        let spec = SweepSpec {
            filters: vec![Filter::CapacityAtMost(8)],
            ..SweepSpec::circuit_only(vec![MemTech::SotMram], vec![1, 4, 8, 32])
        };
        let got = run(&req(spec, OptObjective::Capacity), 1, &Memo::new()).unwrap();
        assert_eq!(got.winner.unwrap().point.capacity_mb, 8);
        assert_eq!(got.best_value, Some(-8.0));
    }

    #[test]
    fn frontier_mode_reuses_pareto_per_workload_cell() {
        let body = json::parse(
            r#"{"techs": ["stt", "sot"], "caps_mb": [1, 2, 4],
                "dnns": ["AlexNet"], "phases": ["inference"],
                "frontier": true}"#,
        )
        .unwrap();
        let r = optimize_request_from_json(&body).unwrap();
        let memo = Memo::new();
        let got = run(&r, 2, &memo).unwrap();
        assert!(got.winner.is_none() && got.best_value.is_none());
        assert!(!got.frontier.is_empty());
        assert_eq!(got.points_evaluated, got.points_total);
        assert_eq!(got.points_pruned, 0);
        // every frontier point is non-dominated within its cell
        let objectives = pareto::edp_area_capacity();
        for a in &got.frontier {
            for b in &got.frontier {
                if a.point != b.point {
                    assert!(!pareto::dominates(b, a, &objectives));
                }
            }
        }
        // spec order is preserved
        let all = r.spec.expand().unwrap();
        let pos: Vec<usize> = got
            .frontier
            .iter()
            .map(|p| all.iter().position(|q| *q == p.point).unwrap())
            .collect();
        assert!(pos.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn counters_account_for_every_implicit_point() {
        let spec = SweepSpec {
            techs: vec![MemTech::SttMram.into()],
            capacities_mb: vec![1, 2],
            dnns: vec!["SqueezeNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![1, 2, 4],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let before = (OPT_EVALUATED.value(), OPT_PRUNED.value());
        let got = run(&req(spec, OptObjective::Latency), 1, &Memo::new()).unwrap();
        assert_eq!(got.points_total, 6);
        assert_eq!(got.points_evaluated + got.points_pruned, 6);
        // other optimize tests share the process-wide counters, so the
        // deltas are at-least, not exact
        assert!(OPT_EVALUATED.value() - before.0 >= got.points_evaluated);
        assert!(OPT_PRUNED.value() - before.1 >= got.points_pruned);
    }
}
