//! Pareto-frontier extraction over sweep results — the co-optimization
//! query: which designs are not dominated on EDP, area and capacity
//! simultaneously (the SOT-MRAM-for-AI-memory-systems co-design
//! question, arXiv:2303.12310, asked inside DeepNVM++'s grid).

use super::PointResult;

/// One optimization objective: extract a scalar from an item; lower is
/// better unless `maximize` is set.
pub struct Objective<T> {
    pub name: &'static str,
    pub maximize: bool,
    pub get: fn(&T) -> f64,
}

/// Signed value such that smaller is always better.
fn score<T>(o: &Objective<T>, x: &T) -> f64 {
    let v = (o.get)(x);
    if o.maximize {
        -v
    } else {
        v
    }
}

/// True when `a` dominates `b`: no worse on every objective and
/// strictly better on at least one.
pub fn dominates<T>(a: &T, b: &T, objectives: &[Objective<T>]) -> bool {
    let mut strictly_better = false;
    for o in objectives {
        let (va, vb) = (score(o, a), score(o, b));
        if va > vb {
            return false;
        }
        if va < vb {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the Pareto-optimal items, in stable input order
/// (duplicates that tie on every objective are all kept). O(n^2) —
/// the grids here are hundreds of points, not millions.
pub fn frontier_indices<T>(items: &[T], objectives: &[Objective<T>]) -> Vec<usize> {
    (0..items.len())
        .filter(|&i| {
            !items
                .iter()
                .enumerate()
                .any(|(j, other)| j != i && dominates(other, &items[i], objectives))
        })
        .collect()
}

/// The Pareto-optimal items themselves, in stable input order.
pub fn frontier<'a, T>(items: &'a [T], objectives: &[Objective<T>]) -> Vec<&'a T> {
    frontier_indices(items, objectives)
        .into_iter()
        .map(|i| &items[i])
        .collect()
}

/// The sweep's standard co-optimization objectives: minimize absolute
/// EDP and silicon area, maximize cache capacity. Absolute EDP is only
/// comparable between points sharing a workload/phase/batch, so apply
/// these within one such group (as `reports::sweep_report` does) —
/// across groups the frontier would just pick the lightest workload.
/// Circuit-only points (no workload evaluation) carry infinite EDP so
/// they never shadow evaluated designs.
pub fn edp_area_capacity() -> [Objective<PointResult>; 3] {
    [
        Objective {
            name: "edp",
            maximize: false,
            get: |p: &PointResult| p.eval.map(|e| e.edp).unwrap_or(f64::INFINITY),
        },
        Objective {
            name: "area_mm2",
            maximize: false,
            get: |p: &PointResult| p.tuned.ppa.area * 1e6,
        },
        Objective {
            name: "capacity_mb",
            maximize: true,
            get: |p: &PointResult| p.point.capacity_mb as f64,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn objs3() -> [Objective<(f64, f64, f64)>; 3] {
        [
            Objective { name: "edp", maximize: false, get: |p: &(f64, f64, f64)| p.0 },
            Objective { name: "area", maximize: false, get: |p: &(f64, f64, f64)| p.1 },
            Objective { name: "cap", maximize: true, get: |p: &(f64, f64, f64)| p.2 },
        ]
    }

    #[test]
    fn dominated_point_dropped() {
        // p1 beats p2 on every axis; p3 wins on EDP alone.
        let pts = [(1.0, 1.0, 4.0), (2.0, 2.0, 2.0), (0.5, 3.0, 4.0)];
        let objs = objs3();
        assert!(dominates(&pts[0], &pts[1], &objs));
        assert!(!dominates(&pts[0], &pts[2], &objs));
        assert_eq!(frontier_indices(&pts, &objs), vec![0, 2]);
    }

    #[test]
    fn ties_keep_both() {
        let pts = [(1.0, 1.0, 1.0), (1.0, 1.0, 1.0)];
        let objs = objs3();
        assert!(!dominates(&pts[0], &pts[1], &objs));
        assert_eq!(frontier_indices(&pts, &objs).len(), 2);
    }

    #[test]
    fn single_objective_degenerates_to_min() {
        let objs = [Objective::<(f64, f64, f64)> {
            name: "edp",
            maximize: false,
            get: |p| p.0,
        }];
        let pts = [(3.0, 0.0, 0.0), (1.0, 0.0, 0.0), (2.0, 0.0, 0.0)];
        assert_eq!(frontier_indices(&pts, &objs), vec![1]);
    }
}
