//! Sweep specification: the queryable design-space grid.
//!
//! A [`SweepSpec`] names one value-list per axis — memory technology,
//! cache capacity, workload, phase, batch size and process node — and
//! [`SweepSpec::expand`] takes their cartesian product into a flat,
//! deterministically ordered list of [`GridPoint`]s. Declarative
//! [`Filter`]s prune the expansion (e.g. NVM-only co-optimization
//! queries) without disturbing the ordering of the surviving points,
//! so results are reproducible regardless of how the grid is later
//! scheduled across workers.

use anyhow::{bail, Result};

use crate::device::MemTech;
use crate::workload::models::{Dnn, Phase};

/// Default capacity axis (MB) — the paper's Algorithm-1/Fig 9/10 set,
/// aliased from the explorer so the grid and the figures can never
/// drift apart.
pub const DEFAULT_CAPACITIES_MB: [u64; 6] =
    crate::nvsim::explorer::PAPER_CAPACITIES_MB;

/// The workload coordinates of a grid point (absent for circuit-only
/// sweeps such as Fig 9, where only the cache PPA is of interest).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkloadPoint {
    /// Zoo workload name (resolved during expansion, so always valid).
    pub dnn: &'static str,
    pub phase: Phase,
    /// Resolved batch size (paper default already applied).
    pub batch: usize,
}

/// One fully resolved point of the design-space grid. The point is its
/// own identity: equal points address the same memoized result.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridPoint {
    pub tech: MemTech,
    pub capacity_mb: u64,
    /// Process node (nm); only 16 nm is calibrated today.
    pub node_nm: u32,
    pub workload: Option<WorkloadPoint>,
}

impl GridPoint {
    /// Canonical content-address of this point (includes the model
    /// version, so cached results are invalidated when the models
    /// change).
    pub fn key(&self) -> String {
        let wl = match self.workload {
            Some(w) => format!("{}:{}:b{}", w.dnn, w.phase.name(), w.batch),
            None => "circuit".to_string(),
        };
        format!(
            "v{}:{}nm:{}:{}MB:{}",
            super::memo::MODEL_VERSION,
            self.node_nm,
            self.tech.name(),
            self.capacity_mb,
            wl
        )
    }

    /// 64-bit FNV-1a hash of [`GridPoint::key`] — the short id used by
    /// the on-disk memo cache.
    pub fn key_hash(&self) -> u64 {
        super::memo::fnv1a64(&self.key())
    }
}

/// Declarative grid filters (applied after cartesian expansion,
/// preserving expansion order).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Filter {
    /// Keep only the NVM rows. The SRAM baseline at each capacity is
    /// still solved internally for normalization.
    NvmOnly,
    TechIs(MemTech),
    CapacityAtLeast(u64),
    CapacityAtMost(u64),
    /// Keep workload points in this phase (circuit-only points pass).
    PhaseIs(Phase),
}

impl Filter {
    pub fn keep(&self, p: &GridPoint) -> bool {
        match self {
            Filter::NvmOnly => p.tech.is_nvm(),
            Filter::TechIs(t) => p.tech == *t,
            Filter::CapacityAtLeast(mb) => p.capacity_mb >= *mb,
            Filter::CapacityAtMost(mb) => p.capacity_mb <= *mb,
            Filter::PhaseIs(ph) => p.workload.map_or(true, |w| w.phase == *ph),
        }
    }
}

/// Axis lists describing one sweep over the cross-layer model.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub techs: Vec<MemTech>,
    pub capacities_mb: Vec<u64>,
    /// Workload names resolved against the zoo (case-insensitive);
    /// empty = circuit-only sweep (one point per tech x capacity).
    pub dnns: Vec<String>,
    pub phases: Vec<Phase>,
    /// Batch sizes; empty = the paper batch per phase (4 / 64).
    pub batches: Vec<usize>,
    /// Process-node axis (nm).
    pub nodes_nm: Vec<u32>,
    pub filters: Vec<Filter>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            techs: MemTech::ALL.to_vec(),
            capacities_mb: DEFAULT_CAPACITIES_MB.to_vec(),
            dnns: Dnn::zoo().iter().map(|d| d.name.to_string()).collect(),
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        }
    }
}

impl SweepSpec {
    /// A PPA-only sweep (no workload axis) — the Fig 9 shape.
    pub fn circuit_only(techs: Vec<MemTech>, capacities_mb: Vec<u64>) -> Self {
        SweepSpec {
            techs,
            capacities_mb,
            dnns: vec![],
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        }
    }

    /// Cartesian expansion into spec order: node, then tech, then
    /// capacity, then workload, then phase, then batch (inner axes vary
    /// fastest). Validation errors — unknown workload, uncalibrated
    /// node, empty axis — surface here, before any work is scheduled.
    pub fn expand(&self) -> Result<Vec<GridPoint>> {
        if self.techs.is_empty() {
            bail!("sweep spec has no memory technologies");
        }
        if self.capacities_mb.is_empty() {
            bail!("sweep spec has no capacities");
        }
        if self.nodes_nm.is_empty() {
            bail!("sweep spec has no process nodes");
        }
        for &node in &self.nodes_nm {
            if node != 16 {
                bail!("process node {node}nm is not calibrated (only 16nm)");
            }
        }
        for &mb in &self.capacities_mb {
            if mb == 0 {
                bail!("capacity must be at least 1 MB");
            }
        }
        let mut dnns: Vec<&'static str> = Vec::new();
        for name in &self.dnns {
            dnns.push(resolve_dnn(name)?);
        }
        if !dnns.is_empty() && self.phases.is_empty() {
            bail!("sweep spec has workloads but no phases");
        }
        for &b in &self.batches {
            if b == 0 {
                bail!("batch size must be at least 1");
            }
        }

        let mut out = Vec::new();
        for &node in &self.nodes_nm {
            for &tech in &self.techs {
                for &mb in &self.capacities_mb {
                    if dnns.is_empty() {
                        out.push(GridPoint {
                            tech,
                            capacity_mb: mb,
                            node_nm: node,
                            workload: None,
                        });
                        continue;
                    }
                    for &dnn in &dnns {
                        for &phase in &self.phases {
                            if self.batches.is_empty() {
                                out.push(GridPoint {
                                    tech,
                                    capacity_mb: mb,
                                    node_nm: node,
                                    workload: Some(WorkloadPoint {
                                        dnn,
                                        phase,
                                        batch: phase.paper_batch(),
                                    }),
                                });
                            } else {
                                for &batch in &self.batches {
                                    out.push(GridPoint {
                                        tech,
                                        capacity_mb: mb,
                                        node_nm: node,
                                        workload: Some(WorkloadPoint {
                                            dnn,
                                            phase,
                                            batch,
                                        }),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out.retain(|p| self.filters.iter().all(|f| f.keep(p)));
        Ok(out)
    }
}

/// Resolve a user-supplied workload name against the zoo
/// (case-insensitive, whitespace-tolerant).
pub fn resolve_dnn(name: &str) -> Result<&'static str> {
    let want = name.trim();
    for d in Dnn::zoo() {
        if d.name.eq_ignore_ascii_case(want) {
            return Ok(d.name);
        }
    }
    bail!("unknown workload '{want}' (see `deepnvm table3` for the zoo)")
}

/// Parse a technology name from CLI input.
pub fn parse_tech(s: &str) -> Result<MemTech> {
    match s.trim().to_ascii_lowercase().as_str() {
        "sram" => Ok(MemTech::Sram),
        "stt" | "stt-mram" | "sttmram" => Ok(MemTech::SttMram),
        "sot" | "sot-mram" | "sotmram" => Ok(MemTech::SotMram),
        other => bail!("unknown memory technology '{other}' (sram|stt|sot)"),
    }
}

/// Parse a phase name from CLI input.
pub fn parse_phase(s: &str) -> Result<Phase> {
    match s.trim().to_ascii_lowercase().as_str() {
        "inference" | "infer" | "i" => Ok(Phase::Inference),
        "training" | "train" | "t" => Ok(Phase::Training),
        other => bail!("unknown phase '{other}' (inference|training)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_counts_and_order() {
        let spec = SweepSpec {
            techs: vec![MemTech::Sram, MemTech::SttMram],
            capacities_mb: vec![1, 2],
            dnns: vec!["AlexNet".into(), "VGG-16".into()],
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let pts = spec.expand().unwrap();
        // 2 techs x 2 caps x 2 dnns x 2 phases
        assert_eq!(pts.len(), 16);
        // tech is the outer axis, phase the inner
        assert_eq!(pts[0].tech, MemTech::Sram);
        assert_eq!(pts[0].capacity_mb, 1);
        assert_eq!(pts[0].workload.unwrap().dnn, "AlexNet");
        assert_eq!(pts[0].workload.unwrap().phase, Phase::Inference);
        assert_eq!(pts[1].workload.unwrap().phase, Phase::Training);
        assert_eq!(pts[15].tech, MemTech::SttMram);
        assert_eq!(pts[15].capacity_mb, 2);
        // paper batches applied
        assert_eq!(pts[0].workload.unwrap().batch, 4);
        assert_eq!(pts[1].workload.unwrap().batch, 64);
        // expansion is deterministic
        assert_eq!(pts, spec.expand().unwrap());
    }

    #[test]
    fn circuit_only_expansion() {
        let spec = SweepSpec::circuit_only(MemTech::ALL.to_vec(), vec![1, 2, 4]);
        let pts = spec.expand().unwrap();
        assert_eq!(pts.len(), 9);
        assert!(pts.iter().all(|p| p.workload.is_none()));
    }

    #[test]
    fn filters_prune_but_keep_order() {
        let spec = SweepSpec {
            filters: vec![Filter::NvmOnly, Filter::CapacityAtLeast(8)],
            ..SweepSpec::circuit_only(MemTech::ALL.to_vec(), vec![1, 8, 32])
        };
        let pts = spec.expand().unwrap();
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().all(|p| p.tech.is_nvm() && p.capacity_mb >= 8));
        assert_eq!(pts[0].tech, MemTech::SttMram);
        assert_eq!(pts[0].capacity_mb, 8);
    }

    #[test]
    fn rejects_bad_specs() {
        let s = SweepSpec { dnns: vec!["NotANet".into()], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        let s = SweepSpec { nodes_nm: vec![7], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        let s = SweepSpec { techs: vec![], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        let s = SweepSpec { batches: vec![0], ..SweepSpec::default() };
        assert!(s.expand().is_err());
    }

    #[test]
    fn workload_names_resolve_case_insensitively() {
        assert_eq!(resolve_dnn("alexnet").unwrap(), "AlexNet");
        assert_eq!(resolve_dnn(" VGG-16 ").unwrap(), "VGG-16");
        assert!(resolve_dnn("lenet").is_err());
    }

    #[test]
    fn keys_are_stable_and_distinct() {
        let spec = SweepSpec::default();
        let pts = spec.expand().unwrap();
        let mut keys: Vec<String> = pts.iter().map(|p| p.key()).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "grid keys must be unique");
        // hash is a pure function of the key
        assert_eq!(pts[0].key_hash(), pts[0].key_hash());
    }

    #[test]
    fn parsers_accept_cli_shorthand() {
        assert_eq!(parse_tech("STT").unwrap(), MemTech::SttMram);
        assert_eq!(parse_tech("sot-mram").unwrap(), MemTech::SotMram);
        assert!(parse_tech("dram").is_err());
        assert_eq!(parse_phase("T").unwrap(), Phase::Training);
        assert!(parse_phase("both").is_err());
    }
}
