//! Sweep specification: the queryable design-space grid.
//!
//! A [`SweepSpec`] names one value-list per axis — memory technology,
//! cache capacity, workload, phase, batch size and process node — and
//! [`SweepSpec::expand`] takes their cartesian product into a flat,
//! deterministically ordered list of [`GridPoint`]s. Declarative
//! [`Filter`]s prune the expansion (e.g. NVM-only co-optimization
//! queries) without disturbing the ordering of the surviving points,
//! so results are reproducible regardless of how the grid is later
//! scheduled across workers.

use anyhow::{anyhow, bail, Result};

use crate::device::MemTech;
use crate::nvsim::org::ASSOC;
use crate::nvsim::{HybridSel, TechSel};
use crate::util::json::Json;
use crate::workload::models::{Dnn, Phase};

/// Default capacity axis (MB) — the paper's Algorithm-1/Fig 9/10 set,
/// aliased from the explorer so the grid and the figures can never
/// drift apart.
pub const DEFAULT_CAPACITIES_MB: [u64; 6] =
    crate::nvsim::explorer::PAPER_CAPACITIES_MB;

/// Largest accepted cache capacity (MB). Far beyond any plausible LLC
/// (the paper tops out at 32), but small enough that `mb * MB` can
/// never overflow the byte math downstream — untrusted HTTP inputs
/// reach [`SweepSpec::expand`] unfiltered.
pub const MAX_CAPACITY_MB: u64 = 4096;

/// Largest accepted batch size. Far beyond any practical sweep axis
/// (the paper uses 4/64), but small enough that batch-line term
/// evaluation stays within the overflow-free envelope the memo's
/// merge-time sanity gate proves for merged traffic coefficients
/// (which checks terms at exactly this batch). Enforced wherever a
/// grid point is formed from untrusted input: spec expansion and the
/// serve `/solve` body.
pub const MAX_BATCH: usize = 1 << 20;

/// The workload coordinates of a grid point (absent for circuit-only
/// sweeps such as Fig 9, where only the cache PPA is of interest).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkloadPoint {
    /// Zoo workload name (resolved during expansion, so always valid).
    pub dnn: &'static str,
    pub phase: Phase,
    /// Resolved batch size (paper default already applied).
    pub batch: usize,
}

/// One fully resolved point of the design-space grid. The point is its
/// own identity: equal points address the same memoized result.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridPoint {
    /// Tech-axis selection — a pure [`MemTech`] or a way-partitioned
    /// hybrid ([`TechSel::Hybrid`]). Hybrid parameters are part of the
    /// point identity, so they bind into [`GridPoint::key`] and every
    /// payload hash derived from it.
    pub tech: TechSel,
    pub capacity_mb: u64,
    /// Process node (nm); see
    /// [`crate::device::CALIBRATED_NODES_NM`] for the calibrated set.
    pub node_nm: u32,
    pub workload: Option<WorkloadPoint>,
}

impl GridPoint {
    /// Canonical content-address of this point (includes the model
    /// version, so cached results are invalidated when the models
    /// change).
    pub fn key(&self) -> String {
        let wl = match self.workload {
            Some(w) => format!("{}:{}:b{}", w.dnn, w.phase.name(), w.batch),
            None => "circuit".to_string(),
        };
        format!(
            "v{}:{}nm:{}:{}MB:{}",
            super::memo::MODEL_VERSION,
            self.node_nm,
            self.tech.name(),
            self.capacity_mb,
            wl
        )
    }

    /// 64-bit FNV-1a hash of [`GridPoint::key`] — the short id used by
    /// the on-disk memo cache.
    pub fn key_hash(&self) -> u64 {
        super::memo::fnv1a64(&self.key())
    }
}

/// Declarative grid filters (applied after cartesian expansion,
/// preserving expansion order).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Filter {
    /// Keep only the NVM rows. The SRAM baseline at each capacity is
    /// still solved internally for normalization.
    NvmOnly,
    TechIs(MemTech),
    CapacityAtLeast(u64),
    CapacityAtMost(u64),
    /// Keep workload points in this phase (circuit-only points pass).
    PhaseIs(Phase),
}

impl Filter {
    pub fn keep(&self, p: &GridPoint) -> bool {
        match self {
            Filter::NvmOnly => p.tech.is_nvm(),
            Filter::TechIs(t) => p.tech == *t,
            Filter::CapacityAtLeast(mb) => p.capacity_mb >= *mb,
            Filter::CapacityAtMost(mb) => p.capacity_mb <= *mb,
            Filter::PhaseIs(ph) => p.workload.map_or(true, |w| w.phase == *ph),
        }
    }
}

/// Axis lists describing one sweep over the cross-layer model.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Tech-axis selections (pure technologies and/or hybrids).
    pub techs: Vec<TechSel>,
    pub capacities_mb: Vec<u64>,
    /// Workload names resolved against the zoo (case-insensitive);
    /// empty = circuit-only sweep (one point per tech x capacity).
    pub dnns: Vec<String>,
    pub phases: Vec<Phase>,
    /// Batch sizes; empty = the paper batch per phase (4 / 64).
    pub batches: Vec<usize>,
    /// Process-node axis (nm).
    pub nodes_nm: Vec<u32>,
    pub filters: Vec<Filter>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            techs: TechSel::pure_all(),
            capacities_mb: DEFAULT_CAPACITIES_MB.to_vec(),
            dnns: Dnn::zoo().iter().map(|d| d.name.to_string()).collect(),
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        }
    }
}

impl SweepSpec {
    /// A PPA-only sweep (no workload axis) — the Fig 9 shape.
    pub fn circuit_only(techs: Vec<MemTech>, capacities_mb: Vec<u64>) -> Self {
        SweepSpec {
            techs: TechSel::pures(&techs),
            capacities_mb,
            dnns: vec![],
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        }
    }

    /// One-line human description of the grid shape, for scheduler and
    /// CLI logs ("3 tech(s) x 6 cap(s) x 5 dnn(s) x 2 phase(s) on 1
    /// node(s): 180 points"). Invalid specs read "? points" — callers
    /// surface the expansion error itself separately.
    pub fn summary(&self) -> String {
        let workloads = if self.dnns.is_empty() {
            "circuit-only".to_string()
        } else {
            format!("{} dnn(s) x {} phase(s)", self.dnns.len(), self.phases.len())
        };
        let points = match self.expand() {
            Ok(p) => p.len().to_string(),
            Err(_) => "?".to_string(),
        };
        format!(
            "{} tech(s) x {} cap(s) x {} on {} node(s): {points} points",
            self.techs.len(),
            self.capacities_mb.len(),
            workloads,
            self.nodes_nm.len()
        )
    }

    /// Cartesian expansion into spec order: node, then tech, then
    /// capacity, then workload, then phase, then batch (inner axes vary
    /// fastest). Validation errors — unknown workload, uncalibrated
    /// node, empty axis — surface here, before any work is scheduled.
    pub fn expand(&self) -> Result<Vec<GridPoint>> {
        if self.techs.is_empty() {
            bail!("sweep spec has no memory technologies");
        }
        if self.capacities_mb.is_empty() {
            bail!("sweep spec has no capacities");
        }
        if self.nodes_nm.is_empty() {
            bail!("sweep spec has no process nodes");
        }
        for &node in &self.nodes_nm {
            if !crate::device::node_calibrated(node) {
                // Typed, not stringly: the serve layer downcasts this
                // to map it onto the `uncalibrated_node` error kind.
                return Err(crate::device::UncalibratedNode(node).into());
            }
        }
        for &tech in &self.techs {
            if let TechSel::Hybrid(h) = tech {
                // `parse_tech_sel` already enforces both; this guards
                // programmatic construction before work is scheduled.
                if !h.nvm.is_nvm() {
                    bail!("hybrid partner must be an NVM, not {}", h.nvm);
                }
                if h.sram_ways as usize > ASSOC {
                    bail!(
                        "hybrid SRAM way count {} exceeds associativity {ASSOC}",
                        h.sram_ways
                    );
                }
            }
        }
        for &mb in &self.capacities_mb {
            if mb == 0 {
                bail!("capacity must be at least 1 MB");
            }
            if mb > MAX_CAPACITY_MB {
                bail!("capacity {mb} MB exceeds the {MAX_CAPACITY_MB} MB model limit");
            }
        }
        let mut dnns: Vec<&'static str> = Vec::new();
        for name in &self.dnns {
            dnns.push(resolve_dnn(name)?);
        }
        if !dnns.is_empty() && self.phases.is_empty() {
            bail!("sweep spec has workloads but no phases");
        }
        for &b in &self.batches {
            if b == 0 {
                bail!("batch size must be at least 1");
            }
            if b > MAX_BATCH {
                bail!("batch size {b} exceeds the {MAX_BATCH} model limit");
            }
        }

        let mut out = Vec::new();
        for &node in &self.nodes_nm {
            for &tech in &self.techs {
                for &mb in &self.capacities_mb {
                    if dnns.is_empty() {
                        out.push(GridPoint {
                            tech,
                            capacity_mb: mb,
                            node_nm: node,
                            workload: None,
                        });
                        continue;
                    }
                    for &dnn in &dnns {
                        for &phase in &self.phases {
                            if self.batches.is_empty() {
                                out.push(GridPoint {
                                    tech,
                                    capacity_mb: mb,
                                    node_nm: node,
                                    workload: Some(WorkloadPoint {
                                        dnn,
                                        phase,
                                        batch: phase.paper_batch(),
                                    }),
                                });
                            } else {
                                for &batch in &self.batches {
                                    out.push(GridPoint {
                                        tech,
                                        capacity_mb: mb,
                                        node_nm: node,
                                        workload: Some(WorkloadPoint {
                                            dnn,
                                            phase,
                                            batch,
                                        }),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out.retain(|p| self.filters.iter().all(|f| f.keep(p)));
        Ok(out)
    }
}

/// Serialize a [`Filter`] as a tagged JSON object (`{"kind": ...}`).
pub fn filter_to_json(f: &Filter) -> Json {
    let mut o = Json::obj();
    match f {
        Filter::NvmOnly => {
            o.set("kind", Json::Str("nvm_only".into()));
        }
        Filter::TechIs(t) => {
            o.set("kind", Json::Str("tech_is".into()));
            o.set("tech", Json::Str(t.name().to_string()));
        }
        Filter::CapacityAtLeast(mb) => {
            o.set("kind", Json::Str("capacity_at_least".into()));
            o.set("mb", Json::Num(*mb as f64));
        }
        Filter::CapacityAtMost(mb) => {
            o.set("kind", Json::Str("capacity_at_most".into()));
            o.set("mb", Json::Num(*mb as f64));
        }
        Filter::PhaseIs(ph) => {
            o.set("kind", Json::Str("phase_is".into()));
            o.set("phase", Json::Str(ph.name().to_string()));
        }
    }
    o
}

/// Parse a [`Filter`] from its tagged JSON form.
pub fn filter_from_json(j: &Json) -> Result<Filter> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("filter needs a string 'kind'"))?;
    Ok(match kind {
        "nvm_only" => Filter::NvmOnly,
        "tech_is" => Filter::TechIs(parse_tech(
            j.get("tech")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tech_is filter needs 'tech'"))?,
        )?),
        "capacity_at_least" => Filter::CapacityAtLeast(
            j.get("mb")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("capacity_at_least filter needs integer 'mb'"))?,
        ),
        "capacity_at_most" => Filter::CapacityAtMost(
            j.get("mb")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("capacity_at_most filter needs integer 'mb'"))?,
        ),
        "phase_is" => Filter::PhaseIs(parse_phase(
            j.get("phase")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("phase_is filter needs 'phase'"))?,
        )?),
        other => bail!("unknown filter kind '{other}'"),
    })
}

/// Serialize a [`SweepSpec`] to JSON — the wire format of the `serve`
/// subsystem's `POST /sweep` body. Every axis is always written, so
/// the document is self-describing.
pub fn spec_to_json(s: &SweepSpec) -> Json {
    let mut o = Json::obj();
    o.set(
        "techs",
        Json::Arr(s.techs.iter().map(|t| Json::Str(t.name())).collect()),
    );
    o.set(
        "caps_mb",
        Json::Arr(s.capacities_mb.iter().map(|&m| Json::Num(m as f64)).collect()),
    );
    o.set(
        "dnns",
        Json::Arr(s.dnns.iter().map(|d| Json::Str(d.clone())).collect()),
    );
    o.set(
        "phases",
        Json::Arr(s.phases.iter().map(|p| Json::Str(p.name().to_string())).collect()),
    );
    o.set(
        "batches",
        Json::Arr(s.batches.iter().map(|&b| Json::Num(b as f64)).collect()),
    );
    o.set(
        "nodes_nm",
        Json::Arr(s.nodes_nm.iter().map(|&n| Json::Num(n as f64)).collect()),
    );
    o.set("filters", Json::Arr(s.filters.iter().map(filter_to_json).collect()));
    o
}

fn str_axis(j: &Json, key: &str) -> Result<Option<Vec<String>>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("'{key}' must be an array of strings"))?;
            let mut out = Vec::with_capacity(arr.len());
            for e in arr {
                out.push(
                    e.as_str()
                        .ok_or_else(|| anyhow!("'{key}' entries must be strings"))?
                        .to_string(),
                );
            }
            Ok(Some(out))
        }
    }
}

/// Extract an optional array-of-non-negative-integers axis (shared
/// with the serve routes so every grid axis parses identically).
pub(crate) fn u64_axis(j: &Json, key: &str) -> Result<Option<Vec<u64>>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("'{key}' must be an array of integers"))?;
            let mut out = Vec::with_capacity(arr.len());
            for e in arr {
                out.push(
                    e.as_u64()
                        .ok_or_else(|| anyhow!("'{key}' entries must be non-negative integers"))?,
                );
            }
            Ok(Some(out))
        }
    }
}

/// Parse a [`SweepSpec`] from JSON. Absent axes take the paper
/// defaults ([`SweepSpec::default`]); a *present but empty* `dnns`
/// array means a circuit-only sweep, exactly like the CLI's
/// `--dnns none`. A top-level `"nvm_only": true` is accepted as
/// shorthand for the [`Filter::NvmOnly`] filter. Unknown keys are
/// ignored so the spec can ride inside a larger request body.
/// Validation of the axis *values* (unknown workloads, uncalibrated
/// nodes) still happens in [`SweepSpec::expand`].
pub fn spec_from_json(j: &Json) -> Result<SweepSpec> {
    let mut s = SweepSpec::default();
    if let Some(names) = str_axis(j, "techs")? {
        let mut techs = Vec::with_capacity(names.len());
        for n in &names {
            techs.push(parse_tech_sel(n)?);
        }
        s.techs = techs;
    }
    if let Some(caps) = u64_axis(j, "caps_mb")? {
        s.capacities_mb = caps;
    }
    if let Some(dnns) = str_axis(j, "dnns")? {
        s.dnns = dnns;
    }
    if let Some(names) = str_axis(j, "phases")? {
        let mut phases = Vec::with_capacity(names.len());
        for n in &names {
            phases.push(parse_phase(n)?);
        }
        s.phases = phases;
    }
    if let Some(batches) = u64_axis(j, "batches")? {
        let mut out = Vec::with_capacity(batches.len());
        for b in batches {
            if b > usize::MAX as u64 {
                bail!("'batches' entry {b} is out of range");
            }
            out.push(b as usize);
        }
        s.batches = out;
    }
    if let Some(nodes) = u64_axis(j, "nodes_nm")? {
        // Range-check before narrowing: a truncating cast would let
        // 2^32+16 alias to the calibrated 16 nm node.
        let mut out = Vec::with_capacity(nodes.len());
        for n in nodes {
            if n > u32::MAX as u64 {
                bail!("'nodes_nm' entry {n} is out of range");
            }
            out.push(n as u32);
        }
        s.nodes_nm = out;
    }
    if let Some(v) = j.get("filters") {
        let arr = v
            .as_arr()
            .ok_or_else(|| anyhow!("'filters' must be an array"))?;
        let mut filters = Vec::with_capacity(arr.len());
        for f in arr {
            filters.push(filter_from_json(f)?);
        }
        s.filters = filters;
    } else {
        s.filters = vec![];
    }
    if j.get("nvm_only").and_then(Json::as_bool) == Some(true)
        && !s.filters.contains(&Filter::NvmOnly)
    {
        s.filters.push(Filter::NvmOnly);
    }
    Ok(s)
}

/// Resolve a user-supplied workload name against the zoo
/// (case-insensitive, whitespace-tolerant).
pub fn resolve_dnn(name: &str) -> Result<&'static str> {
    let want = name.trim();
    for d in Dnn::zoo() {
        if d.name.eq_ignore_ascii_case(want) {
            return Ok(d.name);
        }
    }
    bail!("unknown workload '{want}' (see `deepnvm table3` for the zoo)")
}

/// Parse a technology name from CLI input.
pub fn parse_tech(s: &str) -> Result<MemTech> {
    match s.trim().to_ascii_lowercase().as_str() {
        "sram" => Ok(MemTech::Sram),
        "stt" | "stt-mram" | "sttmram" => Ok(MemTech::SttMram),
        "sot" | "sot-mram" | "sotmram" => Ok(MemTech::SotMram),
        other => bail!("unknown memory technology '{other}' (sram|stt|sot)"),
    }
}

/// Parse a tech-axis selection: everything [`parse_tech`] accepts,
/// plus way-partitioned hybrids spelled `hybrid-<nvm>:<ways>@<steer>`
/// (e.g. `hybrid-stt:4@0.85`) — `ways` SRAM ways out of the cache's
/// 16-way associativity, `steer` the write-steering fraction in
/// [0, 1]. The inverse of [`TechSel::name`].
pub fn parse_tech_sel(s: &str) -> Result<TechSel> {
    let lowered = s.trim().to_ascii_lowercase();
    let Some(rest) = lowered.strip_prefix("hybrid-") else {
        return Ok(TechSel::Pure(parse_tech(s)?));
    };
    let usage = "hybrid-<stt|sot>:<ways>@<steer>";
    let (nvm_s, params) = rest
        .split_once(':')
        .ok_or_else(|| anyhow!("hybrid tech '{s}' must be {usage}"))?;
    let nvm = parse_tech(nvm_s)?;
    if !nvm.is_nvm() {
        bail!("hybrid partner must be an NVM, not '{nvm_s}'");
    }
    let (ways_s, steer_s) = params
        .split_once('@')
        .ok_or_else(|| anyhow!("hybrid tech '{s}' must be {usage}"))?;
    let sram_ways: u8 = ways_s
        .trim()
        .parse()
        .map_err(|_| anyhow!("hybrid SRAM way count '{ways_s}' must be an integer"))?;
    if sram_ways as usize > ASSOC {
        bail!("hybrid SRAM way count {sram_ways} exceeds associativity {ASSOC}");
    }
    let steer: f64 = steer_s
        .trim()
        .parse()
        .map_err(|_| anyhow!("hybrid steer '{steer_s}' must be a number"))?;
    if !steer.is_finite() || !(0.0..=1.0).contains(&steer) {
        bail!("hybrid steer '{steer_s}' must be in [0, 1]");
    }
    // quantize to basis points: the resolution the key encodes
    let steer_bp = (steer * 1e4).round() as u16;
    Ok(TechSel::Hybrid(HybridSel { nvm, sram_ways, steer_bp }))
}

/// Parse a phase name from CLI input.
pub fn parse_phase(s: &str) -> Result<Phase> {
    match s.trim().to_ascii_lowercase().as_str() {
        "inference" | "infer" | "i" => Ok(Phase::Inference),
        "training" | "train" | "t" => Ok(Phase::Training),
        other => bail!("unknown phase '{other}' (inference|training)"),
    }
}

/// Scalar objectives `POST /optimize` and `deepnvm optimize` accept.
/// All are minimized except `Capacity`, which is maximized (scored
/// internally as its negation so one comparison rule covers all five).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptObjective {
    Edp,
    Edap,
    Energy,
    Latency,
    Capacity,
}

impl OptObjective {
    pub const ALL: [OptObjective; 5] = [
        OptObjective::Edp,
        OptObjective::Edap,
        OptObjective::Energy,
        OptObjective::Latency,
        OptObjective::Capacity,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OptObjective::Edp => "edp",
            OptObjective::Edap => "edap",
            OptObjective::Energy => "energy",
            OptObjective::Latency => "latency",
            OptObjective::Capacity => "capacity",
        }
    }

    /// Objectives that project workload traffic through the energy
    /// model; a circuit-only grid cannot answer them.
    pub fn needs_workload(self) -> bool {
        matches!(
            self,
            OptObjective::Edp | OptObjective::Energy | OptObjective::Latency
        )
    }
}

/// Parse an objective name from CLI or HTTP input.
pub fn parse_objective(s: &str) -> Result<OptObjective> {
    match s.trim().to_ascii_lowercase().as_str() {
        "edp" => Ok(OptObjective::Edp),
        "edap" => Ok(OptObjective::Edap),
        "energy" => Ok(OptObjective::Energy),
        "latency" => Ok(OptObjective::Latency),
        "capacity" => Ok(OptObjective::Capacity),
        other => bail!("unknown objective '{other}' (edp|edap|energy|latency|capacity)"),
    }
}

/// One `/optimize` request: the implicit grid (a full [`SweepSpec`],
/// whose `techs`/`nodes_nm` axes double as the membership constraints
/// `tech ∈ {…}` / `node ∈ {…}`) plus the objective and the scalar
/// design budgets.
#[derive(Clone, Debug)]
pub struct OptimizeRequest {
    pub spec: SweepSpec,
    pub objective: OptObjective,
    /// Feasibility budget: tuned cache area (mm²) must not exceed this.
    pub area_max_mm2: Option<f64>,
    /// Feasibility budget: tuned leakage power (W) must not exceed this.
    pub leakage_max_w: Option<f64>,
    /// Multi-objective mode: return the EDP/area/capacity Pareto
    /// frontier of the feasible grid instead of a scalar winner.
    pub frontier: bool,
}

impl OptimizeRequest {
    /// Constraint check for one tuned design. Batch-independent, so an
    /// infeasible (tech, capacity, node) column prunes its whole
    /// workload rectangle before any point is evaluated.
    pub fn feasible(&self, ppa: &crate::nvsim::model::CachePpa) -> bool {
        self.area_max_mm2.is_none_or(|a| ppa.area * 1e6 <= a)
            && self.leakage_max_w.is_none_or(|l| ppa.leakage_power <= l)
    }
}

/// An optional positive finite budget value (`"area_max_mm2"`,
/// `"leakage_max_w"`).
fn budget(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let b = v
                .as_f64()
                .ok_or_else(|| anyhow!("'{key}' must be a number"))?;
            if !b.is_finite() || b <= 0.0 {
                bail!("'{key}' must be a positive finite number");
            }
            Ok(Some(b))
        }
    }
}

/// Parse an [`OptimizeRequest`] from JSON. The grid axes parse exactly
/// as a `/sweep` body ([`spec_from_json`] — absent axes default, and
/// unknown keys are ignored); `objective` defaults to `edp`.
pub fn optimize_request_from_json(j: &Json) -> Result<OptimizeRequest> {
    let spec = spec_from_json(j)?;
    let objective = match j.get("objective") {
        None | Some(Json::Null) => OptObjective::Edp,
        Some(v) => parse_objective(
            v.as_str()
                .ok_or_else(|| anyhow!("'objective' must be a string"))?,
        )?,
    };
    Ok(OptimizeRequest {
        spec,
        objective,
        area_max_mm2: budget(j, "area_max_mm2")?,
        leakage_max_w: budget(j, "leakage_max_w")?,
        frontier: j.get("frontier").and_then(Json::as_bool).unwrap_or(false),
    })
}

/// Serialize an [`OptimizeRequest`] — the wire format of `POST
/// /optimize` (also what the `deepnvm optimize` CLI builds internally,
/// so both surfaces can never drift apart).
pub fn optimize_request_to_json(r: &OptimizeRequest) -> Json {
    let mut o = spec_to_json(&r.spec);
    o.set("objective", Json::Str(r.objective.name().to_string()));
    if let Some(a) = r.area_max_mm2 {
        o.set("area_max_mm2", Json::Num(a));
    }
    if let Some(l) = r.leakage_max_w {
        o.set("leakage_max_w", Json::Num(l));
    }
    if r.frontier {
        o.set("frontier", Json::Bool(true));
    }
    o
}

/// The `/optimize` result: the winning grid point (absent in frontier
/// mode), the Pareto frontier (empty in scalar mode), and the search
/// accounting that the pruning-ratio CI gate reads.
#[derive(Clone, Debug)]
pub struct OptimizeResponse {
    pub objective: OptObjective,
    pub winner: Option<super::PointResult>,
    /// The winner's objective score ([`super::optimize::objective_value`]).
    pub best_value: Option<f64>,
    pub frontier: Vec<super::PointResult>,
    /// Implicit grid size (post-filter spec expansion count).
    pub points_total: u64,
    /// Grid points folded through [`super::evaluate_point`].
    pub points_evaluated: u64,
    /// `points_total - points_evaluated`: never materialized.
    pub points_pruned: u64,
}

/// Serialize an [`OptimizeResponse`]; the winner and frontier entries
/// use the same point document as `/solve` results and memo exports.
pub fn optimize_response_to_json(r: &OptimizeResponse) -> Json {
    let mut o = Json::obj();
    o.set("objective", Json::Str(r.objective.name().to_string()));
    o.set(
        "winner",
        match &r.winner {
            Some(w) => super::memo::point_to_json(w),
            None => Json::Null,
        },
    );
    o.set(
        "best_value",
        match r.best_value {
            Some(v) => Json::Num(v),
            None => Json::Null,
        },
    );
    o.set(
        "frontier",
        Json::Arr(r.frontier.iter().map(super::memo::point_to_json).collect()),
    );
    o.set("points_total", Json::Num(r.points_total as f64));
    o.set("points_evaluated", Json::Num(r.points_evaluated as f64));
    o.set("points_pruned", Json::Num(r.points_pruned as f64));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_counts_and_order() {
        let spec = SweepSpec {
            techs: TechSel::pures(&[MemTech::Sram, MemTech::SttMram]),
            capacities_mb: vec![1, 2],
            dnns: vec!["AlexNet".into(), "VGG-16".into()],
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let pts = spec.expand().unwrap();
        // 2 techs x 2 caps x 2 dnns x 2 phases
        assert_eq!(pts.len(), 16);
        // tech is the outer axis, phase the inner
        assert_eq!(pts[0].tech, MemTech::Sram);
        assert_eq!(pts[0].capacity_mb, 1);
        assert_eq!(pts[0].workload.unwrap().dnn, "AlexNet");
        assert_eq!(pts[0].workload.unwrap().phase, Phase::Inference);
        assert_eq!(pts[1].workload.unwrap().phase, Phase::Training);
        assert_eq!(pts[15].tech, MemTech::SttMram);
        assert_eq!(pts[15].capacity_mb, 2);
        // paper batches applied
        assert_eq!(pts[0].workload.unwrap().batch, 4);
        assert_eq!(pts[1].workload.unwrap().batch, 64);
        // expansion is deterministic
        assert_eq!(pts, spec.expand().unwrap());
    }

    #[test]
    fn circuit_only_expansion() {
        let spec = SweepSpec::circuit_only(MemTech::ALL.to_vec(), vec![1, 2, 4]);
        let pts = spec.expand().unwrap();
        assert_eq!(pts.len(), 9);
        assert!(pts.iter().all(|p| p.workload.is_none()));
    }

    #[test]
    fn multi_node_expansion_is_node_outermost_and_keyed_apart() {
        let spec = SweepSpec {
            nodes_nm: vec![16, 7, 5],
            ..SweepSpec::circuit_only(vec![MemTech::SttMram], vec![1, 2])
        };
        let pts = spec.expand().unwrap();
        assert_eq!(pts.len(), 6, "3 nodes x 2 caps");
        assert_eq!(pts[0].node_nm, 16);
        assert_eq!(pts[2].node_nm, 7);
        assert_eq!(pts[4].node_nm, 5);
        // same (tech, capacity) at different nodes must never share a
        // content key — the memo isolation guarantee
        let keys: std::collections::HashSet<String> =
            pts.iter().map(|p| p.key()).collect();
        assert_eq!(keys.len(), 6);
        assert!(pts[0].key().contains("16nm") && pts[4].key().contains("5nm"));
    }

    #[test]
    fn filters_prune_but_keep_order() {
        let spec = SweepSpec {
            filters: vec![Filter::NvmOnly, Filter::CapacityAtLeast(8)],
            ..SweepSpec::circuit_only(MemTech::ALL.to_vec(), vec![1, 8, 32])
        };
        let pts = spec.expand().unwrap();
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().all(|p| p.tech.is_nvm() && p.capacity_mb >= 8));
        assert_eq!(pts[0].tech, MemTech::SttMram);
        assert_eq!(pts[0].capacity_mb, 8);
    }

    #[test]
    fn rejects_bad_specs() {
        let s = SweepSpec { dnns: vec!["NotANet".into()], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        let s = SweepSpec { nodes_nm: vec![9], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        let s = SweepSpec { nodes_nm: vec![], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        let s = SweepSpec { techs: vec![], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        let s = SweepSpec { batches: vec![0], ..SweepSpec::default() };
        assert!(s.expand().is_err());

        // a batch beyond MAX_BATCH would escape the overflow envelope
        // the memo's merge sanity gate proves for traffic coefficients
        let s = SweepSpec {
            batches: vec![MAX_BATCH + 1],
            ..SweepSpec::default()
        };
        assert!(s.expand().is_err());
        let s = SweepSpec { batches: vec![MAX_BATCH], ..SweepSpec::default() };
        assert!(s.expand().is_ok());

        // 2^44 MB would overflow the byte math (mb * 2^20) downstream
        let s = SweepSpec {
            capacities_mb: vec![1 << 44],
            ..SweepSpec::default()
        };
        assert!(s.expand().is_err());
    }

    #[test]
    fn summary_names_the_grid_shape() {
        let s = SweepSpec::circuit_only(MemTech::ALL.to_vec(), vec![1, 2]);
        assert_eq!(s.summary(), "3 tech(s) x 2 cap(s) x circuit-only on 1 node(s): 6 points");
        let d = SweepSpec {
            nodes_nm: vec![16, 7, 5],
            ..SweepSpec::default()
        };
        assert!(d.summary().contains("5 dnn(s) x 2 phase(s)"));
        assert!(d.summary().contains("on 3 node(s)"));
        let bad = SweepSpec { nodes_nm: vec![9], ..SweepSpec::default() };
        assert!(bad.summary().ends_with("? points"));
    }

    #[test]
    fn workload_names_resolve_case_insensitively() {
        assert_eq!(resolve_dnn("alexnet").unwrap(), "AlexNet");
        assert_eq!(resolve_dnn(" VGG-16 ").unwrap(), "VGG-16");
        assert!(resolve_dnn("lenet").is_err());
    }

    #[test]
    fn keys_are_stable_and_distinct() {
        let spec = SweepSpec::default();
        let pts = spec.expand().unwrap();
        let mut keys: Vec<String> = pts.iter().map(|p| p.key()).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "grid keys must be unique");
        // hash is a pure function of the key
        assert_eq!(pts[0].key_hash(), pts[0].key_hash());
    }

    #[test]
    fn spec_json_roundtrip() {
        let spec = SweepSpec {
            techs: TechSel::pures(&[MemTech::SttMram, MemTech::SotMram]),
            capacities_mb: vec![2, 8],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Training],
            batches: vec![16, 64],
            nodes_nm: vec![16, 7, 5],
            filters: vec![
                Filter::NvmOnly,
                Filter::TechIs(MemTech::SttMram),
                Filter::CapacityAtLeast(2),
                Filter::CapacityAtMost(8),
                Filter::PhaseIs(Phase::Training),
            ],
        };
        let j = spec_to_json(&spec);
        let back = spec_from_json(&j).unwrap();
        assert_eq!(back.techs, spec.techs);
        assert_eq!(back.capacities_mb, spec.capacities_mb);
        assert_eq!(back.dnns, spec.dnns);
        assert_eq!(back.phases, spec.phases);
        assert_eq!(back.batches, spec.batches);
        assert_eq!(back.nodes_nm, spec.nodes_nm);
        assert_eq!(back.filters, spec.filters);
        // and through the text parser
        let reparsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(spec_from_json(&reparsed).unwrap().filters, spec.filters);
    }

    #[test]
    fn spec_from_json_defaults_and_shorthand() {
        // empty object = the full default grid
        let d = spec_from_json(&Json::obj()).unwrap();
        assert_eq!(d.techs, MemTech::ALL.to_vec());
        assert_eq!(d.capacities_mb, DEFAULT_CAPACITIES_MB.to_vec());
        assert_eq!(d.dnns.len(), Dnn::zoo().len());
        assert!(d.filters.is_empty());

        // present-but-empty dnns = circuit-only; nvm_only shorthand
        let j = crate::util::json::parse(
            r#"{"dnns": [], "caps_mb": [1, 2], "nvm_only": true, "jobs": 4}"#,
        )
        .unwrap();
        let s = spec_from_json(&j).unwrap();
        assert!(s.dnns.is_empty());
        assert_eq!(s.capacities_mb, vec![1, 2]);
        assert_eq!(s.filters, vec![Filter::NvmOnly]);
        // 2 caps x 2 NVM techs after the filter
        assert_eq!(s.expand().unwrap().len(), 4);
    }

    #[test]
    fn spec_from_json_rejects_malformed() {
        for bad in [
            r#"{"techs": "stt"}"#,
            r#"{"techs": ["dram"]}"#,
            r#"{"caps_mb": [1.5]}"#,
            r#"{"caps_mb": [-1]}"#,
            r#"{"phases": ["both"]}"#,
            r#"{"filters": [{"kind": "bogus"}]}"#,
            r#"{"filters": [{"kind": "tech_is"}]}"#,
        ] {
            let j = crate::util::json::parse(bad).unwrap();
            assert!(spec_from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn parsers_accept_cli_shorthand() {
        assert_eq!(parse_tech("STT").unwrap(), MemTech::SttMram);
        assert_eq!(parse_tech("sot-mram").unwrap(), MemTech::SotMram);
        assert!(parse_tech("dram").is_err());
        assert_eq!(parse_phase("T").unwrap(), Phase::Training);
        assert!(parse_phase("both").is_err());
    }

    #[test]
    fn parse_tech_sel_covers_pures_and_hybrids() {
        // pure names parse exactly as parse_tech
        assert_eq!(parse_tech_sel("STT").unwrap(), MemTech::SttMram);
        assert_eq!(parse_tech_sel("sram").unwrap(), MemTech::Sram);

        let h = parse_tech_sel("hybrid-stt:4@0.85").unwrap();
        assert_eq!(
            h,
            TechSel::Hybrid(HybridSel {
                nvm: MemTech::SttMram,
                sram_ways: 4,
                steer_bp: 8500,
            })
        );
        // name() is the inverse, including through full MemTech names
        assert_eq!(parse_tech_sel(&h.name()).unwrap(), h);
        assert_eq!(
            parse_tech_sel(" HYBRID-SOT-MRAM:2@0.9 ").unwrap(),
            TechSel::Hybrid(HybridSel {
                nvm: MemTech::SotMram,
                sram_ways: 2,
                steer_bp: 9000,
            })
        );
        for t in TechSel::pure_all() {
            assert_eq!(parse_tech_sel(&t.name()).unwrap(), t);
        }

        for bad in [
            "hybrid-sram:4@0.85", // partner must be NVM
            "hybrid-stt:17@0.85", // ways beyond associativity
            "hybrid-stt:4@1.5",   // steer out of range
            "hybrid-stt:4@-0.1",
            "hybrid-stt:4@nan",
            "hybrid-stt:4",       // missing steer
            "hybrid-stt",         // missing ways
            "hybrid-dram:4@0.85",
        ] {
            assert!(parse_tech_sel(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn hybrid_points_expand_and_key_apart() {
        let spec = SweepSpec {
            techs: vec![
                MemTech::SttMram.into(),
                parse_tech_sel("hybrid-stt:4@0.85").unwrap(),
                parse_tech_sel("hybrid-stt:8@0.85").unwrap(),
            ],
            ..SweepSpec::circuit_only(vec![], vec![2])
        };
        let pts = spec.expand().unwrap();
        assert_eq!(pts.len(), 3);
        // the hybrid parameters are part of the content key, so a
        // tampered sram_ways/steer can never alias another point
        let keys: std::collections::HashSet<String> =
            pts.iter().map(|p| p.key()).collect();
        assert_eq!(keys.len(), 3);
        assert!(pts[1].key().contains("hybrid-stt:4@0.85"), "{}", pts[1].key());

        // hybrids survive the JSON codec round-trip
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(back.techs, spec.techs);

        // NvmOnly keeps hybrids (their bulk ways are NVM)
        let filtered = SweepSpec {
            filters: vec![Filter::NvmOnly],
            ..spec.clone()
        };
        assert_eq!(filtered.expand().unwrap().len(), 3);
        // TechIs matches pure techs only
        let pure_only = SweepSpec {
            filters: vec![Filter::TechIs(MemTech::SttMram)],
            ..spec.clone()
        };
        assert_eq!(pure_only.expand().unwrap().len(), 1);

        // programmatic construction is still validated at expand()
        let bad = SweepSpec {
            techs: vec![TechSel::Hybrid(HybridSel {
                nvm: MemTech::Sram,
                sram_ways: 4,
                steer_bp: 8500,
            })],
            ..SweepSpec::circuit_only(vec![], vec![2])
        };
        assert!(bad.expand().is_err());
        let bad_ways = SweepSpec {
            techs: vec![TechSel::Hybrid(HybridSel {
                nvm: MemTech::SttMram,
                sram_ways: 17,
                steer_bp: 8500,
            })],
            ..SweepSpec::circuit_only(vec![], vec![2])
        };
        assert!(bad_ways.expand().is_err());
    }

    #[test]
    fn objective_names_roundtrip() {
        for obj in OptObjective::ALL {
            assert_eq!(parse_objective(obj.name()).unwrap(), obj);
        }
        assert_eq!(parse_objective(" EDAP ").unwrap(), OptObjective::Edap);
        assert!(parse_objective("throughput").is_err());
        assert!(OptObjective::Edp.needs_workload());
        assert!(OptObjective::Latency.needs_workload());
        assert!(!OptObjective::Edap.needs_workload());
        assert!(!OptObjective::Capacity.needs_workload());
    }

    #[test]
    fn optimize_request_json_roundtrip_and_defaults() {
        // empty body: default grid, EDP objective, no budgets
        let d = optimize_request_from_json(&Json::obj()).unwrap();
        assert_eq!(d.objective, OptObjective::Edp);
        assert!(d.area_max_mm2.is_none() && d.leakage_max_w.is_none());
        assert!(!d.frontier);

        let j = crate::util::json::parse(
            r#"{"objective": "energy", "techs": ["stt"], "caps_mb": [1, 2],
                "dnns": ["AlexNet"], "phases": ["inference"],
                "area_max_mm2": 25.0, "leakage_max_w": 0.5, "frontier": true}"#,
        )
        .unwrap();
        let r = optimize_request_from_json(&j).unwrap();
        assert_eq!(r.objective, OptObjective::Energy);
        assert_eq!(r.spec.techs, vec![MemTech::SttMram]);
        assert_eq!(r.area_max_mm2, Some(25.0));
        assert_eq!(r.leakage_max_w, Some(0.5));
        assert!(r.frontier);

        // the serializer round-trips through the parser
        let back = optimize_request_from_json(&optimize_request_to_json(&r)).unwrap();
        assert_eq!(back.objective, r.objective);
        assert_eq!(back.spec.capacities_mb, r.spec.capacities_mb);
        assert_eq!(back.area_max_mm2, r.area_max_mm2);
        assert_eq!(back.leakage_max_w, r.leakage_max_w);
        assert!(back.frontier);

        for bad in [
            r#"{"objective": "fastest"}"#,
            r#"{"objective": 3}"#,
            r#"{"area_max_mm2": -1}"#,
            r#"{"area_max_mm2": "big"}"#,
            r#"{"leakage_max_w": 0}"#,
        ] {
            let j = crate::util::json::parse(bad).unwrap();
            assert!(optimize_request_from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn feasibility_budgets_bind_on_ppa() {
        let ppa = crate::nvsim::model::CachePpa {
            read_latency: 1e-9,
            write_latency: 2e-9,
            read_energy: 1e-10,
            write_energy: 2e-10,
            leakage_power: 0.3,
            area: 20e-6, // 20 mm²
        };
        let mut r = optimize_request_from_json(&Json::obj()).unwrap();
        assert!(r.feasible(&ppa), "no budgets: everything is feasible");
        r.area_max_mm2 = Some(25.0);
        r.leakage_max_w = Some(0.5);
        assert!(r.feasible(&ppa));
        r.area_max_mm2 = Some(19.0);
        assert!(!r.feasible(&ppa), "area budget binds");
        r.area_max_mm2 = Some(25.0);
        r.leakage_max_w = Some(0.2);
        assert!(!r.feasible(&ppa), "leakage budget binds");
    }

    #[test]
    fn uncalibrated_node_error_is_typed() {
        let s = SweepSpec { nodes_nm: vec![9], ..SweepSpec::default() };
        let err = s.expand().unwrap_err();
        assert!(
            err.chain()
                .any(|c| c.downcast_ref::<crate::device::UncalibratedNode>().is_some()),
            "{err:#}"
        );
    }
}
