//! Parallel grid executor — a hand-rolled `std::thread` + `mpsc` pool
//! (the offline vendor set has no rayon).
//!
//! Scheduling is self-stealing: workers race on one atomic cursor and
//! each idle worker claims the next unclaimed grid point, so load
//! balances automatically across points of very different cost (a 32 MB
//! circuit solve vs a cached 1 MB lookup). Completion order is
//! arbitrary, but results are reassembled into *input order* before
//! returning, so a sweep's output is byte-identical for any `--jobs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Worker count when the caller passes `jobs = 0` ("auto").
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Evaluate every item with `f` on up to `jobs` workers and return the
/// results in input order. `jobs <= 1` runs inline (no threads), which
/// is also the reference serial schedule the parallel path must match.
pub fn run_ordered<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let jobs = jobs.max(1).min(items.len().max(1));
    if jobs <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    // Unwrap the slots only after the scope has joined every worker:
    // if a worker panicked mid-item, `thread::scope` re-raises *that*
    // panic at the join point, so the original diagnostic is preserved
    // instead of being masked by a missing-slot panic here.
    let slots: Vec<Option<R>> = std::thread::scope(|scope| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                if tx.send((i, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots
    });
    slots
        .into_iter()
        .map(|s| s.expect("every grid point produced a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_come_back_in_input_order() {
        let items: Vec<u64> = (0..257).collect();
        for jobs in [1, 2, 4, 9] {
            let out = run_ordered(&items, jobs, |&x| x * x);
            let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
            assert_eq!(out, expect, "jobs={jobs}");
        }
    }

    #[test]
    fn every_item_evaluated_exactly_once() {
        let calls = AtomicU64::new(0);
        let items: Vec<usize> = (0..100).collect();
        let out = run_ordered(&items, 8, |&x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 100);
        assert_eq!(calls.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let none: Vec<u32> = vec![];
        assert!(run_ordered(&none, 4, |&x| x).is_empty());
        assert_eq!(run_ordered(&[7u32], 16, |&x| x + 1), vec![8]);
    }

    #[test]
    fn default_jobs_positive() {
        assert!(default_jobs() >= 1);
    }
}
