//! Shared-secret request authentication for the serve plane.
//!
//! The shard-exchange payload hash (`sweep::memo`) is a content
//! address, not a MAC: anyone who can reach a worker can forge a
//! hash-consistent document. This module closes that hole with a
//! keyed signature over every mutating request: the sender computes
//! `HMAC-SHA256(key, "METHOD\npath\nhex(SHA-256(body))")` and carries
//! the lower-hex tag in the [`AUTH_HEADER`] request header; the server
//! recomputes it and compares in constant time. The digest-of-body
//! indirection keeps the canonical string small and printable whatever
//! the body size (a full-grid memo export is ~1 MB).
//!
//! Everything here is std-only — the offline vendor set has no crypto
//! crates — so SHA-256 (FIPS 180-4) and HMAC (RFC 2104) are
//! implemented from scratch and pinned against the published test
//! vectors below. The one non-obvious property worth stating: the
//! comparison must not short-circuit on the first differing byte, or
//! the tag becomes guessable one byte at a time from response timing.

/// Request header carrying the hex HMAC tag.
pub const AUTH_HEADER: &str = "X-Deepnvm-Auth";

// ------------------------------------------------------------ SHA-256

const H0: [u32; 8] = [
    0x6a09_e667, 0xbb67_ae85, 0x3c6e_f372, 0xa54f_f53a, 0x510e_527f, 0x9b05_688c,
    0x1f83_d9ab, 0x5be0_cd19,
];

#[rustfmt::skip]
const K: [u32; 64] = [
    0x428a_2f98, 0x7137_4491, 0xb5c0_fbcf, 0xe9b5_dba5, 0x3956_c25b, 0x59f1_11f1,
    0x923f_82a4, 0xab1c_5ed5, 0xd807_aa98, 0x1283_5b01, 0x2431_85be, 0x550c_7dc3,
    0x72be_5d74, 0x80de_b1fe, 0x9bdc_06a7, 0xc19b_f174, 0xe49b_69c1, 0xefbe_4786,
    0x0fc1_9dc6, 0x240c_a1cc, 0x2de9_2c6f, 0x4a74_84aa, 0x5cb0_a9dc, 0x76f9_88da,
    0x983e_5152, 0xa831_c66d, 0xb003_27c8, 0xbf59_7fc7, 0xc6e0_0bf3, 0xd5a7_9147,
    0x06ca_6351, 0x1429_2967, 0x27b7_0a85, 0x2e1b_2138, 0x4d2c_6dfc, 0x5338_0d13,
    0x650a_7354, 0x766a_0abb, 0x81c2_c92e, 0x9272_2c85, 0xa2bf_e8a1, 0xa81a_664b,
    0xc24b_8b70, 0xc76c_51a3, 0xd192_e819, 0xd699_0624, 0xf40e_3585, 0x106a_a070,
    0x19a4_c116, 0x1e37_6c08, 0x2748_774c, 0x34b0_bcb5, 0x391c_0cb3, 0x4ed8_aa4a,
    0x5b9c_ca4f, 0x682e_6ff3, 0x748f_82ee, 0x78a5_636f, 0x84c8_7814, 0x8cc7_0208,
    0x90be_fffa, 0xa450_6ceb, 0xbef9_a3f7, 0xc671_78f2,
];

fn compress(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, word) in w.iter_mut().take(16).enumerate() {
        *word = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().expect("4-byte chunk"));
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for (k, wi) in K.iter().zip(w.iter()) {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(*k)
            .wrapping_add(*wi);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (s, v) in state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
        *s = s.wrapping_add(v);
    }
}

/// SHA-256 digest of `data` (FIPS 180-4).
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut state = H0;
    let mut blocks = data.chunks_exact(64);
    for block in blocks.by_ref() {
        compress(&mut state, block);
    }
    // Padding: 0x80, zeros to 56 mod 64, then the bit length big-endian.
    let rem = blocks.remainder();
    let mut tail = [0u8; 128];
    tail[..rem.len()].copy_from_slice(rem);
    tail[rem.len()] = 0x80;
    let tail_len = if rem.len() < 56 { 64 } else { 128 };
    let bits = (data.len() as u64).wrapping_mul(8);
    tail[tail_len - 8..tail_len].copy_from_slice(&bits.to_be_bytes());
    for block in tail[..tail_len].chunks_exact(64) {
        compress(&mut state, block);
    }
    let mut out = [0u8; 32];
    for (chunk, v) in out.chunks_exact_mut(4).zip(state) {
        chunk.copy_from_slice(&v.to_be_bytes());
    }
    out
}

// --------------------------------------------------------------- HMAC

const BLOCK: usize = 64;

/// HMAC-SHA256 of `msg` under `key` (RFC 2104): keys longer than the
/// 64-byte block are hashed first, shorter ones zero-padded.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; BLOCK];
    if key.len() > BLOCK {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Vec::with_capacity(BLOCK + msg.len());
    inner.extend(k.iter().map(|b| b ^ 0x36));
    inner.extend_from_slice(msg);
    let inner_hash = sha256(&inner);
    let mut outer = Vec::with_capacity(BLOCK + 32);
    outer.extend(k.iter().map(|b| b ^ 0x5c));
    outer.extend_from_slice(&inner_hash);
    sha256(&outer)
}

/// Lower-hex rendering of a digest.
pub fn hex(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push(char::from_digit((b >> 4) as u32, 16).expect("nibble"));
        out.push(char::from_digit((b & 0xf) as u32, 16).expect("nibble"));
    }
    out
}

/// Constant-time byte equality: the accumulated OR visits every byte
/// whatever the inputs, so a mismatch's position never shows up in the
/// comparison's duration. Length is not secret (both sides are
/// fixed-width hex tags), so a length mismatch may return early.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b) {
        diff |= x ^ y;
    }
    diff == 0
}

// ---------------------------------------------------- request signing

/// The canonical string a tag commits to: method (upper-cased), path,
/// and the hex SHA-256 of the body, newline-joined. Query strings are
/// deliberately excluded — no mutating route reads them — and the body
/// digest binds the payload without inflating the signed string.
fn canonical(method: &str, path: &str, body: &[u8]) -> String {
    format!("{}\n{}\n{}", method.to_ascii_uppercase(), path, hex(&sha256(body)))
}

/// Compute the [`AUTH_HEADER`] tag for a request.
pub fn sign(key: &str, method: &str, path: &str, body: &[u8]) -> String {
    hex(&hmac_sha256(key.as_bytes(), canonical(method, path, body).as_bytes()))
}

/// Verify a presented tag against the key, in constant time.
pub fn verify(key: &str, method: &str, path: &str, body: &[u8], tag: &str) -> bool {
    let expect = sign(key, method, path, body);
    ct_eq(expect.as_bytes(), tag.trim().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / NIST CAVP vectors.
    #[test]
    fn sha256_matches_the_published_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // two-block message (56 bytes forces the padding into a second block)
        assert_eq!(
            hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // exactly one block of input: padding becomes its own block
        assert_eq!(
            hex(&sha256(&[0x61u8; 64])),
            "ffe054fe7ae0cb6dc65c3af9b61d5209f439851db43d0ba5997337df154668eb"
        );
        assert_eq!(
            hex(&sha256(&[0x61u8; 1_000_000])),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    // RFC 4231 HMAC-SHA-256 test cases 1, 2, and 6.
    #[test]
    fn hmac_sha256_matches_rfc_4231() {
        assert_eq!(
            hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // a 131-byte key exercises the hash-the-key branch
        assert_eq!(
            hex(&hmac_sha256(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn constant_time_eq_and_tag_round_trip() {
        assert!(ct_eq(b"same", b"same"));
        assert!(!ct_eq(b"same", b"sane"));
        assert!(!ct_eq(b"short", b"longer"));

        let tag = sign("k3y", "POST", "/memo/merge", b"{\"a\": 1}");
        assert_eq!(tag.len(), 64, "hex HMAC-SHA256 is 64 chars");
        assert!(verify("k3y", "POST", "/memo/merge", b"{\"a\": 1}", &tag));
        assert!(verify("k3y", "post", "/memo/merge", b"{\"a\": 1}", &tag), "method case-folds");
        assert!(verify("k3y", "POST", "/memo/merge", b"{\"a\": 1}", &format!(" {tag} ")));

        // every component of the canonical string is load-bearing
        assert!(!verify("k3y", "POST", "/memo/merge", b"{\"a\": 2}", &tag));
        assert!(!verify("k3y", "POST", "/shard/run", b"{\"a\": 1}", &tag));
        assert!(!verify("k3y", "PUT", "/memo/merge", b"{\"a\": 1}", &tag));
        assert!(!verify("other", "POST", "/memo/merge", b"{\"a\": 1}", &tag));
        let mut flipped = tag.clone();
        let last = flipped.pop().unwrap();
        flipped.push(if last == '0' { '1' } else { '0' });
        assert!(!verify("k3y", "POST", "/memo/merge", b"{\"a\": 1}", &flipped));
        assert!(!verify("k3y", "POST", "/memo/merge", b"{\"a\": 1}", ""));
    }
}
