//! `serve::scheduler` — the multi-host sweep coordinator.
//!
//! The shard exchange ([`super::shard`]) made fleet sweeps *possible*
//! but left them caller-driven: someone had to `split_caps` the grid,
//! run each shard on a worker, collect `/memo/export`s and
//! `POST /memo/merge` them, and babysit any worker that died along the
//! way. This module turns that shell script into one call. A
//! [`Coordinator`] owns a [`SweepSpec`], partitions it into
//! cost-balanced shards ([`split_caps`]), *assigns* them to a
//! registered worker fleet over the existing HTTP layer
//! (`POST /shard/run`), merges each worker's memo export as it
//! arrives, and reassigns the shards of stragglers and dead workers —
//! a per-shard deadline bounds every dispatch, and a `/healthz` probe
//! after any failure decides whether the worker is retired or merely
//! flaky — until the union answers the full grid with zero circuit
//! solves.
//!
//! Scheduling is work-stealing over shards: one thread per live
//! worker, all racing on one shared queue, so a fast worker naturally
//! absorbs the load a slow or dead one sheds. The grid is cut into
//! more shards than workers ([`SHARDS_PER_WORKER`], capped by the
//! capacity axis) so a retired worker forfeits only a slice of its
//! assignment, not half the grid. Per-shard state (pending / running /
//! merged / failed, with attempt counts) is observable over
//! `GET /scheduler/status` when a status address is configured — the
//! same view `deepnvm coordinate` prints when it finishes.
//!
//! The coordinator is also the fleet's observability aggregator.
//! Every dispatch and probe is stamped with an `X-Deepnvm-Trace`
//! header (`trace_id:parent_span_id`), which workers adopt into their
//! request spans; [`Coordinator::fleet_trace`] then scrapes each
//! worker's `GET /trace`, rebases timestamps by the probe-estimated
//! clock offsets, and stitches one Chrome trace with a distinct `pid`
//! per worker and flow arrows from each `shard.dispatch` span to the
//! worker-side `http./shard/run` span it caused. `GET
//! /scheduler/metrics` on the status server federates every worker's
//! `/metrics` into one exposition: counters sum and the fixed-width
//! log₂ histogram buckets add exactly.

use std::collections::{HashMap, HashSet};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::obs::{metrics, trace, LazyCounter, LazyHistogram, Span};
use crate::sweep::spec::spec_to_json;
use crate::sweep::{self, Memo, SweepSpec};
use crate::util::json::{self, Json};

use super::auth;
use super::http::{self, Response, Server};
use super::shard::split_caps;

/// Shard multiplier: with W workers the grid is cut into up to
/// `W * SHARDS_PER_WORKER` shards (never more than the capacity axis
/// allows), so reassignment after a death moves a slice, not a half.
pub const SHARDS_PER_WORKER: usize = 2;

/// How long a `/healthz` probe may take before a worker is declared
/// dead.
const PROBE_TIMEOUT: Duration = Duration::from_secs(3);

/// Idle re-check interval for worker threads waiting on the queue (a
/// backstop for missed wakeups; completion is condvar-notified).
const POLL: Duration = Duration::from_millis(50);

/// How many times one dispatch re-sends after a `503` shed before the
/// failure surfaces to the reassignment path. Each wait is a jittered
/// exponential backoff ([`http::backoff_delay`]) floored by the
/// worker's `Retry-After`, so a briefly saturated worker drains
/// instead of burning the shard's retry budget.
const SHED_RETRIES: u32 = 4;

/// How many idle polls a worker waits before re-taking a shard it
/// already failed itself. The wait gives a healthy peer a window to
/// steal the shard; the cap guarantees progress when *every* live
/// worker has failed it (otherwise two stuck workers would wait on
/// each other forever instead of exhausting the retry budget).
const GRACE_POLLS: usize = 20;

// Fleet-level obs mirrors (global registry): dispatch/merge timelines
// and probe outcomes, scraped via `GET /metrics` on any co-resident
// server and summarized in `GET /scheduler/status`.
static DISPATCHES: LazyCounter = LazyCounter::new("deepnvm_shard_dispatches_total");
static RETRIES: LazyCounter = LazyCounter::new("deepnvm_shard_retries_total");
static DISPATCH_NS: LazyHistogram = LazyHistogram::new("deepnvm_shard_dispatch_duration_ns");
static MERGE_NS: LazyHistogram = LazyHistogram::new("deepnvm_shard_merge_duration_ns");
static PROBES_OK: LazyCounter = LazyCounter::new("deepnvm_worker_probes_total{result=\"ok\"}");
static PROBES_DEAD: LazyCounter = LazyCounter::new("deepnvm_worker_probes_total{result=\"dead\"}");

/// Coordinator configuration (the CLI's `coordinate --workers
/// --retries --deadline-secs --status-addr`).
#[derive(Clone, Debug)]
pub struct ScheduleConfig {
    /// Worker addresses (`host:port` of running `deepnvm serve`
    /// instances). Deduplicated; order is the probe order.
    pub workers: Vec<String>,
    /// How many times a shard may be *re*assigned after its first
    /// attempt before the whole run fails.
    pub retries: usize,
    /// Per-dispatch deadline: a `/shard/run` whose socket stays silent
    /// this long is treated as a dead or stuck worker and reassigned.
    pub deadline: Duration,
    /// Worker-side thread hint forwarded in each `/shard/run` body and
    /// used for the local zero-solve replay (0 = default).
    pub jobs: usize,
    /// Bind a status server here (`GET /scheduler/status`); `None`
    /// disables it.
    pub status_addr: Option<String>,
    /// Shared secret for an authenticated fleet (`--auth-key` /
    /// `DEEPNVM_AUTH_KEY`): when set, every `POST /shard/run` carries
    /// an `X-Deepnvm-Auth` tag. Must match the workers' key.
    pub auth_key: Option<String>,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        ScheduleConfig {
            workers: vec![],
            retries: 3,
            deadline: Duration::from_secs(120),
            jobs: 0,
            status_addr: None,
            auth_key: None,
        }
    }
}

/// Lifecycle of one shard.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardState {
    /// Queued, waiting for a worker.
    Pending,
    /// Dispatched to `worker`, response outstanding.
    Running { worker: String },
    /// Export merged into the coordinator memo.
    Merged { worker: String, accepted: usize, skipped: usize },
    /// Retry budget exhausted; the run fails.
    Failed { error: String },
}

impl ShardState {
    pub fn name(&self) -> &'static str {
        match self {
            ShardState::Pending => "pending",
            ShardState::Running { .. } => "running",
            ShardState::Merged { .. } => "merged",
            ShardState::Failed { .. } => "failed",
        }
    }

    /// The worker this shard is (or was last) associated with.
    pub fn worker(&self) -> Option<&str> {
        match self {
            ShardState::Running { worker } | ShardState::Merged { worker, .. } => {
                Some(worker)
            }
            _ => None,
        }
    }
}

impl std::fmt::Display for ShardState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardState::Pending => write!(f, "pending"),
            ShardState::Running { worker } => write!(f, "running on {worker}"),
            ShardState::Merged { worker, accepted, skipped } => {
                write!(f, "merged from {worker} (+{accepted} entries, {skipped} dup)")
            }
            ShardState::Failed { error } => write!(f, "FAILED: {error}"),
        }
    }
}

/// Final per-shard record in a [`ScheduleReport`].
#[derive(Clone, Debug)]
pub struct ShardSummary {
    pub caps_mb: Vec<u64>,
    /// Grid points this shard expands to.
    pub points: usize,
    pub state: ShardState,
    /// Dispatch attempts (> 1 means the shard was reassigned).
    pub attempts: usize,
}

/// Outcome of a completed coordination run.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    pub shards: Vec<ShardSummary>,
    /// Points in the full grid, verified by the local replay.
    pub grid_points: usize,
    /// Memo entries newly accepted across all shard merges.
    pub accepted: usize,
    /// Shards that needed more than one dispatch.
    pub reassigned: usize,
    /// Circuit solves the *local* full-grid replay performed — 0 when
    /// the merged union covers the grid, which [`Coordinator::run`]
    /// requires.
    pub replay_solves: u64,
    /// Traffic evaluations the local replay performed (also 0).
    pub replay_evals: u64,
    pub wall: Duration,
}

/// Mutable scheduler state shared by worker threads and the status
/// route.
struct Core {
    /// Pending shard indices (front = next to dispatch).
    queue: Vec<usize>,
    states: Vec<ShardState>,
    /// Dispatch attempts per shard.
    attempts: Vec<usize>,
    merged: usize,
    live_workers: usize,
    worker_alive: Vec<bool>,
    worker_merged: Vec<usize>,
    /// First unrecoverable error; ends the run.
    fatal: Option<String>,
}

struct Shared {
    core: Mutex<Core>,
    changed: Condvar,
    shards: Vec<SweepSpec>,
    shard_points: Vec<usize>,
    workers: Vec<String>,
    total_points: usize,
    started: Instant,
    /// Per-worker span-clock offset (coordinator ns minus worker ns),
    /// estimated from `/healthz` probe RTT midpoints; `None` until a
    /// probe succeeds (or when the worker omits `clock_ns`). Used to
    /// rebase scraped worker timestamps in [`Coordinator::fleet_trace`].
    offsets: Mutex<Vec<Option<i64>>>,
}

/// Distinguishes one Coordinator's dispatch spans (`args.run`) from
/// other runs sharing the process span ring.
static NEXT_RUN_SEQ: AtomicU64 = AtomicU64::new(1);

/// A prepared coordination run: shards cut, status server (optionally)
/// bound. [`Coordinator::run`] executes it.
pub struct Coordinator {
    shared: Arc<Shared>,
    cfg: ScheduleConfig,
    spec: SweepSpec,
    status: Option<Server>,
    run_seq: u64,
}

/// One-call form: prepare and run. The fleet workflow as a function.
pub fn coordinate(
    spec: &SweepSpec,
    cfg: &ScheduleConfig,
    memo: &Memo,
) -> Result<ScheduleReport> {
    Coordinator::new(spec, cfg)?.run(memo)
}

impl Coordinator {
    /// Validate the spec and fleet, cut the shards, and bind the
    /// status server when configured. No worker is contacted yet.
    pub fn new(spec: &SweepSpec, cfg: &ScheduleConfig) -> Result<Coordinator> {
        let mut workers: Vec<String> = Vec::new();
        for w in &cfg.workers {
            let w = w.trim().to_string();
            if !w.is_empty() && !workers.contains(&w) {
                workers.push(w);
            }
        }
        if workers.is_empty() {
            bail!("the scheduler needs at least one worker address");
        }
        if cfg.deadline.is_zero() {
            bail!("the shard deadline must be positive");
        }
        let total_points = spec.expand()?.len();
        let shards = split_caps(spec, workers.len() * SHARDS_PER_WORKER);
        let mut shard_points = Vec::with_capacity(shards.len());
        for s in &shards {
            shard_points.push(s.expand()?.len());
        }
        let n = shards.len();
        let core = Core {
            queue: (0..n).collect(),
            states: vec![ShardState::Pending; n],
            attempts: vec![0; n],
            merged: 0,
            live_workers: 0,
            worker_alive: vec![false; workers.len()],
            worker_merged: vec![0; workers.len()],
            fatal: None,
        };
        let worker_count = workers.len();
        let shared = Arc::new(Shared {
            core: Mutex::new(core),
            changed: Condvar::new(),
            shards,
            shard_points,
            workers,
            total_points,
            started: Instant::now(),
            offsets: Mutex::new(vec![None; worker_count]),
        });
        let status = match &cfg.status_addr {
            Some(addr) => {
                let view = Arc::clone(&shared);
                let server = Server::bind(addr, 2, move |req| {
                    match (req.method.as_str(), req.path.as_str()) {
                        ("GET", "/scheduler/status") => {
                            Response::json(200, &status_json(&view))
                        }
                        ("GET", "/scheduler/metrics") => fleet_metrics(&view),
                        ("GET", "/healthz") => {
                            let mut j = Json::obj();
                            j.set("status", Json::Str("ok".into()));
                            j.set("role", Json::Str("coordinator".into()));
                            Response::json(200, &j)
                        }
                        _ => Response::error(
                            404,
                            "no such route (GET /scheduler/status or /scheduler/metrics)",
                        ),
                    }
                })
                .context("cannot bind the scheduler status address")?;
                Some(server)
            }
            None => None,
        };
        Ok(Coordinator {
            shared,
            cfg: cfg.clone(),
            spec: spec.clone(),
            status,
            run_seq: NEXT_RUN_SEQ.fetch_add(1, Ordering::Relaxed),
        })
    }

    /// The `args.run` tag on this run's `shard.dispatch` spans — what
    /// lets a reader (or test) pick one run's dispatches out of a span
    /// ring shared by several Coordinators in one process.
    pub fn run_seq(&self) -> u64 {
        self.run_seq
    }

    /// Where the status server listens, if one was configured.
    pub fn status_addr(&self) -> Option<SocketAddr> {
        self.status.as_ref().map(Server::local_addr)
    }

    /// Shard count for this run.
    pub fn shard_count(&self) -> usize {
        self.shared.shards.len()
    }

    /// Snapshot of the per-shard scheduler state (what
    /// `GET /scheduler/status` serves).
    pub fn status(&self) -> Json {
        status_json(&self.shared)
    }

    /// Execute the run: probe the fleet, dispatch every shard until
    /// merged (reassigning on failure), then replay the full grid
    /// locally and require zero circuit solves and zero traffic evals.
    pub fn run(&self, memo: &Memo) -> Result<ScheduleReport> {
        let sh = &self.shared;

        // Probe the fleet; a worker failing the liveness probe starts
        // (and stays) retired — it never receives a shard.
        let mut live: Vec<(usize, String)> = Vec::new();
        {
            let mut core = sh.core.lock().unwrap();
            for (w, addr) in sh.workers.iter().enumerate() {
                if self.probe_worker(w, addr) {
                    core.worker_alive[w] = true;
                    live.push((w, addr.clone()));
                } else {
                    eprintln!(
                        "scheduler: worker {addr} failed the /healthz probe; \
                         starting without it"
                    );
                }
            }
            core.live_workers = live.len();
        }
        if live.is_empty() {
            bail!(
                "no worker among {:?} answered /healthz — is `deepnvm serve` running?",
                sh.workers
            );
        }

        std::thread::scope(|scope| {
            for (w, addr) in &live {
                let (w, addr) = (*w, addr.as_str());
                scope.spawn(move || self.worker_loop(w, addr, memo));
            }
        });

        let (accepted, reassigned, summaries) = {
            let core = sh.core.lock().unwrap();
            if let Some(f) = &core.fatal {
                bail!("{f}");
            }
            if core.merged < sh.shards.len() {
                bail!(
                    "scheduler stalled with {}/{} shards merged",
                    core.merged,
                    sh.shards.len()
                );
            }
            let accepted: usize = core
                .states
                .iter()
                .map(|s| match s {
                    ShardState::Merged { accepted, .. } => *accepted,
                    _ => 0,
                })
                .sum();
            let reassigned = core.attempts.iter().filter(|&&a| a > 1).count();
            let summaries: Vec<ShardSummary> = core
                .states
                .iter()
                .enumerate()
                .map(|(i, st)| ShardSummary {
                    caps_mb: sh.shards[i].capacities_mb.clone(),
                    points: sh.shard_points[i],
                    state: st.clone(),
                    attempts: core.attempts[i],
                })
                .collect();
            (accepted, reassigned, summaries)
        };

        // The merged union must answer the full grid from cache alone.
        let s0 = memo.solve_count();
        let e0 = memo.eval_count();
        let res = sweep::run(&self.spec, self.cfg.jobs, memo)?;
        debug_assert_eq!(res.points.len(), sh.total_points);
        Ok(ScheduleReport {
            shards: summaries,
            grid_points: res.points.len(),
            accepted,
            reassigned,
            replay_solves: memo.solve_count() - s0,
            replay_evals: memo.eval_count() - e0,
            wall: sh.started.elapsed(),
        })
    }

    /// One worker's scheduling loop: claim a shard, dispatch it, merge
    /// the export; on failure requeue the shard and decide (via
    /// `/healthz`) whether this worker stays in the fleet.
    fn worker_loop(&self, widx: usize, addr: &str, memo: &Memo) {
        let sh = &self.shared;
        let total = sh.shards.len();
        // Shards that already failed *on this worker*: another worker
        // should pick them up, so this one skips them while a peer is
        // alive (a worker whose handler is broken for one shard must
        // not burn that shard's whole retry budget by itself).
        let mut failed_here: HashSet<usize> = HashSet::new();
        // One pooled keep-alive connection per worker thread: every
        // dispatch to this worker reuses the same socket instead of a
        // fresh TCP handshake per shard.
        let mut client = http::Client::new(addr, self.cfg.deadline);
        loop {
            let mut idle = 0usize;
            let idx = {
                let mut core = sh.core.lock().unwrap();
                loop {
                    if core.fatal.is_some() || core.merged == total {
                        return;
                    }
                    let pick = core
                        .queue
                        .iter()
                        .position(|i| !failed_here.contains(i))
                        .or_else(|| {
                            // Only shards this worker already failed
                            // remain queued: take one anyway once no
                            // peer exists — or once peers have had a
                            // grace window and not stolen it.
                            let must = core.live_workers == 1 || idle >= GRACE_POLLS;
                            (must && !core.queue.is_empty()).then_some(0)
                        });
                    if let Some(pos) = pick {
                        let idx = core.queue.remove(pos);
                        core.attempts[idx] += 1;
                        core.states[idx] =
                            ShardState::Running { worker: addr.to_string() };
                        break idx;
                    }
                    idle += 1;
                    core = sh.changed.wait_timeout(core, POLL).unwrap().0;
                }
            };
            let dispatched = {
                let span = Span::enter("shard.dispatch")
                    .arg("shard", idx as u64)
                    .arg("run", self.run_seq);
                run_shard_on(&mut client, &sh.shards[idx], &self.cfg, span.id())
            };
            match dispatched {
                Ok(export) => {
                    let st = {
                        let _span = Span::enter("shard.merge")
                            .arg("shard", idx as u64)
                            .arg("run", self.run_seq);
                        MERGE_NS.time(|| memo.merge_json(&export))
                    };
                    if !st.version_ok {
                        // A worker built against another MODEL_VERSION
                        // can never contribute; retire it.
                        let why = format!(
                            "worker {addr} exported a different model version"
                        );
                        self.shed(widx, addr, idx, &mut failed_here, &why, false);
                        return;
                    }
                    if st.rejected > 0 {
                        // Hash-rejected entries mean the export was
                        // corrupt or forged: the shard is NOT covered,
                        // so this dispatch failed — reassign it (the
                        // already-accepted entries are harmless; a
                        // clean re-run just skips them as duplicates).
                        let why = format!(
                            "worker {addr} export had {} hash-rejected of {} entries",
                            st.rejected,
                            st.total()
                        );
                        if !self.shed(widx, addr, idx, &mut failed_here, &why, true) {
                            return;
                        }
                        continue;
                    }
                    let mut core = sh.core.lock().unwrap();
                    core.states[idx] = ShardState::Merged {
                        worker: addr.to_string(),
                        accepted: st.accepted,
                        skipped: st.skipped,
                    };
                    core.merged += 1;
                    core.worker_merged[widx] += 1;
                    sh.changed.notify_all();
                }
                Err(e) => {
                    // Straggler past the deadline, severed connection,
                    // or a worker-side error — probe before deciding
                    // whether this worker keeps scheduling.
                    let alive = self.probe_worker(widx, addr);
                    if !self.shed(widx, addr, idx, &mut failed_here, &format!("{e:#}"), alive)
                    {
                        return;
                    }
                }
            }
        }
    }

    /// A dispatch of shard `idx` to this worker failed: requeue the
    /// shard (or fail the run when its retry budget is exhausted) and,
    /// when `alive` is false, retire the worker. Returns whether this
    /// worker thread should keep scheduling.
    fn shed(
        &self,
        widx: usize,
        addr: &str,
        idx: usize,
        failed_here: &mut HashSet<usize>,
        why: &str,
        alive: bool,
    ) -> bool {
        failed_here.insert(idx);
        let sh = &self.shared;
        let mut core = sh.core.lock().unwrap();
        if core.attempts[idx] > self.cfg.retries {
            core.states[idx] = ShardState::Failed { error: why.to_string() };
            core.fatal = Some(format!(
                "shard {idx} failed on attempt {} of {} (last error: {why})",
                core.attempts[idx],
                self.cfg.retries + 1
            ));
        } else {
            eprintln!(
                "scheduler: reassigning shard {idx} after attempt {} ({why})",
                core.attempts[idx]
            );
            RETRIES.inc();
            core.states[idx] = ShardState::Pending;
            core.queue.push(idx);
        }
        if !alive {
            eprintln!("scheduler: worker {addr} is unreachable; retiring it");
            core.worker_alive[widx] = false;
            core.live_workers -= 1;
            if core.live_workers == 0
                && core.merged < sh.shards.len()
                && core.fatal.is_none()
            {
                core.fatal = Some(format!(
                    "every worker died with {}/{} shards merged",
                    core.merged,
                    sh.shards.len()
                ));
            }
        }
        sh.changed.notify_all();
        alive && core.fatal.is_none()
    }

    /// Probe worker `widx` and record its estimated clock offset (used
    /// by [`Coordinator::fleet_trace`] to rebase scraped timestamps).
    fn probe_worker(&self, widx: usize, addr: &str) -> bool {
        let (alive, offset) = probe(addr);
        if let Some(off) = offset {
            self.shared.offsets.lock().unwrap()[widx] = Some(off);
        }
        alive
    }

    /// Stitch this process's span ring together with every live
    /// worker's `GET /trace` export into one Chrome trace document.
    ///
    /// The coordinator keeps `pid` 1; worker `w` gets `pid` `w + 2`,
    /// and its timestamps are rebased by the clock offset estimated
    /// from the most recent `/healthz` probe RTT midpoint (accurate to
    /// about half the probe round trip). Worker spans that carry this
    /// process's trace id are flow-linked (`ph:"s"`/`ph:"f"`) back to
    /// the `shard.dispatch` span that stamped them.
    pub fn fleet_trace(&self) -> Json {
        let local = trace::chrome_trace_json();
        let trace_hex = format!("{:016x}", trace::trace_id());
        let mut events: Vec<Json> = Vec::new();
        // Where each local dispatch span sits, keyed by its span id —
        // the flow arrow's source end.
        let mut dispatch_at: HashMap<u64, (f64, f64, f64)> = HashMap::new();
        if let Some(Json::Arr(evs)) = local.get("traceEvents") {
            for ev in evs {
                if ev.get("name").and_then(Json::as_str) == Some("shard.dispatch") {
                    let args = ev.get("args");
                    let id = args
                        .and_then(|a| a.get("id"))
                        .and_then(Json::as_u64)
                        .unwrap_or(0);
                    let ts = ev.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
                    let tid = ev.get("tid").and_then(Json::as_f64).unwrap_or(0.0);
                    dispatch_at.insert(id, (ts, 1.0, tid));
                }
                events.push(ev.clone());
            }
        }
        events.push(process_name_event(1.0, "coordinator"));
        let offsets = self.shared.offsets.lock().unwrap().clone();
        let mut stitched = 0usize;
        for (w, addr) in self.shared.workers.iter().enumerate() {
            let pid = (w + 2) as f64;
            let body = match http::call(addr, "GET", "/trace", "", PROBE_TIMEOUT) {
                Ok((200, body)) => body,
                _ => {
                    eprintln!("scheduler: worker {addr} /trace scrape failed; skipping");
                    continue;
                }
            };
            let doc = match json::parse(&body) {
                Ok(d) => d,
                Err(_) => {
                    eprintln!("scheduler: worker {addr} /trace was malformed; skipping");
                    continue;
                }
            };
            let off_us = offsets[w].unwrap_or(0) as f64 / 1e3;
            events.push(process_name_event(pid, &format!("worker {addr}")));
            stitched += 1;
            if let Some(Json::Arr(evs)) = doc.get("traceEvents") {
                for ev in evs {
                    let mut e = ev.clone();
                    e.set("pid", Json::Num(pid));
                    let ts = ev.get("ts").and_then(Json::as_f64).unwrap_or(0.0) + off_us;
                    e.set("ts", Json::Num(ts));
                    let args = ev.get("args");
                    let on_trace = args
                        .and_then(|a| a.get("trace"))
                        .and_then(Json::as_str)
                        == Some(trace_hex.as_str());
                    let remote_parent = args
                        .and_then(|a| a.get("remoteParent"))
                        .and_then(Json::as_u64);
                    if on_trace {
                        if let Some(parent) = remote_parent {
                            if let Some(&(dts, dpid, dtid)) = dispatch_at.get(&parent) {
                                let tid =
                                    ev.get("tid").and_then(Json::as_f64).unwrap_or(0.0);
                                events.push(flow_event("s", parent, dpid, dtid, dts));
                                events.push(flow_event("f", parent, pid, tid, ts));
                            }
                        }
                    }
                    events.push(e);
                }
            }
        }
        let mut doc = Json::obj();
        doc.set("displayTimeUnit", Json::Str("ms".into()));
        doc.set("traceId", Json::Str(trace_hex));
        doc.set(
            "droppedSpans",
            local.get("droppedSpans").cloned().unwrap_or(Json::Num(0.0)),
        );
        doc.set("workersStitched", Json::Num(stitched as f64));
        doc.set("traceEvents", Json::Arr(events));
        doc
    }
}

/// `GET /healthz` answered 200 within the probe timeout? Also returns
/// the estimated clock offset (coordinator ns minus worker ns) from
/// the probe's RTT midpoint, when the worker reported `clock_ns`.
fn probe(addr: &str) -> (bool, Option<i64>) {
    let span = Span::enter("worker.probe");
    let header = trace::trace_header_value(trace::trace_id(), span.id());
    let t0 = crate::obs::uptime().as_nanos() as i64;
    let reply = http::call_with(
        addr,
        "GET",
        "/healthz",
        &[(trace::TRACE_HEADER, header.as_str())],
        "",
        PROBE_TIMEOUT,
    );
    let t1 = crate::obs::uptime().as_nanos() as i64;
    match reply {
        Ok((200, body)) => {
            PROBES_OK.inc();
            // Midpoint estimate: the worker read its clock roughly
            // half an RTT after t0, so offset = midpoint - worker_ns.
            let offset = json::parse(&body)
                .ok()
                .and_then(|j| j.get("clock_ns").and_then(Json::as_f64))
                .map(|worker_ns| t0 + (t1 - t0) / 2 - worker_ns as i64);
            (true, offset)
        }
        _ => {
            PROBES_DEAD.inc();
            (false, None)
        }
    }
}

/// A Chrome trace `process_name` metadata event: names the row a
/// process's spans render under.
fn process_name_event(pid: f64, name: &str) -> Json {
    let mut args = Json::obj();
    args.set("name", Json::Str(name.to_string()));
    let mut e = Json::obj();
    e.set("ph", Json::Str("M".into()));
    e.set("name", Json::Str("process_name".into()));
    e.set("pid", Json::Num(pid));
    e.set("tid", Json::Num(0.0));
    e.set("args", args);
    e
}

/// One end of a flow arrow between a dispatch span and the worker span
/// it produced (`ph` is `"s"` at the source, `"f"` at the sink).
fn flow_event(ph: &str, id: u64, pid: f64, tid: f64, ts: f64) -> Json {
    let mut e = Json::obj();
    e.set("ph", Json::Str(ph.to_string()));
    if ph == "f" {
        // Bind to the enclosing slice so the arrow lands on the span
        // itself rather than the next event on the thread.
        e.set("bp", Json::Str("e".into()));
    }
    e.set("name", Json::Str("shard.dispatch.flow".into()));
    e.set("cat", Json::Str("deepnvm".into()));
    e.set("id", Json::Num(id as f64));
    e.set("pid", Json::Num(pid));
    e.set("tid", Json::Num(tid));
    e.set("ts", Json::Num(ts));
    e
}

/// `GET /scheduler/metrics`: scrape every worker's `/metrics`, merge
/// the expositions (summed counters and gauges, bucket-wise histogram
/// addition — exact because every process uses the same log2 bucket
/// bounds), and append the coordinator's own series relabeled with
/// `role="coordinator"` so they never collide with fleet series.
fn fleet_metrics(sh: &Shared) -> Response {
    let mut texts: Vec<String> = Vec::new();
    let mut scraped = 0usize;
    for addr in &sh.workers {
        if let Ok((200, body)) = http::call(addr, "GET", "/metrics", "", PROBE_TIMEOUT) {
            texts.push(body);
            scraped += 1;
        }
    }
    texts.push(metrics::relabel_exposition(
        &crate::obs::global().prometheus_text(),
        "role",
        "coordinator",
    ));
    let comment = format!(
        "# fleet: merged /metrics from {scraped}/{} workers plus coordinator-local \
         series (role=\"coordinator\")\n",
        sh.workers.len()
    );
    let body = comment + &metrics::merge_expositions(&texts);
    Response {
        status: 200,
        content_type: "text/plain; version=0.0.4; charset=utf-8",
        body: body.into_bytes(),
        extra_headers: Vec::new(),
    }
}

/// Dispatch one shard: `POST /shard/run` with the shard spec (plus the
/// jobs hint) over the worker's pooled connection and return its memo
/// export. Any transport error, timeout, or non-200 is the caller's
/// cue to reassign — except a `503` shed, which is retried in place
/// with jittered exponential backoff (floored by the worker's
/// `Retry-After`) up to [`SHED_RETRIES`] times: an over-cap worker is
/// busy, not broken, and reassignment would just move the flood. The
/// dispatch histogram records transport-complete round trips only — a
/// severed socket must not pollute the timeline.
fn run_shard_on(
    client: &mut http::Client,
    shard: &SweepSpec,
    cfg: &ScheduleConfig,
    parent_span: u64,
) -> Result<Json> {
    let addr = client.addr().to_string();
    let mut body = spec_to_json(shard);
    if cfg.jobs > 0 {
        body.set("jobs", Json::Num(cfg.jobs as f64));
    }
    let body = body.to_string();
    // Stamp the dispatch so the worker's root span joins this trace:
    // its record comes back via `GET /trace` with `remoteParent` set
    // to the dispatch span id, which is what fleet_trace flow-links.
    let header = trace::trace_header_value(trace::trace_id(), parent_span);
    let mut headers: Vec<(&str, String)> = vec![(trace::TRACE_HEADER, header)];
    if let Some(key) = &cfg.auth_key {
        headers.push((
            auth::AUTH_HEADER,
            auth::sign(key, "POST", "/shard/run", body.as_bytes()),
        ));
    }
    let header_refs: Vec<(&str, &str)> =
        headers.iter().map(|(n, v)| (*n, v.as_str())).collect();
    let mut shed_attempt = 0u32;
    let (status, text) = loop {
        DISPATCHES.inc();
        let t0 = Instant::now();
        let (status, text) = client.call_with("POST", "/shard/run", &header_refs, &body)?;
        DISPATCH_NS.record_duration(t0.elapsed());
        if status == 503 && shed_attempt < SHED_RETRIES {
            let delay = http::backoff_delay(shed_attempt, client.last_retry_after());
            eprintln!(
                "scheduler: worker {addr} shed the dispatch (503); backing off \
                 {delay:?} before retry {} of {SHED_RETRIES}",
                shed_attempt + 1
            );
            std::thread::sleep(delay);
            shed_attempt += 1;
            continue;
        }
        break (status, text);
    };
    if status != 200 {
        let detail = json::parse(&text)
            .ok()
            .and_then(|j| j.get("error").and_then(Json::as_str).map(str::to_string))
            .unwrap_or_else(|| format!("{} bytes", text.len()));
        bail!("worker {addr} answered {status} to /shard/run: {detail}");
    }
    let j = json::parse(&text)
        .with_context(|| format!("worker {addr} returned malformed JSON"))?;
    j.get("export")
        .cloned()
        .with_context(|| format!("worker {addr} returned no export"))
}

/// The status document: per-shard state, fleet liveness, and totals.
fn status_json(sh: &Shared) -> Json {
    let core = sh.core.lock().unwrap();
    let mut shards = Vec::with_capacity(core.states.len());
    let mut counts = [0usize; 4]; // pending, running, merged, failed
    for (i, st) in core.states.iter().enumerate() {
        let mut o = Json::obj();
        o.set("shard", Json::Num(i as f64));
        o.set(
            "caps_mb",
            Json::Arr(
                sh.shards[i]
                    .capacities_mb
                    .iter()
                    .map(|&m| Json::Num(m as f64))
                    .collect(),
            ),
        );
        o.set("points", Json::Num(sh.shard_points[i] as f64));
        o.set("state", Json::Str(st.name().to_string()));
        o.set(
            "worker",
            match st.worker() {
                Some(w) => Json::Str(w.to_string()),
                None => Json::Null,
            },
        );
        o.set("attempts", Json::Num(core.attempts[i] as f64));
        shards.push(o);
        let k = match st {
            ShardState::Pending => 0,
            ShardState::Running { .. } => 1,
            ShardState::Merged { .. } => 2,
            ShardState::Failed { .. } => 3,
        };
        counts[k] += 1;
    }
    let offsets = sh.offsets.lock().unwrap();
    let workers: Vec<Json> = sh
        .workers
        .iter()
        .enumerate()
        .map(|(w, addr)| {
            let mut o = Json::obj();
            o.set("addr", Json::Str(addr.clone()));
            o.set("alive", Json::Bool(core.worker_alive[w]));
            o.set("shards_merged", Json::Num(core.worker_merged[w] as f64));
            o.set(
                "clock_offset_ns",
                match offsets[w] {
                    Some(off) => Json::Num(off as f64),
                    None => Json::Null,
                },
            );
            o
        })
        .collect();
    let retried = core.attempts.iter().filter(|&&a| a > 1).count();
    let mut j = Json::obj();
    j.set("grid_points", Json::Num(sh.total_points as f64));
    j.set("shards", Json::Arr(shards));
    j.set("workers", Json::Arr(workers));
    j.set("pending", Json::Num(counts[0] as f64));
    j.set("running", Json::Num(counts[1] as f64));
    j.set("merged", Json::Num(counts[2] as f64));
    j.set("failed", Json::Num(counts[3] as f64));
    j.set("retried", Json::Num(retried as f64));
    j.set("uptime_s", Json::Num(sh.started.elapsed().as_secs_f64()));
    // Process-wide obs counters (accumulate across runs in the same
    // process; the pre-obs keys above are kept verbatim).
    j.set("dispatches", Json::Num(DISPATCHES.value() as f64));
    j.set("dispatch_retries", Json::Num(RETRIES.value() as f64));
    j.set("probes_ok", Json::Num(PROBES_OK.value() as f64));
    j.set("probes_dead", Json::Num(PROBES_DEAD.value() as f64));
    j.set("process_uptime_s", Json::Num(crate::obs::uptime().as_secs_f64()));
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::MemTech;
    use crate::workload::models::Phase;

    fn spec() -> SweepSpec {
        SweepSpec {
            techs: vec![MemTech::SttMram],
            capacities_mb: vec![1, 2, 4],
            dnns: vec![],
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        }
    }

    #[test]
    fn new_validates_fleet_and_spec() {
        let cfg = ScheduleConfig::default();
        assert!(Coordinator::new(&spec(), &cfg).is_err(), "no workers");

        let cfg = ScheduleConfig {
            workers: vec!["127.0.0.1:1".into()],
            deadline: Duration::ZERO,
            ..ScheduleConfig::default()
        };
        assert!(Coordinator::new(&spec(), &cfg).is_err(), "zero deadline");

        let cfg = ScheduleConfig {
            workers: vec!["127.0.0.1:1".into()],
            ..ScheduleConfig::default()
        };
        let bad = SweepSpec { capacities_mb: vec![], ..spec() };
        assert!(Coordinator::new(&bad, &cfg).is_err(), "empty capacity axis");
    }

    #[test]
    fn shards_scale_with_fleet_but_cap_at_the_axis() {
        // one worker, three caps: 2 shards (SHARDS_PER_WORKER)
        let cfg = ScheduleConfig {
            workers: vec!["127.0.0.1:1".into(), " 127.0.0.1:1 ".into()],
            ..ScheduleConfig::default()
        };
        // duplicate (whitespace-trimmed) worker collapses to one
        let c = Coordinator::new(&spec(), &cfg).unwrap();
        assert_eq!(c.shard_count(), SHARDS_PER_WORKER.min(3));
        assert!(c.status_addr().is_none());

        let cfg = ScheduleConfig {
            workers: (0..8).map(|i| format!("127.0.0.1:{i}")).collect(),
            ..ScheduleConfig::default()
        };
        let c = Coordinator::new(&spec(), &cfg).unwrap();
        assert_eq!(c.shard_count(), 3, "never more shards than capacities");
    }

    #[test]
    fn status_snapshot_starts_all_pending() {
        let cfg = ScheduleConfig {
            workers: vec!["127.0.0.1:1".into()],
            ..ScheduleConfig::default()
        };
        let c = Coordinator::new(&spec(), &cfg).unwrap();
        let j = c.status();
        assert_eq!(j.get("merged").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("failed").unwrap().as_u64(), Some(0));
        assert_eq!(
            j.get("pending").unwrap().as_u64(),
            Some(c.shard_count() as u64)
        );
        // circuit-only: one point per tech x capacity
        assert_eq!(j.get("grid_points").unwrap().as_u64(), Some(3));
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), c.shard_count());
        assert!(shards
            .iter()
            .all(|s| s.get("state").unwrap().as_str() == Some("pending")));
        let workers = j.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 1);
        assert_eq!(workers[0].get("alive").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn shard_state_display_and_names() {
        let s = ShardState::Merged { worker: "w:1".into(), accepted: 5, skipped: 2 };
        assert_eq!(s.name(), "merged");
        assert_eq!(s.worker(), Some("w:1"));
        assert!(s.to_string().contains("+5 entries"));
        assert_eq!(ShardState::Pending.worker(), None);
        assert!(ShardState::Failed { error: "x".into() }.to_string().contains("x"));
    }
}
