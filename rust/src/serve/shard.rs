//! Shardable memo exchange — the ROADMAP's cross-process sharding
//! front end.
//!
//! The wire format *is* the on-disk `sweep_memo.json` format
//! ([`Memo::to_json`]): content-addressed entries, each carrying a
//! payload hash that [`Memo::merge_json`] re-verifies on arrival. That
//! gives the fleet workflow for free:
//!
//! 1. split one grid into N disjoint specs ([`split_caps`]),
//! 2. each worker runs its shard (`deepnvm sweep` or its own `serve`)
//!    and ships its cache — `GET /memo/export`, or simply the
//!    `sweep_memo.json` it persisted,
//! 3. a coordinator `POST /memo/merge`s every shard; the union answers
//!    the full grid with zero circuit solves, and tampered or stale
//!    entries are rejected entry-by-entry, never merged blind.

use crate::sweep::{Memo, SweepSpec};
use crate::util::json::Json;

use super::http::{Request, Response};
use super::routes::ServerCtx;

/// `GET /memo/export` — the resident cache as one mergeable document.
pub fn export(ctx: &ServerCtx, _req: &Request) -> Response {
    Response::json(200, &ctx.memo().to_json())
}

/// `POST /memo/merge` — union a shard's exported cache into the
/// resident one. Responds with per-entry accounting; a model-version
/// mismatch is a 409 (typed envelope + the accounting fields, so a
/// coordinator can still read `version_ok` off the error body) and
/// merges nothing.
pub fn merge(ctx: &ServerCtx, req: &Request) -> Response {
    let (_, doc) = match super::routes::parse_body(req, |j| Ok(j.clone())) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let st = ctx.memo().merge_json(&doc);
    let mut j = Json::obj();
    j.set("version_ok", Json::Bool(st.version_ok));
    j.set("accepted", Json::Num(st.accepted as f64));
    j.set("skipped", Json::Num(st.skipped as f64));
    j.set("rejected", Json::Num(st.rejected as f64));
    j.set("circuit_entries", Json::Num(ctx.memo().circuit_len() as f64));
    j.set("traffic_entries", Json::Num(ctx.memo().traffic_len() as f64));
    j.set("point_entries", Json::Num(ctx.memo().point_len() as f64));
    if st.version_ok {
        return Response::json(200, &j);
    }
    let mut e = Json::obj();
    e.set("code", Json::Num(409.0));
    e.set("kind", Json::Str("version_mismatch".into()));
    e.set(
        "message",
        Json::Str("shard document was built against another model version; nothing merged".into()),
    );
    j.set("error", e);
    Response::json(409, &j)
}

/// Split a spec into at most `n` disjoint shards along the capacity
/// axis (the axis that dominates circuit-solve cost). Capacities are
/// dealt largest-first onto the currently lightest shard (LPT
/// scheduling, with the capacity itself as the cost proxy: the
/// Algorithm-1 enumeration grows with capacity), so ascending and
/// descending input lists yield the same balanced partition — dealing
/// round-robin in input order used to concentrate the expensive
/// large-capacity solves in one shard. Each shard's capacity list is
/// sorted, so shard specs are independent of input order too. The
/// shard expansions partition the full expansion exactly, so merging
/// the shard memos reproduces the full-grid cache.
pub fn split_caps(spec: &SweepSpec, n: usize) -> Vec<SweepSpec> {
    let n = n.max(1).min(spec.capacities_mb.len());
    if n == 0 {
        return vec![];
    }
    let mut shards: Vec<SweepSpec> = (0..n)
        .map(|_| SweepSpec { capacities_mb: vec![], ..spec.clone() })
        .collect();
    let mut order: Vec<usize> = (0..spec.capacities_mb.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(spec.capacities_mb[i]), i));
    let mut load = vec![0u64; n];
    for i in order {
        let mb = spec.capacities_mb[i];
        let k = (0..n).min_by_key(|&k| (load[k], k)).expect("n >= 1");
        load[k] += mb;
        shards[k].capacities_mb.push(mb);
    }
    for s in &mut shards {
        s.capacities_mb.sort_unstable();
    }
    shards.retain(|s| !s.capacities_mb.is_empty());
    shards
}

/// Convenience for shard workers driven from Rust: run a shard spec
/// into `memo` and return the exported document to ship to the
/// coordinator.
pub fn run_shard(spec: &SweepSpec, jobs: usize, memo: &Memo) -> anyhow::Result<Json> {
    crate::sweep::run(spec, jobs, memo)?;
    Ok(memo.to_json())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::MemTech;
    use crate::workload::models::Phase;
    use std::collections::HashSet;

    fn spec() -> SweepSpec {
        SweepSpec {
            techs: crate::nvsim::TechSel::pures(&MemTech::ALL),
            capacities_mb: vec![1, 2, 4, 8, 16],
            dnns: vec!["AlexNet".into()],
            phases: Phase::ALL.to_vec(),
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        }
    }

    #[test]
    fn shards_partition_the_expansion() {
        let full = spec();
        let all: HashSet<_> = full.expand().unwrap().into_iter().collect();
        for n in [1, 2, 3, 5, 9] {
            let shards = split_caps(&full, n);
            assert!(shards.len() <= n);
            assert!(!shards.is_empty());
            let mut seen = HashSet::new();
            for s in &shards {
                for p in s.expand().unwrap() {
                    assert!(seen.insert(p), "shards must be disjoint (n={n})");
                }
            }
            assert_eq!(seen, all, "shards must cover the full grid (n={n})");
        }
    }

    #[test]
    fn split_caps_balances_cost_regardless_of_input_order() {
        let caps = vec![1u64, 2, 4, 8, 16, 32];
        let asc = SweepSpec { capacities_mb: caps.clone(), ..spec() };
        let desc = SweepSpec {
            capacities_mb: caps.iter().rev().copied().collect(),
            ..spec()
        };
        for s in [&asc, &desc] {
            let shards = split_caps(s, 2);
            let mut loads: Vec<u64> = shards
                .iter()
                .map(|sh| sh.capacities_mb.iter().sum())
                .collect();
            loads.sort_unstable();
            // LPT: {32} vs {16, 8, 4, 2, 1} — round-robin dealing of the
            // descending list used to pile 32+8+2=42 onto one shard.
            assert_eq!(loads, vec![31, 32], "shard costs must balance");
        }
        // the partition itself is input-order independent
        for n in [2, 3, 4] {
            let a: Vec<Vec<u64>> =
                split_caps(&asc, n).iter().map(|s| s.capacities_mb.clone()).collect();
            let d: Vec<Vec<u64>> =
                split_caps(&desc, n).iter().map(|s| s.capacities_mb.clone()).collect();
            assert_eq!(a, d, "ascending/descending inputs must shard identically (n={n})");
        }
    }

    #[test]
    fn multi_node_shards_partition_and_replay_solve_free() {
        // Because CircuitKey and the point keys carry node_nm, the
        // shard exchange handles multi-node grids with no extra code:
        // cut a {16, 7} nm grid, run each shard on its own worker memo,
        // merge, and replay the full cross-node grid from cache alone.
        let full = SweepSpec {
            techs: vec![MemTech::SttMram.into()],
            capacities_mb: vec![1, 2, 4],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![],
            nodes_nm: vec![16, 7],
            filters: vec![],
        };
        let shards = split_caps(&full, 2);
        assert_eq!(shards.len(), 2);
        for s in &shards {
            assert_eq!(s.nodes_nm, vec![16, 7], "shards keep the node axis");
        }
        // shards partition the multi-node expansion exactly
        let all: HashSet<_> = full.expand().unwrap().into_iter().collect();
        let mut seen = HashSet::new();
        for s in &shards {
            for p in s.expand().unwrap() {
                assert!(seen.insert(p), "multi-node shards must be disjoint");
            }
        }
        assert_eq!(seen, all);

        let coordinator = Memo::new();
        for s in &shards {
            let worker = Memo::new();
            let doc = run_shard(s, 2, &worker).unwrap();
            let st = coordinator.merge_json(&doc);
            assert!(st.version_ok);
            assert_eq!(st.rejected, 0);
        }
        let res = crate::sweep::run(&full, 2, &coordinator).unwrap();
        assert_eq!(res.points.len(), all.len());
        assert_eq!(coordinator.solve_count(), 0, "multi-node replay must not solve");
        assert_eq!(coordinator.eval_count(), 0);
        // both nodes' circuits are resident and distinct: stt + sram
        // baseline per (cap, node)
        assert_eq!(coordinator.circuit_len(), 2 * 3 * 2);
    }

    #[test]
    fn merged_shard_memos_answer_full_grid_without_solving() {
        let full = spec();
        let shards = split_caps(&full, 2);
        assert_eq!(shards.len(), 2);

        // two workers, two private caches
        let coordinator = Memo::new();
        for s in &shards {
            let worker = Memo::new();
            let doc = run_shard(s, 2, &worker).unwrap();
            let st = coordinator.merge_json(&doc);
            assert!(st.version_ok);
            assert_eq!(st.rejected, 0);
            assert!(st.accepted > 0);
        }

        // the union replays the FULL grid from cache alone
        let res = crate::sweep::run(&full, 2, &coordinator).unwrap();
        assert_eq!(res.points.len(), full.expand().unwrap().len());
        assert_eq!(coordinator.solve_count(), 0, "no circuit solves after merge");
        assert_eq!(coordinator.eval_count(), 0, "no traffic evals after merge");
    }
}
