//! `deepnvm loadgen` — a closed-loop soak harness for a running
//! server.
//!
//! `N` worker threads each hold one keep-alive connection
//! ([`super::http::Client`]) and drive a mixed `POST /solve` +
//! `POST /sweep` + `POST /optimize` workload against `--addr` for
//! `--duration` seconds. The mix is a ratio (`--mix 9:1` = nine solves
//! per sweep; `--mix 8:1:1` adds one branch-and-bound `/optimize` per
//! cycle — the heavy-query path), rotated deterministically per thread
//! so the blend holds at any concurrency.
//!
//! Latencies land in the same registry `GET /metrics` serves, as
//! `deepnvm_loadgen_request_duration_ns{kind="solve"|"sweep"|"optimize"}`
//! — the
//! report's quantiles are computed from those histograms (via
//! before/after [`HistSnapshot::minus`] deltas, so a loadgen run in a
//! long-lived process reports only its own window), which keeps the
//! printed numbers and the scrape-visible numbers one source of truth.
//! Quantiles are log2-bucket upper bounds, i.e. conservative within
//! 2x; the p99 gate (`--p99-ms`) compares against that upper bound,
//! so a pass is a real pass.

use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::obs::{Histogram, LazyCounter, LazyHistogram};

use super::auth;
use super::http;

/// Per-request socket deadline. Generous: the gate is on quantiles,
/// not on individual stragglers, and a cold first solve may be slow.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(30);

/// How long the pre-flight `/healthz` probe may take.
const PREFLIGHT_TIMEOUT: Duration = Duration::from_secs(3);

// Loadgen's own obs series: scrape-visible on any co-resident server
// and the source of the report's quantiles.
static SOLVE_NS: LazyHistogram =
    LazyHistogram::new("deepnvm_loadgen_request_duration_ns{kind=\"solve\"}");
static SWEEP_NS: LazyHistogram =
    LazyHistogram::new("deepnvm_loadgen_request_duration_ns{kind=\"sweep\"}");
static OPTIMIZE_NS: LazyHistogram =
    LazyHistogram::new("deepnvm_loadgen_request_duration_ns{kind=\"optimize\"}");
// Solve latency split by key class when `--hot-frac` is set: the hot
// set replays a handful of keys (memo-hit steady state), the cold
// tail walks a wide pool of distinct keys (point-cache misses).
static HOT_NS: LazyHistogram =
    LazyHistogram::new("deepnvm_loadgen_request_duration_ns{class=\"hot\"}");
static COLD_NS: LazyHistogram =
    LazyHistogram::new("deepnvm_loadgen_request_duration_ns{class=\"cold\"}");
static ERRORS: LazyCounter = LazyCounter::new("deepnvm_loadgen_errors_total");

/// Configuration for one loadgen run (the CLI's `loadgen --addr
/// --duration --concurrency --mix --p99-ms`).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Target server (`host:port` of a running `deepnvm serve`).
    pub addr: String,
    /// Wall-clock run length.
    pub duration: Duration,
    /// Worker threads, one keep-alive connection each.
    pub concurrency: usize,
    /// Solve requests per mix cycle.
    pub solve_weight: u32,
    /// Sweep requests per mix cycle.
    pub sweep_weight: u32,
    /// Optimize (branch-and-bound) requests per mix cycle.
    pub optimize_weight: u32,
    /// Fraction of solve requests drawn from the small hot key set
    /// (the rest walk the wide cold-tail pool). `None` keeps the
    /// historical all-hot behavior and omits the per-class report.
    pub hot_frac: Option<f64>,
    /// Overall p99 gate in milliseconds; `None` disables gating.
    pub p99_ms: Option<f64>,
    /// Shared secret (`--auth-key` / `DEEPNVM_AUTH_KEY`): when set,
    /// every POST is signed with an `X-Deepnvm-Auth` tag so the soak
    /// can target a hardened server.
    pub auth_key: Option<String>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8090".into(),
            duration: Duration::from_secs(10),
            concurrency: 4,
            solve_weight: 9,
            sweep_weight: 1,
            optimize_weight: 0,
            hot_frac: None,
            p99_ms: None,
            auth_key: None,
        }
    }
}

/// Parse a `--mix` ratio like `"9:1"` or `"8:1:1"` into
/// (solve, sweep, optimize) weights; the optimize weight defaults to 0
/// so the historical two-kind form keeps meaning what it always meant.
pub fn parse_mix(s: &str) -> Result<(u32, u32, u32)> {
    let mut parts = s.split(':');
    let mut next = |what: &str| -> Result<u32> {
        let p = parts
            .next()
            .with_context(|| format!("--mix wants SOLVE:SWEEP[:OPTIMIZE] (e.g. 9:1), got {s:?}"))?;
        p.trim().parse().with_context(|| format!("bad {what} weight {p:?}"))
    };
    let sv = next("solve")?;
    let sw = next("sweep")?;
    let so = if s.matches(':').count() >= 2 { next("optimize")? } else { 0 };
    ensure!(parts.next().is_none(), "--mix {s:?} has too many components");
    ensure!(sv + sw + so > 0, "--mix {s:?} would send no requests");
    Ok((sv, sw, so))
}

/// Latency summary for one request kind.
#[derive(Clone, Copy, Debug)]
pub struct KindStats {
    pub requests: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// What one loadgen run measured.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// Requests answered 200.
    pub requests: u64,
    /// Transport failures and non-200 answers.
    pub errors: u64,
    /// Successful requests per wall-clock second.
    pub qps: f64,
    /// Overall latency quantiles (log2-bucket upper bounds, ms).
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub solve: KindStats,
    pub sweep: KindStats,
    pub optimize: KindStats,
    /// Per-class solve latency; present only when `--hot-frac` split
    /// the key mix.
    pub hot: Option<KindStats>,
    pub cold: Option<KindStats>,
    pub wall: Duration,
}

impl LoadgenReport {
    /// Does the run pass a p99 gate of `limit_ms`?
    pub fn meets_p99(&self, limit_ms: f64) -> bool {
        self.p99_ms <= limit_ms
    }

    /// The human report `deepnvm loadgen` prints. The optimize line
    /// only appears when the mix actually sent optimize requests.
    pub fn render(&self) -> String {
        let mut out = format!(
            "loadgen: {} requests in {:.1}s ({:.0} req/s), {} errors\n\
             loadgen: overall  p50 {:.3} ms  p99 {:.3} ms\n\
             loadgen: solve    {} requests  p50 {:.3} ms  p99 {:.3} ms\n\
             loadgen: sweep    {} requests  p50 {:.3} ms  p99 {:.3} ms",
            self.requests,
            self.wall.as_secs_f64(),
            self.qps,
            self.errors,
            self.p50_ms,
            self.p99_ms,
            self.solve.requests,
            self.solve.p50_ms,
            self.solve.p99_ms,
            self.sweep.requests,
            self.sweep.p50_ms,
            self.sweep.p99_ms,
        );
        if self.optimize.requests > 0 {
            out.push_str(&format!(
                "\nloadgen: optimize {} requests  p50 {:.3} ms  p99 {:.3} ms",
                self.optimize.requests, self.optimize.p50_ms, self.optimize.p99_ms,
            ));
        }
        if let Some(h) = &self.hot {
            out.push_str(&format!(
                "\nloadgen: hot      {} requests  p50 {:.3} ms  p99 {:.3} ms",
                h.requests, h.p50_ms, h.p99_ms,
            ));
        }
        if let Some(c) = &self.cold {
            out.push_str(&format!(
                "\nloadgen: cold     {} requests  p50 {:.3} ms  p99 {:.3} ms",
                c.requests, c.p50_ms, c.p99_ms,
            ));
        }
        out
    }
}

/// The request bodies one thread rotates through. Small pools on
/// purpose: after each body's first solve the server answers from its
/// memo, so a soak measures steady-state serving, not solver cost.
fn solve_bodies() -> Vec<String> {
    let mut v = Vec::new();
    for tech in ["stt", "sot", "sram"] {
        for cap in [1u64, 2] {
            v.push(format!(r#"{{"tech": "{tech}", "capacity_mb": {cap}}}"#));
        }
    }
    v
}

/// The cold tail: a wide pool of distinct solve keys. Hybrid steer /
/// way variations give hundreds of distinct grid points that all
/// compose from the same two cached pure partner solves, so cold
/// requests exercise the point-cache-miss path without re-running
/// Algorithm 1 per key.
fn cold_bodies() -> Vec<String> {
    let mut v = Vec::new();
    for ways in [2u32, 4, 6, 8, 10, 12] {
        for bp in (500..10_000).step_by(500) {
            let steer = bp as f64 / 1e4;
            v.push(format!(r#"{{"tech": "hybrid-stt:{ways}@{steer}", "capacity_mb": 2}}"#));
        }
    }
    v
}

/// Deterministic hot/cold classification of the `i`-th request on a
/// thread: percent-of-cycle against the configured fraction, so the
/// realized mix matches `hot_frac` exactly over any 100 requests.
fn is_hot(i: u64, hot_frac: f64) -> bool {
    (i % 100) < (hot_frac * 100.0).round().clamp(0.0, 100.0) as u64
}

fn sweep_bodies() -> Vec<String> {
    vec![
        r#"{"techs": ["stt"], "caps_mb": [1, 2], "dnns": [], "jobs": 1}"#.to_string(),
        r#"{"techs": ["sot"], "caps_mb": [1, 2], "dnns": [], "jobs": 1}"#.to_string(),
    ]
}

/// `/optimize` bodies: small workload grids, so a soak exercises the
/// branch-and-bound path (column solves, bounds, incumbent search)
/// at memo-hit steady state rather than re-solving circuits forever.
fn optimize_bodies() -> Vec<String> {
    vec![
        r#"{"techs": ["stt", "sot"], "caps_mb": [1, 2], "dnns": ["AlexNet"],
            "phases": ["inference"], "batches": [1, 4], "objective": "edp", "jobs": 1}"#
            .to_string(),
        r#"{"techs": ["stt"], "caps_mb": [1, 2], "dnns": ["AlexNet"],
            "phases": ["training"], "batches": [1, 4], "objective": "energy", "jobs": 1}"#
            .to_string(),
    ]
}

fn kind_stats(delta: &crate::obs::HistSnapshot) -> KindStats {
    KindStats {
        requests: delta.count,
        p50_ms: delta.quantile(0.5) as f64 / 1e6,
        p99_ms: delta.quantile(0.99) as f64 / 1e6,
    }
}

/// Run the soak: probe `/healthz`, drive the mixed workload from
/// `concurrency` threads until `duration` elapses, and summarize this
/// run's latency window. Transport errors and non-200s never abort
/// the run — they count into `errors` (and the CLI gates on them).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    ensure!(cfg.concurrency > 0, "--concurrency must be at least 1");
    ensure!(
        cfg.solve_weight + cfg.sweep_weight + cfg.optimize_weight > 0,
        "the mix would send no requests"
    );
    if let Some(f) = cfg.hot_frac {
        ensure!(f.is_finite() && (0.0..=1.0).contains(&f), "--hot-frac must be in [0, 1]");
    }
    match http::call(&cfg.addr, "GET", "/healthz", "", PREFLIGHT_TIMEOUT) {
        Ok((200, _)) => {}
        Ok((status, _)) => bail!("{} answered {status} to /healthz", cfg.addr),
        Err(e) => bail!("{} is not answering /healthz: {e:#}", cfg.addr),
    }

    let solve_before = SOLVE_NS.handle().snapshot();
    let sweep_before = SWEEP_NS.handle().snapshot();
    let optimize_before = OPTIMIZE_NS.handle().snapshot();
    let hot_before = HOT_NS.handle().snapshot();
    let cold_before = COLD_NS.handle().snapshot();
    let errors_before = ERRORS.value();
    let solves = solve_bodies();
    let colds = cold_bodies();
    let sweeps = sweep_bodies();
    let optimizes = optimize_bodies();
    let cycle = (cfg.solve_weight + cfg.sweep_weight + cfg.optimize_weight) as u64;
    let started = Instant::now();
    let deadline = started + cfg.duration;

    std::thread::scope(|scope| {
        for t in 0..cfg.concurrency {
            let (solves, colds, sweeps, optimizes) = (&solves, &colds, &sweeps, &optimizes);
            scope.spawn(move || {
                let mut client = http::Client::new(&cfg.addr, REQUEST_TIMEOUT);
                // Offset each thread's rotation so the fleet of
                // threads interleaves kinds instead of phase-locking.
                let mut i = t as u64;
                // Consecutive 503 sheds on this connection: drives the
                // exponential backoff curve, reset by any success.
                let mut shed_streak = 0u32;
                while Instant::now() < deadline {
                    // Position within one mix cycle: solves first,
                    // then sweeps, then optimizes.
                    let pos = i % cycle;
                    let mut class = None;
                    let (path, body, hist) = if pos < cfg.solve_weight as u64 {
                        let b = match cfg.hot_frac {
                            Some(f) if !is_hot(i, f) => {
                                class = Some(&COLD_NS);
                                &colds[(i / cycle) as usize % colds.len()]
                            }
                            Some(_) => {
                                class = Some(&HOT_NS);
                                &solves[(i / cycle) as usize % solves.len()]
                            }
                            None => &solves[(i / cycle) as usize % solves.len()],
                        };
                        ("/solve", b, &SOLVE_NS)
                    } else if pos < (cfg.solve_weight + cfg.sweep_weight) as u64 {
                        let b = &sweeps[(i / cycle) as usize % sweeps.len()];
                        ("/sweep", b, &SWEEP_NS)
                    } else {
                        let b = &optimizes[(i / cycle) as usize % optimizes.len()];
                        ("/optimize", b, &OPTIMIZE_NS)
                    };
                    let t0 = Instant::now();
                    let reply = match &cfg.auth_key {
                        Some(key) => {
                            let tag = auth::sign(key, "POST", path, body.as_bytes());
                            client.call_with(
                                "POST",
                                path,
                                &[(auth::AUTH_HEADER, tag.as_str())],
                                body,
                            )
                        }
                        None => client.call("POST", path, body),
                    };
                    match reply {
                        Ok((200, _)) => {
                            shed_streak = 0;
                            let elapsed = t0.elapsed();
                            hist.record_duration(elapsed);
                            if let Some(c) = class {
                                c.record_duration(elapsed);
                            }
                        }
                        Ok((503, _)) => {
                            // The server shed us: count the error, then
                            // back off (honoring Retry-After) instead
                            // of contributing to the flood.
                            ERRORS.inc();
                            let wait = http::backoff_delay(
                                shed_streak,
                                client.last_retry_after(),
                            );
                            shed_streak = shed_streak.saturating_add(1);
                            std::thread::sleep(
                                wait.min(deadline.saturating_duration_since(Instant::now())),
                            );
                        }
                        Ok(_) | Err(_) => ERRORS.inc(),
                    }
                    i += 1;
                }
            });
        }
    });

    let wall = started.elapsed();
    let solve_delta = SOLVE_NS.handle().snapshot().minus(&solve_before);
    let sweep_delta = SWEEP_NS.handle().snapshot().minus(&sweep_before);
    let optimize_delta = OPTIMIZE_NS.handle().snapshot().minus(&optimize_before);
    // The overall quantiles come from federating the per-kind windows
    // — the same bucket-wise merge `/scheduler/metrics` uses.
    let overall = Histogram::new();
    overall.merge_snapshot(&solve_delta);
    overall.merge_snapshot(&sweep_delta);
    overall.merge_snapshot(&optimize_delta);
    let requests = solve_delta.count + sweep_delta.count + optimize_delta.count;
    Ok(LoadgenReport {
        requests,
        errors: ERRORS.value() - errors_before,
        qps: requests as f64 / wall.as_secs_f64().max(1e-9),
        p50_ms: overall.quantile(0.5) as f64 / 1e6,
        p99_ms: overall.quantile(0.99) as f64 / 1e6,
        solve: kind_stats(&solve_delta),
        sweep: kind_stats(&sweep_delta),
        optimize: kind_stats(&optimize_delta),
        hot: cfg
            .hot_frac
            .map(|_| kind_stats(&HOT_NS.handle().snapshot().minus(&hot_before))),
        cold: cfg
            .hot_frac
            .map(|_| kind_stats(&COLD_NS.handle().snapshot().minus(&cold_before))),
        wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_parses_and_rejects_nonsense() {
        assert_eq!(parse_mix("9:1").unwrap(), (9, 1, 0));
        assert_eq!(parse_mix("1:0").unwrap(), (1, 0, 0));
        assert_eq!(parse_mix(" 3 : 2 ").unwrap(), (3, 2, 0));
        assert_eq!(parse_mix("8:1:1").unwrap(), (8, 1, 1));
        assert_eq!(parse_mix("0:0:5").unwrap(), (0, 0, 5));
        assert!(parse_mix("9").is_err());
        assert!(parse_mix("a:b").is_err());
        assert!(parse_mix("0:0").is_err());
        assert!(parse_mix("0:0:0").is_err());
        assert!(parse_mix("1:2:3:4").is_err());
    }

    #[test]
    fn report_renders_and_gates() {
        let mut r = LoadgenReport {
            requests: 100,
            errors: 0,
            qps: 50.0,
            p50_ms: 1.0,
            p99_ms: 4.0,
            solve: KindStats { requests: 90, p50_ms: 1.0, p99_ms: 4.0 },
            sweep: KindStats { requests: 10, p50_ms: 2.0, p99_ms: 4.0 },
            optimize: KindStats { requests: 0, p50_ms: 0.0, p99_ms: 0.0 },
            hot: None,
            cold: None,
            wall: Duration::from_secs(2),
        };
        assert!(r.meets_p99(4.0));
        assert!(!r.meets_p99(3.9));
        let text = r.render();
        assert!(text.contains("100 requests"), "{text}");
        assert!(text.contains("p99 4.000 ms"), "{text}");
        // a two-kind mix stays a two-line per-kind summary
        assert!(!text.contains("optimize"), "{text}");
        // hot/cold lines only appear when --hot-frac was given
        assert!(!text.contains("hot"), "{text}");
        r.optimize = KindStats { requests: 5, p50_ms: 3.0, p99_ms: 6.0 };
        r.hot = Some(KindStats { requests: 76, p50_ms: 0.5, p99_ms: 1.0 });
        r.cold = Some(KindStats { requests: 14, p50_ms: 2.5, p99_ms: 5.0 });
        let text = r.render();
        assert!(text.contains("optimize 5 requests"), "{text}");
        assert!(text.contains("hot      76 requests"), "{text}");
        assert!(text.contains("cold     14 requests"), "{text}");
    }

    #[test]
    fn hot_frac_splits_the_index_space_exactly() {
        for (f, want) in [(0.0, 0), (0.85, 850), (1.0, 1000)] {
            let hits = (0..1000u64).filter(|&i| is_hot(i, f)).count();
            assert_eq!(hits, want, "hot_frac {f}");
        }
        // out-of-range run() inputs are rejected before any thread spawns
        for bad in [-0.1, 1.1, f64::NAN] {
            let cfg = LoadgenConfig { hot_frac: Some(bad), ..LoadgenConfig::default() };
            let err = run(&cfg).unwrap_err().to_string();
            assert!(err.contains("hot-frac"), "{err}");
        }
    }

    #[test]
    fn loadgen_refuses_a_dead_target() {
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:1".into(), // reserved port: nothing listens
            duration: Duration::from_millis(50),
            ..LoadgenConfig::default()
        };
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("/healthz"), "{err}");
    }

    #[test]
    fn body_pools_are_nonempty_and_distinct() {
        let sv = solve_bodies();
        let sw = sweep_bodies();
        let so = optimize_bodies();
        let co = cold_bodies();
        assert!(sv.len() >= 4 && sw.len() >= 2 && so.len() >= 2);
        assert_eq!(co.len(), 114, "6 way counts x 19 steer points");
        for b in sv.iter().chain(sw.iter()).chain(so.iter()).chain(co.iter()) {
            assert!(crate::util::json::parse(b).is_ok(), "{b}");
        }
        // every cold body is a distinct point key (a genuine cold tail)
        let uniq: std::collections::HashSet<&String> = co.iter().collect();
        assert_eq!(uniq.len(), co.len());
        // and every cold tech spelling actually parses as a hybrid
        for b in &co {
            let j = crate::util::json::parse(b).unwrap();
            let t = j.get("tech").unwrap().as_str().unwrap().to_string();
            let sel = crate::sweep::spec::parse_tech_sel(&t).unwrap();
            assert!(sel.pure().is_none(), "{t} should be hybrid");
        }
    }
}
