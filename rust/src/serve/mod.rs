//! `deepnvm serve` — a resident sweep-query server.
//!
//! Every CLI invocation pays process startup, renders, and exits; this
//! subsystem keeps the cross-layer grid *warm* instead. A long-lived
//! HTTP/1.1 process ([`http`]) holds the process-wide [`Memo`] and
//! answers scenario queries ([`routes`]) at cache-hit latency:
//! `--prewarm` runs the full paper grid at startup, after which a
//! `/sweep` for any paper slice performs zero circuit solves. The
//! shard exchange ([`shard`]) lets N workers split one grid and a
//! coordinator union their caches, and the multi-host scheduler
//! ([`scheduler`]) drives that fleet end to end: `deepnvm coordinate`
//! assigns shards, retries stragglers and dead workers, and merges
//! exports until the union replays the full grid with zero solves.
//!
//! Dependency-free by construction: `std::net` + the in-tree
//! `util::json`, matching the offline vendor set.

pub mod auth;
pub mod http;
pub mod loadgen;
pub mod routes;
pub mod scheduler;
pub mod shard;

pub use http::{Request, Response, Server};
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use routes::ServerCtx;
pub use scheduler::{coordinate, Coordinator, ScheduleConfig, ScheduleReport};

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::store::Store;
use crate::device::MemTech;
use crate::sweep::spec::DEFAULT_CAPACITIES_MB;
use crate::sweep::{self, exec, memo, Memo, SweepSpec};

/// Configuration for one server instance (the CLI's
/// `serve --addr --jobs --prewarm --memo-cap --out`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; `:0` picks an ephemeral port.
    pub addr: String,
    /// Worker threads for both connections and in-request sweeps
    /// (0 = one per core).
    pub jobs: usize,
    /// Solve the full paper grid before accepting traffic.
    pub prewarm: bool,
    /// LRU bound on the memo's point layer (None = unbounded).
    pub memo_cap: Option<usize>,
    /// Results directory: the memo warms from and persists to
    /// `<out>/sweep_memo.json` there.
    pub out: String,
    /// Shared secret (`--auth-key` / `DEEPNVM_AUTH_KEY`): when set,
    /// mutating POST routes require a valid `X-Deepnvm-Auth` tag.
    pub auth_key: Option<String>,
    /// Accept-queue bound (`--queue-cap`); `None` = the default
    /// `jobs * `[`http::DEFAULT_QUEUE_CAP_PER_JOB`]. Over-cap
    /// connections are shed with `503` + `Retry-After`.
    pub queue_cap: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8090".into(),
            jobs: 0,
            prewarm: false,
            memo_cap: None,
            out: "results".into(),
            auth_key: None,
            queue_cap: None,
        }
    }
}

/// Evaluate the full paper grid into `memo`: every circuit-only point
/// (the Fig 9 shape) plus the complete workload cross-product (the
/// Fig 10 shape), so any slice of either is a pure cache hit
/// afterwards. Returns (circuit solves performed, resident points).
pub fn prewarm(memo: &Memo, jobs: usize) -> Result<(u64, usize)> {
    let before = memo.solve_count();
    let circuits =
        SweepSpec::circuit_only(MemTech::ALL.to_vec(), DEFAULT_CAPACITIES_MB.to_vec());
    sweep::run(&circuits, jobs, memo)?;
    sweep::run(&SweepSpec::default(), jobs, memo)?;
    Ok((memo.solve_count() - before, memo.point_len()))
}

/// Bind and start a server over `memo`. Warms from the on-disk cache
/// in `cfg.out` when present; with `cfg.prewarm` also solves the full
/// paper grid (and persists it back) before accepting traffic.
pub fn start(cfg: &ServeConfig, memo: &'static Memo) -> Result<Server> {
    memo.set_point_capacity(cfg.memo_cap);
    let jobs = if cfg.jobs == 0 { exec::default_jobs() } else { cfg.jobs };

    let store = Store::new(&cfg.out);
    match memo.load_from(&store) {
        Ok(n) if n > 0 => eprintln!(
            "serve: warmed memo with {n} entries from {}",
            store.blob_path(memo::MEMO_FILE).display()
        ),
        Ok(_) => {}
        Err(e) => eprintln!("warning: ignoring memo cache: {e}"),
    }
    if cfg.prewarm {
        let t0 = Instant::now();
        let (solves, points) = prewarm(memo, jobs)?;
        eprintln!(
            "serve: prewarmed the paper grid in {:.1}s ({solves} circuit solves, \
             {points} resident points)",
            t0.elapsed().as_secs_f64()
        );
        if let Err(e) = memo.save_to(&store) {
            eprintln!("warning: could not persist sweep memo: {e}");
        }
    }

    let ctx = Arc::new(ServerCtx::new(memo, jobs).with_auth_key(cfg.auth_key.clone()));
    Server::bind_with(&cfg.addr, jobs, cfg.queue_cap, move |req| routes::handle(&ctx, req))
}

/// Foreground CLI mode: serve the process-wide memo until killed.
pub fn run(cfg: &ServeConfig) -> Result<()> {
    let server = start(cfg, memo::global())?;
    println!(
        "deepnvm serve: listening on http://{} (GET / for the route table; /healthz, \
         /memo/stats, /memo/export, /metrics, /trace; POST /solve, /sweep, /optimize, \
         /memo/merge, /shard/run)",
        server.local_addr()
    );
    if cfg.auth_key.is_some() {
        println!("deepnvm serve: authentication enabled (mutating POSTs require X-Deepnvm-Auth)");
    }
    server.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prewarm_makes_fig9_slice_free() {
        let memo = Memo::new();
        // a tiny "paper grid": just assert the mechanism, not the full
        // 180-point grid (the e2e test and --prewarm cover that).
        let spec = SweepSpec::circuit_only(MemTech::ALL.to_vec(), vec![1, 2]);
        sweep::run(&spec, 2, &memo).unwrap();
        let solves = memo.solve_count();
        let evals = memo.eval_count();
        sweep::run(&spec, 2, &memo).unwrap();
        assert_eq!(memo.solve_count(), solves);
        assert_eq!(memo.eval_count(), evals);
    }

    #[test]
    fn start_binds_ephemeral_port_and_answers() {
        use std::io::{Read, Write};

        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            jobs: 2,
            out: std::env::temp_dir()
                .join("deepnvm_serve_mod_test")
                .to_string_lossy()
                .into_owned(),
            ..ServeConfig::default()
        };
        let memo: &'static Memo = Box::leak(Box::new(Memo::new()));
        let server = start(&cfg, memo).unwrap();
        let mut s = std::net::TcpStream::connect(server.local_addr()).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
        assert!(buf.contains("\"status\": \"ok\""), "{buf}");
    }
}
