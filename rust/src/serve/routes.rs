//! Route handlers: the query API over the resident sweep grid.
//!
//! | route              | method | body / query                                   |
//! |--------------------|--------|------------------------------------------------|
//! | `/`                | GET    | generated route table (the API reference)      |
//! | `/healthz`         | GET    | liveness + uptime + `api_version`              |
//! | `/memo/stats`      | GET    | cache population and solve/eval counters       |
//! | `/solve`           | POST   | one grid point -> tuned config (+ eval)        |
//! | `/sweep`           | POST   | `SweepSpec` JSON -> spec-ordered report rows   |
//! | `/optimize`        | POST   | `OptimizeRequest` -> branch-and-bound winner   |
//! | `/memo/export`     | GET    | full memo document (shard exchange format)     |
//! | `/memo/merge`      | POST   | memo document -> per-entry merge accounting    |
//! | `/shard/run`       | POST   | shard `SweepSpec` -> run into memo + export    |
//! | `/validate`        | POST   | (dnns, phases, caps) -> sim-vs-analytic table  |
//! | `/metrics`         | GET    | Prometheus text exposition of the obs registry |
//! | `/trace`           | GET    | span ring as Chrome trace-event JSON           |
//!
//! The v1 API contract: every POST body goes through one
//! [`parse_body`] layer; every 4xx/5xx is the typed envelope
//! `{"error": {"code", "kind", "message"}}` with a stable
//! machine-readable `kind` ([`error_response`]); every response —
//! success or error — carries a `Deepnvm-Api-Version` header bound to
//! [`memo::MODEL_VERSION`] (stamped in `http::Response::write_to_with`,
//! so no handler can forget it).
//!
//! `/sweep` renders through the exact same report pipeline as the CLI
//! (`reports::sweep_report_with`, `fig9_with`, `fig10_with`), so the
//! `rows` array is byte-identical, cell for cell, to the CSV the CLI
//! writes for the same query.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::reports::{self, Report};
use crate::device::UncalibratedNode;
use crate::obs::{self, Counter, Registry};
use crate::gpusim::validate;
use crate::sweep::spec::{
    optimize_request_from_json, optimize_response_to_json, parse_phase, parse_tech_sel,
    resolve_dnn, spec_from_json, DEFAULT_CAPACITIES_MB, MAX_BATCH, MAX_CAPACITY_MB,
};
use crate::sweep::{self, memo, GridPoint, Memo, SweepSpec, WorkloadPoint};
use crate::util::json::Json;

use super::auth;
use super::http::{Request, Response};
use super::shard;

use crate::obs::LazyCounter;

// Requests refused at the authentication gate (fleet-visible: a spike
// means a misconfigured peer or an active probe).
static AUTH_REJECTS: LazyCounter = LazyCounter::new("deepnvm_http_auth_rejects_total");

/// One row of the API reference. Dispatch's 405 matrix and the
/// generated `GET /` table both derive from [`ROUTES`], so a new route
/// self-documents by construction.
struct RouteInfo {
    method: &'static str,
    path: &'static str,
    request: &'static str,
    response: &'static str,
}

const ROUTES: [RouteInfo; 12] = [
    RouteInfo {
        method: "GET",
        path: "/",
        request: "-",
        response: "this route table + the error-envelope and versioning contract",
    },
    RouteInfo {
        method: "GET",
        path: "/healthz",
        request: "-",
        response: "liveness: status, uptime_s, requests, clock_ns, api_version",
    },
    RouteInfo {
        method: "GET",
        path: "/memo/stats",
        request: "-",
        response: "cache population + solve/eval counters",
    },
    RouteInfo {
        method: "POST",
        path: "/solve",
        request: "{tech: sram|stt|sot|hybrid-<nvm>:<ways>@<steer>, capacity_mb, \
                  node_nm?, dnn?, phase?, batch?}",
        response: "tuned config for one grid point (+ workload eval)",
    },
    RouteInfo {
        method: "POST",
        path: "/sweep",
        request: "SweepSpec (+ jobs?, pareto?, render?, report?: sweep|fig9|fig10)",
        response: "spec-ordered report rows, byte-identical to the CLI CSV",
    },
    RouteInfo {
        method: "POST",
        path: "/optimize",
        request: "SweepSpec + objective?: edp|edap|energy|latency|capacity, \
                  area_max_mm2?, leakage_max_w?, frontier?, jobs?",
        response: "branch-and-bound winner (or Pareto frontier) + search accounting",
    },
    RouteInfo {
        method: "GET",
        path: "/memo/export",
        request: "-",
        response: "full memo document (the sweep_memo.json shard exchange format)",
    },
    RouteInfo {
        method: "POST",
        path: "/memo/merge",
        request: "memo document",
        response: "per-entry merge accounting",
    },
    RouteInfo {
        method: "POST",
        path: "/shard/run",
        request: "SweepSpec (+ jobs?)",
        response: "run the shard into the resident memo, return the scoped export",
    },
    RouteInfo {
        method: "POST",
        path: "/validate",
        request: "{dnns?, phases?, caps_mb?, batch?} (defaults: the smoke slice)",
        response: "per-(dnn, phase, capacity) analytic-vs-simulated DRAM table + max_rel_err",
    },
    RouteInfo {
        method: "GET",
        path: "/metrics",
        request: "-",
        response: "Prometheus text: route latencies, memo hit/miss, optimize pruning",
    },
    RouteInfo {
        method: "GET",
        path: "/trace",
        request: "-",
        response: "span ring as Chrome trace-event JSON (chrome://tracing)",
    },
];

/// Shared state behind every route: the resident memo cache plus the
/// metric registry requests land in. One instance lives for the whole
/// server.
pub struct ServerCtx {
    memo: &'static Memo,
    /// Worker threads used *inside* a single `/sweep` evaluation.
    jobs: usize,
    /// Registry of request metrics ([`obs::global`] in production;
    /// tests inject a private one for exact-count assertions).
    metrics: &'static Registry,
    /// The one request counter — `healthz`, `/metrics` and
    /// [`ServerCtx::request_count`] all read this same cell.
    requests: Arc<Counter>,
    /// Shared secret for [`auth`] verification. `None` (the default)
    /// leaves the server open — the pre-hardening behavior; set, every
    /// mutating POST must carry a valid `X-Deepnvm-Auth` tag.
    auth_key: Option<String>,
}

impl ServerCtx {
    pub fn new(memo: &'static Memo, jobs: usize) -> Self {
        ServerCtx::with_registry(memo, jobs, obs::global())
    }

    /// As [`ServerCtx::new`] with an explicit metric registry, so
    /// tests asserting exact counts are isolated from unrelated
    /// instrumentation elsewhere in the process.
    pub fn with_registry(memo: &'static Memo, jobs: usize, metrics: &'static Registry) -> Self {
        let requests = metrics.counter("deepnvm_http_requests_total");
        ServerCtx { memo, jobs, metrics, requests, auth_key: None }
    }

    /// Require signed mutating requests (`--auth-key` plumbs here).
    pub fn with_auth_key(mut self, key: Option<String>) -> Self {
        self.auth_key = key;
        self
    }

    /// The resident cache this server answers from.
    pub fn memo(&self) -> &'static Memo {
        self.memo
    }

    /// The registry `GET /metrics` renders.
    pub fn metrics(&self) -> &'static Registry {
        self.metrics
    }

    /// Requests handled since startup.
    pub fn request_count(&self) -> u64 {
        self.requests.get()
    }
}

/// Top-level dispatch, wrapped in per-request instrumentation: the
/// request counter, a per-route latency histogram, a per-route/status
/// response counter, and a span in the trace ring. A request stamped
/// with `X-Deepnvm-Trace: <trace>:<parent>` (the scheduler does this
/// on every dispatch and probe) has its root span adopted into the
/// remote trace, so the coordinator can stitch worker rings into one
/// fleet-wide timeline.
pub fn handle(ctx: &ServerCtx, req: &Request) -> Response {
    ctx.requests.inc();
    let (route, span_name) = route_meta(&req.path);
    let mut span = obs::Span::enter(span_name);
    if let Some((trace, parent)) =
        req.header(obs::trace::TRACE_HEADER).and_then(obs::trace::parse_trace_header)
    {
        span = span.remote(trace, parent);
    }
    let _span = span;
    let t0 = Instant::now();
    let resp = dispatch(ctx, req);
    ctx.metrics
        .histogram_with("deepnvm_http_request_duration_ns", &[("route", route)])
        .record_duration(t0.elapsed());
    ctx.metrics
        .counter_with(
            "deepnvm_http_responses_total",
            &[("route", route), ("status", &resp.status.to_string())],
        )
        .inc();
    resp
}

/// The routes that mutate resident state or start heavy work — the
/// surface the authentication gate covers when a key is configured.
/// Read-only probes (`/healthz`, `/metrics`, `/memo/export`, …) stay
/// open so unauthenticated health checks and scrapers keep working.
const PROTECTED_ROUTES: [&str; 6] =
    ["/memo/merge", "/shard/run", "/solve", "/sweep", "/optimize", "/validate"];

/// Enforce the shared-secret signature on protected routes. Returns
/// the 401 to answer with, or `None` to let dispatch proceed. The gate
/// runs before any handler touches the body, so a rejected
/// `/memo/merge` has merged exactly zero entries.
fn check_auth(ctx: &ServerCtx, req: &Request) -> Option<Response> {
    let key = ctx.auth_key.as_deref()?;
    if req.method != "POST" || !PROTECTED_ROUTES.contains(&req.path.as_str()) {
        return None;
    }
    let valid = req
        .header(auth::AUTH_HEADER)
        .is_some_and(|tag| auth::verify(key, &req.method, &req.path, &req.body, tag));
    if valid {
        None
    } else {
        AUTH_REJECTS.inc();
        Some(Response::error_kind(
            401,
            "unauthorized",
            &format!("missing or invalid {} signature", auth::AUTH_HEADER),
        ))
    }
}

fn dispatch(ctx: &ServerCtx, req: &Request) -> Response {
    if let Some(reject) = check_auth(ctx, req) {
        return reject;
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/") => route_index(),
        ("GET", "/healthz") => healthz(ctx),
        ("GET", "/memo/stats") => memo_stats(ctx),
        ("POST", "/solve") => solve(ctx, req),
        ("POST", "/sweep") => sweep_query(ctx, req),
        ("POST", "/optimize") => optimize_query(ctx, req),
        ("GET", "/memo/export") => shard::export(ctx, req),
        ("POST", "/memo/merge") => shard::merge(ctx, req),
        ("POST", "/shard/run") => shard_run(ctx, req),
        ("POST", "/validate") => validate_query(req),
        ("GET", "/metrics") => metrics_text(ctx),
        ("GET", "/trace") => trace_dump(),
        (_, path) if ROUTES.iter().any(|r| r.path == path) => {
            Response::error(405, "method not allowed for this route")
        }
        _ => Response::error(404, "no such route (GET / for the route table)"),
    }
}

/// Static metric label and span name per route, so the hot path never
/// builds label strings out of attacker-controlled paths (unknown
/// paths collapse into one "other" series).
fn route_meta(path: &str) -> (&'static str, &'static str) {
    match path {
        "/" => ("/", "http./"),
        "/healthz" => ("/healthz", "http./healthz"),
        "/memo/stats" => ("/memo/stats", "http./memo/stats"),
        "/solve" => ("/solve", "http./solve"),
        "/sweep" => ("/sweep", "http./sweep"),
        "/optimize" => ("/optimize", "http./optimize"),
        "/memo/export" => ("/memo/export", "http./memo/export"),
        "/memo/merge" => ("/memo/merge", "http./memo/merge"),
        "/shard/run" => ("/shard/run", "http./shard/run"),
        "/validate" => ("/validate", "http./validate"),
        "/metrics" => ("/metrics", "http./metrics"),
        "/trace" => ("/trace", "http./trace"),
        _ => ("other", "http.other"),
    }
}

/// `GET /` — the generated API reference: one row per [`ROUTES`] entry
/// plus the envelope and versioning contract, so `/optimize` and every
/// future route self-document.
fn route_index() -> Response {
    let mut j = Json::obj();
    j.set("service", Json::Str("deepnvm serve".into()));
    j.set("api_version", Json::Num(memo::MODEL_VERSION as f64));
    j.set(
        "error_envelope",
        Json::Str("every 4xx/5xx body is {\"error\": {code, kind, message}}; kind is stable".into()),
    );
    j.set(
        "version_header",
        Json::Str("every response carries Deepnvm-Api-Version".into()),
    );
    j.set(
        "auth",
        Json::Str(
            "with --auth-key set, mutating POST routes require an X-Deepnvm-Auth tag: \
             hex HMAC-SHA256(key, \"METHOD\\npath\\nhex(sha256(body))\"); \
             failures are 401 kind=unauthorized"
                .into(),
        ),
    );
    let rows = ROUTES
        .iter()
        .map(|r| {
            let mut o = Json::obj();
            o.set("method", Json::Str(r.method.into()));
            o.set("path", Json::Str(r.path.into()));
            o.set("request", Json::Str(r.request.into()));
            o.set("response", Json::Str(r.response.into()));
            o
        })
        .collect();
    j.set("routes", Json::Arr(rows));
    Response::json(200, &j)
}

/// The one request-parse layer behind every POST route: decode the
/// JSON body, then run the route's codec over the document. Malformed
/// JSON is a 400 `bad_json`; a codec rejection maps through
/// [`error_response`] onto its stable 422 kind. The raw document rides
/// along so routes can read transport options (`jobs`, `pareto`,
/// `render`) beside the typed payload.
pub(crate) fn parse_body<T>(
    req: &Request,
    decode: impl FnOnce(&Json) -> Result<T>,
) -> Result<(Json, T), Response> {
    let body = match req.body_json() {
        Ok(b) => b,
        Err(e) => {
            return Err(Response::error_kind(400, "bad_json", &format!("bad JSON body: {e}")))
        }
    };
    match decode(&body) {
        Ok(t) => Ok((body, t)),
        Err(e) => Err(error_response(&e)),
    }
}

/// Map a route-level failure onto the typed envelope: known typed
/// errors anywhere in the chain pick their stable `kind`; everything
/// else is the generic spec-validation 422.
pub(crate) fn error_response(e: &anyhow::Error) -> Response {
    let kind = if e.chain().any(|c| c.downcast_ref::<UncalibratedNode>().is_some()) {
        "uncalibrated_node"
    } else if e.chain().any(|c| c.downcast_ref::<sweep::optimize::Infeasible>().is_some()) {
        "infeasible"
    } else if e.chain().any(|c| c.downcast_ref::<UnknownReport>().is_some()) {
        "unknown_report"
    } else {
        "invalid_spec"
    };
    Response::error_kind(422, kind, &format!("{e:#}"))
}

/// Typed rejection for `"report"` values outside sweep|fig9|fig10 —
/// its own stable error kind, distinct from spec validation.
#[derive(Debug)]
struct UnknownReport(String);

impl std::fmt::Display for UnknownReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown report '{}' (sweep|fig9|fig10)", self.0)
    }
}

impl std::error::Error for UnknownReport {}

/// The per-request worker clamp shared by `/sweep`, `/shard/run` and
/// `/optimize`: a body may ask for FEWER workers than the operator
/// budget (e.g. jobs=1 to force the serial schedule), never more — one
/// query must not be able to spawn unbounded OS threads.
fn jobs_clamp(ctx: &ServerCtx, body: &Json) -> usize {
    body.get("jobs")
        .and_then(Json::as_u64)
        .map(|v| (v as usize).clamp(1, ctx.jobs.max(1)))
        .unwrap_or(ctx.jobs)
}

fn healthz(ctx: &ServerCtx) -> Response {
    let mut j = Json::obj();
    j.set("status", Json::Str("ok".into()));
    // The API version is the model version: a response is only
    // meaningful relative to the calibrated model that produced it,
    // so the two can never drift apart.
    j.set("api_version", Json::Num(memo::MODEL_VERSION as f64));
    // Monotonic process uptime from the obs epoch — the same clock
    // the span traces and `/metrics` use. Key kept from the ad-hoc
    // era; the value source is now the registry-backed one.
    j.set("uptime_s", Json::Num(obs::uptime().as_secs_f64()));
    j.set("requests", Json::Num(ctx.request_count() as f64));
    // Nanoseconds on this process's span clock (the obs epoch) at the
    // moment the probe was handled. The coordinator reads this against
    // the probe's RTT midpoint to estimate a per-worker clock offset
    // for fleet trace stitching. Stays exact in an f64 JSON number for
    // ~104 days of uptime (2^53 ns).
    j.set("clock_ns", Json::Num(obs::uptime().as_nanos() as f64));
    Response::json(200, &j)
}

/// `GET /metrics` — the whole registry in Prometheus text exposition
/// format.
fn metrics_text(ctx: &ServerCtx) -> Response {
    // Scrape-time gauges refresh just before rendering.
    ctx.metrics.gauge("deepnvm_uptime_seconds").set(obs::uptime().as_secs() as i64);
    // The trace ring owns its eviction count; mirror it into the
    // registry monotonically so truncated traces are visible to any
    // Prometheus scraper, not just readers of `/trace`.
    ctx.metrics
        .counter("deepnvm_trace_spans_dropped_total")
        .set_max(obs::trace::dropped());
    Response {
        status: 200,
        content_type: "text/plain; version=0.0.4; charset=utf-8",
        body: ctx.metrics.prometheus_text().into_bytes(),
        extra_headers: Vec::new(),
    }
}

/// `GET /trace` — the span ring as Chrome trace-event JSON.
fn trace_dump() -> Response {
    Response::json(200, &obs::trace::chrome_trace_json())
}

fn memo_stats(ctx: &ServerCtx) -> Response {
    let m = ctx.memo;
    let mut j = Json::obj();
    j.set("circuit_entries", Json::Num(m.circuit_len() as f64));
    j.set("traffic_entries", Json::Num(m.traffic_len() as f64));
    j.set("point_entries", Json::Num(m.point_len() as f64));
    j.set("solve_count", Json::Num(m.solve_count() as f64));
    j.set("traffic_build_count", Json::Num(m.traffic_build_count() as f64));
    j.set("eval_count", Json::Num(m.eval_count() as f64));
    j.set(
        "point_capacity",
        match m.point_capacity() {
            Some(c) => Json::Num(c as f64),
            None => Json::Null,
        },
    );
    j.set("model_version", Json::Num(memo::MODEL_VERSION as f64));
    // obs-backed process counters, alongside the memo's own (all the
    // pre-obs keys above are kept verbatim for existing scrapers).
    j.set("uptime_s", Json::Num(obs::uptime().as_secs_f64()));
    j.set("requests", Json::Num(ctx.request_count() as f64));
    Response::json(200, &j)
}

/// Parse the `/solve` body into one grid point. Validation happens
/// here, before the point can reach the solver's asserts.
fn solve_point_from_json(j: &Json) -> Result<GridPoint> {
    let tech = parse_tech_sel(
        j.get("tech")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("'tech' (sram|stt|sot|hybrid-<nvm>:<ways>@<steer>) is required"))?,
    )?;
    let capacity_mb = j
        .get("capacity_mb")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("'capacity_mb' (a positive integer) is required"))?;
    if capacity_mb == 0 || capacity_mb > MAX_CAPACITY_MB {
        bail!("capacity must be between 1 and {MAX_CAPACITY_MB} MB");
    }
    // Validate on the wide type: a truncating cast first would let
    // 2^32+16 alias to the calibrated 16 nm node.
    let node_nm = match j.get("node_nm") {
        Some(v) => v
            .as_u64()
            .ok_or_else(|| anyhow!("'node_nm' must be an integer"))?,
        None => 16,
    };
    if node_nm > u32::MAX as u64 {
        bail!("'node_nm' {node_nm} is out of range");
    }
    if !crate::device::node_calibrated(node_nm as u32) {
        // Keep the typed error in the chain: the envelope layer maps
        // it onto the `uncalibrated_node` kind.
        return Err(UncalibratedNode(node_nm as u32).into());
    }
    let node_nm = node_nm as u32;
    let workload = match j.get("dnn") {
        Some(Json::Str(name)) => {
            let dnn = resolve_dnn(name)?;
            let phase = match j.get("phase") {
                Some(v) => parse_phase(
                    v.as_str().ok_or_else(|| anyhow!("'phase' must be a string"))?,
                )?,
                None => crate::workload::models::Phase::Inference,
            };
            let batch = match j.get("batch") {
                Some(v) => {
                    let b = v
                        .as_u64()
                        .ok_or_else(|| anyhow!("'batch' must be a positive integer"))?;
                    // The MAX_BATCH ceiling is what keeps batch-line
                    // term evaluation inside the overflow envelope the
                    // memo's merge sanity gate proves.
                    if b == 0 || b > MAX_BATCH as u64 {
                        bail!("batch size {b} is out of range (1..={MAX_BATCH})");
                    }
                    b as usize
                }
                None => phase.paper_batch(),
            };
            Some(WorkloadPoint { dnn, phase, batch })
        }
        Some(Json::Null) | None => None,
        Some(_) => bail!("'dnn' must be a workload name"),
    };
    Ok(GridPoint { tech, capacity_mb, node_nm, workload })
}

fn solve(ctx: &ServerCtx, req: &Request) -> Response {
    let (_, point) = match parse_body(req, solve_point_from_json) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let cached = ctx.memo.has_point(&point);
    // The point is validated above, but the evaluation stays fallible:
    // an uncalibrated node that slips past any parser becomes a typed
    // 422, never a panicked worker thread.
    let result = match sweep::evaluate_point(&point, ctx.memo) {
        Ok(r) => r,
        Err(e) => return error_response(&e),
    };
    let mut j = Json::obj();
    j.set("cached", Json::Bool(cached));
    j.set("result", memo::point_to_json(&result));
    Response::json(200, &j)
}

/// Capacity list for the fig9/fig10 report bodies: `caps_mb` (parsed
/// by the same axis helper the spec codec uses), falling back to the
/// paper axis. Range validation stays with `SweepSpec::expand`, which
/// the fallible fig9/fig10 pipeline surfaces as a 422.
fn caps_from_json(body: &Json) -> Result<Vec<u64>> {
    Ok(crate::sweep::spec::u64_axis(body, "caps_mb")?
        .unwrap_or_else(|| DEFAULT_CAPACITIES_MB.to_vec()))
}

/// The typed `/sweep` payload: which report pipeline to run and its
/// decoded input, resolved inside [`parse_body`] so an unknown report
/// or a bad spec both surface through the one envelope layer.
enum ReportQuery {
    Sweep(SweepSpec),
    Fig9(Vec<u64>),
    Fig10(Vec<u64>),
}

fn report_query_from_json(body: &Json) -> Result<ReportQuery> {
    match body.get("report").and_then(Json::as_str).unwrap_or("sweep") {
        "sweep" => Ok(ReportQuery::Sweep(spec_from_json(body)?)),
        "fig9" => Ok(ReportQuery::Fig9(caps_from_json(body)?)),
        "fig10" => Ok(ReportQuery::Fig10(caps_from_json(body)?)),
        other => Err(UnknownReport(other.to_string()).into()),
    }
}

fn sweep_query(ctx: &ServerCtx, req: &Request) -> Response {
    let (body, query) = match parse_body(req, report_query_from_json) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let jobs = jobs_clamp(ctx, &body);
    let pareto = body.get("pareto").and_then(Json::as_bool).unwrap_or(false);
    let render = body.get("render").and_then(Json::as_bool).unwrap_or(false);

    // Solve/eval deltas over this request — with concurrent writers
    // they are approximate, but on a prewarmed server they read 0 and
    // prove the query was pure cache hits.
    let solves_before = ctx.memo.solve_count();
    let evals_before = ctx.memo.eval_count();

    let run: Result<Report> = match &query {
        ReportQuery::Sweep(spec) => reports::sweep_report_with(spec, jobs, pareto, ctx.memo),
        ReportQuery::Fig9(caps) => reports::fig9_with(caps, jobs, ctx.memo),
        ReportQuery::Fig10(caps) => reports::fig10_with(caps, jobs, ctx.memo),
    };
    let report = match run {
        Ok(r) => r,
        Err(e) => return error_response(&e),
    };

    let mut j = report.csv.to_json();
    j.set("id", Json::Str(report.id.to_string()));
    j.set("title", Json::Str(report.title.clone()));
    j.set(
        "solves",
        Json::Num(ctx.memo.solve_count().saturating_sub(solves_before) as f64),
    );
    j.set(
        "evals",
        Json::Num(ctx.memo.eval_count().saturating_sub(evals_before) as f64),
    );
    if render {
        j.set("text", Json::Str(report.text));
    }
    Response::json(200, &j)
}

/// `POST /shard/run` — the worker side of `deepnvm coordinate`: run a
/// shard spec into the resident memo and hand the export back in one
/// round trip, so the coordinator never has to pair a `/sweep` with a
/// follow-up `/memo/export` (racing writers could interleave between
/// the two). The export is scoped to the shard's own grid points and
/// their circuit dependencies — O(shard) on the wire even when the
/// resident memo holds the whole prewarmed grid. The body is a
/// `SweepSpec` document; `jobs` is clamped to the operator budget
/// exactly like `/sweep`.
fn shard_run(ctx: &ServerCtx, req: &Request) -> Response {
    let (body, spec) = match parse_body(req, spec_from_json) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let jobs = jobs_clamp(ctx, &body);
    let solves_before = ctx.memo.solve_count();
    let evals_before = ctx.memo.eval_count();
    let res = match sweep::run(&spec, jobs, ctx.memo()) {
        Ok(r) => r,
        Err(e) => return error_response(&e),
    };
    let mut j = Json::obj();
    j.set("points", Json::Num(res.points.len() as f64));
    j.set(
        "solves",
        Json::Num(ctx.memo.solve_count().saturating_sub(solves_before) as f64),
    );
    j.set(
        "evals",
        Json::Num(ctx.memo.eval_count().saturating_sub(evals_before) as f64),
    );
    let shard_points: Vec<GridPoint> = res.points.iter().map(|r| r.point).collect();
    j.set("export", ctx.memo().to_json_for(&shard_points));
    Response::json(200, &j)
}

/// `POST /validate` — replay a (dnn, phase, capacity) slice through
/// both the analytic traffic model and the trace-driven gpusim and
/// return the per-cell DRAM-transaction comparison (see
/// [`validate`]). Purely compute-bound and memo-independent: the two
/// substrates are rebuilt per query so the comparison can never be
/// contaminated by resident state.
fn validate_query(req: &Request) -> Response {
    let (_, vreq) = match parse_body(req, validate::request_from_json) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    match validate::run(&vreq) {
        Ok(report) => Response::json(200, &validate::report_to_json(&report)),
        Err(e) => error_response(&e),
    }
}

/// `POST /optimize` — branch-and-bound search over the implicit grid
/// (see [`sweep::optimize`]). The body is a `/sweep` grid plus
/// `objective`, the design budgets and `frontier`; the response is the
/// winning point (bit-identical to exhaustive `/sweep` argmin) and the
/// pruned/evaluated accounting the CI ratio gate reads.
fn optimize_query(ctx: &ServerCtx, req: &Request) -> Response {
    let (body, oreq) = match parse_body(req, optimize_request_from_json) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let jobs = jobs_clamp(ctx, &body);
    match sweep::optimize::run(&oreq, jobs, ctx.memo) {
        Ok(r) => Response::json(200, &optimize_response_to_json(&r)),
        Err(e) => error_response(&e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::MemTech;
    use crate::nvsim::explorer::tuned_cache;
    use crate::workload::models::Phase;

    const MB: u64 = 1024 * 1024;

    fn leaked() -> &'static Memo {
        Box::leak(Box::new(Memo::new()))
    }

    fn ctx() -> ServerCtx {
        // A private registry per test ctx: exact-count assertions must
        // not see requests from other tests in the same process.
        ServerCtx::with_registry(leaked(), 2, Box::leak(Box::new(Registry::new())))
    }

    fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.into(),
            query: vec![],
            headers: vec![],
            body: body.as_bytes().to_vec(),
        }
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".into(),
            path: path.into(),
            query: vec![],
            headers: vec![],
            body: vec![],
        }
    }

    fn body_json(r: &Response) -> Json {
        crate::util::json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap()
    }

    #[test]
    fn dispatch_matrix() {
        let c = ctx();
        assert_eq!(handle(&c, &get("/")).status, 200);
        assert_eq!(handle(&c, &get("/healthz")).status, 200);
        assert_eq!(handle(&c, &get("/memo/stats")).status, 200);
        assert_eq!(handle(&c, &get("/nope")).status, 404);
        // wrong method on a known route
        assert_eq!(handle(&c, &get("/solve")).status, 405);
        assert_eq!(handle(&c, &post("/healthz", "")).status, 405);
        assert_eq!(handle(&c, &get("/shard/run")).status, 405);
        assert_eq!(handle(&c, &get("/optimize")).status, 405);
        assert_eq!(c.request_count(), 8);
    }

    #[test]
    fn route_table_is_generated_and_lists_every_route() {
        let c = ctx();
        let r = handle(&c, &get("/"));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        assert_eq!(
            j.get("api_version").unwrap().as_u64(),
            Some(memo::MODEL_VERSION as u64)
        );
        let rows = j.get("routes").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), ROUTES.len(), "one generated row per route");
        let paths: Vec<&str> =
            rows.iter().map(|r| r.get("path").unwrap().as_str().unwrap()).collect();
        assert!(paths.contains(&"/optimize"), "{paths:?}");
        for row in rows {
            assert!(row.get("method").unwrap().as_str().is_some());
            assert!(row.get("request").unwrap().as_str().is_some());
            assert!(row.get("response").unwrap().as_str().is_some());
        }
    }

    fn signed_post(key: &str, path: &str, body: &str) -> Request {
        let mut r = post(path, body);
        // parse_request lowercases stored header names
        r.headers.push((
            auth::AUTH_HEADER.to_ascii_lowercase(),
            auth::sign(key, "POST", path, body.as_bytes()),
        ));
        r
    }

    #[test]
    fn auth_gate_rejects_unsigned_and_tampered_mutations() {
        let c = ctx().with_auth_key(Some("fleet-secret".into()));
        let kind_of = |r: &Response| {
            body_json(r).get("error").unwrap().get("kind").unwrap().as_str().unwrap().to_string()
        };

        // unsigned mutating POSTs: 401 unauthorized, and /memo/merge
        // merges exactly zero entries
        for path in ["/solve", "/sweep", "/optimize", "/validate", "/shard/run", "/memo/merge"] {
            let r = handle(&c, &post(path, r#"{"tech": "stt", "capacity_mb": 1}"#));
            assert_eq!((r.status, kind_of(&r).as_str()), (401, "unauthorized"), "{path}");
        }
        assert_eq!(c.memo().circuit_len() + c.memo().point_len(), 0, "nothing ran");

        // a correctly signed request is served
        let body = r#"{"tech": "stt", "capacity_mb": 1}"#;
        let r = handle(&c, &signed_post("fleet-secret", "/solve", body));
        assert_eq!(r.status, 200, "{:?}", String::from_utf8_lossy(&r.body));

        // a tag signed over a different body (tampered in flight) fails
        let mut r = post("/solve", body);
        r.headers.push((
            auth::AUTH_HEADER.to_ascii_lowercase(),
            auth::sign("fleet-secret", "POST", "/solve", b"{\"tech\": \"sot\"}"),
        ));
        assert_eq!(handle(&c, &r).status, 401);
        // ...as does a tag under the wrong key
        assert_eq!(handle(&c, &signed_post("wrong-key", "/solve", body)).status, 401);

        // read routes stay open: probes and scrapers need no key
        assert_eq!(handle(&c, &get("/healthz")).status, 200);
        assert_eq!(handle(&c, &get("/metrics")).status, 200);
        assert_eq!(handle(&c, &get("/memo/export")).status, 200);
        // and wrong-method/unknown-path precedence is untouched
        assert_eq!(handle(&c, &get("/solve")).status, 405);
        assert_eq!(handle(&c, &get("/nope")).status, 404);
    }

    #[test]
    fn no_auth_key_means_the_open_pre_hardening_behavior() {
        let c = ctx();
        let r = handle(&c, &post("/solve", r#"{"tech": "stt", "capacity_mb": 1}"#));
        assert_eq!(r.status, 200);
    }

    #[test]
    fn error_envelope_carries_stable_kinds() {
        let c = ctx();
        let kind_of = |r: &Response| {
            let j = body_json(r);
            let e = j.get("error").unwrap();
            assert_eq!(e.get("code").unwrap().as_u64(), Some(r.status as u64));
            assert!(e.get("message").unwrap().as_str().is_some());
            e.get("kind").unwrap().as_str().unwrap().to_string()
        };
        let r = handle(&c, &post("/solve", "{nope"));
        assert_eq!((r.status, kind_of(&r).as_str()), (400, "bad_json"));
        let r = handle(&c, &post("/sweep", r#"{"techs": ["dram"]}"#));
        assert_eq!((r.status, kind_of(&r).as_str()), (422, "invalid_spec"));
        let r = handle(&c, &post("/sweep", r#"{"report": "fig99"}"#));
        assert_eq!((r.status, kind_of(&r).as_str()), (422, "unknown_report"));
        let r = handle(&c, &post("/solve", r#"{"tech": "stt", "capacity_mb": 1, "node_nm": 9}"#));
        assert_eq!((r.status, kind_of(&r).as_str()), (422, "uncalibrated_node"));
        let infeasible = r#"{"techs": ["stt"], "caps_mb": [1], "dnns": [], "area_max_mm2": 1e-9}"#;
        let r = handle(&c, &post("/optimize", infeasible));
        assert_eq!((r.status, kind_of(&r).as_str()), (422, "infeasible"));
        let r = handle(&c, &get("/nope"));
        assert_eq!((r.status, kind_of(&r).as_str()), (404, "not_found"));
        let r = handle(&c, &get("/solve"));
        assert_eq!((r.status, kind_of(&r).as_str()), (405, "method_not_allowed"));
    }

    #[test]
    fn optimize_route_matches_sweep_argmin() {
        let c = ctx();
        let body = r#"{"techs": ["stt", "sot"], "caps_mb": [1, 2], "dnns": ["AlexNet"],
                       "phases": ["inference"], "batches": [1, 4], "jobs": 1}"#;
        let r = handle(&c, &post("/optimize", body));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        assert_eq!(j.get("objective").unwrap().as_str(), Some("edp"));
        let total = j.get("points_total").unwrap().as_u64().unwrap();
        let ev = j.get("points_evaluated").unwrap().as_u64().unwrap();
        assert_eq!(j.get("points_pruned").unwrap().as_u64(), Some(total - ev));
        assert_eq!(total, 2 * 2 * 2);

        // the winner is the exhaustive first-wins argmin over the same
        // grid on a fresh memo
        let spec = spec_from_json(&crate::util::json::parse(body).unwrap()).unwrap();
        let all = sweep::run(&spec, 1, &Memo::new()).unwrap();
        let mut best: Option<(f64, usize)> = None;
        for (i, p) in all.points.iter().enumerate() {
            let v = p.eval.map(|e| e.edp).unwrap_or(f64::INFINITY);
            if best.is_none_or(|(bv, _)| v < bv) {
                best = Some((v, i));
            }
        }
        let (want_v, wi) = best.unwrap();
        let want = &all.points[wi];
        let w = j.get("winner").unwrap();
        assert_eq!(w.get("capacity_mb").unwrap().as_u64(), Some(want.point.capacity_mb));
        assert_eq!(w.get("tech").unwrap().as_str(), Some(want.point.tech.name().as_str()));
        assert_eq!(w.get("batch").unwrap().as_u64().map(|b| b as usize), {
            want.point.workload.map(|wl| wl.batch)
        });
        assert_eq!(j.get("best_value").unwrap().as_f64(), Some(want_v));
        assert_eq!(
            w.get("eval").unwrap().get("edp").unwrap().as_f64(),
            want.eval.map(|e| e.edp),
            "the winner document is bit-identical to the sweep's"
        );
    }

    #[test]
    fn metrics_route_renders_prometheus_text() {
        let c = ctx();
        assert_eq!(handle(&c, &get("/healthz")).status, 200);
        let solve = post("/solve", r#"{"tech": "stt", "capacity_mb": 1}"#);
        assert_eq!(handle(&c, &solve).status, 200);
        let r = handle(&c, &get("/metrics"));
        assert_eq!(r.status, 200);
        assert!(r.content_type.starts_with("text/plain"));
        let text = std::str::from_utf8(&r.body).unwrap();
        // the request counter includes the /metrics scrape itself
        assert!(text.contains("deepnvm_http_requests_total 3"), "{text}");
        assert!(text.contains("# TYPE deepnvm_http_request_duration_ns histogram"), "{text}");
        let healthz_count = "deepnvm_http_request_duration_ns_count{route=\"/healthz\"} 1";
        assert!(text.contains(healthz_count), "{text}");
        let solve_ok = "deepnvm_http_responses_total{route=\"/solve\",status=\"200\"} 1";
        assert!(text.contains(solve_ok), "{text}");
        assert!(text.contains("# TYPE deepnvm_uptime_seconds gauge"), "{text}");
        // /metrics is GET-only like every other read route
        assert_eq!(handle(&c, &post("/metrics", "")).status, 405);
    }

    #[test]
    fn trace_route_returns_chrome_events() {
        let c = ctx();
        assert_eq!(handle(&c, &get("/healthz")).status, 200);
        let r = handle(&c, &get("/trace"));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(
            events.iter().any(|e| e.get("name").and_then(Json::as_str) == Some("http./healthz")),
            "the healthz request span must reach the trace ring"
        );
    }

    #[test]
    fn stats_and_healthz_report_obs_backed_counters() {
        let c = ctx();
        assert_eq!(handle(&c, &get("/")).status, 200);
        let h = body_json(&handle(&c, &get("/healthz")));
        assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
        assert!(h.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(h.get("requests").unwrap().as_u64(), Some(2));
        let s = body_json(&handle(&c, &get("/memo/stats")));
        // pre-obs keys survive for existing scrapers...
        assert!(s.get("solve_count").is_some());
        assert!(s.get("model_version").is_some());
        // ...and the obs-backed ones ride along
        assert_eq!(s.get("requests").unwrap().as_u64(), Some(3));
        assert!(s.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        // the probe clock the coordinator's offset estimate reads
        assert!(h.get("clock_ns").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn trace_header_is_adopted_into_the_request_span() {
        let c = ctx();
        let mut req = get("/healthz");
        // stored header names are lowercase (parse_request lowercases)
        req.headers
            .push(("x-deepnvm-trace".into(), obs::trace::trace_header_value(0xfeed, 99)));
        assert_eq!(handle(&c, &req).status, 200);
        let rec = obs::trace::records()
            .into_iter()
            .rev()
            .find(|r| r.name == "http./healthz" && r.remote_parent == 99)
            .expect("adopted request span reaches the ring");
        assert_eq!(rec.trace, 0xfeed);

        // a malformed header is ignored, not adopted
        let mut req = get("/healthz");
        req.headers.push(("x-deepnvm-trace".into(), "garbage".into()));
        assert_eq!(handle(&c, &req).status, 200);
        let rec = obs::trace::records()
            .into_iter()
            .rev()
            .find(|r| r.name == "http./healthz" && r.remote_parent != 99)
            .expect("span still recorded");
        assert_eq!(rec.trace, obs::trace::trace_id());
    }

    #[test]
    fn metrics_expose_trace_ring_drops() {
        let c = ctx();
        let r = handle(&c, &get("/metrics"));
        let text = std::str::from_utf8(&r.body).unwrap();
        assert!(
            text.contains("# TYPE deepnvm_trace_spans_dropped_total counter"),
            "{text}"
        );
    }

    #[test]
    fn shard_run_returns_a_mergeable_export() {
        let c = ctx();
        // a circuit-only shard: 1 tech x 1 cap
        let body = r#"{"techs": ["stt"], "caps_mb": [1], "dnns": [], "jobs": 1}"#;
        let r = handle(&c, &post("/shard/run", body));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        assert_eq!(j.get("points").unwrap().as_u64(), Some(1));
        assert!(j.get("solves").unwrap().as_u64().unwrap() >= 1);

        // the export merges cleanly into a fresh coordinator memo
        let fresh = Memo::new();
        let st = fresh.merge_json(j.get("export").unwrap());
        assert!(st.version_ok);
        assert_eq!(st.rejected, 0);
        assert_eq!(fresh.point_len(), 1);

        // a warm repeat runs the shard without solving
        let r = handle(&c, &post("/shard/run", body));
        let j = body_json(&r);
        assert_eq!(j.get("solves").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("evals").unwrap().as_u64(), Some(0));

        // the export is scoped to the shard: unrelated resident
        // entries (here a 4 MB solve) never ride along
        let r = handle(&c, &post("/solve", r#"{"tech": "sot", "capacity_mb": 4}"#));
        assert_eq!(r.status, 200);
        let r = handle(&c, &post("/shard/run", body));
        let export = body_json(&r);
        let export = export.get("export").unwrap();
        assert_eq!(export.get("points").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(export.get("circuit").unwrap().as_arr().unwrap().len(), 1);

        // malformed and invalid bodies map to 400/422
        assert_eq!(handle(&c, &post("/shard/run", "{nope")).status, 400);
        assert_eq!(
            handle(&c, &post("/shard/run", r#"{"techs": ["dram"]}"#)).status,
            422
        );
    }

    #[test]
    fn solve_point_parsing_and_validation() {
        let p = solve_point_from_json(
            &crate::util::json::parse(r#"{"tech": "sot", "capacity_mb": 2}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(p.tech, MemTech::SotMram);
        assert_eq!(p.capacity_mb, 2);
        assert_eq!(p.node_nm, 16);
        assert!(p.workload.is_none());

        // calibrated deep nodes are first-class solve targets
        for node in [7u32, 5] {
            let p = solve_point_from_json(
                &crate::util::json::parse(&format!(
                    r#"{{"tech": "stt", "capacity_mb": 2, "node_nm": {node}}}"#
                ))
                .unwrap(),
            )
            .unwrap();
            assert_eq!(p.node_nm, node);
        }

        let p = solve_point_from_json(
            &crate::util::json::parse(
                r#"{"tech": "stt", "capacity_mb": 3, "dnn": "alexnet", "phase": "training"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let w = p.workload.unwrap();
        assert_eq!(w.dnn, "AlexNet");
        assert_eq!(w.phase, Phase::Training);
        assert_eq!(w.batch, 64, "paper batch applies by default");

        // hybrid selections are first-class /solve techs
        let p = solve_point_from_json(
            &crate::util::json::parse(r#"{"tech": "hybrid-stt:4@0.85", "capacity_mb": 2}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(p.tech.name(), "hybrid-stt:4@0.85");
        assert!(p.tech.is_nvm());

        for bad in [
            r#"{}"#,
            r#"{"tech": "dram", "capacity_mb": 1}"#,
            r#"{"tech": "hybrid-sram:4@0.85", "capacity_mb": 1}"#,
            r#"{"tech": "hybrid-stt:17@0.85", "capacity_mb": 1}"#,
            r#"{"tech": "stt"}"#,
            r#"{"tech": "stt", "capacity_mb": 0}"#,
            r#"{"tech": "stt", "capacity_mb": 1, "node_nm": 9}"#,
            // 2^32 + 16 must not alias to the calibrated 16 nm node
            r#"{"tech": "stt", "capacity_mb": 1, "node_nm": 4294967312}"#,
            // 2^44 MB would overflow the capacity byte math
            r#"{"tech": "stt", "capacity_mb": 17592186044416}"#,
            r#"{"tech": "stt", "capacity_mb": 1, "dnn": "NotANet"}"#,
            r#"{"tech": "stt", "capacity_mb": 1, "dnn": "AlexNet", "batch": 0}"#,
            // beyond MAX_BATCH: outside the proven overflow envelope
            r#"{"tech": "stt", "capacity_mb": 1, "dnn": "AlexNet", "batch": 1048577}"#,
        ] {
            let j = crate::util::json::parse(bad).unwrap();
            assert!(solve_point_from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn multi_node_solve_and_shard_run() {
        let c = ctx();
        let area_of = |r: &Response| {
            body_json(r)
                .get("result")
                .unwrap()
                .get("tuned")
                .unwrap()
                .get("ppa")
                .unwrap()
                .get("area")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // a 7 nm solve is a first-class query and lands on a genuinely
        // different design than the 16 nm one
        let r7 = handle(
            &c,
            &post("/solve", r#"{"tech": "stt", "capacity_mb": 2, "node_nm": 7}"#),
        );
        assert_eq!(r7.status, 200);
        let r16 = handle(&c, &post("/solve", r#"{"tech": "stt", "capacity_mb": 2}"#));
        assert!(area_of(&r7) < area_of(&r16), "7 nm must tune denser");

        // an uncalibrated node is a 422 and the server keeps serving
        let bad = handle(
            &c,
            &post("/solve", r#"{"tech": "stt", "capacity_mb": 2, "node_nm": 9}"#),
        );
        assert_eq!(bad.status, 422);
        assert_eq!(handle(&c, &get("/healthz")).status, 200);

        // a multi-node shard runs end to end and exports both nodes'
        // circuit entries (the distributed path gets nodes for free)
        let body =
            r#"{"techs": ["stt"], "caps_mb": [1], "dnns": [], "nodes_nm": [16, 7], "jobs": 1}"#;
        let r = handle(&c, &post("/shard/run", body));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        assert_eq!(j.get("points").unwrap().as_u64(), Some(2));
        let fresh = Memo::new();
        let st = fresh.merge_json(j.get("export").unwrap());
        assert!(st.version_ok);
        assert_eq!(st.rejected, 0);
        assert_eq!(fresh.circuit_len(), 2, "one circuit entry per node");
        assert_eq!(fresh.point_len(), 2);

        // an uncalibrated node axis in a shard spec is a 422, not a
        // dead worker
        assert_eq!(handle(&c, &post("/shard/run", r#"{"nodes_nm": [9]}"#)).status, 422);
        assert_eq!(handle(&c, &get("/healthz")).status, 200);
    }

    #[test]
    fn solve_route_caches_and_matches_direct_solver() {
        let c = ctx();
        let req = post("/solve", r#"{"tech": "stt", "capacity_mb": 2}"#);
        let r1 = handle(&c, &req);
        assert_eq!(r1.status, 200);
        let j1 = body_json(&r1);
        assert_eq!(j1.get("cached").unwrap().as_bool(), Some(false));
        let got = j1
            .get("result")
            .unwrap()
            .get("tuned")
            .unwrap()
            .get("ppa")
            .unwrap()
            .get("read_latency")
            .unwrap()
            .as_f64()
            .unwrap();
        let want = tuned_cache(MemTech::SttMram, 2 * MB).ppa.read_latency;
        assert_eq!(got, want, "JSON roundtrip must preserve the solver's f64s");

        let r2 = handle(&c, &req);
        assert_eq!(body_json(&r2).get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(c.memo().solve_count(), 1, "second solve must be a memo hit");

        // malformed and invalid bodies
        assert_eq!(handle(&c, &post("/solve", "{not json")).status, 400);
        assert_eq!(handle(&c, &post("/solve", r#"{"tech": "x"}"#)).status, 422);
    }

    #[test]
    fn hybrid_solve_composes_from_pure_partners() {
        let c = ctx();
        let r = handle(
            &c,
            &post("/solve", r#"{"tech": "hybrid-stt:4@0.85", "capacity_mb": 2}"#),
        );
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        let got = j
            .get("result")
            .unwrap()
            .get("tuned")
            .unwrap()
            .get("ppa")
            .unwrap()
            .get("write_latency")
            .unwrap()
            .as_f64()
            .unwrap();
        // bit-identical to the direct hybrid designer at the same knobs
        let want = crate::nvsim::hybrid_at(MemTech::SttMram, 2 * MB, 4, 0.85, 16)
            .unwrap()
            .ppa
            .write_latency;
        assert_eq!(got, want, "the route must serve the composed PPA verbatim");
        // one solve per pure partner, none for the hybrid itself
        assert_eq!(c.memo().solve_count(), 2);
        // warm rerun is a pure cache hit
        let r = handle(
            &c,
            &post("/solve", r#"{"tech": "hybrid-stt:4@0.85", "capacity_mb": 2}"#),
        );
        assert_eq!(body_json(&r).get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(c.memo().solve_count(), 2);
    }

    #[test]
    fn validate_route_replays_both_substrates() {
        let c = ctx();
        let body = r#"{"dnns": ["SqueezeNet"], "phases": ["inference"],
                       "caps_mb": [3], "batch": 1}"#;
        let r = handle(&c, &post("/validate", body));
        assert_eq!(r.status, 200);
        let j = body_json(&r);
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        let cell = &cells[0];
        assert_eq!(cell.get("dnn").unwrap().as_str(), Some("SqueezeNet"));
        assert!(cell.get("analytic_dram").unwrap().as_u64().unwrap() > 0);
        assert!(cell.get("sim_dram").unwrap().as_u64().unwrap() > 0);
        assert!(j.get("max_rel_err").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("pass").unwrap().as_bool(), Some(true));

        // bad bodies map through the standard envelope
        assert_eq!(handle(&c, &post("/validate", "{nope")).status, 400);
        assert_eq!(
            handle(&c, &post("/validate", r#"{"dnns": ["NoSuchNet"]}"#)).status,
            422
        );
        // and the route is POST-only like the other query routes
        assert_eq!(handle(&c, &get("/validate")).status, 405);
    }

    #[test]
    fn sweep_route_rows_match_cli_csv() {
        let c = ctx();
        let body = r#"{"techs": ["stt"], "caps_mb": [1, 2], "dnns": ["AlexNet"],
                       "phases": ["inference"], "pareto": true}"#;
        let r = handle(&c, &post("/sweep", body));
        assert_eq!(r.status, 200);
        let j = body_json(&r);

        // the same query through the CLI report path, on a fresh memo
        let spec = spec_from_json(&crate::util::json::parse(body).unwrap()).unwrap();
        let expect = reports::sweep_report_with(&spec, 1, true, &Memo::new()).unwrap();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), expect.csv.rows().len());
        for (row, want) in rows.iter().zip(expect.csv.rows()) {
            let got: Vec<&str> =
                row.as_arr().unwrap().iter().map(|c| c.as_str().unwrap()).collect();
            let want: Vec<&str> = want.iter().map(|s| s.as_str()).collect();
            assert_eq!(got, want, "HTTP rows must be byte-identical to the CSV");
        }
        assert!(j.get("solves").unwrap().as_u64().unwrap() > 0, "cold first query");

        // warm rerun: zero solves, zero evals
        let r = handle(&c, &post("/sweep", body));
        let j = body_json(&r);
        assert_eq!(j.get("solves").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("evals").unwrap().as_u64(), Some(0));

        // bad report kind
        assert_eq!(handle(&c, &post("/sweep", r#"{"report": "fig99"}"#)).status, 422);
        // invalid spec
        assert_eq!(handle(&c, &post("/sweep", r#"{"techs": ["dram"]}"#)).status, 422);
    }
}
