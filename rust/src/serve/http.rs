//! Minimal HTTP/1.1 plumbing over `std::net` (the offline vendor set
//! has no hyper/tokio): a request parser, a response writer, and a
//! [`Server`] that pairs one accepting thread with a fixed pool of
//! connection workers.
//!
//! The pool mirrors the `sweep::exec` idiom — workers race on one
//! shared source of work and each idle worker claims the next
//! connection — except that connections arrive over time rather than
//! from a fixed slice, so the atomic cursor becomes a `Condvar`-backed
//! queue. Semantics are deliberately small: one request per
//! connection, `Connection: close` on every response, bounded header
//! and body sizes, and read/write timeouts so a stalled peer can never
//! wedge a worker.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Reject requests whose request line + headers exceed this.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Reject bodies larger than this (a full-grid memo export is ~1 MB;
/// leave generous headroom for sharded fleets).
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;
/// Per-connection socket timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Typed parse error for an over-limit body, so the connection
/// handler can answer 413 instead of a generic 400.
#[derive(Debug)]
pub struct PayloadTooLarge(pub usize);

impl std::fmt::Display for PayloadTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "body of {} bytes exceeds the {MAX_BODY_BYTES}-byte limit", self.0)
    }
}

impl std::error::Error for PayloadTooLarge {}

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query parameters in arrival order (no percent-decoding —
    /// the API's parameter values are plain tokens).
    pub query: Vec<(String, String)>,
    /// Headers with lower-cased names.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == want)
            .map(|(_, v)| v.as_str())
    }

    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }

    /// Parse the body as a JSON document; an empty body parses as an
    /// empty object so POST endpoints can treat "no options" uniformly.
    pub fn body_json(&self) -> Result<Json> {
        if self.body.is_empty() {
            return Ok(Json::obj());
        }
        json::parse(self.body_str()?)
    }
}

/// One HTTP response.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, j: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: j.to_pretty().into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.as_bytes().to_vec(),
        }
    }

    /// A JSON error body: `{"error": "..."}`.
    pub fn error(status: u16, msg: &str) -> Response {
        let mut j = Json::obj();
        j.set("error", Json::Str(msg.to_string()));
        Response::json(status, &j)
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len()
        )?;
        w.write_all(&self.body)
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Read one `\n`-terminated line, never buffering more than `budget`
/// bytes — the header bound must hold *while* reading, or a peer
/// streaming an endless line would grow memory without limit.
fn read_limited_line<R: BufRead>(
    reader: &mut R,
    out: &mut String,
    budget: usize,
) -> Result<usize> {
    let n = reader.by_ref().take(budget as u64 + 1).read_line(out)?;
    if n > budget {
        bail!("header block exceeds {MAX_HEADER_BYTES} bytes");
    }
    Ok(n)
}

/// Parse one request from a buffered stream. Generic over [`BufRead`]
/// so the parser is unit-testable without sockets.
pub fn parse_request<R: BufRead>(reader: &mut R) -> Result<Request> {
    let (mut req, content_length) = parse_head(reader)?;
    read_body(reader, &mut req, content_length)?;
    Ok(req)
}

/// Parse the request line and headers; the returned request has an
/// empty body and the announced content length is handed back so the
/// caller can interpose (`Expect: 100-continue`) before draining it.
fn parse_head<R: BufRead>(reader: &mut R) -> Result<(Request, usize)> {
    let mut budget = MAX_HEADER_BYTES;
    let mut line = String::new();
    let n = read_limited_line(reader, &mut line, budget)?;
    if n == 0 {
        bail!("connection closed before a request line");
    }
    budget -= n;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| anyhow!("request line has no target"))?
        .to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol '{version}'");
    }

    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        let n = read_limited_line(reader, &mut h, budget)?;
        if n == 0 {
            bail!("connection closed inside the header block");
        }
        budget -= n;
        let h = h.trim_end_matches(&['\r', '\n'][..]);
        if h.is_empty() {
            break;
        }
        let (name, value) = h
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header line '{h}'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let content_length = headers
        .iter()
        .find(|(n, _)| n == "content-length")
        .map(|(_, v)| v.parse::<usize>())
        .transpose()
        .context("bad content-length")?
        .unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        return Err(PayloadTooLarge(content_length).into());
    }

    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.clone(), ""),
    };
    let query = query_str
        .split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (pair.to_string(), String::new()),
        })
        .collect();

    Ok((Request { method, path, query, headers, body: Vec::new() }, content_length))
}

fn read_body<R: BufRead>(
    reader: &mut R,
    req: &mut Request,
    content_length: usize,
) -> Result<()> {
    let mut body = vec![0u8; content_length];
    reader
        .read_exact(&mut body)
        .context("connection closed inside the body")?;
    req.body = body;
    Ok(())
}

/// Read one request off a live connection, honoring
/// `Expect: 100-continue` — clients like curl wait up to a second for
/// the interim response before transmitting bodies over ~1 KB, which
/// would otherwise tax every shard merge in a fleet.
fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Request> {
    let (mut req, content_length) = parse_head(reader)?;
    if content_length > 0
        && req
            .header("expect")
            .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
    {
        let _ = reader.get_mut().write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
        let _ = reader.get_mut().flush();
    }
    read_body(reader, &mut req, content_length)?;
    Ok(req)
}

/// Ceiling on how long [`call`] waits to *connect*, independent of the
/// request deadline: a dead host should be detected in seconds even
/// when the caller is willing to wait minutes for a long shard run.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// One-shot HTTP client over `std::net` — the coordinator side of the
/// protocol this module serves (`serve::scheduler` dispatches shards
/// and probes worker health through it). Connects to `addr`
/// (`host:port`), sends one `Connection: close` request, and returns
/// `(status, body)`. `timeout` bounds each socket read/write (so a
/// stalled worker surfaces as an error, not a hang); connecting is
/// additionally capped at [`CONNECT_TIMEOUT`].
pub fn call(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
    timeout: Duration,
) -> Result<(u16, String)> {
    use std::net::ToSocketAddrs;

    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("cannot resolve '{addr}'"))?
        .next()
        .ok_or_else(|| anyhow!("'{addr}' resolves to no address"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout.min(CONNECT_TIMEOUT))
        .with_context(|| format!("cannot connect to {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body.as_bytes()))
        .with_context(|| format!("cannot send request to {addr}"))?;
    let mut text = String::new();
    BufReader::new(&mut stream)
        .read_to_string(&mut text)
        .with_context(|| format!("connection to {addr} failed mid-response"))?;
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed response from {addr}"))?;
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}

type Handler = dyn Fn(&Request) -> Response + Send + Sync;

#[derive(Default)]
struct Shared {
    queue: Mutex<VecDeque<TcpStream>>,
    ready: Condvar,
    stop: AtomicBool,
}

/// A running HTTP server: one accept thread feeding `jobs` connection
/// workers. Dropping the server shuts it down and joins every thread.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start serving `handler` on `jobs` worker threads.
    pub fn bind(
        addr: &str,
        jobs: usize,
        handler: impl Fn(&Request) -> Response + Send + Sync + 'static,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("cannot bind {addr}"))?;
        let local = listener.local_addr()?;
        let handler: Arc<Handler> = Arc::new(handler);
        let shared = Arc::new(Shared::default());

        let jobs = jobs.max(1);
        let mut workers = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            let shared = Arc::clone(&shared);
            let handler = Arc::clone(&handler);
            workers.push(std::thread::spawn(move || worker_loop(&shared, &handler)));
        }
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&listener, &shared))
        };
        Ok(Server { addr: local, shared, accept: Some(accept), workers })
    }

    /// The address actually bound (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain the workers, and join every thread.
    /// Idempotent.
    pub fn shutdown(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway connection; an
        // unspecified bind address is reachable via loopback.
        let mut connect = self.addr;
        if connect.ip().is_unspecified() {
            connect.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect_timeout(&connect, Duration::from_millis(500));
        self.shared.ready.notify_all();
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Block on the accept thread — the foreground `deepnvm serve`
    /// mode, which runs until the process is killed.
    pub fn join(mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, shared: &Shared) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(s) = stream {
            let mut q = shared.queue.lock().unwrap();
            q.push_back(s);
            drop(q);
            shared.ready.notify_one();
        }
    }
}

fn worker_loop(shared: &Shared, handler: &Arc<Handler>) {
    loop {
        let stream = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        match stream {
            Some(s) => handle_connection(s, handler),
            None => return,
        }
    }
}

fn handle_connection(stream: TcpStream, handler: &Arc<Handler>) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let mut reader = BufReader::new(stream);
    let response = match read_request(&mut reader) {
        // A panic in a route must not kill the worker: surface it as a
        // 500 and keep serving.
        Ok(req) => catch_unwind(AssertUnwindSafe(|| (**handler)(&req)))
            .unwrap_or_else(|_| Response::error(500, "internal error: handler panicked")),
        Err(e) => {
            let status =
                if e.downcast_ref::<PayloadTooLarge>().is_some() { 413 } else { 400 };
            Response::error(status, &format!("bad request: {e}"))
        }
    };
    let mut stream = reader.into_inner();
    let _ = response.write_to(&mut stream);
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(text: &str) -> Result<Request> {
        parse_request(&mut Cursor::new(text.as_bytes()))
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /memo/export?tech=stt&full HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/memo/export");
        assert_eq!(r.query_param("tech"), Some("stt"));
        assert_eq!(r.query_param("full"), Some(""));
        assert_eq!(r.query_param("absent"), None);
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.body.is_empty());
        assert_eq!(r.body_json().unwrap(), Json::obj());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let body = r#"{"tech": "stt"}"#;
        let text = format!(
            "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}extra",
            body.len()
        );
        let r = parse(&text).unwrap();
        assert_eq!(r.body_str().unwrap(), body);
        assert_eq!(
            r.body_json().unwrap().get("tech").unwrap().as_str().unwrap(),
            "stt"
        );
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse("").is_err());
        assert!(parse("GET\r\n\r\n").is_err());
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: xyz\r\n\r\n").is_err());
        // truncated body
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 5\r\n\r\nab").is_err());
        // unbounded header block
        let huge = format!(
            "GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n",
            "a".repeat(MAX_HEADER_BYTES)
        );
        assert!(parse(&huge).is_err());
        // an endless line (no newline at all) must bail at the bound,
        // not buffer the whole stream
        let endless = "G".repeat(MAX_HEADER_BYTES * 4);
        assert!(parse(&endless).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::text(200, "hi").write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));

        let mut out = Vec::new();
        Response::error(404, "nope").write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(s.contains("\"error\": \"nope\""));
    }

    #[test]
    fn server_round_trip_and_shutdown() {
        let mut server = Server::bind("127.0.0.1:0", 2, |req| {
            Response::text(200, &format!("echo {}", req.path))
        })
        .unwrap();
        let addr = server.local_addr();
        for _ in 0..3 {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /ping HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
            assert!(buf.ends_with("echo /ping"), "{buf}");
        }
        // malformed request gets a 400, not a hang
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"BOGUS\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 400"), "{buf}");

        // an over-limit Content-Length is a 413, not a generic 400
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 413"), "{buf}");

        server.shutdown();
        server.shutdown(); // idempotent
    }

    #[test]
    fn expect_100_continue_gets_interim_response() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            Response::text(200, &format!("got {} bytes", req.body.len()))
        })
        .unwrap();
        let mut s = TcpStream::connect(server.local_addr()).unwrap();
        s.write_all(
            b"POST /solve HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 4\r\n\r\nbody",
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 100 Continue\r\n\r\n"), "{buf}");
        assert!(buf.contains("HTTP/1.1 200 OK"), "{buf}");
        assert!(buf.ends_with("got 4 bytes"), "{buf}");
    }

    #[test]
    fn client_call_round_trips() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            Response::text(200, &format!("{} {} {}", req.method, req.path, req.body.len()))
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let (status, body) = call(&addr, "POST", "/x", "12345", Duration::from_secs(5)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "POST /x 5");

        // nothing listening: an error, not a hang
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead = l.local_addr().unwrap().to_string();
        drop(l);
        assert!(call(&dead, "GET", "/", "", Duration::from_secs(1)).is_err());
        // unresolvable host
        assert!(call("no-such-host.invalid:1", "GET", "/", "", Duration::from_secs(1)).is_err());
    }

    #[test]
    fn handler_panic_yields_500() {
        let server = Server::bind("127.0.0.1:0", 1, |_req| panic!("boom")).unwrap();
        let addr = server.local_addr();
        for _ in 0..2 {
            // the worker must survive the first panic to serve the second
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            assert!(buf.starts_with("HTTP/1.1 500"), "{buf}");
        }
    }
}
