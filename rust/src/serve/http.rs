//! Minimal HTTP/1.1 plumbing over `std::net` (the offline vendor set
//! has no hyper/tokio): a request parser, a response writer, and a
//! [`Server`] that pairs one accepting thread with a fixed pool of
//! connection workers.
//!
//! The pool mirrors the `sweep::exec` idiom — workers race on one
//! shared source of work and each idle worker claims the next
//! connection — except that connections arrive over time rather than
//! from a fixed slice, so the atomic cursor becomes a `Condvar`-backed
//! queue. Semantics are deliberately small: bounded header and body
//! sizes, read/write timeouts so a stalled peer can never wedge a
//! worker, and `Connection: close` by default. Keep-alive is strictly
//! opt-in — a request carrying `Connection: keep-alive` holds its
//! worker for follow-up requests (up to [`MAX_KEEPALIVE_REQUESTS`]),
//! which is how a [`Client`] amortizes the TCP handshake the scheduler
//! used to pay per shard dispatch. Plain clients that read to EOF keep
//! working unchanged.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::{LazyCounter, LazyGauge};
use crate::util::json::{self, Json};

/// Reject requests whose request line + headers exceed this.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Reject bodies larger than this (a full-grid memo export is ~1 MB;
/// leave generous headroom for sharded fleets).
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;
/// Per-connection socket timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Cap on requests served over one keep-alive connection, so a single
/// chatty client cannot hold a pool worker forever.
pub const MAX_KEEPALIVE_REQUESTS: u32 = 1024;
/// Default accept-queue bound per pool worker: with `jobs` workers the
/// server admits up to `jobs * DEFAULT_QUEUE_CAP_PER_JOB` queued
/// connections before shedding (override with `--queue-cap`).
pub const DEFAULT_QUEUE_CAP_PER_JOB: usize = 4;
/// `Retry-After` seconds advertised on a shed connection. Queued work
/// drains in milliseconds once a worker frees up, so the hint is short;
/// clients layer jittered exponential backoff on top of it.
pub const RETRY_AFTER_SECS: u64 = 1;

// Accepted-connection count and queue depth across every in-process
// server (the production binary runs one), feeding `GET /metrics`.
static CONNECTIONS: LazyCounter = LazyCounter::new("deepnvm_http_connections_total");
static QUEUE_DEPTH: LazyGauge = LazyGauge::new("deepnvm_http_queue_depth");
// Load-shedding telemetry: connections refused at the admission gate,
// and the deepest the accept queue has ever been (high-water marks are
// monotone, so a Counter with `set_max` fits).
static SHED: LazyCounter = LazyCounter::new("deepnvm_http_shed_total");
static QUEUE_HIGHWATER: LazyCounter = LazyCounter::new("deepnvm_http_queue_highwater");

/// Typed parse error for an over-limit body, so the connection
/// handler can answer 413 instead of a generic 400.
#[derive(Debug)]
pub struct PayloadTooLarge(pub usize);

impl std::fmt::Display for PayloadTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "body of {} bytes exceeds the {MAX_BODY_BYTES}-byte limit", self.0)
    }
}

impl std::error::Error for PayloadTooLarge {}

/// Typed marker for a connection that closed cleanly before sending a
/// request line. On a keep-alive connection this is the normal end of
/// the exchange (the peer simply hung up between requests), so the
/// connection handler drops the socket instead of answering 400.
#[derive(Debug)]
pub struct ConnClosed;

impl std::fmt::Display for ConnClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "connection closed before a request line")
    }
}

impl std::error::Error for ConnClosed {}

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query parameters in arrival order (no percent-decoding —
    /// the API's parameter values are plain tokens).
    pub query: Vec<(String, String)>,
    /// Headers with lower-cased names.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == want)
            .map(|(_, v)| v.as_str())
    }

    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }

    /// Parse the body as a JSON document; an empty body parses as an
    /// empty object so POST endpoints can treat "no options" uniformly.
    pub fn body_json(&self) -> Result<Json> {
        if self.body.is_empty() {
            return Ok(Json::obj());
        }
        json::parse(self.body_str()?)
    }
}

/// One HTTP response.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers beyond the fixed block
    /// ([`Response::write_to_with`] stamps content type/length, the API
    /// version, and connection intent itself) — the shed path rides
    /// `Retry-After` here.
    pub extra_headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, j: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: j.to_pretty().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.as_bytes().to_vec(),
            extra_headers: Vec::new(),
        }
    }

    /// Attach one extra response header.
    pub fn with_header(mut self, name: &'static str, value: String) -> Response {
        self.extra_headers.push((name, value));
        self
    }

    /// A JSON error body in the v1 typed envelope with the `kind`
    /// derived from the status code:
    /// `{"error": {"code": 404, "kind": "not_found", "message": "…"}}`.
    /// Routes with a more specific classification (uncalibrated node,
    /// infeasible budgets, …) use [`Response::error_kind`] directly.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::error_kind(status, default_error_kind(status), msg)
    }

    /// The typed error envelope with an explicit machine-readable
    /// `kind`. Kinds are part of the v1 API contract: clients branch on
    /// them, so they must stay stable across releases (the human
    /// `message` may change freely).
    pub fn error_kind(status: u16, kind: &str, msg: &str) -> Response {
        let mut e = Json::obj();
        e.set("code", Json::Num(status as f64));
        e.set("kind", Json::Str(kind.to_string()));
        e.set("message", Json::Str(msg.to_string()));
        let mut j = Json::obj();
        j.set("error", e);
        Response::json(status, &j)
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        self.write_to_with(w, false)
    }

    /// As [`Response::write_to`], announcing `Connection: keep-alive`
    /// when the server intends to serve another request on the same
    /// connection.
    pub fn write_to_with(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        let conn = if keep_alive { "keep-alive" } else { "close" };
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
             Deepnvm-Api-Version: {}\r\n{}Connection: {conn}\r\n\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len(),
            crate::sweep::memo::MODEL_VERSION,
            header_lines(
                &self
                    .extra_headers
                    .iter()
                    .map(|(n, v)| (*n, v.as_str()))
                    .collect::<Vec<_>>()
            ),
        )?;
        w.write_all(&self.body)
    }
}

/// The stable error `kind` implied by a status code alone — what
/// [`Response::error`] stamps into the envelope when the route has no
/// more specific classification.
pub fn default_error_kind(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        401 => "unauthorized",
        404 => "not_found",
        405 => "method_not_allowed",
        409 => "conflict",
        413 => "payload_too_large",
        422 => "invalid_request",
        500 => "internal",
        503 => "overloaded",
        _ => "error",
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Read one `\n`-terminated line, never buffering more than `budget`
/// bytes — the header bound must hold *while* reading, or a peer
/// streaming an endless line would grow memory without limit.
fn read_limited_line<R: BufRead>(
    reader: &mut R,
    out: &mut String,
    budget: usize,
) -> Result<usize> {
    let n = reader.by_ref().take(budget as u64 + 1).read_line(out)?;
    if n > budget {
        bail!("header block exceeds {MAX_HEADER_BYTES} bytes");
    }
    Ok(n)
}

/// Parse one request from a buffered stream. Generic over [`BufRead`]
/// so the parser is unit-testable without sockets.
pub fn parse_request<R: BufRead>(reader: &mut R) -> Result<Request> {
    let (mut req, content_length) = parse_head(reader)?;
    read_body(reader, &mut req, content_length)?;
    Ok(req)
}

/// Parse the request line and headers; the returned request has an
/// empty body and the announced content length is handed back so the
/// caller can interpose (`Expect: 100-continue`) before draining it.
fn parse_head<R: BufRead>(reader: &mut R) -> Result<(Request, usize)> {
    let mut budget = MAX_HEADER_BYTES;
    let mut line = String::new();
    let n = read_limited_line(reader, &mut line, budget)?;
    if n == 0 {
        return Err(ConnClosed.into());
    }
    budget -= n;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| anyhow!("request line has no target"))?
        .to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol '{version}'");
    }

    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        let n = read_limited_line(reader, &mut h, budget)?;
        if n == 0 {
            bail!("connection closed inside the header block");
        }
        budget -= n;
        let h = h.trim_end_matches(&['\r', '\n'][..]);
        if h.is_empty() {
            break;
        }
        let (name, value) = h
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header line '{h}'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    // All Content-Length copies must agree: honoring the first of two
    // differing values is exactly the framing ambiguity request
    // smuggling exploits, so a conflict is a hard 400.
    let mut content_length: Option<usize> = None;
    for (n, v) in &headers {
        if n == "content-length" {
            let parsed = v.parse::<usize>().context("bad content-length")?;
            match content_length {
                Some(prev) if prev != parsed => {
                    bail!("conflicting content-length headers ({prev} vs {parsed})")
                }
                _ => content_length = Some(parsed),
            }
        }
    }
    let content_length = content_length.unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        return Err(PayloadTooLarge(content_length).into());
    }

    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.clone(), ""),
    };
    let query = query_str
        .split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (pair.to_string(), String::new()),
        })
        .collect();

    Ok((Request { method, path, query, headers, body: Vec::new() }, content_length))
}

fn read_body<R: BufRead>(
    reader: &mut R,
    req: &mut Request,
    content_length: usize,
) -> Result<()> {
    let mut body = vec![0u8; content_length];
    reader
        .read_exact(&mut body)
        .context("connection closed inside the body")?;
    req.body = body;
    Ok(())
}

/// Read one request off a live connection, honoring
/// `Expect: 100-continue` — clients like curl wait up to a second for
/// the interim response before transmitting bodies over ~1 KB, which
/// would otherwise tax every shard merge in a fleet.
fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Request> {
    let (mut req, content_length) = parse_head(reader)?;
    if content_length > 0
        && req
            .header("expect")
            .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
    {
        let _ = reader.get_mut().write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
        let _ = reader.get_mut().flush();
    }
    read_body(reader, &mut req, content_length)?;
    Ok(req)
}

/// Ceiling on how long [`call`] waits to *connect*, independent of the
/// request deadline: a dead host should be detected in seconds even
/// when the caller is willing to wait minutes for a long shard run.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// One-shot HTTP client over `std::net` — the coordinator side of the
/// protocol this module serves (`serve::scheduler` dispatches shards
/// and probes worker health through it). Connects to `addr`
/// (`host:port`), sends one `Connection: close` request, and returns
/// `(status, body)`. `timeout` bounds each socket read/write (so a
/// stalled worker surfaces as an error, not a hang); connecting is
/// additionally capped at [`CONNECT_TIMEOUT`].
pub fn call(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
    timeout: Duration,
) -> Result<(u16, String)> {
    call_with(addr, method, path, &[], body, timeout)
}

/// Render extra request headers as `Name: value\r\n` lines (the
/// propagation hook: the scheduler stamps `X-Deepnvm-Trace` here).
fn header_lines(headers: &[(&str, &str)]) -> String {
    let mut out = String::new();
    for (name, value) in headers {
        out.push_str(name);
        out.push_str(": ");
        out.push_str(value);
        out.push_str("\r\n");
    }
    out
}

/// [`call`] with extra request headers.
pub fn call_with(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &str,
    timeout: Duration,
) -> Result<(u16, String)> {
    use std::net::ToSocketAddrs;

    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("cannot resolve '{addr}'"))?
        .next()
        .ok_or_else(|| anyhow!("'{addr}' resolves to no address"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout.min(CONNECT_TIMEOUT))
        .with_context(|| format!("cannot connect to {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n{}\
         Connection: close\r\n\r\n",
        body.len(),
        header_lines(headers),
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body.as_bytes()))
        .with_context(|| format!("cannot send request to {addr}"))?;
    let mut text = String::new();
    BufReader::new(&mut stream)
        .read_to_string(&mut text)
        .with_context(|| format!("connection to {addr} failed mid-response"))?;
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed response from {addr}"))?;
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}

/// A persistent keep-alive HTTP client: holds one pooled connection to
/// `addr` and reuses it across calls, so a scheduler dispatching many
/// requests to the same worker pays the TCP handshake once instead of
/// per request (the win shows up in the server's per-route latency
/// histograms). If a pooled connection fails mid-call — the server
/// idle-closed it, restarted, or hit its keep-alive cap — the client
/// reconnects and retries exactly once; an error on a *fresh*
/// connection is reported as-is.
pub struct Client {
    addr: String,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
    retry_after: Option<u64>,
}

impl Client {
    pub fn new(addr: &str, timeout: Duration) -> Client {
        Client { addr: addr.to_string(), timeout, conn: None, retry_after: None }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// `Retry-After` seconds from the most recent response, if the
    /// server sent the header (a shed 503 always does). Callers doing
    /// their own retry loop feed this into [`backoff_delay`].
    pub fn last_retry_after(&self) -> Option<Duration> {
        self.retry_after.map(Duration::from_secs)
    }

    fn connect(&self) -> Result<BufReader<TcpStream>> {
        use std::net::ToSocketAddrs;

        let sock = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("cannot resolve '{}'", self.addr))?
            .next()
            .ok_or_else(|| anyhow!("'{}' resolves to no address", self.addr))?;
        let stream = TcpStream::connect_timeout(&sock, self.timeout.min(CONNECT_TIMEOUT))
            .with_context(|| format!("cannot connect to {}", self.addr))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        Ok(BufReader::new(stream))
    }

    /// Send one request over the pooled connection (opening it first
    /// if needed) and read the framed response.
    pub fn call(&mut self, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        self.call_with(method, path, &[], body)
    }

    /// [`Client::call`] with extra request headers.
    pub fn call_with(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &str,
    ) -> Result<(u16, String)> {
        let had_pooled = self.conn.is_some();
        match self.try_call(method, path, headers, body) {
            Ok(out) => Ok(out),
            Err(e) => {
                self.conn = None;
                if had_pooled {
                    // A pooled connection can die between calls for
                    // reasons that say nothing about the server's
                    // health; one fresh-connection retry tells a stale
                    // socket apart from a dead worker.
                    let retried = self.try_call(method, path, headers, body);
                    if retried.is_err() {
                        self.conn = None;
                    }
                    retried
                } else {
                    Err(e)
                }
            }
        }
    }

    fn try_call(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &str,
    ) -> Result<(u16, String)> {
        if self.conn.is_none() {
            self.conn = Some(self.connect()?);
        }
        let reader = self.conn.as_mut().expect("connection just opened");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Length: {}\r\n{}\
             Connection: keep-alive\r\n\r\n",
            self.addr,
            body.len(),
            header_lines(headers),
        );
        let stream = reader.get_mut();
        stream
            .write_all(head.as_bytes())
            .and_then(|()| stream.write_all(body.as_bytes()))
            .and_then(|()| stream.flush())
            .with_context(|| format!("cannot send request to {}", self.addr))?;
        let resp =
            read_response(reader).with_context(|| format!("bad response from {}", self.addr))?;
        if resp.close {
            self.conn = None;
        }
        self.retry_after = resp.retry_after;
        Ok((resp.status, resp.body))
    }
}

/// One framed response as [`read_response`] parses it off the wire.
struct FramedResponse {
    status: u16,
    /// The peer announced `Connection: close` (possibly inside a token
    /// list), so the pooled connection must not be reused.
    close: bool,
    /// `Retry-After` seconds, when the peer sent the header — the
    /// backoff hint a shed (503) answer carries.
    retry_after: Option<u64>,
    body: String,
}

/// Read one framed response — status line, headers, exactly
/// `Content-Length` body bytes — without consuming past it, so a
/// keep-alive connection stays aligned for the next exchange.
fn read_response(reader: &mut BufReader<TcpStream>) -> Result<FramedResponse> {
    let mut budget = MAX_HEADER_BYTES;
    let mut line = String::new();
    let n = read_limited_line(reader, &mut line, budget)?;
    if n == 0 {
        return Err(ConnClosed.into());
    }
    budget -= n;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line '{}'", line.trim()))?;
    let mut content_length: Option<usize> = None;
    let mut close = false;
    let mut retry_after = None;
    loop {
        let mut h = String::new();
        let n = read_limited_line(reader, &mut h, budget)?;
        if n == 0 {
            bail!("connection closed inside the response headers");
        }
        budget -= n;
        let h = h.trim_end_matches(&['\r', '\n'][..]);
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim();
            if name == "content-length" {
                content_length = Some(value.parse().context("bad content-length in response")?);
            } else if name == "connection" && connection_tokens(value).0 {
                close = true;
            } else if name == "retry-after" {
                retry_after = value.parse::<u64>().ok();
            }
        }
    }
    let len = content_length.ok_or_else(|| anyhow!("response carries no content-length"))?;
    if len > MAX_BODY_BYTES {
        bail!("response body of {len} bytes exceeds the limit");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("connection closed inside the response body")?;
    Ok(FramedResponse {
        status,
        close,
        retry_after,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Jittered exponential backoff delay for a retry `attempt` (0-based):
/// 100 ms doubling per attempt, capped at 5 s, plus up to 50% additive
/// jitter so a fleet of shed clients does not re-arrive in lockstep. A
/// server-provided `Retry-After` floors the result — the server knows
/// its drain rate better than the client does.
pub fn backoff_delay(attempt: u32, retry_after: Option<Duration>) -> Duration {
    const BASE_MS: u64 = 100;
    const CAP_MS: u64 = 5_000;
    let exp = BASE_MS.saturating_mul(1u64 << attempt.min(10)).min(CAP_MS);
    // Cheap decorrelation without an RNG dependency: sub-second clock
    // nanoseconds are plenty uniform for spreading a retry convoy.
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| u64::from(d.subsec_nanos()))
        .unwrap_or(0);
    let jitter = nanos % (exp / 2 + 1);
    let delay = Duration::from_millis(exp + jitter);
    match retry_after {
        Some(floor) => delay.max(floor),
        None => delay,
    }
}

type Handler = dyn Fn(&Request) -> Response + Send + Sync;

#[derive(Default)]
struct Shared {
    queue: Mutex<VecDeque<TcpStream>>,
    ready: Condvar,
    stop: AtomicBool,
    conns: AtomicU64,
}

/// A running HTTP server: one accept thread feeding `jobs` connection
/// workers. Dropping the server shuts it down and joins every thread.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start serving `handler` on `jobs` worker threads, with the
    /// accept queue bounded at the default cap
    /// (`jobs * `[`DEFAULT_QUEUE_CAP_PER_JOB`]).
    pub fn bind(
        addr: &str,
        jobs: usize,
        handler: impl Fn(&Request) -> Response + Send + Sync + 'static,
    ) -> Result<Server> {
        Server::bind_with(addr, jobs, None, handler)
    }

    /// [`Server::bind`] with an explicit accept-queue cap. Connections
    /// arriving while `queue_cap` connections already wait are shed
    /// immediately with `503` + `Retry-After` instead of queueing
    /// without bound — an overloaded server stays answerable (the
    /// workers keep draining) rather than accumulating every socket a
    /// flood can open.
    pub fn bind_with(
        addr: &str,
        jobs: usize,
        queue_cap: Option<usize>,
        handler: impl Fn(&Request) -> Response + Send + Sync + 'static,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("cannot bind {addr}"))?;
        let local = listener.local_addr()?;
        let handler: Arc<Handler> = Arc::new(handler);
        let shared = Arc::new(Shared::default());

        let jobs = jobs.max(1);
        let queue_cap = queue_cap.unwrap_or(jobs * DEFAULT_QUEUE_CAP_PER_JOB).max(1);
        let mut workers = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            let shared = Arc::clone(&shared);
            let handler = Arc::clone(&handler);
            workers.push(std::thread::spawn(move || worker_loop(&shared, &handler)));
        }
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&listener, &shared, queue_cap))
        };
        Ok(Server { addr: local, shared, accept: Some(accept), workers })
    }

    /// The address actually bound (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections accepted so far — not requests: a keep-alive
    /// connection counts once however many requests it carries, which
    /// is exactly what the [`Client`] reuse tests measure.
    pub fn connections_served(&self) -> u64 {
        self.shared.conns.load(Ordering::Relaxed)
    }

    /// Stop accepting, drain the workers, and join every thread.
    /// Idempotent.
    pub fn shutdown(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway connection; an
        // unspecified bind address is reachable via loopback.
        let mut connect = self.addr;
        if connect.ip().is_unspecified() {
            connect.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect_timeout(&connect, Duration::from_millis(500));
        self.shared.ready.notify_all();
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Block on the accept thread — the foreground `deepnvm serve`
    /// mode, which runs until the process is killed.
    pub fn join(mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, shared: &Shared, queue_cap: usize) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(s) = stream {
            shared.conns.fetch_add(1, Ordering::Relaxed);
            CONNECTIONS.inc();
            let mut q = shared.queue.lock().unwrap();
            if q.len() >= queue_cap {
                drop(q);
                shed(s);
                continue;
            }
            q.push_back(s);
            QUEUE_HIGHWATER.handle().set_max(q.len() as u64);
            QUEUE_DEPTH.add(1);
            drop(q);
            shared.ready.notify_one();
        }
    }
}

/// Refuse one over-cap connection: answer `503` with `Retry-After` and
/// the typed `overloaded` envelope, then close. Runs on the accept
/// thread, so the write timeout is short — the response is ~150 bytes
/// and fits any socket send buffer; a peer that cannot take even that
/// is simply dropped.
fn shed(stream: TcpStream) {
    SHED.inc();
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let resp = Response::error(503, "accept queue full; back off and retry")
        .with_header("Retry-After", RETRY_AFTER_SECS.to_string());
    let mut w = &stream;
    let _ = resp.write_to(&mut w);
    let _ = stream.shutdown(Shutdown::Both);
}

fn worker_loop(shared: &Shared, handler: &Arc<Handler>) {
    loop {
        let stream = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(s) = q.pop_front() {
                    QUEUE_DEPTH.sub(1);
                    break Some(s);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        match stream {
            Some(s) => handle_connection(s, handler),
            None => return,
        }
    }
}

/// Interpret a `Connection` header value as the comma-separated token
/// list RFC 9110 defines, returning `(has_close, has_keep_alive)`.
/// Exact-matching the whole value would misread real traffic two ways:
/// `keep-alive, X-Custom` (a client naming a hop-by-hop header) would
/// silently downgrade to close, and `close, X-Custom` from a proxy
/// would be missed entirely, desyncing the connection framing.
fn connection_tokens(value: &str) -> (bool, bool) {
    let mut close = false;
    let mut keep_alive = false;
    for token in value.split(',') {
        let token = token.trim();
        if token.eq_ignore_ascii_case("close") {
            close = true;
        } else if token.eq_ignore_ascii_case("keep-alive") {
            keep_alive = true;
        }
    }
    (close, keep_alive)
}

fn wants_keep_alive(req: &Request) -> bool {
    // `close` wins over `keep-alive` if a confused peer sends both.
    req.header("connection").is_some_and(|v| {
        let (close, keep_alive) = connection_tokens(v);
        keep_alive && !close
    })
}

fn handle_connection(stream: TcpStream, handler: &Arc<Handler>) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let mut reader = BufReader::new(stream);
    // Keep-alive is opt-in per request: clients that read to EOF never
    // send the header and get the historical one-request-then-close
    // behavior; a [`Client`] asks for it and loops here.
    for served in 1..=MAX_KEEPALIVE_REQUESTS {
        let (response, keep) = match read_request(&mut reader) {
            // A panic in a route must not kill the worker: surface it
            // as a 500 and keep serving.
            Ok(req) => {
                let keep = served < MAX_KEEPALIVE_REQUESTS && wants_keep_alive(&req);
                let resp = catch_unwind(AssertUnwindSafe(|| (**handler)(&req)))
                    .unwrap_or_else(|_| Response::error(500, "internal error: handler panicked"));
                (resp, keep)
            }
            Err(e) => {
                if e.downcast_ref::<ConnClosed>().is_some() {
                    // The peer hung up between requests (or this is
                    // the shutdown poke): nothing to answer.
                    break;
                }
                let status =
                    if e.downcast_ref::<PayloadTooLarge>().is_some() { 413 } else { 400 };
                (Response::error(status, &format!("bad request: {e}")), false)
            }
        };
        let sent = {
            let stream = reader.get_mut();
            response.write_to_with(stream, keep).and_then(|()| stream.flush()).is_ok()
        };
        if !sent || !keep {
            break;
        }
    }
    let _ = reader.into_inner().shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(text: &str) -> Result<Request> {
        parse_request(&mut Cursor::new(text.as_bytes()))
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /memo/export?tech=stt&full HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/memo/export");
        assert_eq!(r.query_param("tech"), Some("stt"));
        assert_eq!(r.query_param("full"), Some(""));
        assert_eq!(r.query_param("absent"), None);
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.body.is_empty());
        assert_eq!(r.body_json().unwrap(), Json::obj());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let body = r#"{"tech": "stt"}"#;
        let text = format!(
            "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}extra",
            body.len()
        );
        let r = parse(&text).unwrap();
        assert_eq!(r.body_str().unwrap(), body);
        assert_eq!(
            r.body_json().unwrap().get("tech").unwrap().as_str().unwrap(),
            "stt"
        );
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse("").is_err());
        assert!(parse("GET\r\n\r\n").is_err());
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: xyz\r\n\r\n").is_err());
        // truncated body
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 5\r\n\r\nab").is_err());
        // unbounded header block
        let huge = format!(
            "GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n",
            "a".repeat(MAX_HEADER_BYTES)
        );
        assert!(parse(&huge).is_err());
        // an endless line (no newline at all) must bail at the bound,
        // not buffer the whole stream
        let endless = "G".repeat(MAX_HEADER_BYTES * 4);
        assert!(parse(&endless).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::text(200, "hi").write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));

        let mut out = Vec::new();
        Response::error(404, "nope").write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        let version = format!("Deepnvm-Api-Version: {}\r\n", crate::sweep::memo::MODEL_VERSION);
        assert!(s.contains(&version), "{s}");
        assert!(s.contains("\"code\": 404"), "{s}");
        assert!(s.contains("\"kind\": \"not_found\""), "{s}");
        assert!(s.contains("\"message\": \"nope\""), "{s}");

        // extra headers land between the fixed block and Connection
        let mut out = Vec::new();
        Response::error(503, "busy")
            .with_header("Retry-After", "1".to_string())
            .write_to(&mut out)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{s}");
        assert!(s.contains("Retry-After: 1\r\n"), "{s}");
        assert!(s.contains("\"kind\": \"overloaded\""), "{s}");
    }

    #[test]
    fn server_round_trip_and_shutdown() {
        let mut server = Server::bind("127.0.0.1:0", 2, |req| {
            Response::text(200, &format!("echo {}", req.path))
        })
        .unwrap();
        let addr = server.local_addr();
        for _ in 0..3 {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /ping HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
            assert!(buf.ends_with("echo /ping"), "{buf}");
        }
        // malformed request gets a 400, not a hang
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"BOGUS\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 400"), "{buf}");

        // an over-limit Content-Length is a 413, not a generic 400
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 413"), "{buf}");

        server.shutdown();
        server.shutdown(); // idempotent
    }

    #[test]
    fn extra_headers_reach_the_handler() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            let trace = req.header("x-deepnvm-trace").unwrap_or("none");
            Response::text(200, &format!("trace {trace}"))
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        // one-shot path
        let (status, body) = call_with(
            &addr,
            "GET",
            "/probe",
            &[("X-Deepnvm-Trace", "00ff:0001")],
            "",
            Duration::from_secs(5),
        )
        .unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "trace 00ff:0001");
        // pooled keep-alive path
        let mut c = Client::new(&addr, Duration::from_secs(5));
        let (status, body) =
            c.call_with("GET", "/probe", &[("X-Deepnvm-Trace", "00aa:0002")], "").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "trace 00aa:0002");
        let (_, body) = c.call("GET", "/probe", "").unwrap();
        assert_eq!(body, "trace none", "headers are per-call, not sticky");
    }

    #[test]
    fn expect_100_continue_gets_interim_response() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            Response::text(200, &format!("got {} bytes", req.body.len()))
        })
        .unwrap();
        let mut s = TcpStream::connect(server.local_addr()).unwrap();
        s.write_all(
            b"POST /solve HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 4\r\n\r\nbody",
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 100 Continue\r\n\r\n"), "{buf}");
        assert!(buf.contains("HTTP/1.1 200 OK"), "{buf}");
        assert!(buf.ends_with("got 4 bytes"), "{buf}");
    }

    #[test]
    fn client_call_round_trips() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            Response::text(200, &format!("{} {} {}", req.method, req.path, req.body.len()))
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let (status, body) = call(&addr, "POST", "/x", "12345", Duration::from_secs(5)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "POST /x 5");

        // nothing listening: an error, not a hang
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead = l.local_addr().unwrap().to_string();
        drop(l);
        assert!(call(&dead, "GET", "/", "", Duration::from_secs(1)).is_err());
        // unresolvable host
        assert!(call("no-such-host.invalid:1", "GET", "/", "", Duration::from_secs(1)).is_err());
    }

    #[test]
    fn server_keepalive_serves_multiple_requests_per_connection() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            Response::text(200, &format!("echo {}", req.path))
        })
        .unwrap();
        let s = TcpStream::connect(server.local_addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = s.try_clone().unwrap();
        let mut reader = BufReader::new(s);
        for i in 0..3 {
            let req = format!("GET /r{i} HTTP/1.1\r\nConnection: keep-alive\r\n\r\n");
            writer.write_all(req.as_bytes()).unwrap();
            let r = read_response(&mut reader).unwrap();
            assert_eq!(r.status, 200);
            assert!(!r.close, "server advertises keep-alive back");
            assert_eq!(r.body, format!("echo /r{i}"));
        }
        assert_eq!(server.connections_served(), 1, "three requests, one connection");
    }

    #[test]
    fn connection_header_token_lists_negotiate_keep_alive() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            Response::text(200, &format!("echo {}", req.path))
        })
        .unwrap();
        let s = TcpStream::connect(server.local_addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = s.try_clone().unwrap();
        let mut reader = BufReader::new(s);
        // a token list naming keep-alive plus a hop-by-hop header must
        // NOT silently downgrade to close
        for i in 0..2 {
            let req =
                format!("GET /r{i} HTTP/1.1\r\nConnection: keep-alive, X-Custom\r\n\r\n");
            writer.write_all(req.as_bytes()).unwrap();
            let r = read_response(&mut reader).unwrap();
            assert_eq!(r.status, 200);
            assert!(!r.close, "keep-alive inside a token list must hold");
            assert_eq!(r.body, format!("echo /r{i}"));
        }
        // close wins over keep-alive whatever the order
        writer
            .write_all(b"GET /last HTTP/1.1\r\nConnection: keep-alive, close\r\n\r\n")
            .unwrap();
        let r = read_response(&mut reader).unwrap();
        assert_eq!(r.status, 200);
        assert!(r.close, "close anywhere in the list wins");
        assert_eq!(
            server.connections_served(),
            1,
            "all three exchanges rode one connection"
        );
    }

    #[test]
    fn client_detects_close_inside_a_token_list() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let responder = std::thread::spawn(move || {
            // A proxy-style peer: answers, announces close inside a
            // token list, and hangs up. Missing the token would leave a
            // dead socket pooled.
            for _ in 0..2 {
                let (mut s, _) = listener.accept().unwrap();
                let mut buf = [0u8; 512];
                let _ = s.read(&mut buf);
                let _ = s.write_all(
                    b"HTTP/1.1 200 OK\r\nContent-Type: text/plain\r\n\
                      Content-Length: 2\r\nConnection: close, X-Hop\r\n\r\nok",
                );
                let _ = s.shutdown(Shutdown::Both);
            }
        });
        let mut c = Client::new(&addr, Duration::from_secs(5));
        assert_eq!(c.call("GET", "/", "").unwrap(), (200, "ok".to_string()));
        assert!(c.conn.is_none(), "token-list close must evict the pooled connection");
        assert_eq!(c.call("GET", "/", "").unwrap(), (200, "ok".to_string()));
        responder.join().unwrap();
    }

    #[test]
    fn conflicting_duplicate_content_lengths_are_rejected() {
        // agreeing duplicates parse fine
        let ok = parse("POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nab")
            .unwrap();
        assert_eq!(ok.body_str().unwrap(), "ab");
        // differing duplicates are the request-smuggling shape: hard error
        assert!(parse(
            "POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 5\r\n\r\nhello"
        )
        .is_err());
        // and over a live socket that surfaces as a 400
        let server = Server::bind("127.0.0.1:0", 1, |_req| Response::text(200, "nope")).unwrap();
        let mut s = TcpStream::connect(server.local_addr()).unwrap();
        s.write_all(
            b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 5\r\n\r\nhello",
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 400"), "{buf}");
    }

    #[test]
    fn over_cap_connections_are_shed_and_the_server_stays_live() {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let handler_gate = Arc::clone(&gate);
        let shed_before = SHED.value();
        let server = Server::bind_with("127.0.0.1:0", 1, Some(2), move |_req| {
            let (lock, cv) = &*handler_gate;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            Response::text(200, "served")
        })
        .unwrap();
        let addr = server.local_addr();

        // Flood: the worker blocks on the gate, so at most 1 in-flight
        // + 2 queued connections can be admitted; within a few attempts
        // one MUST be shed with an immediate 503 (admitted connections
        // stay silent until the gate opens).
        let mut admitted = Vec::new();
        let mut shed_response = None;
        for _ in 0..20 {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
            s.write_all(b"GET / HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            let mut buf = String::new();
            match s.read_to_string(&mut buf) {
                Ok(_) if buf.starts_with("HTTP/1.1 503") => {
                    shed_response = Some(buf);
                    break;
                }
                _ => admitted.push(s),
            }
        }
        let resp = shed_response.expect("the flood must hit the admission gate");
        assert!(resp.to_ascii_lowercase().contains("retry-after: 1"), "{resp}");
        assert!(resp.contains("\"kind\": \"overloaded\""), "{resp}");
        assert!(resp.contains("\"code\": 503"), "{resp}");
        assert!(SHED.value() > shed_before);
        assert!(
            admitted.len() <= 3,
            "1 in-flight + queue cap 2, but {} connections were admitted",
            admitted.len()
        );

        // open the gate: every admitted connection drains with a 200 —
        // shedding never cancels accepted work
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        for mut s in admitted {
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
        }
        // and the server is live for fresh traffic after the flood
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"GET /after HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
    }

    #[test]
    fn backoff_delay_grows_and_honors_retry_after() {
        let d0 = backoff_delay(0, None);
        assert!(d0 >= Duration::from_millis(100) && d0 <= Duration::from_millis(151), "{d0:?}");
        let d3 = backoff_delay(3, None);
        assert!(d3 >= Duration::from_millis(800) && d3 <= Duration::from_millis(1201), "{d3:?}");
        // the exponent caps: even absurd attempts stay bounded
        assert!(backoff_delay(40, None) <= Duration::from_millis(7_501));
        // a server hint floors the delay
        assert!(backoff_delay(0, Some(Duration::from_secs(2))) >= Duration::from_secs(2));
    }

    #[test]
    fn keepalive_client_reuses_one_connection() {
        let server = Server::bind("127.0.0.1:0", 1, |req| {
            Response::text(200, &format!("n={}", req.body.len()))
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let mut c = Client::new(&addr, Duration::from_secs(5));
        for _ in 0..5 {
            let (status, body) = c.call("POST", "/x", "abc").unwrap();
            assert_eq!(status, 200);
            assert_eq!(body, "n=3");
        }
        assert_eq!(server.connections_served(), 1, "five calls, one connection");
        // the one-shot client still opens (and closes) per call
        call(&addr, "GET", "/", "", Duration::from_secs(5)).unwrap();
        call(&addr, "GET", "/", "", Duration::from_secs(5)).unwrap();
        assert_eq!(server.connections_served(), 3);
    }

    #[test]
    fn keepalive_client_retries_a_stale_pooled_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let responder = std::thread::spawn(move || {
            // Serve exactly one request per accepted connection, then
            // close it — despite advertising keep-alive. The second
            // client call therefore writes into a dead pooled socket.
            for _ in 0..2 {
                let (mut s, _) = listener.accept().unwrap();
                let mut buf = [0u8; 512];
                let _ = s.read(&mut buf);
                let _ = s.write_all(
                    b"HTTP/1.1 200 OK\r\nContent-Type: text/plain\r\n\
                      Content-Length: 2\r\nConnection: keep-alive\r\n\r\nok",
                );
            }
        });
        let mut c = Client::new(&addr, Duration::from_secs(5));
        assert_eq!(c.call("GET", "/", "").unwrap(), (200, "ok".to_string()));
        // recovery must be transparent: fresh connection, same answer
        assert_eq!(c.call("GET", "/", "").unwrap(), (200, "ok".to_string()));
        responder.join().unwrap();
    }

    #[test]
    fn handler_panic_yields_500() {
        let server = Server::bind("127.0.0.1:0", 1, |_req| panic!("boom")).unwrap();
        let addr = server.local_addr();
        for _ in 0..2 {
            // the worker must survive the first panic to serve the second
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            assert!(buf.starts_with("HTTP/1.1 500"), "{buf}");
        }
    }
}
