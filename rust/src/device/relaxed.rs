//! Relaxed-retention STT (paper §II cites Smullen'11 [32] and the
//! volatile-STT line of work [33]-[35]: trade non-volatility for write
//! speed/energy). Implemented as a device-level knob on the thermal
//! stability factor Delta.
//!
//! Physics: retention time follows the Arrhenius law
//! `t_ret = tau0 * exp(Delta)` with tau0 ~ 1 ns, while the critical
//! current scales linearly, `Ic0 ∝ Delta` (through Hk·V). Lowering
//! Delta from the ~85 of the 10-year cell to ~30 cuts the write
//! current and the LLGS switching time — at the cost of needing
//! DRAM-style refresh whose energy this module also models.

use super::llgs::LlgsProblem;
use super::mtj::{Mtj, HBAR, MU0, QE};

/// Attempt period for the Arrhenius retention law (s).
pub const TAU0: f64 = 1e-9;

/// A retention-relaxed variant of an STT stack.
#[derive(Clone, Copy, Debug)]
pub struct RelaxedStt {
    pub mtj: Mtj,
    /// Target thermal stability (the knob).
    pub delta: f64,
}

impl RelaxedStt {
    /// Derive a relaxed stack from the baseline by scaling Hk to hit
    /// the requested Delta (volume and Ms stay — same cell layout).
    pub fn with_delta(base: Mtj, delta: f64) -> Self {
        let delta0 = base.thermal_stability();
        let mut mtj = base;
        mtj.hk = base.hk * delta / delta0;
        RelaxedStt { mtj, delta }
    }

    /// Retention time (s), Arrhenius.
    pub fn retention(&self) -> f64 {
        TAU0 * self.delta.exp()
    }

    /// Refresh power per cell (W): each refresh is a read + conditional
    /// write; refresh every retention/margin.
    pub fn refresh_power_per_cell(&self, e_refresh: f64, margin: f64) -> f64 {
        e_refresh / (self.retention() / margin)
    }

    /// Switching time at drive current `i` (s), via the LLGS solver.
    pub fn write_latency(&self, i: f64, pulse_budget: f64) -> f64 {
        let eta = self.mtj.polarization
            / (2.0 * (1.0 + self.mtj.polarization * self.mtj.polarization * 0.95));
        let a_j = HBAR * eta * i / (2.0 * QE * self.mtj.ms * self.mtj.volume());
        let p = LlgsProblem {
            b_k: MU0 * self.mtj.hk,
            easy: [0.0, 0.0, 1.0],
            alpha: self.mtj.alpha,
            a_j,
            p: [0.0, 0.0, 1.0],
            theta0: self.mtj.theta0(),
        };
        p.solve(pulse_budget).t_switch
    }
}

/// One point of the retention-relaxation tradeoff curve.
#[derive(Clone, Copy, Debug)]
pub struct RelaxPoint {
    pub delta: f64,
    pub retention_s: f64,
    pub write_latency_s: f64,
    pub write_energy_j: f64,
    /// Refresh power for a 3 MB array (W).
    pub refresh_power_3mb: f64,
}

/// Sweep Delta and report the tradeoff at a fixed ~120 uA drive (the
/// 3-fin sizing from the Table I flow).
pub fn tradeoff(deltas: &[f64]) -> Vec<RelaxPoint> {
    let base = Mtj::stt_16nm();
    let i_drive = 120e-6;
    let vdd = 0.8;
    let cells_3mb = 3.0 * 1024.0 * 1024.0 * 8.0;
    deltas
        .iter()
        .map(|&d| {
            let r = RelaxedStt::with_delta(base, d);
            let t = r.write_latency(i_drive, 40e-9);
            let e_write = vdd * i_drive * t;
            // refresh = read + write, 2x margin before expiry
            let e_refresh = e_write + 0.06e-12;
            RelaxPoint {
                delta: d,
                retention_s: r.retention(),
                write_latency_s: t,
                write_energy_j: e_write,
                refresh_power_3mb: cells_3mb
                    * r.refresh_power_per_cell(e_refresh, 2.0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_delta_gives_decade_retention() {
        let base = Mtj::stt_16nm();
        let r = RelaxedStt::with_delta(base, base.thermal_stability());
        // Delta ~85 -> ~1e28 s: effectively non-volatile.
        assert!(r.retention() > 3.15e8, "retention {} s", r.retention());
    }

    #[test]
    fn relaxing_delta_speeds_and_cheapens_writes() {
        let pts = tradeoff(&[30.0, 50.0, 70.0, 85.0]);
        for w in pts.windows(2) {
            assert!(
                w[0].write_latency_s <= w[1].write_latency_s * 1.05,
                "latency must fall as Delta falls: {:?}",
                (w[0].delta, w[1].delta)
            );
            assert!(w[0].retention_s < w[1].retention_s);
        }
        // the Smullen'11-class effect: Delta ~30 writes meaningfully
        // faster than the non-volatile cell. At a fixed drive current
        // the macrospin speedup is bounded by the overdrive already in
        // hand (~1.5x here); Smullen's larger gains also shrink the
        // drive transistor, which the cache-level hybrid study covers.
        let fast = &pts[0];
        let nv = &pts[3];
        let speedup = nv.write_latency_s / fast.write_latency_s;
        assert!(speedup > 1.3, "speedup {speedup}");
        // energy falls with latency at fixed drive
        assert!(fast.write_energy_j < nv.write_energy_j);
    }

    #[test]
    fn refresh_power_negligible_until_delta_very_low() {
        let pts = tradeoff(&[25.0, 40.0, 60.0]);
        // Delta 40: retention ~ 6 min -> refresh power far below the
        // SRAM leakage it displaces (~6.7 W for 3 MB).
        let d40 = pts.iter().find(|p| p.delta == 40.0).unwrap();
        assert!(
            d40.refresh_power_3mb < 0.1,
            "refresh at Delta 40: {} W",
            d40.refresh_power_3mb
        );
        // ... and grows steeply as Delta falls
        assert!(pts[0].refresh_power_3mb > d40.refresh_power_3mb * 100.0);
    }

    #[test]
    fn scaled_stack_hits_requested_delta() {
        let base = Mtj::stt_16nm();
        let r = RelaxedStt::with_delta(base, 42.0);
        assert!((r.mtj.thermal_stability() - 42.0).abs() < 1e-6);
    }

    #[test]
    fn arrhenius_uses_physical_constants() {
        // sanity anchor: KB*TEMP at 300K = 25.9 meV / 4.14e-21 J
        use super::super::mtj::{KB, TEMP};
        assert!((KB * TEMP - 4.1419e-21).abs() / 4.14e-21 < 1e-3);
    }
}
