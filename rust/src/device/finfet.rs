//! Analytic 16nm-class FinFET access-transistor model.
//!
//! Replaces the commercial PDK the paper used. Alpha-power-law I/V
//! (Sakurai-Newton) with parameters calibrated to public 16FF data:
//! ~55 uA/fin NMOS drive at VDD=0.8 V, ~0.45 fF/fin effective gate
//! capacitance, ~1 nA/fin subthreshold leakage (HP flavor; the SRAM
//! array uses the HD low-leakage flavor with ~25 pA/fin).

/// Process corner / flavor of the device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    /// High-performance logic transistor (periphery, MRAM write paths).
    Hp,
    /// High-density low-leakage (SRAM array transistors).
    Hd,
}

/// FinFET device model. All quantities per the full device (i.e.
/// already multiplied by `fins`).
#[derive(Clone, Copy, Debug)]
pub struct FinFet {
    pub fins: u32,
    pub flavor: Flavor,
    /// Threshold voltage (V).
    pub vth: f64,
    /// Velocity-saturation exponent (alpha-power law).
    pub alpha: f64,
    /// Drive transconductance coefficient per fin (A/V^alpha).
    pub k_fin: f64,
    /// Effective gate capacitance per fin (F).
    pub cg_fin: f64,
    /// Drain/source junction capacitance per fin (F).
    pub cd_fin: f64,
    /// Subthreshold + gate leakage per fin at VDD (A).
    pub ileak_fin: f64,
}

/// Supply voltage of the 16nm node modeled throughout the framework.
pub const VDD: f64 = 0.8;

impl FinFet {
    pub fn new(fins: u32, flavor: Flavor) -> Self {
        match flavor {
            Flavor::Hp => FinFet {
                fins,
                flavor,
                vth: 0.30,
                alpha: 1.25,
                // calibrated: Ion(0.8 V) ~ 55 uA/fin
                k_fin: 55e-6 / (VDD - 0.30f64).powf(1.25),
                cg_fin: 0.45e-15,
                cd_fin: 0.25e-15,
                ileak_fin: 1.0e-9,
            },
            Flavor::Hd => FinFet {
                fins,
                flavor,
                vth: 0.42,
                alpha: 1.3,
                // HD: ~28 uA/fin
                k_fin: 28e-6 / (VDD - 0.42f64).powf(1.3),
                cg_fin: 0.40e-15,
                cd_fin: 0.22e-15,
                ileak_fin: 25e-12,
            },
        }
    }

    /// Saturation drive voltage Vdsat(Vgs).
    fn vdsat(&self, vgs: f64) -> f64 {
        // Empirical: Vdsat scales with overdrive^(alpha/2).
        0.35 * ((vgs - self.vth).max(0.0) / (VDD - self.vth)).powf(self.alpha / 2.0)
            * (VDD - self.vth)
            + 0.05
    }

    /// Drain current (A) at the given biases (alpha-power law, with a
    /// linear region below Vdsat).
    pub fn ids(&self, vgs: f64, vds: f64) -> f64 {
        let ov = vgs - self.vth;
        // subthreshold floor (continuous at ov = 0 so Ids is monotone)
        let ss = 0.080;
        let sub = self.fins as f64
            * self.ileak_fin
            * 10f64.powf(ov.min(0.0) / ss)
            * (vds / VDD).clamp(0.0, 1.0);
        if ov <= 0.0 {
            return sub;
        }
        let isat = sub + self.fins as f64 * self.k_fin * ov.powf(self.alpha);
        let vdsat = self.vdsat(vgs);
        if vds >= vdsat {
            isat
        } else {
            // smooth linear region: parabolic interpolation to 0 at vds=0
            let x = vds / vdsat;
            isat * x * (2.0 - x)
        }
    }

    /// On-current at full bias.
    pub fn ion(&self) -> f64 {
        self.ids(VDD, VDD)
    }

    /// Effective on-resistance for RC delay estimation (Vdd/2 point).
    pub fn r_on(&self) -> f64 {
        let i_half = self.ids(VDD, VDD / 2.0);
        if i_half <= 0.0 {
            f64::INFINITY
        } else {
            (VDD / 2.0) / i_half
        }
    }

    /// Total gate capacitance (F).
    pub fn cg(&self) -> f64 {
        self.fins as f64 * self.cg_fin
    }

    /// Total drain capacitance (F).
    pub fn cd(&self) -> f64 {
        self.fins as f64 * self.cd_fin
    }

    /// Off-state leakage at VDD (A).
    pub fn leakage(&self) -> f64 {
        self.fins as f64 * self.ileak_fin
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drive_current_calibration() {
        let t = FinFet::new(1, Flavor::Hp);
        let ion = t.ion();
        assert!(
            (50e-6..60e-6).contains(&ion),
            "HP Ion/fin {ion:.3e} out of 16FF band"
        );
        let t4 = FinFet::new(4, Flavor::Hp);
        assert!((t4.ion() / ion - 4.0).abs() < 1e-9, "Ion scales with fins");
    }

    #[test]
    fn hd_is_low_leakage() {
        let hp = FinFet::new(1, Flavor::Hp);
        let hd = FinFet::new(1, Flavor::Hd);
        assert!(hd.leakage() < hp.leakage() / 10.0);
        assert!(hd.ion() < hp.ion());
    }

    #[test]
    fn current_monotone_in_vgs_and_vds() {
        let t = FinFet::new(2, Flavor::Hp);
        let mut prev = 0.0;
        for i in 0..=16 {
            let vgs = i as f64 * VDD / 16.0;
            let ids = t.ids(vgs, VDD);
            assert!(ids >= prev, "non-monotone in vgs at {vgs}");
            prev = ids;
        }
        let mut prev = 0.0;
        for i in 0..=16 {
            let vds = i as f64 * VDD / 16.0;
            let ids = t.ids(VDD, vds);
            assert!(ids >= prev - 1e-12, "non-monotone in vds at {vds}");
            prev = ids;
        }
    }

    #[test]
    fn subthreshold_slope() {
        let t = FinFet::new(1, Flavor::Hp);
        // 80 mV/decade below Vth
        let i1 = t.ids(t.vth - 0.080, VDD);
        let i2 = t.ids(t.vth - 0.160, VDD);
        let ratio = i1 / i2;
        assert!((ratio - 10.0).abs() < 0.5, "slope ratio {ratio}");
    }

    #[test]
    fn r_on_is_finite_and_reasonable() {
        let t = FinFet::new(4, Flavor::Hp);
        let r = t.r_on();
        // 4-fin HP: a few kOhm
        assert!((500.0..10_000.0).contains(&r), "r_on {r}");
    }
}
