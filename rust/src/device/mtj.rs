//! Magnetic tunnel junction device models.
//!
//! Two stacks, mirroring the paper's sources:
//!
//! * **STT** (perpendicular MTJ, Kim'15-CICC-style): write current flows
//!   *through* the junction; spin-transfer torque from the polarized
//!   current switches the free layer. Set (P->AP) needs more current
//!   than reset (AP->P) because polarization efficiency is asymmetric.
//! * **SOT** (Kazemi'16-TED-style): a charge current through an adjacent
//!   heavy-metal (beta-W) strip injects a spin current via the spin Hall
//!   effect; read and write paths are electrically separate, so the read
//!   transistor can be minimum-size and the junction never sees write
//!   stress.
//!
//! Physical constants in SI; geometry at the 16nm-node scale the paper
//! targets.

/// Reduced Planck constant (J*s).
pub const HBAR: f64 = 1.054_571_8e-34;
/// Elementary charge (C).
pub const QE: f64 = 1.602_176_6e-19;
/// Vacuum permeability (T*m/A).
pub const MU0: f64 = 1.256_637e-6;
/// Gyromagnetic ratio (rad/(s*T)).
pub const GAMMA: f64 = 1.760_859e11;
/// Boltzmann constant (J/K).
pub const KB: f64 = 1.380_649e-23;
/// Operating temperature (K).
pub const TEMP: f64 = 300.0;

/// MTJ stack parameters.
#[derive(Clone, Copy, Debug)]
pub struct Mtj {
    /// Free-layer diameter (m); junctions are circular.
    pub diameter: f64,
    /// Free-layer thickness (m).
    pub t_free: f64,
    /// Saturation magnetization (A/m).
    pub ms: f64,
    /// Gilbert damping.
    pub alpha: f64,
    /// Effective perpendicular anisotropy field (A/m).
    pub hk: f64,
    /// Resistance-area product in the parallel state (Ohm*m^2).
    pub ra_p: f64,
    /// Tunnel magnetoresistance ratio (R_AP = R_P * (1 + tmr)).
    pub tmr: f64,
    /// Spin polarization (STT) of the fixed layer.
    pub polarization: f64,
}

impl Mtj {
    /// Junction area (m^2).
    pub fn area(&self) -> f64 {
        std::f64::consts::PI * (self.diameter / 2.0).powi(2)
    }

    /// Free-layer volume (m^3).
    pub fn volume(&self) -> f64 {
        self.area() * self.t_free
    }

    /// Parallel-state resistance (Ohm).
    pub fn r_p(&self) -> f64 {
        self.ra_p / self.area()
    }

    /// Antiparallel-state resistance (Ohm).
    pub fn r_ap(&self) -> f64 {
        self.r_p() * (1.0 + self.tmr)
    }

    /// Thermal stability factor Delta = E_b / kT with E_b = mu0 Ms Hk V / 2.
    pub fn thermal_stability(&self) -> f64 {
        0.5 * MU0 * self.ms * self.hk * self.volume() / (KB * TEMP)
    }

    /// Initial cone angle used for deterministic switching analysis:
    /// the RMS thermal tilt theta_0 = sqrt(1 / (2 Delta)).
    pub fn theta0(&self) -> f64 {
        (1.0 / (2.0 * self.thermal_stability())).sqrt()
    }

    /// STT critical switching current (A), Slonczewski macrospin:
    /// Ic0 = (2 e / hbar) * (alpha / eta) * mu0 Ms Hk V  (perpendicular).
    pub fn ic0_stt(&self, polarity_eta: f64) -> f64 {
        (2.0 * QE / HBAR) * (self.alpha / polarity_eta)
            * MU0
            * self.ms
            * self.hk
            * self.volume()
            / 2.0
    }

    /// 16nm-node perpendicular STT stack (Kim'15-class). The MTJ pillar
    /// sits above the access device, so its diameter (~50 nm) is set by
    /// MTJ patterning, not the logic pitch. Calibrated so the Table I
    /// flow lands in the paper's band (~8-11 ns, ~1 pJ set writes,
    /// Delta ~ 100).
    pub fn stt_16nm() -> Self {
        Mtj {
            diameter: 50e-9,
            t_free: 1.3e-9,
            ms: 0.85e6,
            alpha: 0.0064,
            hk: 2.6e5,
            ra_p: 9.0e-12, // 9 Ohm*um^2
            tmr: 1.5,
            polarization: 0.65,
        }
    }

    /// 16nm SOT stack (Kazemi'16-class): the free layer is switched by
    /// the heavy-metal spin current (type-y cell), so the junction can
    /// trade RA for read margin independently of the write path.
    pub fn sot_16nm() -> Self {
        Mtj {
            diameter: 40e-9,
            t_free: 1.2e-9,
            ms: 0.90e6,
            alpha: 0.010,
            hk: 2.1e5,
            ra_p: 8.0e-12,
            tmr: 1.8,
            polarization: 0.60,
        }
    }

    /// 7nm-class STT stack, scaled from [`Mtj::stt_16nm`]: the pillar
    /// shrinks to ~35 nm (MTJ patterning limits it well above the logic
    /// pitch), and the anisotropy field rises to hold the retention
    /// barrier Delta >= 40 at the smaller free-layer volume — the
    /// interfacial-PMA scaling path the deeply-scaled-node literature
    /// (journal extension, SOT-DTCO'23) assumes. RA and TMR tick up
    /// with stack maturity to preserve read margin.
    pub fn stt_7nm() -> Self {
        Mtj {
            diameter: 35e-9,
            t_free: 1.2e-9,
            ms: 0.90e6,
            alpha: 0.0064,
            hk: 3.45e5, // Delta ~54 at the smaller volume
            ra_p: 8.0e-12,
            tmr: 1.6,
            polarization: 0.65,
        }
    }

    /// 5nm-class STT stack (see [`Mtj::stt_7nm`] for the scaling
    /// rationale; the ~30 nm pillar is near the patterning floor).
    pub fn stt_5nm() -> Self {
        Mtj {
            diameter: 30e-9,
            t_free: 1.1e-9,
            ms: 0.95e6,
            alpha: 0.0064,
            hk: 4.3e5, // Delta ~48
            ra_p: 8.0e-12,
            tmr: 1.7,
            polarization: 0.65,
        }
    }

    /// 7nm-class SOT stack, scaled from [`Mtj::sot_16nm`] like
    /// [`Mtj::stt_7nm`].
    pub fn sot_7nm() -> Self {
        Mtj {
            diameter: 30e-9,
            t_free: 1.1e-9,
            ms: 0.95e6,
            alpha: 0.010,
            hk: 4.9e5,
            ra_p: 9.0e-12,
            tmr: 1.9,
            polarization: 0.60,
        }
    }

    /// 5nm-class SOT stack.
    pub fn sot_5nm() -> Self {
        Mtj {
            diameter: 26e-9,
            t_free: 1.0e-9,
            ms: 1.0e6,
            alpha: 0.010,
            hk: 6.2e5,
            ra_p: 10.0e-12,
            tmr: 2.0,
            polarization: 0.60,
        }
    }

    /// Calibrated STT stack at a process node.
    pub fn stt_at(node_nm: u32) -> Result<Self, super::types::UncalibratedNode> {
        Ok(match node_nm {
            16 => Self::stt_16nm(),
            7 => Self::stt_7nm(),
            5 => Self::stt_5nm(),
            other => return Err(super::types::UncalibratedNode(other)),
        })
    }

    /// Calibrated SOT stack at a process node.
    pub fn sot_at(node_nm: u32) -> Result<Self, super::types::UncalibratedNode> {
        Ok(match node_nm {
            16 => Self::sot_16nm(),
            7 => Self::sot_7nm(),
            5 => Self::sot_5nm(),
            other => return Err(super::types::UncalibratedNode(other)),
        })
    }
}

/// Heavy-metal write channel of a SOT cell.
#[derive(Clone, Copy, Debug)]
pub struct SotChannel {
    /// Spin Hall angle of the heavy metal (beta-W ~ 0.33).
    pub theta_sh: f64,
    /// Channel resistance seen by the write current (Ohm).
    pub r_channel: f64,
    /// Channel thickness (m) — sets the spin-current injection ratio.
    pub t_channel: f64,
    /// Channel width (m), roughly the junction diameter.
    pub width: f64,
}

impl SotChannel {
    pub fn beta_w_16nm() -> Self {
        SotChannel {
            theta_sh: 0.30,
            r_channel: 600.0,
            t_channel: 4e-9,
            width: 40e-9,
        }
    }

    /// 7nm-class channel: width tracks the smaller junction and the
    /// shrinking cross-section raises the channel resistance; the spin
    /// Hall angle is a material property and stays put.
    pub fn beta_w_7nm() -> Self {
        SotChannel {
            theta_sh: 0.30,
            r_channel: 850.0,
            t_channel: 3.5e-9,
            width: 30e-9,
        }
    }

    /// 5nm-class channel.
    pub fn beta_w_5nm() -> Self {
        SotChannel {
            theta_sh: 0.30,
            r_channel: 1000.0,
            t_channel: 3.2e-9,
            width: 26e-9,
        }
    }

    /// Calibrated channel at a process node (paired with
    /// [`Mtj::sot_at`]).
    pub fn beta_w_at(node_nm: u32) -> Result<Self, super::types::UncalibratedNode> {
        Ok(match node_nm {
            16 => Self::beta_w_16nm(),
            7 => Self::beta_w_7nm(),
            5 => Self::beta_w_5nm(),
            other => return Err(super::types::UncalibratedNode(other)),
        })
    }

    /// Effective spin current injected into the free layer for a charge
    /// current `i_c` through the channel under junction area `a_mtj`:
    /// I_s = theta_SH * (A_mtj / A_channel_cross) * I_c, where the
    /// geometric gain A_mtj/(w*t) can exceed 1 — the root of SOT's
    /// energy advantage.
    pub fn spin_current(&self, i_c: f64, a_mtj: f64) -> f64 {
        let a_cross = self.width * self.t_channel;
        self.theta_sh * (a_mtj / a_cross) * i_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resistances_ordered() {
        let m = Mtj::stt_16nm();
        assert!(m.r_ap() > m.r_p());
        // R_P = RA / A: ~6 Ohm*um^2 over ~804 nm^2 -> ~7.5 kOhm
        let rp = m.r_p();
        assert!((4e3..12e3).contains(&rp), "r_p {rp}");
    }

    #[test]
    fn thermal_stability_retention_class() {
        // Delta >= 40 gives ~10-year retention; both stacks must hold it.
        for m in [Mtj::stt_16nm(), Mtj::sot_16nm()] {
            let d = m.thermal_stability();
            assert!((40.0..120.0).contains(&d), "Delta {d}");
        }
    }

    #[test]
    fn stt_critical_current_scale() {
        let m = Mtj::stt_16nm();
        let ic = m.ic0_stt(m.polarization);
        // Published 1x-nm perpendicular MTJs: Ic0 tens of uA.
        assert!((10e-6..120e-6).contains(&ic), "ic0 {ic:.3e}");
    }

    #[test]
    fn sot_geometric_spin_gain() {
        let ch = SotChannel::beta_w_16nm();
        let m = Mtj::sot_16nm();
        let gain = ch.spin_current(1.0, m.area());
        // theta_sh * area ratio: should exceed the bare spin Hall angle
        assert!(gain > ch.theta_sh, "gain {gain}");
    }

    #[test]
    fn theta0_small_angle() {
        let m = Mtj::stt_16nm();
        assert!(m.theta0() < 0.2, "theta0 {}", m.theta0());
    }

    #[test]
    fn scaled_stacks_hold_retention_and_shrink() {
        for node in crate::device::CALIBRATED_NODES_NM {
            for m in [Mtj::stt_at(node).unwrap(), Mtj::sot_at(node).unwrap()] {
                let d = m.thermal_stability();
                assert!((40.0..120.0).contains(&d), "{node}nm Delta {d}");
                assert!(m.r_ap() > m.r_p());
                assert!(m.theta0() < 0.25, "{node}nm theta0 {}", m.theta0());
            }
        }
        // pillars shrink monotonically with the node
        assert!(Mtj::stt_7nm().area() < Mtj::stt_16nm().area());
        assert!(Mtj::stt_5nm().area() < Mtj::stt_7nm().area());
        assert!(Mtj::sot_5nm().area() < Mtj::sot_7nm().area());
        // the channel narrows with the junction and resists more
        assert!(SotChannel::beta_w_7nm().r_channel > SotChannel::beta_w_16nm().r_channel);
        assert!(SotChannel::beta_w_5nm().width < SotChannel::beta_w_7nm().width);
        // 16 nm accessors are the legacy constructors, uncalibrated errors
        assert_eq!(Mtj::stt_at(16).unwrap().diameter, Mtj::stt_16nm().diameter);
        assert!(Mtj::stt_at(10).is_err());
        assert!(Mtj::sot_at(3).is_err());
        assert!(SotChannel::beta_w_at(9).is_err());
    }
}
