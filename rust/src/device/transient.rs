//! SPICE-lite RC transient simulation of the bitcell *read* path.
//!
//! Replaces the paper's SPICE read analysis. The sensing scheme is the
//! standard voltage-mode one: data and reference bitlines are
//! precharged, the wordline opens the access device, and the cell
//! discharges its bitline through (access R_on + storage resistance).
//! The sense amp fires once the differential reaches 25 mV — exactly
//! the criterion in paper §III-A ("sensing delay is measured from
//! wordline activation to the point where the bitline voltage
//! difference reaches 25 mV"); sense energy integrates the power drawn
//! over that window.

use super::finfet::VDD;

/// One discharge branch: a bitline capacitance discharging through a
/// series resistance.
#[derive(Clone, Copy, Debug)]
pub struct Branch {
    /// Series resistance (access device + storage element), Ohm.
    pub r_series: f64,
    /// Bitline capacitance, F.
    pub c_bitline: f64,
    /// Precharge voltage, V.
    pub v0: f64,
}

/// Result of a differential sensing transient.
#[derive(Clone, Copy, Debug)]
pub struct SenseResult {
    /// Time for |V_data - V_ref| to reach the threshold (s).
    pub latency: f64,
    /// Energy drawn from the bitlines + read-current path over the
    /// window (J).
    pub energy: f64,
    /// Whether the threshold was reached before `t_max`.
    pub resolved: bool,
}

/// Differential read: data branch vs reference branch, forward-Euler
/// integration (the exact exponential is available, but we keep the
/// numeric transient so arbitrary nonlinear branches can be added —
/// this mirrors how the SPICE flow was used).
pub fn sense_differential(
    data: Branch,
    reference: Branch,
    v_threshold: f64,
    t_max: f64,
) -> SenseResult {
    // Step at 1/200 of the faster RC constant for <0.5% error.
    let tau_min = (data.r_series * data.c_bitline)
        .min(reference.r_series * reference.c_bitline);
    let dt = tau_min / 200.0;

    let mut vd = data.v0;
    let mut vr = reference.v0;
    let mut t = 0.0;
    let mut energy = 0.0;
    while t < t_max {
        let id = vd / data.r_series;
        let ir = vr / reference.r_series;
        // power dissipated in both branches
        energy += (vd * id + vr * ir) * dt;
        vd -= id / data.c_bitline * dt;
        vr -= ir / reference.c_bitline * dt;
        t += dt;
        if (vd - vr).abs() >= v_threshold {
            return SenseResult { latency: t, energy, resolved: true };
        }
    }
    SenseResult { latency: t_max, energy, resolved: false }
}

/// Convenience: MTJ read with the cell in its two states against a
/// mid-point reference resistor; returns the worse (slower) case, which
/// is what the sense spec must cover.
pub fn mtj_sense(
    r_access: f64,
    r_p: f64,
    r_ap: f64,
    c_bitline: f64,
    v_read: f64,
) -> SenseResult {
    let r_ref = 0.5 * (r_p + r_ap) + r_access;
    let mk = |r_cell: f64| Branch {
        r_series: r_access + r_cell,
        c_bitline,
        v0: v_read,
    };
    let reference = Branch { r_series: r_ref, c_bitline, v0: v_read };
    let a = sense_differential(mk(r_p), reference, 0.025, 20e-9);
    let b = sense_differential(mk(r_ap), reference, 0.025, 20e-9);
    if a.latency >= b.latency {
        a
    } else {
        b
    }
}

/// SRAM 6T read: single-ended discharge of one bitline through the
/// pull-down stack while the other stays precharged; differential is
/// against the static complement line.
pub fn sram_sense(r_pulldown: f64, c_bitline: f64) -> SenseResult {
    let data = Branch { r_series: r_pulldown, c_bitline, v0: VDD };
    // complement bitline holds VDD: model as an (effectively) infinite RC
    let reference = Branch { r_series: 1e12, c_bitline, v0: VDD };
    sense_differential(data, reference, 0.025, 20e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_analytic_rc() {
        // Single branch vs v0*(1 - exp(-t/RC)) differential against a
        // frozen reference: |dV| = v0 * (1 - exp(-t/RC)).
        let r = 10e3;
        let c = 30e-15;
        let v0 = 0.4;
        let res = sense_differential(
            Branch { r_series: r, c_bitline: c, v0 },
            Branch { r_series: 1e12, c_bitline: c, v0 },
            0.025,
            50e-9,
        );
        assert!(res.resolved);
        let analytic = -r * c * (1.0f64 - 0.025 / v0).ln();
        let err = (res.latency - analytic).abs() / analytic;
        assert!(err < 0.02, "latency {} vs analytic {analytic}", res.latency);
    }

    #[test]
    fn larger_tmr_senses_faster() {
        let fast = mtj_sense(3e3, 6e3, 6e3 * 2.5, 25e-15, 0.35);
        let slow = mtj_sense(3e3, 6e3, 6e3 * 2.0, 25e-15, 0.35);
        assert!(fast.resolved && slow.resolved);
        assert!(fast.latency < slow.latency);
    }

    #[test]
    fn energy_grows_with_window() {
        let short = mtj_sense(3e3, 6e3, 15e3, 15e-15, 0.35);
        let long = mtj_sense(3e3, 6e3, 15e3, 60e-15, 0.35);
        assert!(long.latency > short.latency);
        assert!(long.energy > short.energy);
    }

    #[test]
    fn unresolvable_reports_unresolved() {
        // zero TMR: no differential ever develops
        let res = mtj_sense(3e3, 6e3, 6e3, 25e-15, 0.35);
        assert!(!res.resolved);
    }

    #[test]
    fn sram_sense_sub_ns() {
        // 1-fin HD pull-down ~ 15 kOhm into ~20 fF
        let res = sram_sense(15e3, 20e-15);
        assert!(res.resolved);
        assert!(res.latency < 1e-9, "sram sense {}", res.latency);
    }
}
