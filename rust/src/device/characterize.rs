//! The Table I flow: sweep access-device fin counts, simulate write
//! (LLGS) and read (RC transient) for each sizing, and pick the
//! EDAP-balanced optimum — the paper's "optimal balance between the
//! latency, energy, and area" (§III-A).
//!
//! Slonczewski polarization efficiency is state-dependent,
//! `g(theta) = P / (2 (1 + P^2 cos(theta)))`, which makes the *set*
//! (P->AP) transition slower than *reset* (AP->P) — exactly the
//! asymmetry Table I reports. The write driver is modeled as the access
//! FinFET in series with the state-dependent junction (STT) or the
//! heavy-metal channel (SOT).

use super::finfet::{FinFet, Flavor, VDD};
use super::llgs::LlgsProblem;
use super::mtj::{Mtj, SotChannel, HBAR, MU0, QE};
use super::transient;
use super::types::{BitcellParams, MemTech, UncalibratedNode};

/// Node-indexed layout constants for the bitcell area model
/// (Seo-&-Roy-style formulation; the 16 nm set is calibrated to the
/// foundry-normalized Table I areas, the 7/5 nm sets to published
/// foundry pitches and HD 6T cell areas).
pub mod layout {
    use super::UncalibratedNode;

    /// Bitcell layout geometry at one process node (meters / m^2).
    #[derive(Clone, Copy, Debug)]
    pub struct Layout {
        /// Fin pitch.
        pub fin_pitch: f64,
        /// Cell height in contacted-poly-pitch units x CPP.
        pub cell_height: f64,
        /// Fixed width overhead: contacts, MTJ via, isolation.
        pub width_base: f64,
        /// Extra width for the SOT cell's separate read stack + SL
        /// contact.
        pub sot_read_overhead: f64,
        /// Foundry 6T HD SRAM bitcell area — the normalization base
        /// shared with the cache model's tag arrays. The ONLY place
        /// this number lives (`nvsim::tech` reads it from here).
        pub sram_cell_area: f64,
    }

    impl Layout {
        /// 16 nm-class geometry (the paper's node).
        pub fn n16() -> Self {
            Layout {
                fin_pitch: 48e-9,
                cell_height: 135e-9,
                width_base: 60e-9,
                sot_read_overhead: 22e-9,
                sram_cell_area: 0.074e-12,
            }
        }

        /// 7 nm-class geometry (foundry N7: ~27 nm fin pitch, ~0.027
        /// um^2 HD 6T cell). The fixed width overheads shrink much
        /// more slowly than the logic pitch: the MTJ via and pillar
        /// landing pad (~35 nm pillar) are patterning-limited, which
        /// is exactly why MRAM's *relative* density edge narrows at
        /// deep nodes (see `NodeScale::mram_area_rel`).
        pub fn n7() -> Self {
            Layout {
                fin_pitch: 27e-9,
                cell_height: 81e-9,
                width_base: 50e-9,
                sot_read_overhead: 18e-9,
                sram_cell_area: 0.027e-12,
            }
        }

        /// 5 nm-class geometry (foundry N5: ~24 nm fin pitch, ~0.021
        /// um^2 HD 6T cell; MTJ-limited width overheads as at 7 nm).
        pub fn n5() -> Self {
            Layout {
                fin_pitch: 24e-9,
                cell_height: 68e-9,
                width_base: 45e-9,
                sot_read_overhead: 16e-9,
                sram_cell_area: 0.021e-12,
            }
        }

        /// Geometry for a calibrated node.
        pub fn at(node_nm: u32) -> Result<Self, UncalibratedNode> {
            Ok(match node_nm {
                16 => Self::n16(),
                7 => Self::n7(),
                5 => Self::n5(),
                other => return Err(UncalibratedNode(other)),
            })
        }
    }
}

/// Foundry 6T SRAM cell area at a calibrated node (m^2) — the Table I
/// / tag-array normalization base. One source of truth: delegates to
/// [`layout::Layout`], which `nvsim::tech` also reads, so the device
/// and circuit layers can never drift apart.
pub fn sram_cell_area(node_nm: u32) -> Result<f64, UncalibratedNode> {
    Ok(layout::Layout::at(node_nm)?.sram_cell_area)
}

/// Wordline rise contribution included in the bitcell-level sense
/// latency: the paper measures "from wordline activation", and the
/// SPICE testbench includes the WL driver charging the segment's gate
/// load (~50% point of a 2 kOhm x ~220 fF line).
pub const WL_RISE: f64 = 300e-12;

/// Write-pulse budgets: the cell must complete its magnetization change
/// within the array write cycle it will be embedded in, else the sizing
/// is rejected as non-functional ("modulated to the point of failure").
pub const STT_PULSE_BUDGET: f64 = 10e-9;
pub const SOT_PULSE_BUDGET: f64 = 400e-12;

/// One point of the fin-count sweep.
#[derive(Clone, Copy, Debug)]
pub struct FinSweepPoint {
    pub fins_write: u32,
    pub fins_read: u32,
    pub write_latency_set: f64,
    pub write_latency_reset: f64,
    pub write_energy_set: f64,
    pub write_energy_reset: f64,
    pub sense_latency: f64,
    pub sense_energy: f64,
    pub area_rel: f64,
    /// Whether both polarities switched within the pulse budget.
    pub functional: bool,
}

impl FinSweepPoint {
    /// Bitcell-level energy-delay-area product used to rank sizings.
    pub fn edap(&self) -> f64 {
        let lat = 0.5 * (self.write_latency_set + self.write_latency_reset)
            + self.sense_latency;
        let en = 0.5 * (self.write_energy_set + self.write_energy_reset)
            + self.sense_energy;
        lat * en * self.area_rel
    }

    fn to_params(self, tech: MemTech) -> BitcellParams {
        BitcellParams {
            tech,
            sense_latency: self.sense_latency,
            sense_energy: self.sense_energy,
            write_latency_set: self.write_latency_set,
            write_latency_reset: self.write_latency_reset,
            write_energy_set: self.write_energy_set,
            write_energy_reset: self.write_energy_reset,
            fins_write: self.fins_write,
            fins_read: self.fins_read,
            area_rel: self.area_rel,
            cell_leakage: 0.0,
        }
    }
}

/// Full characterization output.
#[derive(Clone, Debug)]
pub struct CharacterizeResult {
    pub stt: BitcellParams,
    pub sot: BitcellParams,
    pub stt_sweep: Vec<FinSweepPoint>,
    pub sot_sweep: Vec<FinSweepPoint>,
}

/// Solve the series circuit "FinFET + resistor across VDD" for the
/// branch current: find I with I = Ids(VDD, VDD - I*R). The residual
/// f(I) = I - Ids(VDD, VDD - I*R) is strictly increasing, so bisection
/// on [0, Ion] converges unconditionally (a damped fixed point does
/// not: in the steep linear region |dIds/dVds| * R >> 1).
fn solve_series_drive(xtor: &FinFet, r_series: f64) -> f64 {
    let mut lo = 0.0;
    let mut hi = xtor.ion();
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        let vds = (VDD - mid * r_series).max(0.0);
        let f = mid - xtor.ids(VDD, vds);
        if f > 0.0 {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Spin-torque field (Tesla) for drive current `i` through an MTJ with
/// polarization efficiency `eta`.
fn a_j(mtj: &Mtj, eta: f64, i: f64) -> f64 {
    HBAR * eta * i / (2.0 * QE * mtj.ms * mtj.volume())
}

/// Slonczewski state-dependent polarization efficiency.
fn eta_slonczewski(p: f64, cos_theta: f64) -> f64 {
    p / (2.0 * (1.0 + p * p * cos_theta))
}

/// Cell-level MTJ bitcell area from the layout formulation at the
/// given node geometry.
fn mram_area_rel(fins_write: u32, fins_read: u32, sot: bool, l: &layout::Layout) -> f64 {
    let extra_read = if sot { l.sot_read_overhead } else { 0.0 };
    // Write stack width: fins side by side; the read device of an STT
    // cell IS the write device (shared), so only SOT adds read width.
    let read_fins_width = if sot {
        (fins_read.saturating_sub(1)) as f64 * l.fin_pitch
    } else {
        0.0
    };
    let width = (fins_write - 1) as f64 * l.fin_pitch
        + read_fins_width
        + l.width_base
        + extra_read;
    width * l.cell_height / l.sram_cell_area
}

/// Characterize an STT bitcell at the given write fin count on the
/// paper's 16 nm node.
pub fn stt_point(fins: u32) -> FinSweepPoint {
    stt_point_at(16, fins).expect("16 nm is calibrated")
}

/// As [`stt_point`] at an explicit process node: same flow, driven by
/// the node's MTJ stack and layout geometry.
pub fn stt_point_at(node_nm: u32, fins: u32) -> Result<FinSweepPoint, UncalibratedNode> {
    let mtj = Mtj::stt_at(node_nm)?;
    let l = layout::Layout::at(node_nm)?;
    let xtor = FinFet::new(fins, Flavor::Hp);
    let pulse_budget = STT_PULSE_BUDGET;

    // --- write: resistance-limited drive through the junction -------
    // series: access device + junction; solve I = Ids(VDD, VDD - I*R)
    // by bisection (f(I) = I - Ids(..) is monotone increasing in I).
    let drive = |r_state: f64| -> f64 { solve_series_drive(&xtor, r_state) };

    // set: P -> AP. Incubation happens near parallel, so the junction
    // is mostly in R_P; efficiency at cos(theta)=+1 (low).
    let r_set = 0.5 * (mtj.r_p() + mtj.r_ap());
    let i_set = drive(mtj.r_p() * 0.7 + r_set * 0.3);
    let eta_set = eta_slonczewski(mtj.polarization, 0.95);
    let prob_set = LlgsProblem {
        b_k: MU0 * mtj.hk,
        easy: [0.0, 0.0, 1.0],
        alpha: mtj.alpha,
        a_j: a_j(&mtj, eta_set, i_set),
        p: [0.0, 0.0, 1.0],
        theta0: mtj.theta0(),
    };
    let t_set = prob_set.solve(pulse_budget);

    // reset: AP -> P. Higher efficiency; junction mostly in R_AP, so
    // the same supply pushes less current but the voltage across the
    // junction (hence power I^2 R) is higher.
    let i_reset = drive(mtj.r_ap() * 0.7 + r_set * 0.3);
    let eta_reset = eta_slonczewski(mtj.polarization, -0.95);
    let prob_reset = LlgsProblem { a_j: a_j(&mtj, eta_reset, i_reset), ..prob_set };
    let t_reset = prob_reset.solve(pulse_budget);

    // energy drawn from the supply during the pulse (+ driver caps)
    let e_drv = 2.0 * xtor.cg() * VDD * VDD;
    let e_set = VDD * i_set * t_set.t_switch + e_drv;
    let e_reset = VDD * i_reset * t_reset.t_switch + e_drv;

    // --- read: 25 mV differential sensing ---------------------------
    let v_read = 0.28; // read-disturb-safe bias (shared write path)
    let r_access_read = xtor.r_on();
    let sense = transient::mtj_sense(
        r_access_read,
        mtj.r_p(),
        mtj.r_ap(),
        50e-15,
        v_read,
    );
    let e_senseamp = 55e-15; // latch + column circuitry
    Ok(FinSweepPoint {
        fins_write: fins,
        fins_read: fins,
        write_latency_set: t_set.t_switch,
        write_latency_reset: t_reset.t_switch,
        write_energy_set: e_set,
        write_energy_reset: e_reset,
        sense_latency: WL_RISE + sense.latency,
        sense_energy: sense.energy + e_senseamp,
        area_rel: mram_area_rel(fins, fins, false, &l),
        functional: t_set.switched && t_reset.switched && sense.resolved,
    })
}

/// Characterize a SOT bitcell at the given write fin count on the
/// paper's 16 nm node (read device fixed at 1 fin thanks to the
/// decoupled read path).
pub fn sot_point(fins_write: u32) -> FinSweepPoint {
    sot_point_at(16, fins_write).expect("16 nm is calibrated")
}

/// As [`sot_point`] at an explicit process node.
pub fn sot_point_at(node_nm: u32, fins_write: u32) -> Result<FinSweepPoint, UncalibratedNode> {
    let mtj = Mtj::sot_at(node_nm)?;
    let ch = SotChannel::beta_w_at(node_nm)?;
    let l = layout::Layout::at(node_nm)?;
    let wr = FinFet::new(fins_write, Flavor::Hp);
    let rd = FinFet::new(1, Flavor::Hp);
    let pulse_budget = SOT_PULSE_BUDGET;

    // charge current through the heavy-metal channel
    let i_c = solve_series_drive(&wr, ch.r_channel);
    let i_s = ch.spin_current(i_c, mtj.area());
    // SOT damping-like torque efficiency ~ 1 (the spin current is
    // already the polarized quantity); small set/reset asymmetry from
    // the Oersted field aiding one polarity.
    let base = LlgsProblem {
        b_k: MU0 * mtj.hk,
        easy: [0.0, 1.0, 0.0],
        alpha: mtj.alpha,
        a_j: a_j(&mtj, 1.0, i_s),
        p: [0.0, 1.0, 0.0],
        theta0: mtj.theta0(),
    };
    let t_set = base.solve(pulse_budget);
    let t_reset =
        LlgsProblem { a_j: base.a_j * 1.22, ..base }.solve(pulse_budget);

    let e_drv = 2.0 * wr.cg() * VDD * VDD;
    let e_set = VDD * i_c * t_set.t_switch + e_drv;
    let e_reset = VDD * i_c * t_reset.t_switch + e_drv;

    // read through the dedicated 1-fin device: a somewhat higher read
    // bias is safe because the junction never sees write-path stress.
    let v_read = 0.30;
    let sense =
        transient::mtj_sense(rd.r_on(), mtj.r_p(), mtj.r_ap(), 50e-15, v_read);
    let e_senseamp = 12e-15;
    Ok(FinSweepPoint {
        fins_write,
        fins_read: 1,
        write_latency_set: t_set.t_switch,
        write_latency_reset: t_reset.t_switch,
        write_energy_set: e_set,
        write_energy_reset: e_reset,
        sense_latency: WL_RISE + sense.latency,
        sense_energy: sense.energy + e_senseamp,
        area_rel: mram_area_rel(fins_write, 1, true, &l),
        functional: t_set.switched && t_reset.switched && sense.resolved,
    })
}

/// Run the full fin-count sweep (1..=8 write fins) for both MRAM
/// flavors on the paper's 16 nm node and pick the min-EDAP functional
/// sizing for each.
pub fn characterize() -> CharacterizeResult {
    characterize_at(16).expect("16 nm is calibrated")
}

/// As [`characterize`] at an explicit process node: the same Table I
/// flow against the node's MTJ stacks and layout geometry.
pub fn characterize_at(node_nm: u32) -> Result<CharacterizeResult, UncalibratedNode> {
    let mut stt_sweep = Vec::with_capacity(8);
    let mut sot_sweep = Vec::with_capacity(8);
    for fins in 1..=8 {
        stt_sweep.push(stt_point_at(node_nm, fins)?);
        sot_sweep.push(sot_point_at(node_nm, fins)?);
    }

    let pick = |sweep: &[FinSweepPoint]| -> FinSweepPoint {
        *sweep
            .iter()
            .filter(|p| p.functional)
            .min_by(|a, b| a.edap().partial_cmp(&b.edap()).unwrap())
            .expect("no functional sizing in sweep")
    };

    Ok(CharacterizeResult {
        stt: pick(&stt_sweep).to_params(MemTech::SttMram),
        sot: pick(&sot_sweep).to_params(MemTech::SotMram),
        stt_sweep,
        sot_sweep,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Relative-band assertion helper.
    fn in_band(x: f64, lo: f64, hi: f64, what: &str) {
        assert!(
            (lo..=hi).contains(&x),
            "{what} = {x:.4e} outside [{lo:.3e}, {hi:.3e}]"
        );
    }

    #[test]
    fn stt_optimum_matches_table1_class() {
        let r = characterize();
        // Paper: 4 fins, 8.4/7.78 ns, 1.1/2.2 pJ, sense 650 ps/0.076 pJ.
        // Model-vs-paper deltas are recorded in EXPERIMENTS.md §T1.
        assert!(
            (3..=5).contains(&r.stt.fins_write),
            "stt fins {}",
            r.stt.fins_write
        );
        in_band(r.stt.write_latency_set, 4e-9, 14e-9, "stt set latency");
        in_band(r.stt.write_latency_reset, 3e-9, 12e-9, "stt reset latency");
        assert!(
            r.stt.write_latency_set > r.stt.write_latency_reset,
            "set must be the slow polarity"
        );
        in_band(r.stt.write_energy_set, 0.4e-12, 2.5e-12, "stt set energy");
        in_band(r.stt.write_energy_reset, 0.15e-12, 2.5e-12, "stt reset energy");
        in_band(r.stt.sense_latency, 350e-12, 1000e-12, "stt sense latency");
        in_band(r.stt.sense_energy, 0.03e-12, 0.15e-12, "stt sense energy");
        in_band(r.stt.area_rel, 0.25, 0.45, "stt area");
    }

    #[test]
    fn sot_optimum_matches_table1_class() {
        let r = characterize();
        // Paper: 3(w)+1(r) fins, 313/243 ps, 0.08 pJ, sense 650 ps/0.020 pJ.
        assert!(
            (2..=4).contains(&r.sot.fins_write),
            "sot fins {}",
            r.sot.fins_write
        );
        assert_eq!(r.sot.fins_read, 1);
        in_band(r.sot.write_latency_set, 120e-12, 650e-12, "sot set latency");
        assert!(r.sot.write_latency_reset < r.sot.write_latency_set);
        in_band(r.sot.write_energy_set, 0.01e-12, 0.25e-12, "sot energy");
        in_band(r.sot.sense_latency, 350e-12, 1300e-12, "sot sense latency");
        assert!(
            r.sot.sense_energy < r.stt.sense_energy,
            "decoupled read path must be cheaper"
        );
        in_band(r.sot.area_rel, 0.18, 0.40, "sot area");
        // Both MRAM cells are >=2.5x denser than the 6T SRAM cell. (The
        // paper's SOT cell is also denser than its STT cell because STT
        // needs 4 shared fins vs our sweep's 3; at equal write fins the
        // SOT read stack adds width — recorded in EXPERIMENTS.md §T1.)
        assert!(r.sot.area_rel < 0.4 && r.stt.area_rel < 0.4);
    }

    #[test]
    fn sot_writes_orders_faster_than_stt() {
        let r = characterize();
        assert!(
            r.stt.write_latency_set / r.sot.write_latency_set > 10.0,
            "stt {} vs sot {}",
            r.stt.write_latency_set,
            r.sot.write_latency_set
        );
        assert!(r.stt.write_energy_set / r.sot.write_energy_set > 4.0);
    }

    #[test]
    fn more_fins_faster_stt_writes() {
        let p2 = stt_point(2);
        let p6 = stt_point(6);
        if p2.functional && p6.functional {
            assert!(p6.write_latency_set < p2.write_latency_set);
        }
        assert!(p6.area_rel > p2.area_rel, "area grows with fins");
    }

    #[test]
    fn sweep_is_complete_and_monotone_area() {
        let r = characterize();
        assert_eq!(r.stt_sweep.len(), 8);
        assert_eq!(r.sot_sweep.len(), 8);
        for w in r.stt_sweep.windows(2) {
            assert!(w[1].area_rel > w[0].area_rel);
        }
    }

    #[test]
    fn sram_cell_area_is_node_indexed_and_single_sourced() {
        assert_eq!(sram_cell_area(16).unwrap(), layout::Layout::n16().sram_cell_area);
        let a16 = sram_cell_area(16).unwrap();
        let a7 = sram_cell_area(7).unwrap();
        let a5 = sram_cell_area(5).unwrap();
        assert!(a7 < a16 && a5 < a7, "cells shrink with the node");
        assert_eq!(sram_cell_area(9).unwrap_err(), UncalibratedNode(9));
    }

    #[test]
    fn scaled_nodes_characterize_to_functional_cells() {
        // the 7 nm flow must find functional (budget-respecting)
        // sizings for both flavors — the smaller free-layer volume
        // keeps the torque margin in the 16 nm class
        let n7 = characterize_at(7).unwrap();
        assert!(n7.stt.write_latency_set <= STT_PULSE_BUDGET);
        assert!(n7.sot.write_latency_set <= SOT_PULSE_BUDGET);
        // area stays MRAM-dense relative to the same-node SRAM cell
        assert!(n7.stt.area_rel < 0.6 && n7.sot.area_rel < 0.6);
        // iso-sizing, the 7 nm stack writes no slower than 16 nm: the
        // shrunken volume raises the spin-torque field per ampere
        let (p16, p7) = (stt_point(4), stt_point_at(7, 4).unwrap());
        if p16.functional && p7.functional {
            assert!(
                p7.write_latency_set < p16.write_latency_set * 1.15,
                "7nm 4-fin set {} vs 16nm {}",
                p7.write_latency_set,
                p16.write_latency_set
            );
        }
        assert!(characterize_at(9).is_err());
        assert!(stt_point_at(9, 4).is_err());
        assert!(sot_point_at(9, 4).is_err());
    }

    #[test]
    fn physical_flow_agrees_with_calibration_on_density_trend() {
        // Two layers model MRAM area per node: the Table-I-style
        // physical layout here and the calibrated
        // `BitcellParams::paper_at` scaling the cache model consumes.
        // They are intentionally independent (model vs calibration,
        // like the 16 nm Table I deltas), but must agree on the
        // *direction*: relative to same-node SRAM, MRAM cells do NOT
        // get denser at deep nodes, because the MTJ via/pillar width
        // is patterning-limited while the SRAM cell shrinks fully.
        let a16 = stt_point(4).area_rel;
        let a7 = stt_point_at(7, 4).unwrap().area_rel;
        let a5 = sot_point_at(5, 3).unwrap().area_rel;
        assert!(a7 > a16 * 0.95, "iso-sizing 7nm stt {a7} vs 16nm {a16}");
        // and both layers keep every MRAM cell denser than SRAM
        assert!(a7 < 1.0 && a5 < 1.0);
        let cal7 = crate::device::BitcellParams::paper_at(MemTech::SttMram, 7)
            .unwrap()
            .area_rel;
        // same band, not wild divergence (ratio within ~2x either way)
        assert!(
            (0.5..2.0).contains(&(a7 / cal7)),
            "physical {a7} vs calibrated {cal7} at 7nm"
        );
    }
}
