//! Shared device-layer types: the memory technologies under study and
//! the bitcell parameter bundle handed to the cache modeler.

use std::fmt;

/// Memory technology under study (paper's set M in Algorithm 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemTech {
    Sram,
    SttMram,
    SotMram,
}

impl MemTech {
    pub const ALL: [MemTech; 3] = [MemTech::Sram, MemTech::SttMram, MemTech::SotMram];

    pub fn name(&self) -> &'static str {
        match self {
            MemTech::Sram => "SRAM",
            MemTech::SttMram => "STT-MRAM",
            MemTech::SotMram => "SOT-MRAM",
        }
    }

    pub fn is_nvm(&self) -> bool {
        !matches!(self, MemTech::Sram)
    }
}

impl fmt::Display for MemTech {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// MTJ write direction: set = parallel->antiparallel is the *harder*
/// direction for STT; the paper reports both (Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WritePolarity {
    Set,
    Reset,
}

/// Bitcell parameters produced by device characterization (Table I) and
/// consumed by the NVSim-class cache modeler.
///
/// Units follow the framework convention: seconds, joules, watts.
/// `area_rel` is the cell area normalized to the foundry 6T SRAM cell
/// (exactly as the paper's Table I reports it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BitcellParams {
    pub tech: MemTech,
    /// Wordline-to-25mV-differential sense delay.
    pub sense_latency: f64,
    /// Energy integrated over the sensing window.
    pub sense_energy: f64,
    /// Write-enable-to-complete-magnetization-change (set / reset).
    pub write_latency_set: f64,
    pub write_latency_reset: f64,
    pub write_energy_set: f64,
    pub write_energy_reset: f64,
    /// Access-device sizing chosen by the sweep.
    pub fins_write: u32,
    pub fins_read: u32,
    /// Cell area normalized to the foundry SRAM bitcell.
    pub area_rel: f64,
    /// Static leakage per cell in W (0 for MTJ storage; the SRAM cell
    /// leaks through its cross-coupled inverters).
    pub cell_leakage: f64,
}

impl BitcellParams {
    /// Worst-case (max of set/reset) write latency.
    pub fn write_latency(&self) -> f64 {
        self.write_latency_set.max(self.write_latency_reset)
    }

    /// Mean write energy over an assumed 50/50 set/reset mix.
    pub fn write_energy(&self) -> f64 {
        0.5 * (self.write_energy_set + self.write_energy_reset)
    }

    /// Paper-calibrated Table I values for STT-MRAM (16nm).
    pub fn paper_stt() -> Self {
        BitcellParams {
            tech: MemTech::SttMram,
            sense_latency: 650e-12,
            sense_energy: 0.076e-12,
            write_latency_set: 8400e-12,
            write_latency_reset: 7780e-12,
            write_energy_set: 1.1e-12,
            write_energy_reset: 2.2e-12,
            fins_write: 4,
            fins_read: 4, // shared read/write device
            area_rel: 0.34,
            cell_leakage: 0.0,
        }
    }

    /// Paper-calibrated Table I values for SOT-MRAM (16nm).
    pub fn paper_sot() -> Self {
        BitcellParams {
            tech: MemTech::SotMram,
            sense_latency: 650e-12,
            sense_energy: 0.020e-12,
            write_latency_set: 313e-12,
            write_latency_reset: 243e-12,
            write_energy_set: 0.08e-12,
            write_energy_reset: 0.08e-12,
            fins_write: 3,
            fins_read: 1,
            area_rel: 0.29,
            cell_leakage: 0.0,
        }
    }

    /// Foundry-6T-SRAM reference cell (the normalization baseline).
    /// Latency/energy here are the cell-level access contributions; the
    /// cache modeler adds the array/periphery terms. The leakage value
    /// is the per-cell subthreshold+gate leakage that makes the paper's
    /// 3MB SRAM cache leak ~6.4 W (Table II): 6T at 16nm, high-density
    /// low-leakage flavor.
    pub fn paper_sram() -> Self {
        BitcellParams {
            tech: MemTech::Sram,
            sense_latency: 380e-12,
            sense_energy: 0.040e-12,
            write_latency_set: 290e-12,
            write_latency_reset: 290e-12,
            write_energy_set: 0.045e-12,
            write_energy_reset: 0.045e-12,
            fins_write: 1,
            fins_read: 1,
            area_rel: 1.0,
            // 6T HD cell at 16nm, worst-case-corner leakage as NVSim's
            // tech file reports it (calibrated so the 3 MB cache lands
            // on Table II's 6442 mW together with the periphery terms).
            cell_leakage: 185e-9,
        }
    }

    /// Paper defaults per technology.
    pub fn paper(tech: MemTech) -> Self {
        match tech {
            MemTech::Sram => Self::paper_sram(),
            MemTech::SttMram => Self::paper_stt(),
            MemTech::SotMram => Self::paper_sot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values_match_table1() {
        let stt = BitcellParams::paper_stt();
        assert_eq!(stt.sense_latency, 650e-12);
        assert_eq!(stt.write_latency(), 8400e-12);
        assert!((stt.write_energy() - 1.65e-12).abs() < 1e-18);
        let sot = BitcellParams::paper_sot();
        assert_eq!(sot.fins_write, 3);
        assert_eq!(sot.fins_read, 1);
        assert!(sot.area_rel < stt.area_rel);
    }

    #[test]
    fn nvm_cells_do_not_leak() {
        assert_eq!(BitcellParams::paper_stt().cell_leakage, 0.0);
        assert_eq!(BitcellParams::paper_sot().cell_leakage, 0.0);
        assert!(BitcellParams::paper_sram().cell_leakage > 0.0);
    }

    #[test]
    fn memtech_display_and_flags() {
        assert_eq!(MemTech::SttMram.to_string(), "STT-MRAM");
        assert!(MemTech::SttMram.is_nvm());
        assert!(!MemTech::Sram.is_nvm());
        assert_eq!(MemTech::ALL.len(), 3);
    }
}
