//! Shared device-layer types: the memory technologies under study, the
//! calibrated process-node set, and the bitcell parameter bundle handed
//! to the cache modeler.

use std::fmt;

/// Process nodes (nm) with full cross-layer calibration: interconnect
/// and periphery ([`crate::nvsim::TechParams`]), bitcell geometry
/// ([`super::characterize::layout`]) and device stacks
/// ([`super::mtj::Mtj`]). This list is THE source of truth — sweep-spec
/// validation, the serve routes and the memo merge path all check
/// against it, so adding a node here (plus its calibration data) lights
/// it up everywhere at once.
pub const CALIBRATED_NODES_NM: [u32; 3] = [16, 7, 5];

/// Whether `node_nm` names a calibrated process node.
pub fn node_calibrated(node_nm: u32) -> bool {
    CALIBRATED_NODES_NM.contains(&node_nm)
}

/// Typed error for a process node outside [`CALIBRATED_NODES_NM`].
/// Model entry points return this instead of panicking so untrusted
/// inputs (HTTP bodies, merged memo documents) degrade to an error
/// response, never a dead worker thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UncalibratedNode(pub u32);

impl fmt::Display for UncalibratedNode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "process node {}nm is not calibrated (calibrated:", self.0)?;
        for (i, n) in CALIBRATED_NODES_NM.iter().enumerate() {
            write!(f, "{}{n}", if i == 0 { " " } else { ", " })?;
        }
        write!(f, " nm)")
    }
}

impl std::error::Error for UncalibratedNode {}

/// Memory technology under study (paper's set M in Algorithm 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemTech {
    Sram,
    SttMram,
    SotMram,
}

impl MemTech {
    pub const ALL: [MemTech; 3] = [MemTech::Sram, MemTech::SttMram, MemTech::SotMram];

    pub fn name(&self) -> &'static str {
        match self {
            MemTech::Sram => "SRAM",
            MemTech::SttMram => "STT-MRAM",
            MemTech::SotMram => "SOT-MRAM",
        }
    }

    pub fn is_nvm(&self) -> bool {
        !matches!(self, MemTech::Sram)
    }
}

impl fmt::Display for MemTech {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// MTJ write direction: set = parallel->antiparallel is the *harder*
/// direction for STT; the paper reports both (Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WritePolarity {
    Set,
    Reset,
}

/// Bitcell parameters produced by device characterization (Table I) and
/// consumed by the NVSim-class cache modeler.
///
/// Units follow the framework convention: seconds, joules, watts.
/// `area_rel` is the cell area normalized to the foundry 6T SRAM cell
/// (exactly as the paper's Table I reports it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BitcellParams {
    pub tech: MemTech,
    /// Wordline-to-25mV-differential sense delay.
    pub sense_latency: f64,
    /// Energy integrated over the sensing window.
    pub sense_energy: f64,
    /// Write-enable-to-complete-magnetization-change (set / reset).
    pub write_latency_set: f64,
    pub write_latency_reset: f64,
    pub write_energy_set: f64,
    pub write_energy_reset: f64,
    /// Access-device sizing chosen by the sweep.
    pub fins_write: u32,
    pub fins_read: u32,
    /// Cell area normalized to the foundry SRAM bitcell.
    pub area_rel: f64,
    /// Static leakage per cell in W (0 for MTJ storage; the SRAM cell
    /// leaks through its cross-coupled inverters).
    pub cell_leakage: f64,
}

impl BitcellParams {
    /// Worst-case (max of set/reset) write latency.
    pub fn write_latency(&self) -> f64 {
        self.write_latency_set.max(self.write_latency_reset)
    }

    /// Mean write energy over an assumed 50/50 set/reset mix.
    pub fn write_energy(&self) -> f64 {
        0.5 * (self.write_energy_set + self.write_energy_reset)
    }

    /// Paper-calibrated Table I values for STT-MRAM (16nm).
    pub fn paper_stt() -> Self {
        BitcellParams {
            tech: MemTech::SttMram,
            sense_latency: 650e-12,
            sense_energy: 0.076e-12,
            write_latency_set: 8400e-12,
            write_latency_reset: 7780e-12,
            write_energy_set: 1.1e-12,
            write_energy_reset: 2.2e-12,
            fins_write: 4,
            fins_read: 4, // shared read/write device
            area_rel: 0.34,
            cell_leakage: 0.0,
        }
    }

    /// Paper-calibrated Table I values for SOT-MRAM (16nm).
    pub fn paper_sot() -> Self {
        BitcellParams {
            tech: MemTech::SotMram,
            sense_latency: 650e-12,
            sense_energy: 0.020e-12,
            write_latency_set: 313e-12,
            write_latency_reset: 243e-12,
            write_energy_set: 0.08e-12,
            write_energy_reset: 0.08e-12,
            fins_write: 3,
            fins_read: 1,
            area_rel: 0.29,
            cell_leakage: 0.0,
        }
    }

    /// Foundry-6T-SRAM reference cell (the normalization baseline).
    /// Latency/energy here are the cell-level access contributions; the
    /// cache modeler adds the array/periphery terms. The leakage value
    /// is the per-cell subthreshold+gate leakage that makes the paper's
    /// 3MB SRAM cache leak ~6.4 W (Table II): 6T at 16nm, high-density
    /// low-leakage flavor.
    pub fn paper_sram() -> Self {
        BitcellParams {
            tech: MemTech::Sram,
            sense_latency: 380e-12,
            sense_energy: 0.040e-12,
            write_latency_set: 290e-12,
            write_latency_reset: 290e-12,
            write_energy_set: 0.045e-12,
            write_energy_reset: 0.045e-12,
            fins_write: 1,
            fins_read: 1,
            area_rel: 1.0,
            // 6T HD cell at 16nm, worst-case-corner leakage as NVSim's
            // tech file reports it (calibrated so the 3 MB cache lands
            // on Table II's 6442 mW together with the periphery terms).
            cell_leakage: 185e-9,
        }
    }

    /// Paper defaults per technology (the 16 nm Table I calibration).
    pub fn paper(tech: MemTech) -> Self {
        match tech {
            MemTech::Sram => Self::paper_sram(),
            MemTech::SttMram => Self::paper_stt(),
            MemTech::SotMram => Self::paper_sot(),
        }
    }

    /// Calibrated bitcell parameters at a process node, scaled from the
    /// 16 nm Table I baselines by [`NodeScale`]. 16 nm returns the
    /// baselines bit-for-bit.
    pub fn paper_at(tech: MemTech, node_nm: u32) -> Result<Self, UncalibratedNode> {
        let s = NodeScale::at(node_nm)?;
        let base = Self::paper(tech);
        let area_rel = match tech {
            MemTech::Sram => base.area_rel,
            _ => base.area_rel * s.mram_area_rel,
        };
        Ok(BitcellParams {
            sense_latency: base.sense_latency * s.latency,
            sense_energy: base.sense_energy * s.energy,
            write_latency_set: base.write_latency_set * s.latency,
            write_latency_reset: base.write_latency_reset * s.latency,
            write_energy_set: base.write_energy_set * s.energy,
            write_energy_reset: base.write_energy_reset * s.energy,
            area_rel,
            cell_leakage: base.cell_leakage * s.sram_cell_leak,
            ..base
        })
    }
}

/// Deep-scaling multipliers applied to the 16 nm bitcell calibration
/// (DeepNVM++'s journal extension carries the scalability analysis to
/// deeply-scaled nodes; these factors follow its first-order trends):
///
/// * `latency` — switching and sensing speed up with faster access
///   devices, but less than the FO4 gain (the MTJ dynamics and the
///   sense window are device-limited, not logic-limited).
/// * `energy` — CV²: cell and driver capacitance shrink with geometry
///   while VDD drops 0.8 -> 0.7 -> 0.65 V.
/// * `sram_cell_leak` — per-cell 6T leakage *rises* at deeply-scaled
///   geometries (DIBL, gate leakage, worst-corner Vt spread) even as
///   dynamic energy falls — the effect that widens the NVM advantage
///   at 7/5 nm.
/// * `mram_area_rel` — the MTJ pillar is patterning-limited (~30-50 nm)
///   and shrinks slower than the logic pitch, so the cell's area
///   *relative to same-node SRAM* grows; MRAM stays denser (< 1) but
///   the density edge narrows.
/// * `periph_leak_density` — leakage per mm^2 of peripheral silicon
///   *rises* as more (leakier) transistors pack each unit area; the
///   cache model applies it to decoder/sense/driver strips.
#[derive(Clone, Copy, Debug)]
pub struct NodeScale {
    pub latency: f64,
    pub energy: f64,
    pub sram_cell_leak: f64,
    pub mram_area_rel: f64,
    pub periph_leak_density: f64,
}

impl NodeScale {
    /// Scaling factors for a calibrated node (16 nm is identity).
    /// This is the ONLY per-node factor table: every other node switch
    /// (`TechParams::at`, `Layout::at`, `Mtj::*_at`) dispatches to
    /// full calibration structs, and the cache model's periphery reads
    /// its factors from here, so a node added to
    /// [`CALIBRATED_NODES_NM`] cannot be half-wired.
    pub fn at(node_nm: u32) -> Result<Self, UncalibratedNode> {
        Ok(match node_nm {
            16 => NodeScale {
                latency: 1.0,
                energy: 1.0,
                sram_cell_leak: 1.0,
                mram_area_rel: 1.0,
                periph_leak_density: 1.0,
            },
            7 => NodeScale {
                latency: 0.82,
                energy: 0.55,
                sram_cell_leak: 1.35,
                mram_area_rel: 1.30,
                periph_leak_density: 2.2,
            },
            5 => NodeScale {
                latency: 0.74,
                energy: 0.42,
                sram_cell_leak: 1.60,
                mram_area_rel: 1.55,
                periph_leak_density: 2.8,
            },
            other => return Err(UncalibratedNode(other)),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values_match_table1() {
        let stt = BitcellParams::paper_stt();
        assert_eq!(stt.sense_latency, 650e-12);
        assert_eq!(stt.write_latency(), 8400e-12);
        assert!((stt.write_energy() - 1.65e-12).abs() < 1e-18);
        let sot = BitcellParams::paper_sot();
        assert_eq!(sot.fins_write, 3);
        assert_eq!(sot.fins_read, 1);
        assert!(sot.area_rel < stt.area_rel);
    }

    #[test]
    fn nvm_cells_do_not_leak() {
        assert_eq!(BitcellParams::paper_stt().cell_leakage, 0.0);
        assert_eq!(BitcellParams::paper_sot().cell_leakage, 0.0);
        assert!(BitcellParams::paper_sram().cell_leakage > 0.0);
    }

    #[test]
    fn node_list_and_errors() {
        assert_eq!(CALIBRATED_NODES_NM, [16, 7, 5]);
        assert!(node_calibrated(16) && node_calibrated(7) && node_calibrated(5));
        assert!(!node_calibrated(9) && !node_calibrated(0));
        let e = NodeScale::at(9).unwrap_err();
        assert_eq!(e, UncalibratedNode(9));
        assert!(e.to_string().contains("9nm"));
        // the error names the calibrated set, derived from the constant
        assert!(e.to_string().contains("16, 7, 5 nm"), "{e}");
        assert!(BitcellParams::paper_at(MemTech::Sram, 3).is_err());
    }

    #[test]
    fn sixteen_nm_scaling_is_identity() {
        for tech in MemTech::ALL {
            let base = BitcellParams::paper(tech);
            let scaled = BitcellParams::paper_at(tech, 16).unwrap();
            assert_eq!(base, scaled, "{tech}");
        }
    }

    #[test]
    fn scaled_nodes_follow_first_order_trends() {
        for tech in MemTech::ALL {
            let n16 = BitcellParams::paper_at(tech, 16).unwrap();
            let n7 = BitcellParams::paper_at(tech, 7).unwrap();
            let n5 = BitcellParams::paper_at(tech, 5).unwrap();
            // faster and cheaper accesses as the node scales
            assert!(n5.sense_latency < n7.sense_latency);
            assert!(n7.sense_latency < n16.sense_latency, "{tech}");
            assert!(n5.write_energy() < n7.write_energy());
            assert!(n7.write_energy() < n16.write_energy(), "{tech}");
            if tech == MemTech::Sram {
                // SRAM leaks *more* per cell at deep nodes
                assert!(n5.cell_leakage > n7.cell_leakage);
                assert!(n7.cell_leakage > n16.cell_leakage);
                assert_eq!(n7.area_rel, 1.0, "SRAM is its own area baseline");
            } else {
                // MRAM density edge narrows but never inverts
                assert!(n16.area_rel < n7.area_rel);
                assert!(n7.area_rel < n5.area_rel);
                assert!(n5.area_rel < 1.0, "{tech} must stay denser than SRAM");
                assert_eq!(n7.cell_leakage, 0.0, "MTJs do not leak at any node");
            }
        }
    }

    #[test]
    fn memtech_display_and_flags() {
        assert_eq!(MemTech::SttMram.to_string(), "STT-MRAM");
        assert!(MemTech::SttMram.is_nvm());
        assert!(!MemTech::Sram.is_nvm());
        assert_eq!(MemTech::ALL.len(), 3);
    }
}
