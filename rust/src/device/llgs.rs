//! Macrospin Landau-Lifshitz-Gilbert-Slonczewski (LLGS) solver.
//!
//! Replaces the paper's SPICE transient *write* analysis: given a drive
//! current (hence a spin-torque field `a_j`), integrate the free-layer
//! magnetization until it crosses the switched threshold, yielding the
//! write latency that the fin-count sweep in [`super::characterize`]
//! modulates "to the point of failure".
//!
//! Dynamics (explicit Landau-Lifshitz form, fields in Tesla):
//!
//! ```text
//! dm/dt = -g' (m x B) - g' alpha m x (m x B) + g' a_j m x (m x p)
//! g' = gamma / (1 + alpha^2)
//! B  = B_k (m . e) e          (uniaxial easy axis e)
//! a_j = hbar * eta * I / (2 e Ms V)   [Tesla]
//! ```
//!
//! STT: p = easy axis (fixed layer), switching starts from the thermal
//! tilt theta0 and shows the characteristic incubation. SOT is modeled
//! as a type-y cell (easy axis parallel to the injected spin
//! polarization): same equation, but `a_j` carries the spin-Hall
//! geometric gain, so switching is sub-ns at modest charge currents.
//! The critical spin-torque field is `a_j,c = alpha * B_k` (macrospin);
//! tests pin this numerically.

use super::mtj::GAMMA;

/// Problem definition for one switching simulation.
#[derive(Clone, Copy, Debug)]
pub struct LlgsProblem {
    /// Uniaxial anisotropy field (T), i.e. mu0 * Hk.
    pub b_k: f64,
    /// Easy-axis unit vector.
    pub easy: [f64; 3],
    /// Gilbert damping.
    pub alpha: f64,
    /// Spin-torque field magnitude (T); sign chosen to destabilize the
    /// initial state.
    pub a_j: f64,
    /// Spin polarization direction (unit vector).
    pub p: [f64; 3],
    /// Initial tilt from the easy axis (rad) — thermal theta0.
    pub theta0: f64,
}

/// Result of a switching simulation.
#[derive(Clone, Copy, Debug)]
pub struct Trajectory {
    pub switched: bool,
    /// Time of threshold crossing (s); `t_max` if not switched.
    pub t_switch: f64,
    /// Steps integrated (diagnostics / perf accounting).
    pub steps: u64,
    /// Final magnetization.
    pub m_final: [f64; 3],
}

#[inline]
fn cross(a: [f64; 3], b: [f64; 3]) -> [f64; 3] {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

#[inline]
fn dot(a: [f64; 3], b: [f64; 3]) -> f64 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
}

#[inline]
fn norm(a: [f64; 3]) -> [f64; 3] {
    let n = dot(a, a).sqrt();
    [a[0] / n, a[1] / n, a[2] / n]
}

impl LlgsProblem {
    /// dm/dt at magnetization `m`.
    #[inline]
    fn deriv(&self, m: [f64; 3]) -> [f64; 3] {
        let g = GAMMA / (1.0 + self.alpha * self.alpha);
        let me = dot(m, self.easy);
        let b = [
            self.b_k * me * self.easy[0],
            self.b_k * me * self.easy[1],
            self.b_k * me * self.easy[2],
        ];
        let mxb = cross(m, b);
        let mxmxb = cross(m, mxb);
        let mxp = cross(m, self.p);
        let mxmxp = cross(m, mxp);
        [
            -g * (mxb[0] + self.alpha * mxmxb[0] - self.a_j * mxmxp[0]),
            -g * (mxb[1] + self.alpha * mxmxb[1] - self.a_j * mxmxp[1]),
            -g * (mxb[2] + self.alpha * mxmxb[2] - self.a_j * mxmxp[2]),
        ]
    }

    /// Initial magnetization: easy axis tilted by theta0 (in a plane
    /// orthogonal to the easy axis, deterministic direction).
    fn m0(&self) -> [f64; 3] {
        let e = norm(self.easy);
        // find any unit vector orthogonal to e
        let t = if e[0].abs() < 0.9 { [1.0, 0.0, 0.0] } else { [0.0, 1.0, 0.0] };
        let o = norm(cross(e, t));
        let (s, c) = self.theta0.sin_cos();
        norm([
            c * e[0] + s * o[0],
            c * e[1] + s * o[1],
            c * e[2] + s * o[2],
        ])
    }

    /// Integrate with RK4 until `m . easy` crosses `-threshold` or
    /// `t_max` elapses. `dt` is chosen from the precession period.
    pub fn solve(&self, t_max: f64) -> Trajectory {
        // Precession frequency sets the stable step: ~40 steps/period.
        let f_prec = GAMMA * (self.b_k + self.a_j.abs()) / (2.0 * std::f64::consts::PI);
        let dt = (1.0 / (f_prec * 40.0)).min(2e-12);
        let threshold = 0.90;

        let mut m = self.m0();
        let mut t = 0.0;
        let mut steps = 0u64;
        while t < t_max {
            // RK4 step
            let k1 = self.deriv(m);
            let m2 = [
                m[0] + 0.5 * dt * k1[0],
                m[1] + 0.5 * dt * k1[1],
                m[2] + 0.5 * dt * k1[2],
            ];
            let k2 = self.deriv(m2);
            let m3 = [
                m[0] + 0.5 * dt * k2[0],
                m[1] + 0.5 * dt * k2[1],
                m[2] + 0.5 * dt * k2[2],
            ];
            let k3 = self.deriv(m3);
            let m4 = [m[0] + dt * k3[0], m[1] + dt * k3[1], m[2] + dt * k3[2]];
            let k4 = self.deriv(m4);
            m = [
                m[0] + dt / 6.0 * (k1[0] + 2.0 * k2[0] + 2.0 * k3[0] + k4[0]),
                m[1] + dt / 6.0 * (k1[1] + 2.0 * k2[1] + 2.0 * k3[1] + k4[1]),
                m[2] + dt / 6.0 * (k1[2] + 2.0 * k2[2] + 2.0 * k3[2] + k4[2]),
            ];
            m = norm(m); // renormalize |m| = 1 (macrospin invariant)
            t += dt;
            steps += 1;
            if dot(m, norm(self.easy)) < -threshold {
                return Trajectory { switched: true, t_switch: t, steps, m_final: m };
            }
        }
        Trajectory { switched: false, t_switch: t_max, steps, m_final: m }
    }
}

/// Critical spin-torque field for antidamping switching (macrospin).
pub fn critical_aj(alpha: f64, b_k: f64) -> f64 {
    alpha * b_k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stt_problem(overdrive: f64) -> LlgsProblem {
        let alpha = 0.012;
        let b_k = 0.30; // ~2.4e5 A/m * mu0
        LlgsProblem {
            b_k,
            easy: [0.0, 0.0, 1.0],
            alpha,
            a_j: overdrive * critical_aj(alpha, b_k),
            p: [0.0, 0.0, 1.0],
            theta0: 0.08,
        }
    }

    #[test]
    fn switches_above_critical() {
        let t = stt_problem(2.0).solve(50e-9);
        assert!(t.switched, "2x overdrive must switch");
        assert!(t.t_switch > 0.1e-9 && t.t_switch < 50e-9);
    }

    #[test]
    fn does_not_switch_below_critical() {
        let t = stt_problem(0.5).solve(20e-9);
        assert!(!t.switched, "0.5x overdrive must not switch");
        // and it must relax back toward the easy axis
        assert!(t.m_final[2] > 0.9, "m_z {}", t.m_final[2]);
    }

    #[test]
    fn latency_decreases_with_overdrive() {
        let t15 = stt_problem(1.5).solve(100e-9);
        let t3 = stt_problem(3.0).solve(100e-9);
        let t6 = stt_problem(6.0).solve(100e-9);
        assert!(t15.switched && t3.switched && t6.switched);
        assert!(
            t15.t_switch > t3.t_switch && t3.t_switch > t6.t_switch,
            "{} {} {}",
            t15.t_switch,
            t3.t_switch,
            t6.t_switch
        );
    }

    #[test]
    fn smaller_theta0_longer_incubation() {
        let mut a = stt_problem(2.0);
        a.theta0 = 0.02;
        let mut b = stt_problem(2.0);
        b.theta0 = 0.2;
        let ta = a.solve(100e-9);
        let tb = b.solve(100e-9);
        assert!(ta.switched && tb.switched);
        assert!(ta.t_switch > tb.t_switch);
    }

    #[test]
    fn magnetization_stays_unit() {
        let t = stt_problem(2.5).solve(50e-9);
        let n = (t.m_final[0].powi(2) + t.m_final[1].powi(2) + t.m_final[2].powi(2))
            .sqrt();
        assert!((n - 1.0).abs() < 1e-9, "|m| {n}");
    }

    #[test]
    fn inplane_type_y_switches_fast() {
        // SOT-like: easy axis y, polarization y, low damping, strong a_j.
        let alpha = 0.010;
        let b_k = 0.26;
        let p = LlgsProblem {
            b_k,
            easy: [0.0, 1.0, 0.0],
            alpha,
            a_j: 20.0 * critical_aj(alpha, b_k),
            p: [0.0, 1.0, 0.0],
            theta0: 0.09,
        };
        let t = p.solve(5e-9);
        assert!(t.switched);
        assert!(t.t_switch < 1e-9, "SOT-class switch {} s", t.t_switch);
    }
}
