//! Circuit-level NVM characterization (paper §III-A, Table I).
//!
//! The paper uses a commercial 16nm FinFET PDK plus published STT
//! (Kim'15 CICC) and SOT (Kazemi'16 TED) compact models, driving
//! parameterized SPICE netlists in which the read/write pulse widths are
//! modulated to the point of failure and access-device fin counts are
//! swept. None of that proprietary stack is available here, so this
//! module rebuilds the *same flow* from first principles:
//!
//! * [`finfet`] — analytic alpha-power-law FinFET I/V with per-fin
//!   drive, capacitance, and leakage calibrated to public 16FF data.
//! * [`mtj`] — magnetic tunnel junction device models (geometry, RA
//!   product, TMR, thermal stability) for perpendicular STT and
//!   heavy-metal SOT stacks.
//! * [`llgs`] — a macrospin Landau-Lifshitz-Gilbert-Slonczewski ODE
//!   solver (RK4) that produces switching trajectories and write
//!   latency under a given drive current, replacing the SPICE transient
//!   write analysis.
//! * [`transient`] — an RC nodal transient simulator for the read path
//!   (bitline differential development to the 25 mV sense threshold),
//!   replacing the SPICE read analysis.
//! * [`characterize`] — the fin-count sweep (paper's Table I flow):
//!   pick the optimal access-device size, emit [`BitcellParams`].
//!
//! The flow's outputs are validated against the published Table I
//! values in tests (tolerance documented per parameter); downstream
//! cache modeling defaults to the paper-calibrated
//! [`BitcellParams::paper_stt`]/[`BitcellParams::paper_sot`] constants
//! so that Table II+ reproductions do not inherit device-layer drift.

pub mod characterize;
pub mod relaxed;
pub mod finfet;
pub mod llgs;
pub mod mtj;
pub mod transient;
pub mod types;

pub use characterize::{characterize, characterize_at, sram_cell_area, CharacterizeResult};
pub use types::{
    node_calibrated, BitcellParams, MemTech, NodeScale, UncalibratedNode, WritePolarity,
    CALIBRATED_NODES_NM,
};
