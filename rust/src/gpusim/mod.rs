//! Trace-driven GPU memory-hierarchy simulator (the GPGPU-Sim
//! substitute, paper §III-D).
//!
//! The paper extends GPGPU-Sim to run DarkNet AlexNet and to support
//! L2 capacities beyond the GTX 1080 Ti's 3 MB, measuring how the
//! total number of DRAM transactions falls as the L2 grows (Fig. 6).
//! Only the *memory system* outcome of that simulation feeds DeepNVM++
//! (DRAM access counts), so this substitute models exactly that part,
//! at full fidelity where it matters:
//!
//! * per-SM L1 data caches (Table IV: 48 KB, 128 B lines, 6-way LRU,
//!   write-through / no-write-allocate — the Pascal L1 policy),
//! * a shared, banked, sectored L2 (128 B lines, 16-way LRU,
//!   write-back / write-allocate, capacity 3-24 MB),
//! * a GDDR5X-class DRAM model (32 B transactions, per-bank row
//!   buffers) that counts reads/writes and row hits/misses.
//!
//! Traces come from [`crate::workload::trace`] — the same tiled-GEMM
//! schedule the analytic traffic model counts, so the two layers
//! cross-validate (rust/tests/traffic_vs_gpusim.rs). That
//! cross-validation is also a first-class query: `deepnvm validate`
//! (and `POST /validate` on the server) replays a requested
//! (dnn, phase, capacity) slice through both substrates via
//! [`validate`] and reports per-cell relative DRAM-transaction error,
//! gated in CI against [`validate::MAX_REL_ERR`].

pub mod cache;
pub mod config;
pub mod dram;
pub mod gpu;
pub mod validate;

pub use cache::{Cache, CacheConfig};
pub use config::GpuConfig;
pub use gpu::{GpuSim, SimStats};
