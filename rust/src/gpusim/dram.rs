//! GDDR5X-class DRAM channel model: transaction counting with per-bank
//! open-row tracking for the latency/energy model.
//!
//! The iso-area analysis needs (a) total DRAM transaction counts
//! (Fig. 6) and (b) a per-transaction latency/energy figure for the
//! EDP-with-DRAM results (Fig. 8). Row-buffer locality determines the
//! effective per-access latency, so the model tracks open rows per
//! (channel, bank).

/// One DRAM access is a 32 B atom (GDDR5X granularity — matches the L2
/// sector size the paper's transaction counters use).
pub const DRAM_TX_BYTES: u64 = 32;

/// Timing/energy constants for the latency & energy model (GDDR5X-class,
/// in seconds / joules per 32 B transaction).
pub mod timing {
    /// Row-buffer hit access time (CAS only).
    pub const T_ROW_HIT: f64 = 15e-9;
    /// Row miss: precharge + activate + CAS.
    pub const T_ROW_MISS: f64 = 45e-9;
    /// Energy per 32 B transaction on a row hit. ~15 pJ/bit I/O+array.
    pub const E_ROW_HIT: f64 = 3.8e-9;
    /// Extra energy for activate/precharge on a row miss.
    pub const E_ROW_MISS_EXTRA: f64 = 2.2e-9;
}

/// The DRAM subsystem: `channels x banks` open-row registers.
#[derive(Clone, Debug)]
pub struct Dram {
    channels: usize,
    banks: usize,
    row_bytes: u64,
    open_rows: Vec<u64>, // u64::MAX = closed
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
}

impl Dram {
    pub fn new(channels: usize, banks: usize, row_bytes: u64) -> Self {
        Dram {
            channels,
            banks,
            row_bytes,
            open_rows: vec![u64::MAX; channels * banks],
            reads: 0,
            writes: 0,
            row_hits: 0,
            row_misses: 0,
        }
    }

    /// Issue one line-sized access as `line_bytes / 32` transactions.
    pub fn access(&mut self, addr: u64, write: bool, line_bytes: u64) {
        let tx = (line_bytes / DRAM_TX_BYTES).max(1);
        // channel interleaving at line granularity, bank by row bits
        let line = addr / line_bytes;
        let ch = (line % self.channels as u64) as usize;
        let row = addr / (self.row_bytes * self.channels as u64);
        let bank = (row % self.banks as u64) as usize;
        let slot = ch * self.banks + bank;
        if self.open_rows[slot] == row {
            self.row_hits += tx;
        } else {
            self.row_misses += 1;
            self.row_hits += tx - 1; // burst continues in the open row
            self.open_rows[slot] = row;
        }
        if write {
            self.writes += tx;
        } else {
            self.reads += tx;
        }
    }

    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }

    /// Aggregate access latency (s) under the row model, assuming the
    /// channel-level parallelism hides `channels`-way overlap.
    pub fn total_latency(&self) -> f64 {
        (self.row_hits as f64 * timing::T_ROW_HIT
            + self.row_misses as f64 * timing::T_ROW_MISS)
            / self.channels as f64
    }

    /// Aggregate DRAM energy (J).
    pub fn total_energy(&self) -> f64 {
        self.total() as f64 * timing::E_ROW_HIT
            + self.row_misses as f64 * timing::E_ROW_MISS_EXTRA
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_fetch_counts_four_transactions() {
        let mut d = Dram::new(11, 16, 2048);
        d.access(0, false, 128);
        assert_eq!(d.reads, 4);
        assert_eq!(d.writes, 0);
    }

    #[test]
    fn sequential_stream_mostly_row_hits() {
        let mut d = Dram::new(1, 16, 2048);
        for i in 0..64 {
            d.access(i * 128, false, 128);
        }
        // 64 lines x 2048B rows -> 4 rows -> 4 misses
        assert_eq!(d.row_misses, 4);
        assert_eq!(d.row_hits + d.row_misses, d.total());
    }

    #[test]
    fn random_stream_many_row_misses() {
        let mut d = Dram::new(1, 2, 2048);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..1000 {
            d.access(rng.below(1 << 30) & !127, false, 128);
        }
        assert!(d.row_misses > 500, "misses {}", d.row_misses);
    }

    #[test]
    fn energy_and_latency_positive_and_monotone() {
        let mut d = Dram::new(11, 16, 2048);
        d.access(0, false, 128);
        let e1 = d.total_energy();
        let l1 = d.total_latency();
        d.access(1 << 20, true, 128);
        assert!(d.total_energy() > e1);
        assert!(d.total_latency() > l1);
    }
}
