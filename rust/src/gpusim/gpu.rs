//! The assembled hierarchy: 28 L1s -> banked L2 -> DRAM, consuming a
//! memory trace.

use crate::workload::trace::MemAccess;

use super::cache::Cache;
use super::config::GpuConfig;
use super::dram::Dram;

/// Aggregate statistics of one simulation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    pub accesses: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_reads: u64,
    pub l2_writes: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub dram_row_hits: u64,
    pub dram_row_misses: u64,
    /// DRAM latency/energy under the row model (s / J).
    pub dram_latency: f64,
    pub dram_energy: f64,
}

impl SimStats {
    pub fn dram_total(&self) -> u64 {
        self.dram_reads + self.dram_writes
    }

    pub fn l1_hit_rate(&self) -> f64 {
        self.l1_hits as f64 / (self.l1_hits + self.l1_misses).max(1) as f64
    }

    pub fn l2_hit_rate(&self) -> f64 {
        self.l2_hits as f64 / (self.l2_hits + self.l2_misses).max(1) as f64
    }
}

/// The simulator: per-SM L1s, one logical L2 (banking affects timing,
/// not transaction counts), DRAM behind it.
pub struct GpuSim {
    cfg: GpuConfig,
    l1s: Vec<Cache>,
    l2: Cache,
    dram: Dram,
    l2_reads: u64,
    l2_writes: u64,
}

impl GpuSim {
    pub fn new(cfg: GpuConfig) -> Self {
        GpuSim {
            l1s: (0..cfg.n_sms).map(|_| Cache::new(cfg.l1_config())).collect(),
            l2: Cache::new(cfg.l2_config()),
            dram: Dram::new(cfg.dram_channels, cfg.dram_banks, cfg.dram_row_bytes),
            cfg,
            l2_reads: 0,
            l2_writes: 0,
        }
    }

    /// Process one 32 B sector access.
    #[inline]
    pub fn access(&mut self, a: MemAccess) {
        let l1 = &mut self.l1s[a.sm as usize % self.cfg.n_sms];
        let r1 = l1.access(a.addr, a.write);

        // L1 write-through: every write reaches L2. Reads reach L2 only
        // on L1 miss.
        let to_l2 = a.write || !r1.hit;
        if !to_l2 {
            return;
        }
        if a.write {
            self.l2_writes += 1;
        } else {
            self.l2_reads += 1;
        }
        let r2 = self.l2.access(a.addr, a.write);
        if !r2.hit && r2.filled {
            // line fill from DRAM
            self.dram.access(a.addr, false, self.cfg.line_bytes);
        }
        if let Some(victim) = r2.writeback {
            self.dram.access(victim, true, self.cfg.line_bytes);
        }
        if !r2.hit && !r2.filled {
            // (write-through-no-allocate L2 would land here; with
            // BackAllocate this is unreachable, kept for policy swaps)
            self.dram.access(a.addr, a.write, super::dram::DRAM_TX_BYTES);
        }
    }

    /// Drive a whole trace through the hierarchy.
    pub fn run(&mut self, trace: impl Iterator<Item = MemAccess>) -> SimStats {
        let mut n = 0u64;
        for a in trace {
            self.access(a);
            n += 1;
        }
        self.stats(n)
    }

    fn stats(&self, accesses: u64) -> SimStats {
        let l1_hits: u64 = self.l1s.iter().map(|c| c.hits).sum();
        let l1_misses: u64 = self.l1s.iter().map(|c| c.misses).sum();
        SimStats {
            accesses,
            l1_hits,
            l1_misses,
            l2_reads: self.l2_reads,
            l2_writes: self.l2_writes,
            l2_hits: self.l2.hits,
            l2_misses: self.l2.misses,
            dram_reads: self.dram.reads,
            dram_writes: self.dram.writes,
            dram_row_hits: self.dram.row_hits,
            dram_row_misses: self.dram.row_misses,
            dram_latency: self.dram.total_latency(),
            dram_energy: self.dram.total_energy(),
        }
    }
}

/// Convenience: simulate one network end to end (paper Fig. 6 runs
/// AlexNet inference) and return the stats.
pub fn simulate_dnn(
    cfg: GpuConfig,
    dnn: &crate::workload::models::Dnn,
    phase: crate::workload::models::Phase,
    batch: usize,
) -> SimStats {
    let trace = crate::workload::trace::DnnTrace::new(dnn, phase, batch);
    GpuSim::new(cfg).run(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{Dnn, Phase};
    use crate::workload::trace::MemAccess;

    const MB: u64 = 1024 * 1024;

    fn seq_trace(n: u64, write_every: u64) -> impl Iterator<Item = MemAccess> {
        (0..n).map(move |i| MemAccess {
            addr: i * 32,
            write: write_every > 0 && i % write_every == 0,
            sm: (i % 28) as u16,
        })
    }

    #[test]
    fn sequential_reads_fetch_each_line_once() {
        let mut sim = GpuSim::new(GpuConfig::gtx1080ti(3 * MB));
        let s = sim.run(seq_trace(4096, 0));
        // 4096 sectors = 1024 lines; each fetched exactly once
        assert_eq!(s.dram_reads, 1024 * 4);
        assert_eq!(s.dram_writes, 0);
    }

    #[test]
    fn l1_catches_intra_line_locality() {
        // 4 sectors per line from the same SM: 1 miss + 3 hits
        let mut sim = GpuSim::new(GpuConfig::gtx1080ti(3 * MB));
        let trace = (0..4096u64).map(|i| MemAccess {
            addr: i * 32,
            write: false,
            sm: 0,
        });
        let s = sim.run(trace);
        assert!(s.l1_hit_rate() > 0.70, "hit rate {}", s.l1_hit_rate());
    }

    #[test]
    fn larger_l2_reduces_dram_traffic_on_looped_stream() {
        // loop over an 8 MB footprint twice: a 16 MB L2 captures the
        // second pass, a 1 MB L2 does not.
        let loop_trace = || {
            (0..2u64)
                .flat_map(|_| (0..(8 * MB / 32)).map(|i| i * 32))
                .map(|addr| MemAccess { addr, write: false, sm: (addr % 28) as u16 })
        };
        let small = GpuSim::new(GpuConfig::gtx1080ti(MB)).run(loop_trace());
        let large = GpuSim::new(GpuConfig::gtx1080ti(16 * MB)).run(loop_trace());
        assert!(
            large.dram_total() < small.dram_total() / 18 * 10,
            "large {} small {}",
            large.dram_total(),
            small.dram_total()
        );
    }

    #[test]
    fn writes_generate_writebacks() {
        let mut sim = GpuSim::new(GpuConfig::gtx1080ti(MB));
        // write an 8MB region: dirty lines must spill
        let trace = (0..(8 * MB / 32)).map(|i| MemAccess {
            addr: i * 32,
            write: true,
            sm: 0,
        });
        let s = sim.run(trace);
        assert!(s.dram_writes > 0, "no writebacks");
    }

    #[test]
    fn squeezenet_end_to_end_smoke() {
        let d = Dnn::by_name("SqueezeNet").unwrap();
        let s = simulate_dnn(GpuConfig::gtx1080ti(3 * MB), &d, Phase::Inference, 1);
        assert!(s.accesses > 1_000_000, "{}", s.accesses);
        assert!(s.l2_hit_rate() > 0.1 && s.l2_hit_rate() < 1.0);
        assert!(s.dram_total() > 0);
        assert!(s.dram_energy > 0.0 && s.dram_latency > 0.0);
    }
}
