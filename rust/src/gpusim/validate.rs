//! Cross-validation of the analytic traffic model against the
//! trace-driven hierarchy simulation, packaged as a queryable report
//! (`deepnvm validate` / `POST /validate`).
//!
//! The paper validates its nvprof-derived traffic counts by replaying
//! the same tiled-GEMM schedule through an extended GPGPU-Sim and
//! comparing total DRAM transactions (§III-D, Fig. 6). This module is
//! that experiment as a first-class query: for every requested
//! (dnn, phase, capacity) cell it
//!
//! 1. sums the analytic [`TrafficModel`] over the network's GEMM-backed
//!    layers (pool/eltwise layers exist only analytically — the trace
//!    generator does not schedule them, so they are excluded from both
//!    sides),
//! 2. replays the [`crate::workload::trace::DnnTrace`] schedule through
//!    [`GpuSim`](super::GpuSim) at the same L2 capacity, and
//! 3. reports both DRAM transaction totals and their relative error.
//!
//! The report's `max_rel_err` is the citable headline number; CI's
//! `validate-smoke` job gates it against [`MAX_REL_ERR`], the same
//! bound `rust/tests/traffic_vs_gpusim.rs` pins (the analytic spill
//! model is deliberately simple, so agreement is ballpark — within
//! 2.5x either way — not exact).

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::workload::models::{Dnn, Phase};
use crate::workload::traffic::{TrafficModel, WorkloadStats};

use super::gpu::simulate_dnn;
use super::GpuConfig;

/// Documented ceiling on per-cell relative DRAM-transaction error —
/// |sim - analytic| / analytic <= 1.5 corresponds to the 0.4x..2.5x
/// agreement band the cross-validation tests pin. CI fails the
/// `validate-smoke` job when any cell exceeds it.
pub const MAX_REL_ERR: f64 = 1.5;

const MB: u64 = 1024 * 1024;

/// One validation query: the (dnn, phase, capacity) slice to replay.
#[derive(Clone, Debug)]
pub struct ValidateRequest {
    pub dnns: Vec<String>,
    pub phases: Vec<Phase>,
    pub capacities_mb: Vec<u64>,
    pub batch: usize,
}

impl Default for ValidateRequest {
    /// The smoke slice: the two cheapest zoo networks, inference, the
    /// GTX 1080 Ti's stock 3 MB plus one grown capacity — small enough
    /// for CI, wide enough to exercise the capacity axis.
    fn default() -> Self {
        ValidateRequest {
            dnns: vec!["AlexNet".into(), "SqueezeNet".into()],
            phases: vec![Phase::Inference],
            capacities_mb: vec![3, 8],
            batch: 1,
        }
    }
}

/// One (dnn, phase, capacity) cell of the report.
#[derive(Clone, Debug)]
pub struct ValidateCell {
    pub dnn: &'static str,
    pub phase: Phase,
    pub capacity_mb: u64,
    pub batch: usize,
    /// Analytic GEMM-only DRAM transactions ([`WorkloadStats::dram_total`]).
    pub analytic_dram: u64,
    /// Simulated DRAM transactions over the same schedule.
    pub sim_dram: u64,
    /// |sim - analytic| / analytic.
    pub rel_err: f64,
}

/// The full report: every requested cell plus the bound it is judged
/// against.
#[derive(Clone, Debug)]
pub struct ValidateReport {
    pub cells: Vec<ValidateCell>,
    /// The gate the report was produced under ([`MAX_REL_ERR`]).
    pub bound: f64,
}

impl ValidateReport {
    /// Worst per-cell relative error — the citable headline.
    pub fn max_rel_err(&self) -> f64 {
        self.cells.iter().map(|c| c.rel_err).fold(0.0, f64::max)
    }

    pub fn pass(&self) -> bool {
        self.max_rel_err() <= self.bound
    }
}

/// Analytic DRAM traffic restricted to the GEMM-backed layers — the
/// portion of the network the trace generator schedules.
fn gemm_only_stats(dnn: &Dnn, phase: Phase, batch: usize, l2_bytes: u64) -> WorkloadStats {
    let m = TrafficModel { l2_bytes, ..Default::default() };
    let mut s = WorkloadStats::default();
    for l in &dnn.layers {
        if l.gemm_dims(batch).is_some() {
            s.add(&m.layer_stats(l, phase, batch));
        }
    }
    s
}

/// Run one validation query: replay every requested cell through both
/// substrates and tabulate the disagreement.
pub fn run(req: &ValidateRequest) -> Result<ValidateReport> {
    if req.dnns.is_empty() || req.phases.is_empty() || req.capacities_mb.is_empty() {
        bail!("validate needs at least one dnn, phase and capacity");
    }
    if req.batch == 0 {
        bail!("batch must be >= 1");
    }
    let mut cells = Vec::new();
    for name in &req.dnns {
        let dnn = Dnn::by_name(name)
            .with_context(|| format!("unknown workload '{name}' (not in the zoo)"))?;
        for &phase in &req.phases {
            for &mb in &req.capacities_mb {
                if mb == 0 || mb > 64 {
                    bail!("capacity {mb} MB outside the simulable 1..=64 range");
                }
                let l2 = mb * MB;
                let analytic = gemm_only_stats(&dnn, phase, req.batch, l2);
                let sim = simulate_dnn(GpuConfig::gtx1080ti(l2), &dnn, phase, req.batch);
                let a = analytic.dram_total();
                let s = sim.dram_total();
                let rel_err = (s as f64 - a as f64).abs() / (a.max(1) as f64);
                cells.push(ValidateCell {
                    dnn: dnn.name,
                    phase,
                    capacity_mb: mb,
                    batch: req.batch,
                    analytic_dram: a,
                    sim_dram: s,
                    rel_err,
                });
            }
        }
    }
    Ok(ValidateReport { cells, bound: MAX_REL_ERR })
}

/// Parse a `POST /validate` body. Every field is optional; omitted
/// fields take the smoke-slice defaults.
pub fn request_from_json(j: &Json) -> Result<ValidateRequest> {
    let mut req = ValidateRequest::default();
    if let Some(arr) = j.get("dnns").and_then(Json::as_arr) {
        req.dnns = arr
            .iter()
            .map(|d| d.as_str().map(str::to_string).context("dnns must be strings"))
            .collect::<Result<_>>()?;
    }
    if let Some(arr) = j.get("phases").and_then(Json::as_arr) {
        req.phases = arr
            .iter()
            .map(|p| {
                p.as_str()
                    .context("phases must be strings")
                    .and_then(crate::sweep::spec::parse_phase)
            })
            .collect::<Result<_>>()?;
    }
    if let Some(arr) = j.get("caps_mb").and_then(Json::as_arr) {
        req.capacities_mb = arr
            .iter()
            .map(|c| c.as_u64().context("caps_mb must be positive integers"))
            .collect::<Result<_>>()?;
    }
    if let Some(b) = j.get("batch") {
        req.batch = b.as_usize().context("batch must be a positive integer")?;
    }
    Ok(req)
}

/// Serialize a report (the `/validate` response body and the
/// `deepnvm validate --json` output).
pub fn report_to_json(r: &ValidateReport) -> Json {
    let cells = r
        .cells
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("dnn", Json::Str(c.dnn.to_string()));
            o.set("phase", Json::Str(c.phase.name().to_string()));
            o.set("capacity_mb", Json::Num(c.capacity_mb as f64));
            o.set("batch", Json::Num(c.batch as f64));
            o.set("analytic_dram", Json::Num(c.analytic_dram as f64));
            o.set("sim_dram", Json::Num(c.sim_dram as f64));
            o.set("rel_err", Json::Num(c.rel_err));
            o
        })
        .collect();
    let mut out = Json::obj();
    out.set("cells", Json::Arr(cells));
    out.set("bound", Json::Num(r.bound));
    out.set("max_rel_err", Json::Num(r.max_rel_err()));
    out.set("pass", Json::Bool(r.pass()));
    out
}

/// Human-readable table (the default `deepnvm validate` output).
pub fn render_table(r: &ValidateReport) -> String {
    let mut out = String::new();
    out.push_str("dnn,phase,capacity_mb,batch,analytic_dram,sim_dram,rel_err\n");
    for c in &r.cells {
        out.push_str(&format!(
            "{},{},{},{},{},{},{:.4}\n",
            c.dnn, c.phase.name(), c.capacity_mb, c.batch, c.analytic_dram,
            c.sim_dram, c.rel_err,
        ));
    }
    out.push_str(&format!(
        "max_rel_err {:.4} bound {:.2} -> {}\n",
        r.max_rel_err(),
        r.bound,
        if r.pass() { "PASS" } else { "FAIL" },
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_slice_stays_within_the_documented_bound() {
        let report = run(&ValidateRequest {
            dnns: vec!["SqueezeNet".into()],
            phases: vec![Phase::Inference],
            capacities_mb: vec![3],
            batch: 1,
        })
        .unwrap();
        assert_eq!(report.cells.len(), 1);
        let c = &report.cells[0];
        assert!(c.analytic_dram > 0 && c.sim_dram > 0);
        assert!(
            report.pass(),
            "rel_err {} exceeds the documented bound {}",
            c.rel_err,
            report.bound
        );
    }

    #[test]
    fn report_covers_the_full_request_product_in_order() {
        let report = run(&ValidateRequest {
            dnns: vec!["AlexNet".into(), "SqueezeNet".into()],
            phases: vec![Phase::Inference],
            capacities_mb: vec![2, 8],
            batch: 1,
        })
        .unwrap();
        assert_eq!(report.cells.len(), 4, "dnns x phases x caps");
        let keys: Vec<(&str, u64)> =
            report.cells.iter().map(|c| (c.dnn, c.capacity_mb)).collect();
        assert_eq!(
            keys,
            vec![("AlexNet", 2), ("AlexNet", 8), ("SqueezeNet", 2), ("SqueezeNet", 8)]
        );
        // growing the L2 never increases simulated DRAM traffic
        for w in report.cells.chunks(2) {
            assert!(
                w[1].sim_dram <= w[0].sim_dram,
                "{}: larger L2 must not spill more",
                w[0].dnn
            );
        }
    }

    #[test]
    fn bad_requests_are_typed_errors() {
        assert!(run(&ValidateRequest { dnns: vec![], ..Default::default() }).is_err());
        assert!(run(&ValidateRequest {
            dnns: vec!["NoSuchNet".into()],
            ..Default::default()
        })
        .is_err());
        assert!(run(&ValidateRequest {
            capacities_mb: vec![0],
            ..Default::default()
        })
        .is_err());
        assert!(run(&ValidateRequest { batch: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn json_round_trip_and_rendering() {
        let body = crate::util::json::parse(
            r#"{"dnns": ["SqueezeNet"], "phases": ["inference"],
                "caps_mb": [3], "batch": 1}"#,
        )
        .unwrap();
        let req = request_from_json(&body).unwrap();
        assert_eq!(req.dnns, vec!["SqueezeNet".to_string()]);
        assert_eq!(req.capacities_mb, vec![3]);
        let report = run(&req).unwrap();
        let j = report_to_json(&report);
        assert_eq!(j.get("cells").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("max_rel_err").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("pass").unwrap().as_bool(), Some(report.pass()));
        let table = render_table(&report);
        assert!(table.contains("SqueezeNet,inference,3,1,"));
        assert!(table.lines().count() == 3, "header + 1 cell + summary");
        // defaults fill omitted fields
        let req = request_from_json(&crate::util::json::parse("{}").unwrap()).unwrap();
        assert_eq!(req.phases, vec![Phase::Inference]);
        assert_eq!(req.capacities_mb, vec![3, 8]);
    }
}
