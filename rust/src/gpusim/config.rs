//! GPU configuration (paper Table IV: the GTX 1080 Ti model).

use super::cache::{CacheConfig, WritePolicy};

/// Hierarchy-level configuration of the simulated GPU.
#[derive(Clone, Copy, Debug)]
pub struct GpuConfig {
    /// Streaming multiprocessors (each owns an L1D).
    pub n_sms: usize,
    /// L1 data cache per SM.
    pub l1_bytes: u64,
    pub l1_ways: usize,
    /// Shared L2.
    pub l2_bytes: u64,
    pub l2_ways: usize,
    /// Line size shared by L1/L2 (Table IV: 128 B everywhere).
    pub line_bytes: u64,
    /// DRAM channels (1080 Ti: 11 x 32-bit GDDR5X; modeled as 11).
    pub dram_channels: usize,
    /// DRAM row-buffer (page) size per channel-bank (bytes).
    pub dram_row_bytes: u64,
    /// Banks per DRAM channel.
    pub dram_banks: usize,
    /// Core clock (Hz) — Table IV: 1481 MHz.
    pub core_clock: f64,
}

impl GpuConfig {
    /// GTX 1080 Ti per Table IV, with the L2 capacity as a parameter
    /// (the paper's GPGPU-Sim extension: 3 MB baseline, doubled up to
    /// 24 MB for the iso-area study).
    pub fn gtx1080ti(l2_bytes: u64) -> Self {
        GpuConfig {
            n_sms: 28,
            l1_bytes: 48 * 1024,
            l1_ways: 6,
            l2_bytes,
            l2_ways: 16,
            line_bytes: 128,
            dram_channels: 11,
            dram_row_bytes: 2048,
            dram_banks: 16,
            core_clock: 1481e6,
        }
    }

    pub fn l1_config(&self) -> CacheConfig {
        CacheConfig {
            capacity_bytes: self.l1_bytes,
            line_bytes: self.line_bytes,
            ways: self.l1_ways,
            policy: WritePolicy::ThroughNoAllocate,
        }
    }

    pub fn l2_config(&self) -> CacheConfig {
        CacheConfig {
            capacity_bytes: self.l2_bytes,
            line_bytes: self.line_bytes,
            ways: self.l2_ways,
            policy: WritePolicy::BackAllocate,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_shape() {
        let g = GpuConfig::gtx1080ti(3 * 1024 * 1024);
        assert_eq!(g.n_sms, 28);
        assert_eq!(g.l1_bytes, 48 * 1024);
        assert_eq!(g.l1_ways, 6);
        assert_eq!(g.l2_ways, 16);
        assert_eq!(g.line_bytes, 128);
        // 48KB / (128B * 6) = 64 sets (power of two)
        assert_eq!(g.l1_config().sets(), 64);
        // 3MB / (128 * 16) = 1536 sets — NOT a power of two; the sim
        // pads to the next power of two internally (gpu.rs).
    }
}
