//! Set-associative cache with true-LRU replacement and configurable
//! write policy — the building block for both L1 and L2.

/// Write policy on hits/misses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WritePolicy {
    /// Write-through, no-write-allocate (GPU L1).
    ThroughNoAllocate,
    /// Write-back, write-allocate (GPU L2).
    BackAllocate,
}

/// Static geometry + policy.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub capacity_bytes: u64,
    pub line_bytes: u64,
    pub ways: usize,
    pub policy: WritePolicy,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        (self.capacity_bytes / (self.line_bytes * self.ways as u64)) as usize
    }
}

/// Result of one access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccessResult {
    pub hit: bool,
    /// A dirty victim line's base address that must be written back.
    pub writeback: Option<u64>,
    /// Whether the access allocated a line (miss fill).
    pub filled: bool,
}

/// One cache instance. Flat arrays (tags / stamps / flags) indexed by
/// set*ways + way — no per-set allocation, cache-friendly probes.
#[derive(Clone, Debug)]
pub struct Cache {
    cfg: CacheConfig,
    sets: usize,
    /// log2(line_bytes) — lines are always a power of two, so the
    /// address-to-line division is a shift (perf: the L1 probe runs
    /// once per trace event; see EXPERIMENTS.md §Perf).
    line_shift: u32,
    /// `sets - 1` when `sets` is a power of two (mask indexing).
    set_mask: Option<usize>,
    /// Lemire fastmod magic for the non-power-of-two case:
    /// `line % sets == (((magic * line) as u128 * sets) >> 64)`.
    set_magic: u64,
    tags: Vec<u64>,
    stamps: Vec<u64>,
    valid: Vec<bool>,
    dirty: Vec<bool>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        let sets = cfg.sets();
        assert!(sets > 0, "degenerate cache: {cfg:?}");
        assert!(cfg.line_bytes.is_power_of_two(), "line size must be 2^n");
        // sets need NOT be a power of two: a 3 MB / 16-way / 128 B L2
        // has 1536 sets (modulo indexing, as GPGPU-Sim does).
        let n = sets * cfg.ways;
        Cache {
            line_shift: cfg.line_bytes.trailing_zeros(),
            set_mask: sets.is_power_of_two().then(|| sets - 1),
            set_magic: (u64::MAX / sets as u64).wrapping_add(1),
            cfg,
            sets,
            tags: vec![0; n],
            stamps: vec![0; n],
            valid: vec![false; n],
            dirty: vec![false; n],
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    #[inline]
    fn index(&self, addr: u64) -> (usize, u64) {
        let line = addr >> self.line_shift;
        match self.set_mask {
            Some(mask) => ((line as usize) & mask, line),
            None => {
                // Lemire fastmod (exact for line < 2^64)
                let low = self.set_magic.wrapping_mul(line);
                let set = ((low as u128 * self.sets as u128) >> 64) as usize;
                (set, line)
            }
        }
    }

    /// Probe + update for one access. Returns hit/miss and any dirty
    /// writeback triggered by the fill.
    pub fn access(&mut self, addr: u64, write: bool) -> AccessResult {
        self.tick += 1;
        let (set, line) = self.index(addr);
        let base = set * self.cfg.ways;

        // probe
        for w in 0..self.cfg.ways {
            let i = base + w;
            if self.valid[i] && self.tags[i] == line {
                self.hits += 1;
                self.stamps[i] = self.tick;
                if write && self.cfg.policy == WritePolicy::BackAllocate {
                    self.dirty[i] = true;
                }
                return AccessResult { hit: true, writeback: None, filled: false };
            }
        }
        self.misses += 1;

        // miss: allocate?
        let allocate = match (write, self.cfg.policy) {
            (true, WritePolicy::ThroughNoAllocate) => false,
            _ => true,
        };
        if !allocate {
            return AccessResult { hit: false, writeback: None, filled: false };
        }

        // victim: invalid way first, else LRU
        let mut victim = base;
        let mut oldest = u64::MAX;
        for w in 0..self.cfg.ways {
            let i = base + w;
            if !self.valid[i] {
                victim = i;
                break;
            }
            if self.stamps[i] < oldest {
                oldest = self.stamps[i];
                victim = i;
            }
        }
        let writeback = if self.valid[victim] && self.dirty[victim] {
            Some(self.tags[victim] * self.cfg.line_bytes)
        } else {
            None
        };
        self.valid[victim] = true;
        self.tags[victim] = line;
        self.stamps[victim] = self.tick;
        self.dirty[victim] = write && self.cfg.policy == WritePolicy::BackAllocate;
        AccessResult { hit: false, writeback, filled: true }
    }

    /// Flush: count of dirty lines (end-of-simulation writeback burst).
    pub fn dirty_lines(&self) -> u64 {
        self.dirty
            .iter()
            .zip(&self.valid)
            .filter(|(d, v)| **d && **v)
            .count() as u64
    }

    pub fn hit_rate(&self) -> f64 {
        self.hits as f64 / (self.hits + self.misses).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn small(policy: WritePolicy) -> Cache {
        Cache::new(CacheConfig {
            capacity_bytes: 4 * 128 * 2, // 4 sets x 2 ways x 128B
            line_bytes: 128,
            ways: 2,
            policy,
        })
    }

    #[test]
    fn repeat_access_hits() {
        let mut c = small(WritePolicy::BackAllocate);
        assert!(!c.access(0x1000, false).hit);
        assert!(c.access(0x1000, false).hit);
        assert!(c.access(0x1040, false).hit, "same 128B line");
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = small(WritePolicy::BackAllocate);
        // set 0: lines 0, 4, 8 (stride = sets*line = 512)
        c.access(0, false);
        c.access(512, false);
        c.access(0, false); // refresh line 0
        let r = c.access(1024, false); // evicts 512 (older)
        assert!(!r.hit);
        assert!(c.access(0, false).hit, "line 0 must survive");
        assert!(!c.access(512, false).hit, "line 512 was evicted");
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut c = small(WritePolicy::BackAllocate);
        c.access(0, true); // dirty
        c.access(512, false);
        let r = c.access(1024, false); // evicts line 0 (dirty)
        assert_eq!(r.writeback, Some(0));
    }

    #[test]
    fn write_through_does_not_allocate() {
        let mut c = small(WritePolicy::ThroughNoAllocate);
        let r = c.access(0x2000, true);
        assert!(!r.hit && !r.filled);
        // a read of the same line still misses (nothing was cached)
        assert!(!c.access(0x2000, false).hit);
        // but a write to a line present from a read hits
        c.access(0x3000, false);
        assert!(c.access(0x3000, true).hit);
    }

    #[test]
    fn dirty_lines_counted() {
        let mut c = small(WritePolicy::BackAllocate);
        c.access(0, true);
        c.access(512, true);
        c.access(128, false);
        assert_eq!(c.dirty_lines(), 2);
    }

    #[test]
    fn write_allocate_marks_dirty_and_clean_evictions_are_silent() {
        let mut c = small(WritePolicy::BackAllocate);
        // write miss allocates the line and marks it dirty
        let r = c.access(0, true);
        assert!(!r.hit && r.filled && r.writeback.is_none());
        assert_eq!(c.dirty_lines(), 1);
        // a read fill is clean: evicting it later must stay silent
        c.access(512, false);
        c.access(0, true); // refresh line 0, leaving 512 as LRU victim
        let r = c.access(1024, false); // evicts clean line 512
        assert!(!r.hit && r.filled);
        assert_eq!(r.writeback, None, "clean victims are silent");
        assert_eq!(c.dirty_lines(), 1, "the dirty line survives the eviction");
    }

    #[test]
    fn write_through_never_accumulates_dirty_lines() {
        let mut c = small(WritePolicy::ThroughNoAllocate);
        // read-allocate then write-hit: the line stays clean (the
        // write went through to the next level)
        c.access(0x3000, false);
        assert!(c.access(0x3000, true).hit);
        c.access(0x4000, true); // write miss: no allocation either
        assert_eq!(c.dirty_lines(), 0, "write-through lines are never dirty");
        // and evictions from a write-through cache never write back
        for i in 0..16u64 {
            assert_eq!(c.access(i * 512, false).writeback, None);
        }
    }

    #[test]
    fn lru_eviction_order_is_exact_over_repeated_conflict_fills() {
        let mut c = small(WritePolicy::BackAllocate);
        // conflict chain in set 0 (stride 512): with 2 ways, each fill
        // beyond the second evicts exactly the least recently touched
        c.access(0, false);
        c.access(512, false);
        c.access(1024, false); // evicts 0
        assert!(!c.access(0, false).hit, "0 was the LRU victim"); // evicts 512
        assert!(!c.access(512, false).hit, "512 rotated out next"); // evicts 1024
        assert!(!c.access(1024, false).hit, "1024 rotated out in turn");
        // the two most recently filled lines survive
        assert!(c.access(512, false).hit);
        assert!(c.access(1024, false).hit);
    }

    #[test]
    fn dirty_eviction_writes_back_victim_base_address() {
        let mut c = small(WritePolicy::BackAllocate);
        c.access(512, true); // dirty line at 512
        c.access(0, false);
        let r = c.access(1024, false); // evicts 512
        assert_eq!(r.writeback, Some(512), "writeback carries the victim base");
        assert_eq!(c.dirty_lines(), 0, "an evicted dirty line leaves the count");
    }

    #[test]
    fn prop_working_set_within_capacity_always_hits_after_warmup() {
        proptest::check(30, |g| {
            let ways = *g.choose(&[2usize, 4, 8]);
            let sets = *g.choose(&[4usize, 16, 64]);
            let line = 128u64;
            let mut c = Cache::new(CacheConfig {
                capacity_bytes: line * ways as u64 * sets as u64,
                line_bytes: line,
                ways,
                policy: WritePolicy::BackAllocate,
            });
            // working set = exactly capacity lines
            let n_lines = (sets * ways) as u64;
            for pass in 0..3 {
                for i in 0..n_lines {
                    let r = c.access(i * line, false);
                    if pass > 0 {
                        assert!(r.hit, "pass {pass}, line {i}");
                    }
                }
            }
        });
    }

    #[test]
    fn prop_hits_plus_misses_equals_accesses() {
        proptest::check(20, |g| {
            let mut c = small(WritePolicy::BackAllocate);
            let n = g.usize_in(1, 2000);
            for _ in 0..n {
                let addr = g.u64_in(0, 1 << 14);
                c.access(addr, g.bool());
            }
            assert_eq!(c.hits + c.misses, n as u64);
        });
    }
}
