//! Analytic L2/DRAM transaction model (the nvprof substitute).
//!
//! Every conv/fc layer executes as im2col + tiled GEMM with supertile
//! reuse (the schedule of the L1 Pallas kernel, scaled to the GPU's SM
//! tiling). L2 transactions are the block loads/stores that miss the
//! SM-local storage:
//!
//! ```text
//! GEMM (M x K) @ (K x N), supertile T = 128:
//!   A (im2col activations) streamed ceil(N/T) times -> M*K*ceil(N/T) reads
//!   B (weights)            streamed ceil(M/T) times -> K*N*ceil(M/T) reads
//!   C (outputs)            written once             -> M*N writes
//!   im2col buffer          written + implicit read  -> M*K writes (Caffe
//!                          materializes im2col; its read IS the A stream)
//! ```
//!
//! Training = forward + two backward GEMMs (dX = dY Bᵀ, dW = Aᵀ dY) at
//! the training batch + a weight-update pass (read W, read dW, write W).
//!
//! This structure reproduces the paper's aggregate observations without
//! per-network tuning: reads carry ~83% of SRAM dynamic energy; training
//! becomes *more* read-dominant as batch grows (the ceil(M/T) weight
//! re-streaming term); inference read/write ratio *falls* as batch grows
//! (weight reads amortize while activation writes scale).
//!
//! DRAM transactions: compulsory weight + input streaming plus capacity
//! spills of the layer working set against the L2 (validated against
//! the gpusim hierarchy simulation in rust/tests/traffic_vs_gpusim.rs).

use super::models::{Dnn, Layer, Phase};

/// Bytes per L2/DRAM transaction (32 B sectors, as nvprof counts).
pub const TX_BYTES: u64 = 32;
/// Bytes per fp32 element.
const ELEM: u64 = 4;
/// Supertile edge: the effective SM-level reuse tile (the thread-block
/// C-tile of Pascal-class SGEMM).
const SUPERTILE: u64 = 128;

/// Memory statistics for one workload execution (whole network, one
/// batch through one phase).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkloadStats {
    pub l2_reads: u64,
    pub l2_writes: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub macs: u64,
}

impl WorkloadStats {
    pub fn add(&mut self, o: &WorkloadStats) {
        self.l2_reads += o.l2_reads;
        self.l2_writes += o.l2_writes;
        self.dram_reads += o.dram_reads;
        self.dram_writes += o.dram_writes;
        self.macs += o.macs;
    }

    /// Read/write transaction ratio.
    pub fn rw_ratio(&self) -> f64 {
        self.l2_reads as f64 / self.l2_writes.max(1) as f64
    }

    pub fn dram_total(&self) -> u64 {
        self.dram_reads + self.dram_writes
    }
}

/// The model, parameterized by the cache it runs against (capacity
/// affects DRAM spill traffic only — L2 transaction counts are a
/// property of the kernel schedule, as in the nvprof counters).
#[derive(Clone, Copy, Debug)]
pub struct TrafficModel {
    /// L2 capacity used for the spill model (bytes).
    pub l2_bytes: u64,
    /// Whether im2col buffers are materialized through L2 (Caffe: yes).
    pub materialize_im2col: bool,
}

impl Default for TrafficModel {
    fn default() -> Self {
        TrafficModel { l2_bytes: 3 * 1024 * 1024, materialize_im2col: true }
    }
}

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// One GEMM's L2 traffic in transactions.
fn gemm_l2(m: u64, k: u64, n: u64, im2col: bool) -> (u64, u64) {
    let pa = ceil_div(n, SUPERTILE);
    let pb = ceil_div(m, SUPERTILE);
    let read_elems = m * k * pa + k * n * pb;
    let mut write_elems = m * n;
    if im2col {
        write_elems += m * k;
    }
    (
        ceil_div(read_elems * ELEM, TX_BYTES),
        ceil_div(write_elems * ELEM, TX_BYTES),
    )
}

/// One GEMM's DRAM traffic in transactions, given the L2 capacity:
/// compulsory streaming of operands that live in DRAM (weights, input
/// activations if they spilled from the previous layer) plus re-fetch
/// of streams whose reuse interval exceeds the cache.
fn gemm_dram(m: u64, k: u64, n: u64, l2_bytes: u64) -> (u64, u64) {
    let a_bytes = m * k * ELEM;
    let b_bytes = k * n * ELEM;
    let c_bytes = m * n * ELEM;
    let pa = ceil_div(n, SUPERTILE);
    let pb = ceil_div(m, SUPERTILE);

    // Compulsory: each operand enters once; output leaves once (unless
    // consumed on chip — next layer usually reads it back, modeled as
    // that layer's compulsory input read).
    let mut reads = a_bytes + b_bytes;
    let writes = c_bytes;

    // Capacity: if an operand that is re-streamed does not fit in its
    // share of the L2 alongside the streaming partner, each extra pass
    // re-fetches it from DRAM.
    let working = a_bytes + b_bytes;
    if working > l2_bytes {
        // the re-streamed operand misses: charge extra passes for the
        // larger of the two (the one that cannot be held)
        if a_bytes > b_bytes {
            reads += a_bytes.min(a_bytes.saturating_sub(l2_bytes / 2)) * (pa - 1).min(3);
        } else {
            reads += b_bytes.min(b_bytes.saturating_sub(l2_bytes / 2)) * (pb - 1).min(3);
        }
    }
    (ceil_div(reads, TX_BYTES), ceil_div(writes, TX_BYTES))
}

impl TrafficModel {
    /// Traffic of one layer for one phase at batch `b`.
    pub fn layer_stats(&self, layer: &Layer, phase: Phase, b: usize) -> WorkloadStats {
        let mut s = WorkloadStats::default();
        let Some((m, k, n)) = layer.gemm_dims(b) else {
            // pool / eltwise: stream activations through L2 once
            let elems = (b * layer.in_hw * layer.in_hw) as u64
                * layer.cout().max(64) as u64;
            let tx = ceil_div(elems * ELEM, TX_BYTES);
            s.l2_reads = tx;
            s.l2_writes = tx / 2;
            return s;
        };

        // ---- forward ---------------------------------------------------
        // Caffe materializes im2col buffers only for spatial kernels —
        // a 1x1 conv's im2col is the identity and is skipped.
        let spatial = matches!(
            layer.kind,
            super::models::LayerKind::Conv { k, .. } if k > 1
        );
        let (r, w) = gemm_l2(m, k, n, self.materialize_im2col && spatial);
        let (dr, dw) = gemm_dram(m, k, n, self.l2_bytes);
        s.l2_reads += r;
        s.l2_writes += w;
        s.dram_reads += dr;
        s.dram_writes += dw;
        s.macs += m * k * n;

        if phase == Phase::Training {
            // ---- backward: dX = dY (N x K path), dW = (K path) -------
            // dX: (M x N) @ (N x K)
            let (r1, w1) = gemm_l2(m, n, k, false);
            let (dr1, dw1) = gemm_dram(m, n, k, self.l2_bytes);
            // dW: (K x M) @ (M x N)
            let (r2, w2) = gemm_l2(k, m, n, false);
            let (dr2, dw2) = gemm_dram(k, m, n, self.l2_bytes);
            s.l2_reads += r1 + r2;
            s.l2_writes += w1 + w2;
            s.dram_reads += dr1 + dr2;
            s.dram_writes += dw1 + dw2;
            s.macs += 2 * m * k * n;

            // ---- weight update: read W + dW, write W -----------------
            let w_elems = k * n;
            let upd = ceil_div(w_elems * ELEM, TX_BYTES);
            s.l2_reads += 2 * upd;
            s.l2_writes += upd;
        }
        s
    }

    /// Traffic of a whole network at batch `b`.
    pub fn run(&self, dnn: &Dnn, phase: Phase, b: usize) -> WorkloadStats {
        let mut total = WorkloadStats::default();
        for layer in &dnn.layers {
            total.add(&self.layer_stats(layer, phase, b));
        }
        total
    }

    /// Paper-default run: batch 4 (inference) / 64 (training).
    pub fn run_paper(&self, dnn: &Dnn, phase: Phase) -> WorkloadStats {
        self.run(dnn, phase, phase.paper_batch())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::workload::models::Dnn;

    #[test]
    fn reads_dominate_writes_across_zoo() {
        // Paper: reads carry ~83% of (SRAM) dynamic energy; with near-
        // equal per-op energies that is an aggregate R/W of ~3-7x.
        let m = TrafficModel::default();
        let mut ratios = vec![];
        for d in Dnn::zoo() {
            for ph in Phase::ALL {
                let s = m.run_paper(&d, ph);
                ratios.push(s.rw_ratio());
                assert!(
                    s.rw_ratio() > 1.5,
                    "{} {}: R/W {}",
                    d.name,
                    ph.name(),
                    s.rw_ratio()
                );
            }
        }
        let mean = crate::util::stats::mean(&ratios);
        assert!((2.5..9.0).contains(&mean), "aggregate R/W {mean}");
    }

    #[test]
    fn training_heavier_than_inference() {
        let m = TrafficModel::default();
        for d in Dnn::zoo() {
            let i = m.run_paper(&d, Phase::Inference);
            let t = m.run_paper(&d, Phase::Training);
            assert!(t.l2_reads > 3 * i.l2_reads, "{}", d.name);
            assert!(t.macs > 3 * i.macs, "{}", d.name);
        }
    }

    #[test]
    fn training_more_read_dominant_with_batch() {
        // Paper Fig 5: "training workloads become more read dominant
        // as batch size increases".
        let m = TrafficModel::default();
        let d = Dnn::by_name("AlexNet").unwrap();
        let r16 = m.run(&d, Phase::Training, 16).rw_ratio();
        let r256 = m.run(&d, Phase::Training, 256).rw_ratio();
        assert!(r256 > r16, "train R/W: b16 {r16}, b256 {r256}");
    }

    #[test]
    fn inference_rw_ratio_falls_with_batch() {
        // Paper Fig 5: "inference workloads have lower read/write ratio
        // as batch size increases".
        let m = TrafficModel::default();
        let d = Dnn::by_name("AlexNet").unwrap();
        let r1 = m.run(&d, Phase::Inference, 1).rw_ratio();
        let r64 = m.run(&d, Phase::Inference, 64).rw_ratio();
        assert!(r64 < r1, "infer R/W: b1 {r1}, b64 {r64}");
    }

    #[test]
    fn macs_scale_linearly_with_batch() {
        let m = TrafficModel::default();
        let d = Dnn::by_name("VGG-16").unwrap();
        let s1 = m.run(&d, Phase::Inference, 1);
        let s8 = m.run(&d, Phase::Inference, 8);
        assert_eq!(s8.macs, 8 * s1.macs);
        // and match the model zoo's static count
        assert_eq!(s1.macs, d.total_macs());
    }

    #[test]
    fn dram_traffic_below_l2_traffic() {
        let m = TrafficModel::default();
        for d in Dnn::zoo() {
            let s = m.run_paper(&d, Phase::Inference);
            assert!(
                s.dram_total() < s.l2_reads + s.l2_writes,
                "{}: dram {} vs l2 {}",
                d.name,
                s.dram_total(),
                s.l2_reads + s.l2_writes
            );
        }
    }

    #[test]
    fn bigger_l2_never_increases_dram_traffic() {
        proptest::check(40, |g| {
            let zoo = Dnn::zoo();
            let d = g.choose(&zoo);
            let b = g.usize_in(1, 64);
            let ph = *g.choose(&Phase::ALL);
            let small = TrafficModel { l2_bytes: 1 << 20, ..Default::default() };
            let large = TrafficModel { l2_bytes: 24 << 20, ..Default::default() };
            let ds = small.run(d, ph, b).dram_total();
            let dl = large.run(d, ph, b).dram_total();
            assert!(dl <= ds, "{}: dram {} -> {}", d.name, ds, dl);
        });
    }

    #[test]
    fn l2_transactions_independent_of_l2_capacity() {
        // nvprof-counted L2 transactions are requests *arriving* at L2;
        // they are a property of the kernel schedule, not of capacity.
        let a = TrafficModel { l2_bytes: 1 << 20, ..Default::default() };
        let b = TrafficModel { l2_bytes: 16 << 20, ..Default::default() };
        let d = Dnn::by_name("GoogLeNet").unwrap();
        let sa = a.run_paper(&d, Phase::Inference);
        let sb = b.run_paper(&d, Phase::Inference);
        assert_eq!(sa.l2_reads, sb.l2_reads);
        assert_eq!(sa.l2_writes, sb.l2_writes);
    }
}
