//! Analytic L2/DRAM transaction model (the nvprof substitute).
//!
//! Every conv/fc layer executes as im2col + tiled GEMM with supertile
//! reuse (the schedule of the L1 Pallas kernel, scaled to the GPU's SM
//! tiling). L2 transactions are the block loads/stores that miss the
//! SM-local storage:
//!
//! ```text
//! GEMM (M x K) @ (K x N), supertile T = 128:
//!   A (im2col activations) streamed ceil(N/T) times -> M*K*ceil(N/T) reads
//!   B (weights)            streamed ceil(M/T) times -> K*N*ceil(M/T) reads
//!   C (outputs)            written once             -> M*N writes
//!   im2col buffer          written + implicit read  -> M*K writes (Caffe
//!                          materializes im2col; its read IS the A stream)
//! ```
//!
//! Training = forward + two backward GEMMs (dX = dY Bᵀ, dW = Aᵀ dY) at
//! the training batch + a weight-update pass (read W, read dW, write W).
//!
//! This structure reproduces the paper's aggregate observations without
//! per-network tuning: reads carry ~83% of SRAM dynamic energy; training
//! becomes *more* read-dominant as batch grows (the ceil(M/T) weight
//! re-streaming term); inference read/write ratio *falls* as batch grows
//! (weight reads amortize while activation writes scale).
//!
//! DRAM transactions: compulsory weight + input streaming plus capacity
//! spills of the layer working set against the L2 (validated against
//! the gpusim hierarchy simulation in rust/tests/traffic_vs_gpusim.rs).

use super::models::{Dnn, Layer, Phase};

/// Bytes per L2/DRAM transaction (32 B sectors, as nvprof counts).
pub const TX_BYTES: u64 = 32;
/// Bytes per fp32 element.
const ELEM: u64 = 4;
/// Supertile edge: the effective SM-level reuse tile (the thread-block
/// C-tile of Pascal-class SGEMM). Public because it is also the ceil
/// divisor of the closed-form batch terms, which the sweep memo's
/// merge-time sanity gate re-evaluates.
pub const SUPERTILE: u64 = 128;

/// Memory statistics for one workload execution (whole network, one
/// batch through one phase).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkloadStats {
    pub l2_reads: u64,
    pub l2_writes: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub macs: u64,
}

impl WorkloadStats {
    pub fn add(&mut self, o: &WorkloadStats) {
        self.l2_reads += o.l2_reads;
        self.l2_writes += o.l2_writes;
        self.dram_reads += o.dram_reads;
        self.dram_writes += o.dram_writes;
        self.macs += o.macs;
    }

    /// Read/write transaction ratio.
    pub fn rw_ratio(&self) -> f64 {
        self.l2_reads as f64 / self.l2_writes.max(1) as f64
    }

    pub fn dram_total(&self) -> u64 {
        self.dram_reads + self.dram_writes
    }
}

/// The model, parameterized by the cache it runs against (capacity
/// affects DRAM spill traffic only — L2 transaction counts are a
/// property of the kernel schedule, as in the nvprof counters).
#[derive(Clone, Copy, Debug)]
pub struct TrafficModel {
    /// L2 capacity used for the spill model (bytes).
    pub l2_bytes: u64,
    /// Whether im2col buffers are materialized through L2 (Caffe: yes).
    pub materialize_im2col: bool,
}

impl Default for TrafficModel {
    fn default() -> Self {
        TrafficModel { l2_bytes: 3 * 1024 * 1024, materialize_im2col: true }
    }
}

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// One GEMM's L2 traffic in transactions.
fn gemm_l2(m: u64, k: u64, n: u64, im2col: bool) -> (u64, u64) {
    let pa = ceil_div(n, SUPERTILE);
    let pb = ceil_div(m, SUPERTILE);
    let read_elems = m * k * pa + k * n * pb;
    let mut write_elems = m * n;
    if im2col {
        write_elems += m * k;
    }
    (
        ceil_div(read_elems * ELEM, TX_BYTES),
        ceil_div(write_elems * ELEM, TX_BYTES),
    )
}

/// One GEMM's DRAM traffic in transactions, given the L2 capacity:
/// compulsory streaming of operands that live in DRAM (weights, input
/// activations if they spilled from the previous layer) plus re-fetch
/// of streams whose reuse interval exceeds the cache.
fn gemm_dram(m: u64, k: u64, n: u64, l2_bytes: u64) -> (u64, u64) {
    let a_bytes = m * k * ELEM;
    let b_bytes = k * n * ELEM;
    let c_bytes = m * n * ELEM;
    let pa = ceil_div(n, SUPERTILE);
    let pb = ceil_div(m, SUPERTILE);

    // Compulsory: each operand enters once; output leaves once (unless
    // consumed on chip — next layer usually reads it back, modeled as
    // that layer's compulsory input read).
    let mut reads = a_bytes + b_bytes;
    let writes = c_bytes;

    // Capacity: if an operand that is re-streamed does not fit in its
    // share of the L2 alongside the streaming partner, each extra pass
    // re-fetches it from DRAM.
    let working = a_bytes + b_bytes;
    if working > l2_bytes {
        // the re-streamed operand misses: charge extra passes for the
        // larger of the two (the one that cannot be held)
        if a_bytes > b_bytes {
            reads += a_bytes.min(a_bytes.saturating_sub(l2_bytes / 2)) * (pa - 1).min(3);
        } else {
            reads += b_bytes.min(b_bytes.saturating_sub(l2_bytes / 2)) * (pb - 1).min(3);
        }
    }
    (ceil_div(reads, TX_BYTES), ceil_div(writes, TX_BYTES))
}

// ---------------------------------------------------------------------
// Closed-form batch axis.
//
// Every quantity above is piecewise-affine in the batch size `b`: the
// GEMM dims are (b*m1, K, N) with only M batch-dependent, so per GEMM
//
//   read_elems(b)  = slope*b + coeff * ceil(m1*b / T)      (T = 128)
//   write_elems(b) = slope*b (+ const)
//   {a,b,c}_bytes(b) = base + slope*b                      (DRAM spill)
//
// The only non-affine piece is the ceil(M/T) weight re-streaming term,
// which [`TxTerm`]/[`DramTerm`] keep symbolic. [`TrafficModel::line`]
// folds a whole (dnn, phase) into one [`BatchLine`] of such terms —
// built once, then evaluated at ANY batch in O(layers) integer folds,
// bit-identical to [`TrafficModel::run`] (each GEMM keeps its own
// transaction rounding, so no ceil is ever merged across GEMMs).
// ---------------------------------------------------------------------

/// One ceil-rounded L2 transaction term, symbolic in the batch size:
///
/// `tx(b) = ceil((base + slope*b + ceil_mult * ceil(ceil_unit*b / T)) * ELEM / TX_BYTES)`
///
/// with `T = SUPERTILE`. This is exactly one GEMM's read or write
/// stream from [`gemm_l2`], with the batch left symbolic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TxTerm {
    pub base: u64,
    pub slope: u64,
    /// Multiplier of the piecewise ceil(M/T) re-streaming term (the
    /// weight-stream pass count); 0 when the GEMM's M is constant.
    pub ceil_mult: u64,
    /// Rows added per batch item (`m1`): the ceil argument is
    /// `ceil_unit * b`.
    pub ceil_unit: u64,
}

impl TxTerm {
    /// Transactions at batch `b`.
    pub fn at(&self, b: u64) -> u64 {
        let elems = self.base
            + self.slope * b
            + self.ceil_mult * ceil_div(self.ceil_unit * b, SUPERTILE);
        ceil_div(elems * ELEM, TX_BYTES)
    }

    /// Real-arithmetic lower bound on [`TxTerm::at`] for every batch
    /// `>= b`: both ceils (the supertile pass count and the final
    /// transaction rounding) are dropped, and every coefficient is
    /// nonnegative, so the affine value at the rectangle's low-batch
    /// corner bounds the whole batch range. The branch-and-bound
    /// optimizer's slice triage rides on this.
    pub fn lower_bound(&self, b: u64) -> f64 {
        let elems = self.base as f64
            + self.slope as f64 * b as f64
            + self.ceil_mult as f64 * self.ceil_unit as f64 * b as f64
                / SUPERTILE as f64;
        elems * ELEM as f64 / TX_BYTES as f64
    }

    /// Whether the term is a batch-independent constant.
    fn is_const(&self) -> bool {
        self.slope == 0 && self.ceil_mult == 0
    }
}

/// One GEMM's DRAM compulsory + capacity-spill traffic, symbolic in the
/// batch size. Operand footprints are affine (`x_base + x_slope*b`
/// bytes); the pass counts stay symbolic exactly as in [`gemm_dram`]:
/// `pa` is constant (the third GEMM dim never carries the batch) and
/// `pb` is `pb_const` or the piecewise `ceil(pb_unit*b / T)`. The L2
/// capacity is an *evaluation-time* parameter — coefficients are
/// capacity-independent, which is what lets one [`BatchLine`] serve
/// every cache size in a sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DramTerm {
    pub a_base: u64,
    pub a_slope: u64,
    pub b_base: u64,
    pub b_slope: u64,
    pub c_base: u64,
    pub c_slope: u64,
    /// A-stream pass count: ceil(N/T), constant.
    pub pa: u64,
    /// B-stream pass count when constant (`pb_unit == 0`).
    pub pb_const: u64,
    /// When non-zero, `pb(b) = ceil(pb_unit*b / T)`.
    pub pb_unit: u64,
}

impl DramTerm {
    /// (read, write) transactions at batch `b` against an L2 of
    /// `l2_bytes` — the same arithmetic as [`gemm_dram`], term for
    /// term.
    pub fn at(&self, b: u64, l2_bytes: u64) -> (u64, u64) {
        let a_bytes = self.a_base + self.a_slope * b;
        let b_bytes = self.b_base + self.b_slope * b;
        let c_bytes = self.c_base + self.c_slope * b;
        let pb = if self.pb_unit == 0 {
            self.pb_const
        } else {
            ceil_div(self.pb_unit * b, SUPERTILE)
        };
        let mut reads = a_bytes + b_bytes;
        let writes = c_bytes;
        if a_bytes + b_bytes > l2_bytes {
            if a_bytes > b_bytes {
                reads += a_bytes.min(a_bytes.saturating_sub(l2_bytes / 2))
                    * (self.pa - 1).min(3);
            } else {
                reads += b_bytes.min(b_bytes.saturating_sub(l2_bytes / 2))
                    * (pb - 1).min(3);
            }
        }
        (ceil_div(reads, TX_BYTES), ceil_div(writes, TX_BYTES))
    }

    /// Compulsory-only `(read, write)` lower bound on [`DramTerm::at`]
    /// for every batch `>= b` and ANY L2 capacity: the capacity-spill
    /// term only ever adds reads and the transaction ceil only rounds
    /// up, so dropping both is admissible no matter where the spill
    /// branch lands.
    pub fn lower_bound(&self, b: u64) -> (f64, f64) {
        let a_bytes = (self.a_base + self.a_slope * b) as f64;
        let b_bytes = (self.b_base + self.b_slope * b) as f64;
        let c_bytes = (self.c_base + self.c_slope * b) as f64;
        ((a_bytes + b_bytes) / TX_BYTES as f64, c_bytes / TX_BYTES as f64)
    }
}

/// A whole network's traffic for one phase, as closed-form batch
/// coefficients: build once per `(dnn, phase)` with
/// [`TrafficModel::line`], evaluate any batch with [`BatchLine::at`] /
/// [`BatchLine::at_capacity`] — bit-identical to re-running the full
/// GEMM lowering at that batch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchLine {
    /// L2 capacity (bytes) [`BatchLine::at`] evaluates spill terms
    /// against — the building model's `l2_bytes`. The coefficients
    /// themselves are capacity-independent.
    pub l2_bytes: u64,
    /// Per-GEMM L2 read terms (one transaction rounding each, exactly
    /// like the direct path).
    pub l2_reads: Vec<TxTerm>,
    /// Per-GEMM L2 write terms.
    pub l2_writes: Vec<TxTerm>,
    /// Pool/eltwise activation streams: reads += tx(b), writes +=
    /// tx(b)/2.
    pub streams: Vec<TxTerm>,
    /// Per-GEMM DRAM terms.
    pub dram: Vec<DramTerm>,
    /// Batch-independent L2 read transactions (training weight
    /// updates and constant-M GEMM streams), prefolded.
    pub const_reads: u64,
    /// Batch-independent L2 write transactions, prefolded.
    pub const_writes: u64,
    /// MACs per batch item (forward, plus backward when training).
    pub macs_slope: u64,
}

impl BatchLine {
    /// Stats at batch `b` against the line's own L2 capacity.
    pub fn at(&self, b: usize) -> WorkloadStats {
        self.at_capacity(b, self.l2_bytes)
    }

    /// Stats at batch `b` against an explicit L2 capacity (the sweep
    /// engine's path: one line per `(dnn, phase)` serves every cache
    /// size on the capacity axis).
    pub fn at_capacity(&self, b: usize, l2_bytes: u64) -> WorkloadStats {
        let b = b as u64;
        let mut s = WorkloadStats {
            l2_reads: self.const_reads,
            l2_writes: self.const_writes,
            macs: self.macs_slope * b,
            ..WorkloadStats::default()
        };
        for t in &self.l2_reads {
            s.l2_reads += t.at(b);
        }
        for t in &self.l2_writes {
            s.l2_writes += t.at(b);
        }
        for t in &self.streams {
            let tx = t.at(b);
            s.l2_reads += tx;
            s.l2_writes += tx / 2;
        }
        for d in &self.dram {
            let (r, w) = d.at(b, l2_bytes);
            s.dram_reads += r;
            s.dram_writes += w;
        }
        s
    }

    /// Admissible lower bound on [`BatchLine::at_capacity`] for every
    /// batch `>= b` and ANY L2 capacity: L2 terms with their ceils
    /// dropped, DRAM reduced to its compulsory stream. Every closed-
    /// form coefficient is nonnegative, so each component is
    /// nondecreasing in the batch — a whole (capacity, batch)
    /// rectangle is bounded by its low-batch corner. The final floor
    /// backs off by one part in 1e9 so f64 rounding can never lift a
    /// bound above the exact integer count it must stay under.
    pub fn lower_bound_at(&self, b: usize) -> WorkloadStats {
        let floor = |x: f64| (x * (1.0 - 1e-9)).max(0.0) as u64;
        let b = b as u64;
        let mut reads = self.const_reads as f64;
        let mut writes = self.const_writes as f64;
        for t in &self.l2_reads {
            reads += t.lower_bound(b);
        }
        for t in &self.l2_writes {
            writes += t.lower_bound(b);
        }
        for t in &self.streams {
            let tx = t.lower_bound(b);
            reads += tx;
            // the exact path adds tx_int / 2 (integer), >= tx/2 - 1/2
            writes += tx / 2.0 - 0.5;
        }
        let mut dram_reads = 0.0;
        let mut dram_writes = 0.0;
        for d in &self.dram {
            let (r, w) = d.lower_bound(b);
            dram_reads += r;
            dram_writes += w;
        }
        WorkloadStats {
            l2_reads: floor(reads),
            l2_writes: floor(writes),
            dram_reads: floor(dram_reads),
            dram_writes: floor(dram_writes),
            macs: self.macs_slope * b,
        }
    }

    fn push_read(&mut self, t: TxTerm) {
        if t.is_const() {
            self.const_reads += t.at(0);
        } else {
            self.l2_reads.push(t);
        }
    }

    fn push_write(&mut self, t: TxTerm) {
        if t.is_const() {
            self.const_writes += t.at(0);
        } else {
            self.l2_writes.push(t);
        }
    }
}

/// A GEMM dimension symbolic in the batch: `value(b) = c + s*b`. Every
/// lowered dim carries the batch wholly or not at all, so exactly one
/// of the two fields is non-zero (enforced by [`dim_mul`]).
#[derive(Clone, Copy, Debug)]
struct Dim {
    c: u64,
    s: u64,
}

const fn con(c: u64) -> Dim {
    Dim { c, s: 0 }
}

const fn lin(s: u64) -> Dim {
    Dim { c: 0, s }
}

/// Product of two symbolic dims; at most one may carry the batch (a
/// quadratic term would mean the lowering changed shape — the debug
/// assert pins that invariant).
fn dim_mul(a: Dim, b: Dim) -> Dim {
    debug_assert!(a.s == 0 || b.s == 0, "batch-quadratic GEMM term");
    Dim { c: a.c * b.c, s: a.c * b.s + a.s * b.c }
}

/// One GEMM's traffic with the batch symbolic: the closed-form twin of
/// [`gemm_l2`] + [`gemm_dram`] for dims `(m, k, n)` where `n` is always
/// batch-free (true for every lowered GEMM: forward, dX and dW).
fn gemm_line(m: Dim, k: Dim, n: u64, im2col: bool) -> (TxTerm, TxTerm, DramTerm) {
    let pa = ceil_div(n, SUPERTILE);
    let mk = dim_mul(m, k);
    let kn = dim_mul(k, con(n));
    let mn = dim_mul(m, con(n));

    // read_elems = m*k*pa + k*n*pb, pb = ceil(m/T)
    let (read, pb_const, pb_unit) = if m.s == 0 {
        let pb = ceil_div(m.c, SUPERTILE);
        (
            TxTerm {
                base: mk.c * pa + kn.c * pb,
                slope: mk.s * pa + kn.s * pb,
                ceil_mult: 0,
                ceil_unit: 0,
            },
            pb,
            0,
        )
    } else {
        // m carries the batch, so k and n do not: k*n is constant and
        // multiplies the symbolic ceil directly.
        debug_assert_eq!(kn.s, 0);
        (
            TxTerm {
                base: mk.c * pa,
                slope: mk.s * pa,
                ceil_mult: kn.c,
                ceil_unit: m.s,
            },
            0,
            m.s,
        )
    };

    // write_elems = m*n (+ m*k for a materialized im2col buffer)
    let w = if im2col {
        Dim { c: mn.c + mk.c, s: mn.s + mk.s }
    } else {
        mn
    };
    let write = TxTerm { base: w.c, slope: w.s, ceil_mult: 0, ceil_unit: 0 };

    let dram = DramTerm {
        a_base: mk.c * ELEM,
        a_slope: mk.s * ELEM,
        b_base: kn.c * ELEM,
        b_slope: kn.s * ELEM,
        c_base: mn.c * ELEM,
        c_slope: mn.s * ELEM,
        pa,
        pb_const,
        pb_unit,
    };
    (read, write, dram)
}

impl TrafficModel {
    /// Lower `(dnn, phase)` into its closed-form batch coefficients —
    /// the one-time cost that makes every batch on the axis an
    /// O(layers) evaluation. `line(d, ph).at(b)` is bit-identical to
    /// `run(d, ph, b)` for every `b` (pinned exhaustively in
    /// `rust/tests/properties.rs`).
    pub fn line(&self, dnn: &Dnn, phase: Phase) -> BatchLine {
        let mut line = BatchLine { l2_bytes: self.l2_bytes, ..BatchLine::default() };
        for layer in &dnn.layers {
            // gemm_dims(b) = (b*m1, k, n): only M carries the batch.
            let Some((m1, k, n)) = layer.gemm_dims(1) else {
                let kappa = (layer.in_hw * layer.in_hw) as u64
                    * layer.cout().max(64) as u64;
                line.streams.push(TxTerm { slope: kappa, ..TxTerm::default() });
                continue;
            };
            let m = lin(m1);
            let spatial = matches!(
                layer.kind,
                super::models::LayerKind::Conv { k, .. } if k > 1
            );
            let (r, w, d) =
                gemm_line(m, con(k), n, self.materialize_im2col && spatial);
            line.push_read(r);
            line.push_write(w);
            line.dram.push(d);
            line.macs_slope += m1 * k * n;

            if phase == Phase::Training {
                // dX: (M x N) @ (N x K); dW: (K x M) @ (M x N)
                let (r1, w1, d1) = gemm_line(m, con(n), k, false);
                let (r2, w2, d2) = gemm_line(con(k), m, n, false);
                line.push_read(r1);
                line.push_read(r2);
                line.push_write(w1);
                line.push_write(w2);
                line.dram.push(d1);
                line.dram.push(d2);
                line.macs_slope += 2 * m1 * k * n;

                // weight update: read W + dW, write W (batch-free)
                let upd = ceil_div(k * n * ELEM, TX_BYTES);
                line.const_reads += 2 * upd;
                line.const_writes += upd;
            }
        }
        line
    }
}

impl TrafficModel {
    /// Traffic of one layer for one phase at batch `b`.
    pub fn layer_stats(&self, layer: &Layer, phase: Phase, b: usize) -> WorkloadStats {
        let mut s = WorkloadStats::default();
        let Some((m, k, n)) = layer.gemm_dims(b) else {
            // pool / eltwise: stream activations through L2 once
            let elems = (b * layer.in_hw * layer.in_hw) as u64
                * layer.cout().max(64) as u64;
            let tx = ceil_div(elems * ELEM, TX_BYTES);
            s.l2_reads = tx;
            s.l2_writes = tx / 2;
            return s;
        };

        // ---- forward ---------------------------------------------------
        // Caffe materializes im2col buffers only for spatial kernels —
        // a 1x1 conv's im2col is the identity and is skipped.
        let spatial = matches!(
            layer.kind,
            super::models::LayerKind::Conv { k, .. } if k > 1
        );
        let (r, w) = gemm_l2(m, k, n, self.materialize_im2col && spatial);
        let (dr, dw) = gemm_dram(m, k, n, self.l2_bytes);
        s.l2_reads += r;
        s.l2_writes += w;
        s.dram_reads += dr;
        s.dram_writes += dw;
        s.macs += m * k * n;

        if phase == Phase::Training {
            // ---- backward: dX = dY (N x K path), dW = (K path) -------
            // dX: (M x N) @ (N x K)
            let (r1, w1) = gemm_l2(m, n, k, false);
            let (dr1, dw1) = gemm_dram(m, n, k, self.l2_bytes);
            // dW: (K x M) @ (M x N)
            let (r2, w2) = gemm_l2(k, m, n, false);
            let (dr2, dw2) = gemm_dram(k, m, n, self.l2_bytes);
            s.l2_reads += r1 + r2;
            s.l2_writes += w1 + w2;
            s.dram_reads += dr1 + dr2;
            s.dram_writes += dw1 + dw2;
            s.macs += 2 * m * k * n;

            // ---- weight update: read W + dW, write W -----------------
            let w_elems = k * n;
            let upd = ceil_div(w_elems * ELEM, TX_BYTES);
            s.l2_reads += 2 * upd;
            s.l2_writes += upd;
        }
        s
    }

    /// Traffic of a whole network at batch `b`.
    pub fn run(&self, dnn: &Dnn, phase: Phase, b: usize) -> WorkloadStats {
        let mut total = WorkloadStats::default();
        for layer in &dnn.layers {
            total.add(&self.layer_stats(layer, phase, b));
        }
        total
    }

    /// Paper-default run: batch 4 (inference) / 64 (training).
    pub fn run_paper(&self, dnn: &Dnn, phase: Phase) -> WorkloadStats {
        self.run(dnn, phase, phase.paper_batch())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::workload::models::Dnn;

    #[test]
    fn reads_dominate_writes_across_zoo() {
        // Paper: reads carry ~83% of (SRAM) dynamic energy; with near-
        // equal per-op energies that is an aggregate R/W of ~3-7x.
        let m = TrafficModel::default();
        let mut ratios = vec![];
        for d in Dnn::zoo() {
            for ph in Phase::ALL {
                let s = m.run_paper(&d, ph);
                ratios.push(s.rw_ratio());
                assert!(
                    s.rw_ratio() > 1.5,
                    "{} {}: R/W {}",
                    d.name,
                    ph.name(),
                    s.rw_ratio()
                );
            }
        }
        let mean = crate::util::stats::mean(&ratios);
        assert!((2.5..9.0).contains(&mean), "aggregate R/W {mean}");
    }

    #[test]
    fn training_heavier_than_inference() {
        let m = TrafficModel::default();
        for d in Dnn::zoo() {
            let i = m.run_paper(&d, Phase::Inference);
            let t = m.run_paper(&d, Phase::Training);
            assert!(t.l2_reads > 3 * i.l2_reads, "{}", d.name);
            assert!(t.macs > 3 * i.macs, "{}", d.name);
        }
    }

    #[test]
    fn training_more_read_dominant_with_batch() {
        // Paper Fig 5: "training workloads become more read dominant
        // as batch size increases". Proven on the closed-form fast
        // path (the one batch sweeps actually ride), which the
        // equality asserts tie back to the direct lowering.
        let m = TrafficModel::default();
        let d = Dnn::by_name("AlexNet").unwrap();
        let line = m.line(&d, Phase::Training);
        assert_eq!(line.at(16), m.run(&d, Phase::Training, 16));
        assert_eq!(line.at(256), m.run(&d, Phase::Training, 256));
        let r16 = line.at(16).rw_ratio();
        let r256 = line.at(256).rw_ratio();
        assert!(r256 > r16, "train R/W: b16 {r16}, b256 {r256}");
    }

    #[test]
    fn inference_rw_ratio_falls_with_batch() {
        // Paper Fig 5: "inference workloads have lower read/write ratio
        // as batch size increases" — again on the BatchLine fast path.
        let m = TrafficModel::default();
        let d = Dnn::by_name("AlexNet").unwrap();
        let line = m.line(&d, Phase::Inference);
        assert_eq!(line.at(1), m.run(&d, Phase::Inference, 1));
        assert_eq!(line.at(64), m.run(&d, Phase::Inference, 64));
        let r1 = line.at(1).rw_ratio();
        let r64 = line.at(64).rw_ratio();
        assert!(r64 < r1, "infer R/W: b1 {r1}, b64 {r64}");
    }

    #[test]
    fn batch_line_matches_direct_run_smoke() {
        // Unit-level anchor; the exhaustive zoo x phase x batch x
        // breakpoint suite lives in rust/tests/properties.rs.
        let m = TrafficModel::default();
        let d = Dnn::by_name("GoogLeNet").unwrap();
        for ph in Phase::ALL {
            let line = m.line(&d, ph);
            for b in [1usize, 4, 64, 129] {
                assert_eq!(line.at(b), m.run(&d, ph, b), "{} b{b}", ph.name());
            }
        }
    }

    #[test]
    fn batch_line_coefficients_are_capacity_independent() {
        // Only DRAM spill evaluation sees the L2 capacity: a line built
        // at one capacity must reproduce the direct path at another via
        // at_capacity — the invariant that lets the sweep memo key
        // traffic on (dnn, phase) alone.
        let d = Dnn::by_name("VGG-16").unwrap();
        let line = TrafficModel::default().line(&d, Phase::Training);
        for l2 in [1u64 << 20, 6 << 20, 24 << 20] {
            let direct = TrafficModel { l2_bytes: l2, ..Default::default() };
            assert_eq!(line.at_capacity(32, l2), direct.run(&d, Phase::Training, 32));
        }
    }

    #[test]
    fn batch_line_folds_constants_and_keeps_piecewise_terms() {
        let d = Dnn::by_name("AlexNet").unwrap();
        let m = TrafficModel::default();
        let inf = m.line(&d, Phase::Inference);
        // inference: no weight-update constants, one read term per
        // conv/fc layer, each carrying the symbolic ceil(M/T) stream
        assert_eq!(inf.const_reads, 0);
        assert_eq!(inf.l2_reads.len(), 8, "5 conv + 3 fc");
        assert!(inf.l2_reads.iter().all(|t| t.ceil_unit > 0));
        assert_eq!(inf.streams.len(), 3, "3 pools");
        assert_eq!(inf.macs_slope, d.total_macs());
        // training: dW GEMMs and weight updates contribute constants
        let tr = m.line(&d, Phase::Training);
        assert!(tr.const_reads > 0 && tr.const_writes > 0);
        assert_eq!(tr.macs_slope, 3 * d.total_macs());
        assert_eq!(tr.dram.len(), 3 * 8);
    }

    #[test]
    fn macs_scale_linearly_with_batch() {
        let m = TrafficModel::default();
        let d = Dnn::by_name("VGG-16").unwrap();
        let s1 = m.run(&d, Phase::Inference, 1);
        let s8 = m.run(&d, Phase::Inference, 8);
        assert_eq!(s8.macs, 8 * s1.macs);
        // and match the model zoo's static count
        assert_eq!(s1.macs, d.total_macs());
    }

    #[test]
    fn dram_traffic_below_l2_traffic() {
        let m = TrafficModel::default();
        for d in Dnn::zoo() {
            let s = m.run_paper(&d, Phase::Inference);
            assert!(
                s.dram_total() < s.l2_reads + s.l2_writes,
                "{}: dram {} vs l2 {}",
                d.name,
                s.dram_total(),
                s.l2_reads + s.l2_writes
            );
        }
    }

    #[test]
    fn bigger_l2_never_increases_dram_traffic() {
        proptest::check(40, |g| {
            let zoo = Dnn::zoo();
            let d = g.choose(&zoo);
            let b = g.usize_in(1, 64);
            let ph = *g.choose(&Phase::ALL);
            let small = TrafficModel { l2_bytes: 1 << 20, ..Default::default() };
            let large = TrafficModel { l2_bytes: 24 << 20, ..Default::default() };
            let ds = small.run(d, ph, b).dram_total();
            let dl = large.run(d, ph, b).dram_total();
            assert!(dl <= ds, "{}: dram {} -> {}", d.name, ds, dl);
        });
    }

    #[test]
    fn lower_bound_never_exceeds_exact_stats() {
        // The optimizer's rectangle bound: the ceil-dropped line at the
        // low-batch corner must stay at or below the exact stats for
        // every batch >= b and every capacity.
        proptest::check(60, |g| {
            let zoo = Dnn::zoo();
            let d = g.choose(&zoo);
            let ph = *g.choose(&Phase::ALL);
            let line = TrafficModel::default().line(d, ph);
            let b = g.usize_in(1, 96);
            let hi = b + g.usize_in(0, 64);
            let l2 = *g.choose(&[1u64 << 20, 3 << 20, 24 << 20]);
            let lb = line.lower_bound_at(b);
            let exact = line.at_capacity(hi, l2);
            assert!(lb.l2_reads <= exact.l2_reads, "{} {}", d.name, ph.name());
            assert!(lb.l2_writes <= exact.l2_writes, "{}", d.name);
            assert!(lb.dram_reads <= exact.dram_reads, "{}", d.name);
            assert!(lb.dram_writes <= exact.dram_writes, "{}", d.name);
            assert!(lb.macs <= exact.macs);
        });
    }

    #[test]
    fn l2_transactions_independent_of_l2_capacity() {
        // nvprof-counted L2 transactions are requests *arriving* at L2;
        // they are a property of the kernel schedule, not of capacity.
        let a = TrafficModel { l2_bytes: 1 << 20, ..Default::default() };
        let b = TrafficModel { l2_bytes: 16 << 20, ..Default::default() };
        let d = Dnn::by_name("GoogLeNet").unwrap();
        let sa = a.run_paper(&d, Phase::Inference);
        let sb = b.run_paper(&d, Phase::Inference);
        assert_eq!(sa.l2_reads, sb.l2_reads);
        assert_eq!(sa.l2_writes, sb.l2_writes);
    }
}
