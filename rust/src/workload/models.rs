//! The DNN zoo (paper Table III): AlexNet, GoogLeNet, VGG-16,
//! ResNet-18, SqueezeNet — layer-by-layer, with ImageNet input shapes.
//!
//! Layer tables follow the original papers; unit tests pin the
//! aggregate weight/MAC counts to Table III.

/// Inference or training pass (paper: I / T).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Inference,
    Training,
}

impl Phase {
    pub const ALL: [Phase; 2] = [Phase::Inference, Phase::Training];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Inference => "inference",
            Phase::Training => "training",
        }
    }

    /// Batch size the paper uses for this phase ("batch size 4 for
    /// inference and 64 for training, as is typical in related work").
    pub fn paper_batch(&self) -> usize {
        match self {
            Phase::Inference => 4,
            Phase::Training => 64,
        }
    }
}

/// One layer's compute-relevant configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LayerKind {
    Conv {
        k: usize,
        stride: usize,
        pad: usize,
        cin: usize,
        cout: usize,
        /// Grouped convolution (AlexNet's split layers).
        groups: usize,
    },
    Fc {
        din: usize,
        dout: usize,
    },
    Pool {
        k: usize,
        stride: usize,
    },
    /// Residual / concat junctions move activations but hold no weights.
    Eltwise,
}

/// A layer plus its resolved input spatial size.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Input feature-map height=width (square activations).
    pub in_hw: usize,
    /// Output feature-map height=width.
    pub out_hw: usize,
}

impl Layer {
    /// Weight parameter count.
    pub fn weights(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { k, cin, cout, groups, .. } => {
                (k * k * (cin / groups) * cout + cout) as u64
            }
            LayerKind::Fc { din, dout } => (din * dout + dout) as u64,
            _ => 0,
        }
    }

    /// Multiply-accumulate ops for batch 1 (forward).
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { k, cin, cout, groups, .. } => {
                (self.out_hw * self.out_hw) as u64
                    * (k * k * (cin / groups) * cout) as u64
            }
            LayerKind::Fc { din, dout } => (din * dout) as u64,
            _ => 0,
        }
    }

    /// Output channels (activation depth after this layer).
    pub fn cout(&self) -> usize {
        match self.kind {
            LayerKind::Conv { cout, .. } => cout,
            LayerKind::Fc { dout, .. } => dout,
            _ => 0,
        }
    }

    /// GEMM dimensions (M, K, N) of the lowered layer for batch `b`
    /// (conv via im2col, per the L1 Pallas schedule). Pool/eltwise
    /// return None.
    pub fn gemm_dims(&self, b: usize) -> Option<(u64, u64, u64)> {
        match self.kind {
            LayerKind::Conv { k, cin, cout, groups, .. } => Some((
                (b * self.out_hw * self.out_hw) as u64,
                (k * k * cin / groups) as u64,
                cout as u64,
            )),
            LayerKind::Fc { din, dout } => {
                Some((b as u64, din as u64, dout as u64))
            }
            _ => None,
        }
    }

    /// Input activation elements for batch 1.
    pub fn in_elems(&self, cin_actual: usize) -> u64 {
        (self.in_hw * self.in_hw * cin_actual) as u64
    }
}

/// A full network.
#[derive(Clone, Debug)]
pub struct Dnn {
    pub name: &'static str,
    pub top5_error: f64,
    pub layers: Vec<Layer>,
}

impl Dnn {
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn conv_count(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
            .count()
    }

    pub fn fc_count(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Fc { .. }))
            .count()
    }

    /// All five Table III networks.
    pub fn zoo() -> Vec<Dnn> {
        vec![alexnet(), googlenet(), vgg16(), resnet18(), squeezenet()]
    }

    pub fn by_name(name: &str) -> Option<Dnn> {
        Self::zoo().into_iter().find(|d| d.name.eq_ignore_ascii_case(name))
    }
}

/// Builder that tracks spatial size through the layer stack.
struct Stack {
    layers: Vec<Layer>,
    hw: usize,
}

impl Stack {
    fn new(input_hw: usize) -> Self {
        Stack { layers: vec![], hw: input_hw }
    }

    fn conv(&mut self, name: &str, k: usize, s: usize, p: usize, cin: usize, cout: usize) {
        self.conv_g(name, k, s, p, cin, cout, 1);
    }

    fn conv_g(
        &mut self,
        name: &str,
        k: usize,
        s: usize,
        p: usize,
        cin: usize,
        cout: usize,
        groups: usize,
    ) {
        let out = (self.hw + 2 * p - k) / s + 1;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv { k, stride: s, pad: p, cin, cout, groups },
            in_hw: self.hw,
            out_hw: out,
        });
        self.hw = out;
    }

    /// Conv that does not advance the running spatial size (parallel
    /// branch inside an inception/fire module).
    fn conv_branch(&mut self, name: &str, k: usize, p: usize, cin: usize, cout: usize) {
        let out = (self.hw + 2 * p - k) + 1;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv { k, stride: 1, pad: p, cin, cout, groups: 1 },
            in_hw: self.hw,
            out_hw: out,
        });
    }

    fn pool(&mut self, name: &str, k: usize, s: usize) {
        let out = (self.hw - k) / s + 1;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Pool { k, stride: s },
            in_hw: self.hw,
            out_hw: out,
        });
        self.hw = out;
    }

    /// Ceil-mode pool (Caffe's default), used by GoogLeNet/SqueezeNet.
    fn pool_ceil(&mut self, name: &str, k: usize, s: usize) {
        let out = (self.hw - k + s - 1) / s + 1;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Pool { k, stride: s },
            in_hw: self.hw,
            out_hw: out,
        });
        self.hw = out;
    }

    fn fc(&mut self, name: &str, din: usize, dout: usize) {
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Fc { din, dout },
            in_hw: 1,
            out_hw: 1,
        });
        self.hw = 1;
    }
}

/// AlexNet (Krizhevsky'12), grouped convs as published: 5 conv + 3 fc,
/// 61M weights, 724M MACs.
pub fn alexnet() -> Dnn {
    let mut s = Stack::new(227);
    s.conv("conv1", 11, 4, 0, 3, 96);
    s.pool("pool1", 3, 2);
    s.conv_g("conv2", 5, 1, 2, 96, 256, 2);
    s.pool("pool2", 3, 2);
    s.conv("conv3", 3, 1, 1, 256, 384);
    s.conv_g("conv4", 3, 1, 1, 384, 384, 2);
    s.conv_g("conv5", 3, 1, 1, 384, 256, 2);
    s.pool("pool5", 3, 2);
    s.fc("fc6", 256 * 6 * 6, 4096);
    s.fc("fc7", 4096, 4096);
    s.fc("fc8", 4096, 1000);
    Dnn { name: "AlexNet", top5_error: 16.4, layers: s.layers }
}

/// GoogLeNet (Szegedy'15): 57 conv + 1 fc, ~7M weights, ~1.43G MACs.
pub fn googlenet() -> Dnn {
    let mut s = Stack::new(224);
    s.conv("conv1", 7, 2, 3, 3, 64);
    s.pool_ceil("pool1", 3, 2);
    s.conv("conv2_reduce", 1, 1, 0, 64, 64);
    s.conv("conv2", 3, 1, 1, 64, 192);
    s.pool_ceil("pool2", 3, 2);

    // (name, cin, n1x1, n3r, n3, n5r, n5, pool_proj)
    let inceptions: [(&str, usize, usize, usize, usize, usize, usize, usize); 9] = [
        ("3a", 192, 64, 96, 128, 16, 32, 32),
        ("3b", 256, 128, 128, 192, 32, 96, 64),
        ("4a", 480, 192, 96, 208, 16, 48, 64),
        ("4b", 512, 160, 112, 224, 24, 64, 64),
        ("4c", 512, 128, 128, 256, 24, 64, 64),
        ("4d", 512, 112, 144, 288, 32, 64, 64),
        ("4e", 528, 256, 160, 320, 32, 128, 128),
        ("5a", 832, 256, 160, 320, 32, 128, 128),
        ("5b", 832, 384, 192, 384, 48, 128, 128),
    ];
    for (i, &(nm, cin, n1, n3r, n3, n5r, n5, pp)) in inceptions.iter().enumerate() {
        s.conv_branch(&format!("inc{nm}_1x1"), 1, 0, cin, n1);
        s.conv_branch(&format!("inc{nm}_3x3r"), 1, 0, cin, n3r);
        s.conv_branch(&format!("inc{nm}_3x3"), 3, 1, n3r, n3);
        s.conv_branch(&format!("inc{nm}_5x5r"), 1, 0, cin, n5r);
        s.conv_branch(&format!("inc{nm}_5x5"), 5, 2, n5r, n5);
        s.conv_branch(&format!("inc{nm}_pool_proj"), 1, 0, cin, pp);
        // spatial reductions after 3b and 4e
        if nm == "3b" || nm == "4e" {
            s.pool_ceil(&format!("pool_after_{nm}"), 3, 2);
        }
        let _ = i;
    }
    s.pool("pool5_avg", 7, 1);
    s.fc("fc", 1024, 1000);
    Dnn { name: "GoogLeNet", top5_error: 6.7, layers: s.layers }
}

/// VGG-16 (Simonyan'14): 13 conv + 3 fc, 138M weights, 15.5G MACs.
pub fn vgg16() -> Dnn {
    let mut s = Stack::new(224);
    let blocks: [(usize, usize, usize); 5] = [
        (2, 3, 64),
        (2, 64, 128),
        (3, 128, 256),
        (3, 256, 512),
        (3, 512, 512),
    ];
    for (bi, &(n, cin, cout)) in blocks.iter().enumerate() {
        for li in 0..n {
            let ci = if li == 0 { cin } else { cout };
            s.conv(&format!("conv{}_{}", bi + 1, li + 1), 3, 1, 1, ci, cout);
        }
        s.pool(&format!("pool{}", bi + 1), 2, 2);
    }
    s.fc("fc6", 512 * 7 * 7, 4096);
    s.fc("fc7", 4096, 4096);
    s.fc("fc8", 4096, 1000);
    Dnn { name: "VGG-16", top5_error: 7.3, layers: s.layers }
}

/// ResNet-18 (He'16), identity-shortcut variant the paper's Table III
/// counts (17 conv + 1 fc, 11.8M weights, ~2G MACs; projection
/// shortcuts folded into eltwise junctions).
pub fn resnet18() -> Dnn {
    let mut s = Stack::new(224);
    s.conv("conv1", 7, 2, 3, 3, 64);
    s.pool("pool1", 3, 2);
    let stages: [(usize, usize, usize); 4] =
        [(64, 64, 1), (64, 128, 2), (128, 256, 2), (256, 512, 2)];
    for (si, &(cin, cout, stride1)) in stages.iter().enumerate() {
        for bi in 0..2 {
            let (ci, st) =
                if bi == 0 { (cin, stride1) } else { (cout, 1) };
            s.conv(&format!("res{}_{}a", si + 2, bi + 1), 3, st, 1, ci, cout);
            s.conv(&format!("res{}_{}b", si + 2, bi + 1), 3, 1, 1, cout, cout);
            s.layers.push(Layer {
                name: format!("res{}_{}_add", si + 2, bi + 1),
                kind: LayerKind::Eltwise,
                in_hw: s.hw,
                out_hw: s.hw,
            });
        }
    }
    s.pool("pool5_avg", 7, 1);
    s.fc("fc", 512, 1000);
    Dnn { name: "ResNet-18", top5_error: 10.71, layers: s.layers }
}

/// SqueezeNet v1.0 (Iandola'16): 26 conv, 0 fc, 1.2M weights, 837M MACs.
pub fn squeezenet() -> Dnn {
    let mut s = Stack::new(227);
    s.conv("conv1", 7, 2, 0, 3, 96);
    s.pool_ceil("pool1", 3, 2);
    // (squeeze, expand) channel plan; input channels tracked manually.
    let fires: [(&str, usize, usize, usize); 8] = [
        ("fire2", 96, 16, 64),
        ("fire3", 128, 16, 64),
        ("fire4", 128, 32, 128),
        ("fire5", 256, 32, 128),
        ("fire6", 256, 48, 192),
        ("fire7", 384, 48, 192),
        ("fire8", 384, 64, 256),
        ("fire9", 512, 64, 256),
    ];
    for &(nm, cin, sq, ex) in &fires {
        s.conv_branch(&format!("{nm}_squeeze"), 1, 0, cin, sq);
        s.conv_branch(&format!("{nm}_e1x1"), 1, 0, sq, ex);
        s.conv_branch(&format!("{nm}_e3x3"), 3, 1, sq, ex);
        if nm == "fire4" || nm == "fire8" {
            s.pool_ceil(&format!("pool_after_{nm}"), 3, 2);
        }
    }
    s.conv("conv10", 1, 1, 0, 512, 1000);
    s.pool("pool10_avg", 13, 1);
    Dnn { name: "SqueezeNet", top5_error: 16.4, layers: s.layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(got: f64, want: f64, tol: f64, what: &str) {
        let err = (got - want).abs() / want;
        assert!(err < tol, "{what}: got {got:.3e}, want {want:.3e} ({:.0}%)", err * 100.0);
    }

    #[test]
    fn table3_alexnet() {
        let d = alexnet();
        assert_eq!(d.conv_count(), 5);
        assert_eq!(d.fc_count(), 3);
        close(d.total_weights() as f64, 61e6, 0.05, "alexnet weights");
        close(d.total_macs() as f64, 724e6, 0.05, "alexnet MACs");
    }

    #[test]
    fn table3_googlenet() {
        let d = googlenet();
        assert_eq!(d.conv_count(), 57);
        assert_eq!(d.fc_count(), 1);
        close(d.total_weights() as f64, 7e6, 0.12, "googlenet weights");
        close(d.total_macs() as f64, 1.43e9, 0.12, "googlenet MACs");
    }

    #[test]
    fn table3_vgg16() {
        let d = vgg16();
        assert_eq!(d.conv_count(), 13);
        assert_eq!(d.fc_count(), 3);
        close(d.total_weights() as f64, 138e6, 0.05, "vgg weights");
        close(d.total_macs() as f64, 15.5e9, 0.05, "vgg MACs");
    }

    #[test]
    fn table3_resnet18() {
        let d = resnet18();
        assert_eq!(d.conv_count(), 17);
        assert_eq!(d.fc_count(), 1);
        close(d.total_weights() as f64, 11.8e6, 0.08, "resnet weights");
        close(d.total_macs() as f64, 2e9, 0.12, "resnet MACs");
    }

    #[test]
    fn table3_squeezenet() {
        let d = squeezenet();
        assert_eq!(d.conv_count(), 26);
        assert_eq!(d.fc_count(), 0);
        close(d.total_weights() as f64, 1.2e6, 0.08, "squeezenet weights");
        close(d.total_macs() as f64, 837e6, 0.08, "squeezenet MACs");
    }

    #[test]
    fn zoo_has_five_networks() {
        let zoo = Dnn::zoo();
        assert_eq!(zoo.len(), 5);
        assert!(Dnn::by_name("vgg-16").is_some());
        assert!(Dnn::by_name("nope").is_none());
    }

    #[test]
    fn gemm_dims_match_macs() {
        // For every conv/fc layer: M*K*N (batch 1) == macs().
        for d in Dnn::zoo() {
            for l in &d.layers {
                if let Some((m, k, n)) = l.gemm_dims(1) {
                    assert_eq!(m * k * n, l.macs(), "{}: {}", d.name, l.name);
                }
            }
        }
    }

    #[test]
    fn spatial_sizes_resolve_to_classifier() {
        // Every network must end at 1x1 spatial (after final pool/fc).
        for d in Dnn::zoo() {
            let last = d.layers.last().unwrap();
            assert_eq!(last.out_hw, 1, "{}: {}", d.name, last.name);
        }
    }

    #[test]
    fn phase_batches_match_paper() {
        assert_eq!(Phase::Inference.paper_batch(), 4);
        assert_eq!(Phase::Training.paper_batch(), 64);
    }
}
