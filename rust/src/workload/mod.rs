//! Architecture-level DL workload modeling (paper §III-C): the five
//! ImageNet DNNs of Table III and their L2/DRAM memory behaviour.
//!
//! The paper obtains memory statistics by profiling Caffe on a real
//! 1080 Ti with nvprof. Neither exists here, so [`traffic`] rebuilds
//! the same statistics analytically from the networks' layer tables:
//! every conv/fc lowers to an im2col + tiled GEMM (exactly the schedule
//! of the L1 Pallas kernel in `python/compile/kernels/matmul.py`), and
//! each block load/store that misses the SM-local storage becomes an L2
//! transaction. [`trace`] turns the same schedule into an address-level
//! trace for the `gpusim` hierarchy simulator, which cross-validates
//! the analytic counts and supplies the iso-area DRAM statistics.

pub mod models;
pub mod trace;
pub mod traffic;

pub use models::{Dnn, Layer, LayerKind, Phase};
pub use traffic::{TrafficModel, WorkloadStats};
