//! Address-level memory-trace generation (the DarkNet-on-GPGPU-Sim
//! substitute).
//!
//! Turns the same tiled-GEMM schedule the analytic [`super::traffic`]
//! model counts into a concrete stream of 32-byte sector accesses with
//! SM affinity, for the `gpusim` hierarchy simulator. Supertiles are
//! assigned round-robin to SMs exactly like thread blocks; within one
//! supertile the A-rows block, B-cols block and C tile are touched in
//! schedule order.
//!
//! Traces are generated lazily (iterator) — a full AlexNet pass is tens
//! of millions of accesses and is never materialized.

use super::models::{Dnn, Phase};

/// Sector size (bytes) of one traced access.
pub const SECTOR: u64 = 32;
/// Supertile edge — must match `traffic::SUPERTILE`.
pub const SUPERTILE: u64 = 128;
/// SMs in the modeled GPU (GTX 1080 Ti: 28).
pub const N_SMS: u16 = 28;

/// One memory access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemAccess {
    pub addr: u64,
    pub write: bool,
    /// Issuing SM (selects the L1).
    pub sm: u16,
}

/// Virtual address-space layout: per-layer regions, 256 MB apart so
/// tensors never alias.
const REGION: u64 = 1 << 28;

/// A GEMM's operand base addresses.
#[derive(Clone, Copy, Debug)]
struct GemmSpace {
    a: u64,
    b: u64,
    c: u64,
}

/// Streamed trace for one GEMM (M x K) @ (K x N).
struct GemmTrace {
    m: u64,
    k: u64,
    n: u64,
    space: GemmSpace,
    im2col: bool,
    // --- cursor state ---
    phase: u8, // 0 = im2col write, 1 = tiles
    pos: u64,  // linear position within the current phase
    tile: u64, // current supertile index
    tile_pos: u64,
    // --- per-tile cache (perf: next() is the hottest loop in the
    // repo; recomputing the div_ceil sector counts per access cost
    // ~25% of trace-generation time — see EXPERIMENTS.md §Perf) ---
    cur_na: u64,
    cur_nb: u64,
    cur_nc: u64,
    cur_a_base: u64,
    cur_b_base: u64,
    cur_c_base: u64,
    cur_sm: u16,
    tile_dirty: bool,
}

impl GemmTrace {
    fn new(m: u64, k: u64, n: u64, space: GemmSpace, im2col: bool) -> Self {
        GemmTrace {
            m,
            k,
            n,
            space,
            im2col,
            phase: if im2col { 0 } else { 1 },
            pos: 0,
            tile: 0,
            tile_pos: 0,
            cur_na: 0,
            cur_nb: 0,
            cur_nc: 0,
            cur_a_base: 0,
            cur_b_base: 0,
            cur_c_base: 0,
            cur_sm: 0,
            tile_dirty: true,
        }
    }

    /// Refresh the per-tile cache for the current `tile` index.
    fn load_tile(&mut self) {
        let is = self.tile / self.pa();
        let js = self.tile % self.pa();
        self.cur_sm = (self.tile % N_SMS as u64) as u16;
        self.cur_na = self.a_sectors(is);
        self.cur_nb = self.b_sectors(js);
        self.cur_nc = self.c_sectors(is, js);
        self.cur_a_base = self.space.a + (is * SUPERTILE) * self.k * 4;
        self.cur_b_base = self.space.b + (js * SUPERTILE) * self.k * 4;
        self.cur_c_base =
            self.space.c + (is * SUPERTILE * self.n + js * SUPERTILE) * 4;
        self.tile_dirty = false;
    }

    fn pa(&self) -> u64 {
        self.n.div_ceil(SUPERTILE)
    }

    fn pb(&self) -> u64 {
        self.m.div_ceil(SUPERTILE)
    }

    /// Sectors in the A block of supertile row `is`: rows x K elements.
    fn a_sectors(&self, is: u64) -> u64 {
        let rows = (self.m - is * SUPERTILE).min(SUPERTILE);
        (rows * self.k * 4).div_ceil(SECTOR)
    }

    fn b_sectors(&self, js: u64) -> u64 {
        let cols = (self.n - js * SUPERTILE).min(SUPERTILE);
        (self.k * cols * 4).div_ceil(SECTOR)
    }

    fn c_sectors(&self, is: u64, js: u64) -> u64 {
        let rows = (self.m - is * SUPERTILE).min(SUPERTILE);
        let cols = (self.n - js * SUPERTILE).min(SUPERTILE);
        (rows * cols * 4).div_ceil(SECTOR)
    }
}

impl Iterator for GemmTrace {
    type Item = MemAccess;

    fn next(&mut self) -> Option<MemAccess> {
        // Phase 0: im2col materialization — stream-write the A region.
        if self.phase == 0 {
            let total = (self.m * self.k * 4).div_ceil(SECTOR);
            if self.pos < total {
                let a = MemAccess {
                    addr: self.space.a + self.pos * SECTOR,
                    write: true,
                    sm: (self.pos % N_SMS as u64) as u16,
                };
                self.pos += 1;
                return Some(a);
            }
            self.phase = 1;
            self.pos = 0;
        }

        // Phase 1: supertile sweep, row-major over (is, js). Per-tile
        // geometry comes from the cached fields (see load_tile).
        let n_tiles = self.pa() * self.pb();
        while self.tile < n_tiles {
            if self.tile_dirty {
                self.load_tile();
            }
            let (na, nb, nc) = (self.cur_na, self.cur_nb, self.cur_nc);
            let sm = self.cur_sm;
            let p = self.tile_pos;
            self.tile_pos += 1;
            if p < na {
                // A rows block: contiguous from the block's base
                return Some(MemAccess {
                    addr: self.cur_a_base + p * SECTOR,
                    write: false,
                    sm,
                });
            } else if p < na + nb {
                // B cols block: B stored col-major so a column block is
                // contiguous (weights are laid out for streaming)
                return Some(MemAccess {
                    addr: self.cur_b_base + (p - na) * SECTOR,
                    write: false,
                    sm,
                });
            } else if p < na + nb + nc {
                return Some(MemAccess {
                    addr: self.cur_c_base + (p - na - nb) * SECTOR,
                    write: true,
                    sm,
                });
            }
            self.tile += 1;
            self.tile_pos = 0;
            self.tile_dirty = true;
        }
        None
    }
}

/// Streamed trace for a whole network execution.
pub struct DnnTrace {
    gemms: Vec<GemmTrace>,
    current: usize,
}

impl DnnTrace {
    /// Build the trace plan for `dnn` at batch `b`. Training appends
    /// the two backward GEMMs per layer.
    pub fn new(dnn: &Dnn, phase: Phase, b: usize) -> Self {
        let mut gemms = Vec::new();
        let mut region = 1u64; // region 0 reserved
        let mut space = || {
            let s = GemmSpace {
                a: region * REGION,
                b: (region + 1) * REGION,
                c: (region + 2) * REGION,
            };
            region += 3;
            s
        };
        for layer in &dnn.layers {
            let Some((m, k, n)) = layer.gemm_dims(b) else { continue };
            // im2col materialized only for spatial kernels (k > 1),
            // matching traffic.rs.
            let im2col = matches!(
                layer.kind,
                super::models::LayerKind::Conv { k, .. } if k > 1
            );
            gemms.push(GemmTrace::new(m, k, n, space(), im2col));
            if phase == Phase::Training {
                gemms.push(GemmTrace::new(m, n, k, space(), false)); // dX
                gemms.push(GemmTrace::new(k, m, n, space(), false)); // dW
            }
        }
        DnnTrace { gemms, current: 0 }
    }

    /// Total accesses without draining the iterator (for sizing).
    pub fn len_estimate(&self) -> u64 {
        self.gemms
            .iter()
            .map(|g| {
                let im2col = if g.im2col {
                    (g.m * g.k * 4).div_ceil(SECTOR)
                } else {
                    0
                };
                let mut tiles = 0;
                for is in 0..g.pb() {
                    for js in 0..g.pa() {
                        tiles +=
                            g.a_sectors(is) + g.b_sectors(js) + g.c_sectors(is, js);
                    }
                }
                im2col + tiles
            })
            .sum()
    }
}

impl Iterator for DnnTrace {
    type Item = MemAccess;

    fn next(&mut self) -> Option<MemAccess> {
        while self.current < self.gemms.len() {
            if let Some(a) = self.gemms[self.current].next() {
                return Some(a);
            }
            self.current += 1;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::Dnn;
    use crate::workload::traffic::TrafficModel;

    #[test]
    fn gemm_trace_counts_match_formula() {
        let g = GemmTrace::new(
            512,
            128,
            512,
            GemmSpace { a: 0, b: REGION, c: 2 * REGION },
            true,
        );
        let (reads, writes): (u64, u64) =
            g.fold((0, 0), |(r, w), a| if a.write { (r, w + 1) } else { (r + 1, w) });
        // pa = pb = 4; A: 512*128*4 elems, B: 128*512*4 elems -> /8 sectors
        assert_eq!(reads, (512 * 128 * 4 + 128 * 512 * 4) * 4 / 32);
        // C once + im2col buffer
        assert_eq!(writes, (512 * 512 + 512 * 128) * 4 / 32);
    }

    #[test]
    fn trace_matches_traffic_model_counts() {
        // The lazy trace and the closed-form model must agree on L2
        // transaction counts for pure-GEMM layers (pool/eltwise are
        // modeled only analytically).
        let d = Dnn::by_name("AlexNet").unwrap();
        let t = DnnTrace::new(&d, Phase::Inference, 1);
        let (mut reads, mut writes) = (0u64, 0u64);
        for a in t {
            if a.write {
                writes += 1;
            } else {
                reads += 1;
            }
        }
        let m = TrafficModel::default();
        let mut gemm_only = crate::workload::traffic::WorkloadStats::default();
        for l in &d.layers {
            if l.gemm_dims(1).is_some() {
                gemm_only.add(&m.layer_stats(l, Phase::Inference, 1));
            }
        }
        // sector rounding differs slightly (per-block vs per-tensor)
        let rerr =
            (reads as f64 - gemm_only.l2_reads as f64).abs() / gemm_only.l2_reads as f64;
        let werr = (writes as f64 - gemm_only.l2_writes as f64).abs()
            / gemm_only.l2_writes as f64;
        assert!(rerr < 0.02, "reads {reads} vs model {}", gemm_only.l2_reads);
        assert!(werr < 0.02, "writes {writes} vs model {}", gemm_only.l2_writes);
    }

    #[test]
    fn len_estimate_is_exact() {
        let d = Dnn::by_name("SqueezeNet").unwrap();
        let t = DnnTrace::new(&d, Phase::Inference, 1);
        let est = t.len_estimate();
        let n = t.count() as u64;
        assert_eq!(est, n);
    }

    #[test]
    fn training_trace_longer_than_inference() {
        let d = Dnn::by_name("ResNet-18").unwrap();
        let i = DnnTrace::new(&d, Phase::Inference, 2).len_estimate();
        let t = DnnTrace::new(&d, Phase::Training, 2).len_estimate();
        assert!(t > 2 * i);
    }

    #[test]
    fn addresses_stay_in_their_regions() {
        let d = Dnn::by_name("SqueezeNet").unwrap();
        for a in DnnTrace::new(&d, Phase::Inference, 1).take(100_000) {
            assert!(a.addr >= REGION);
            assert!(a.sm < N_SMS);
        }
    }
}
