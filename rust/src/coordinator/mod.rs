//! L3 coordination: the framework front end.
//!
//! DeepNVM++'s contribution is the cross-layer methodology, so the
//! coordinator's job is orchestration: a CLI over every experiment
//! ([`cli`]), paper-style report rendering ([`reports`] — one function
//! per table/figure, each returning both a printable table and a CSV),
//! and a results store ([`store`]) that persists every run with its
//! configuration for reproducibility.

pub mod cli;
pub mod reports;
pub mod store;

pub use cli::{run_cli, CliOptions};
pub use reports::Report;
