//! Report generation: one function per paper table/figure. Each
//! returns a [`Report`] containing a rendered text table (what the CLI
//! prints) and a CSV (what the results store persists) so benches,
//! examples and the CLI share one implementation.

use crate::analysis::{area_reuse, iso_area, iso_capacity, mobile, scalability, trend};
use crate::device::{characterize, BitcellParams, MemTech};
use crate::sweep::{self, memo, pareto, SweepSpec};
use crate::util::csv::Csv;
use crate::util::table::{f, Table};
use crate::workload::models::{Dnn, Phase};

const MB: u64 = 1024 * 1024;

/// A rendered experiment artifact.
pub struct Report {
    pub id: &'static str,
    pub title: String,
    pub text: String,
    pub csv: Csv,
}

/// Table I — bitcell parameters from the device-characterization flow,
/// side by side with the paper's published values.
pub fn table1() -> Report {
    let r = characterize::characterize();
    let paper_stt = BitcellParams::paper_stt();
    let paper_sot = BitcellParams::paper_sot();

    let mut t = Table::new(&[
        "parameter",
        "STT model",
        "STT paper",
        "SOT model",
        "SOT paper",
    ])
    .title("Table I: STT/SOT bitcell parameters (device characterization)");
    let mut csv = Csv::new(&["parameter", "stt_model", "stt_paper", "sot_model", "sot_paper"]);
    let mut row = |name: &str, sm: f64, sp: f64, om: f64, op: f64, scale: f64, prec: usize| {
        let cells = [
            name.to_string(),
            f(sm * scale, prec),
            f(sp * scale, prec),
            f(om * scale, prec),
            f(op * scale, prec),
        ];
        t.row(&cells);
        csv.row(&cells);
    };
    row("sense latency (ps)", r.stt.sense_latency, paper_stt.sense_latency, r.sot.sense_latency, paper_sot.sense_latency, 1e12, 0);
    row("sense energy (pJ)", r.stt.sense_energy, paper_stt.sense_energy, r.sot.sense_energy, paper_sot.sense_energy, 1e12, 3);
    row("write latency set (ps)", r.stt.write_latency_set, paper_stt.write_latency_set, r.sot.write_latency_set, paper_sot.write_latency_set, 1e12, 0);
    row("write latency reset (ps)", r.stt.write_latency_reset, paper_stt.write_latency_reset, r.sot.write_latency_reset, paper_sot.write_latency_reset, 1e12, 0);
    row("write energy set (pJ)", r.stt.write_energy_set, paper_stt.write_energy_set, r.sot.write_energy_set, paper_sot.write_energy_set, 1e12, 2);
    row("write energy reset (pJ)", r.stt.write_energy_reset, paper_stt.write_energy_reset, r.sot.write_energy_reset, paper_sot.write_energy_reset, 1e12, 2);
    row("fins (write)", r.stt.fins_write as f64, paper_stt.fins_write as f64, r.sot.fins_write as f64, paper_sot.fins_write as f64, 1.0, 0);
    row("fins (read)", r.stt.fins_read as f64, paper_stt.fins_read as f64, r.sot.fins_read as f64, paper_sot.fins_read as f64, 1.0, 0);
    row("area (norm. to SRAM)", r.stt.area_rel, paper_stt.area_rel, r.sot.area_rel, paper_sot.area_rel, 1.0, 3);
    Report { id: "T1", title: "Table I".into(), text: t.to_string(), csv }
}

/// Table II — EDAP-tuned cache PPA at the iso-capacity and iso-area
/// points.
pub fn table2() -> Report {
    let points: [(&str, MemTech, u64); 5] = [
        ("SRAM 3MB", MemTech::Sram, 3),
        ("STT 3MB (iso-cap)", MemTech::SttMram, 3),
        ("STT 7MB (iso-area)", MemTech::SttMram, 7),
        ("SOT 3MB (iso-cap)", MemTech::SotMram, 3),
        ("SOT 10MB (iso-area)", MemTech::SotMram, 10),
    ];
    let mut t = Table::new(&[
        "design", "RdLat(ns)", "WrLat(ns)", "RdE(nJ)", "WrE(nJ)", "Leak(mW)",
        "Area(mm2)", "org",
    ])
    .title("Table II: cache latency/energy/area (EDAP-optimal configs)");
    let mut csv = Csv::new(&[
        "design", "read_lat_ns", "write_lat_ns", "read_nj", "write_nj",
        "leak_mw", "area_mm2", "org",
    ]);
    for (name, tech, mb) in points {
        let c = memo::tuned(tech, mb * MB);
        let p = c.ppa;
        let cells = [
            name.to_string(),
            f(p.read_latency * 1e9, 2),
            f(p.write_latency * 1e9, 2),
            f(p.read_energy * 1e9, 2),
            f(p.write_energy * 1e9, 2),
            f(p.leakage_power * 1e3, 0),
            f(p.area * 1e6, 2),
            c.org.describe(),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    Report { id: "T2", title: "Table II".into(), text: t.to_string(), csv }
}

/// Table III — DNN configurations (sanity anchor for the zoo).
pub fn table3() -> Report {
    let mut t = Table::new(&["DNN", "top-5 err", "CONV", "FC", "weights", "MACs"])
        .title("Table III: DNN configurations");
    let mut csv = Csv::new(&["dnn", "top5", "conv", "fc", "weights", "macs"]);
    for d in Dnn::zoo() {
        let cells = [
            d.name.to_string(),
            f(d.top5_error, 2),
            d.conv_count().to_string(),
            d.fc_count().to_string(),
            format!("{:.1}M", d.total_weights() as f64 / 1e6),
            format!("{:.2}G", d.total_macs() as f64 / 1e9),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    Report { id: "T3", title: "Table III".into(), text: t.to_string(), csv }
}

/// Fig 1 — NVIDIA L2 capacity trend.
pub fn fig1() -> Report {
    let mut t = Table::new(&["GPU", "year", "L2 (KB)"])
        .title("Fig 1: L2 capacity in recent NVIDIA GPUs");
    let mut csv = Csv::new(&["gpu", "year", "l2_kb"]);
    for (gpu, year, kb) in trend::NVIDIA_L2_TREND {
        let cells = [gpu.to_string(), year.to_string(), kb.to_string()];
        t.row(&cells);
        csv.row(&cells);
    }
    let slope = trend::trend_slope_kb_per_year();
    let mut text = t.to_string();
    text.push_str(&format!("trend: +{slope:.0} KB/year\n"));
    Report { id: "F1", title: "Fig 1".into(), text, csv }
}

/// Figs 3+4 — iso-capacity energy breakdowns and EDP.
pub fn fig3_fig4() -> (Report, Report) {
    let rows = iso_capacity::study();
    let mut t3 = Table::new(&["workload", "tech", "dyn (xSRAM)", "leak (xSRAM)"])
        .title("Fig 3: iso-capacity dynamic & leakage energy (normalized to SRAM)");
    let mut c3 = Csv::new(&["workload", "phase", "tech", "dyn_norm", "leak_norm"]);
    let mut t4 = Table::new(&["workload", "tech", "energy (xSRAM)", "EDP (xSRAM)"])
        .title("Fig 4: iso-capacity total energy & EDP (normalized, DRAM in EDP)");
    let mut c4 = Csv::new(&["workload", "phase", "tech", "energy_norm", "edp_norm"]);
    for r in &rows {
        let wl = format!("{} ({})", r.dnn, if r.phase == Phase::Inference { "I" } else { "T" });
        t3.row(&[wl.clone(), r.tech.name().into(), f(r.dyn_norm, 2), f(r.leak_norm, 3)]);
        c3.row(&[r.dnn.into(), r.phase.name().into(), r.tech.name().into(), f(r.dyn_norm, 4), f(r.leak_norm, 4)]);
        t4.row(&[wl, r.tech.name().into(), f(r.energy_norm, 3), f(r.edp_norm, 3)]);
        c4.row(&[r.dnn.into(), r.phase.name().into(), r.tech.name().into(), f(r.energy_norm, 4), f(r.edp_norm, 4)]);
    }
    // summary lines (the paper's headline averages)
    let (stt_dyn, stt_leak, stt_e, stt_edp) = iso_capacity::summarize(&rows, MemTech::SttMram);
    let (sot_dyn, sot_leak, sot_e, sot_edp) = iso_capacity::summarize(&rows, MemTech::SotMram);
    let mut s3 = t3.to_string();
    s3.push_str(&format!(
        "avg dyn: STT {stt_dyn:.2}x, SOT {sot_dyn:.2}x (paper 2.1x / 1.3x); \
         leak reduction: STT {:.1}x, SOT {:.1}x (paper 5.9x / 10x)\n",
        1.0 / stt_leak,
        1.0 / sot_leak
    ));
    let mut s4 = t4.to_string();
    s4.push_str(&format!(
        "avg energy reduction: STT {:.1}x, SOT {:.1}x (paper 5.1x / 8.6x); \
         best EDP reduction: STT {stt_edp:.1}x, SOT {sot_edp:.1}x (paper 3.8x / 4.7x)\n",
        1.0 / stt_e,
        1.0 / sot_e
    ));
    (
        Report { id: "F3", title: "Fig 3".into(), text: s3, csv: c3 },
        Report { id: "F4", title: "Fig 4".into(), text: s4, csv: c4 },
    )
}

/// Fig 5 — batch-size impact on EDP (AlexNet).
pub fn fig5(batches: &[usize]) -> Report {
    let rows = iso_capacity::batch_study(batches);
    let mut t = Table::new(&["batch", "phase", "tech", "EDP red. (x)"])
        .title("Fig 5: batch-size impact on AlexNet EDP (vs SRAM)");
    let mut csv = Csv::new(&["batch", "phase", "tech", "edp_reduction"]);
    for (b, tech, ph, norm) in rows {
        let cells = [
            b.to_string(),
            ph.name().into(),
            tech.name().into(),
            f(1.0 / norm, 2),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    Report { id: "F5", title: "Fig 5".into(), text: t.to_string(), csv }
}

/// Fig 6 — DRAM access reduction vs L2 capacity (gpusim, AlexNet).
pub fn fig6(batch: usize) -> Report {
    let curve = iso_area::dram_reduction_curve(&[6, 7, 10, 12, 24], batch);
    let mut t = Table::new(&["L2 (MB)", "DRAM reduction (%)"])
        .title(format!("Fig 6: DRAM access reduction vs L2 capacity (AlexNet b={batch})").as_str());
    let mut csv = Csv::new(&["l2_mb", "dram_reduction_pct"]);
    for (mb, red) in curve {
        let cells = [mb.to_string(), f(red, 1)];
        t.row(&cells);
        csv.row(&cells);
    }
    let mut text = t.to_string();
    text.push_str("paper: 14.6% @7MB (STT), 19.8% @10MB (SOT)\n");
    Report { id: "F6", title: "Fig 6".into(), text, csv }
}

/// Figs 7+8 — iso-area energy and EDP.
pub fn fig7_fig8(reductions: Option<(f64, f64)>) -> (Report, Report) {
    let rows = iso_area::study(reductions);
    let mut t7 = Table::new(&["workload", "tech", "dyn (xSRAM)", "leak (xSRAM)"])
        .title("Fig 7: iso-area dynamic & leakage energy (STT 7MB, SOT 10MB)");
    let mut c7 = Csv::new(&["workload", "phase", "tech", "dyn_norm", "leak_norm"]);
    let mut t8 = Table::new(&["workload", "tech", "EDP no-DRAM", "EDP w/ DRAM"])
        .title("Fig 8: iso-area EDP without/with DRAM (normalized to SRAM)");
    let mut c8 = Csv::new(&["workload", "phase", "tech", "edp_no_dram", "edp_with_dram"]);
    for r in &rows {
        let wl = format!("{} ({})", r.dnn, if r.phase == Phase::Inference { "I" } else { "T" });
        t7.row(&[wl.clone(), r.tech.name().into(), f(r.dyn_norm, 2), f(r.leak_norm, 3)]);
        c7.row(&[r.dnn.into(), r.phase.name().into(), r.tech.name().into(), f(r.dyn_norm, 4), f(r.leak_norm, 4)]);
        t8.row(&[wl, r.tech.name().into(), f(r.edp_norm_no_dram, 3), f(r.edp_norm_with_dram, 3)]);
        c8.row(&[r.dnn.into(), r.phase.name().into(), r.tech.name().into(), f(r.edp_norm_no_dram, 4), f(r.edp_norm_with_dram, 4)]);
    }
    let stt_w = iso_area::mean_of(&rows, MemTech::SttMram, |r| r.edp_norm_with_dram);
    let sot_w = iso_area::mean_of(&rows, MemTech::SotMram, |r| r.edp_norm_with_dram);
    let mut s8 = t8.to_string();
    s8.push_str(&format!(
        "avg EDP reduction w/ DRAM: STT {:.2}x, SOT {:.2}x (paper 2x / 2.3x); \
         capacity gain 2.3x / 3.3x\n",
        1.0 / stt_w,
        1.0 / sot_w
    ));
    (
        Report { id: "F7", title: "Fig 7".into(), text: t7.to_string(), csv: c7 },
        Report { id: "F8", title: "Fig 8".into(), text: s8, csv: c8 },
    )
}

/// Fig 9 — cache capacity scaling (area / latency / energy).
pub fn fig9(capacities_mb: &[u64]) -> Report {
    fig9_with(capacities_mb, 0, memo::global()).expect("static fig9 axes expand")
}

/// As [`fig9`] against an explicit worker budget and memo cache — the
/// serve subsystem's report-to-JSON path renders through this, so an
/// HTTP `/sweep` with `"report": "fig9"` emits rows byte-identical to
/// the CLI CSV. Fallible: serve feeds it untrusted capacity axes, and
/// validation errors must become 422s, not panics.
pub fn fig9_with(
    capacities_mb: &[u64],
    jobs: usize,
    memo: &memo::Memo,
) -> anyhow::Result<Report> {
    let sweep = scalability::ppa_sweep_with(capacities_mb, jobs, memo)?;
    let mut t = Table::new(&[
        "tech", "MB", "RdLat(ns)", "WrLat(ns)", "RdE(nJ)", "WrE(nJ)",
        "Leak(mW)", "Area(mm2)",
    ])
    .title("Fig 9: capacity scaling of EDAP-optimal caches");
    let mut csv = Csv::new(&[
        "tech", "mb", "read_lat_ns", "write_lat_ns", "read_nj", "write_nj",
        "leak_mw", "area_mm2",
    ]);
    for c in &sweep {
        let p = c.ppa;
        let cells = [
            c.tech.name().to_string(),
            (c.capacity_bytes / MB).to_string(),
            f(p.read_latency * 1e9, 2),
            f(p.write_latency * 1e9, 2),
            f(p.read_energy * 1e9, 3),
            f(p.write_energy * 1e9, 3),
            f(p.leakage_power * 1e3, 0),
            f(p.area * 1e6, 2),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    Ok(Report { id: "F9", title: "Fig 9".into(), text: t.to_string(), csv })
}

/// Fig 10 — normalized energy/latency/EDP across workloads vs capacity.
pub fn fig10(capacities_mb: &[u64]) -> Report {
    fig10_with(capacities_mb, 0, memo::global()).expect("static fig10 axes expand")
}

/// As [`fig10`] against an explicit worker budget and memo cache
/// (fallible, like [`fig9_with`]).
pub fn fig10_with(
    capacities_mb: &[u64],
    jobs: usize,
    memo: &memo::Memo,
) -> anyhow::Result<Report> {
    let pts = scalability::workload_sweep_with(capacities_mb, jobs, memo)?;
    let mut t = Table::new(&[
        "tech", "MB", "phase", "E (xSRAM)", "±", "T (xSRAM)", "±", "EDP (xSRAM)", "±",
    ])
    .title("Fig 10: scalability, mean ± std across the five workloads");
    let mut csv = Csv::new(&[
        "tech", "mb", "phase", "energy_norm", "energy_std", "latency_norm",
        "latency_std", "edp_norm", "edp_std",
    ]);
    for p in &pts {
        let cells = [
            p.tech.name().to_string(),
            p.capacity_mb.to_string(),
            p.phase.name().to_string(),
            f(p.energy_norm_mean, 3),
            f(p.energy_norm_std, 3),
            f(p.latency_norm_mean, 3),
            f(p.latency_norm_std, 3),
            f(p.edp_norm_mean, 3),
            f(p.edp_norm_std, 3),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    Ok(Report { id: "F10", title: "Fig 10".into(), text: t.to_string(), csv })
}

/// Cross-node scalability report (`deepnvm nodes`): the EDAP-tuned
/// cache at every (node, tech, capacity) with the per-node NVM-vs-SRAM
/// EDAP crossover — the co-optimization view the 7/5 nm calibration
/// lights up (journal extension's scalability axis).
pub fn nodes_report(capacities_mb: &[u64], nodes_nm: &[u32]) -> anyhow::Result<Report> {
    nodes_report_with(capacities_mb, nodes_nm, 0, memo::global())
}

/// As [`nodes_report`] against an explicit worker budget and memo
/// cache (fallible: both axes may arrive from untrusted inputs).
pub fn nodes_report_with(
    capacities_mb: &[u64],
    nodes_nm: &[u32],
    jobs: usize,
    memo: &memo::Memo,
) -> anyhow::Result<Report> {
    let pts = scalability::node_sweep_with(capacities_mb, nodes_nm, jobs, memo)?;
    let mut t = Table::new(&[
        "node", "tech", "MB", "RdLat(ns)", "WrLat(ns)", "Leak(mW)", "Area(mm2)",
        "EDAP",
    ])
    .title("Process-node scaling: EDAP-optimal caches per (node, tech, capacity)");
    let mut csv = Csv::new(&[
        "node_nm", "tech", "mb", "read_lat_ns", "write_lat_ns", "leak_mw",
        "area_mm2", "edap",
    ]);
    for p in &pts {
        let cells = [
            format!("{}nm", p.node_nm),
            p.tech.name().to_string(),
            p.capacity_mb.to_string(),
            f(p.read_latency * 1e9, 2),
            f(p.write_latency * 1e9, 2),
            f(p.leakage_power * 1e3, 0),
            f(p.area * 1e6, 2),
            format!("{:.4e}", p.edap),
        ];
        t.row(&cells);
        csv.row(&[
            p.node_nm.to_string(),
            p.tech.name().to_string(),
            p.capacity_mb.to_string(),
            f(p.read_latency * 1e9, 4),
            f(p.write_latency * 1e9, 4),
            f(p.leakage_power * 1e3, 2),
            f(p.area * 1e6, 4),
            format!("{:.6e}", p.edap),
        ]);
    }
    let mut text = t.to_string();
    text.push_str("NVM-vs-SRAM EDAP crossover per node (smallest winning capacity):\n");
    for x in scalability::nvm_crossovers(&pts) {
        match x.crossover_mb {
            Some(mb) => text.push_str(&format!(
                "  {:>4}nm {:9}  >= {mb} MB\n",
                x.node_nm,
                x.tech.name()
            )),
            None => text.push_str(&format!(
                "  {:>4}nm {:9}  SRAM wins across the swept range\n",
                x.node_nm,
                x.tech.name()
            )),
        }
    }
    Ok(Report { id: "NODES", title: "Process-node scaling".into(), text, csv })
}

/// Extension A (paper §V, implemented): what the freed iso-capacity
/// area buys in compute.
pub fn ext_area_reuse() -> Report {
    let rows = area_reuse::study();
    let mut t = Table::new(&["tech", "freed (mm2)", "SM-equivalents", "mean speedup"])
        .title("Extension: reclaiming the iso-capacity area savings as compute");
    let mut csv = Csv::new(&["tech", "freed_mm2", "sm_equivalents", "mean_speedup"]);
    for r in &rows {
        let cells = [
            r.tech.name().to_string(),
            f(r.freed_mm2, 2),
            f(r.sm_equivalents, 2),
            format!("{:.3}x", r.mean_speedup),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    let mut text = t.to_string();
    text.push_str(
        "finding: at 3MB the whitespace buys a *fraction* of one GP102 SM —\n\
         core-cluster-scale additions, not whole SMs (paper §V left this open)\n",
    );
    Report { id: "X1", title: "Ext: area reuse".into(), text, csv }
}

/// Extension B (paper §V, implemented): mobile LLC design space.
pub fn ext_mobile() -> Report {
    let rows = mobile::study(&[1, 2, 4]);
    let mut t = Table::new(&["LLC (MB)", "DNN", "tech", "E/inf (uJ)", "E (xSRAM)", "EDP (xSRAM)"])
        .title("Extension: mobile inference LLC (batch 1, LPDDR4X)");
    let mut csv = Csv::new(&["llc_mb", "dnn", "tech", "energy_uj", "energy_norm", "edp_norm"]);
    for r in &rows {
        let cells = [
            r.llc_mb.to_string(),
            r.dnn.to_string(),
            r.tech.name().to_string(),
            f(r.energy_per_inference * 1e6, 1),
            f(r.energy_norm, 3),
            f(r.edp_norm, 3),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    Report { id: "X2", title: "Ext: mobile LLC".into(), text: t.to_string(), csv }
}

/// Extension C: hybrid SRAM+STT way-partitioned caches (the §II
/// related-work mitigation, evaluated inside DeepNVM++).
pub fn ext_hybrid() -> Report {
    let sweep = crate::nvsim::hybrid::sweep(MemTech::SttMram, 3 * MB, 0.85);
    let mut t = Table::new(&[
        "SRAM ways", "RdLat(ns)", "WrLat(ns)", "Leak(mW)", "Area(mm2)",
    ])
    .title("Extension: hybrid SRAM+STT way-partitioned 3MB cache (steer 0.85)");
    let mut csv = Csv::new(&["sram_ways", "read_lat_ns", "write_lat_ns", "leak_mw", "area_mm2"]);
    for h in &sweep {
        let cells = [
            h.sram_ways.to_string(),
            f(h.ppa.read_latency * 1e9, 2),
            f(h.ppa.write_latency * 1e9, 2),
            f(h.ppa.leakage_power * 1e3, 0),
            f(h.ppa.area * 1e6, 2),
        ];
        t.row(&cells);
        csv.row(&cells);
    }
    let mut text = t.to_string();
    text.push_str(
        "finding: 2-4 SRAM ways absorb most of STT's write-latency pain at a\n\
         fraction of SRAM's leakage — the [29]-class hybrid result, inside\n\
         DeepNVM++'s calibrated models\n",
    );
    Report { id: "X3", title: "Ext: hybrid cache".into(), text, csv }
}

/// Extension D: relaxed-retention STT (Smullen'11-class volatile STT).
pub fn ext_relaxed() -> Report {
    let pts = crate::device::relaxed::tradeoff(&[25.0, 30.0, 40.0, 55.0, 70.0, 85.0]);
    let mut t = Table::new(&[
        "Delta", "retention", "write lat (ns)", "write E (pJ)", "refresh 3MB (uW)",
    ])
    .title("Extension: relaxed-retention STT (volatility vs write cost)");
    let mut csv = Csv::new(&["delta", "retention_s", "write_lat_ns", "write_pj", "refresh_uw"]);
    for p in &pts {
        let ret = if p.retention_s > 3.15e7 {
            format!("{:.1} yr", p.retention_s / 3.15e7)
        } else if p.retention_s > 1.0 {
            format!("{:.0} s", p.retention_s)
        } else {
            format!("{:.1} ms", p.retention_s * 1e3)
        };
        t.row(&[
            f(p.delta, 0),
            ret,
            f(p.write_latency_s * 1e9, 2),
            f(p.write_energy_j * 1e12, 2),
            f(p.refresh_power_3mb * 1e6, 3),
        ]);
        csv.row(&[
            f(p.delta, 0),
            format!("{:.3e}", p.retention_s),
            f(p.write_latency_s * 1e9, 3),
            f(p.write_energy_j * 1e12, 3),
            f(p.refresh_power_3mb * 1e6, 4),
        ]);
    }
    Report { id: "X4", title: "Ext: relaxed retention".into(), text: t.to_string(), csv }
}

/// `deepnvm sweep` — evaluate an arbitrary design-space grid through
/// the parallel, memoized sweep engine and render it as one report.
/// Rows follow spec order; the `pareto` column marks the
/// EDP/area/capacity frontier (the co-optimization query).
pub fn sweep_report(
    spec: &SweepSpec,
    jobs: usize,
    show_pareto: bool,
) -> anyhow::Result<Report> {
    sweep_report_with(spec, jobs, show_pareto, memo::global())
}

/// As [`sweep_report`] against an explicit memo cache (serve's
/// `POST /sweep` handler reuses the whole report pipeline through
/// this, so HTTP rows are byte-identical to CLI CSV rows).
pub fn sweep_report_with(
    spec: &SweepSpec,
    jobs: usize,
    show_pareto: bool,
    memo: &memo::Memo,
) -> anyhow::Result<Report> {
    let res = sweep::run(spec, jobs, memo)?;
    // Absolute EDP is only comparable within one workload, so the
    // frontier is computed per (dnn, phase, batch) group: "which
    // (tech, capacity) designs are undominated for THIS workload".
    // Circuit-only points form their own area-vs-capacity group.
    let objectives = pareto::edp_area_capacity();
    let mut groups: std::collections::HashMap<
        Option<(&'static str, Phase, usize)>,
        Vec<usize>,
    > = std::collections::HashMap::new();
    for (i, p) in res.points.iter().enumerate() {
        let key = p.point.workload.map(|w| (w.dnn, w.phase, w.batch));
        groups.entry(key).or_default().push(i);
    }
    let mut front: std::collections::HashSet<usize> = std::collections::HashSet::new();
    for indices in groups.values() {
        let members: Vec<crate::sweep::PointResult> =
            indices.iter().map(|&i| res.points[i].clone()).collect();
        for local in pareto::frontier_indices(&members, &objectives) {
            front.insert(indices[local]);
        }
    }

    let mut t = Table::new(&[
        "tech", "MB", "workload", "RdLat(ns)", "WrLat(ns)", "Leak(mW)",
        "Area(mm2)", "E(xSRAM)", "EDP(xSRAM)", "P",
    ])
    .title(
        format!(
            "Design-space sweep: {} grid points ({} distinct cache designs)",
            res.points.len(),
            res.tuned_configs().len()
        )
        .as_str(),
    );
    let mut csv = Csv::new(&[
        "tech", "mb", "node_nm", "dnn", "phase", "batch", "read_lat_ns",
        "write_lat_ns", "read_nj", "write_nj", "leak_mw", "area_mm2",
        "energy_norm", "latency_norm", "edp_norm", "pareto",
    ]);
    for (i, p) in res.points.iter().enumerate() {
        let ppa = p.tuned.ppa;
        let on_front = front.contains(&i);
        let (wl_cell, dnn, phase, batch, e_norm, l_norm, edp_norm) =
            match (p.point.workload, p.eval) {
                (Some(w), Some(e)) => (
                    format!(
                        "{} ({}) b{}",
                        w.dnn,
                        if w.phase == Phase::Inference { "I" } else { "T" },
                        w.batch
                    ),
                    w.dnn.to_string(),
                    w.phase.name().to_string(),
                    w.batch.to_string(),
                    f(e.energy_norm, 4),
                    f(e.latency_norm, 4),
                    f(e.edp_norm, 4),
                ),
                _ => (
                    "-".to_string(),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                ),
            };
        t.row(&[
            p.point.tech.name().to_string(),
            p.point.capacity_mb.to_string(),
            wl_cell,
            f(ppa.read_latency * 1e9, 2),
            f(ppa.write_latency * 1e9, 2),
            f(ppa.leakage_power * 1e3, 0),
            f(ppa.area * 1e6, 2),
            if e_norm.is_empty() { "-".into() } else { f(p.eval.unwrap().energy_norm, 3) },
            if edp_norm.is_empty() { "-".into() } else { f(p.eval.unwrap().edp_norm, 3) },
            if on_front { "*".into() } else { String::new() },
        ]);
        csv.row(&[
            p.point.tech.name().to_string(),
            p.point.capacity_mb.to_string(),
            p.point.node_nm.to_string(),
            dnn,
            phase,
            batch,
            f(ppa.read_latency * 1e9, 4),
            f(ppa.write_latency * 1e9, 4),
            f(ppa.read_energy * 1e9, 4),
            f(ppa.write_energy * 1e9, 4),
            f(ppa.leakage_power * 1e3, 2),
            f(ppa.area * 1e6, 4),
            e_norm,
            l_norm,
            edp_norm,
            if on_front { "1".into() } else { "0".into() },
        ]);
    }

    let mut text = t.to_string();
    if show_pareto {
        text.push_str(
            "Pareto frontier, per workload (min EDP, min area, max capacity):\n",
        );
        let mut idx: Vec<usize> = front.iter().copied().collect();
        idx.sort_unstable();
        for i in idx {
            let p = &res.points[i];
            let wl = match p.point.workload {
                Some(w) => format!("{} {} b{}", w.dnn, w.phase.name(), w.batch),
                None => "circuit".to_string(),
            };
            match p.eval {
                Some(e) => text.push_str(&format!(
                    "  {} {}MB  {}  EDP {:.3e} J*s  area {:.2} mm2  ({:.2}x SRAM EDP)\n",
                    p.point.tech.name(),
                    p.point.capacity_mb,
                    wl,
                    e.edp,
                    p.tuned.ppa.area * 1e6,
                    e.edp_norm,
                )),
                None => text.push_str(&format!(
                    "  {} {}MB  {}  area {:.2} mm2\n",
                    p.point.tech.name(),
                    p.point.capacity_mb,
                    wl,
                    p.tuned.ppa.area * 1e6,
                )),
            }
        }
    }
    Ok(Report { id: "SW", title: "Design-space sweep".into(), text, csv })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_reports_render() {
        for r in [table2(), table3(), fig1()] {
            assert!(!r.text.is_empty());
            assert!(r.csv.n_rows() > 0, "{} empty csv", r.id);
        }
    }

    #[test]
    fn fig5_report_covers_batches() {
        let r = fig5(&[4, 64]);
        // 2 batches x 2 phases x 2 techs
        assert_eq!(r.csv.n_rows(), 8);
    }

    #[test]
    fn fig9_rows_complete() {
        let r = fig9(&[2, 8]);
        assert_eq!(r.csv.n_rows(), 3 * 2);
    }

    #[test]
    fn nodes_report_renders_cross_node_grid() {
        let r = nodes_report_with(&[2, 8], &[16, 7], 1, &memo::Memo::new()).unwrap();
        assert_eq!(r.csv.n_rows(), 2 * 3 * 2, "nodes x techs x caps");
        assert!(r.text.contains("crossover"));
        assert!(r.csv.to_string().lines().any(|l| l.starts_with("7,")));
        // an uncalibrated node axis errors instead of panicking
        assert!(nodes_report_with(&[2], &[9], 1, &memo::Memo::new()).is_err());
    }

    #[test]
    fn sweep_report_renders_grid_and_frontier() {
        let spec = SweepSpec {
            techs: crate::nvsim::TechSel::pures(&[MemTech::Sram, MemTech::SotMram]),
            capacities_mb: vec![1, 2],
            dnns: vec!["AlexNet".into()],
            phases: vec![Phase::Inference],
            batches: vec![],
            nodes_nm: vec![16],
            filters: vec![],
        };
        let r = sweep_report(&spec, 2, true).unwrap();
        assert_eq!(r.csv.n_rows(), 4);
        assert!(r.text.contains("Pareto frontier"));
        // at least one design must be Pareto-optimal
        assert!(r.csv.to_string().lines().any(|l| l.ends_with(",1")));
    }

    #[test]
    fn circuit_only_sweep_report() {
        let spec = SweepSpec::circuit_only(vec![MemTech::SttMram], vec![1, 4]);
        let r = sweep_report(&spec, 1, false).unwrap();
        assert_eq!(r.csv.n_rows(), 2);
        assert!(!r.text.contains("Pareto frontier"));
    }
}
