//! Command-line interface (hand-rolled: the offline vendor set has no
//! clap). `deepnvm <command> [--out DIR] [--quick] [--batches a,b,c]`.

use anyhow::{bail, Context, Result};

use super::reports::{self, Report};
use super::store::Store;
use crate::device::MemTech;
use crate::nvsim::TechSel;
use crate::sweep::spec::{parse_phase, parse_tech_sel};
use crate::sweep::{Filter, SweepSpec};
use crate::workload::models::{Dnn, Phase};

const USAGE: &str = "\
DeepNVM++ — cross-layer NVM modeling for deep learning (TCAD'21 repro)

USAGE: deepnvm <command> [options]

COMMANDS (paper artifacts):
  table1        Bitcell characterization (device sweep vs paper)
  table2        EDAP-tuned cache PPA (iso-capacity + iso-area points)
  table3        DNN zoo configurations
  fig1          NVIDIA L2 capacity trend
  fig3 fig4     Iso-capacity energy / EDP studies
  fig5          Batch-size impact on AlexNet EDP
  fig6          DRAM reduction vs L2 capacity (hierarchy simulation)
  fig7 fig8     Iso-area energy / EDP studies
  fig9 fig10    Scalability sweeps (1-32 MB)
  nodes         Cross-node scalability: EDAP-tuned PPA per process node
                plus the NVM-vs-SRAM crossover point per node
  ext-area      Extension: spend the freed area on compute (paper SSV)
  ext-mobile    Extension: mobile inference LLC design space (paper SSV)
  ext-hybrid    Extension: hybrid SRAM+STT way-partitioned caches (SSII)
  ext-relaxed   Extension: relaxed-retention (volatile) STT (SSII)
  all           Every table and figure (writes CSVs to --out)

DESIGN-SPACE ENGINE:
  sweep         Evaluate any tech x capacity x workload x phase x batch
                grid in parallel, with memoized circuit solves persisted
                to <out>/sweep_memo.json (warm reruns solve nothing).
                The batch axis is closed-form: traffic coefficients are
                lowered once per workload x phase, so wide --batches
                sweeps cost O(batches) folds, not O(batches) lowerings
  optimize      Search the implicit grid instead of sweeping it:
                branch-and-bound with closed-form lower bounds finds
                the argmin of --objective under --area-max/--leak-max
                budgets, bit-identical to the exhaustive answer while
                evaluating a fraction of the grid (--frontier returns
                the Pareto frontier of the feasible set instead)
  serve         Long-lived HTTP server over the same engine: scenario
                queries at cache-hit latency (POST /solve, /sweep,
                /optimize) and shardable memo exchange (GET
                /memo/export, POST /memo/merge, POST /shard/run)
  coordinate    Multi-host scheduler: split a grid into cost-balanced
                shards, assign them to a fleet of `deepnvm serve`
                workers, retry stragglers/dead workers, merge exports,
                and verify a zero-solve full-grid replay. Dispatches
                carry an X-Deepnvm-Trace header; --trace-out writes a
                stitched fleet trace and --status-addr also serves
                GET /scheduler/metrics (federated worker /metrics)
  loadgen       Closed-loop soak harness: drive a mixed
                /solve+/sweep+/optimize workload at a running server
                over keep-alive connections, report QPS and p50/p99,
                and optionally gate on --p99-ms (nonzero exit on
                breach)
  validate      Cross-validate the analytic traffic model against the
                trace-driven hierarchy simulation: replay every
                requested (dnn, phase, capacity) cell through both and
                tabulate per-cell relative DRAM-transaction error
                (nonzero exit when any cell exceeds the documented
                bound)

OTHER:
  e2e-train     Train the TinyCNN artifact via PJRT (needs `make artifacts`)
  help          This message

OPTIONS:
  --out DIR       results directory (default: results)
  --quick         cheaper settings (fig6 batch 1, coarser sweeps)
  --batches LIST  comma-separated batch sizes (fig5 axis; sweep batch axis)
  --steps N       training steps for e2e-train (default 60)
  --trace-out F   on exit, write the run's span timeline as Chrome
                  trace-event JSON to F (open in chrome://tracing; any
                  command except serve, which exposes GET /trace instead;
                  a successful coordinate writes the stitched fleet trace)
  --trace-ring N  span ring capacity (default 65536; or the
                  DEEPNVM_TRACE_RING env var; must precede first span)

SWEEP OPTIONS:
  --techs LIST    sram,stt,sot, or way-partitioned hybrids spelled
                  hybrid-<nvm>:<sram_ways>@<steer> (e.g.
                  hybrid-stt:4@0.85: 4 of 16 ways SRAM, 85% of writes
                  steered to them); default: the three pure techs
  --caps LIST     capacities in MB (default: 1,2,4,8,16,32)
  --dnns LIST     zoo workloads, or 'none' for a circuit-only PPA sweep
  --phases LIST   inference,training (default: both)
  --nodes LIST    process nodes in nm (calibrated: 16,7,5; default: 16) —
                  also the `nodes` report axis
  --jobs N        worker threads (default: one per core)
  --pareto        print the EDP/area/capacity Pareto frontier
  --nvm-only      drop SRAM rows (the baseline is still solved for norms)
  --cold          ignore any on-disk memo cache in --out
  --memo-cap N    LRU-bound the memo's point layer to N entries (keeps
                  sweep_memo.json from growing without limit)

OPTIMIZE OPTIONS (plus the sweep axis flags above):
  --objective O   edp|edap|energy|latency|capacity (default: edp;
                  capacity is maximized, the rest are minimized)
  --area-max MM2  feasibility budget: tuned cache area must not
                  exceed MM2 mm²
  --leak-max W    feasibility budget: tuned leakage power must not
                  exceed W watts
  --frontier      return the EDP/area/capacity Pareto frontier of the
                  feasible set instead of a scalar winner
  --jobs, --out, --cold, --memo-cap as above

SERVE OPTIONS:
  --addr A:P      bind address (default 127.0.0.1:8090; :0 = ephemeral)
  --prewarm       solve the full paper grid before accepting traffic,
                  so steady-state queries perform zero circuit solves
  --auth-key KEY  shared secret (or the DEEPNVM_AUTH_KEY env var): when
                  set, mutating POST routes require a valid
                  X-Deepnvm-Auth HMAC tag (401 `unauthorized` otherwise)
  --queue-cap N   accept-queue bound (default 4x jobs); over-cap
                  connections are shed with 503 + Retry-After
  --jobs, --out, --memo-cap as above

COORDINATE OPTIONS:
  --workers LIST     comma-separated worker addresses (required)
  --spec FILE        SweepSpec JSON file (default: built from the sweep
                     axis flags above)
  --retries N        reassignments allowed per shard (default 3)
  --deadline-secs S  per-shard dispatch deadline (default 120)
  --status-addr A:P  serve GET /scheduler/status and /scheduler/metrics
                     (federated fleet metrics) here during the run
  --auth-key KEY     sign every POST /shard/run with X-Deepnvm-Auth
                     (or the DEEPNVM_AUTH_KEY env var; must match the
                     workers' key)
  --jobs, --out, --cold as above (the merged memo persists to --out)

LOADGEN OPTIONS:
  --addr A:P      target server (default 127.0.0.1:8090)
  --duration S    run length in seconds (default 10)
  --concurrency N worker threads, one keep-alive connection each
                  (default 4)
  --mix SV:SW[:SO] solve:sweep[:optimize] request ratio (default 9:1)
  --hot-frac F    draw fraction F of /solve bodies from the small hot
                  pool (cache-hit path) and 1-F from a 114-key cold
                  tail of hybrid points, reporting per-class p50/p99
  --p99-ms MS     fail (exit 1) when overall p99 exceeds MS
  --auth-key KEY  sign every POST with X-Deepnvm-Auth (or the
                  DEEPNVM_AUTH_KEY env var), for soaking a hardened
                  server

VALIDATE OPTIONS:
  --dnns LIST     workloads to replay (default: AlexNet,SqueezeNet)
  --phases LIST   inference,training (default: inference)
  --caps LIST     L2 capacities in MB, 1..=64 (default: 3,8)
  --batches N     a single batch size (default 1)
  --json          emit the report as JSON instead of the CSV table

EXAMPLE:
  deepnvm sweep --techs stt,sot --caps 2,8,32 --dnns AlexNet,ResNet-18 \\
      --jobs 8 --pareto --out results
  deepnvm serve --addr 0.0.0.0:8090 --prewarm --memo-cap 100000
  deepnvm coordinate --workers host1:8090,host2:8090 --caps 1,2,4,8,16,32 \\
      --status-addr 127.0.0.1:8095 --out results
";

/// Parsed options.
#[derive(Clone, Debug)]
pub struct CliOptions {
    pub command: String,
    pub out: String,
    pub quick: bool,
    pub batches: Vec<usize>,
    /// Whether --batches was given (sweep defaults to paper batches
    /// when it was not).
    pub batches_explicit: bool,
    pub steps: usize,
    // sweep axes (empty = command defaults)
    pub techs: Vec<TechSel>,
    pub caps: Vec<u64>,
    pub dnns: Vec<String>,
    pub phases: Vec<Phase>,
    /// Process-node axis in nm (empty = the 16 nm default).
    pub nodes: Vec<u32>,
    /// Sweep worker threads (0 = one per core).
    pub jobs: usize,
    pub pareto: bool,
    pub nvm_only: bool,
    pub cold: bool,
    /// LRU bound on the memo point layer (`--memo-cap`; sweep + serve).
    pub memo_cap: Option<usize>,
    /// Bind address for `serve`.
    pub addr: String,
    /// Prewarm the full paper grid before `serve` accepts traffic.
    pub prewarm: bool,
    /// Shared secret for serve / coordinate / loadgen (`--auth-key`;
    /// `None` falls back to the `DEEPNVM_AUTH_KEY` env var).
    pub auth_key: Option<String>,
    /// Accept-queue bound for `serve` (`--queue-cap`; `None` = 4x jobs).
    pub queue_cap: Option<usize>,
    /// Worker fleet for `coordinate` (`--workers`).
    pub workers: Vec<String>,
    /// SweepSpec JSON file for `coordinate` (`--spec`); None = build
    /// the spec from the sweep axis flags.
    pub spec_file: Option<String>,
    /// Per-shard reassignment budget for `coordinate`.
    pub retries: usize,
    /// Per-shard dispatch deadline for `coordinate`, in seconds.
    pub deadline_secs: u64,
    /// Status-server bind address for `coordinate` (`--status-addr`).
    pub status_addr: Option<String>,
    /// Write the run's span timeline here as Chrome trace-event JSON
    /// on exit (`--trace-out`).
    pub trace_out: Option<String>,
    /// Span ring capacity (`--trace-ring`); None = the
    /// `DEEPNVM_TRACE_RING` env var or the built-in default.
    pub trace_ring: Option<usize>,
    /// Loadgen run length in seconds (`--duration`).
    pub duration_secs: u64,
    /// Loadgen worker threads (`--concurrency`).
    pub concurrency: usize,
    /// Loadgen solve:sweep[:optimize] ratio (`--mix`).
    pub mix: String,
    /// Loadgen hot-set fraction (`--hot-frac`).
    pub hot_frac: Option<f64>,
    /// Loadgen p99 gate in milliseconds (`--p99-ms`).
    pub p99_ms: Option<f64>,
    /// Emit JSON instead of the human table (`validate --json`).
    pub json: bool,
    /// Search objective for `optimize` (`--objective`).
    pub objective: crate::sweep::OptObjective,
    /// Area budget in mm² for `optimize` (`--area-max`).
    pub area_max: Option<f64>,
    /// Leakage budget in watts for `optimize` (`--leak-max`).
    pub leak_max: Option<f64>,
    /// Pareto-frontier mode for `optimize` (`--frontier`).
    pub frontier: bool,
}

impl Default for CliOptions {
    fn default() -> Self {
        CliOptions {
            command: "help".into(),
            out: "results".into(),
            quick: false,
            batches: vec![1, 4, 16, 64, 128, 256],
            batches_explicit: false,
            steps: 60,
            techs: vec![],
            caps: vec![],
            dnns: vec![],
            phases: vec![],
            nodes: vec![],
            jobs: 0,
            pareto: false,
            nvm_only: false,
            cold: false,
            memo_cap: None,
            addr: "127.0.0.1:8090".into(),
            prewarm: false,
            auth_key: None,
            queue_cap: None,
            workers: vec![],
            spec_file: None,
            retries: 3,
            deadline_secs: 120,
            status_addr: None,
            trace_out: None,
            trace_ring: None,
            duration_secs: 10,
            concurrency: 4,
            mix: "9:1".into(),
            hot_frac: None,
            p99_ms: None,
            json: false,
            objective: crate::sweep::OptObjective::Edp,
            area_max: None,
            leak_max: None,
            frontier: false,
        }
    }
}

fn split_list(v: &str) -> Vec<&str> {
    v.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
}

/// Parse argv (excluding the binary name).
pub fn parse_args(args: &[String]) -> Result<CliOptions> {
    let mut o = CliOptions::default();
    let mut it = args.iter();
    if let Some(cmd) = it.next() {
        o.command = cmd.clone();
    }
    while let Some(a) = it.next() {
        let mut value = || {
            it.next()
                .ok_or_else(|| anyhow::anyhow!("{a} needs a value"))
        };
        match a.as_str() {
            "--out" => {
                o.out = value()?.clone();
            }
            "--quick" => o.quick = true,
            "--batches" => {
                o.batches = split_list(value()?)
                    .iter()
                    .map(|s| s.parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| anyhow::anyhow!("bad --batches: {e}"))?;
                if o.batches.is_empty() {
                    bail!("--batches needs at least one value");
                }
                o.batches_explicit = true;
            }
            "--steps" => {
                o.steps = value()?.parse()?;
            }
            "--techs" => {
                o.techs = split_list(value()?)
                    .iter()
                    .map(|s| parse_tech_sel(s))
                    .collect::<Result<_>>()?;
                if o.techs.is_empty() {
                    bail!("--techs needs at least one value");
                }
            }
            "--caps" => {
                o.caps = split_list(value()?)
                    .iter()
                    .map(|s| s.parse::<u64>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| anyhow::anyhow!("bad --caps: {e}"))?;
                if o.caps.is_empty() {
                    bail!("--caps needs at least one value");
                }
            }
            "--dnns" => {
                o.dnns = split_list(value()?)
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
                if o.dnns.is_empty() {
                    bail!("--dnns needs at least one value");
                }
            }
            "--phases" => {
                o.phases = split_list(value()?)
                    .iter()
                    .map(|s| parse_phase(s))
                    .collect::<Result<_>>()?;
                if o.phases.is_empty() {
                    bail!("--phases needs at least one value");
                }
            }
            "--nodes" => {
                o.nodes = split_list(value()?)
                    .iter()
                    .map(|s| s.parse::<u32>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| anyhow::anyhow!("bad --nodes: {e}"))?;
                if o.nodes.is_empty() {
                    bail!("--nodes needs at least one value");
                }
            }
            "--jobs" => {
                o.jobs = value()?.parse()?;
            }
            "--pareto" => o.pareto = true,
            "--nvm-only" => o.nvm_only = true,
            "--cold" => o.cold = true,
            "--memo-cap" => {
                let cap: usize = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --memo-cap: {e}"))?;
                if cap == 0 {
                    bail!("--memo-cap must be at least 1");
                }
                o.memo_cap = Some(cap);
            }
            "--addr" => {
                o.addr = value()?.clone();
            }
            "--prewarm" => o.prewarm = true,
            "--auth-key" => {
                let key = value()?.clone();
                if key.is_empty() {
                    bail!("--auth-key must not be empty");
                }
                o.auth_key = Some(key);
            }
            "--queue-cap" => {
                let cap: usize = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --queue-cap: {e}"))?;
                if cap == 0 {
                    bail!("--queue-cap must be at least 1");
                }
                o.queue_cap = Some(cap);
            }
            "--workers" => {
                o.workers = split_list(value()?)
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
                if o.workers.is_empty() {
                    bail!("--workers needs at least one address");
                }
            }
            "--spec" => {
                o.spec_file = Some(value()?.clone());
            }
            "--retries" => {
                o.retries = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --retries: {e}"))?;
            }
            "--deadline-secs" => {
                o.deadline_secs = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --deadline-secs: {e}"))?;
                if o.deadline_secs == 0 {
                    bail!("--deadline-secs must be at least 1");
                }
            }
            "--status-addr" => {
                o.status_addr = Some(value()?.clone());
            }
            "--trace-out" => {
                o.trace_out = Some(value()?.clone());
            }
            "--trace-ring" => {
                let cap: usize = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --trace-ring: {e}"))?;
                if cap == 0 {
                    bail!("--trace-ring must be at least 1");
                }
                o.trace_ring = Some(cap);
            }
            "--duration" => {
                o.duration_secs = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --duration: {e}"))?;
                if o.duration_secs == 0 {
                    bail!("--duration must be at least 1 second");
                }
            }
            "--concurrency" => {
                o.concurrency = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --concurrency: {e}"))?;
                if o.concurrency == 0 {
                    bail!("--concurrency must be at least 1");
                }
            }
            "--mix" => {
                let v = value()?.clone();
                // Validate eagerly so a typo fails at parse time, not
                // mid-soak.
                crate::serve::loadgen::parse_mix(&v)?;
                o.mix = v;
            }
            "--hot-frac" => {
                let f: f64 = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --hot-frac: {e}"))?;
                if !f.is_finite() || !(0.0..=1.0).contains(&f) {
                    bail!("--hot-frac must be in [0, 1]");
                }
                o.hot_frac = Some(f);
            }
            "--json" => o.json = true,
            "--p99-ms" => {
                let ms: f64 = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --p99-ms: {e}"))?;
                if ms.is_nan() || ms <= 0.0 {
                    bail!("--p99-ms must be positive");
                }
                o.p99_ms = Some(ms);
            }
            "--objective" => {
                o.objective = crate::sweep::spec::parse_objective(value()?)?;
            }
            "--area-max" => {
                let a: f64 = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --area-max: {e}"))?;
                if !a.is_finite() || a <= 0.0 {
                    bail!("--area-max must be a positive number of mm²");
                }
                o.area_max = Some(a);
            }
            "--leak-max" => {
                let l: f64 = value()?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --leak-max: {e}"))?;
                if !l.is_finite() || l <= 0.0 {
                    bail!("--leak-max must be a positive number of watts");
                }
                o.leak_max = Some(l);
            }
            "--frontier" => o.frontier = true,
            other => bail!("unknown option '{other}' (try: deepnvm help)"),
        }
    }
    Ok(o)
}

/// The shared fleet secret: the explicit `--auth-key` flag, else the
/// `DEEPNVM_AUTH_KEY` env var (the same fallback on serve, coordinate,
/// and loadgen, so one exported variable keys a whole fleet).
fn resolve_auth_key(o: &CliOptions) -> Option<String> {
    o.auth_key
        .clone()
        .or_else(|| std::env::var("DEEPNVM_AUTH_KEY").ok())
        .filter(|k| !k.is_empty())
}

fn scal_caps(quick: bool) -> Vec<u64> {
    if quick {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    }
}

/// Build the sweep spec for `deepnvm sweep` from CLI options.
pub fn sweep_spec_from(o: &CliOptions) -> Result<SweepSpec> {
    let techs =
        if o.techs.is_empty() { TechSel::pures(&MemTech::ALL) } else { o.techs.clone() };
    let caps = if o.caps.is_empty() { scal_caps(o.quick) } else { o.caps.clone() };
    let circuit_only =
        o.dnns.len() == 1 && o.dnns[0].eq_ignore_ascii_case("none");
    let dnns = if circuit_only {
        vec![]
    } else if o.dnns.is_empty() {
        if o.quick {
            vec!["AlexNet".to_string()]
        } else {
            Dnn::zoo().iter().map(|d| d.name.to_string()).collect()
        }
    } else {
        o.dnns.clone()
    };
    let phases = if o.phases.is_empty() { Phase::ALL.to_vec() } else { o.phases.clone() };
    let batches = if o.batches_explicit { o.batches.clone() } else { vec![] };
    let nodes_nm = if o.nodes.is_empty() { vec![16] } else { o.nodes.clone() };
    let filters = if o.nvm_only { vec![Filter::NvmOnly] } else { vec![] };
    Ok(SweepSpec {
        techs,
        capacities_mb: caps,
        dnns,
        phases,
        batches,
        nodes_nm,
        filters,
    })
}

/// Generate the reports for one command.
pub fn generate(o: &CliOptions) -> Result<Vec<Report>> {
    let fig6_batch = if o.quick { 1 } else { 4 };
    Ok(match o.command.as_str() {
        "table1" => vec![reports::table1()],
        "table2" => vec![reports::table2()],
        "table3" => vec![reports::table3()],
        "fig1" => vec![reports::fig1()],
        "fig3" | "fig4" => {
            let (f3, f4) = reports::fig3_fig4();
            vec![f3, f4]
        }
        "fig5" => vec![reports::fig5(&o.batches)],
        "fig6" => vec![reports::fig6(fig6_batch)],
        "fig7" | "fig8" => {
            let (f7, f8) = reports::fig7_fig8(if o.quick {
                Some((0.146, 0.198)) // paper's measured reductions
            } else {
                None // re-simulate
            });
            vec![f7, f8]
        }
        "fig9" => vec![reports::fig9(&scal_caps(o.quick))],
        "fig10" => vec![reports::fig10(&scal_caps(o.quick))],
        "nodes" => {
            let caps = if o.caps.is_empty() { scal_caps(o.quick) } else { o.caps.clone() };
            let nodes = if o.nodes.is_empty() {
                crate::device::CALIBRATED_NODES_NM.to_vec()
            } else {
                o.nodes.clone()
            };
            // Same memo lifecycle as `sweep`: warm-load the on-disk
            // cache (unless --cold) and persist afterwards, so repeated
            // cross-node reports replay instead of re-solving.
            let store = Store::new(&o.out);
            let memo = crate::sweep::memo::global();
            memo.set_point_capacity(o.memo_cap);
            if !o.cold {
                match memo.load_from(&store) {
                    Ok(n) if n > 0 => {
                        eprintln!("nodes: warmed memo with {n} cached entries");
                    }
                    Ok(_) => {}
                    Err(e) => eprintln!("warning: ignoring memo cache: {e}"),
                }
            }
            let r = reports::nodes_report_with(&caps, &nodes, o.jobs, memo)?;
            if o.cold {
                if let Err(e) = memo.load_from(&store) {
                    eprintln!("warning: ignoring memo cache: {e}");
                }
            }
            if let Err(e) = memo.save_to(&store) {
                eprintln!("warning: could not persist sweep memo: {e}");
            }
            vec![r]
        }
        "sweep" => {
            let spec = sweep_spec_from(o)?;
            let store = Store::new(&o.out);
            let memo = crate::sweep::memo::global();
            // Bound the point layer before any load/run so the cache —
            // and the sweep_memo.json persisted below — stays trimmed.
            memo.set_point_capacity(o.memo_cap);
            if !o.cold {
                match memo.load_from(&store) {
                    Ok(n) if n > 0 => {
                        eprintln!("sweep: warmed memo with {n} cached entries");
                    }
                    Ok(_) => {}
                    Err(e) => eprintln!("warning: ignoring memo cache: {e}"),
                }
            }
            let r = reports::sweep_report(&spec, o.jobs, o.pareto)?;
            if o.cold {
                // --cold skipped the load above; merge the previously
                // persisted entries back in so saving below extends the
                // accumulated cache instead of truncating it to this run.
                if let Err(e) = memo.load_from(&store) {
                    eprintln!("warning: ignoring memo cache: {e}");
                }
            }
            if let Err(e) = memo.save_to(&store) {
                eprintln!("warning: could not persist sweep memo: {e}");
            }
            vec![r]
        }
        "ext-area" => vec![reports::ext_area_reuse()],
        "ext-mobile" => vec![reports::ext_mobile()],
        "ext-hybrid" => vec![reports::ext_hybrid()],
        "ext-relaxed" => vec![reports::ext_relaxed()],
        "all" => {
            let mut v = vec![
                reports::table1(),
                reports::table2(),
                reports::table3(),
                reports::fig1(),
            ];
            let (f3, f4) = reports::fig3_fig4();
            v.push(f3);
            v.push(f4);
            v.push(reports::fig5(&o.batches));
            v.push(reports::fig6(fig6_batch));
            let (f7, f8) = reports::fig7_fig8(None);
            v.push(f7);
            v.push(f8);
            v.push(reports::fig9(&scal_caps(o.quick)));
            v.push(reports::fig10(&scal_caps(o.quick)));
            v.push(reports::ext_area_reuse());
            v.push(reports::ext_mobile());
            v.push(reports::ext_hybrid());
            v.push(reports::ext_relaxed());
            v
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    })
}

/// The spec `deepnvm coordinate` distributes: an explicit `--spec`
/// JSON file when given, else the sweep axis flags.
fn coordinate_spec(o: &CliOptions) -> Result<SweepSpec> {
    match &o.spec_file {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("cannot read --spec {path}"))?;
            let doc = crate::util::json::parse(&text)
                .with_context(|| format!("--spec {path} is not valid JSON"))?;
            crate::sweep::spec::spec_from_json(&doc)
        }
        None => sweep_spec_from(o),
    }
}

/// `deepnvm coordinate`: drive a worker fleet through one grid and
/// persist the merged memo. Fails unless the merged union replays the
/// full grid with zero circuit solves and zero traffic evals.
fn coordinate_cmd(o: &CliOptions, trace_written: &mut bool) -> Result<()> {
    if o.workers.is_empty() {
        bail!("coordinate needs --workers host:port[,host:port...]");
    }
    let spec = coordinate_spec(o)?;
    let cfg = crate::serve::ScheduleConfig {
        workers: o.workers.clone(),
        retries: o.retries,
        deadline: std::time::Duration::from_secs(o.deadline_secs),
        jobs: o.jobs,
        status_addr: o.status_addr.clone(),
        auth_key: resolve_auth_key(o),
    };
    let memo = crate::sweep::memo::global();
    let store = Store::new(&o.out);
    if !o.cold {
        match memo.load_from(&store) {
            Ok(n) if n > 0 => {
                eprintln!("coordinate: warmed memo with {n} cached entries");
            }
            Ok(_) => {}
            Err(e) => eprintln!("warning: ignoring memo cache: {e}"),
        }
    }

    let coordinator = crate::serve::Coordinator::new(&spec, &cfg)?;
    if let Some(addr) = coordinator.status_addr() {
        println!("coordinate: status at http://{addr}/scheduler/status");
    }
    println!(
        "coordinate: {} -> {} shard(s) over {} worker(s)",
        spec.summary(),
        coordinator.shard_count(),
        o.workers.len()
    );
    let report = coordinator.run(memo)?;
    // A completed fleet run upgrades --trace-out from the local span
    // ring to the stitched fleet trace (coordinator + every worker's
    // /trace, clock-rebased and flow-linked). On failure the generic
    // local dump in run_cli still fires.
    if let Some(path) = &o.trace_out {
        let doc = coordinator.fleet_trace();
        let stitched = doc
            .get("workersStitched")
            .and_then(crate::util::json::Json::as_u64)
            .unwrap_or(0);
        match std::fs::write(path, doc.to_pretty()) {
            Ok(()) => {
                eprintln!("trace: wrote the stitched fleet trace ({stitched} worker(s)) to {path}");
                *trace_written = true;
            }
            Err(e) => eprintln!("warning: could not write --trace-out {path}: {e}"),
        }
    }
    for (i, s) in report.shards.iter().enumerate() {
        println!(
            "  shard {i}: caps {:?} ({} points, {} attempt(s)) -> {}",
            s.caps_mb, s.points, s.attempts, s.state
        );
    }
    println!(
        "coordinate: merged {} new entries ({} shard(s) reassigned) in {:.1}s",
        report.accepted,
        report.reassigned,
        report.wall.as_secs_f64()
    );
    println!(
        "coordinate: replay: {} circuit solves, {} traffic evals over {} points",
        report.replay_solves, report.replay_evals, report.grid_points
    );
    if report.replay_solves != 0 || report.replay_evals != 0 {
        bail!(
            "the merged shard union did not cover the grid ({} solves, {} evals \
             on replay) — were the workers LRU-capped below their shard size?",
            report.replay_solves,
            report.replay_evals
        );
    }
    match memo.save_to(&store) {
        Ok(path) => println!("coordinate: merged memo persisted to {}", path.display()),
        Err(e) => eprintln!("warning: could not persist sweep memo: {e}"),
    }
    Ok(())
}

/// `deepnvm optimize`: branch-and-bound search over the implicit grid,
/// with the same memo lifecycle as `sweep` (warm-load the on-disk
/// cache unless --cold, persist afterwards) so repeated searches reuse
/// every circuit solve the search did materialize.
fn optimize_cmd(o: &CliOptions) -> Result<()> {
    let req = crate::sweep::OptimizeRequest {
        spec: sweep_spec_from(o)?,
        objective: o.objective,
        area_max_mm2: o.area_max,
        leakage_max_w: o.leak_max,
        frontier: o.frontier,
    };
    let store = Store::new(&o.out);
    let memo = crate::sweep::memo::global();
    memo.set_point_capacity(o.memo_cap);
    if !o.cold {
        match memo.load_from(&store) {
            Ok(n) if n > 0 => {
                eprintln!("optimize: warmed memo with {n} cached entries");
            }
            Ok(_) => {}
            Err(e) => eprintln!("warning: ignoring memo cache: {e}"),
        }
    }
    let resp = crate::sweep::optimize::run(&req, o.jobs, memo)?;
    println!(
        "optimize: {} over {} implicit point(s): evaluated {}, pruned {}",
        req.objective.name(),
        resp.points_total,
        resp.points_evaluated,
        resp.points_pruned
    );
    println!("{}", crate::sweep::spec::optimize_response_to_json(&resp).to_pretty());
    if o.cold {
        if let Err(e) = memo.load_from(&store) {
            eprintln!("warning: ignoring memo cache: {e}");
        }
    }
    if let Err(e) = memo.save_to(&store) {
        eprintln!("warning: could not persist sweep memo: {e}");
    }
    Ok(())
}

/// `deepnvm loadgen`: soak a running server and gate on the report.
/// Fails on any transport error, on an idle run, and on a `--p99-ms`
/// breach — so CI can use the exit code directly.
fn loadgen_cmd(o: &CliOptions) -> Result<()> {
    let (solve_weight, sweep_weight, optimize_weight) =
        crate::serve::loadgen::parse_mix(&o.mix)?;
    let cfg = crate::serve::LoadgenConfig {
        addr: o.addr.clone(),
        duration: std::time::Duration::from_secs(o.duration_secs),
        concurrency: o.concurrency,
        solve_weight,
        sweep_weight,
        optimize_weight,
        hot_frac: o.hot_frac,
        p99_ms: o.p99_ms,
        auth_key: resolve_auth_key(o),
    };
    let report = crate::serve::loadgen::run(&cfg)?;
    println!("{}", report.render());
    if report.requests == 0 {
        bail!("loadgen completed no successful requests");
    }
    if report.errors > 0 {
        bail!("loadgen saw {} failed request(s)", report.errors);
    }
    if let Some(limit) = cfg.p99_ms {
        if !report.meets_p99(limit) {
            bail!(
                "p99 {:.3} ms exceeds the --p99-ms gate of {limit} ms",
                report.p99_ms
            );
        }
        println!("loadgen: p99 {:.3} ms is within the {limit} ms gate", report.p99_ms);
    }
    Ok(())
}

/// `deepnvm validate`: replay the requested (dnn, phase, capacity)
/// cells through both the analytic traffic model and the trace-driven
/// hierarchy simulation, print the per-cell error table (or `--json`),
/// and fail when any cell's relative error exceeds the documented
/// bound — so CI can gate on the exit code directly.
fn validate_cmd(o: &CliOptions) -> Result<()> {
    let mut req = crate::gpusim::validate::ValidateRequest::default();
    if !o.dnns.is_empty() {
        req.dnns = o.dnns.clone();
    }
    if !o.phases.is_empty() {
        req.phases = o.phases.clone();
    }
    if !o.caps.is_empty() {
        req.capacities_mb = o.caps.clone();
    }
    if o.batches_explicit {
        if o.batches.len() != 1 {
            bail!("validate replays one batch size: give --batches a single value");
        }
        req.batch = o.batches[0];
    }
    let report = crate::gpusim::validate::run(&req)?;
    if o.json {
        println!("{}", crate::gpusim::validate::report_to_json(&report).to_pretty());
    } else {
        print!("{}", crate::gpusim::validate::render_table(&report));
    }
    if !report.pass() {
        bail!(
            "max relative error {:.4} exceeds the {:.2} bound",
            report.max_rel_err(),
            report.bound
        );
    }
    Ok(())
}

/// Run the e2e training demo (delegates to the runtime).
#[cfg(feature = "pjrt")]
fn e2e_train(o: &CliOptions) -> Result<()> {
    let engine = crate::runtime::Engine::default()?;
    println!("platform: {}", engine.platform());
    let (report, params) =
        crate::runtime::trainer::train(&engine, o.steps, 0.05, 7, |s, l| {
            if s % 10 == 0 {
                println!("step {s:>4}  loss {l:.4}");
            }
        })?;
    let acc = crate::runtime::trainer::eval_accuracy(&engine, &params, 999)?;
    println!(
        "trained {} steps (batch {}) in {:.2}s ({:.1} steps/s): loss {:.3} -> {:.3}, \
         eval accuracy {:.0}%",
        report.steps,
        report.batch,
        report.seconds,
        report.steps_per_sec(),
        report.first_loss(),
        report.last_loss(),
        acc * 100.0
    );
    Ok(())
}

/// Without the `pjrt` feature the PJRT runtime is not compiled in.
#[cfg(not(feature = "pjrt"))]
fn e2e_train(_o: &CliOptions) -> Result<()> {
    bail!(
        "e2e-train needs the PJRT runtime: rebuild with `--features pjrt` \
         (requires the vendored xla crate)"
    )
}

/// Dump the span ring as Chrome trace-event JSON (`--trace-out`).
fn write_trace(path: &str) {
    let doc = crate::obs::trace::chrome_trace_json();
    match std::fs::write(path, doc.to_pretty()) {
        Ok(()) => {
            eprintln!("trace: wrote {} span(s) to {path}", crate::obs::trace::span_count());
        }
        Err(e) => eprintln!("warning: could not write --trace-out {path}: {e}"),
    }
}

/// Full CLI entry point. Returns the process exit code.
pub fn run_cli(args: &[String]) -> i32 {
    // Anchor the obs clock first, so span timestamps and the uptime
    // metrics measure from process start rather than first use.
    crate::obs::epoch();
    let o = match parse_args(args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if let Some(cap) = o.trace_ring {
        if !crate::obs::trace::set_ring_capacity(cap) {
            eprintln!("warning: --trace-ring ignored; the span ring is already live");
        }
    }
    let mut fleet_trace_written = false;
    let code = match o.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            0
        }
        "e2e-train" => match e2e_train(&o) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        "serve" => {
            let cfg = crate::serve::ServeConfig {
                addr: o.addr.clone(),
                jobs: o.jobs,
                prewarm: o.prewarm,
                memo_cap: o.memo_cap,
                out: o.out.clone(),
                auth_key: resolve_auth_key(&o),
                queue_cap: o.queue_cap,
            };
            match crate::serve::run(&cfg) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("error: {e:#}");
                    1
                }
            }
        }
        "optimize" => match optimize_cmd(&o) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        "coordinate" => match coordinate_cmd(&o, &mut fleet_trace_written) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        "loadgen" => match loadgen_cmd(&o) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        "validate" => match validate_cmd(&o) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        _ => match generate(&o) {
            Ok(rs) => {
                let mut store = Store::new(&o.out);
                for r in &rs {
                    println!("{}", r.text);
                    if let Err(e) = store.save(r) {
                        eprintln!("warning: could not save {}: {e}", r.id);
                    }
                }
                let _ = store.finish(&[
                    ("command", o.command.as_str()),
                    ("quick", if o.quick { "true" } else { "false" }),
                ]);
                0
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
    };
    // `serve` never reaches this point (it runs until killed; its span
    // ring is live over `GET /trace` instead). A successful coordinate
    // already wrote the richer stitched fleet trace.
    if let Some(path) = &o.trace_out {
        if !fleet_trace_written {
            write_trace(path);
        }
    }
    code
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options() {
        let o = parse_args(&sv(&["fig5", "--batches", "2,8", "--quick", "--out", "/tmp/x"]))
            .unwrap();
        assert_eq!(o.command, "fig5");
        assert_eq!(o.batches, vec![2, 8]);
        assert!(o.batches_explicit);
        assert!(o.quick);
        assert_eq!(o.out, "/tmp/x");
    }

    #[test]
    fn parses_sweep_options() {
        let o = parse_args(&sv(&[
            "sweep", "--techs", "stt,sot", "--caps", "2,8", "--dnns", "AlexNet",
            "--phases", "training", "--jobs", "4", "--pareto", "--nvm-only",
            "--cold",
        ]))
        .unwrap();
        assert_eq!(o.techs, vec![MemTech::SttMram, MemTech::SotMram]);
        assert_eq!(o.caps, vec![2, 8]);
        assert_eq!(o.dnns, vec!["AlexNet".to_string()]);
        assert_eq!(o.phases, vec![Phase::Training]);
        assert_eq!(o.jobs, 4);
        assert!(o.pareto && o.nvm_only && o.cold);

        let spec = sweep_spec_from(&o).unwrap();
        assert_eq!(spec.techs, vec![MemTech::SttMram, MemTech::SotMram]);
        assert_eq!(spec.capacities_mb, vec![2, 8]);
        assert_eq!(spec.batches, Vec::<usize>::new(), "paper batches by default");
        assert_eq!(spec.filters, vec![Filter::NvmOnly]);
    }

    #[test]
    fn parses_serve_options() {
        let o = parse_args(&sv(&[
            "serve", "--addr", "127.0.0.1:0", "--prewarm", "--memo-cap", "500",
            "--jobs", "3", "--out", "/tmp/r",
        ]))
        .unwrap();
        assert_eq!(o.command, "serve");
        assert_eq!(o.addr, "127.0.0.1:0");
        assert!(o.prewarm);
        assert_eq!(o.memo_cap, Some(500));
        assert_eq!(o.jobs, 3);
        assert_eq!(o.out, "/tmp/r");
        assert!(o.auth_key.is_none() && o.queue_cap.is_none());

        assert!(parse_args(&sv(&["serve", "--memo-cap", "0"])).is_err());
        assert!(parse_args(&sv(&["serve", "--memo-cap", "x"])).is_err());
        assert!(parse_args(&sv(&["serve", "--addr"])).is_err());
    }

    #[test]
    fn parses_hardening_options() {
        let o = parse_args(&sv(&[
            "serve", "--auth-key", "fleet-secret", "--queue-cap", "64",
        ]))
        .unwrap();
        assert_eq!(o.auth_key.as_deref(), Some("fleet-secret"));
        assert_eq!(o.queue_cap, Some(64));

        // coordinate and loadgen take the same key flag
        let o = parse_args(&sv(&[
            "coordinate", "--workers", "h:1", "--auth-key", "k",
        ]))
        .unwrap();
        assert_eq!(o.auth_key.as_deref(), Some("k"));
        let o = parse_args(&sv(&["loadgen", "--auth-key", "k"])).unwrap();
        assert_eq!(o.auth_key.as_deref(), Some("k"));

        assert!(parse_args(&sv(&["serve", "--auth-key", ""])).is_err());
        assert!(parse_args(&sv(&["serve", "--auth-key"])).is_err());
        assert!(parse_args(&sv(&["serve", "--queue-cap", "0"])).is_err());
        assert!(parse_args(&sv(&["serve", "--queue-cap", "x"])).is_err());
    }

    #[test]
    fn parses_coordinate_options() {
        let o = parse_args(&sv(&[
            "coordinate", "--workers", "h1:8090, h2:8090", "--retries", "5",
            "--deadline-secs", "30", "--status-addr", "127.0.0.1:0", "--caps", "1,2",
        ]))
        .unwrap();
        assert_eq!(o.command, "coordinate");
        assert_eq!(o.workers, vec!["h1:8090".to_string(), "h2:8090".to_string()]);
        assert_eq!(o.retries, 5);
        assert_eq!(o.deadline_secs, 30);
        assert_eq!(o.status_addr.as_deref(), Some("127.0.0.1:0"));
        assert!(o.spec_file.is_none());

        assert!(parse_args(&sv(&["coordinate", "--workers", ","])).is_err());
        assert!(parse_args(&sv(&["coordinate", "--deadline-secs", "0"])).is_err());
        assert!(parse_args(&sv(&["coordinate", "--retries", "x"])).is_err());
    }

    #[test]
    fn coordinate_requires_workers_and_a_readable_spec() {
        let o = parse_args(&sv(&["coordinate"])).unwrap();
        let e = coordinate_cmd(&o, &mut false).unwrap_err();
        assert!(e.to_string().contains("--workers"), "{e}");

        let o = parse_args(&sv(&[
            "coordinate", "--workers", "h:1", "--spec", "/nonexistent/spec.json",
        ]))
        .unwrap();
        let e = coordinate_cmd(&o, &mut false).unwrap_err();
        assert!(format!("{e:#}").contains("--spec"), "{e:#}");

        // a spec file round-trips through the JSON codec
        let dir = std::env::temp_dir().join("deepnvm_coordinate_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        std::fs::write(&path, r#"{"techs": ["stt"], "caps_mb": [1, 2], "dnns": []}"#)
            .unwrap();
        let o = parse_args(&sv(&[
            "coordinate", "--workers", "h:1", "--spec",
            path.to_str().unwrap(),
        ]))
        .unwrap();
        let spec = coordinate_spec(&o).unwrap();
        assert_eq!(spec.capacities_mb, vec![1, 2]);
        assert!(spec.dnns.is_empty());
    }

    #[test]
    fn sweep_dnns_none_gives_circuit_only_spec() {
        let o = parse_args(&sv(&["sweep", "--dnns", "none", "--caps", "1"])).unwrap();
        let spec = sweep_spec_from(&o).unwrap();
        assert!(spec.dnns.is_empty());
        assert_eq!(spec.expand().unwrap().len(), 3);
    }

    #[test]
    fn parses_the_nodes_axis() {
        let o = parse_args(&sv(&[
            "sweep", "--nodes", "16,7,5", "--dnns", "none", "--caps", "1",
        ]))
        .unwrap();
        assert_eq!(o.nodes, vec![16, 7, 5]);
        let spec = sweep_spec_from(&o).unwrap();
        assert_eq!(spec.nodes_nm, vec![16, 7, 5]);
        assert_eq!(spec.expand().unwrap().len(), 9, "3 nodes x 3 techs x 1 cap");

        // default stays the paper's 16 nm
        let o = parse_args(&sv(&["sweep", "--dnns", "none", "--caps", "1"])).unwrap();
        assert_eq!(sweep_spec_from(&o).unwrap().nodes_nm, vec![16]);

        // uncalibrated nodes parse but fail spec validation up front
        let o = parse_args(&sv(&["sweep", "--nodes", "9", "--caps", "1"])).unwrap();
        assert!(sweep_spec_from(&o).unwrap().expand().is_err());

        assert!(parse_args(&sv(&["sweep", "--nodes", "x"])).is_err());
        assert!(parse_args(&sv(&["sweep", "--nodes", ","])).is_err());
    }

    #[test]
    fn nodes_command_generates_the_cross_node_report() {
        let out = std::env::temp_dir().join("deepnvm_nodes_cli_test");
        let o = parse_args(&sv(&[
            "nodes", "--caps", "2,8", "--nodes", "16,7", "--quick", "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        let rs = generate(&o).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, "NODES");
        assert_eq!(rs[0].csv.n_rows(), 2 * 3 * 2);
        assert!(rs[0].text.contains("crossover"));
    }

    #[test]
    fn parses_trace_out() {
        let o = parse_args(&sv(&["fig1", "--trace-out", "/tmp/t.json"])).unwrap();
        assert_eq!(o.trace_out.as_deref(), Some("/tmp/t.json"));
        let o = parse_args(&sv(&["fig1"])).unwrap();
        assert!(o.trace_out.is_none());
        assert!(parse_args(&sv(&["fig1", "--trace-out"])).is_err());
    }

    #[test]
    fn parses_trace_ring() {
        let o = parse_args(&sv(&["fig1", "--trace-ring", "1024"])).unwrap();
        assert_eq!(o.trace_ring, Some(1024));
        let o = parse_args(&sv(&["fig1"])).unwrap();
        assert!(o.trace_ring.is_none());
        assert!(parse_args(&sv(&["fig1", "--trace-ring", "0"])).is_err());
        assert!(parse_args(&sv(&["fig1", "--trace-ring", "x"])).is_err());
    }

    #[test]
    fn parses_loadgen_options() {
        let o = parse_args(&sv(&[
            "loadgen", "--addr", "127.0.0.1:8099", "--duration", "30",
            "--concurrency", "8", "--mix", "3:2", "--p99-ms", "250",
        ]))
        .unwrap();
        assert_eq!(o.command, "loadgen");
        assert_eq!(o.addr, "127.0.0.1:8099");
        assert_eq!(o.duration_secs, 30);
        assert_eq!(o.concurrency, 8);
        assert_eq!(o.mix, "3:2");
        assert_eq!(o.p99_ms, Some(250.0));

        // defaults
        let o = parse_args(&sv(&["loadgen"])).unwrap();
        assert_eq!(o.duration_secs, 10);
        assert_eq!(o.concurrency, 4);
        assert_eq!(o.mix, "9:1");
        assert!(o.p99_ms.is_none());

        assert!(parse_args(&sv(&["loadgen", "--duration", "0"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--concurrency", "0"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--mix", "0:0"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--mix", "0:0:0"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--mix", "nine"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--p99-ms", "-1"])).is_err());

        // the optimize kind rides the same flag
        let o = parse_args(&sv(&["loadgen", "--mix", "8:1:1"])).unwrap();
        assert_eq!(o.mix, "8:1:1");
    }

    #[test]
    fn parses_hot_frac() {
        let o = parse_args(&sv(&["loadgen", "--hot-frac", "0.85"])).unwrap();
        assert_eq!(o.hot_frac, Some(0.85));
        let o = parse_args(&sv(&["loadgen"])).unwrap();
        assert!(o.hot_frac.is_none());
        assert!(parse_args(&sv(&["loadgen", "--hot-frac", "1.5"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--hot-frac", "-0.1"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--hot-frac", "nan"])).is_err());
        assert!(parse_args(&sv(&["loadgen", "--hot-frac"])).is_err());
    }

    #[test]
    fn parses_hybrid_techs() {
        let o = parse_args(&sv(&[
            "sweep", "--techs", "stt,hybrid-stt:4@0.85", "--caps", "2", "--dnns",
            "none",
        ]))
        .unwrap();
        assert_eq!(o.techs.len(), 2);
        assert_eq!(o.techs[0], MemTech::SttMram);
        assert_eq!(o.techs[1].name(), "hybrid-stt:4@0.85");
        let spec = sweep_spec_from(&o).unwrap();
        assert_eq!(spec.expand().unwrap().len(), 2, "2 techs x 1 cap, circuit-only");

        // an SRAM partner, too many ways, or a bad steer all fail at parse
        assert!(parse_args(&sv(&["sweep", "--techs", "hybrid-sram:4@0.85"])).is_err());
        assert!(parse_args(&sv(&["sweep", "--techs", "hybrid-stt:40@0.85"])).is_err());
        assert!(parse_args(&sv(&["sweep", "--techs", "hybrid-stt:4@1.5"])).is_err());
    }

    #[test]
    fn validate_cmd_gates_on_the_bound() {
        let o = parse_args(&sv(&[
            "validate", "--dnns", "SqueezeNet", "--phases", "inference", "--caps",
            "3",
        ]))
        .unwrap();
        assert!(validate_cmd(&o).is_ok());

        let o = parse_args(&sv(&["validate", "--dnns", "NoSuchNet", "--caps", "3"]))
            .unwrap();
        assert!(validate_cmd(&o).is_err());

        // validate replays exactly one batch size
        let o = parse_args(&sv(&["validate", "--batches", "1,2"])).unwrap();
        assert!(validate_cmd(&o).is_err());

        let o = parse_args(&sv(&["validate", "--json"])).unwrap();
        assert!(o.json);
    }

    #[test]
    fn parses_optimize_options() {
        let o = parse_args(&sv(&[
            "optimize", "--objective", "edap", "--area-max", "25", "--leak-max",
            "0.5", "--frontier", "--techs", "stt,sot", "--caps", "1,2",
        ]))
        .unwrap();
        assert_eq!(o.command, "optimize");
        assert_eq!(o.objective, crate::sweep::OptObjective::Edap);
        assert_eq!(o.area_max, Some(25.0));
        assert_eq!(o.leak_max, Some(0.5));
        assert!(o.frontier);
        assert_eq!(o.caps, vec![1, 2]);

        // defaults
        let o = parse_args(&sv(&["optimize"])).unwrap();
        assert_eq!(o.objective, crate::sweep::OptObjective::Edp);
        assert!(o.area_max.is_none() && o.leak_max.is_none() && !o.frontier);

        assert!(parse_args(&sv(&["optimize", "--objective", "speed"])).is_err());
        assert!(parse_args(&sv(&["optimize", "--area-max", "0"])).is_err());
        assert!(parse_args(&sv(&["optimize", "--area-max", "nan"])).is_err());
        assert!(parse_args(&sv(&["optimize", "--leak-max", "-2"])).is_err());
    }

    #[test]
    fn rejects_unknown_flags_and_bad_values() {
        assert!(parse_args(&sv(&["fig5", "--bogus"])).is_err());
        assert!(parse_args(&sv(&["fig5", "--batches", "a,b"])).is_err());
        assert!(parse_args(&sv(&["fig5", "--out"])).is_err());
        assert!(parse_args(&sv(&["sweep", "--techs", "dram"])).is_err());
        assert!(parse_args(&sv(&["sweep", "--phases", "both"])).is_err());
        assert!(parse_args(&sv(&["sweep", "--caps", "x"])).is_err());
    }

    #[test]
    fn unknown_command_fails_generation() {
        let o = parse_args(&sv(&["notacmd"])).unwrap();
        assert!(generate(&o).is_err());
    }

    #[test]
    fn quick_table_commands_generate() {
        for cmd in ["table2", "table3", "fig1"] {
            let o = parse_args(&sv(&[cmd])).unwrap();
            let rs = generate(&o).unwrap();
            assert!(!rs.is_empty());
        }
    }
}
