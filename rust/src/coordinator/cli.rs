//! Command-line interface (hand-rolled: the offline vendor set has no
//! clap). `deepnvm <command> [--out DIR] [--quick] [--batches a,b,c]`.

use anyhow::{bail, Result};

use super::reports::{self, Report};
use super::store::Store;

const USAGE: &str = "\
DeepNVM++ — cross-layer NVM modeling for deep learning (TCAD'21 repro)

USAGE: deepnvm <command> [options]

COMMANDS (paper artifacts):
  table1        Bitcell characterization (device sweep vs paper)
  table2        EDAP-tuned cache PPA (iso-capacity + iso-area points)
  table3        DNN zoo configurations
  fig1          NVIDIA L2 capacity trend
  fig3 fig4     Iso-capacity energy / EDP studies
  fig5          Batch-size impact on AlexNet EDP
  fig6          DRAM reduction vs L2 capacity (hierarchy simulation)
  fig7 fig8     Iso-area energy / EDP studies
  fig9 fig10    Scalability sweeps (1-32 MB)
  ext-area      Extension: spend the freed area on compute (paper SSV)
  ext-mobile    Extension: mobile inference LLC design space (paper SSV)
  ext-hybrid    Extension: hybrid SRAM+STT way-partitioned caches (SSII)
  ext-relaxed   Extension: relaxed-retention (volatile) STT (SSII)
  all           Every table and figure (writes CSVs to --out)

OTHER:
  e2e-train     Train the TinyCNN artifact via PJRT (needs `make artifacts`)
  help          This message

OPTIONS:
  --out DIR       results directory (default: results)
  --quick         cheaper settings (fig6 batch 1, coarser sweeps)
  --batches LIST  comma-separated batch sizes for fig5
  --steps N       training steps for e2e-train (default 60)
";

/// Parsed options.
#[derive(Clone, Debug)]
pub struct CliOptions {
    pub command: String,
    pub out: String,
    pub quick: bool,
    pub batches: Vec<usize>,
    pub steps: usize,
}

impl Default for CliOptions {
    fn default() -> Self {
        CliOptions {
            command: "help".into(),
            out: "results".into(),
            quick: false,
            batches: vec![1, 4, 16, 64, 128, 256],
            steps: 60,
        }
    }
}

/// Parse argv (excluding the binary name).
pub fn parse_args(args: &[String]) -> Result<CliOptions> {
    let mut o = CliOptions::default();
    let mut it = args.iter();
    if let Some(cmd) = it.next() {
        o.command = cmd.clone();
    }
    while let Some(a) = it.next() {
        match a.as_str() {
            "--out" => {
                o.out = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("--out needs a value"))?
                    .clone();
            }
            "--quick" => o.quick = true,
            "--batches" => {
                let v = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("--batches needs a value"))?;
                o.batches = v
                    .split(',')
                    .map(|s| s.trim().parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| anyhow::anyhow!("bad --batches: {e}"))?;
                if o.batches.is_empty() {
                    bail!("--batches needs at least one value");
                }
            }
            "--steps" => {
                o.steps = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("--steps needs a value"))?
                    .parse()?;
            }
            other => bail!("unknown option '{other}' (try: deepnvm help)"),
        }
    }
    Ok(o)
}

fn scal_caps(quick: bool) -> Vec<u64> {
    if quick {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    }
}

/// Generate the reports for one command.
pub fn generate(o: &CliOptions) -> Result<Vec<Report>> {
    let fig6_batch = if o.quick { 1 } else { 4 };
    Ok(match o.command.as_str() {
        "table1" => vec![reports::table1()],
        "table2" => vec![reports::table2()],
        "table3" => vec![reports::table3()],
        "fig1" => vec![reports::fig1()],
        "fig3" | "fig4" => {
            let (f3, f4) = reports::fig3_fig4();
            vec![f3, f4]
        }
        "fig5" => vec![reports::fig5(&o.batches)],
        "fig6" => vec![reports::fig6(fig6_batch)],
        "fig7" | "fig8" => {
            let (f7, f8) = reports::fig7_fig8(if o.quick {
                Some((0.146, 0.198)) // paper's measured reductions
            } else {
                None // re-simulate
            });
            vec![f7, f8]
        }
        "fig9" => vec![reports::fig9(&scal_caps(o.quick))],
        "fig10" => vec![reports::fig10(&scal_caps(o.quick))],
        "ext-area" => vec![reports::ext_area_reuse()],
        "ext-mobile" => vec![reports::ext_mobile()],
        "ext-hybrid" => vec![reports::ext_hybrid()],
        "ext-relaxed" => vec![reports::ext_relaxed()],
        "all" => {
            let mut v = vec![
                reports::table1(),
                reports::table2(),
                reports::table3(),
                reports::fig1(),
            ];
            let (f3, f4) = reports::fig3_fig4();
            v.push(f3);
            v.push(f4);
            v.push(reports::fig5(&o.batches));
            v.push(reports::fig6(fig6_batch));
            let (f7, f8) = reports::fig7_fig8(None);
            v.push(f7);
            v.push(f8);
            v.push(reports::fig9(&scal_caps(o.quick)));
            v.push(reports::fig10(&scal_caps(o.quick)));
            v.push(reports::ext_area_reuse());
            v.push(reports::ext_mobile());
            v.push(reports::ext_hybrid());
            v.push(reports::ext_relaxed());
            v
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    })
}

/// Run the e2e training demo (delegates to the runtime).
fn e2e_train(o: &CliOptions) -> Result<()> {
    let engine = crate::runtime::Engine::default()?;
    println!("platform: {}", engine.platform());
    let (report, params) =
        crate::runtime::trainer::train(&engine, o.steps, 0.05, 7, |s, l| {
            if s % 10 == 0 {
                println!("step {s:>4}  loss {l:.4}");
            }
        })?;
    let acc = crate::runtime::trainer::eval_accuracy(&engine, &params, 999)?;
    println!(
        "trained {} steps (batch {}) in {:.2}s ({:.1} steps/s): loss {:.3} -> {:.3}, \
         eval accuracy {:.0}%",
        report.steps,
        report.batch,
        report.seconds,
        report.steps_per_sec(),
        report.first_loss(),
        report.last_loss(),
        acc * 100.0
    );
    Ok(())
}

/// Full CLI entry point. Returns the process exit code.
pub fn run_cli(args: &[String]) -> i32 {
    let o = match parse_args(args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match o.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            0
        }
        "e2e-train" => match e2e_train(&o) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        _ => match generate(&o) {
            Ok(rs) => {
                let mut store = Store::new(&o.out);
                for r in &rs {
                    println!("{}", r.text);
                    if let Err(e) = store.save(r) {
                        eprintln!("warning: could not save {}: {e}", r.id);
                    }
                }
                let _ = store.finish(&[
                    ("command", o.command.as_str()),
                    ("quick", if o.quick { "true" } else { "false" }),
                ]);
                0
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options() {
        let o = parse_args(&sv(&["fig5", "--batches", "2,8", "--quick", "--out", "/tmp/x"]))
            .unwrap();
        assert_eq!(o.command, "fig5");
        assert_eq!(o.batches, vec![2, 8]);
        assert!(o.quick);
        assert_eq!(o.out, "/tmp/x");
    }

    #[test]
    fn rejects_unknown_flags_and_bad_values() {
        assert!(parse_args(&sv(&["fig5", "--bogus"])).is_err());
        assert!(parse_args(&sv(&["fig5", "--batches", "a,b"])).is_err());
        assert!(parse_args(&sv(&["fig5", "--out"])).is_err());
    }

    #[test]
    fn unknown_command_fails_generation() {
        let o = parse_args(&sv(&["notacmd"])).unwrap();
        assert!(generate(&o).is_err());
    }

    #[test]
    fn quick_table_commands_generate() {
        for cmd in ["table2", "table3", "fig1"] {
            let o = parse_args(&sv(&[cmd])).unwrap();
            let rs = generate(&o).unwrap();
            assert!(!rs.is_empty());
        }
    }
}
