//! Results store: persists every generated report (CSV per experiment
//! plus a run-level JSON index) so studies are reproducible and
//! diffable.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::Json;

use super::reports::Report;

/// A store rooted at an output directory (default `results/`).
pub struct Store {
    dir: PathBuf,
    index: Vec<(String, String)>,
}

impl Store {
    pub fn new(dir: impl AsRef<Path>) -> Self {
        Store { dir: dir.as_ref().to_path_buf(), index: vec![] }
    }

    /// Persist one report: `<dir>/<id>.csv`.
    pub fn save(&mut self, report: &Report) -> Result<PathBuf> {
        let path = self.dir.join(format!("{}.csv", report.id.to_lowercase()));
        report.csv.write(&path)?;
        self.index
            .push((report.id.to_string(), report.title.clone()));
        Ok(path)
    }

    /// Path a named auxiliary blob would occupy in this store.
    pub fn blob_path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Persist a named auxiliary blob (e.g. the sweep memo cache)
    /// alongside the report CSVs, without touching the run index.
    pub fn save_blob(&self, name: &str, contents: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)?;
        let path = self.blob_path(name);
        std::fs::write(&path, contents)?;
        Ok(path)
    }

    /// Read a named auxiliary blob if present.
    pub fn read_blob(&self, name: &str) -> Result<Option<String>> {
        let path = self.blob_path(name);
        if !path.exists() {
            return Ok(None);
        }
        Ok(Some(std::fs::read_to_string(path)?))
    }

    /// Write the run index (`index.json`) listing everything saved.
    pub fn finish(&self, meta: &[(&str, &str)]) -> Result<PathBuf> {
        let mut root = Json::obj();
        let mut m = Json::obj();
        for (k, v) in meta {
            m.set(k, Json::Str(v.to_string()));
        }
        root.set("meta", m);
        let mut arts = Json::obj();
        for (id, title) in &self.index {
            let mut a = Json::obj();
            a.set("title", Json::Str(title.clone()));
            a.set("file", Json::Str(format!("{}.csv", id.to_lowercase())));
            arts.set(id, a);
        }
        root.set("experiments", arts);
        let path = self.dir.join("index.json");
        std::fs::create_dir_all(&self.dir)?;
        std::fs::write(&path, root.to_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::Csv;

    #[test]
    fn save_and_index_roundtrip() {
        let dir = std::env::temp_dir().join("deepnvm_store_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = Store::new(&dir);
        let mut csv = Csv::new(&["a", "b"]);
        csv.row(&["1".into(), "2".into()]);
        let r = Report {
            id: "T9",
            title: "Test table".into(),
            text: "x".into(),
            csv,
        };
        let p = store.save(&r).unwrap();
        assert!(p.exists());
        let idx = store.finish(&[("cmd", "test")]).unwrap();
        let parsed =
            crate::util::json::parse(&std::fs::read_to_string(idx).unwrap())
                .unwrap();
        assert_eq!(
            parsed
                .get("experiments")
                .unwrap()
                .get("T9")
                .unwrap()
                .get("file")
                .unwrap()
                .as_str()
                .unwrap(),
            "t9.csv"
        );
    }

    #[test]
    fn blob_roundtrip() {
        let dir = std::env::temp_dir().join("deepnvm_store_blob_test");
        let _ = std::fs::remove_dir_all(&dir);
        let store = Store::new(&dir);
        assert!(store.read_blob("memo.json").unwrap().is_none());
        let p = store.save_blob("memo.json", "{\"v\": 1}").unwrap();
        assert!(p.exists());
        assert_eq!(
            store.read_blob("memo.json").unwrap().as_deref(),
            Some("{\"v\": 1}")
        );
    }
}
