//! DeepNVM++ — cross-layer modeling and optimization framework of
//! non-volatile memories (STT-MRAM / SOT-MRAM) vs SRAM for last-level
//! caches in GPU architectures running deep-learning workloads.
//!
//! Reproduction of: Inci, Isgenc, Marculescu, "DeepNVM++: Cross-Layer
//! Modeling and Optimization Framework of Non-Volatile Memories for Deep
//! Learning", IEEE TCAD 2021 (DOI 10.1109/TCAD.2021.3127148).

pub mod device;
pub mod nvsim;
pub mod workload;
pub mod gpusim;
pub mod analysis;
pub mod sweep;
pub mod obs;
pub mod serve;
pub mod runtime;
pub mod coordinator;
pub mod util;
