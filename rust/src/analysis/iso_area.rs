//! Iso-area study (paper §IV-B, Figs 6-8): within the SRAM baseline's
//! silicon footprint, STT fits 7 MB and SOT fits 10 MB. The larger
//! caches cut DRAM traffic (measured with the gpusim hierarchy
//! simulator, Fig 6); energy/EDP follow with and without the DRAM
//! terms (Figs 7-8).

use crate::device::MemTech;
use crate::gpusim::gpu::simulate_dnn;
use crate::gpusim::GpuConfig;
use crate::nvsim::CachePpa;
use crate::sweep::memo;
use crate::workload::models::{Dnn, Phase};
use crate::workload::traffic::TrafficModel;

use super::energy::{evaluate, DramCost};

const MB: u64 = 1024 * 1024;

/// Iso-area capacities (paper Table II): SRAM 3 MB footprint holds
/// STT 7 MB / SOT 10 MB.
pub const SRAM_MB: u64 = 3;
pub const STT_MB: u64 = 7;
pub const SOT_MB: u64 = 10;

/// Fig 6: DRAM-access reduction (%) vs L2 capacity, from the hierarchy
/// simulation of AlexNet (the paper's GPGPU-Sim + DarkNet setup).
pub fn dram_reduction_curve(capacities_mb: &[u64], batch: usize) -> Vec<(u64, f64)> {
    let dnn = Dnn::by_name("AlexNet").expect("zoo");
    let base = simulate_dnn(
        GpuConfig::gtx1080ti(SRAM_MB * MB),
        &dnn,
        Phase::Inference,
        batch,
    )
    .dram_total() as f64;
    capacities_mb
        .iter()
        .map(|&mb| {
            let s = simulate_dnn(
                GpuConfig::gtx1080ti(mb * MB),
                &dnn,
                Phase::Inference,
                batch,
            );
            (mb, 100.0 * (1.0 - s.dram_total() as f64 / base))
        })
        .collect()
}

/// DRAM reduction factor (0..1) for one capacity, from the simulation.
pub fn dram_reduction_at(mb: u64, batch: usize) -> f64 {
    let curve = dram_reduction_curve(&[mb], batch);
    curve[0].1 / 100.0
}

/// One iso-area result row.
#[derive(Clone, Debug)]
pub struct IsoAreaRow {
    pub dnn: &'static str,
    pub phase: Phase,
    pub tech: MemTech,
    pub capacity_mb: u64,
    pub dyn_norm: f64,
    pub leak_norm: f64,
    pub energy_norm: f64,
    /// Fig 8 left: EDP normalized to SRAM, cache terms only.
    pub edp_norm_no_dram: f64,
    /// Fig 8 right: EDP normalized to SRAM with DRAM energy+latency.
    pub edp_norm_with_dram: f64,
}

/// Designs at the iso-area points (served from the sweep memo).
pub fn iso_caches() -> [(MemTech, u64, CachePpa); 3] {
    [
        (MemTech::Sram, SRAM_MB, memo::tuned(MemTech::Sram, SRAM_MB * MB).ppa),
        (MemTech::SttMram, STT_MB, memo::tuned(MemTech::SttMram, STT_MB * MB).ppa),
        (MemTech::SotMram, SOT_MB, memo::tuned(MemTech::SotMram, SOT_MB * MB).ppa),
    ]
}

/// Figs 7-8 study. DRAM reduction factors come from the gpusim curve
/// (pass `None` to re-simulate, or supply cached factors for speed).
pub fn study(reductions: Option<(f64, f64)>) -> Vec<IsoAreaRow> {
    let caches = iso_caches();
    let (red_stt, red_sot) = reductions.unwrap_or_else(|| {
        let b = Phase::Inference.paper_batch();
        (dram_reduction_at(STT_MB, b), dram_reduction_at(SOT_MB, b))
    });
    let dram = DramCost::default();
    let mut rows = Vec::new();
    for dnn in Dnn::zoo() {
        for phase in Phase::ALL {
            // L2 transactions are schedule properties (identical across
            // technologies); DRAM traffic shrinks with the larger MRAMs.
            let base_traffic =
                TrafficModel { l2_bytes: SRAM_MB * MB, ..Default::default() };
            let stats = base_traffic.run_paper(&dnn, phase);
            let scale = |f: f64| {
                let mut s = stats;
                s.dram_reads = (s.dram_reads as f64 * (1.0 - f)) as u64;
                s.dram_writes = (s.dram_writes as f64 * (1.0 - f)) as u64;
                s
            };
            let sram = evaluate(&stats, &caches[0].2, None);
            let sram_dram = evaluate(&stats, &caches[0].2, Some(dram));
            for &(tech, mb, ppa) in &caches[1..] {
                let red = if tech == MemTech::SttMram { red_stt } else { red_sot };
                let s2 = scale(red);
                let e = evaluate(&s2, &ppa, None);
                let e_dram = evaluate(&s2, &ppa, Some(dram));
                rows.push(IsoAreaRow {
                    dnn: dnn.name,
                    phase,
                    tech,
                    capacity_mb: mb,
                    dyn_norm: e.dynamic() / sram.dynamic(),
                    leak_norm: e.leakage / sram.leakage,
                    energy_norm: e.energy() / sram.energy(),
                    edp_norm_no_dram: e.edp() / sram.edp(),
                    edp_norm_with_dram: e_dram.edp() / sram_dram.edp(),
                });
            }
        }
    }
    rows
}

/// Mean of a row field for one tech.
pub fn mean_of(
    rows: &[IsoAreaRow],
    tech: MemTech,
    f: impl Fn(&IsoAreaRow) -> f64,
) -> f64 {
    let v: Vec<f64> =
        rows.iter().filter(|r| r.tech == tech).map(f).collect();
    crate::util::stats::mean(&v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_reduction_monotone_and_in_band() {
        // Paper (batch 4): 14.6% at 7 MB (STT), 19.8% at 10 MB (SOT);
        // our hierarchy lands at ~11% / ~12% with the curve's shape
        // preserved (monotone, ~20% at 24 MB) — see EXPERIMENTS.md §F6.
        let curve = dram_reduction_curve(&[7, 10, 24], 4);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 0.5, "non-monotone: {curve:?}");
        }
        let at7 = curve.iter().find(|(mb, _)| *mb == 7).unwrap().1;
        let at10 = curve.iter().find(|(mb, _)| *mb == 10).unwrap().1;
        let at24 = curve.iter().find(|(mb, _)| *mb == 24).unwrap().1;
        assert!((6.0..25.0).contains(&at7), "7MB reduction {at7}");
        assert!((8.0..30.0).contains(&at10), "10MB reduction {at10}");
        assert!(at24 > at10, "curve must keep growing to 24MB");
    }

    #[test]
    fn fig7_fig8_shape() {
        // cached reduction factors (paper's: 0.146 / 0.198) to keep the
        // test independent of the simulation runtime
        let rows = study(Some((0.146, 0.198)));
        assert_eq!(rows.len(), 5 * 2 * 2);

        // Fig 7: dynamic STT ~2.5x, SOT ~1.4x; leakage 2.1x / 2.3x lower.
        let stt_dyn = mean_of(&rows, MemTech::SttMram, |r| r.dyn_norm);
        let sot_dyn = mean_of(&rows, MemTech::SotMram, |r| r.dyn_norm);
        assert!((1.5..4.0).contains(&stt_dyn), "STT dyn {stt_dyn}");
        assert!((1.0..2.5).contains(&sot_dyn), "SOT dyn {sot_dyn}");

        let stt_leak = mean_of(&rows, MemTech::SttMram, |r| r.leak_norm);
        let sot_leak = mean_of(&rows, MemTech::SotMram, |r| r.leak_norm);
        assert!(
            (1.2..5.0).contains(&(1.0 / stt_leak)),
            "STT leak red {}",
            1.0 / stt_leak
        );
        assert!(
            (1.2..6.0).contains(&(1.0 / sot_leak)),
            "SOT leak red {}",
            1.0 / sot_leak
        );

        // Fig 8: with DRAM included the EDP reduction must improve over
        // the cache-only number (bigger caches pay off off-chip).
        // Paper: ~1.1x/1.2x without DRAM -> 2x/2.3x with DRAM; our
        // model reproduces the no-DRAM point closely and the with-DRAM
        // direction (weaker magnitude — EXPERIMENTS.md §F8).
        let stt_no = mean_of(&rows, MemTech::SttMram, |r| r.edp_norm_no_dram);
        let stt_with = mean_of(&rows, MemTech::SttMram, |r| r.edp_norm_with_dram);
        let sot_no = mean_of(&rows, MemTech::SotMram, |r| r.edp_norm_no_dram);
        let sot_with = mean_of(&rows, MemTech::SotMram, |r| r.edp_norm_with_dram);
        assert!(
            stt_with < stt_no * 1.05,
            "DRAM terms should help iso-area STT: {stt_no} -> {stt_with}"
        );
        assert!((1.0 / stt_no) > 0.8, "STT EDP red (no DRAM) {}", 1.0 / stt_no);
        assert!((1.0 / sot_no) > 1.0, "SOT EDP red (no DRAM) {}", 1.0 / sot_no);
        assert!((1.0 / stt_with) > 1.05, "STT EDP red {}", 1.0 / stt_with);
        assert!((1.0 / sot_with) > 1.3, "SOT EDP red {}", 1.0 / sot_with);
        assert!(sot_with < stt_with, "SOT must beat STT iso-area");
    }

    #[test]
    fn capacity_ratio_matches_paper() {
        // 7/3 = 2.3x, 10/3 = 3.3x — the paper's headline capacity gain.
        assert!((STT_MB as f64 / SRAM_MB as f64 - 2.33).abs() < 0.01);
        assert!((SOT_MB as f64 / SRAM_MB as f64 - 3.33).abs() < 0.01);
    }
}
