//! Mobile design-space extension (paper §V, "Mobile design space
//! exploration for NVM" — called out as meriting further research;
//! implemented here as a first exploration).
//!
//! Scenario: the last-level cache of a mobile SoC running *inference
//! only* (Wu et al., HPCA'19: most mobile inference runs on CPUs), with
//! a small LLC (1-4 MB), battery-bound energy budgets, and latency
//! constraints per frame. The same cross-layer models apply; only the
//! platform constants change.

use crate::device::MemTech;
use crate::nvsim::explorer::tuned_cache;
use crate::workload::models::{Dnn, Phase};
use crate::workload::traffic::TrafficModel;

use super::energy::{evaluate, DramCost};

const MB: u64 = 1024 * 1024;

/// Mobile LPDDR4X-class DRAM: slower, slightly cheaper per bit than the
/// GDDR5X desktop part.
pub fn mobile_dram() -> DramCost {
    DramCost { energy_per_tx: 2.6e-9, latency_per_tx: 60e-9 / 4.0 }
}

/// One mobile result row.
#[derive(Clone, Copy, Debug)]
pub struct MobileRow {
    pub tech: MemTech,
    pub llc_mb: u64,
    pub dnn: &'static str,
    /// Energy per inference (J) — the battery metric.
    pub energy_per_inference: f64,
    /// Normalized to SRAM at the same capacity.
    pub energy_norm: f64,
    pub edp_norm: f64,
}

/// Mobile inference study: batch 1 (interactive latency), LLC sweep.
pub fn study(llc_mbs: &[u64]) -> Vec<MobileRow> {
    let dram = mobile_dram();
    let mut out = Vec::new();
    for &mb in llc_mbs {
        let sram = tuned_cache(MemTech::Sram, mb * MB).ppa;
        let traffic = TrafficModel { l2_bytes: mb * MB, ..Default::default() };
        for dnn in Dnn::zoo() {
            // batch 1: a user-facing mobile inference
            let stats = traffic.run(&dnn, Phase::Inference, 1);
            let base = evaluate(&stats, &sram, Some(dram));
            for tech in [MemTech::SttMram, MemTech::SotMram] {
                let ppa = tuned_cache(tech, mb * MB).ppa;
                let e = evaluate(&stats, &ppa, Some(dram));
                out.push(MobileRow {
                    tech,
                    llc_mb: mb,
                    dnn: dnn.name,
                    energy_per_inference: e.energy(),
                    energy_norm: e.energy() / base.energy(),
                    edp_norm: e.edp() / base.edp(),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn mram_saves_energy_on_mobile_inference() {
        let rows = study(&[2]);
        for r in &rows {
            assert!(
                r.energy_norm < 1.0,
                "{} {} {}MB: energy norm {}",
                r.tech,
                r.dnn,
                r.llc_mb,
                r.energy_norm
            );
        }
        // SOT (low write energy + low leak) should be the best fit for
        // read-heavy batch-1 inference.
        let stt: Vec<f64> = rows
            .iter()
            .filter(|r| r.tech == MemTech::SttMram)
            .map(|r| r.energy_norm)
            .collect();
        let sot: Vec<f64> = rows
            .iter()
            .filter(|r| r.tech == MemTech::SotMram)
            .map(|r| r.energy_norm)
            .collect();
        assert!(mean(&sot) < mean(&stt));
    }

    #[test]
    fn squeezenet_is_the_frugal_mobile_network() {
        // SqueezeNet was designed for edge deployment; it must burn the
        // least absolute energy per inference of the zoo.
        let rows = study(&[2]);
        let energy = |name: &str| {
            rows.iter()
                .filter(|r| r.dnn == name && r.tech == MemTech::SotMram)
                .map(|r| r.energy_per_inference)
                .next()
                .unwrap()
        };
        for other in ["AlexNet", "VGG-16", "ResNet-18", "GoogLeNet"] {
            assert!(
                energy("SqueezeNet") < energy(other),
                "SqueezeNet vs {other}"
            );
        }
    }

    #[test]
    fn benefits_hold_across_llc_sizes() {
        let rows = study(&[1, 4]);
        for mb in [1u64, 4] {
            let sel: Vec<f64> = rows
                .iter()
                .filter(|r| r.llc_mb == mb)
                .map(|r| r.edp_norm)
                .collect();
            assert!(mean(&sel) < 1.0, "{}MB mean EDP norm {}", mb, mean(&sel));
        }
    }
}
