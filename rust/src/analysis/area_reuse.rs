//! Area-reuse extension (paper §V, "Implications of dense NVM caches on
//! logic usage" — left as future work there, implemented here).
//!
//! At iso-capacity, the MRAM cache frees 58-65% of the SRAM cache's
//! silicon. This module quantifies what the reclaimed whitespace buys:
//!
//! * **More SMs**: extra streaming multiprocessors at the 1080 Ti's
//!   per-SM area, raising peak throughput.
//! * **More L2**: growing the MRAM cache until it refills the SRAM
//!   footprint (this degenerates into the iso-area study, included for
//!   continuity).
//!
//! The throughput model is first-order: compute-bound layers scale with
//! SM count; memory-bound layers do not. The per-layer boundedness comes
//! from the roofline of the traffic model.

use crate::device::MemTech;
use crate::nvsim::explorer::tuned_cache;
use crate::workload::models::{Dnn, Phase};
use crate::workload::traffic::{TrafficModel, TX_BYTES};

const MB: u64 = 1024 * 1024;

/// GTX 1080 Ti derived constants for the reuse model.
pub mod gpu {
    /// Die area (mm^2), GP102.
    pub const DIE_AREA_MM2: f64 = 471.0;
    /// SM count.
    pub const N_SMS: f64 = 28.0;
    /// Approximate area of one SM + its slice of fabric (mm^2):
    /// ~60% of the die is SM tiles on GP102.
    pub const SM_AREA_MM2: f64 = DIE_AREA_MM2 * 0.60 / N_SMS;
    /// Peak per-SM fp32 MAC throughput (MAC/s): 128 lanes x 1.48 GHz.
    pub const SM_MACS_PER_S: f64 = 128.0 * 1.48e9;
    /// Sustained L2 bandwidth (B/s) for the roofline split.
    pub const L2_BW: f64 = 1.2e12;
}

/// Outcome of spending the freed area on compute.
///
/// A full GP102 SM (~10 mm^2) does not fit in the ~3.4 mm^2 the MRAM
/// cache frees — a finding in itself: at iso-capacity the reclaimed
/// whitespace buys *fractional* SM-equivalents (extra CUDA-core
/// clusters / wider register files), so the speedup model works in
/// SM-equivalents rather than whole SMs.
#[derive(Clone, Copy, Debug)]
pub struct ReuseResult {
    pub tech: MemTech,
    /// Area freed by the denser cache (mm^2).
    pub freed_mm2: f64,
    /// Fractional SM-equivalents of compute that fit.
    pub sm_equivalents: f64,
    /// Workload-mean speedup from the extra compute (roofline model).
    pub mean_speedup: f64,
}

/// Fraction of a workload's time that is compute-bound under the
/// roofline split (MACs / SM throughput vs bytes / L2 bandwidth).
fn compute_bound_fraction(dnn: &Dnn, phase: Phase) -> f64 {
    let stats = TrafficModel::default().run_paper(dnn, phase);
    let t_compute = stats.macs as f64 / (gpu::N_SMS * gpu::SM_MACS_PER_S);
    let bytes = (stats.l2_reads + stats.l2_writes) as f64 * TX_BYTES as f64;
    let t_mem = bytes / gpu::L2_BW;
    t_compute / (t_compute + t_mem)
}

/// Evaluate spending the iso-capacity area savings on extra SMs.
pub fn study() -> Vec<ReuseResult> {
    let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
    let mut out = Vec::new();
    for tech in [MemTech::SttMram, MemTech::SotMram] {
        let mram = tuned_cache(tech, 3 * MB).ppa;
        let freed_mm2 = (sram.area - mram.area) * 1e6;
        let sm_equivalents = freed_mm2 / gpu::SM_AREA_MM2;
        let sm_scale = (gpu::N_SMS + sm_equivalents) / gpu::N_SMS;

        // Amdahl over the compute-bound fraction, averaged across zoo.
        let mut speedups = Vec::new();
        for dnn in Dnn::zoo() {
            for phase in Phase::ALL {
                let f = compute_bound_fraction(&dnn, phase);
                speedups.push(1.0 / ((1.0 - f) + f / sm_scale));
            }
        }
        out.push(ReuseResult {
            tech,
            freed_mm2,
            sm_equivalents,
            mean_speedup: crate::util::stats::mean(&speedups),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freed_area_matches_paper_percentages() {
        // Paper §V: 58% (STT) and 65% (SOT) area reduction on average.
        let rows = study();
        let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa.area * 1e6;
        for r in &rows {
            let pct = r.freed_mm2 / sram;
            assert!((0.45..0.75).contains(&pct), "{}: freed {pct}", r.tech);
        }
    }

    #[test]
    fn freed_area_buys_fractional_sms_only() {
        // The honest §V answer: the reclaimed whitespace at 3 MB is a
        // fraction of one SM — meaningful for core clusters, not for
        // whole SMs.
        for r in study() {
            assert!(
                (0.1..1.0).contains(&r.sm_equivalents),
                "{}: {} SM-equivalents",
                r.tech,
                r.sm_equivalents
            );
        }
    }

    #[test]
    fn speedup_above_one_but_amdahl_limited() {
        for r in study() {
            let sm_scale = (gpu::N_SMS + r.sm_equivalents) / gpu::N_SMS;
            assert!(r.mean_speedup > 1.0, "{}", r.tech);
            assert!(
                r.mean_speedup < sm_scale,
                "{}: speedup {} exceeds SM scaling {}",
                r.tech,
                r.mean_speedup,
                sm_scale
            );
        }
    }

    #[test]
    fn compute_bound_fraction_sane() {
        for d in Dnn::zoo() {
            let f = compute_bound_fraction(&d, Phase::Inference);
            assert!((0.0..1.0).contains(&f), "{}: {f}", d.name);
        }
    }
}
