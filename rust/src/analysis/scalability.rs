//! Scalability study (paper §IV-C, Figs 9-10): sweep cache capacity
//! 1-32 MB, EDAP-tune each (memory, capacity) point independently, and
//! project workload energy/latency/EDP vs SRAM.
//!
//! Both figures are thin queries over the shared [`crate::sweep`]
//! grid: points are evaluated by the parallel executor and every
//! circuit solve is memoized process-wide, so `fig9`, `fig10` and
//! `deepnvm all` share one set of Algorithm-1 solves. Numerical
//! equivalence with the original serial path is pinned by
//! `rust/tests/sweep.rs`.

use crate::device::MemTech;
use crate::nvsim::explorer::TunedConfig;
use crate::nvsim::TechSel;
use crate::sweep::{self, SweepSpec};
use crate::workload::models::{Dnn, Phase};

/// The paper's sweep (Fig 9/10 x-axis) — one source of truth in the
/// explorer, shared with [`crate::sweep::spec::DEFAULT_CAPACITIES_MB`].
pub const CAPACITIES_MB: [u64; 6] = crate::nvsim::explorer::PAPER_CAPACITIES_MB;

/// Fig 9: PPA of the tuned design at each (tech, capacity).
pub fn ppa_sweep(capacities_mb: &[u64]) -> Vec<TunedConfig> {
    ppa_sweep_with(capacities_mb, 0, sweep::memo::global())
        .expect("static fig9 axes expand")
}

/// As [`ppa_sweep`] against an explicit worker budget and memo cache
/// (the serve subsystem queries its own resident cache through this;
/// `jobs = 0` means one worker per core). Fallible because serve
/// feeds it untrusted capacity axes; spec validation errors surface
/// here instead of panicking.
pub fn ppa_sweep_with(
    capacities_mb: &[u64],
    jobs: usize,
    memo: &sweep::Memo,
) -> anyhow::Result<Vec<TunedConfig>> {
    if capacities_mb.is_empty() {
        return Ok(Vec::new()); // total on empty input, like the legacy loop
    }
    let spec = SweepSpec::circuit_only(MemTech::ALL.to_vec(), capacities_mb.to_vec());
    let res = sweep::run(&spec, jobs, memo)?;
    Ok(res.points.into_iter().map(|p| p.tuned).collect())
}

/// One Fig 10 point: normalized mean +/- std across the five workloads.
#[derive(Clone, Copy, Debug)]
pub struct ScalePoint {
    pub tech: MemTech,
    pub capacity_mb: u64,
    pub phase: Phase,
    pub energy_norm_mean: f64,
    pub energy_norm_std: f64,
    pub latency_norm_mean: f64,
    pub latency_norm_std: f64,
    pub edp_norm_mean: f64,
    pub edp_norm_std: f64,
}

/// Fig 10: for each capacity and phase, normalized energy / latency /
/// EDP of STT and SOT vs SRAM, mean and std across the workload zoo.
///
/// One shared swept grid supplies every per-(tech, capacity, workload,
/// phase) point; this function only aggregates. Within each group the
/// zoo order is preserved so the floating-point accumulation order —
/// and therefore every reported mean/std — matches the historical
/// serial loop bit-for-bit.
pub fn workload_sweep(capacities_mb: &[u64]) -> Vec<ScalePoint> {
    workload_sweep_with(capacities_mb, 0, sweep::memo::global())
        .expect("static fig10 axes expand")
}

/// As [`workload_sweep`] against an explicit worker budget and memo
/// cache (fallible for serve's untrusted axes, like
/// [`ppa_sweep_with`]).
pub fn workload_sweep_with(
    capacities_mb: &[u64],
    jobs: usize,
    memo: &sweep::Memo,
) -> anyhow::Result<Vec<ScalePoint>> {
    if capacities_mb.is_empty() {
        return Ok(Vec::new()); // total on empty input, like the legacy loop
    }
    let spec = SweepSpec {
        techs: TechSel::pures(&[MemTech::SttMram, MemTech::SotMram]),
        capacities_mb: capacities_mb.to_vec(),
        dnns: Dnn::zoo().iter().map(|d| d.name.to_string()).collect(),
        phases: Phase::ALL.to_vec(),
        batches: vec![],
        nodes_nm: vec![16],
        filters: vec![],
    };
    let res = sweep::run(&spec, jobs, memo)?;

    let mut out = Vec::new();
    for &mb in capacities_mb {
        for &tech in &[MemTech::SttMram, MemTech::SotMram] {
            for phase in Phase::ALL {
                let mut e_norms = vec![];
                let mut t_norms = vec![];
                let mut edp_norms = vec![];
                for p in res.points.iter().filter(|p| {
                    p.point.tech == tech
                        && p.point.capacity_mb == mb
                        && p.point.workload.is_some_and(|w| w.phase == phase)
                }) {
                    let e = p.eval.expect("workload points carry an eval");
                    e_norms.push(e.energy_norm);
                    t_norms.push(e.latency_norm);
                    edp_norms.push(e.edp_norm);
                }
                use crate::util::stats::{mean, std_dev};
                out.push(ScalePoint {
                    tech,
                    capacity_mb: mb,
                    phase,
                    energy_norm_mean: mean(&e_norms),
                    energy_norm_std: std_dev(&e_norms),
                    latency_norm_mean: mean(&t_norms),
                    latency_norm_std: std_dev(&t_norms),
                    edp_norm_mean: mean(&edp_norms),
                    edp_norm_std: std_dev(&edp_norms),
                });
            }
        }
    }
    Ok(out)
}

/// One point of the cross-node scalability study: the EDAP-tuned cache
/// at (node, tech, capacity), with the circuit-level figures the
/// journal extension plots against deeply-scaled nodes.
#[derive(Clone, Copy, Debug)]
pub struct NodePoint {
    pub node_nm: u32,
    pub tech: MemTech,
    pub capacity_mb: u64,
    pub read_latency: f64,
    pub write_latency: f64,
    pub leakage_power: f64,
    pub area: f64,
    /// Circuit-level EDAP figure of merit ([`crate::nvsim::CachePpa::edap`]).
    pub edap: f64,
}

/// Cross-node scalability sweep: EDAP-tune every (node, tech,
/// capacity) and report PPA + EDAP per point, in spec order (node
/// outermost). The cross-node co-optimization view the 7/5 nm
/// calibration lights up.
pub fn node_sweep(capacities_mb: &[u64], nodes_nm: &[u32]) -> anyhow::Result<Vec<NodePoint>> {
    node_sweep_with(capacities_mb, nodes_nm, 0, sweep::memo::global())
}

/// As [`node_sweep`] against an explicit worker budget and memo cache
/// (fallible: both axes may arrive from untrusted CLI/HTTP inputs).
pub fn node_sweep_with(
    capacities_mb: &[u64],
    nodes_nm: &[u32],
    jobs: usize,
    memo: &sweep::Memo,
) -> anyhow::Result<Vec<NodePoint>> {
    if capacities_mb.is_empty() || nodes_nm.is_empty() {
        return Ok(Vec::new());
    }
    let spec = SweepSpec {
        nodes_nm: nodes_nm.to_vec(),
        ..SweepSpec::circuit_only(MemTech::ALL.to_vec(), capacities_mb.to_vec())
    };
    let res = sweep::run(&spec, jobs, memo)?;
    Ok(res
        .points
        .into_iter()
        .map(|p| NodePoint {
            node_nm: p.point.node_nm,
            tech: p.point.tech.pure().expect("circuit_only specs are pure"),
            capacity_mb: p.point.capacity_mb,
            read_latency: p.tuned.ppa.read_latency,
            write_latency: p.tuned.ppa.write_latency,
            leakage_power: p.tuned.ppa.leakage_power,
            area: p.tuned.ppa.area,
            edap: p.tuned.ppa.edap(),
        })
        .collect())
}

/// Per (node, NVM technology): the smallest swept capacity at which
/// the NVM cache's EDAP beats the same-node SRAM cache — the
/// crossover point the scalability story hinges on. `None` when SRAM
/// wins across the whole swept range.
#[derive(Clone, Copy, Debug)]
pub struct NodeCrossover {
    pub node_nm: u32,
    pub tech: MemTech,
    pub crossover_mb: Option<u64>,
}

/// Extract the NVM-vs-SRAM crossover per node from a [`node_sweep`]
/// result.
pub fn nvm_crossovers(points: &[NodePoint]) -> Vec<NodeCrossover> {
    // Order-preserving unique: the input is grouped by node when it
    // comes straight from node_sweep, but callers may re-sort/filter.
    let mut nodes: Vec<u32> = Vec::new();
    for p in points {
        if !nodes.contains(&p.node_nm) {
            nodes.push(p.node_nm);
        }
    }
    let mut out = Vec::new();
    for &node in &nodes {
        for tech in [MemTech::SttMram, MemTech::SotMram] {
            let mut caps: Vec<u64> = points
                .iter()
                .filter(|p| p.node_nm == node && p.tech == tech)
                .map(|p| p.capacity_mb)
                .collect();
            caps.sort_unstable();
            let at = |t: MemTech, mb: u64| {
                points
                    .iter()
                    .find(|p| p.node_nm == node && p.tech == t && p.capacity_mb == mb)
                    .map(|p| p.edap)
            };
            let crossover_mb = caps.into_iter().find(|&mb| {
                matches!(
                    (at(tech, mb), at(MemTech::Sram, mb)),
                    (Some(nvm), Some(sram)) if nvm < sram
                )
            });
            out.push(NodeCrossover { node_nm: node, tech, crossover_mb });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn fig9_area_gap_grows_with_capacity() {
        let sweep = ppa_sweep(&[2, 16]);
        let get = |tech, mb: u64| {
            sweep
                .iter()
                .find(|c| c.tech == tech && c.capacity_bytes == mb * MB)
                .unwrap()
                .ppa
        };
        let r2 = get(MemTech::Sram, 2).area / get(MemTech::SttMram, 2).area;
        let r16 = get(MemTech::Sram, 16).area / get(MemTech::SttMram, 16).area;
        assert!(r16 > r2 * 0.95, "area advantage must not shrink: {r2} -> {r16}");
        assert!(r16 > 2.0, "STT area advantage at 16MB: {r16}");
    }

    #[test]
    fn fig9_read_latency_crossover() {
        // Paper: SRAM reads faster below ~3-4 MB, MRAM faster beyond.
        let sweep = ppa_sweep(&[1, 16, 32]);
        let get = |tech, mb: u64| {
            sweep
                .iter()
                .find(|c| c.tech == tech && c.capacity_bytes == mb * MB)
                .unwrap()
                .ppa
        };
        assert!(
            get(MemTech::Sram, 1).read_latency
                < get(MemTech::SttMram, 1).read_latency,
            "SRAM must win small reads"
        );
        assert!(
            get(MemTech::SttMram, 32).read_latency
                < get(MemTech::Sram, 32).read_latency,
            "STT must win large reads"
        );
        // STT write latency worst everywhere (device limit)
        for mb in [1u64, 16, 32] {
            assert!(
                get(MemTech::SttMram, mb).write_latency
                    > get(MemTech::Sram, mb).write_latency
            );
        }
    }

    #[test]
    fn fig10_energy_reduction_grows_with_capacity() {
        let pts = workload_sweep(&[2, 16]);
        let red = |tech, mb, ph| {
            1.0 / pts
                .iter()
                .find(|p| p.tech == tech && p.capacity_mb == mb && p.phase == ph)
                .unwrap()
                .energy_norm_mean
        };
        for tech in [MemTech::SttMram, MemTech::SotMram] {
            for ph in Phase::ALL {
                let r2 = red(tech, 2, ph);
                let r16 = red(tech, 16, ph);
                assert!(
                    r16 > r2,
                    "{tech} {}: energy reduction must grow: {r2} -> {r16}",
                    ph.name()
                );
            }
        }
    }

    #[test]
    fn fig10_edp_reduction_large_at_32mb() {
        // Paper: up to 65x (STT) / 95x (SOT) EDP reduction at large
        // capacity. Our structural model preserves the shape (reduction
        // grows with capacity, SOT > STT, several-x at 32 MB) at weaker
        // magnitude — the paper's NVSim runs degrade SRAM faster at
        // scale than our calibration does (EXPERIMENTS.md §F10).
        let pts = workload_sweep(&[32]);
        let red = |tech| {
            let v: Vec<f64> = pts
                .iter()
                .filter(|p| p.tech == tech)
                .map(|p| 1.0 / p.edp_norm_mean)
                .collect();
            crate::util::stats::max(&v)
        };
        let stt = red(MemTech::SttMram);
        let sot = red(MemTech::SotMram);
        assert!(stt > 5.0, "STT 32MB EDP reduction {stt}");
        assert!(sot > 8.0, "SOT 32MB EDP reduction {sot}");
        assert!(sot > stt, "SOT must beat STT at scale");
    }

    #[test]
    fn error_bars_are_finite_and_nonnegative() {
        for p in workload_sweep(&[4]) {
            assert!(p.energy_norm_std >= 0.0 && p.energy_norm_std.is_finite());
            assert!(p.edp_norm_std >= 0.0);
            assert!(p.latency_norm_mean > 0.0);
        }
    }

    #[test]
    fn node_sweep_covers_the_grid_with_distinct_nodes() {
        let memo = sweep::Memo::new();
        let pts = node_sweep_with(&[2, 8], &[16, 7], 2, &memo).unwrap();
        assert_eq!(pts.len(), 2 * 3 * 2, "nodes x techs x caps");
        for p in &pts {
            assert!(p.edap > 0.0 && p.edap.is_finite());
            assert!(p.area > 0.0 && p.leakage_power > 0.0);
        }
        // per-node designs are distinct: 7 nm is denser at iso-capacity
        let area = |node, tech, mb| {
            pts.iter()
                .find(|p| p.node_nm == node && p.tech == tech && p.capacity_mb == mb)
                .unwrap()
                .area
        };
        for tech in MemTech::ALL {
            for mb in [2u64, 8] {
                assert!(
                    area(7, tech, mb) < area(16, tech, mb),
                    "{tech} {mb}MB must shrink at 7nm"
                );
            }
        }
        // empty axes are total
        assert!(node_sweep_with(&[], &[16], 1, &memo).unwrap().is_empty());
        assert!(node_sweep_with(&[2], &[], 1, &memo).unwrap().is_empty());
        // uncalibrated axis surfaces the spec error
        assert!(node_sweep_with(&[2], &[9], 1, &memo).is_err());
    }

    #[test]
    fn nvm_crossover_exists_and_moves_down_at_deep_nodes() {
        let memo = sweep::Memo::new();
        let pts =
            node_sweep_with(&[1, 2, 4, 8, 16, 32], &[16, 7, 5], 0, &memo).unwrap();
        let xs = nvm_crossovers(&pts);
        assert_eq!(xs.len(), 3 * 2, "nodes x NVM techs");
        let get = |node, tech| {
            xs.iter()
                .find(|x| x.node_nm == node && x.tech == tech)
                .unwrap()
                .crossover_mb
        };
        for tech in [MemTech::SttMram, MemTech::SotMram] {
            for node in [16u32, 7, 5] {
                assert!(
                    get(node, tech).is_some(),
                    "{tech} must overtake SRAM within 32MB at {node}nm"
                );
            }
            // deeply-scaled SRAM leaks harder, so the crossover can
            // only hold or move toward smaller capacities
            assert!(get(7, tech).unwrap() <= get(16, tech).unwrap(), "{tech}");
            assert!(get(5, tech).unwrap() <= get(16, tech).unwrap(), "{tech}");
        }
    }
}
