//! Scalability study (paper §IV-C, Figs 9-10): sweep cache capacity
//! 1-32 MB, EDAP-tune each (memory, capacity) point independently, and
//! project workload energy/latency/EDP vs SRAM.

use crate::device::MemTech;
use crate::nvsim::explorer::{tuned_cache, TunedConfig};
use crate::workload::models::{Dnn, Phase};
use crate::workload::traffic::TrafficModel;

use super::energy::{evaluate, DramCost};

const MB: u64 = 1024 * 1024;

/// The paper's sweep (Fig 9/10 x-axis).
pub const CAPACITIES_MB: [u64; 6] = [1, 2, 4, 8, 16, 32];

/// Fig 9: PPA of the tuned design at each (tech, capacity).
pub fn ppa_sweep(capacities_mb: &[u64]) -> Vec<TunedConfig> {
    let mut out = Vec::new();
    for &tech in &MemTech::ALL {
        for &mb in capacities_mb {
            out.push(tuned_cache(tech, mb * MB));
        }
    }
    out
}

/// One Fig 10 point: normalized mean +/- std across the five workloads.
#[derive(Clone, Copy, Debug)]
pub struct ScalePoint {
    pub tech: MemTech,
    pub capacity_mb: u64,
    pub phase: Phase,
    pub energy_norm_mean: f64,
    pub energy_norm_std: f64,
    pub latency_norm_mean: f64,
    pub latency_norm_std: f64,
    pub edp_norm_mean: f64,
    pub edp_norm_std: f64,
}

/// Fig 10: for each capacity and phase, normalized energy / latency /
/// EDP of STT and SOT vs SRAM, mean and std across the workload zoo.
pub fn workload_sweep(capacities_mb: &[u64]) -> Vec<ScalePoint> {
    let dram = DramCost::default();
    let mut out = Vec::new();
    for &mb in capacities_mb {
        let sram = tuned_cache(MemTech::Sram, mb * MB).ppa;
        let traffic = TrafficModel { l2_bytes: mb * MB, ..Default::default() };
        for &tech in &[MemTech::SttMram, MemTech::SotMram] {
            let ppa = tuned_cache(tech, mb * MB).ppa;
            for phase in Phase::ALL {
                let mut e_norms = vec![];
                let mut t_norms = vec![];
                let mut edp_norms = vec![];
                for dnn in Dnn::zoo() {
                    let stats = traffic.run_paper(&dnn, phase);
                    let base = evaluate(&stats, &sram, Some(dram));
                    let e = evaluate(&stats, &ppa, Some(dram));
                    e_norms.push(e.energy() / base.energy());
                    t_norms.push(e.time_total / base.time_total);
                    edp_norms.push(e.edp() / base.edp());
                }
                use crate::util::stats::{mean, std_dev};
                out.push(ScalePoint {
                    tech,
                    capacity_mb: mb,
                    phase,
                    energy_norm_mean: mean(&e_norms),
                    energy_norm_std: std_dev(&e_norms),
                    latency_norm_mean: mean(&t_norms),
                    latency_norm_std: std_dev(&t_norms),
                    edp_norm_mean: mean(&edp_norms),
                    edp_norm_std: std_dev(&edp_norms),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_area_gap_grows_with_capacity() {
        let sweep = ppa_sweep(&[2, 16]);
        let get = |tech, mb: u64| {
            sweep
                .iter()
                .find(|c| c.tech == tech && c.capacity_bytes == mb * MB)
                .unwrap()
                .ppa
        };
        let r2 = get(MemTech::Sram, 2).area / get(MemTech::SttMram, 2).area;
        let r16 = get(MemTech::Sram, 16).area / get(MemTech::SttMram, 16).area;
        assert!(r16 > r2 * 0.95, "area advantage must not shrink: {r2} -> {r16}");
        assert!(r16 > 2.0, "STT area advantage at 16MB: {r16}");
    }

    #[test]
    fn fig9_read_latency_crossover() {
        // Paper: SRAM reads faster below ~3-4 MB, MRAM faster beyond.
        let sweep = ppa_sweep(&[1, 16, 32]);
        let get = |tech, mb: u64| {
            sweep
                .iter()
                .find(|c| c.tech == tech && c.capacity_bytes == mb * MB)
                .unwrap()
                .ppa
        };
        assert!(
            get(MemTech::Sram, 1).read_latency
                < get(MemTech::SttMram, 1).read_latency,
            "SRAM must win small reads"
        );
        assert!(
            get(MemTech::SttMram, 32).read_latency
                < get(MemTech::Sram, 32).read_latency,
            "STT must win large reads"
        );
        // STT write latency worst everywhere (device limit)
        for mb in [1u64, 16, 32] {
            assert!(
                get(MemTech::SttMram, mb).write_latency
                    > get(MemTech::Sram, mb).write_latency
            );
        }
    }

    #[test]
    fn fig10_energy_reduction_grows_with_capacity() {
        let pts = workload_sweep(&[2, 16]);
        let red = |tech, mb, ph| {
            1.0 / pts
                .iter()
                .find(|p| p.tech == tech && p.capacity_mb == mb && p.phase == ph)
                .unwrap()
                .energy_norm_mean
        };
        for tech in [MemTech::SttMram, MemTech::SotMram] {
            for ph in Phase::ALL {
                let r2 = red(tech, 2, ph);
                let r16 = red(tech, 16, ph);
                assert!(
                    r16 > r2,
                    "{tech} {}: energy reduction must grow: {r2} -> {r16}",
                    ph.name()
                );
            }
        }
    }

    #[test]
    fn fig10_edp_reduction_large_at_32mb() {
        // Paper: up to 65x (STT) / 95x (SOT) EDP reduction at large
        // capacity. Our structural model preserves the shape (reduction
        // grows with capacity, SOT > STT, several-x at 32 MB) at weaker
        // magnitude — the paper's NVSim runs degrade SRAM faster at
        // scale than our calibration does (EXPERIMENTS.md §F10).
        let pts = workload_sweep(&[32]);
        let red = |tech| {
            let v: Vec<f64> = pts
                .iter()
                .filter(|p| p.tech == tech)
                .map(|p| 1.0 / p.edp_norm_mean)
                .collect();
            crate::util::stats::max(&v)
        };
        let stt = red(MemTech::SttMram);
        let sot = red(MemTech::SotMram);
        assert!(stt > 5.0, "STT 32MB EDP reduction {stt}");
        assert!(sot > 8.0, "SOT 32MB EDP reduction {sot}");
        assert!(sot > stt, "SOT must beat STT at scale");
    }

    #[test]
    fn error_bars_are_finite_and_nonnegative() {
        for p in workload_sweep(&[4]) {
            assert!(p.energy_norm_std >= 0.0 && p.energy_norm_std.is_finite());
            assert!(p.edp_norm_std >= 0.0);
            assert!(p.latency_norm_mean > 0.0);
        }
    }
}
