//! Fig 1: L2 cache capacity in recent NVIDIA GPUs (public data, the
//! paper's motivation chart [17]).

/// (GPU, launch year, L2 capacity in KB).
pub const NVIDIA_L2_TREND: [(&str, u32, u32); 8] = [
    ("GTX 580", 2010, 768),
    ("GTX 680", 2012, 512),
    ("GTX 780", 2013, 1536),
    ("GTX 980", 2014, 2048),
    ("GTX 1080 Ti", 2017, 2816),
    ("Titan V", 2017, 4608),
    ("RTX 2080 Ti", 2018, 5632),
    ("RTX 3090", 2020, 6144),
];

/// Least-squares slope of capacity (KB) per year — the "current trend
/// of GPU architectures is towards increasing last-level cache
/// capacity" quantified.
pub fn trend_slope_kb_per_year() -> f64 {
    let n = NVIDIA_L2_TREND.len() as f64;
    let xs: Vec<f64> = NVIDIA_L2_TREND.iter().map(|t| t.1 as f64).collect();
    let ys: Vec<f64> = NVIDIA_L2_TREND.iter().map(|t| t.2 as f64).collect();
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trend_is_strongly_upward() {
        let slope = trend_slope_kb_per_year();
        assert!(slope > 300.0, "slope {slope} KB/year");
    }

    #[test]
    fn data_is_chronological() {
        for w in NVIDIA_L2_TREND.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }
}
