//! Iso-capacity study (paper §IV-A, Figs 3-5): replace the 1080 Ti's
//! 3 MB SRAM L2 with 3 MB STT-/SOT-MRAM and evaluate every workload.

use crate::device::MemTech;
use crate::nvsim::CachePpa;
use crate::sweep::memo;
use crate::workload::models::{Dnn, Phase};
use crate::workload::traffic::TrafficModel;

use super::energy::{evaluate, DramCost};

/// The iso-capacity point (bytes): the GTX 1080 Ti L2.
pub const ISO_CAPACITY: u64 = 3 * 1024 * 1024;

/// One (workload, phase, technology) result, normalized to SRAM.
#[derive(Clone, Debug)]
pub struct IsoCapRow {
    pub dnn: &'static str,
    pub phase: Phase,
    pub tech: MemTech,
    /// Fig 3 left: dynamic energy normalized to SRAM.
    pub dyn_norm: f64,
    /// Fig 3 right: leakage energy normalized to SRAM.
    pub leak_norm: f64,
    /// Fig 4 left: total energy normalized to SRAM (cache terms).
    pub energy_norm: f64,
    /// Fig 4 right: EDP normalized to SRAM (DRAM included, as in the
    /// paper's caption).
    pub edp_norm: f64,
    /// Read share of SRAM dynamic energy (diagnostic; ~0.83 in paper).
    pub sram_read_share: f64,
}

/// Cache designs for the three technologies at the iso-capacity point
/// (served from the process-wide sweep memo, so every study shares one
/// Algorithm-1 solve per technology).
pub fn iso_caches() -> [(MemTech, CachePpa); 3] {
    [
        (MemTech::Sram, memo::tuned(MemTech::Sram, ISO_CAPACITY).ppa),
        (MemTech::SttMram, memo::tuned(MemTech::SttMram, ISO_CAPACITY).ppa),
        (MemTech::SotMram, memo::tuned(MemTech::SotMram, ISO_CAPACITY).ppa),
    ]
}

/// Run the full Fig 3/4 study: 5 DNNs x {I, T} x {STT, SOT}.
pub fn study() -> Vec<IsoCapRow> {
    let caches = iso_caches();
    let traffic = TrafficModel { l2_bytes: ISO_CAPACITY, ..Default::default() };
    let dram = DramCost::default();
    let mut rows = Vec::new();
    for dnn in Dnn::zoo() {
        for phase in Phase::ALL {
            let stats = traffic.run_paper(&dnn, phase);
            let eval =
                |ppa: &CachePpa, d: Option<DramCost>| evaluate(&stats, ppa, d);
            let sram = eval(&caches[0].1, None);
            let sram_dram = eval(&caches[0].1, Some(dram));
            for &(tech, ppa) in &caches[1..] {
                let e = eval(&ppa, None);
                let e_dram = eval(&ppa, Some(dram));
                rows.push(IsoCapRow {
                    dnn: dnn.name,
                    phase,
                    tech,
                    dyn_norm: e.dynamic() / sram.dynamic(),
                    leak_norm: e.leakage / sram.leakage,
                    energy_norm: e.energy() / sram.energy(),
                    edp_norm: e_dram.edp() / sram_dram.edp(),
                    sram_read_share: sram.read_share(),
                });
            }
        }
    }
    rows
}

/// Fig 5: EDP vs batch size for AlexNet (normalized to SRAM at the
/// same batch). Returns (batch, tech, phase, edp_norm).
///
/// Rides the closed-form batch engine: the GEMM lowering runs once per
/// phase, and every batch on the axis is an O(layers) coefficient fold
/// — bit-identical to re-running `TrafficModel::run` at that batch
/// (pinned byte-for-byte in `rust/tests/cli_reports.rs`).
pub fn batch_study(batches: &[usize]) -> Vec<(usize, MemTech, Phase, f64)> {
    let caches = iso_caches();
    let traffic = TrafficModel { l2_bytes: ISO_CAPACITY, ..Default::default() };
    let dram = DramCost::default();
    let dnn = Dnn::by_name("AlexNet").expect("zoo");
    let lines = Phase::ALL.map(|phase| (phase, traffic.line(&dnn, phase)));
    let mut out = Vec::new();
    for &b in batches {
        for (phase, line) in &lines {
            let stats = line.at(b);
            let sram = evaluate(&stats, &caches[0].1, Some(dram));
            for &(tech, ppa) in &caches[1..] {
                let e = evaluate(&stats, &ppa, Some(dram));
                out.push((b, tech, *phase, e.edp() / sram.edp()));
            }
        }
    }
    out
}

/// Paper-style summary over the study rows: (mean dyn, mean leak, mean
/// energy, best edp reduction) for one technology.
pub fn summarize(rows: &[IsoCapRow], tech: MemTech) -> (f64, f64, f64, f64) {
    let sel: Vec<&IsoCapRow> = rows.iter().filter(|r| r.tech == tech).collect();
    let dyn_mean =
        crate::util::stats::mean(&sel.iter().map(|r| r.dyn_norm).collect::<Vec<_>>());
    let leak_mean =
        crate::util::stats::mean(&sel.iter().map(|r| r.leak_norm).collect::<Vec<_>>());
    let energy_mean = crate::util::stats::mean(
        &sel.iter().map(|r| r.energy_norm).collect::<Vec<_>>(),
    );
    let best_edp_red = 1.0
        / sel
            .iter()
            .map(|r| r.edp_norm)
            .fold(f64::INFINITY, f64::min);
    (dyn_mean, leak_mean, energy_mean, best_edp_red)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn study_shape_matches_paper_fig3() {
        let rows = study();
        assert_eq!(rows.len(), 5 * 2 * 2);

        // STT dynamic energy ~2.1x SRAM, SOT ~1.3x (paper averages).
        let (stt_dyn, stt_leak, _, _) = summarize(&rows, MemTech::SttMram);
        let (sot_dyn, sot_leak, _, _) = summarize(&rows, MemTech::SotMram);
        assert!((1.4..3.4).contains(&stt_dyn), "STT dyn {stt_dyn}");
        assert!((1.0..2.2).contains(&sot_dyn), "SOT dyn {sot_dyn}");
        assert!(stt_dyn > sot_dyn, "STT reads cost more than SOT");

        // Leakage: STT ~5.9x lower, SOT ~10x lower.
        assert!((1.0 / stt_leak) > 3.5, "STT leak reduction {}", 1.0 / stt_leak);
        assert!((1.0 / sot_leak) > 6.0, "SOT leak reduction {}", 1.0 / sot_leak);
        assert!(sot_leak < stt_leak);
    }

    #[test]
    fn study_shape_matches_paper_fig4() {
        let rows = study();
        // Total energy: STT ~5.1x lower, SOT ~8.6x lower (leakage
        // dominance).
        let (_, _, stt_e, stt_edp) = summarize(&rows, MemTech::SttMram);
        let (_, _, sot_e, sot_edp) = summarize(&rows, MemTech::SotMram);
        assert!((1.0 / stt_e) > 3.0, "STT energy red {}", 1.0 / stt_e);
        assert!((1.0 / sot_e) > 5.0, "SOT energy red {}", 1.0 / sot_e);
        // EDP reduction "up to 3.8x / 4.7x" (DRAM included).
        assert!((2.0..7.0).contains(&stt_edp), "STT best EDP red {stt_edp}");
        assert!((2.5..9.0).contains(&sot_edp), "SOT best EDP red {sot_edp}");
    }

    #[test]
    fn sram_read_share_near_83_percent() {
        let rows = study();
        let shares: Vec<f64> = rows.iter().map(|r| r.sram_read_share).collect();
        let mean = crate::util::stats::mean(&shares);
        assert!((0.70..0.92).contains(&mean), "read share {mean}");
    }

    #[test]
    fn batch_study_trends() {
        // Paper Fig 5: training EDP reduction improves with batch for
        // STT; all reductions stay > 1 (MRAM wins at every batch).
        let rows = batch_study(&[4, 16, 64, 128]);
        for &(b, tech, ph, norm) in &rows {
            assert!(
                norm < 1.0,
                "{tech} {} b={b}: EDP norm {norm} >= 1",
                ph.name()
            );
        }
        let stt_train: Vec<f64> = rows
            .iter()
            .filter(|(_, t, p, _)| *t == MemTech::SttMram && *p == Phase::Training)
            .map(|&(_, _, _, n)| 1.0 / n)
            .collect();
        assert!(
            stt_train.last().unwrap() > stt_train.first().unwrap(),
            "STT training EDP reduction must grow with batch: {stt_train:?}"
        );
    }
}
