//! The cross-layer analyses — DeepNVM++'s end products (paper §IV).
//!
//! Combines the device-calibrated cache PPA (nvsim), the workload
//! memory statistics (workload/traffic, standing in for nvprof) and the
//! hierarchy simulation (gpusim, standing in for GPGPU-Sim) into the
//! paper's studies:
//!
//! * [`energy`] — the paper's evaluation model: "multiply the number of
//!   read and write transactions by the corresponding latency and
//!   energy values", leakage power x runtime, optional DRAM terms.
//! * [`iso_capacity`] — Figs 3-5: 3 MB MRAM replacing 3 MB SRAM.
//! * [`iso_area`] — Figs 6-8: 7 MB STT / 10 MB SOT in SRAM's footprint,
//!   with gpusim-measured DRAM-access reduction.
//! * [`scalability`] — Figs 9-10: 1-32 MB sweep, EDAP-optimal per
//!   capacity.
//! * [`trend`] — Fig 1: the public NVIDIA L2-capacity trend.

pub mod area_reuse;
pub mod energy;
pub mod iso_area;
pub mod iso_capacity;
pub mod mobile;
pub mod scalability;
pub mod trend;

pub use energy::{evaluate, DramCost};
