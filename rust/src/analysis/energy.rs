//! The paper's evaluation model (§III-B): transaction-weighted energy
//! and latency, leakage x runtime, optional DRAM terms.

use crate::nvsim::CachePpa;
use crate::workload::traffic::WorkloadStats;

/// Per-transaction DRAM cost (32 B). Defaults follow the gpusim DRAM
/// timing model with 11-channel overlap; the energy figure is in line
/// with the Eyeriss relative-cost ladder the paper cites (DRAM ~200x a
/// MAC, global buffer ~6x).
#[derive(Clone, Copy, Debug)]
pub struct DramCost {
    pub energy_per_tx: f64,
    pub latency_per_tx: f64,
}

impl Default for DramCost {
    fn default() -> Self {
        DramCost {
            energy_per_tx: 3.8e-9,
            // 15 ns row hit / 11 channels of overlap
            latency_per_tx: 15e-9 / 11.0,
        }
    }
}

/// Energy/latency breakdown of one workload on one cache design.
#[derive(Clone, Copy, Debug, Default)]
pub struct Evaluation {
    /// Dynamic L2 energy, reads / writes (J).
    pub dyn_read: f64,
    pub dyn_write: f64,
    /// Leakage energy over the (cache-only) runtime (J).
    pub leakage: f64,
    /// DRAM energy (J), zero when DRAM is excluded.
    pub dram_energy: f64,
    /// Cache-only runtime (s): R x read_lat + W x write_lat.
    pub time_cache: f64,
    /// Runtime including DRAM service time (s).
    pub time_total: f64,
}

impl Evaluation {
    pub fn dynamic(&self) -> f64 {
        self.dyn_read + self.dyn_write
    }

    /// Total energy (J).
    pub fn energy(&self) -> f64 {
        self.dynamic() + self.leakage + self.dram_energy
    }

    /// Energy-delay product (J*s).
    pub fn edp(&self) -> f64 {
        self.energy() * self.time_total
    }

    /// Share of dynamic energy carried by reads (paper: ~83% for SRAM).
    pub fn read_share(&self) -> f64 {
        self.dyn_read / self.dynamic().max(f64::MIN_POSITIVE)
    }
}

/// Evaluate `stats` against cache `ppa`. `dram`: include off-chip terms
/// (Fig 4 EDP and all iso-area results include them; Fig 3 and the
/// left chart of Fig 8 exclude them).
pub fn evaluate(
    stats: &WorkloadStats,
    ppa: &CachePpa,
    dram: Option<DramCost>,
) -> Evaluation {
    let r = stats.l2_reads as f64;
    let w = stats.l2_writes as f64;
    let time_cache = r * ppa.read_latency + w * ppa.write_latency;

    let (dram_energy, dram_time) = match dram {
        Some(d) => {
            let tx = stats.dram_total() as f64;
            (tx * d.energy_per_tx, tx * d.latency_per_tx)
        }
        None => (0.0, 0.0),
    };
    let time_total = time_cache + dram_time;
    Evaluation {
        dyn_read: r * ppa.read_energy,
        dyn_write: w * ppa.write_energy,
        // leakage accrues over the whole execution window
        leakage: ppa.leakage_power * time_total,
        dram_energy,
        time_cache,
        time_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::MemTech;
    use crate::nvsim::explorer::tuned_cache;
    use crate::workload::models::{Dnn, Phase};
    use crate::workload::traffic::TrafficModel;

    const MB: u64 = 1024 * 1024;

    fn stats() -> WorkloadStats {
        TrafficModel::default()
            .run_paper(&Dnn::by_name("AlexNet").unwrap(), Phase::Inference)
    }

    #[test]
    fn sram_read_share_matches_paper() {
        // Paper: "83% of the total dynamic energy of SRAM comes from
        // read operations ... on average across all workloads".
        let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
        let m = TrafficModel::default();
        let mut shares = vec![];
        for d in Dnn::zoo() {
            for ph in Phase::ALL {
                let e = evaluate(&m.run_paper(&d, ph), &sram, None);
                shares.push(e.read_share());
            }
        }
        let mean = crate::util::stats::mean(&shares);
        assert!((0.70..0.92).contains(&mean), "read share {mean}");
    }

    #[test]
    fn leakage_dominates_sram_energy() {
        // The paper's central observation: with SRAM's ~6.4 W leaking
        // over the runtime, leakage energy dwarfs dynamic energy.
        let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
        let e = evaluate(&stats(), &sram, None);
        assert!(e.leakage > 5.0 * e.dynamic(), "leak {} dyn {}", e.leakage, e.dynamic());
    }

    #[test]
    fn dram_terms_only_when_requested() {
        let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
        let without = evaluate(&stats(), &sram, None);
        let with = evaluate(&stats(), &sram, Some(DramCost::default()));
        assert_eq!(without.dram_energy, 0.0);
        assert!(with.dram_energy > 0.0);
        assert!(with.time_total > without.time_total);
        assert!(with.edp() > without.edp());
    }

    #[test]
    fn evaluation_identities() {
        let sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
        let e = evaluate(&stats(), &sram, Some(DramCost::default()));
        assert!((e.energy() - (e.dynamic() + e.leakage + e.dram_energy)).abs() < 1e-12);
        assert!(e.read_share() > 0.0 && e.read_share() < 1.0);
        assert!((e.edp() - e.energy() * e.time_total).abs() < 1e-15);
    }
}
